# AOT driver: lower the L2 model to HLO *text* for the rust PJRT runtime.
#
# HLO text (NOT lowered.compiler_ir("hlo") protos or .serialize()) is the
# interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
# instruction ids which the xla crate's xla_extension 0.5.1 rejects
# (`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
# cleanly.  See /opt/xla-example/gen_hlo.py and its README.
#
# Usage:  cd python && python -m compile.aot --out ../artifacts/compress_analysis.hlo.txt

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_analyze_groups() -> str:
    spec = jax.ShapeDtypeStruct((model.GROUPS, 4, 16), jnp.uint32)
    lowered = jax.jit(model.analyze_groups).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts/compress_analysis.hlo.txt",
        help="output HLO text path",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    text = lower_analyze_groups()
    out.write_text(text)
    print(f"wrote {len(text)} chars to {out} (groups={model.GROUPS})")


if __name__ == "__main__":
    main()
