# L2: the jax compute graph the rust coordinator executes via PJRT.
#
# The paper's "model" is the memory controller's compression-analysis
# pipeline: a batch of 4-line groups flows through the L1 kernel (per-line
# FPC/BDI/hybrid sizes) and then through the group-layout decision (Fig. 6
# of the paper), producing per-group CSI codes the controller uses to pack
# lines and to drive markers/LLP training.
#
# `analyze_groups` is lowered ONCE by aot.py to artifacts/*.hlo.txt and is
# never called from python at runtime.  The rust hot loop has a native port
# of the same math (rust/src/compress/) for per-access decisions; the AOT
# artifact is the batched analysis engine (workload characterization, Fig. 4
# compressibility sweeps) and the cross-language parity anchor.

import jax.numpy as jnp

from .kernels import fpc_bdi
from .kernels.ref import (
    CSI_PAIR_AB,
    CSI_PAIR_BOTH,
    CSI_PAIR_CD,
    CSI_QUAD,
    CSI_UNCOMPRESSED,
    PAIR_BUDGET,
)

# Batch geometry of the AOT artifact.  The rust runtime pads every request
# up to this group count (GROUPS * 4 lines = 4096 lines per execute call).
GROUPS = 1024


def csi_from_sizes(sizes):
    """Group layout decision.  sizes: int32[..., 4] hybrid bytes -> CSI."""
    total = jnp.sum(sizes, axis=-1)
    ab = (sizes[..., 0] + sizes[..., 1]) <= PAIR_BUDGET
    cd = (sizes[..., 2] + sizes[..., 3]) <= PAIR_BUDGET
    csi = jnp.where(
        ab & cd,
        CSI_PAIR_BOTH,
        jnp.where(ab, CSI_PAIR_AB, jnp.where(cd, CSI_PAIR_CD, CSI_UNCOMPRESSED)),
    )
    return jnp.where(total <= PAIR_BUDGET, CSI_QUAD, csi).astype(jnp.int32)


def analyze_groups(groups):
    """uint32[G, 4, 16] -> (csi int32[G], sizes int32[G, 4]).

    csi: packing decision per group (0..4, see kernels/ref.py docstring).
    sizes: per-line hybrid FPC+BDI compressed size in bytes (64 = raw).
    """
    g = groups.shape[0]
    lines = groups.reshape(g * 4, 16)
    sizes = fpc_bdi.line_sizes(lines)[:, 2].reshape(g, 4)
    return csi_from_sizes(sizes), sizes
