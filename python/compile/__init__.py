# Build-time compile package: L2 jax model + L1 pallas kernels + AOT driver.
# Python here runs ONCE (`make artifacts`) and never on the request path.
#
# The BDI delta math needs 64-bit integer lanes, so x64 must be enabled
# before any jax array is created.  Importing anything from this package
# guarantees that.
import jax

jax.config.update("jax_enable_x64", True)
