# L1: Pallas kernel(s) for the paper's compute hot-spot — batched FPC+BDI
# compressibility analysis of 64-byte cachelines.  `ref` is the pure-jnp
# oracle and the canonical spec; `fpc_bdi` is the Pallas implementation.
from . import fpc_bdi, ref  # noqa: F401
