# L1 Pallas kernel: batched FPC+BDI compressibility analysis.
#
# Input : uint32[N, 16]  — N cachelines, 16 little-endian u32 words each.
# Output: int32 [N, 3]   — (fpc_bytes, bdi_bytes, hybrid_bytes) per line.
#
# The size model is specified in ref.py (the pure-jnp oracle); this kernel
# must agree bit-for-bit (pytest: python/tests/test_kernel.py).
#
# TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's hot-spot is a
# memory-controller compression pipeline; here it is reshaped as a streaming
# VPU kernel.  Lines are tiled N-major with BLOCK=256 lines per grid step
# (= 16 KiB of input in VMEM, far under budget); every FPC class test and
# BDI delta check is an elementwise vector integer op, there is no matmul
# (MXU is idle by construction) and no scalar loop, so the kernel is purely
# bandwidth-bound: 64 B in + 12 B out per line.
#
# interpret=True is mandatory on this CPU-PJRT setup: real TPU lowering
# emits a Mosaic custom-call the CPU plugin cannot execute.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256  # lines per grid step; 256*64B = 16 KiB input tile


def _se_ok32(v, bits):
    """v (int32) is a sign-extended `bits`-bit value, via shift round-trip."""
    sh = 32 - bits
    return ((v << sh) >> sh) == v


def _se_ok64(v, bits):
    sh = 64 - bits
    return ((v << sh) >> sh) == v


def _fpc_bits(w):
    """FPC data bits per u32 word.  w: uint32[...]."""
    i = w.astype(jnp.int32)
    bits = jnp.full(w.shape, 32, jnp.int32)
    lo = (w & jnp.uint32(0xFFFF)).astype(jnp.int32)
    hi = (w >> 16).astype(jnp.int32)
    lo16 = (lo << 16) >> 16  # as signed 16-bit
    hi16 = (hi << 16) >> 16
    bits = jnp.where(_se_ok32(lo16, 8) & _se_ok32(hi16, 8), 16, bits)
    bits = jnp.where(lo == 0, 16, bits)  # halfword padded with zero half
    bits = jnp.where(_se_ok32(i, 16), 16, bits)
    bits = jnp.where(_se_ok32(i, 8), 8, bits)
    b = w & jnp.uint32(0xFF)
    rep = (b | (b << 8) | (b << 16) | (b << 24)) == w  # all four bytes equal
    bits = jnp.where(rep, 8, bits)
    bits = jnp.where(_se_ok32(i, 4), 4, bits)
    bits = jnp.where(w == 0, 0, bits)
    return bits


def _bdi_fits(x, width, bits):
    """All wrapping deltas (x - x[..., :1]) at element `width` fit in `bits`
    signed bits.  x: int64[..., n]."""
    d = x - x[..., :1]
    if width < 64:
        d = d & jnp.int64((1 << width) - 1)
        d = (d << (64 - width)) >> (64 - width)  # sign-extend width-bit value
    return jnp.all(_se_ok64(d, bits), axis=-1)


def _sizes_kernel(lines_ref, out_ref):
    w = lines_ref[...]  # uint32[BLOCK, 16]

    # --- FPC ---
    fpc_bits = jnp.sum(3 + _fpc_bits(w), axis=-1)
    fpc = ((fpc_bits + 7) // 8).astype(jnp.int32)

    # --- BDI ---
    w64 = w.astype(jnp.int64)
    q = w64[:, 0::2] | (w64[:, 1::2] << 32)  # int64[BLOCK, 8]
    # u16 halfwords in little-endian order (base = halfword 0 of the line)
    h = jnp.stack([w64 & jnp.int64(0xFFFF), w64 >> 16], axis=-1).reshape(
        w.shape[0], 32
    )

    bdi = jnp.full((w.shape[0],), 64, jnp.int32)
    bdi = jnp.where(_bdi_fits(q, 64, 32), 40, bdi)  # base8-delta4
    bdi = jnp.where(_bdi_fits(w64, 32, 16), 36, bdi)  # base4-delta2
    bdi = jnp.where(_bdi_fits(h, 16, 8), 34, bdi)  # base2-delta1
    bdi = jnp.where(_bdi_fits(q, 64, 16), 24, bdi)  # base8-delta2
    bdi = jnp.where(_bdi_fits(w64, 32, 8), 20, bdi)  # base4-delta1
    bdi = jnp.where(_bdi_fits(q, 64, 8), 16, bdi)  # base8-delta1
    bdi = jnp.where(jnp.all(q == q[:, :1], axis=-1), 8, bdi)  # rep8
    bdi = jnp.where(jnp.all(q == 0, axis=-1), 1, bdi)  # zeros

    hybrid = jnp.minimum(64, 1 + jnp.minimum(fpc, bdi))
    out_ref[...] = jnp.stack([fpc, bdi, hybrid], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def line_sizes(lines):
    """uint32[N, 16] -> int32[N, 3] of (fpc, bdi, hybrid) bytes.

    N must be a multiple of BLOCK for the AOT artifact; the jit wrapper pads
    and slices for ad-hoc shapes (tests call it with arbitrary N).
    """
    n = lines.shape[0]
    pad = (-n) % BLOCK
    padded = jnp.pad(lines, ((0, pad), (0, 0)))
    np_ = padded.shape[0]
    out = pl.pallas_call(
        _sizes_kernel,
        grid=(np_ // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK, 16), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 3), jnp.int32),
        interpret=True,
    )(padded)
    return out[:n]


def hybrid_size_bytes(lines):
    """uint32[..., 16] -> int32[...] hybrid sizes (kernel-backed)."""
    flat = lines.reshape(-1, 16)
    return line_sizes(flat)[:, 2].reshape(lines.shape[:-1])
