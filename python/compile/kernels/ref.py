# Pure-jnp correctness oracle for the FPC+BDI compressibility kernel.
#
# This file is the CANONICAL SPECIFICATION of the compressed-size model used
# across the whole repo.  Three implementations must agree bit-for-bit:
#   1. this oracle (pure jnp, written for obviousness, not speed),
#   2. the Pallas kernel in fpc_bdi.py (vectorized, interpret=True),
#   3. the rust-native port in rust/src/compress/ (used in the simulator
#      hot loop; parity-tested against the AOT HLO artifact in rust tests).
#
# --- Size model -------------------------------------------------------------
#
# A cacheline is 64 bytes = sixteen little-endian u32 words.
#
# FPC (Frequent Pattern Compression, Alameldeen & Wood, per-word 3-bit
# prefix).  Data bits per 32-bit word = min over the applicable classes:
#   zero word                         -> 0
#   4-bit  sign-extended              -> 4
#   repeated bytes (b0=b1=b2=b3)      -> 8
#   8-bit  sign-extended              -> 8
#   16-bit sign-extended              -> 16
#   halfword padded with zero half    -> 16   (low 16 bits are zero)
#   two halfwords, each 8-bit SE      -> 16
#   uncompressible word               -> 32
# fpc_bytes = ceil(sum_w (3 + databits(w)) / 8)
#
# BDI (Base-Delta-Immediate, Pekhimenko et al., single arbitrary base = first
# element).  bdi_bytes = min over the applicable encodings:
#   zeros  (all u64 == 0)                     -> 1
#   rep8   (all u64 equal)                    -> 8
#   base8-delta1 / delta2 / delta4            -> 8  + 8*{1,2,4} = 16/24/40
#   base4-delta1 / delta2  (u32 granularity)  -> 4  + 16*{1,2}  = 20/36
#   base2-delta1           (u16 granularity)  -> 2  + 32*1      = 34
#   uncompressible line                       -> 64
# Deltas are wrapping subtractions at the element width from the base
# (= element 0) and must fit as sign-extended k-byte values.
#
# Hybrid FPC+BDI (what CRAM stores): 1 byte of in-line header selecting the
# algorithm + its parameters, so
#   hybrid_bytes = min(64, 1 + min(fpc_bytes, bdi_bytes))
# A value of 64 means "stored uncompressed" (raw line, no header).
#
# --- Group layout / CSI -----------------------------------------------------
#
# Groups of 4 consecutive lines [A,B,C,D] (line address ends 00,01,10,11).
# A compressed physical line reserves 4 bytes for the marker, so the budget
# is 60 bytes.  CSI encoding (must match rust/src/cram/group.rs):
#   0 = all uncompressed
#   1 = A+B packed at slot A, C and D uncompressed
#   2 = C+D packed at slot C, A and B uncompressed
#   3 = A+B packed at slot A and C+D packed at slot C
#   4 = A+B+C+D packed at slot A (4:1)
# Decision: 4:1 if sum(sizes) <= 60, else each pair independently if
# size_x + size_y <= 60.

import jax.numpy as jnp

MARKER_RESERVE = 4  # bytes reserved at the tail of a compressed line
PAIR_BUDGET = 64 - MARKER_RESERVE  # = 60

CSI_UNCOMPRESSED = 0
CSI_PAIR_AB = 1
CSI_PAIR_CD = 2
CSI_PAIR_BOTH = 3
CSI_QUAD = 4


def _se_fits(v, bits):
    """True if signed value v fits in `bits` bits (sign-extended)."""
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return (v >= lo) & (v <= hi)


def fpc_word_bits(w):
    """Data bits for one u32 word under FPC.  w: uint32 array."""
    w = w.astype(jnp.uint32)
    i = w.astype(jnp.int32)
    b0 = w & 0xFF
    b1 = (w >> 8) & 0xFF
    b2 = (w >> 16) & 0xFF
    b3 = (w >> 24) & 0xFF
    lo_half = (w & 0xFFFF).astype(jnp.int32)
    hi_half = ((w >> 16) & 0xFFFF).astype(jnp.int32)
    # interpret halves as signed 16-bit
    lo_s = jnp.where(lo_half >= 0x8000, lo_half - 0x10000, lo_half)
    hi_s = jnp.where(hi_half >= 0x8000, hi_half - 0x10000, hi_half)

    bits = jnp.full(w.shape, 32, dtype=jnp.int32)
    # Assign from widest to narrowest so the final value is the minimum
    # applicable class.
    two_half_se8 = _se_fits(lo_s, 8) & _se_fits(hi_s, 8)
    bits = jnp.where(two_half_se8, 16, bits)
    half_pad_zero = (w & 0xFFFF) == 0
    bits = jnp.where(half_pad_zero, 16, bits)
    bits = jnp.where(_se_fits(i, 16), 16, bits)
    bits = jnp.where(_se_fits(i, 8), 8, bits)
    rep_bytes = (b0 == b1) & (b1 == b2) & (b2 == b3)
    bits = jnp.where(rep_bytes, 8, bits)
    bits = jnp.where(_se_fits(i, 4), 4, bits)
    bits = jnp.where(w == 0, 0, bits)
    return bits


def fpc_size_bytes(lines):
    """FPC compressed size in bytes.  lines: uint32[..., 16]."""
    bits = fpc_word_bits(lines)  # [..., 16]
    total = jnp.sum(3 + bits, axis=-1)
    return (total + 7) // 8


def _as_u64(lines):
    """uint32[..., 16] -> int64[..., 8] little-endian (u64 values carried
    in int64 two's complement)."""
    lo = lines.astype(jnp.int64)[..., 0::2]
    hi = lines.astype(jnp.int64)[..., 1::2]
    return lo | (hi << 32)


def _as_u16(lines):
    """uint32[..., 16] -> int64[..., 32] of u16 halfwords, little-endian."""
    lo = (lines & 0xFFFF).astype(jnp.int64)
    hi = ((lines >> 16) & 0xFFFF).astype(jnp.int64)
    return jnp.stack([lo, hi], axis=-1).reshape(*lines.shape[:-1], 32)


def _deltas_fit(x, width, bits):
    """Wrapping (x - x[0]) at element `width` bits fits sign-extended `bits`.
    x: int64[..., n] holding unsigned `width`-bit values."""
    d = x - x[..., :1]
    if width < 64:
        mask = jnp.int64((1 << width) - 1)
        d = d & mask
        sign = jnp.int64(1) << (width - 1)
        d = jnp.where(d >= sign, d - (jnp.int64(1) << width), d)
    # width == 64: int64 two's-complement subtraction already wraps.
    shift = 64 - bits
    return jnp.all(((d << shift) >> shift) == d, axis=-1)


def bdi_size_bytes(lines):
    """BDI compressed size in bytes.  lines: uint32[..., 16]."""
    q = _as_u64(lines)  # [..., 8]
    w = lines.astype(jnp.int64)  # [..., 16] u32 values
    h = _as_u16(lines)  # [..., 32]

    size = jnp.full(lines.shape[:-1], 64, dtype=jnp.int32)
    # Assign from worst (largest) to best (smallest) size.
    size = jnp.where(_deltas_fit(q, 64, 32), 40, size)  # base8-delta4
    size = jnp.where(_deltas_fit(w, 32, 16), 36, size)  # base4-delta2
    size = jnp.where(_deltas_fit(h, 16, 8), 34, size)  # base2-delta1
    size = jnp.where(_deltas_fit(q, 64, 16), 24, size)  # base8-delta2
    size = jnp.where(_deltas_fit(w, 32, 8), 20, size)  # base4-delta1
    size = jnp.where(_deltas_fit(q, 64, 8), 16, size)  # base8-delta1
    size = jnp.where(jnp.all(q == q[..., :1], axis=-1), 8, size)  # rep8
    size = jnp.where(jnp.all(q == 0, axis=-1), 1, size)  # zeros
    return size


def hybrid_size_bytes(lines):
    """Hybrid FPC+BDI size: 1-byte header + best algorithm, capped at 64
    (=stored raw).  lines: uint32[..., 16] -> int32[...]."""
    fpc = fpc_size_bytes(lines).astype(jnp.int32)
    bdi = bdi_size_bytes(lines).astype(jnp.int32)
    return jnp.minimum(64, 1 + jnp.minimum(fpc, bdi))


def line_sizes(lines):
    """Reference for the kernel output: uint32[N,16] -> int32[N,3] of
    (fpc_bytes, bdi_bytes, hybrid_bytes)."""
    return jnp.stack(
        [
            fpc_size_bytes(lines).astype(jnp.int32),
            bdi_size_bytes(lines).astype(jnp.int32),
            hybrid_size_bytes(lines),
        ],
        axis=-1,
    )


def csi_decision(sizes):
    """Group CSI from per-line hybrid sizes.  sizes: int32[..., 4]."""
    total = jnp.sum(sizes, axis=-1)
    quad = total <= PAIR_BUDGET
    ab = (sizes[..., 0] + sizes[..., 1]) <= PAIR_BUDGET
    cd = (sizes[..., 2] + sizes[..., 3]) <= PAIR_BUDGET
    csi = jnp.where(
        ab & cd,
        CSI_PAIR_BOTH,
        jnp.where(ab, CSI_PAIR_AB, jnp.where(cd, CSI_PAIR_CD, CSI_UNCOMPRESSED)),
    )
    return jnp.where(quad, CSI_QUAD, csi).astype(jnp.int32)


def analyze_groups(groups):
    """Reference for the L2 model: uint32[G,4,16] -> (csi int32[G],
    sizes int32[G,4] of hybrid bytes)."""
    sizes = hybrid_size_bytes(groups)
    return csi_decision(sizes), sizes
