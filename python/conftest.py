# Make `compile.*` importable when pytest runs from the repo root
# (python -m pytest python/tests -q), matching the documented
# `cd python && python -m compile.aot` layout.
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
