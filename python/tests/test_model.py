# L2 model tests: group CSI decisions vs the oracle + hand-pinned layouts.

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def groups_of(lines):
    """(n*4, 16) -> (n, 4, 16)."""
    return np.asarray(lines, dtype=np.uint32).reshape(-1, 4, 16)


def test_csi_all_zero_group():
    g = groups_of(np.zeros((4, 16), dtype=np.uint32))
    csi, sizes = model.analyze_groups(g)
    # each zero line -> hybrid 2 bytes; 4*2=8 <= 60 -> 4:1
    assert int(csi[0]) == ref.CSI_QUAD
    assert list(np.asarray(sizes[0])) == [2, 2, 2, 2]


def test_csi_incompressible_group():
    rng = np.random.default_rng(5)
    g = rng.integers(1 << 28, 1 << 31, size=(1, 4, 16), dtype=np.uint32)
    # xor-scramble to defeat accidental classes
    g = g ^ (np.arange(16, dtype=np.uint32) * np.uint32(0x9E3779B9) + np.uint32(1))
    csi, sizes = model.analyze_groups(g.astype(np.uint32))
    assert int(csi[0]) == ref.CSI_UNCOMPRESSED


def test_csi_pair_ab_only():
    zero = np.zeros(16, dtype=np.uint32)
    rng = np.random.default_rng(9)
    incompressible = (
        rng.integers(1 << 28, 1 << 31, size=(2, 16), dtype=np.uint32)
        ^ (np.arange(16, dtype=np.uint32) * np.uint32(0x9E3779B9) + np.uint32(1))
    ).astype(np.uint32)
    g = groups_of(np.stack([zero, zero, incompressible[0], incompressible[1]]))
    csi, _ = model.analyze_groups(g)
    assert int(csi[0]) == ref.CSI_PAIR_AB


def test_csi_pair_cd_only():
    zero = np.zeros(16, dtype=np.uint32)
    rng = np.random.default_rng(9)
    bad = (
        rng.integers(1 << 28, 1 << 31, size=(2, 16), dtype=np.uint32)
        ^ (np.arange(16, dtype=np.uint32) * np.uint32(0x9E3779B9) + np.uint32(1))
    ).astype(np.uint32)
    g = groups_of(np.stack([bad[0], bad[1], zero, zero]))
    csi, _ = model.analyze_groups(g)
    assert int(csi[0]) == ref.CSI_PAIR_CD


def test_csi_both_pairs_not_quad():
    # Four lines, each hybrid size ~17 (base8-delta1): pairs fit (34<=60)
    # but the quad does not (68>60) -> CSI_PAIR_BOTH.
    lines = []
    for k in range(4):
        base = np.uint64(0x1000_0000_0000_0000 + (k << 32))
        q = np.array([base + np.uint64(d) for d in range(8)], dtype=np.uint64)
        lines.append(q.view(np.uint32))
    g = groups_of(np.stack(lines))
    csi, sizes = model.analyze_groups(g)
    s = np.asarray(sizes[0])
    assert list(s) == [17, 17, 17, 17]
    assert int(csi[0]) == ref.CSI_PAIR_BOTH


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64))
def test_model_matches_oracle(seed, n):
    rng = np.random.default_rng(seed)
    regs = ["uniform", "zeros", "small", "rep"]
    lines = []
    for _ in range(n * 4):
        r = regs[rng.integers(0, len(regs))]
        if r == "uniform":
            lines.append(rng.integers(0, 2**32, 16, dtype=np.uint32))
        elif r == "zeros":
            lines.append(np.zeros(16, dtype=np.uint32))
        elif r == "small":
            lines.append(rng.integers(0, 128, 16).astype(np.uint32))
        else:
            b = np.uint32(rng.integers(0, 256))
            lines.append(np.full(16, b | (b << 8) | (b << 16) | (b << 24), dtype=np.uint32))
    g = groups_of(np.stack(lines))
    csi_m, sizes_m = model.analyze_groups(g)
    csi_r, sizes_r = ref.analyze_groups(g)
    np.testing.assert_array_equal(np.asarray(csi_m), np.asarray(csi_r))
    np.testing.assert_array_equal(np.asarray(sizes_m), np.asarray(sizes_r))


def test_aot_lowering_produces_hlo_text():
    from compile import aot

    text = aot.lower_analyze_groups()
    assert "HloModule" in text
    # entry signature: u32[GROUPS,4,16] -> (s32[GROUPS], s32[GROUPS,4])
    assert f"u32[{model.GROUPS},4,16]" in text.replace(" ", "")
