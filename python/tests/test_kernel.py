# Kernel vs oracle parity — the CORE correctness signal for L1.
#
# The pallas kernel (compile.kernels.fpc_bdi) and the pure-jnp oracle
# (compile.kernels.ref) must agree EXACTLY (integer sizes, no tolerance) on
# every value regime the simulator generates.  Hand-computed cases pin the
# spec itself; hypothesis sweeps shapes and value regimes.

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fpc_bdi, ref

# ---------------------------------------------------------------------------
# value-regime generators (mirror rust/src/workloads value models)
# ---------------------------------------------------------------------------


def lines_from(rng, regime, n):
    """Generate n cachelines (n,16) u32 under a named value regime."""
    if regime == "uniform":
        return rng.integers(0, 2**32, size=(n, 16), dtype=np.uint32)
    if regime == "zeros":
        return np.zeros((n, 16), dtype=np.uint32)
    if regime == "small_ints":
        return rng.integers(0, 256, size=(n, 16)).astype(np.uint32)
    if regime == "small_signed":
        v = rng.integers(-8, 8, size=(n, 16))
        return v.astype(np.int32).view(np.uint32).reshape(n, 16)
    if regime == "rep_bytes":
        b = rng.integers(0, 256, size=(n, 1), dtype=np.uint32)
        w = b | (b << 8) | (b << 16) | (b << 24)
        return np.broadcast_to(w, (n, 16)).copy().astype(np.uint32)
    if regime == "base_delta8":
        base = rng.integers(0, 2**63, size=(n, 1), dtype=np.uint64)
        delta = rng.integers(-100, 100, size=(n, 8)).astype(np.int64)
        q = (base + delta.view(np.uint64)).astype(np.uint64)
        return q.view(np.uint32).reshape(n, 16)
    if regime == "base_delta4":
        base = rng.integers(0, 2**31, size=(n, 1), dtype=np.uint32)
        delta = rng.integers(-100, 100, size=(n, 16)).astype(np.int32)
        return (base.astype(np.int64) + delta).astype(np.uint32)
    if regime == "half_zero":
        hi = rng.integers(0, 2**16, size=(n, 16), dtype=np.uint32)
        return (hi << 16).astype(np.uint32)
    if regime == "mixed":
        parts = [
            lines_from(rng, r, max(1, n // 6))
            for r in ("uniform", "zeros", "small_ints", "rep_bytes", "base_delta8", "half_zero")
        ]
        out = np.concatenate(parts, axis=0)[:n]
        if out.shape[0] < n:
            out = np.concatenate([out, lines_from(rng, "uniform", n - out.shape[0])])
        return out
    raise ValueError(regime)


REGIMES = [
    "uniform",
    "zeros",
    "small_ints",
    "small_signed",
    "rep_bytes",
    "base_delta8",
    "base_delta4",
    "half_zero",
    "mixed",
]

# ---------------------------------------------------------------------------
# hand-computed spec pins
# ---------------------------------------------------------------------------


def hybrid_of(line16):
    out = np.asarray(fpc_bdi.line_sizes(np.asarray([line16], dtype=np.uint32)))
    return out[0]


def test_zero_line_sizes():
    fpc, bdi, hyb = hybrid_of([0] * 16)
    # FPC: 16 words * (3 prefix + 0 data) = 48 bits = 6 bytes
    assert fpc == 6
    # BDI zeros encoding = 1 byte
    assert bdi == 1
    # hybrid = 1 header + min(6,1) = 2
    assert hyb == 2


def test_small_positive_words():
    fpc, bdi, hyb = hybrid_of([7] * 16)
    # FPC: 4-bit SE per word: 16*(3+4) = 112 bits = 14 bytes
    assert fpc == 14
    # BDI: u64s all equal 0x0000000700000007 -> rep8 = 8 bytes
    assert bdi == 8
    assert hyb == 9


def test_repeated_bytes_word():
    fpc, bdi, hyb = hybrid_of([0x41414141] * 16)
    # FPC: repeated-bytes class: 16*(3+8) = 176 bits = 22 bytes
    assert fpc == 22
    assert bdi == 8  # rep8
    assert hyb == 9


def test_half_zero_word():
    # 0xABCD0000: low half zero -> 16 data bits; not 16-bit SE.
    fpc, bdi, hyb = hybrid_of([0xABCD0000] * 16)
    assert fpc == (16 * (3 + 16) + 7) // 8  # 38
    assert bdi == 8  # all u64 equal -> rep8


def test_neg_one_words():
    # 0xFFFFFFFF = -1: 4-bit sign-extended.
    fpc, bdi, hyb = hybrid_of([0xFFFFFFFF] * 16)
    assert fpc == 14
    assert bdi == 8


def test_base8_delta1_line():
    base = 0x1234_5678_9ABC_DE00
    qwords = np.array([base + d for d in range(8)], dtype=np.uint64)
    line = qwords.view(np.uint32)
    fpc, bdi, hyb = hybrid_of(line)
    assert bdi == 16  # 8-byte base + 8 1-byte deltas
    assert hyb == 17


def test_base8_delta2_line():
    base = 0x1234_5678_9ABC_DE00
    qwords = np.array([base + 200 * d for d in range(8)], dtype=np.uint64)
    line = qwords.view(np.uint32)
    _, bdi, _ = hybrid_of(line)
    assert bdi == 24


def test_incompressible_line():
    rng = np.random.default_rng(7)
    line = rng.integers(2**28, 2**32 - 2**28, size=16, dtype=np.uint32)
    # Force word diversity so no class applies.
    line = line | 0x01010101
    line = np.array(
        [w ^ (0x9E3779B9 * (i + 1) & 0xFFFFFFFF) for i, w in enumerate(line)],
        dtype=np.uint32,
    )
    fpc, bdi, hyb = hybrid_of(line)
    assert hyb == 64 or hyb == min(64, 1 + min(fpc, bdi))


def test_pair_budget_constant():
    assert ref.PAIR_BUDGET == 60
    assert ref.MARKER_RESERVE == 4


# ---------------------------------------------------------------------------
# kernel vs oracle parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("regime", REGIMES)
def test_parity_regimes(regime):
    rng = np.random.default_rng(hash(regime) % 2**32)
    lines = lines_from(rng, regime, 500)
    got = np.asarray(fpc_bdi.line_sizes(lines))
    want = np.asarray(ref.line_sizes(lines))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 700),
    regime=st.sampled_from(REGIMES),
)
def test_parity_hypothesis(seed, n, regime):
    rng = np.random.default_rng(seed)
    lines = lines_from(rng, regime, n)
    got = np.asarray(fpc_bdi.line_sizes(lines))
    want = np.asarray(ref.line_sizes(lines))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    words=st.lists(st.integers(0, 2**32 - 1), min_size=16, max_size=16),
)
def test_parity_adversarial_single_line(words):
    """Arbitrary bit patterns, including boundary values hypothesis finds."""
    line = np.array([words], dtype=np.uint32)
    got = np.asarray(fpc_bdi.line_sizes(line))
    want = np.asarray(ref.line_sizes(line))
    np.testing.assert_array_equal(got, want)


def test_parity_boundary_values():
    """Sign-extension boundary words for every FPC class edge."""
    edges = [
        0, 1, 7, 8, 0xFFFFFFF8, 0xFFFFFFF7,  # 4-bit SE edges
        127, 128, 0xFFFFFF80, 0xFFFFFF7F,  # 8-bit
        32767, 32768, 0xFFFF8000, 0xFFFF7FFF,  # 16-bit
        0x00010000, 0x7FFF0000, 0x80000000, 0xFFFF0000,  # half-zero
        0x007F007F, 0x0080007F, 0xFF80FF80, 0xFF7FFF80,  # two-half SE8
        0xAAAAAAAA, 0xABABABAB,  # rep bytes
    ]
    rng = np.random.default_rng(3)
    lines = []
    for e in edges:
        line = rng.integers(0, 2**32, size=16, dtype=np.uint32)
        line[rng.integers(0, 16)] = e
        lines.append(line)
        lines.append(np.full(16, e, dtype=np.uint32))
    lines = np.stack(lines)
    np.testing.assert_array_equal(
        np.asarray(fpc_bdi.line_sizes(lines)), np.asarray(ref.line_sizes(lines))
    )


def test_padding_any_n():
    """line_sizes pads to BLOCK internally; result must not depend on it."""
    rng = np.random.default_rng(11)
    lines = lines_from(rng, "mixed", 1000)
    full = np.asarray(fpc_bdi.line_sizes(lines))
    for n in (1, 2, 255, 256, 257, 600):
        np.testing.assert_array_equal(
            np.asarray(fpc_bdi.line_sizes(lines[:n])), full[:n]
        )
