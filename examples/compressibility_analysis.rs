//! Compressibility analysis through the AOT PJRT artifact (L1/L2 layers).
//!
//! This example exercises the *whole three-layer stack*: the Pallas
//! FPC+BDI kernel (L1) inside the jax `analyze_groups` graph (L2) was
//! AOT-lowered to `artifacts/compress_analysis.hlo.txt` at build time;
//! here the rust runtime (L3) loads it on the PJRT CPU client, streams
//! batches of generated cachelines through it, and cross-checks every
//! result against the native rust compressors — the end-to-end parity
//! proof that the simulator's native hot path and the accelerator kernel
//! implement the same math.
//!
//! It then prints the Fig. 4 compressibility profile per workload.
//!
//! Run: `make artifacts && cargo run --release --example compressibility_analysis`

use cram::compress::hybrid;
use cram::cram::group::Csi;
use cram::mem::CacheLine;
use cram::runtime::AnalysisEngine;
use cram::workloads::profiles::all27;

fn main() {
    let engine = AnalysisEngine::load(AnalysisEngine::DEFAULT_ARTIFACT)
        .expect("load artifact — run `make artifacts` first");
    println!("loaded + compiled {}", AnalysisEngine::DEFAULT_ARTIFACT);

    let n_groups = 2048usize;
    println!(
        "\n{:<10} {:>8} {:>8} {:>8} {:>10} {:>10}",
        "workload", "quad", "pairs", "uncomp", "P(<=60B)", "parity"
    );
    for w in all27() {
        if !w.mix_of.is_empty() {
            continue;
        }
        let model = w.value_model(0xF16_4);
        let groups: Vec<[CacheLine; 4]> = (0..n_groups as u64)
            .map(|g| core::array::from_fn(|s| model.gen_line(g * 4 + s as u64, 0)))
            .collect();

        // L1/L2 via PJRT
        let analysis = engine.analyze(&groups).expect("analyze");

        // native parity check: every size and CSI must match bit-for-bit
        let mut mismatches = 0u64;
        let mut quad = 0u64;
        let mut pairs = 0u64;
        let mut uncomp = 0u64;
        let mut pair60 = 0u64;
        for (g, a) in groups.iter().zip(&analysis) {
            let native_sizes: [u32; 4] = core::array::from_fn(|i| hybrid::compressed_size(&g[i]));
            let native_csi = Csi::from_sizes(native_sizes);
            if native_sizes != a.sizes || native_csi != a.csi {
                mismatches += 1;
            }
            match a.csi {
                Csi::Quad => quad += 1,
                Csi::Uncompressed => uncomp += 1,
                _ => pairs += 1,
            }
            if a.sizes[0] + a.sizes[1] <= 60 {
                pair60 += 1;
            }
        }
        assert_eq!(mismatches, 0, "HLO artifact must match native compressors");
        println!(
            "{:<10} {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}% {:>10}",
            w.name,
            100.0 * quad as f64 / n_groups as f64,
            100.0 * pairs as f64 / n_groups as f64,
            100.0 * uncomp as f64 / n_groups as f64,
            100.0 * pair60 as f64 / n_groups as f64,
            "exact"
        );
    }
    println!("\ncompressibility_analysis OK (PJRT == native on every group)");
}
