//! Quickstart: the CRAM mechanism end to end on a handful of lines.
//!
//! Walks through the paper's core ideas with the byte-accurate substrate:
//!   1. hybrid FPC+BDI compression of real cachelines,
//!   2. group packing with implicit-metadata markers,
//!   3. marker classification on reads (one access ⇒ data + status),
//!   4. a marker collision handled by line inversion + the LIT,
//!   5. the LLP finding relocated lines in one access,
//!   6. a tiny 8-core simulation comparing Dynamic-CRAM to the baseline.
//!
//! Run: `cargo run --release --example quickstart`

use cram::compress::{compressed_size, decode, encode};
use cram::controller::Design;
use cram::cram::marker::LineKind;
use cram::cram::store::CompressedStore;
use cram::mem::CacheLine;
use cram::sim::{simulate, SimConfig};
use cram::workloads::profiles::by_name;

fn main() {
    println!("== 1. hybrid FPC+BDI compression =====================================");
    let zero = CacheLine::zero();
    let counters = CacheLine::from_words([7; 16]);
    let pointers = CacheLine::from_qwords(core::array::from_fn(|i| {
        0x5500_DEAD_B000u64 + 8 * i as u64
    }));
    let random = CacheLine::from_words(core::array::from_fn(|i| {
        0x9E37_79B9u32.wrapping_mul(i as u32 + 1) | 1
    }));
    for (name, line) in [
        ("zero line", &zero),
        ("small counters", &counters),
        ("pointer array", &pointers),
        ("random data", &random),
    ] {
        let size = compressed_size(line);
        println!(
            "  {name:<15} -> {size:>2} bytes {}",
            if size >= 64 { "(stored raw)" } else { "" }
        );
        if let Some(c) = encode(line) {
            assert_eq!(decode(&c), *line, "lossless roundtrip");
        }
    }

    println!("\n== 2. packing a group + implicit metadata ============================");
    let mut store = CompressedStore::new(0xC0FFEE);
    let group = [zero, counters, zero, counters];
    let (csi, written) = store.write_group_auto(0, &group);
    println!(
        "  four compressible lines packed as {csi:?} ({} locations touched)",
        written.len()
    );

    println!("\n== 3. one read returns data AND compression status ===================");
    let interp = store.read_interpret(0);
    println!(
        "  read(loc 0) -> {:?}, recovered {} lines in ONE access",
        interp.kind,
        interp.lines.len()
    );
    assert_eq!(interp.lines.len(), 4);
    let stale = store.read_interpret(1);
    println!("  read(loc 1) -> {:?} (stale slot holds Marker-IL)", stale.kind);
    assert_eq!(stale.kind, LineKind::Invalid);

    println!("\n== 4. marker collision -> inversion + LIT ============================");
    let loc = 100;
    let mut evil = random;
    evil.set_tail_u32(store.markers.marker2(loc)); // forge the 2:1 marker
    let rand2 = CacheLine::from_words(core::array::from_fn(|i| {
        0x8BADF00Du32.wrapping_mul(i as u32 + 3) | 1
    }));
    store.write_group_auto(100, &[evil, rand2, rand2, rand2]);
    println!(
        "  wrote a line whose tail equals marker2(loc): LIT tracks {} inverted line(s)",
        store.lit.len()
    );
    let back = store.read_interpret(loc);
    assert_eq!(back.lines[0].1, evil, "inversion is transparent");
    println!("  read back OK — inversion is transparent to the LLC");

    println!("\n== 5. line location: misprediction costs one extra access ============");
    let (data, accesses, _) = store.read_line(1, 1); // wrong guess: B moved to slot 0
    assert_eq!(data, counters);
    println!("  read(line 1, predicted loc 1): {accesses} accesses (marker verified the walk)");
    let (_, accesses, _) = store.read_line(1, 0); // right guess
    println!("  read(line 1, predicted loc 0): {accesses} access");

    println!("\n== 6. tiny simulation: Dynamic-CRAM vs uncompressed ==================");
    let profile = by_name("libq").expect("workload");
    let insts = 600_000;
    let base = simulate(&profile, &SimConfig::default().with_insts(insts));
    let dynamic = simulate(
        &profile,
        &SimConfig::default().with_design(Design::Dynamic).with_insts(insts),
    );
    println!(
        "  libq x8 cores, {insts} insts/core: weighted speedup {}",
        cram::util::pct(dynamic.weighted_speedup(&base))
    );
    println!(
        "  bandwidth-free prefetches used: {} / {}",
        dynamic.prefetch_used, dynamic.prefetch_installed
    );
    println!("\nquickstart OK");
}
