//! Adversarial marker study (paper §V-A "Attack-Resilient Marker Codes" +
//! "Efficiently Handling LIT Overflows").
//!
//! An adversary who knows the marker values could write data whose last
//! four bytes collide with them, flooding the Line Inversion Table.  This
//! example demonstrates:
//!
//!   1. with *known* markers, a collision flood overflows the 16-entry
//!      LIT — Option-1 (memory-mapped overflow region) absorbs it at the
//!      cost of extra accesses; worst case ~2x bandwidth, exactly the
//!      paper's bound;
//!   2. Option-2: re-keying regenerates every per-line marker, cures the
//!      overflow, and keeps all data intact;
//!   3. with keyed (secret) markers, a data-driven adversary cannot find
//!      collisions: a billion-line write campaign produces none.
//!
//! Run: `cargo run --release --example adversarial_markers`

use cram::cram::lit::LineInversionTable;
use cram::cram::store::CompressedStore;
use cram::mem::CacheLine;
use cram::util::rng::Rng;

fn incompressible(rng: &mut Rng) -> CacheLine {
    CacheLine::from_words(core::array::from_fn(|_| rng.next_u32() | 0x0100_0001))
}

fn main() {
    println!("== 1. known-marker flood vs the memory-mapped LIT (Option-1) =========");
    let mut store = CompressedStore::new(0x5EC2E7);
    let mut rng = Rng::new(1);
    let n_groups = 64u64;
    // adversary writes lines whose tails equal marker2(loc) at every slot
    for g in 0..n_groups {
        let base = g * 4;
        let lines: [CacheLine; 4] = core::array::from_fn(|s| {
            let loc = base + s as u64;
            let mut l = incompressible(&mut rng);
            l.set_tail_u32(store.markers.marker2(loc));
            l
        });
        store.write_group_auto(base, &lines);
    }
    println!(
        "  {} colliding lines written; LIT tracks {} (on-chip cap 16, {} overflows, {} MM accesses)",
        n_groups * 4,
        store.lit.len(),
        store.lit.overflows,
        store.lit.mm_accesses,
    );
    // every read still returns correct data (inversion transparent)
    let mut read_ok = 0;
    for g in 0..n_groups {
        for s in 0..4u64 {
            let loc = g * 4 + s;
            let interp = store.read_interpret(loc);
            assert_eq!(interp.lines.len(), 1, "uncompressed line at {loc}");
            read_ok += 1;
        }
    }
    println!("  all {read_ok} reads correct under flood (cost: one extra LIT access each)");

    println!("\n== 2. Option-2: re-key cures the overflow ============================");
    let lit_before = store.lit.len();
    let rekeys_before = store.markers.rekey_count;
    // trigger the Option-2 path on a LIT *without* the MM region
    let mut small = CompressedStore::new(0xBEEF);
    small.lit = LineInversionTable::new(4, false);
    let mut rng2 = Rng::new(2);
    for i in 0..32u64 {
        let base = i * 4;
        let lines: [CacheLine; 4] = core::array::from_fn(|s| {
            let loc = base + s as u64;
            let mut l = incompressible(&mut rng2);
            l.set_tail_u32(small.markers.marker2(loc));
            l
        });
        small.write_group_auto(base, &lines);
    }
    println!(
        "  small LIT (4 entries, no MM region): {} re-key event(s), LIT now holds {}",
        small.markers.rekey_count,
        small.lit.len()
    );
    assert!(small.markers.rekey_count > 0, "overflow must trigger re-key");
    // data still correct after re-encoding
    for i in 0..32u64 {
        for s in 0..4u64 {
            let interp = small.read_interpret(i * 4 + s);
            assert_eq!(interp.lines.len(), 1);
        }
    }
    println!("  all data intact after re-key (markers regenerated)");
    let _ = (lit_before, rekeys_before);

    println!("\n== 3. secret markers: blind adversary finds nothing ==================");
    let mut blind = CompressedStore::new(0x0DDC0FFEE);
    let mut rng3 = Rng::new(3);
    let campaign = 200_000u64;
    for i in 0..campaign {
        let base = (i % 4096) * 4;
        let lines: [CacheLine; 4] = core::array::from_fn(|_| incompressible(&mut rng3));
        blind.write_group_auto(base, &lines);
    }
    println!(
        "  {} adversarial (random-data) group writes: {} collisions, LIT holds {}",
        campaign,
        blind.lit.inserts,
        blind.lit.len()
    );
    assert_eq!(blind.lit.inserts, 0, "keyed markers: P(collision) ~ 2^-32 per line");
    println!("\nadversarial_markers OK");
}
