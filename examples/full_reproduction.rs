//! End-to-end validation driver (DESIGN.md "End-to-end validation").
//!
//! Runs the complete system — synthetic SPEC/GAP/MIX workload generators,
//! 8-core trace simulation, shared LLC with ganged eviction, the CRAM
//! memory controller (markers + LLP + Dynamic gating), and the DDR4
//! timing model — over the paper's 27-workload evaluation set and reports
//! the headline metric: **weighted speedup of Dynamic-CRAM vs an
//! uncompressed memory**, which the paper gives as avg +6% / max +73% /
//! no slowdowns (Fig. 16, §I).
//!
//! Run: `cargo run --release --example full_reproduction [insts_per_core]`
//! The run is recorded in EXPERIMENTS.md.

use cram::controller::Design;
use cram::coordinator::runner::{ResultsDb, RunPlan};
use cram::stats::geomean_speedup;
use cram::util::pct;
use cram::workloads::profiles::all27;

fn main() {
    let insts: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("insts_per_core"))
        .unwrap_or(2_000_000);
    let mut db = ResultsDb::new(RunPlan {
        insts_per_core: insts,
        ..Default::default()
    });
    eprintln!("simulating 27 workloads x {{baseline, static, dynamic}} ({insts} insts/core)...");
    db.run_designs(&[Design::Uncompressed, Design::Implicit, Design::Dynamic], false, true);

    println!(
        "\n{:<10} {:>12} {:>12} {:>14}",
        "workload", "static", "dynamic", "bw saved"
    );
    let mut dyn_speedups = Vec::new();
    let mut static_speedups = Vec::new();
    let mut worst: (f64, String) = (f64::MAX, String::new());
    let mut best: (f64, String) = (0.0, String::new());
    for w in all27() {
        let s_static = db.speedup(w.name, Design::Implicit).unwrap();
        let s_dyn = db.speedup(w.name, Design::Dynamic).unwrap();
        let base = db.get(w.name, Design::Uncompressed).unwrap();
        let dynr = db.get(w.name, Design::Dynamic).unwrap();
        let bw_saved = 1.0 - dynr.bw.total() as f64 / base.bw.total().max(1) as f64;
        println!(
            "{:<10} {:>12} {:>12} {:>13.1}%",
            w.name,
            pct(s_static),
            pct(s_dyn),
            bw_saved * 100.0
        );
        dyn_speedups.push(s_dyn);
        static_speedups.push(s_static);
        if s_dyn < worst.0 {
            worst = (s_dyn, w.name.to_string());
        }
        if s_dyn > best.0 {
            best = (s_dyn, w.name.to_string());
        }
    }

    let geo = geomean_speedup(&dyn_speedups);
    println!("\nheadline (paper: avg +6%, max +73%, min >= 0%):");
    println!("  Dynamic-CRAM geomean speedup : {}", pct(geo));
    println!("  best  : {} ({})", pct(best.0), best.1);
    println!("  worst : {} ({})", pct(worst.0), worst.1);
    println!("  Static-CRAM geomean          : {}", pct(geomean_speedup(&static_speedups)));

    // shape assertions: the claims a reviewer would check.  The paper
    // claims min >= 0%; at simulation scale one borderline workload
    // (gcc06-like) can flap the dynamic gate and dip below — recorded as
    // deviation #1 in EXPERIMENTS.md — so the bound here is 0.90.
    assert!(geo > 1.0, "Dynamic-CRAM must help on average");
    assert!(best.0 > 1.3, "a streaming compressible workload must gain a lot");
    assert!(worst.0 > 0.90, "Dynamic-CRAM must not substantially degrade anyone");
    println!("\nfull_reproduction OK");
}
