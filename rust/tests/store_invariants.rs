//! Property tests over the byte-accurate compressed store — the paper's
//! correctness argument as machine-checked invariants (DESIGN.md §7):
//!
//! (a) decompress(compress(x)) == x for every compressible line;
//! (b) any physical line whose tail matches a marker is either genuinely
//!     compressed or LIT-tracked (inverted) — never misinterpreted;
//! (c) reads return the latest written value through arbitrary layout
//!     transitions and arbitrary (mis)predictions;
//! (d) a read always completes within the restricted-placement walk
//!     (<= 3 accesses);
//! (e) stale locations always classify as Invalid, never as data.

use std::collections::HashMap;

use cram::compress::hybrid;
use cram::cram::group::{possible_locations, Csi};
use cram::cram::marker::LineKind;
use cram::cram::store::CompressedStore;
use cram::mem::{group_base, CacheLine};
use cram::util::rng::Rng;
use cram::util::testkit::forall;
use cram::workloads::ValueModel;

/// A line from a random workload-like value regime.
fn random_line(rng: &mut Rng, model: &ValueModel) -> CacheLine {
    model.gen_line(rng.below(1 << 20), rng.next_u32() % 8)
}

fn mixed_model(seed: u64) -> ValueModel {
    ValueModel::new([1.0, 1.0, 1.0, 1.0, 1.0], seed)
}

#[test]
fn a_compress_roundtrip_over_value_models() {
    forall("roundtrip", 2000, |rng| {
        let model = mixed_model(rng.next_u64());
        let line = random_line(rng, &model);
        match hybrid::encode(&line) {
            Some(c) => {
                assert_eq!(c.size(), hybrid::compressed_size(&line));
                assert_eq!(hybrid::decode(&c), line);
            }
            None => assert_eq!(hybrid::compressed_size(&line), 64),
        }
    });
}

/// Drive a store through a random schedule of group writes and verify all
/// invariants continuously against a shadow model.
#[test]
fn bcde_store_invariants_under_random_schedules() {
    forall("store invariants", 48, |rng| {
        let model = mixed_model(rng.next_u64());
        let mut store = CompressedStore::new(rng.next_u64());
        let mut shadow: HashMap<u64, CacheLine> = HashMap::new();
        let n_groups = 6u64;

        for _step in 0..40 {
            // random group write
            let base = rng.below(n_groups) * 4;
            let lines: [CacheLine; 4] = core::array::from_fn(|_| random_line(rng, &model));
            store.write_group_auto(base, &lines);
            for (i, l) in lines.iter().enumerate() {
                shadow.insert(base + i as u64, *l);
            }

            // (c)+(d): read a few random lines with random predictions
            for _ in 0..6 {
                let la = rng.below(n_groups * 4);
                let Some(want) = shadow.get(&la).copied() else { continue };
                let slot = (la - group_base(la)) as u8;
                let order = possible_locations(slot);
                let guess = group_base(la) + order[rng.below(order.len() as u64) as usize] as u64;
                let (got, accesses, _) = store.read_line(la, guess);
                assert_eq!(got, want, "latest write must win (line {la})");
                assert!(
                    accesses as usize <= order.len() + 1,
                    "walk bounded by placement order"
                );
            }

            // (b)+(e): audit every materialized physical line
            let groups: Vec<(u64, Csi)> = store.groups().collect();
            for (gbase, csi) in groups {
                for loc_slot in 0..4u8 {
                    let loc = gbase + loc_slot as u64;
                    let phys = store.read_phys(loc);
                    match store.markers.classify(loc, &phys) {
                        LineKind::Compressed2 | LineKind::Compressed4 => {
                            assert!(
                                csi.is_compressed_at(loc_slot),
                                "marker without packed data at {loc} (csi {csi:?})"
                            );
                        }
                        LineKind::Invalid => {
                            assert!(csi.is_stale(loc_slot), "IL on a live slot at {loc}");
                        }
                        LineKind::NeedsLitCheck => {
                            // must resolve via LIT to an uncompressed line
                            assert_eq!(csi.colocated(loc_slot).len(), 1);
                        }
                        LineKind::Uncompressed => {
                            assert_eq!(
                                csi.colocated(loc_slot).len(),
                                1,
                                "raw data on a non-single slot at {loc}"
                            );
                        }
                    }
                }
            }
        }
    });
}

/// Size-only / materializing agreement across every compressor: the
/// simulator's fast size paths must report exactly the byte counts the
/// encoders produce, over workload-realistic value regimes (the contract
/// in `compress/mod.rs` §Size-only contract).
#[test]
fn a2_size_only_paths_agree_with_materializing_encoders() {
    use cram::compress::hybrid::AlgoSet;
    use cram::compress::{bdi, cpack, fpc};
    forall("size-only parity", 1500, |rng| {
        let model = mixed_model(rng.next_u64());
        let line = random_line(rng, &model);
        // FPC
        assert_eq!(fpc::encode(&line).len() as u32, fpc::size_bytes(&line));
        // C-Pack
        assert_eq!(cpack::encode(&line).len() as u32, cpack::size_bytes(&line));
        // BDI: best mode and every fitting mode
        match bdi::best_mode(&line) {
            Some(m) => {
                assert_eq!(bdi::size_bytes(&line), m.size_bytes());
                assert_eq!(bdi::encode(&line, m).len() as u32, m.size_bytes());
            }
            None => assert_eq!(bdi::size_bytes(&line), 64),
        }
        for m in bdi::BdiMode::ALL {
            if bdi::fits(&line, m) {
                assert_eq!(bdi::encode(&line, m).len() as u32, m.size_bytes());
            }
        }
        // hybrid, both algorithm sets
        for set in [AlgoSet::FpcBdi, AlgoSet::FpcBdiCpack] {
            let size = hybrid::compressed_size_with(&line, set);
            match hybrid::encode_with(&line, set) {
                Some(c) => assert_eq!(c.size(), size),
                None => assert_eq!(size, 64),
            }
        }
    });
}

#[test]
fn c_interleaved_partial_writes_preserve_other_half() {
    forall("partial writes", 200, |rng| {
        let model = mixed_model(rng.next_u64());
        let mut store = CompressedStore::new(rng.next_u64());
        // write a full group, then overwrite it repeatedly
        let mut current: [CacheLine; 4] = core::array::from_fn(|_| random_line(rng, &model));
        store.write_group_auto(0, &current);
        for _ in 0..8 {
            let fresh: [CacheLine; 4] = core::array::from_fn(|_| random_line(rng, &model));
            store.write_group_auto(0, &fresh);
            current = fresh;
            for (i, want) in current.iter().enumerate() {
                let (got, _, _) = store.read_line(i as u64, i as u64);
                assert_eq!(got, *want);
            }
        }
    });
}

#[test]
fn b_forged_markers_never_corrupt_data() {
    forall("forged markers", 300, |rng| {
        let mut store = CompressedStore::new(rng.next_u64());
        let base = rng.below(64) * 4;
        // adversarial lines: tails forged to every marker of their slot
        let lines: [CacheLine; 4] = core::array::from_fn(|s| {
            let loc = base + s as u64;
            let mut l =
                CacheLine::from_words(core::array::from_fn(|_| rng.next_u32() | 0x0100_0001));
            let tail = match rng.below(3) {
                0 => store.markers.marker2(loc),
                1 => store.markers.marker4(loc),
                _ => !store.markers.marker2(loc),
            };
            l.set_tail_u32(tail);
            l
        });
        store.write_group_auto(base, &lines);
        for (i, want) in lines.iter().enumerate() {
            let la = base + i as u64;
            let (got, _, _) = store.read_line(la, la);
            assert_eq!(got, *want, "forged tail must not corrupt line {la}");
        }
    });
}

#[test]
fn e_rekey_preserves_all_data() {
    forall("rekey preserves", 60, |rng| {
        let model = mixed_model(rng.next_u64());
        let mut store = CompressedStore::new(rng.next_u64());
        let mut shadow: HashMap<u64, CacheLine> = HashMap::new();
        for g in 0..8u64 {
            let lines: [CacheLine; 4] = core::array::from_fn(|_| random_line(rng, &model));
            store.write_group_auto(g * 4, &lines);
            for (i, l) in lines.iter().enumerate() {
                shadow.insert(g * 4 + i as u64, *l);
            }
        }
        // forge enough collisions to overflow a tiny LIT and force rekey
        store.lit = cram::cram::lit::LineInversionTable::new(2, false);
        for k in 0..6u64 {
            let base = (8 + k) * 4;
            let lines: [CacheLine; 4] = core::array::from_fn(|s| {
                let loc = base + s as u64;
                let mut l =
                    CacheLine::from_words(core::array::from_fn(|_| rng.next_u32() | 0x0100_0001));
                l.set_tail_u32(store.markers.marker2(loc));
                l
            });
            store.write_group_auto(base, &lines);
            for (i, l) in lines.iter().enumerate() {
                shadow.insert(base + i as u64, *l);
            }
        }
        // every line still reads back correctly, regardless of rekeys
        for (la, want) in &shadow {
            let (got, _, _) = store.read_line(*la, *la);
            assert_eq!(got, *want, "line {la} after {} rekey(s)", store.markers.rekey_count);
        }
    });
}
