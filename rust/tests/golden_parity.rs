//! Golden parity pin for the layered-controller refactor.
//!
//! Renders the Figure 3 and Figure T1 exhibits from a seeded small run
//! and compares them **bit-for-bit** against a committed snapshot
//! (`rust/tests/golden/figures_small.txt`).  The refactor that split the
//! controller into policy × placement layers is semantics-preserving by
//! construction; this pin makes any future drift in the shared
//! [`CramEngine`] / executor split fail loudly instead of silently
//! bending every figure.
//!
//! Snapshot lifecycle:
//! * **absent** → the test records it and passes, printing a reminder to
//!   commit the file (the bootstrap mirrors `BENCH_sim.json`: the dev
//!   containers for PRs 3–5 had no Rust toolchain, so the snapshot could
//!   not be recorded in-tree — the first machine that runs the suite
//!   writes it, and committing it arms the pin);
//! * **present** → any byte of drift fails with the first differing
//!   line and leaves the new rendering next to the snapshot as
//!   `figures_small.txt.new` for inspection;
//! * **intentional change** → re-bless with
//!   `CRAM_UPDATE_GOLDEN=1 cargo test -q --test golden_parity` and
//!   commit the updated snapshot (justify the figure change in the PR).

use std::fs;
use std::path::PathBuf;

use cram::controller::Design;
use cram::coordinator::figures;
use cram::coordinator::runner::{ResultsDb, RunPlan};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/figures_small.txt")
}

/// Render the pinned exhibits at a fixed reduced scale.  Everything here
/// is deterministic: fixed seed, fixed insts, and the thread pool only
/// reorders independent jobs keyed into a map.
fn render_pinned_figures() -> String {
    let mut db = ResultsDb::new(RunPlan {
        insts_per_core: 20_000,
        seed: 0xC0DE,
        threads: 4,
    });
    // Figure 3's designs (ideal vs practical) + the Figure T1 tiered
    // matrix: together they cross every engine consumer — flat packing,
    // explicit metadata, and the far-tier executor.
    db.run_designs(
        &[Design::Uncompressed, Design::Ideal, Design::explicit(false)],
        false,
        false,
    );
    db.run_tiered_t1(false);
    format!(
        "{}{}",
        figures::figure3(&db).render(),
        figures::figure_t1(&db).render()
    )
}

fn first_diff_line(a: &str, b: &str) -> String {
    for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
        if la != lb {
            return format!("line {}:\n  golden:  {la}\n  current: {lb}", i + 1);
        }
    }
    format!(
        "line counts differ: golden {} vs current {}",
        a.lines().count(),
        b.lines().count()
    )
}

#[test]
fn figures_match_the_committed_golden_snapshot() {
    let rendered = render_pinned_figures();
    let path = golden_path();
    let bless = std::env::var("CRAM_UPDATE_GOLDEN").is_ok();
    match fs::read_to_string(&path) {
        Ok(golden) if !bless => {
            if rendered != golden {
                let _ = fs::write(path.with_extension("txt.new"), &rendered);
                panic!(
                    "figure outputs drifted from the committed golden snapshot \
                     ({}).\nFirst difference — {}\nIf the change is intentional, \
                     re-bless with CRAM_UPDATE_GOLDEN=1 and commit the snapshot; \
                     the new rendering was saved as figures_small.txt.new.",
                    path.display(),
                    first_diff_line(&golden, &rendered),
                );
            }
        }
        _ => {
            fs::create_dir_all(path.parent().expect("golden dir")).expect("mkdir golden");
            fs::write(&path, &rendered).expect("write golden snapshot");
            eprintln!(
                "golden snapshot {} at {} — commit it to arm the parity pin",
                if bless { "re-blessed" } else { "bootstrap-recorded" },
                path.display()
            );
        }
    }
}

#[test]
fn pinned_rendering_is_deterministic() {
    // the pin is only meaningful if two in-process runs agree byte-for-
    // byte (thread scheduling must not leak into the rendering) — checked
    // on the smaller T1 matrix to keep the suite fast
    let render = || {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 10_000,
            seed: 0xC0DE,
            threads: 4,
        });
        db.run_tiered_t1(false);
        figures::figure_t1(&db).render()
    };
    assert_eq!(render(), render());
}
