//! Integration pins for the sharded experiment engine.
//!
//! Two contracts keep the engine honest end-to-end:
//!
//! * **Scheduling independence** — a figure rendered from a db filled at
//!   `threads = N` is byte-identical to one filled at `threads = 1`.
//!   Everything between job submission and figure text (cost-ordered
//!   pool drain, striped merge, persistent-cache serialization) may
//!   only reorder work, never change it.
//! * **Cache round-trip** — a db reloaded from the on-disk
//!   `CRAM_RESULTS.json` renders the same bytes as the db that wrote
//!   it, executes nothing, and a cache written under a different plan
//!   (or plain garbage) is ignored wholesale.

use std::fs;
use std::path::PathBuf;

use cram::controller::Design;
use cram::coordinator::figures;
use cram::coordinator::runner::{ResultsDb, RunPlan};

fn plan(threads: usize) -> RunPlan {
    RunPlan { insts_per_core: 8_000, seed: 0x5EED, threads }
}

/// The exhibits the pins render: figure 3 (flat engine consumers) and
/// figure T1 (the tiered executor).
fn fill(db: &mut ResultsDb) {
    db.run_designs(
        &[Design::Uncompressed, Design::Ideal, Design::explicit(false)],
        false,
        false,
    );
    db.run_tiered_t1(false);
}

fn render(db: &ResultsDb) -> String {
    format!(
        "{}{}",
        figures::figure3(db).render(),
        figures::figure_t1(db).render()
    )
}

/// A per-test scratch path inside the target dir (the suite has no
/// tempfile dependency); removed on drop so reruns start cold.
struct Scratch(PathBuf);

impl Scratch {
    fn new(name: &str) -> Self {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("target")
            .join(format!("engine_determinism_{name}.json"));
        let _ = fs::remove_file(&p);
        fs::create_dir_all(p.parent().unwrap()).expect("target dir");
        Scratch(p)
    }

    fn path(&self) -> &str {
        self.0.to_str().expect("utf-8 path")
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.0);
    }
}

#[test]
fn sharded_fill_renders_bit_identically_to_serial() {
    let run = |threads: usize| {
        let mut db = ResultsDb::new(plan(threads));
        fill(&mut db);
        (render(&db), db.serialize())
    };
    let (fig_serial, cache_serial) = run(1);
    let (fig_sharded, cache_sharded) = run(8);
    assert_eq!(fig_serial, fig_sharded, "figure bytes depend on thread count");
    assert_eq!(cache_serial, cache_sharded, "cache bytes depend on thread count");
}

#[test]
fn cache_round_trip_preserves_figure_bytes_and_skips_execution() {
    let scratch = Scratch::new("roundtrip");

    // first invocation: cold cache, everything simulates, db persists
    let mut writer = ResultsDb::new(plan(4));
    let load = writer.attach_cache(scratch.path(), false);
    assert_eq!(load.loaded, 0, "cold start");
    assert!(load.note.is_none(), "a missing file is not an error");
    fill(&mut writer);
    let written = render(&writer);
    assert!(!writer.is_empty());

    // second invocation: same plan — full reload, zero simulations,
    // identical bytes
    let mut reader = ResultsDb::new(plan(4));
    let load = reader.attach_cache(scratch.path(), false);
    assert_eq!(load.loaded, writer.len(), "{:?}", load.note);
    let stats = reader.run_designs(
        &[Design::Uncompressed, Design::Ideal, Design::explicit(false)],
        false,
        false,
    );
    assert_eq!(stats.executed, 0);
    assert_eq!(stats.from_cache, stats.requested);
    let stats = reader.run_tiered_t1(false);
    assert_eq!(stats.executed, 0);
    assert_eq!(render(&reader), written);

    // a different plan is a different fingerprint: the file is ignored
    // wholesale, with a note saying why
    let mut other = ResultsDb::new(RunPlan { seed: 0xD1FF, ..plan(4) });
    let load = other.attach_cache(scratch.path(), false);
    assert_eq!(load.loaded, 0);
    assert!(load.note.is_some(), "stale cache must be reported");

    // --refresh ignores even a compatible cache (but still re-arms
    // write-back — running a batch overwrites the file)
    let mut refresher = ResultsDb::new(plan(4));
    let load = refresher.attach_cache(scratch.path(), true);
    assert_eq!(load.loaded, 0);
}

#[test]
fn corrupt_cache_is_ignored_not_trusted() {
    let scratch = Scratch::new("corrupt");
    fs::write(scratch.path(), "{not json at all").expect("write garbage");
    let mut db = ResultsDb::new(plan(2));
    let load = db.attach_cache(scratch.path(), false);
    assert_eq!(load.loaded, 0);
    assert!(load.note.is_some(), "garbage must be reported, not crash");
    assert!(db.is_empty());
}
