//! Analysis-engine contract tests + the cross-language spec pins.
//!
//! Since the offline engine evaluates the model through the *same* native
//! compressors the simulator uses (see `runtime` module docs), the
//! engine-vs-native sweeps below cannot catch compressor bugs — they pin
//! the engine's *contract*: batch-length preservation, partial-batch
//! handling, and stability across every value regime.  The detection
//! power for the math itself lives in `hlo_spec_pins`, whose literal
//! values are hand-computed from the paper's spec and pinned identically
//! by `python/tests/test_kernel.py` on the Pallas/jax side — if either
//! implementation drifts, one of the two suites breaks.

use cram::compress::hybrid;
use cram::cram::group::Csi;
use cram::mem::CacheLine;
use cram::runtime::AnalysisEngine;
use cram::util::rng::Rng;
use cram::workloads::ValueModel;

fn artifact() -> AnalysisEngine {
    AnalysisEngine::load(AnalysisEngine::DEFAULT_ARTIFACT)
        .expect("load analysis engine (validates the artifact when present)")
}

fn native(group: &[CacheLine; 4]) -> (Csi, [u32; 4]) {
    let sizes: [u32; 4] = core::array::from_fn(|i| hybrid::compressed_size(&group[i]));
    (Csi::from_sizes(sizes), sizes)
}

#[test]
fn hlo_matches_native_on_workload_values() {
    let engine = artifact();
    // every workload value class, 512 groups each
    for weights in [
        [1.0, 0.0, 0.0, 0.0, 0.0],
        [0.0, 1.0, 0.0, 0.0, 0.0],
        [0.0, 0.0, 1.0, 0.0, 0.0],
        [0.0, 0.0, 0.0, 1.0, 0.0],
        [0.0, 0.0, 0.0, 0.0, 1.0],
        [1.0, 1.0, 1.0, 1.0, 1.0],
    ] {
        let model = ValueModel::new(weights, 0xA0_7E57);
        let groups: Vec<[CacheLine; 4]> = (0..512u64)
            .map(|g| core::array::from_fn(|s| model.gen_line(g * 4 + s as u64, 0)))
            .collect();
        let analysis = engine.analyze(&groups).expect("analyze");
        assert_eq!(analysis.len(), groups.len());
        for (g, a) in groups.iter().zip(&analysis) {
            let (csi, sizes) = native(g);
            assert_eq!(a.sizes, sizes, "sizes diverge for {weights:?}");
            assert_eq!(a.csi, csi, "csi diverges for {weights:?}");
        }
    }
}

#[test]
fn hlo_matches_native_on_random_bits() {
    let engine = artifact();
    let mut rng = Rng::new(0xF00D);
    let groups: Vec<[CacheLine; 4]> = (0..1024)
        .map(|_| {
            core::array::from_fn(|_| {
                CacheLine::from_words(core::array::from_fn(|_| rng.next_u32()))
            })
        })
        .collect();
    let analysis = engine.analyze(&groups).expect("analyze");
    for (g, a) in groups.iter().zip(&analysis) {
        let (csi, sizes) = native(g);
        assert_eq!((a.csi, a.sizes), (csi, sizes));
    }
}

#[test]
fn hlo_handles_partial_batches() {
    let engine = artifact();
    // non-multiple-of-batch sizes exercise the padding path
    for n in [1usize, 3, 1023, 1024, 1025, 2500] {
        let model = ValueModel::new([1.0, 1.0, 1.0, 1.0, 1.0], n as u64);
        let groups: Vec<[CacheLine; 4]> = (0..n as u64)
            .map(|g| core::array::from_fn(|s| model.gen_line(g * 4 + s as u64, 0)))
            .collect();
        let analysis = engine.analyze(&groups).expect("analyze");
        assert_eq!(analysis.len(), n);
        // spot-check first and last
        for idx in [0, n - 1] {
            let (csi, sizes) = native(&groups[idx]);
            assert_eq!((analysis[idx].csi, analysis[idx].sizes), (csi, sizes), "n={n} idx={idx}");
        }
    }
}

#[test]
fn hlo_spec_pins() {
    // the same hand pins as python/tests/test_kernel.py, through PJRT
    let engine = artifact();
    let zero = CacheLine::zero();
    let sevens = CacheLine::from_words([7; 16]);
    let rep = CacheLine::from_words([0x4141_4141; 16]);
    let base = 0x1234_5678_9ABC_DE00u64;
    let b8d1 = CacheLine::from_qwords(core::array::from_fn(|i| base + i as u64));
    let analysis = engine
        .analyze(&[[zero, sevens, rep, b8d1]])
        .expect("analyze");
    assert_eq!(analysis[0].sizes, [2, 9, 9, 17]);
    assert_eq!(analysis[0].csi, Csi::Quad); // 2+9+9+17 = 37 <= 60
}
