//! Cross-design integration tests over the full simulator: the behavioral
//! contracts each paper figure depends on, checked at reduced scale.

use cram::controller::Design;
use cram::sim::{simulate, SimConfig};
use cram::stats::SimResult;
use cram::workloads::profiles::by_name;

fn run(wl: &str, design: Design, insts: u64) -> SimResult {
    simulate(
        &by_name(wl).unwrap(),
        &SimConfig::default().with_design(design).with_insts(insts),
    )
}

#[test]
fn traffic_conservation_uncompressed() {
    // every LLC read miss is exactly one demand read; writes only from
    // dirty evictions
    let r = run("sphinx", Design::Uncompressed, 400_000);
    assert_eq!(r.bw.overhead(), 0, "baseline has zero overhead traffic");
    assert!(r.bw.demand_reads > 0);
    assert!(r.bw.demand_writes > 0);
}

#[test]
fn ideal_reduces_reads_on_compressible_streams() {
    let base = run("libq", Design::Uncompressed, 800_000);
    let ideal = run("libq", Design::Ideal, 800_000);
    assert!(
        (ideal.bw.demand_reads as f64) < 0.6 * base.bw.demand_reads as f64,
        "4:1-heavy stream should cut reads hard: {} vs {}",
        ideal.bw.demand_reads,
        base.bw.demand_reads
    );
    assert!(ideal.weighted_speedup(&base) > 1.15);
}

#[test]
fn static_cram_overheads_are_visible_and_bounded() {
    // needs steady state: the one-time pack cost (invalidates) amortizes
    // away only once the sweep has been re-traversed a few times
    let r = run("libq", Design::Implicit, 2_000_000);
    // steady state: packed clean re-evictions are free, so overheads stay
    // a small fraction of traffic
    let total = r.bw.total() as f64;
    assert!(r.bw.second_reads > 0, "some LLP mispredictions exist");
    assert!(
        (r.bw.invalidates as f64) < 0.25 * total,
        "invalidate churn bounded: {} of {}",
        r.bw.invalidates,
        total
    );
}

#[test]
fn llp_beats_metadata_cache_on_scattered_workloads() {
    // Fig. 14's claim: tiny LLP >> 32KB metadata cache for low-locality
    // workloads
    let implicit = run("xz", Design::Implicit, 500_000);
    let explicit = run("xz", Design::explicit(false), 500_000);
    let acc = implicit.llp_accuracy.expect("implicit design consults the LCT");
    assert!(acc > 0.9, "llp {acc}");
    assert!(
        acc > explicit.meta_hit_rate.unwrap() + 0.1,
        "LLP {} must beat meta$ {}",
        acc,
        explicit.meta_hit_rate.unwrap()
    );
}

#[test]
fn explicit_metadata_traffic_tracks_miss_rate() {
    let r = run("xz", Design::explicit(false), 500_000);
    let expected = r.bw.demand_reads as f64 * (1.0 - r.meta_hit_rate.unwrap());
    let got = r.bw.meta_reads as f64;
    // read-side meta misses dominate meta traffic; write-side update
    // misses add some more — so got >= read-side expectation, same order
    assert!(
        got >= 0.5 * expected && got <= 3.0 * expected + 1000.0,
        "meta reads {got} vs expected ~{expected}"
    );
}

#[test]
fn dynamic_never_much_worse_than_baseline() {
    for wl in ["cc_twi", "pr_twi", "bc_twi", "xz", "mcf17"] {
        let base = run(wl, Design::Uncompressed, 500_000);
        let d = run(wl, Design::Dynamic, 500_000);
        let s = d.weighted_speedup(&base);
        assert!(s > 0.96, "{wl}: dynamic speedup {s} below protection bound");
    }
}

#[test]
fn dynamic_captures_compressible_upside() {
    // steady state needed: dynamic's counters settle during warmup and the
    // packing transient must be amortized (see EXPERIMENTS.md on scaling)
    let base = run("libq", Design::Uncompressed, 2_000_000);
    let stat = run("libq", Design::Implicit, 2_000_000);
    let dynr = run("libq", Design::Dynamic, 2_000_000);
    let s_stat = stat.weighted_speedup(&base);
    let s_dyn = dynr.weighted_speedup(&base);
    assert!(s_stat > 1.2);
    assert!(
        s_dyn > 1.0 + (s_stat - 1.0) * 0.3,
        "dynamic ({s_dyn}) must capture a good share of static ({s_stat})"
    );
}

#[test]
fn next_line_prefetch_costs_bandwidth() {
    let base = run("cc_twi", Design::Uncompressed, 300_000);
    let pf = run("cc_twi", Design::NextLinePrefetch, 300_000);
    assert!(pf.bw.prefetch_reads > 0);
    assert!(
        pf.weighted_speedup(&base) < 1.0,
        "prefetch must hurt scattered graph workloads (Table V)"
    );
}

#[test]
fn channel_scaling_sane() {
    // more channels => higher baseline performance
    let p = by_name("milc").unwrap();
    let mk = |ch| {
        simulate(
            &p,
            &SimConfig::default().with_insts(400_000).with_channels(ch),
        )
    };
    let c1 = mk(1);
    let c4 = mk(4);
    assert!(
        c4.total_ipc() > c1.total_ipc() * 1.2,
        "4ch {} vs 1ch {}",
        c4.total_ipc(),
        c1.total_ipc()
    );
}

#[test]
fn mix_workloads_have_per_core_behaviour() {
    let r = run("mix1", Design::Dynamic, 400_000);
    // mix1 = libq/mcf17/fotonik/xz x2: per-core IPCs must differ
    // under heavy shared-bandwidth contention per-core IPCs converge, but
    // heterogeneity must still be visible
    let max = r.ipc.iter().cloned().fold(0.0f64, f64::max);
    let min = r.ipc.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max / min > 1.03, "heterogeneous mix: ipc {:?}", r.ipc);
}

#[test]
fn determinism_across_identical_runs() {
    let a = run("soplex", Design::Dynamic, 300_000);
    let b = run("soplex", Design::Dynamic, 300_000);
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.bw.total(), b.bw.total());
    assert_eq!(a.llc_misses, b.llc_misses);
}

#[test]
fn private_caches_filter_llc_traffic() {
    let p = by_name("gcc06").unwrap();
    let mut cfg = SimConfig::default().with_insts(300_000);
    let flat = simulate(&p, &cfg);
    cfg.private_caches = true;
    let filtered = simulate(&p, &cfg);
    // L1/L2 absorb part of the stream: fewer LLC accesses reach memory
    assert!(
        filtered.llc_hits + filtered.llc_misses < flat.llc_hits + flat.llc_misses,
        "private caches must filter: {} vs {}",
        filtered.llc_hits + filtered.llc_misses,
        flat.llc_hits + flat.llc_misses
    );
}

#[test]
fn latency_histogram_counts_demand_reads_across_designs() {
    // the Figure Q1 accounting invariant: one latency sample per demand
    // read, under every design family (flat, metadata, CRAM, tiered)
    for design in [
        Design::Uncompressed,
        Design::explicit(false),
        Design::Dynamic,
        Design::NextLinePrefetch,
        Design::tiered(true),
    ] {
        let r = run("sphinx", design, 200_000);
        assert_eq!(
            r.read_lat.count(),
            r.bw.demand_reads,
            "{}: histogram count vs demand reads",
            r.design
        );
    }
}

#[test]
fn latency_sensitive_workloads_expose_the_tail() {
    // the lat_* profiles exist to make scheduling visible: dependent
    // pointer chases must show a p99 well above p50
    let r = run("lat_chase", Design::Uncompressed, 300_000);
    assert!(r.mpki() > 1.0, "lat_chase misses: {}", r.mpki());
    let (p50, p99) = (r.read_lat.percentile(0.5), r.read_lat.percentile(0.99));
    assert!(
        p99 > p50,
        "pointer chase has a distinguishable tail: p50 {p50} p99 {p99}"
    );
    assert!(r.read_lat.count() == r.bw.demand_reads);
}

#[test]
fn explicit_metadata_stretches_the_tail_on_scattered_reads() {
    // xz thrashes the 32KB metadata cache, serializing a metadata read
    // in front of demand reads — that must show up in read latency
    let base = run("xz", Design::Uncompressed, 300_000);
    let explicit = run("xz", Design::explicit(false), 300_000);
    assert!(
        explicit.read_lat.mean() > base.read_lat.mean(),
        "serialized metadata lookups must raise mean read latency: {} vs {}",
        explicit.read_lat.mean(),
        base.read_lat.mean()
    );
}

#[test]
fn uncompressed_run_reports_no_llp_accuracy() {
    // the baseline never consults the LCT: accuracy must be n/a, not the
    // 100% figure-13 used to print for runs with zero predictions
    let r = run("sphinx", Design::Uncompressed, 200_000);
    assert_eq!(r.llp_accuracy, None);
}

#[test]
fn compressed_llc_preserves_cross_design_invariants() {
    // the compressed LLC changes residency, not accounting: the latency
    // and baseline-overhead invariants must survive under every family
    for design in [Design::Uncompressed, Design::Implicit, Design::Dynamic] {
        let p = by_name("llcfit_ptr").unwrap();
        let cfg = SimConfig::default()
            .with_design(design)
            .with_insts(250_000)
            .with_compressed_llc();
        let r = simulate(&p, &cfg);
        assert_eq!(
            r.read_lat.count(),
            r.bw.demand_reads,
            "{}: one latency sample per demand read",
            r.design
        );
        if design == Design::Uncompressed {
            assert_eq!(r.bw.overhead(), 0, "baseline has zero overhead traffic");
        }
        let st = r.llc_stats.expect("compressed run records cache stats");
        assert!(st.samples > 0);
        assert!(st.avg_lines() > 0.0);
    }
}

#[test]
fn compressed_llc_control_workload_stays_data_limited() {
    // llcfit_rand is the honesty control: high-entropy lines leave the
    // data budget as the binding constraint, so effective capacity stays
    // near 1x and the compressed LLC must not tank performance
    let p = by_name("llcfit_rand").unwrap();
    let plain = simulate(
        &p,
        &SimConfig::default().with_design(Design::Dynamic).with_insts(500_000),
    );
    let comp = simulate(
        &p,
        &SimConfig::default()
            .with_design(Design::Dynamic)
            .with_insts(500_000)
            .with_compressed_llc(),
    );
    let st = comp.llc_stats.unwrap();
    assert!(
        st.effective_ratio() < 1.6,
        "incompressible control cannot double residency: {}",
        st.effective_ratio()
    );
    let s = comp.weighted_speedup(&plain);
    assert!(s > 0.95, "control workload must not regress much: {s}");
}

#[test]
fn cpack_algo_set_runs_end_to_end() {
    let p = by_name("omnet17").unwrap();
    let mut cfg = SimConfig::default()
        .with_design(Design::Dynamic)
        .with_insts(300_000);
    cfg.algo = cram::compress::AlgoSet::FpcBdiCpack;
    let r = simulate(&p, &cfg);
    assert!(r.cycles > 0);
}
