//! Set-associative cache (tag array) with LRU replacement.
//!
//! Extensions the paper needs beyond a vanilla cache:
//! * a 2-bit *prior compressibility level* per line (§V-A "Handling
//!   Updates to Compressed Lines") so evictions know which locations to
//!   write/invalidate;
//! * the requesting core id + a reuse bit, maintained for Dynamic-CRAM's
//!   sampled sets (§VI-A);
//! * *ganged eviction*: evicting one member of a compressed group forces
//!   out all members, avoiding read-modify-write of packed lines.

use crate::mem::{group_base, GROUP_LINES};
use crate::util::small::InlineVec;

/// Cache geometry.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub bytes: usize,
    pub ways: usize,
}

impl CacheConfig {
    /// Paper LLC: 8MB, 16-way.
    pub fn paper_llc() -> Self {
        Self { bytes: 8 * 1024 * 1024, ways: 16 }
    }

    pub fn sets(&self) -> usize {
        self.bytes / 64 / self.ways
    }
}

/// Result of a demand access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccessInfo {
    pub hit: bool,
    /// Hit on a compression-prefetched line that had never been used.
    pub first_prefetch_use: bool,
}

/// An evicted line with everything the memory controller needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Evicted {
    pub line_addr: u64,
    pub dirty: bool,
    /// Prior compressibility (0 = uncompressed, 1 = 2:1, 2 = 4:1) recorded
    /// when the line was filled from memory.
    pub level: u8,
    pub core: u8,
    /// Was the line referenced after insertion?  (Dynamic-CRAM's "useful
    /// prefetch" signal for lines installed as free prefetches.)
    pub referenced: bool,
    /// Was the line installed as a compression prefetch (not demanded)?
    pub was_prefetch: bool,
}

#[derive(Clone, Copy, Debug, Default)]
struct Entry {
    tag: u64,
    valid: bool,
    dirty: bool,
    lru: u64,
    level: u8,
    core: u8,
    referenced: bool,
    was_prefetch: bool,
}

/// Tag-array set-associative cache with LRU.
pub struct SetAssocCache {
    sets: Vec<Vec<Entry>>,
    set_mask: u64,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl SetAssocCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let n = cfg.sets();
        assert!(n.is_power_of_two(), "set count must be a power of two");
        Self {
            sets: vec![vec![Entry::default(); cfg.ways]; n],
            set_mask: n as u64 - 1,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    pub fn set_of(&self, line_addr: u64) -> u64 {
        line_addr & self.set_mask
    }

    pub fn num_sets(&self) -> usize {
        self.sets.len()
    }

    #[inline]
    fn find(&mut self, line_addr: u64) -> Option<&mut Entry> {
        let si = (line_addr & self.set_mask) as usize;
        self.sets[si]
            .iter_mut()
            .find(|e| e.valid && e.tag == line_addr)
    }

    /// Demand access.  Returns `true` on hit (LRU + flags updated).
    pub fn access(&mut self, line_addr: u64, write: bool) -> bool {
        self.access_ex(line_addr, write).hit
    }

    /// Demand access with detail: whether this hit was the *first use* of
    /// a compression-prefetched line (Dynamic-CRAM's benefit event).
    pub fn access_ex(&mut self, line_addr: u64, write: bool) -> AccessInfo {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.find(line_addr) {
            let first_prefetch_use = e.was_prefetch && !e.referenced;
            e.lru = tick;
            e.dirty |= write;
            e.referenced = true;
            self.hits += 1;
            AccessInfo { hit: true, first_prefetch_use }
        } else {
            self.misses += 1;
            AccessInfo { hit: false, first_prefetch_use: false }
        }
    }

    /// Probe without updating state.
    pub fn contains(&self, line_addr: u64) -> bool {
        let si = (line_addr & self.set_mask) as usize;
        self.sets[si].iter().any(|e| e.valid && e.tag == line_addr)
    }

    /// Dirty status of a resident line.
    pub fn is_dirty(&self, line_addr: u64) -> bool {
        let si = (line_addr & self.set_mask) as usize;
        self.sets[si]
            .iter()
            .any(|e| e.valid && e.tag == line_addr && e.dirty)
    }

    /// Prior-compressibility level of a resident line, if present.
    pub fn level_of(&self, line_addr: u64) -> Option<u8> {
        let si = (line_addr & self.set_mask) as usize;
        self.sets[si]
            .iter()
            .find(|e| e.valid && e.tag == line_addr)
            .map(|e| e.level)
    }

    /// Install a line, returning the victim if one had to be evicted.
    /// `prefetch` marks lines installed for free by compression (their
    /// `referenced` bit starts clear and feeds Dynamic-CRAM's benefit
    /// tracking on eviction).
    pub fn fill(
        &mut self,
        line_addr: u64,
        dirty: bool,
        level: u8,
        core: u8,
        prefetch: bool,
    ) -> Option<Evicted> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.find(line_addr) {
            // Already resident (e.g. racing prefetch): merge flags.
            e.dirty |= dirty;
            e.level = level;
            return None;
        }
        let si = (line_addr & self.set_mask) as usize;
        let set = &mut self.sets[si];
        let victim_idx = if let Some(i) = set.iter().position(|e| !e.valid) {
            i
        } else {
            // LRU among valid entries; prefetched-but-unreferenced lines
            // are preferred victims (they are the cheapest to lose).
            set.iter()
                .enumerate()
                .min_by_key(|(_, e)| (e.referenced as u64, e.lru))
                .map(|(i, _)| i)
                .unwrap()
        };
        let v = set[victim_idx];
        set[victim_idx] = Entry {
            tag: line_addr,
            valid: true,
            dirty,
            lru: if prefetch { tick.saturating_sub(1) } else { tick },
            level,
            core,
            referenced: !prefetch,
            was_prefetch: prefetch,
        };
        if v.valid {
            Some(Evicted {
                line_addr: v.tag,
                dirty: v.dirty,
                level: v.level,
                core: v.core,
                referenced: v.referenced,
                was_prefetch: v.was_prefetch,
            })
        } else {
            None
        }
    }

    /// Remove a specific line (returns it if it was present).
    pub fn invalidate(&mut self, line_addr: u64) -> Option<Evicted> {
        let si = (line_addr & self.set_mask) as usize;
        let set = &mut self.sets[si];
        if let Some(i) = set.iter().position(|e| e.valid && e.tag == line_addr) {
            let e = set[i];
            set[i].valid = false;
            Some(Evicted {
                line_addr: e.tag,
                dirty: e.dirty,
                level: e.level,
                core: e.core,
                referenced: e.referenced,
                was_prefetch: e.was_prefetch,
            })
        } else {
            None
        }
    }

    /// Ganged eviction: force out every resident member of `line_addr`'s
    /// group (including the line itself).  Order is slot order.  Returns
    /// an inline (heap-free) gang — a group has at most four members.
    pub fn evict_group(&mut self, line_addr: u64) -> InlineVec<Evicted, 4> {
        let base = group_base(line_addr);
        let mut gang = InlineVec::new();
        for i in 0..GROUP_LINES {
            if let Some(e) = self.invalidate(base + i) {
                gang.push(e);
            }
        }
        gang
    }

    /// Which members of the group are currently resident (slot mask).
    pub fn group_residency(&self, line_addr: u64) -> [bool; 4] {
        let base = group_base(line_addr);
        core::array::from_fn(|i| self.contains(base + i as u64))
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SetAssocCache {
        // 8KB, 2-way: 64 sets
        SetAssocCache::new(CacheConfig { bytes: 8192, ways: 2 })
    }

    #[test]
    fn hit_after_fill() {
        let mut c = small();
        assert!(!c.access(100, false));
        c.fill(100, false, 0, 0, false);
        assert!(c.access(100, false));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction() {
        let mut c = small();
        // two lines in the same set (set = addr & 63)
        c.fill(0, false, 0, 0, false);
        c.fill(64, false, 0, 0, false);
        c.access(0, false); // 0 is now MRU
        let v = c.fill(128, false, 0, 0, false).expect("eviction");
        assert_eq!(v.line_addr, 64);
    }

    #[test]
    fn dirty_propagates_to_victim() {
        let mut c = small();
        c.fill(0, false, 0, 0, false);
        c.access(0, true); // dirty it
        c.fill(64, false, 0, 0, false);
        let v = c.fill(128, false, 0, 0, false).unwrap();
        // 0 was MRU? no: fill(64) is newer... victims by LRU: access(0) at
        // tick2, fill(64) tick3 -> victim is 0 (oldest) with dirty = true
        assert_eq!(v.line_addr, 0);
        assert!(v.dirty);
    }

    #[test]
    fn level_recorded_and_reported() {
        let mut c = small();
        c.fill(8, false, 2, 3, false);
        assert_eq!(c.level_of(8), Some(2));
        let v = c.invalidate(8).unwrap();
        assert_eq!(v.level, 2);
        assert_eq!(v.core, 3);
    }

    #[test]
    fn ganged_eviction_clears_group() {
        let mut c = small();
        for i in 0..4 {
            c.fill(256 + i, i == 1, 1, 0, false);
        }
        c.fill(1000, false, 0, 0, false); // unrelated
        let evicted = c.evict_group(257);
        assert_eq!(evicted.len(), 4);
        assert!(evicted.iter().any(|e| e.dirty));
        for i in 0..4 {
            assert!(!c.contains(256 + i));
        }
        assert!(c.contains(1000));
    }

    #[test]
    fn prefetch_lines_start_unreferenced() {
        let mut c = small();
        c.fill(8, false, 1, 0, true);
        let v = c.invalidate(8).unwrap();
        assert!(!v.referenced);
        assert!(v.was_prefetch);

        c.fill(16, false, 1, 0, true);
        c.access(16, false);
        let v = c.invalidate(16).unwrap();
        assert!(v.referenced, "demand access sets the reuse bit");
    }

    #[test]
    fn prefetch_preferred_victim() {
        let mut c = small();
        c.fill(0, false, 0, 0, false);
        c.access(0, false);
        c.fill(64, false, 0, 0, true); // prefetch, never referenced
        c.access(0, false); // 0 clearly MRU and referenced
        let v = c.fill(128, false, 0, 0, false).unwrap();
        assert_eq!(v.line_addr, 64, "unreferenced prefetch evicted first");
    }

    #[test]
    fn group_residency_mask() {
        let mut c = small();
        c.fill(4, false, 0, 0, false);
        c.fill(6, false, 0, 0, false);
        assert_eq!(c.group_residency(5), [true, false, true, false]);
    }

    #[test]
    fn paper_llc_geometry() {
        let cfg = CacheConfig::paper_llc();
        assert_eq!(cfg.sets(), 8192);
        let c = SetAssocCache::new(cfg);
        assert_eq!(c.num_sets(), 8192);
    }
}
