//! Compressed LLC: Touché-style superblock tags over a fixed data budget.
//!
//! The baseline [`SetAssocCache`](crate::cache::SetAssocCache) holds every
//! line uncompressed, so compression-friendly workloads leave LLC capacity
//! on the table exactly where they would benefit most.  This cache stores
//! lines at their hybrid-compressor size instead:
//!
//! * **Data budget.**  Each set owns a fixed byte budget (default: the
//!   base `ways` × 64 B — the same silicon as the uncompressed array).
//!   Compressed lines pack into it, so a set can hold more lines than it
//!   has ways' worth of data.
//! * **Superblock tags.**  Extra residency needs extra tags, and naive
//!   per-line tags would double the tag array.  Touché's observation:
//!   co-compressible lines are *neighbors*, so one tag per CRAM group
//!   (superblock) with four sector-valid bits covers up to four lines.
//!   Sets are indexed by **group** (not line), each set holding
//!   `ways × tag_ratio` superblock tags (default 2×) — a bounded tag
//!   array that still doubles reachable residency.
//! * **Superblock replacement.**  The victim unit is a whole superblock:
//!   evicting one member of a CRAM group forces out all resident members
//!   *by construction*, which is exactly the ganged-eviction contract the
//!   memory-side CRAM engine needs (packed halves never split, so
//!   writebacks never read-modify-write packed blocks).  Preference
//!   order mirrors the baseline: unreferenced prefetched superblocks
//!   first, then LRU.
//!
//! Capacity telemetry ([`CacheStats`]) samples resident lines/bytes on
//! every demand access, counts evictions forced by tag exhaustion vs the
//! data budget (tag pressure vs data pressure), and reports *effective
//! capacity* — time-averaged resident lines over the uncompressed-
//! equivalent capacity at the same data budget.

use crate::cache::set_assoc::{AccessInfo, CacheConfig, Evicted};
use crate::mem::{group_base, group_of, GROUP_LINES};
use crate::util::small::InlineVec;

/// Knobs of the compressed LLC (the `repro ablate llc` sweep axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompressedLlcConfig {
    /// Superblock tags per set, as a multiple of the base ways (Touché
    /// provisions 2×).
    pub tag_ratio: usize,
    /// Data budget per set in 64-byte lines' worth (0 ⇒ the base ways,
    /// i.e. the same data array as the uncompressed cache).
    pub data_lines: usize,
}

impl Default for CompressedLlcConfig {
    fn default() -> Self {
        Self { tag_ratio: 2, data_lines: 0 }
    }
}

/// Compressed-LLC occupancy / pressure counters.  All counting fields are
/// monotone, so a warmup snapshot subtracts with [`CacheStats::since`]
/// exactly like the scalar bandwidth counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Occupancy samples taken (one per demand access).
    pub samples: u64,
    /// Sum over samples of resident lines (÷ `samples` = average).
    pub lines_sum: u64,
    /// Sum over samples of resident compressed bytes.
    pub bytes_sum: u64,
    /// Superblock evictions forced by tag exhaustion (tag pressure).
    pub tag_evictions: u64,
    /// Superblock evictions forced by the data budget (data pressure).
    pub data_evictions: u64,
    /// Uncompressed-equivalent capacity in lines at the same data budget
    /// (sets × data budget ÷ 64 B) — the denominator of effective capacity.
    pub baseline_lines: u64,
    /// Total superblock tags across the cache.
    pub tag_capacity: u64,
}

impl CacheStats {
    /// Time-averaged resident lines.
    pub fn avg_lines(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.lines_sum as f64 / self.samples as f64
        }
    }

    /// Time-averaged resident compressed bytes.
    pub fn avg_bytes(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.bytes_sum as f64 / self.samples as f64
        }
    }

    /// Effective capacity: average resident lines over the uncompressed-
    /// equivalent capacity (> 1.0 ⇔ compression bought real residency).
    pub fn effective_ratio(&self) -> f64 {
        if self.baseline_lines == 0 {
            0.0
        } else {
            self.avg_lines() / self.baseline_lines as f64
        }
    }

    /// Counter-wise difference vs a warmup snapshot (capacities carry
    /// over unchanged).
    pub fn since(&self, warm: &CacheStats) -> CacheStats {
        CacheStats {
            samples: self.samples - warm.samples,
            lines_sum: self.lines_sum - warm.lines_sum,
            bytes_sum: self.bytes_sum - warm.bytes_sum,
            tag_evictions: self.tag_evictions - warm.tag_evictions,
            data_evictions: self.data_evictions - warm.data_evictions,
            baseline_lines: self.baseline_lines,
            tag_capacity: self.tag_capacity,
        }
    }
}

/// One superblock tag: a CRAM group with per-slot sector state.
#[derive(Clone, Copy, Debug, Default)]
struct SuperBlock {
    /// Group index (line address ÷ 4).  Meaningless when `valid == 0`.
    tag: u64,
    /// Per-slot residency bits (bit s ⇔ line `tag*4 + s` resident).
    valid: u8,
    dirty: u8,
    referenced: u8,
    prefetch: u8,
    /// LRU clock at superblock granularity (access or fill of any member).
    lru: u64,
    /// Prior-compressibility tag bits per slot (0/1/2 — §V-A).
    level: [u8; 4],
    /// Requesting core per slot (Dynamic-CRAM attribution).
    core: [u8; 4],
    /// Stored (compressed) size per slot in bytes; counts against the
    /// set's data budget while the slot is valid.
    size: [u8; 4],
}

impl SuperBlock {
    #[inline]
    fn evicted(&self, slot: usize) -> Evicted {
        Evicted {
            line_addr: self.tag * GROUP_LINES + slot as u64,
            dirty: self.dirty & (1 << slot) != 0,
            level: self.level[slot],
            core: self.core[slot],
            referenced: self.referenced & (1 << slot) != 0,
            was_prefetch: self.prefetch & (1 << slot) != 0,
        }
    }
}

/// The compressed LLC.  API mirrors [`SetAssocCache`] where the
/// simulator needs it; `fill` additionally takes the line's compressed
/// size and may evict several superblocks to make room.
pub struct CompressedCache {
    sets: Vec<Vec<SuperBlock>>,
    /// Resident compressed bytes per set (kept incrementally).
    occ: Vec<u32>,
    set_mask: u64,
    /// Data budget per set in bytes.
    budget: u32,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    /// Currently resident lines / compressed bytes (cache-wide).
    lines_now: u64,
    bytes_now: u64,
    stats: CacheStats,
}

impl CompressedCache {
    pub fn new(base: CacheConfig, cfg: CompressedLlcConfig) -> Self {
        let n = base.sets();
        assert!(n.is_power_of_two(), "set count must be a power of two");
        let tags = base.ways * cfg.tag_ratio.max(1);
        let data_lines = if cfg.data_lines == 0 { base.ways } else { cfg.data_lines };
        let budget = (data_lines * 64) as u32;
        // a full superblock is at most 4 × 64 B; the budget must hold one
        // so the eviction loop (which spares the superblock being filled)
        // always terminates within budget
        assert!(
            budget >= 64 * GROUP_LINES as u32,
            "data budget must hold one full superblock (got {budget} B)"
        );
        Self {
            sets: vec![vec![SuperBlock::default(); tags]; n],
            occ: vec![0; n],
            set_mask: n as u64 - 1,
            budget,
            tick: 0,
            hits: 0,
            misses: 0,
            lines_now: 0,
            bytes_now: 0,
            stats: CacheStats {
                baseline_lines: (n * data_lines) as u64,
                tag_capacity: (n * tags) as u64,
                ..CacheStats::default()
            },
        }
    }

    /// Sets are indexed by *group* so a superblock tag covers all four
    /// members (they must co-reside for the tag to reach them).
    #[inline]
    fn set_of(&self, line_addr: u64) -> usize {
        (group_of(line_addr) & self.set_mask) as usize
    }

    pub fn num_sets(&self) -> usize {
        self.sets.len()
    }

    /// Occupancy / pressure counters (plus hits/misses on the struct).
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    #[inline]
    fn find(&self, si: usize, group: u64) -> Option<usize> {
        self.sets[si]
            .iter()
            .position(|sb| sb.valid != 0 && sb.tag == group)
    }

    /// Demand access.  Returns hit status plus the first-use flag of a
    /// compression-prefetched line (Dynamic-CRAM's benefit event), and
    /// samples the occupancy telemetry.
    pub fn access_ex(&mut self, line_addr: u64, write: bool) -> AccessInfo {
        self.tick += 1;
        let tick = self.tick;
        self.stats.samples += 1;
        self.stats.lines_sum += self.lines_now;
        self.stats.bytes_sum += self.bytes_now;
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr - group_base(line_addr)) as usize;
        let bit = 1u8 << slot;
        if let Some(i) = self.find(si, group) {
            let sb = &mut self.sets[si][i];
            if sb.valid & bit != 0 {
                let first_prefetch_use =
                    sb.prefetch & bit != 0 && sb.referenced & bit == 0;
                sb.lru = tick;
                if write {
                    sb.dirty |= bit;
                }
                sb.referenced |= bit;
                self.hits += 1;
                return AccessInfo { hit: true, first_prefetch_use };
            }
        }
        self.misses += 1;
        AccessInfo { hit: false, first_prefetch_use: false }
    }

    /// Probe without updating state.
    pub fn contains(&self, line_addr: u64) -> bool {
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr % GROUP_LINES) as usize;
        self.find(si, group)
            .is_some_and(|i| self.sets[si][i].valid & (1 << slot) != 0)
    }

    /// Dirty status of a resident line.
    pub fn is_dirty(&self, line_addr: u64) -> bool {
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr % GROUP_LINES) as usize;
        self.find(si, group)
            .is_some_and(|i| self.sets[si][i].dirty & (1 << slot) != 0)
    }

    /// Prior-compressibility level of a resident line, if present.
    pub fn level_of(&self, line_addr: u64) -> Option<u8> {
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr % GROUP_LINES) as usize;
        self.find(si, group).and_then(|i| {
            let sb = &self.sets[si][i];
            (sb.valid & (1 << slot) != 0).then_some(sb.level[slot])
        })
    }

    /// Stored (compressed) size of a resident line.
    pub fn size_of(&self, line_addr: u64) -> Option<u32> {
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr % GROUP_LINES) as usize;
        self.find(si, group).and_then(|i| {
            let sb = &self.sets[si][i];
            (sb.valid & (1 << slot) != 0).then_some(sb.size[slot] as u32)
        })
    }

    /// Evict the whole superblock at `sets[si][idx]`, appending every
    /// resident member to `victims` in slot order (a natural gang).
    fn evict_superblock(&mut self, si: usize, idx: usize, victims: &mut Vec<Evicted>) {
        let sb = self.sets[si][idx];
        for slot in 0..GROUP_LINES as usize {
            if sb.valid & (1 << slot) != 0 {
                victims.push(sb.evicted(slot));
                self.lines_now -= 1;
                self.bytes_now -= sb.size[slot] as u64;
                self.occ[si] -= sb.size[slot] as u32;
            }
        }
        self.sets[si][idx] = SuperBlock::default();
    }

    /// Victim superblock in `si`, sparing index `keep`: unreferenced
    /// prefetched superblocks first (cheapest to lose — mirrors the
    /// baseline cache), then LRU.  `None` when only `keep` is live.
    fn pick_victim(&self, si: usize, keep: usize) -> Option<usize> {
        self.sets[si]
            .iter()
            .enumerate()
            .filter(|&(i, sb)| i != keep && sb.valid != 0)
            .min_by_key(|(_, sb)| ((sb.referenced != 0) as u64, sb.lru))
            .map(|(i, _)| i)
    }

    /// Install a line stored at `size` compressed bytes.  Every line
    /// forced out lands in `victims` — whole superblocks in slot order,
    /// so consecutive entries of one group form the gang the memory
    /// controller's ganged-writeback contract expects.
    #[allow(clippy::too_many_arguments)]
    pub fn fill(
        &mut self,
        line_addr: u64,
        dirty: bool,
        level: u8,
        core: u8,
        prefetch: bool,
        size: u32,
        victims: &mut Vec<Evicted>,
    ) {
        debug_assert!((1..=64).contains(&size), "line size {size} out of range");
        self.tick += 1;
        let tick = self.tick;
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr % GROUP_LINES) as usize;
        let bit = 1u8 << slot;

        let idx = match self.find(si, group) {
            Some(i) => i,
            None => {
                // allocate a tag: a free entry if any, else evict the
                // victim superblock (tag pressure)
                match self.sets[si].iter().position(|sb| sb.valid == 0) {
                    Some(free) => free,
                    None => {
                        let v = self
                            .pick_victim(si, usize::MAX)
                            .expect("a full tag array has a victim");
                        self.stats.tag_evictions += 1;
                        self.evict_superblock(si, v, victims);
                        v
                    }
                }
            }
        };

        {
            let sb = &mut self.sets[si][idx];
            if sb.valid == 0 {
                sb.tag = group;
            }
            if sb.valid & bit != 0 {
                // already resident (e.g. racing prefetch): merge flags,
                // refresh the stored size (mirrors the baseline merge) —
                // and fall through to the budget loop below, since a line
                // re-installed at a larger size can push the set over
                if dirty {
                    sb.dirty |= bit;
                }
                sb.level[slot] = level;
                let old = sb.size[slot] as u32;
                sb.size[slot] = size as u8;
                self.occ[si] = self.occ[si] - old + size;
                self.bytes_now = self.bytes_now - old as u64 + size as u64;
            } else {
                sb.valid |= bit;
                if dirty {
                    sb.dirty |= bit;
                } else {
                    sb.dirty &= !bit;
                }
                sb.level[slot] = level;
                sb.core[slot] = core;
                sb.size[slot] = size as u8;
                if prefetch {
                    sb.prefetch |= bit;
                    sb.referenced &= !bit;
                    // prefetches age like the baseline: one tick older
                    // than a demand fill, so they lose LRU ties to
                    // demanded data
                    sb.lru = sb.lru.max(tick.saturating_sub(1));
                } else {
                    sb.prefetch &= !bit;
                    sb.referenced |= bit;
                    sb.lru = tick;
                }
                self.occ[si] += size;
                self.lines_now += 1;
                self.bytes_now += size as u64;
            }
        }

        // data budget: shed LRU superblocks (sparing the one just filled)
        // until the set fits again
        while self.occ[si] > self.budget {
            let Some(v) = self.pick_victim(si, idx) else {
                // only the filled superblock is live; it fits the budget
                // by the constructor invariant (budget >= 256 B)
                debug_assert!(self.occ[si] <= self.budget);
                break;
            };
            self.stats.data_evictions += 1;
            self.evict_superblock(si, v, victims);
        }
    }

    /// Remove a specific line (returns it if it was present).
    pub fn invalidate(&mut self, line_addr: u64) -> Option<Evicted> {
        let si = self.set_of(line_addr);
        let group = group_of(line_addr);
        let slot = (line_addr % GROUP_LINES) as usize;
        let bit = 1u8 << slot;
        let i = self.find(si, group)?;
        let sb = &mut self.sets[si][i];
        if sb.valid & bit == 0 {
            return None;
        }
        let out = sb.evicted(slot);
        let size = sb.size[slot];
        sb.valid &= !bit;
        sb.dirty &= !bit;
        sb.referenced &= !bit;
        sb.prefetch &= !bit;
        if sb.valid == 0 {
            *sb = SuperBlock::default();
        }
        self.occ[si] -= size as u32;
        self.lines_now -= 1;
        self.bytes_now -= size as u64;
        Some(out)
    }

    /// Ganged eviction: force out every resident member of `line_addr`'s
    /// group.  With superblock tags the group lives under one tag in one
    /// set, so this clears a single superblock; order is slot order.
    pub fn evict_group(&mut self, line_addr: u64) -> InlineVec<Evicted, 4> {
        let base = group_base(line_addr);
        let mut gang = InlineVec::new();
        for i in 0..GROUP_LINES {
            if let Some(e) = self.invalidate(base + i) {
                gang.push(e);
            }
        }
        gang
    }

    /// Which members of the group are currently resident (slot mask).
    pub fn group_residency(&self, line_addr: u64) -> [bool; 4] {
        let base = group_base(line_addr);
        core::array::from_fn(|i| self.contains(base + i as u64))
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 16 KB, 4-way base geometry: 64 sets, 256 B data budget per set,
    /// 8 superblock tags per set at the default 2× ratio.
    fn small() -> CompressedCache {
        CompressedCache::new(
            CacheConfig { bytes: 16384, ways: 4 },
            CompressedLlcConfig::default(),
        )
    }

    fn fill1(c: &mut CompressedCache, line: u64, dirty: bool, size: u32) -> Vec<Evicted> {
        let mut v = Vec::new();
        c.fill(line, dirty, 0, 0, false, size, &mut v);
        v
    }

    #[test]
    fn hit_after_fill() {
        let mut c = small();
        assert!(!c.access_ex(100, false).hit);
        assert!(fill1(&mut c, 100, false, 32).is_empty());
        assert!(c.access_ex(100, false).hit);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.size_of(100), Some(32));
    }

    #[test]
    fn group_members_share_one_set_and_tag() {
        let mut c = small();
        for i in 0..4 {
            fill1(&mut c, 256 + i, false, 16);
        }
        assert_eq!(c.group_residency(257), [true; 4]);
        // one superblock: evicting via the group API clears all four
        let gang = c.evict_group(258);
        assert_eq!(gang.len(), 4);
        assert_eq!(c.group_residency(257), [false; 4]);
    }

    #[test]
    fn compressed_lines_exceed_base_ways() {
        let mut c = small();
        // 8 whole groups of 8-byte lines map to set 0 (groups 0, 64, ...):
        // 32 resident lines in a set whose base geometry holds 4 raw lines
        for g in 0..8u64 {
            for s in 0..4u64 {
                let v = fill1(&mut c, g * 64 * 4 + s, false, 8);
                assert!(v.is_empty(), "256 B of 8 B lines fit the budget");
            }
        }
        for g in 0..8u64 {
            for s in 0..4u64 {
                assert!(c.contains(g * 64 * 4 + s));
            }
        }
        let st = c.stats();
        assert_eq!(st.tag_evictions + st.data_evictions, 0);
    }

    #[test]
    fn tag_exhaustion_evicts_whole_superblock() {
        let mut c = small();
        // fill all 8 tags of set 0 with full groups of tiny lines
        for g in 0..8u64 {
            for s in 0..4u64 {
                fill1(&mut c, g * 64 * 4 + s, s == 1, 4);
            }
        }
        // a 9th group in the same set: no free tag, data budget fine
        let v = fill1(&mut c, 8 * 64 * 4, false, 4);
        assert_eq!(v.len(), 4, "tag victim is a whole superblock (a gang)");
        let base = group_base(v[0].line_addr);
        assert!(v.iter().all(|e| group_base(e.line_addr) == base));
        assert!(v.iter().any(|e| e.dirty), "dirty bit travels with the gang");
        assert_eq!(c.stats().tag_evictions, 1);
        assert_eq!(c.stats().data_evictions, 0);
    }

    #[test]
    fn data_budget_evicts_under_incompressible_fill() {
        let mut c = small();
        // 64-byte (raw) lines: the 256 B budget holds four; a fifth in the
        // same set must force a data eviction despite free tags
        for g in 0..4u64 {
            let v = fill1(&mut c, g * 64 * 4, false, 64);
            assert!(v.is_empty());
        }
        let v = fill1(&mut c, 4 * 64 * 4, false, 64);
        assert_eq!(v.len(), 1);
        assert_eq!(c.stats().data_evictions, 1);
        assert_eq!(c.stats().tag_evictions, 0);
    }

    #[test]
    fn lru_and_prefetch_preference_mirror_baseline() {
        let mut c = small();
        fill1(&mut c, 0, false, 64); // group 0
        c.access_ex(0, false);
        // prefetched, never-referenced group: preferred victim
        let mut v = Vec::new();
        c.fill(64 * 4, false, 0, 0, true, 64, &mut v);
        c.access_ex(0, false); // group 0 clearly MRU and referenced
        fill1(&mut c, 2 * 64 * 4, false, 64);
        fill1(&mut c, 3 * 64 * 4, false, 64);
        let vict = fill1(&mut c, 4 * 64 * 4, false, 64);
        assert_eq!(vict.len(), 1);
        assert_eq!(vict[0].line_addr, 64 * 4, "unreferenced prefetch evicted first");
        assert!(vict[0].was_prefetch);
        assert!(!vict[0].referenced);
    }

    #[test]
    fn first_prefetch_use_reported_once() {
        let mut c = small();
        let mut v = Vec::new();
        c.fill(8, false, 1, 2, true, 16, &mut v);
        let a = c.access_ex(8, false);
        assert!(a.hit && a.first_prefetch_use);
        let b = c.access_ex(8, false);
        assert!(b.hit && !b.first_prefetch_use);
    }

    #[test]
    fn invalidate_round_trips_flags() {
        let mut c = small();
        let mut v = Vec::new();
        c.fill(8, true, 2, 3, false, 24, &mut v);
        assert!(c.is_dirty(8));
        assert_eq!(c.level_of(8), Some(2));
        let e = c.invalidate(8).unwrap();
        assert_eq!(e.line_addr, 8);
        assert!(e.dirty);
        assert_eq!(e.level, 2);
        assert_eq!(e.core, 3);
        assert!(!c.contains(8));
        assert_eq!(c.invalidate(8), None);
    }

    #[test]
    fn occupancy_telemetry_tracks_residency() {
        let mut c = small();
        fill1(&mut c, 0, false, 8);
        fill1(&mut c, 1, false, 8);
        c.access_ex(0, false); // sample: 2 lines, 16 bytes
        c.access_ex(1, false); // sample: 2 lines, 16 bytes
        let st = c.stats();
        assert_eq!(st.samples, 2);
        assert_eq!(st.lines_sum, 4);
        assert_eq!(st.bytes_sum, 32);
        assert!((st.avg_lines() - 2.0).abs() < 1e-12);
        assert!((st.avg_bytes() - 16.0).abs() < 1e-12);
        // warmup subtraction
        let warm = st;
        c.access_ex(0, false);
        let d = c.stats().since(&warm);
        assert_eq!(d.samples, 1);
        assert_eq!(d.lines_sum, 2);
        assert_eq!(d.baseline_lines, warm.baseline_lines);
    }

    #[test]
    fn effective_ratio_exceeds_one_when_packed() {
        let mut c = small();
        // resident: 8 sets' worth is irrelevant — stuff one set beyond its
        // base ways and sample
        for g in 0..8u64 {
            for s in 0..4u64 {
                fill1(&mut c, g * 64 * 4 + s, false, 8);
            }
        }
        c.access_ex(0, false);
        let st = c.stats();
        // 32 lines resident vs baseline 64 sets * 4 ways = 256 — the
        // *cache-wide* ratio needs every set filled; check the raw sums
        assert_eq!(st.lines_sum, 32);
        assert_eq!(st.baseline_lines, 256);
        assert_eq!(st.tag_capacity, 64 * 8);
    }

    #[test]
    fn merge_refreshes_size_and_occupancy() {
        let mut c = small();
        fill1(&mut c, 0, false, 64);
        fill1(&mut c, 0, true, 16); // re-fill resident line at smaller size
        assert!(c.is_dirty(0));
        assert_eq!(c.size_of(0), Some(16));
        // freed budget: three more raw lines now fit without eviction
        for g in 1..4u64 {
            assert!(fill1(&mut c, g * 64 * 4, false, 64).is_empty());
        }
        assert!(fill1(&mut c, 4 * 64 * 4, false, 16).is_empty());
        assert_eq!(c.stats().data_evictions, 0);
    }

    #[test]
    fn merge_growth_enforces_budget() {
        let mut c = small();
        fill1(&mut c, 0, false, 8);
        fill1(&mut c, 1, false, 8);
        for g in 1..5u64 {
            let sz = if g == 4 { 32 } else { 64 };
            assert!(fill1(&mut c, g * 64 * 4, false, sz).is_empty());
        }
        // resident line 0 re-installed at raw size: occupancy grows past
        // the 256 B budget and the set must shed a victim superblock
        let v = fill1(&mut c, 0, false, 64);
        assert_eq!(c.size_of(0), Some(64));
        assert!(!v.is_empty(), "growth past the budget must evict");
        assert_eq!(c.stats().data_evictions, 1);
        assert!(c.contains(1), "the merged superblock itself is spared");
    }

    #[test]
    #[should_panic(expected = "data budget must hold one full superblock")]
    fn tiny_data_budget_rejected() {
        let _ = CompressedCache::new(
            CacheConfig { bytes: 8192, ways: 2 },
            CompressedLlcConfig::default(),
        );
    }

    #[test]
    fn paper_llc_geometry_budget() {
        let c = CompressedCache::new(CacheConfig::paper_llc(), CompressedLlcConfig::default());
        assert_eq!(c.num_sets(), 8192);
        let st = c.stats();
        assert_eq!(st.baseline_lines, 8192 * 16);
        assert_eq!(st.tag_capacity, 8192 * 32);
    }
}
