//! On-chip cache substrate: a tag-array set-associative cache model with
//! the CRAM-specific tag extensions (2-bit prior-compressibility, core id
//! + reuse bit for sampled sets) and ganged eviction of compressed groups.
//!
//! Two LLC organizations share the `Evicted`/`AccessInfo` contracts:
//!
//! * [`SetAssocCache`] — the baseline uncompressed tag array;
//! * [`CompressedCache`] — the Touché-style compressed LLC (superblock
//!   tags over a fixed per-set data budget), selected by
//!   `SimConfig::llc_compressed`.
//!
//! The simulator is trace-driven at line granularity, so the caches track
//! tags, flags and (compressed) sizes only — data bytes live in the
//! byte-accurate [`crate::cram::store::CompressedStore`] when fidelity
//! demands it.

pub mod compressed;
pub mod set_assoc;

pub use compressed::{CacheStats, CompressedCache, CompressedLlcConfig};
pub use set_assoc::{AccessInfo, CacheConfig, Evicted, SetAssocCache};
