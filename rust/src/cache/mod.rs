//! On-chip cache substrate: a tag-array set-associative cache model with
//! the CRAM-specific tag extensions (2-bit prior-compressibility, core id
//! + reuse bit for sampled sets) and ganged eviction of compressed groups.
//!
//! The simulator is trace-driven at line granularity, so the cache tracks
//! tags and flags only — data bytes live in the byte-accurate
//! [`crate::cram::store::CompressedStore`] when fidelity demands it.

pub mod set_assoc;

pub use set_assoc::{AccessInfo, CacheConfig, Evicted, SetAssocCache};
