//! Small shared utilities: deterministic RNG, statistics helpers, and the
//! in-crate bench / property-test harnesses (criterion and proptest are not
//! available in this offline environment — see DESIGN.md §Substitutions).

pub mod bench;
pub mod json;
pub mod rng;
pub mod small;
pub mod testkit;

/// FNV-1a 64-bit hash — the deterministic hash behind the results-db
/// stripe index and the persistent-cache fingerprint.  `DefaultHasher`
/// makes no cross-version stability promise, so anything that reaches
/// disk (or picks a shard that tests pin) hashes through this instead.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Geometric mean of a slice of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Format a ratio as a signed percentage string, e.g. 1.063 -> "+6.3%".
pub fn pct(ratio: f64) -> String {
    format!("{:+.1}%", (ratio - 1.0) * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 1.0);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(1.063), "+6.3%");
        assert_eq!(pct(0.9), "-10.0%");
    }
}
