//! Minimal JSON reader/writer for the persistent results cache
//! (`coordinator::persist`) — serde is not available in this offline
//! environment (DESIGN.md §Substitutions).
//!
//! Numbers are kept as their **raw source token** (`Json::Num(String)`)
//! instead of an `f64`, so 64-bit counters round-trip exactly: the cache
//! stores cycle counts and byte totals that an intermediate `f64` would
//! silently truncate past 2^53.  Typed accessors (`as_u64`, `as_f64`, …)
//! parse the token on demand.

/// A parsed JSON value.  Object fields keep their source order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Raw number token exactly as it appeared in the source.
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Escape a string for embedding in a JSON document (no surrounding
/// quotes).  Control characters take the `\u00XX` form.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\n' | b'\r' | b'\t')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.num(),
            Some(c) => Err(format!("unexpected byte {:?} at offset {}", *c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, pat: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(pat.as_bytes()) {
            self.i += pat.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn num(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(
            self.b.get(self.i),
            Some(c) if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.i += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        // validate the token shape once; accessors re-parse to the width
        // the caller asks for
        tok.parse::<f64>()
            .map_err(|_| format!("bad number {tok:?} at offset {start}"))?;
        Ok(Json::Num(tok.to_string()))
    }

    fn string(&mut self) -> Result<String, String> {
        debug_assert_eq!(self.b.get(self.i), Some(&b'"'));
        self.i += 1;
        let mut out: Vec<u8> = Vec::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return String::from_utf8(out).map_err(|_| "bad utf-8 in string".into());
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.b.get(self.i).ok_or("unterminated escape")?;
                    match esc {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b'r' => out.push(b'\r'),
                        b't' => out.push(b'\t'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            // BMP only — the writer never emits surrogates
                            let c = char::from_u32(code)
                                .ok_or("surrogate \\u escape unsupported")?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape \\{}", *c as char)),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    out.push(c);
                    self.i += 1;
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Json, String> {
        self.i += 1; // '['
        self.ws();
        let mut items = Vec::new();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn obj(&mut self) -> Result<Json, String> {
        self.i += 1; // '{'
        self.ws();
        let mut fields = Vec::new();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            if self.b.get(self.i) != Some(&b'"') {
                return Err(format!("expected object key at offset {}", self.i));
            }
            let key = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at offset {}", self.i));
            }
            self.i += 1;
            self.ws();
            fields.push((key, self.value()?));
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a":1,"b":[true,null,"x\n\"y\""],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert!(arr[1].is_null());
        assert_eq!(arr[2].as_str(), Some("x\n\"y\""));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn large_u64_round_trips_exactly() {
        // past 2^53 — an f64 intermediate would corrupt this
        let doc = format!("{{\"v\":{}}}", u64::MAX);
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("v").unwrap().as_u64(), Some(u64::MAX));
        assert_eq!(v.get("v").unwrap().as_f64(), Some(u64::MAX as f64));
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let s = "line1\nline2\t\"quoted\" \\ \u{1}unicode\u{e9}";
        let doc = format!("{{\"k\":\"{}\"}}", escape(s));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(s));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn whitespace_and_empty_containers() {
        let v = Json::parse(" { \"a\" : [ ] , \"b\" : { } } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(matches!(v.get("b"), Some(Json::Obj(f)) if f.is_empty()));
    }
}
