//! Deterministic pseudo-random number generation.
//!
//! Everything in the simulator is seeded so experiments are exactly
//! reproducible.  `SplitMix64` doubles as the keyed per-line marker hash
//! (the paper uses a DES-based keyed hash; crypto strength is irrelevant to
//! the performance claims — what matters is that markers are per-line,
//! keyed by a per-machine secret, and cheap to regenerate on LIT overflow).

/// SplitMix64 — tiny, high-quality 64-bit mixer.  Used both as a stream RNG
/// and as a keyed hash via [`splitmix64`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix64(self.state)
    }
}

/// One-shot SplitMix64 finalizer: hash `x` under `key`.
#[inline]
pub fn splitmix64(key: u64, x: u64) -> u64 {
    mix64(key ^ x.wrapping_mul(0x9E3779B97F4A7C15))
}

#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — the workhorse stream RNG for trace generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`.  `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (simulation RNG, not crypto): map the 64-bit value to [0, n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Geometric-ish positive integer with the given mean (>= 1).
    #[inline]
    pub fn geometric(&mut self, mean: f64) -> u64 {
        if mean <= 1.0 {
            return 1;
        }
        let p = 1.0 / mean;
        let u = self.f64().max(1e-300);
        let g = (u.ln() / (1.0 - p).ln()).ceil();
        g.max(1.0) as u64
    }

    /// Pick an index according to (unnormalized) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let avg = sum / 10_000.0;
        assert!((avg - 0.5).abs() < 0.02, "avg={avg}");
    }

    #[test]
    fn geometric_mean_close() {
        let mut r = Rng::new(11);
        let mean = 20.0;
        let n = 20_000;
        let s: u64 = (0..n).map(|_| r.geometric(mean)).sum();
        let avg = s as f64 / n as f64;
        assert!((avg - mean).abs() < 1.0, "avg={avg}");
    }

    #[test]
    fn splitmix_keyed_hash_distinct() {
        // different keys must give different markers for the same address
        let a = splitmix64(1, 0x1234);
        let b = splitmix64(2, 0x1234);
        assert_ne!(a, b);
        // and different addresses different markers under one key
        assert_ne!(splitmix64(1, 1), splitmix64(1, 2));
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}
