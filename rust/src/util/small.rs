//! `InlineVec` — a fixed-capacity, stack-allocated vector (SmallVec-style
//! without the heap spill), used on the simulator's per-access hot paths
//! where the element count is architecturally bounded: a compression group
//! has exactly four lines, so probe lists, install lists, written-location
//! lists and ganged-eviction sets never exceed four entries.  Replacing
//! `Vec` with this type removes one heap allocation per LLC miss / group
//! writeback.
//!
//! Pushing beyond `N` panics — on these paths that is a simulator bug, not
//! a recoverable condition.

/// Fixed-capacity inline vector.  Derefs to a slice, so all `&[T]` reads
/// (`len`, `iter`, indexing, `contains`, ...) work unchanged.
#[derive(Clone, Copy, Debug)]
pub struct InlineVec<T: Copy + Default, const N: usize> {
    items: [T; N],
    len: u8,
}

impl<T: Copy + Default, const N: usize> InlineVec<T, N> {
    pub fn new() -> Self {
        Self { items: [T::default(); N], len: 0 }
    }

    /// Build from a slice (must fit in `N`).
    pub fn of(items: &[T]) -> Self {
        let mut v = Self::new();
        for &x in items {
            v.push(x);
        }
        v
    }

    /// Append an element.  Panics if the vector is full.
    #[inline]
    pub fn push(&mut self, x: T) {
        assert!((self.len as usize) < N, "InlineVec overflow (capacity {N})");
        self.items[self.len as usize] = x;
        self.len += 1;
    }

    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.items[..self.len as usize]
    }

    /// Mutable view of the live elements (used to patch fields in place,
    /// e.g. the controller filling `Install::size` for a compressed LLC).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.items[..self.len as usize]
    }

    #[inline]
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

impl<T: Copy + Default, const N: usize> Default for InlineVec<T, N> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy + Default, const N: usize> std::ops::Deref for InlineVec<T, N> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy + Default + PartialEq, const N: usize> PartialEq for InlineVec<T, N> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + Default + Eq, const N: usize> Eq for InlineVec<T, N> {}

impl<'a, T: Copy + Default, const N: usize> IntoIterator for &'a InlineVec<T, N> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read_back() {
        let mut v: InlineVec<u64, 4> = InlineVec::new();
        assert!(v.is_empty());
        v.push(10);
        v.push(20);
        assert_eq!(v.len(), 2);
        assert_eq!(v.as_slice(), &[10, 20]);
        assert_eq!(v[1], 20);
        assert!(v.contains(&10));
    }

    #[test]
    fn of_builds_from_slice() {
        let v: InlineVec<u32, 4> = InlineVec::of(&[1, 2, 3]);
        assert_eq!(v.as_slice(), &[1, 2, 3]);
        let empty: InlineVec<u32, 4> = InlineVec::of(&[]);
        assert!(empty.is_empty());
    }

    #[test]
    fn equality_ignores_spare_capacity() {
        let a: InlineVec<u8, 4> = InlineVec::of(&[1, 2]);
        let mut b: InlineVec<u8, 4> = InlineVec::of(&[1, 2, 9]);
        assert_ne!(a, b);
        b.clear();
        let b = InlineVec::of(&[1, 2]);
        assert_eq!(a, b);
    }

    #[test]
    fn iterates_like_a_slice() {
        let v: InlineVec<u64, 4> = InlineVec::of(&[5, 6, 7]);
        let mut sum = 0;
        for &x in &v {
            sum += x;
        }
        assert_eq!(sum, 18);
        assert_eq!(v.iter().copied().max(), Some(7));
    }

    #[test]
    #[should_panic(expected = "InlineVec overflow")]
    fn overflow_panics() {
        let mut v: InlineVec<u8, 2> = InlineVec::new();
        v.push(1);
        v.push(2);
        v.push(3);
    }
}
