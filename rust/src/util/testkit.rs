//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `forall` drives a closure with a seeded RNG for `cases` iterations and,
//! on failure, re-runs a *shrinking* pass: it retries the failing case id
//! so the panic message always contains a reproducible `(seed, case)` pair.
//!
//! ```
//! use cram::util::testkit::forall;
//! forall("addition commutes", 1000, |rng| {
//!     let a = rng.next_u32() as u64;
//!     let b = rng.next_u32() as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Default number of cases for property tests.
pub const DEFAULT_CASES: u64 = 256;

/// Run `body` for `cases` seeded cases.  Panics with a reproducible label
/// if any case fails.
pub fn forall<F: FnMut(&mut Rng) + std::panic::UnwindSafe + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    mut body: F,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u32 fits u64", 64, |rng| {
            let x = rng.next_u32() as u64;
            assert!(x <= u32::MAX as u64);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failing_case() {
        forall("always fails", 8, |_rng| {
            assert!(false, "boom");
        });
    }
}
