//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs, reporting median / mean / p10 /
//! p90 like criterion's summary line.  Used by the `rust/benches/*` targets
//! (declared `harness = false`).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    /// Elements per second at the median, if a throughput denominator set.
    pub fn elems_per_sec(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.median.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let thr = match self.elems_per_sec() {
            Some(t) if t >= 1e6 => format!("  {:>8.2} Melem/s", t / 1e6),
            Some(t) => format!("  {:>8.2} Kelem/s", t / 1e3),
            None => String::new(),
        };
        format!(
            "{:<44} time: [{:>10.3?} {:>10.3?} {:>10.3?}]{}",
            self.name, self.p10, self.median, self.p90, thr
        )
    }

    /// One JSON object for the CI bench artifact:
    /// `{"name": ..., "median_ns": ..., "melem_per_s": ...}`.
    pub fn json(&self) -> String {
        let name = self.name.replace('\\', "\\\\").replace('"', "\\\"");
        match self.elems_per_sec() {
            Some(t) => format!(
                "{{\"name\":\"{}\",\"median_ns\":{},\"melem_per_s\":{:.3}}}",
                name,
                self.median.as_nanos(),
                t / 1e6
            ),
            None => format!(
                "{{\"name\":\"{}\",\"median_ns\":{}}}",
                name,
                self.median.as_nanos()
            ),
        }
    }
}

/// Write a bench-result set as a JSON array (the `BENCH_*.json` CI
/// artifacts that record the repo's perf trajectory).
pub fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let body: Vec<String> = results.iter().map(|r| r.json()).collect();
    std::fs::write(path, format!("[\n  {}\n]\n", body.join(",\n  ")))
}

/// Extract every `melem_per_s` value from a `BENCH_*.json` body (the
/// format [`write_json`] emits; a full JSON parser would be a dependency
/// this crate deliberately avoids).
pub fn read_json_melems(text: &str) -> Vec<f64> {
    let key = "\"melem_per_s\":";
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(i) = rest.find(key) {
        rest = &rest[i + key.len()..];
        let end = rest
            .find(|c: char| c == ',' || c == '}')
            .unwrap_or(rest.len());
        if let Ok(v) = rest[..end].trim().parse::<f64>() {
            out.push(v);
        }
        rest = &rest[end..];
    }
    out
}

/// Median of a non-empty value set.
pub fn median(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty(), "median of empty set");
    xs.sort_by(|a, b| a.partial_cmp(b).expect("no NaN throughputs"));
    xs[xs.len() / 2]
}

/// The bench regression gate (`repro bench --check`, run by CI and
/// locally): compare the median Melem/s of `current` against the
/// committed baseline at `baseline_path`, failing when throughput
/// regressed by more than `tolerance_pct` percent.
///
/// A missing or throughput-free baseline is a *bootstrap pass* (the gate
/// reports how to record one) so the job stays green on branches created
/// before the baseline landed.
pub fn check_regression(
    baseline_path: &str,
    current_melems: &[f64],
    tolerance_pct: f64,
) -> Result<String, String> {
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            return Ok(format!(
                "bench check: no baseline at {baseline_path} — bootstrap pass \
                 (record one with `repro bench --json {baseline_path}` and commit it)"
            ))
        }
    };
    let base = read_json_melems(&text);
    if base.is_empty() {
        return Ok(format!(
            "bench check: baseline {baseline_path} has no melem_per_s entries — bootstrap pass"
        ));
    }
    if current_melems.is_empty() {
        return Err("bench check: current run produced no throughput entries".into());
    }
    let base_med = median(base);
    let cur_med = median(current_melems.to_vec());
    let floor = base_med * (1.0 - tolerance_pct / 100.0);
    let delta = (cur_med / base_med - 1.0) * 100.0;
    if cur_med < floor {
        Err(format!(
            "bench check FAILED: median {cur_med:.2} Melem/s vs baseline {base_med:.2} \
             ({delta:+.1}%, tolerance -{tolerance_pct:.0}%)"
        ))
    } else {
        Ok(format!(
            "bench check OK: median {cur_med:.2} Melem/s vs baseline {base_med:.2} ({delta:+.1}%)"
        ))
    }
}

/// Benchmark runner with criterion-like defaults.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_samples: 10,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            min_samples: 5,
        }
    }

    /// Time `f` repeatedly; `elements` is the per-iteration throughput
    /// denominator (e.g. number of lines compressed).
    pub fn run<F: FnMut()>(&self, name: &str, elements: Option<u64>, mut f: F) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let _ = warm_iters;

        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let result = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            median: samples[n / 2],
            mean: total / n as u32,
            p10: samples[n / 10],
            p90: samples[(n * 9) / 10],
            elements,
        };
        println!("{}", result.report());
        result
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept for call-site clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
        };
        let mut acc = 0u64;
        let r = b.run("noop", Some(100), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.median <= r.p90);
        assert!(r.p10 <= r.median);
        assert!(r.elems_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn read_json_melems_roundtrips_write_json() {
        let mk = |name: &str, melems: Option<u64>| BenchResult {
            name: name.into(),
            iters: 1,
            median: Duration::from_millis(1),
            mean: Duration::from_millis(1),
            p10: Duration::from_millis(1),
            p90: Duration::from_millis(1),
            elements: melems,
        };
        // 2000 elems / 1ms = 2 Melem/s; 5000 -> 5 Melem/s
        let results = vec![mk("a", Some(2_000)), mk("b", None), mk("c", Some(5_000))];
        let path = std::env::temp_dir().join("cram_bench_rt.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &results).unwrap();
        let melems = read_json_melems(&std::fs::read_to_string(&path).unwrap());
        assert_eq!(melems.len(), 2, "entries without throughput are skipped");
        assert!((melems[0] - 2.0).abs() < 1e-9 && (melems[1] - 5.0).abs() < 1e-9);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn median_is_positional() {
        assert_eq!(median(vec![3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(vec![4.0, 1.0]), 4.0); // upper median
    }

    #[test]
    fn regression_gate_passes_within_tolerance_and_fails_beyond() {
        let path = std::env::temp_dir().join("cram_bench_base.json");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(
            &path,
            "[\n  {\"name\":\"x\",\"median_ns\":1,\"melem_per_s\":10.000}\n]\n",
        )
        .unwrap();
        // -10% with 15% tolerance: pass
        assert!(check_regression(&path, &[9.0], 15.0).is_ok());
        // -20%: fail
        let err = check_regression(&path, &[8.0], 15.0).unwrap_err();
        assert!(err.contains("FAILED"), "{err}");
        // improvement: pass
        assert!(check_regression(&path, &[30.0], 15.0).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn regression_gate_bootstraps_without_baseline() {
        let msg =
            check_regression("/nonexistent/cram/BENCH.json", &[1.0], 15.0).unwrap();
        assert!(msg.contains("bootstrap"), "{msg}");
    }

    #[test]
    fn json_line_shape() {
        let r = BenchResult {
            name: "sim/\"quoted\"".into(),
            iters: 1,
            median: Duration::from_nanos(1500),
            mean: Duration::from_nanos(1500),
            p10: Duration::from_nanos(1000),
            p90: Duration::from_nanos(2000),
            elements: Some(3_000_000),
        };
        let j = r.json();
        assert!(j.contains("\"median_ns\":1500"), "{j}");
        assert!(j.contains("melem_per_s"), "{j}");
        assert!(j.contains("\\\"quoted\\\""), "escaped: {j}");
        let no_thr = BenchResult { elements: None, ..r };
        assert!(!no_thr.json().contains("melem_per_s"));
    }
}
