//! # CRAM — hardware-based memory compression for bandwidth enhancement
//!
//! Full-system reproduction of *CRAM: Efficient Hardware-Based Memory
//! Compression for Bandwidth Enhancement* (Young, Kariyappa, Qureshi, 2018).
//!
//! The crate is the L3 (rust) layer of a three-layer stack:
//!
//! * **L3 (this crate)** — the memory-system simulator and the CRAM memory
//!   controller designs: implicit-metadata markers, the Line Inversion
//!   Table, the Line Location Predictor, Dynamic-CRAM set-sampling, plus
//!   every baseline the paper compares against (uncompressed, ideal
//!   compression, explicit-metadata with a metadata cache, row-buffer
//!   optimized explicit metadata, next-line prefetch).
//! * **L2 (python/compile/model.py)** — the batched compression-analysis
//!   graph, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/fpc_bdi.py)** — the Pallas FPC+BDI
//!   compressibility kernel; [`compress`] is its bit-exact native port used
//!   in the simulator hot loop, and [`runtime`] loads the AOT artifact so
//!   the two are parity-tested end to end.
//!
//! Module map (see DESIGN.md for the per-experiment index):
//!
//! | module | role |
//! |---|---|
//! | [`mem`] | 64-byte cacheline type and address helpers |
//! | [`compress`] | FPC / BDI / hybrid compressors (sizes + real bitstreams) |
//! | [`cram`] | markers, LIT, LLP, group layout, compressed store, metadata, Dynamic-CRAM |
//! | [`cache`] | set-associative cache hierarchy with ganged eviction |
//! | [`dram`] | DDR4 channel/rank/bank timing model with FR-FCFS scheduling |
//! | [`tier`] | tiered memory: CXL link model + near/far routing with hot-page migration; executes the design's policy on the expander via the shared engine (Figures T1/X1) |
//! | [`controller`] | the layered controller: `policy` (the Policy × Placement design space), `engine` (the shared CramEngine), `host` (flat executor); every design is a composition |
//! | [`workloads`] | synthetic SPEC/GAP/MIX workload models (Table II calibrated) + the far-memory-pressure set |
//! | [`sim`] | multi-core trace-driven system simulator |
//! | [`energy`] | DRAM energy / power / EDP model (Fig. 19) |
//! | [`stats`] | counters, bandwidth breakdown, per-tier traffic, weighted speedup |
//! | [`coordinator`] | experiment orchestrator: figure/table harnesses |
//! | [`runtime`] | loader/executor for the AOT compression-analysis artifact |
//! | [`util`] | RNG, geomean, mini bench + property-test harnesses |

pub mod cache;
pub mod compress;
pub mod controller;
pub mod coordinator;
pub mod cram;
pub mod dram;
pub mod energy;
pub mod mem;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod tier;
pub mod util;
pub mod workloads;
