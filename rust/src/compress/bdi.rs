//! BDI — Base-Delta-Immediate compression (Pekhimenko et al.), single
//! arbitrary base (= element 0), matching `python/compile/kernels/ref.py`:
//!
//! | mode        | layout                            | bytes |
//! |-------------|-----------------------------------|-------|
//! | Zeros       | (nothing)                         | 1     |
//! | Rep8        | one 8-byte value                  | 8     |
//! | B8D1/D2/D4  | 8-byte base + 8 deltas of k bytes | 16/24/40 |
//! | B4D1/D2     | 4-byte base + 16 deltas of k      | 20/36 |
//! | B2D1        | 2-byte base + 32 deltas of 1      | 34    |
//!
//! Deltas are wrapping subtractions at the element width and must fit as
//! sign-extended k-byte values.

use crate::mem::CacheLine;

/// BDI encoding mode.  Discriminants are stable: they are stored in the
/// hybrid header byte (see `hybrid.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum BdiMode {
    Zeros = 0,
    Rep8 = 1,
    B8D1 = 2,
    B8D2 = 3,
    B8D4 = 4,
    B4D1 = 5,
    B4D2 = 6,
    B2D1 = 7,
}

impl BdiMode {
    pub const ALL: [BdiMode; 8] = [
        BdiMode::Zeros,
        BdiMode::Rep8,
        BdiMode::B8D1,
        BdiMode::B8D2,
        BdiMode::B8D4,
        BdiMode::B4D1,
        BdiMode::B4D2,
        BdiMode::B2D1,
    ];

    pub fn from_u8(v: u8) -> Option<Self> {
        Self::ALL.get(v as usize).copied()
    }

    /// Encoded payload size in bytes.
    pub fn size_bytes(self) -> u32 {
        match self {
            BdiMode::Zeros => 1,
            BdiMode::Rep8 => 8,
            BdiMode::B8D1 => 16,
            BdiMode::B8D2 => 24,
            BdiMode::B8D4 => 40,
            BdiMode::B4D1 => 20,
            BdiMode::B4D2 => 36,
            BdiMode::B2D1 => 34,
        }
    }
}

#[inline]
fn se_fits64(v: i64, bits: u32) -> bool {
    let sh = 64 - bits;
    (v << sh) >> sh == v
}

/// Does the line fit mode `m`?
pub fn fits(line: &CacheLine, m: BdiMode) -> bool {
    match m {
        BdiMode::Zeros => line.qwords().iter().all(|&q| q == 0),
        BdiMode::Rep8 => {
            let q = line.qwords();
            q.iter().all(|&v| v == q[0])
        }
        BdiMode::B8D1 | BdiMode::B8D2 | BdiMode::B8D4 => {
            let bits = match m {
                BdiMode::B8D1 => 8,
                BdiMode::B8D2 => 16,
                _ => 32,
            };
            let q = line.qwords();
            q.iter()
                .all(|&v| se_fits64(v.wrapping_sub(q[0]) as i64, bits))
        }
        BdiMode::B4D1 | BdiMode::B4D2 => {
            let bits = if m == BdiMode::B4D1 { 8 } else { 16 };
            let w = line.words();
            w.iter()
                .all(|&v| se_fits64(v.wrapping_sub(w[0]) as i32 as i64, bits))
        }
        BdiMode::B2D1 => {
            let h = line.halfwords();
            h.iter()
                .all(|&v| se_fits64(v.wrapping_sub(h[0]) as i16 as i64, 8))
        }
    }
}

/// One pass over the qword view: fit flags for every 8-byte-base mode
/// (Zeros, Rep8, B8D1, B8D2, B8D4).  Equivalent to five [`fits`] calls.
#[inline]
fn qword_flags(line: &CacheLine) -> (bool, bool, bool, bool, bool) {
    let q = line.qwords();
    let base = q[0];
    let (mut zeros, mut rep) = (true, true);
    let (mut d1, mut d2, mut d4) = (true, true, true);
    for &v in &q {
        zeros &= v == 0;
        rep &= v == base;
        let d = v.wrapping_sub(base) as i64;
        d1 &= d as i8 as i64 == d;
        d2 &= d as i16 as i64 == d;
        d4 &= d as i32 as i64 == d;
    }
    (zeros, rep, d1, d2, d4)
}

/// One pass over the word view: fit flags for B4D1 and B4D2.
#[inline]
fn word_flags(line: &CacheLine) -> (bool, bool) {
    let w = line.words();
    let base = w[0];
    let (mut d1, mut d2) = (true, true);
    for &v in w {
        let d = v.wrapping_sub(base) as i32;
        d1 &= d as i8 as i32 == d;
        d2 &= d as i16 as i32 == d;
    }
    (d1, d2)
}

/// Best (smallest) applicable mode, or `None` if nothing fits — the
/// size-only fast path: mode search in ascending-size order over fused
/// single-pass fit analyses, early-exiting at the first fitting mode (the
/// common classes resolve from the qword pass alone; the word and
/// halfword views are only scanned when a cheaper mode missed).
/// Bit-identical to probing [`fits`] per mode in ascending-size order
/// (Zeros 1, Rep8 8, B8D1 16, B4D1 20, B8D2 24, B2D1 34, B4D2 36,
/// B8D4 40) — pinned by `size_only_agrees_with_fits_probe`.
pub fn best_mode(line: &CacheLine) -> Option<BdiMode> {
    let (zeros, rep, d1, d2, d4) = qword_flags(line);
    if zeros {
        return Some(BdiMode::Zeros); // 1 B
    }
    if rep {
        return Some(BdiMode::Rep8); // 8 B
    }
    if d1 {
        return Some(BdiMode::B8D1); // 16 B
    }
    let (w1, w2) = word_flags(line);
    if w1 {
        return Some(BdiMode::B4D1); // 20 B
    }
    if d2 {
        return Some(BdiMode::B8D2); // 24 B
    }
    if fits(line, BdiMode::B2D1) {
        return Some(BdiMode::B2D1); // 34 B
    }
    if w2 {
        return Some(BdiMode::B4D2); // 36 B
    }
    if d4 {
        return Some(BdiMode::B8D4); // 40 B
    }
    None
}

/// BDI compressed size in bytes; 64 if nothing fits.
pub fn size_bytes(line: &CacheLine) -> u32 {
    best_mode(line).map_or(64, |m| m.size_bytes())
}

/// Encode under a specific mode.  Panics if the mode does not fit
/// (callers go through [`best_mode`]).
pub fn encode(line: &CacheLine, m: BdiMode) -> Vec<u8> {
    debug_assert!(fits(line, m));
    let mut out = Vec::with_capacity(m.size_bytes() as usize);
    match m {
        BdiMode::Zeros => out.push(0),
        BdiMode::Rep8 => out.extend_from_slice(&line.qwords()[0].to_le_bytes()),
        BdiMode::B8D1 | BdiMode::B8D2 | BdiMode::B8D4 => {
            let k = match m {
                BdiMode::B8D1 => 1,
                BdiMode::B8D2 => 2,
                _ => 4,
            };
            let q = line.qwords();
            out.extend_from_slice(&q[0].to_le_bytes());
            for &v in &q {
                let d = v.wrapping_sub(q[0]);
                out.extend_from_slice(&d.to_le_bytes()[..k]);
            }
        }
        BdiMode::B4D1 | BdiMode::B4D2 => {
            let k = if m == BdiMode::B4D1 { 1 } else { 2 };
            let w = line.words();
            out.extend_from_slice(&w[0].to_le_bytes());
            for &v in w {
                let d = v.wrapping_sub(w[0]);
                out.extend_from_slice(&d.to_le_bytes()[..k]);
            }
        }
        BdiMode::B2D1 => {
            let h = line.halfwords();
            out.extend_from_slice(&h[0].to_le_bytes());
            for &v in &h {
                out.push(v.wrapping_sub(h[0]) as u8);
            }
        }
    }
    debug_assert_eq!(out.len() as u32, m.size_bytes());
    out
}

#[inline]
fn se8(v: u8) -> i64 {
    v as i8 as i64
}

/// Decode a BDI payload back to the line.
pub fn decode(bytes: &[u8], m: BdiMode) -> CacheLine {
    match m {
        BdiMode::Zeros => CacheLine::zero(),
        BdiMode::Rep8 => {
            let v = u64::from_le_bytes(bytes[..8].try_into().unwrap());
            CacheLine::from_qwords([v; 8])
        }
        BdiMode::B8D1 | BdiMode::B8D2 | BdiMode::B8D4 => {
            let k = match m {
                BdiMode::B8D1 => 1usize,
                BdiMode::B8D2 => 2,
                _ => 4,
            };
            let base = u64::from_le_bytes(bytes[..8].try_into().unwrap());
            let mut q = [0u64; 8];
            for (i, v) in q.iter_mut().enumerate() {
                let off = 8 + i * k;
                let mut d = 0i64;
                for j in (0..k).rev() {
                    d = (d << 8) | bytes[off + j] as i64;
                }
                // sign-extend k bytes
                let sh = 64 - 8 * k as u32;
                d = (d << sh) >> sh;
                *v = base.wrapping_add(d as u64);
            }
            CacheLine::from_qwords(q)
        }
        BdiMode::B4D1 | BdiMode::B4D2 => {
            let k = if m == BdiMode::B4D1 { 1usize } else { 2 };
            let base = u32::from_le_bytes(bytes[..4].try_into().unwrap());
            let mut w = [0u32; 16];
            for (i, v) in w.iter_mut().enumerate() {
                let off = 4 + i * k;
                let mut d = 0i32;
                for j in (0..k).rev() {
                    d = (d << 8) | bytes[off + j] as i32;
                }
                let sh = 32 - 8 * k as u32;
                d = (d << sh) >> sh;
                *v = base.wrapping_add(d as u32);
            }
            CacheLine::from_words(w)
        }
        BdiMode::B2D1 => {
            let base = u16::from_le_bytes(bytes[..2].try_into().unwrap());
            let mut h = [0u16; 32];
            for (i, v) in h.iter_mut().enumerate() {
                *v = base.wrapping_add(se8(bytes[2 + i]) as u16);
            }
            let mut w = [0u32; 16];
            for i in 0..16 {
                w[i] = h[2 * i] as u32 | ((h[2 * i + 1] as u32) << 16);
            }
            CacheLine::from_words(w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    #[test]
    fn zeros_and_rep() {
        assert_eq!(size_bytes(&CacheLine::zero()), 1);
        let rep = CacheLine::from_qwords([0xDEAD_BEEF_0BAD_F00D; 8]);
        assert_eq!(best_mode(&rep), Some(BdiMode::Rep8));
        assert_eq!(decode(&encode(&rep, BdiMode::Rep8), BdiMode::Rep8), rep);
    }

    #[test]
    fn spec_pins() {
        // base8-delta1 line
        let base = 0x1234_5678_9ABC_DE00u64;
        let q: [u64; 8] = core::array::from_fn(|i| base + i as u64);
        let line = CacheLine::from_qwords(q);
        assert_eq!(size_bytes(&line), 16);
        // base8-delta2
        let q2: [u64; 8] = core::array::from_fn(|i| base + 200 * i as u64);
        assert_eq!(size_bytes(&CacheLine::from_qwords(q2)), 24);
        // negative deltas wrap correctly
        let q3: [u64; 8] = core::array::from_fn(|i| base.wrapping_sub(i as u64));
        assert_eq!(size_bytes(&CacheLine::from_qwords(q3)), 16);
    }

    #[test]
    fn delta_wrapping_at_element_width() {
        // u16 elements where delta wraps around 0xFFFF: 0x0001 - 0x0005 =
        // -4 (fits SE8) — the width-limited wrap must be honored.
        let mut h = [0x0005u16; 32];
        h[3] = 0x0001;
        let mut w = [0u32; 16];
        for i in 0..16 {
            w[i] = h[2 * i] as u32 | ((h[2 * i + 1] as u32) << 16);
        }
        let line = CacheLine::from_words(w);
        assert!(fits(&line, BdiMode::B2D1));
    }

    #[test]
    fn incompressible() {
        // pseudo-random line defeats all modes with high probability; use a
        // fixed known-bad pattern.
        let w: [u32; 16] =
            core::array::from_fn(|i| 0x9E37_79B9u32.wrapping_mul(i as u32 + 1) | 0x8000_0001);
        let line = CacheLine::from_words(w);
        assert_eq!(size_bytes(&line), 64);
        assert_eq!(best_mode(&line), None);
    }

    /// Reference oracle for the fused fast path: probe [`fits`] per mode
    /// in ascending-size order (the pre-optimization implementation).
    fn best_mode_by_probe(line: &CacheLine) -> Option<BdiMode> {
        const BY_SIZE: [BdiMode; 8] = [
            BdiMode::Zeros,
            BdiMode::Rep8,
            BdiMode::B8D1,
            BdiMode::B4D1,
            BdiMode::B8D2,
            BdiMode::B2D1,
            BdiMode::B4D2,
            BdiMode::B8D4,
        ];
        BY_SIZE.into_iter().find(|&m| fits(line, m))
    }

    #[test]
    fn size_only_agrees_with_fits_probe() {
        // mode-targeted lines plus raw random ones: the single-pass mode
        // search must pick exactly what the per-mode probe picks
        forall("bdi fast path == probe", 2048, |rng| {
            let line = targeted_line(rng);
            assert_eq!(best_mode(&line), best_mode_by_probe(&line), "{line:?}");
            let raw = CacheLine::from_words(core::array::from_fn(|_| rng.next_u32()));
            assert_eq!(best_mode(&raw), best_mode_by_probe(&raw), "{raw:?}");
        });
    }

    #[test]
    fn size_only_agrees_with_materializing_encoder_all_modes() {
        // For every mode and many lines: the size-only path must report
        // exactly the byte length the materializing encoder produces.
        forall("bdi size == encode len", 1024, |rng| {
            let line = targeted_line(rng);
            if let Some(m) = best_mode(&line) {
                assert_eq!(size_bytes(&line), m.size_bytes(), "mode {m:?}");
                assert_eq!(encode(&line, m).len() as u32, size_bytes(&line));
            } else {
                assert_eq!(size_bytes(&line), 64);
            }
            // and for every mode that fits (not just the best one)
            for m in BdiMode::ALL {
                if fits(&line, m) {
                    assert_eq!(encode(&line, m).len() as u32, m.size_bytes());
                    assert_eq!(decode(&encode(&line, m), m), line);
                }
            }
        });
    }

    /// A line biased toward a randomly chosen BDI mode (same generators as
    /// `roundtrip_every_mode`).
    fn targeted_line(rng: &mut crate::util::rng::Rng) -> CacheLine {
        let m = BdiMode::ALL[rng.below(8) as usize];
        match m {
            BdiMode::Zeros => CacheLine::zero(),
            BdiMode::Rep8 => CacheLine::from_qwords([rng.next_u64(); 8]),
            BdiMode::B8D1 | BdiMode::B8D2 | BdiMode::B8D4 => {
                let bits = match m {
                    BdiMode::B8D1 => 7,
                    BdiMode::B8D2 => 15,
                    _ => 31,
                };
                let base = rng.next_u64();
                CacheLine::from_qwords(core::array::from_fn(|_| {
                    let d =
                        (rng.next_u64() & ((1 << bits) - 1)) as i64 - (1i64 << (bits - 1));
                    base.wrapping_add(d as u64)
                }))
            }
            BdiMode::B4D1 | BdiMode::B4D2 => {
                let bits = if m == BdiMode::B4D1 { 7 } else { 15 };
                let base = rng.next_u32();
                CacheLine::from_words(core::array::from_fn(|_| {
                    let d =
                        (rng.next_u32() & ((1 << bits) - 1)) as i32 - (1i32 << (bits - 1));
                    base.wrapping_add(d as u32)
                }))
            }
            BdiMode::B2D1 => {
                let base = rng.next_u32() as u16;
                let h: [u16; 32] = core::array::from_fn(|_| {
                    let d = (rng.next_u32() & 0x7F) as i32 - 64;
                    base.wrapping_add(d as u16)
                });
                let mut w = [0u32; 16];
                for i in 0..16 {
                    w[i] = h[2 * i] as u32 | ((h[2 * i + 1] as u32) << 16);
                }
                CacheLine::from_words(w)
            }
        }
    }

    #[test]
    fn roundtrip_every_mode() {
        forall("bdi roundtrip", 512, |rng| {
            // construct a line guaranteed to fit a randomly chosen mode
            let m = BdiMode::ALL[rng.below(8) as usize];
            let line = match m {
                BdiMode::Zeros => CacheLine::zero(),
                BdiMode::Rep8 => CacheLine::from_qwords([rng.next_u64(); 8]),
                BdiMode::B8D1 | BdiMode::B8D2 | BdiMode::B8D4 => {
                    let bits = match m {
                        BdiMode::B8D1 => 7,
                        BdiMode::B8D2 => 15,
                        _ => 31,
                    };
                    let base = rng.next_u64();
                    CacheLine::from_qwords(core::array::from_fn(|_| {
                        let d = (rng.next_u64() & ((1 << bits) - 1)) as i64
                            - (1i64 << (bits - 1));
                        base.wrapping_add(d as u64)
                    }))
                }
                BdiMode::B4D1 | BdiMode::B4D2 => {
                    let bits = if m == BdiMode::B4D1 { 7 } else { 15 };
                    let base = rng.next_u32();
                    CacheLine::from_words(core::array::from_fn(|_| {
                        let d = (rng.next_u32() & ((1 << bits) - 1)) as i32
                            - (1i32 << (bits - 1));
                        base.wrapping_add(d as u32)
                    }))
                }
                BdiMode::B2D1 => {
                    let base = rng.next_u32() as u16;
                    let h: [u16; 32] = core::array::from_fn(|_| {
                        let d = (rng.next_u32() & 0x7F) as i32 - 64;
                        base.wrapping_add(d as u16)
                    });
                    let mut w = [0u32; 16];
                    for i in 0..16 {
                        w[i] = h[2 * i] as u32 | ((h[2 * i + 1] as u32) << 16);
                    }
                    CacheLine::from_words(w)
                }
            };
            assert!(fits(&line, m), "mode {m:?} should fit");
            assert_eq!(decode(&encode(&line, m), m), line, "mode {m:?}");
        });
    }
}
