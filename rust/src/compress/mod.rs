//! FPC / BDI / hybrid line compressors.
//!
//! This is the bit-exact native port of the L1 Pallas kernel
//! (`python/compile/kernels/fpc_bdi.py`); the canonical size model is
//! specified in `python/compile/kernels/ref.py` and parity is enforced by
//! `rust/tests/parity_hlo.rs` (native vs the AOT HLO artifact executed via
//! PJRT) plus the pytest suite (kernel vs oracle).
//!
//! Unlike the python side (which only needs sizes), the simulator also
//! needs real *bitstreams*: the compressed-store substrate packs actual
//! bytes into physical lines, and the round-trip `decode(encode(x)) == x`
//! is a property-test target.
//!
//! **Size-only contract** (DESIGN.md §Simulation performance): every
//! compressor exposes a `size_bytes` fast path that runs in a single pass
//! with no heap allocation and no bitstream materialization, and must
//! report exactly the byte length its materializing `encode` would
//! produce.  The timing simulator only ever calls the size path; `encode`
//! / `decode` serve the byte-accurate store and the round-trip tests.
//! Property tests in each module (and `rust/tests/store_invariants.rs`)
//! pin the size/encode agreement for all compressors and all BDI modes.

pub mod bdi;
pub mod bits;
pub mod cpack;
pub mod fpc;
pub mod hybrid;

pub use hybrid::{compressed_size, decode, encode, AlgoSet, CompressedLine};

/// Size in bytes meaning "stored uncompressed" (raw line, no header).
pub const RAW_SIZE: u32 = 64;

/// Pair/quad packing budget: 64 bytes minus the 4-byte marker reserve.
pub const PACK_BUDGET: u32 = 60;
