//! Little bit-granular writer/reader for the FPC bitstream.

/// Append-only bit writer (LSB-first within each byte).
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    bytes: Vec<u8>,
    bit_len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n <= 32).
    pub fn push(&mut self, v: u32, n: usize) {
        debug_assert!(n <= 32);
        for i in 0..n {
            let bit = (v >> i) & 1;
            let byte_idx = self.bit_len / 8;
            if byte_idx == self.bytes.len() {
                self.bytes.push(0);
            }
            self.bytes[byte_idx] |= (bit as u8) << (self.bit_len % 8);
            self.bit_len += 1;
        }
    }

    pub fn bit_len(&self) -> usize {
        self.bit_len
    }

    /// Finished stream, padded with zero bits to a byte boundary.
    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// Sequential bit reader matching [`BitWriter`]'s layout.
#[derive(Debug)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// Read `n` bits (n <= 32) as the low bits of the returned value.
    pub fn pull(&mut self, n: usize) -> u32 {
        debug_assert!(n <= 32);
        let mut v = 0u32;
        for i in 0..n {
            let byte_idx = self.pos / 8;
            let bit = (self.bytes[byte_idx] >> (self.pos % 8)) & 1;
            v |= (bit as u32) << i;
            self.pos += 1;
        }
        v
    }

    pub fn bits_read(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_various_widths() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push(0xFFFF_FFFF, 32);
        w.push(0, 1);
        w.push(0x5A, 8);
        assert_eq!(w.bit_len(), 44);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.pull(3), 0b101);
        assert_eq!(r.pull(32), 0xFFFF_FFFF);
        assert_eq!(r.pull(1), 0);
        assert_eq!(r.pull(8), 0x5A);
    }

    #[test]
    fn roundtrip_random_streams() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let mut widths = Vec::new();
            let mut vals = Vec::new();
            let mut w = BitWriter::new();
            for _ in 0..rng.below(40) + 1 {
                let n = (rng.below(32) + 1) as usize;
                let v = rng.next_u32() & if n == 32 { u32::MAX } else { (1 << n) - 1 };
                widths.push(n);
                vals.push(v);
                w.push(v, n);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (n, v) in widths.iter().zip(&vals) {
                assert_eq!(r.pull(*n), *v);
            }
        }
    }

    #[test]
    fn byte_padding() {
        let mut w = BitWriter::new();
        w.push(1, 1);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 1);
        assert_eq!(bytes[0], 1);
    }
}
