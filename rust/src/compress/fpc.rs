//! FPC — Frequent Pattern Compression (Alameldeen & Wood).
//!
//! Per 32-bit word: a 3-bit class prefix followed by the class's data bits.
//! The size model matches `python/compile/kernels/ref.py` exactly:
//!
//! | class | pattern                          | data bits |
//! |-------|----------------------------------|-----------|
//! | 0     | zero word                        | 0         |
//! | 1     | 4-bit sign-extended              | 4         |
//! | 2     | 8-bit sign-extended              | 8         |
//! | 3     | 16-bit sign-extended             | 16        |
//! | 4     | halfword padded with zero half   | 16        |
//! | 5     | two halfwords, each 8-bit SE     | 16        |
//! | 6     | repeated bytes                   | 8         |
//! | 7     | uncompressed word                | 32        |
//!
//! The *encoder* picks, for every word, the applicable class with the
//! fewest data bits (ties broken by ascending class id), so the encoded
//! length always equals [`size_bytes`].

use crate::compress::bits::{BitReader, BitWriter};
use crate::mem::CacheLine;

/// Smallest possible FPC output: 16 words × 3 prefix bits = 48 bits.
/// The hybrid selector uses this floor to skip the FPC pass entirely when
/// BDI already produced a size FPC cannot beat.
pub const MIN_SIZE: u32 = 6;

/// True if `v` (as i32) fits in `bits` bits sign-extended.
#[inline]
fn se_fits(v: i32, bits: u32) -> bool {
    let sh = 32 - bits;
    (v << sh) >> sh == v
}

/// Data bits for one word under the cheapest applicable class.
#[inline]
pub fn word_bits(w: u32) -> u32 {
    word_class(w).1
}

/// (class id, data bits) for one word — cheapest applicable class, ties by
/// ascending class id.
#[inline]
pub fn word_class(w: u32) -> (u8, u32) {
    let i = w as i32;
    if w == 0 {
        return (0, 0);
    }
    if se_fits(i, 4) {
        return (1, 4);
    }
    if se_fits(i, 8) {
        return (2, 8);
    }
    let b = w & 0xFF;
    if b | (b << 8) | (b << 16) | (b << 24) == w {
        return (6, 8);
    }
    if se_fits(i, 16) {
        return (3, 16);
    }
    if w & 0xFFFF == 0 {
        return (4, 16);
    }
    let lo = ((w & 0xFFFF) as u16) as i16 as i32;
    let hi = ((w >> 16) as u16) as i16 as i32;
    if se_fits(lo, 8) && se_fits(hi, 8) {
        return (5, 16);
    }
    (7, 32)
}

/// FPC compressed size in bytes (ceil of the bit total).
pub fn size_bytes(line: &CacheLine) -> u32 {
    let bits: u32 = line.words().iter().map(|&w| 3 + word_bits(w)).sum();
    (bits + 7) / 8
}

/// Encode a line to its FPC bitstream (padded to a byte boundary).
/// `encode(line).len() == size_bytes(line)` always holds.
pub fn encode(line: &CacheLine) -> Vec<u8> {
    let mut w = BitWriter::new();
    for &word in line.words() {
        let (class, bits) = word_class(word);
        w.push(class as u32, 3);
        match class {
            0 => {}
            1 | 2 | 3 | 7 => w.push(word & mask(bits), bits as usize),
            4 => w.push(word >> 16, 16),
            5 => {
                w.push(word & 0xFF, 8); // low half's 8-bit payload
                w.push((word >> 16) & 0xFF, 8); // high half's payload
            }
            6 => w.push(word & 0xFF, 8),
            _ => unreachable!(),
        }
    }
    w.into_bytes()
}

#[inline]
fn mask(bits: u32) -> u32 {
    if bits >= 32 {
        u32::MAX
    } else {
        (1 << bits) - 1
    }
}

#[inline]
fn sign_extend(v: u32, bits: u32) -> u32 {
    let sh = 32 - bits;
    (((v << sh) as i32) >> sh) as u32
}

/// Decode an FPC bitstream back to the original line.
pub fn decode(bytes: &[u8]) -> CacheLine {
    decode_with_len(bytes).0
}

/// Decode and also report how many bytes of `bytes` the stream occupied
/// (bit total rounded up) — used when payloads are packed back to back.
pub fn decode_with_len(bytes: &[u8]) -> (CacheLine, usize) {
    let mut r = BitReader::new(bytes);
    let mut words = [0u32; 16];
    for w in &mut words {
        let class = r.pull(3) as u8;
        *w = match class {
            0 => 0,
            1 => sign_extend(r.pull(4), 4),
            2 => sign_extend(r.pull(8), 8),
            3 => sign_extend(r.pull(16), 16),
            4 => r.pull(16) << 16,
            5 => {
                let lo = sign_extend(r.pull(8), 8) & 0xFFFF;
                let hi = sign_extend(r.pull(8), 8) & 0xFFFF;
                lo | (hi << 16)
            }
            6 => {
                let b = r.pull(8);
                b | (b << 8) | (b << 16) | (b << 24)
            }
            7 => r.pull(32),
            _ => unreachable!(),
        };
    }
    (CacheLine::from_words(words), r.bits_read().div_ceil(8))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    #[test]
    fn zero_line() {
        let line = CacheLine::zero();
        assert_eq!(size_bytes(&line), 6); // 16 * 3 bits = 48 bits
        assert_eq!(decode(&encode(&line)), line);
    }

    #[test]
    fn word_class_spec_pins() {
        assert_eq!(word_class(0), (0, 0));
        assert_eq!(word_class(7), (1, 4));
        assert_eq!(word_class(0xFFFF_FFF8), (1, 4)); // -8
        assert_eq!(word_class(127), (2, 8));
        assert_eq!(word_class(0xFFFF_FF80), (2, 8)); // -128
        assert_eq!(word_class(0x4141_4141), (6, 8));
        assert_eq!(word_class(32767), (3, 16));
        assert_eq!(word_class(0xABCD_0000), (4, 16));
        assert_eq!(word_class(0x007F_0080), (7, 32)); // low half 128: not SE8
        assert_eq!(word_class(0x007F_007F), (5, 16));
        assert_eq!(word_class(0xFF80_FF80), (5, 16)); // both halves -128
        assert_eq!(word_class(0x1234_5678), (7, 32));
    }

    #[test]
    fn encoded_len_matches_size() {
        forall("fpc len == size", 512, |rng| {
            let words: [u32; 16] = core::array::from_fn(|_| match rng.below(6) {
                0 => 0,
                1 => rng.below(16) as u32,
                2 => rng.next_u32() & 0xFF,
                3 => {
                    let b = rng.next_u32() & 0xFF;
                    b | (b << 8) | (b << 16) | (b << 24)
                }
                4 => rng.next_u32() & 0xFFFF_0000,
                _ => rng.next_u32(),
            });
            let line = CacheLine::from_words(words);
            assert_eq!(encode(&line).len() as u32, size_bytes(&line));
        });
    }

    #[test]
    fn roundtrip_random() {
        forall("fpc roundtrip", 512, |rng| {
            let words: [u32; 16] = core::array::from_fn(|_| match rng.below(7) {
                0 => 0,
                1 => (rng.next_u32() as i32 % 8) as u32,
                2 => rng.next_u32() & 0xFF,
                3 => (rng.next_u32() as i32 >> 16) as u32,
                4 => rng.next_u32() & 0xFFFF_0000,
                5 => {
                    let b = rng.next_u32() & 0xFF;
                    b * 0x0101_0101
                }
                _ => rng.next_u32(),
            });
            let line = CacheLine::from_words(words);
            assert_eq!(decode(&encode(&line)), line, "line {line:?}");
        });
    }
}
