//! C-Pack — dictionary-based cache compression (Chen et al., TVLSI 2010).
//!
//! The paper notes CRAM is orthogonal to the compression algorithm and can
//! be implemented with dictionary-based schemes such as C-Pack (§VIII-A).
//! This module provides a faithful C-Pack so the claim is testable: the
//! `repro ablate compressor` harness compares FPC+BDI against
//! FPC+BDI+C-Pack packing rates end to end.
//!
//! Per 32-bit word, against a 16-entry FIFO dictionary of previously seen
//! words (built per line):
//!
//! | code  | pattern               | bits (code + payload)    |
//! |-------|-----------------------|--------------------------|
//! | 00    | zzzz (zero word)      | 2                        |
//! | 01    | xxxx (uncompressed)   | 2 + 32                   |
//! | 10bbbb| mmmm (full dict match)| 6                        |
//! | 1100  | mmxx (high-half match)| 4 + 4(idx) + 16          |
//! | 1101  | zzzx (low byte only)  | 4 + 8                    |
//! | 1110  | mmmx (3-byte match)   | 4 + 4(idx) + 8           |
//!
//! Sizes are bit-accurate; encode/decode round-trips exactly.  The
//! dictionary starts empty and every non-(zero/low-byte) word is pushed
//! after being coded, exactly as in the C-Pack hardware pipeline.

use crate::compress::bits::{BitReader, BitWriter};
use crate::mem::CacheLine;

const DICT_WORDS: usize = 16;

/// Smallest possible C-Pack output: 16 zero words × 2 bits = 4 bytes.
/// Lets the hybrid selector skip the C-Pack pass when FPC/BDI already
/// produced a size it cannot beat.
pub const MIN_SIZE: u32 = 4;

/// Fixed-capacity FIFO dictionary (the hardware's 16-word structure) —
/// no heap allocation on the size-only path.
struct Dict {
    words: [u32; DICT_WORDS],
    len: usize,
}

impl Dict {
    #[inline]
    fn new() -> Self {
        Self { words: [0; DICT_WORDS], len: 0 }
    }

    #[inline]
    fn as_slice(&self) -> &[u32] {
        &self.words[..self.len]
    }

    /// FIFO push of the last 16 dictionary-eligible words.
    #[inline]
    fn push(&mut self, w: u32) {
        if self.len == DICT_WORDS {
            self.words.copy_within(1.., 0);
            self.words[DICT_WORDS - 1] = w;
        } else {
            self.words[self.len] = w;
            self.len += 1;
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Code {
    Zero,
    Raw,
    Full(u8),
    HighHalf(u8),
    LowByte,
    ThreeByte(u8),
}

fn classify(w: u32, dict: &[u32]) -> Code {
    if w == 0 {
        return Code::Zero;
    }
    if w & 0xFFFF_FF00 == 0 {
        return Code::LowByte;
    }
    // prefer the cheapest dictionary code
    let mut three: Option<u8> = None;
    let mut high: Option<u8> = None;
    for (i, &d) in dict.iter().enumerate() {
        if d == w {
            return Code::Full(i as u8);
        }
        if three.is_none() && d & 0xFFFF_FF00 == w & 0xFFFF_FF00 {
            three = Some(i as u8);
        }
        if high.is_none() && d & 0xFFFF_0000 == w & 0xFFFF_0000 {
            high = Some(i as u8);
        }
    }
    if let Some(i) = three {
        return Code::ThreeByte(i);
    }
    if let Some(i) = high {
        return Code::HighHalf(i);
    }
    Code::Raw
}

fn code_bits(c: Code) -> u32 {
    match c {
        Code::Zero => 2,
        Code::Raw => 2 + 32,
        Code::Full(_) => 2 + 4,
        Code::HighHalf(_) => 4 + 4 + 16,
        Code::LowByte => 4 + 8,
        Code::ThreeByte(_) => 4 + 4 + 8,
    }
}

/// C-Pack compressed size in bytes — the size-only fast path: one pass,
/// fixed-array dictionary, no heap allocation, no bitstream built.
/// `size_bytes(line) == encode(line).len()` always (pinned by tests).
pub fn size_bytes(line: &CacheLine) -> u32 {
    let mut dict = Dict::new();
    let mut bits = 0u32;
    for &w in line.words() {
        let c = classify(w, dict.as_slice());
        bits += code_bits(c);
        if !matches!(c, Code::Zero | Code::LowByte) {
            dict.push(w);
        }
    }
    bits.div_ceil(8)
}

/// Encode a line to its C-Pack bitstream.
pub fn encode(line: &CacheLine) -> Vec<u8> {
    let mut dict = Dict::new();
    let mut out = BitWriter::new();
    for &w in line.words() {
        let c = classify(w, dict.as_slice());
        // prefix code, emitted selector-first (the BitWriter is LSB-first,
        // so each field is pushed separately in decode order)
        match c {
            Code::Zero => out.push(0, 2),
            Code::Raw => {
                out.push(1, 2);
                out.push(w, 32);
            }
            Code::Full(i) => {
                out.push(2, 2);
                out.push(i as u32, 4);
            }
            Code::HighHalf(i) => {
                out.push(3, 2);
                out.push(0, 2);
                out.push(i as u32, 4);
                out.push(w & 0xFFFF, 16);
            }
            Code::LowByte => {
                out.push(3, 2);
                out.push(1, 2);
                out.push(w & 0xFF, 8);
            }
            Code::ThreeByte(i) => {
                out.push(3, 2);
                out.push(2, 2);
                out.push(i as u32, 4);
                out.push(w & 0xFF, 8);
            }
        }
        if !matches!(c, Code::Zero | Code::LowByte) {
            dict.push(w);
        }
    }
    out.into_bytes()
}

/// Decode a C-Pack bitstream back to the line.
pub fn decode(bytes: &[u8]) -> CacheLine {
    decode_with_len(bytes).0
}

/// Decode and report bytes consumed (for back-to-back packed payloads).
pub fn decode_with_len(bytes: &[u8]) -> (CacheLine, usize) {
    let mut dict = Dict::new();
    let mut r = BitReader::new(bytes);
    let mut words = [0u32; 16];
    for w in &mut words {
        let sel = r.pull(2);
        let (value, dict_eligible) = match sel {
            0 => (0, false),
            1 => (r.pull(32), true),
            2 => {
                let i = r.pull(4) as usize;
                (dict.as_slice()[i], true)
            }
            3 => match r.pull(2) {
                0 => {
                    let i = r.pull(4) as usize;
                    let low = r.pull(16);
                    ((dict.as_slice()[i] & 0xFFFF_0000) | low, true)
                }
                1 => (r.pull(8), false),
                2 => {
                    let i = r.pull(4) as usize;
                    let low = r.pull(8);
                    ((dict.as_slice()[i] & 0xFFFF_FF00) | low, true)
                }
                _ => unreachable!("extended code 3 unused"),
            },
            _ => unreachable!(),
        };
        *w = value;
        if dict_eligible {
            dict.push(value);
        }
    }
    (CacheLine::from_words(words), r.bits_read().div_ceil(8))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    #[test]
    fn zero_line_is_tiny() {
        // 16 words x 2 bits = 32 bits = 4 bytes
        assert_eq!(size_bytes(&CacheLine::zero()), 4);
    }

    #[test]
    fn repeated_words_hit_dictionary() {
        let line = CacheLine::from_words([0xDEAD_BEEF; 16]);
        // word 1: raw (34 bits), words 2..16: full match (6 bits each)
        assert_eq!(size_bytes(&line), (34 + 15 * 6 + 7) / 8);
        assert_eq!(decode(&encode(&line)), line);
    }

    #[test]
    fn pointer_arrays_compress_via_three_byte_match() {
        // nearby pointers differ in the low byte: 3-byte dict matches
        let line = CacheLine::from_words(core::array::from_fn(|i| {
            0x7FFF_AB00u32 + (i as u32 * 8)
        }));
        let s = size_bytes(&line);
        assert!(s < 40, "pointer line should compress well: {s}");
        assert_eq!(decode(&encode(&line)), line);
    }

    #[test]
    fn encoded_len_matches_size_fn() {
        forall("cpack len == size", 512, |rng| {
            let line = CacheLine::from_words(core::array::from_fn(|_| match rng.below(5) {
                0 => 0,
                1 => rng.next_u32() & 0xFF,
                2 => 0x1234_5600 | (rng.next_u32() & 0xFF),
                3 => rng.next_u32() & 0xFFFF_0000,
                _ => rng.next_u32(),
            }));
            assert_eq!(encode(&line).len() as u32, size_bytes(&line));
        });
    }

    #[test]
    fn roundtrip_random() {
        forall("cpack roundtrip", 1024, |rng| {
            let line = CacheLine::from_words(core::array::from_fn(|_| match rng.below(6) {
                0 => 0,
                1 => rng.next_u32() & 0xFF,
                2 => 0xAABB_CC00 | (rng.next_u32() & 0xFF),
                3 => 0xAABB_0000 | (rng.next_u32() & 0xFFFF),
                _ => rng.next_u32(),
            }));
            assert_eq!(decode(&encode(&line)), line, "{line:?}");
        });
    }

    #[test]
    fn worst_case_bounded() {
        // all-raw line: 16 * 34 bits = 68 bytes (C-Pack can expand; the
        // hybrid layer falls back to FPC/BDI or raw storage)
        let line = CacheLine::from_words(core::array::from_fn(|i| {
            0x8000_0001u32.wrapping_mul(i as u32 * 2654435761 + 1) | 0x0101_0100
        }));
        assert!(size_bytes(&line) <= 68);
    }
}
