//! Hybrid FPC+BDI — the compression CRAM actually stores (paper §III-A):
//! compress with both, keep the smaller, spend 1 header byte in-line to
//! record which algorithm (and BDI mode) was used.
//!
//! `compressed_size` matches the L1 kernel / jnp oracle exactly:
//! `min(64, 1 + min(fpc, bdi))`, where 64 means "stored raw".

use crate::compress::{bdi, cpack, fpc, RAW_SIZE};
use crate::mem::CacheLine;

/// Header byte values.  0 = FPC; 1..=8 = BDI mode + 1; 9 = C-Pack.
const HDR_FPC: u8 = 0;
const HDR_CPACK: u8 = 9;

/// Which algorithms the hybrid selects among.  The paper evaluates
/// FPC+BDI; §VIII-A notes any algorithm works — [`AlgoSet::FpcBdiCpack`]
/// adds the dictionary-based C-Pack (ablation: `repro ablate compressor`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AlgoSet {
    #[default]
    FpcBdi,
    FpcBdiCpack,
}

/// A compressed line: header + payload.  Guaranteed `< 64` bytes total
/// (otherwise [`encode`] returns `None` and the line is stored raw).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompressedLine {
    pub bytes: Vec<u8>,
}

impl CompressedLine {
    pub fn size(&self) -> u32 {
        self.bytes.len() as u32
    }
}

/// Hybrid compressed size in bytes; [`RAW_SIZE`] (=64) means raw.
/// (Canonical FPC+BDI — bit-identical to the L1 kernel / jnp oracle.)
///
/// Size-only fast path: algorithms run in hit-rate order and later passes
/// are skipped when an earlier result already reaches the later
/// algorithm's output floor.  BDI goes first — its floor (1 B, the Zeros
/// mode that dominates real heaps) is far below FPC's 6 B floor, so a
/// strong BDI hit proves FPC cannot win and the common case runs one
/// algorithm, not two.  The skip is exact, never heuristic: results are
/// bit-identical to evaluating everything and taking the min.
pub fn compressed_size(line: &CacheLine) -> u32 {
    let b = bdi::size_bytes(line);
    if b <= fpc::MIN_SIZE {
        return 1 + b; // <= 7: already compressed, FPC can't beat it
    }
    let f = fpc::size_bytes(line);
    (1 + f.min(b)).min(RAW_SIZE)
}

/// Hybrid size under a configurable algorithm set (same exact-skip
/// ordering: the C-Pack pass only runs when FPC/BDI left room above the
/// C-Pack output floor).
pub fn compressed_size_with(line: &CacheLine, set: AlgoSet) -> u32 {
    match set {
        AlgoSet::FpcBdi => compressed_size(line),
        AlgoSet::FpcBdiCpack => {
            let fb = compressed_size(line);
            if fb <= 1 + cpack::MIN_SIZE {
                return fb; // C-Pack's best possible can't improve on this
            }
            fb.min((1 + cpack::size_bytes(line)).min(RAW_SIZE))
        }
    }
}

/// Compress; `None` if the result would not beat a raw line.
/// When `Some`, `result.size() == compressed_size(line) < 64`.
pub fn encode(line: &CacheLine) -> Option<CompressedLine> {
    encode_with(line, AlgoSet::FpcBdi)
}

/// Compress under a configurable algorithm set.
pub fn encode_with(line: &CacheLine, set: AlgoSet) -> Option<CompressedLine> {
    let f = fpc::size_bytes(line);
    let b = bdi::size_bytes(line);
    if set == AlgoSet::FpcBdiCpack {
        let c = cpack::size_bytes(line);
        if c < f.min(b) && 1 + c < RAW_SIZE {
            let mut bytes = Vec::with_capacity(1 + c as usize);
            bytes.push(HDR_CPACK);
            bytes.extend_from_slice(&cpack::encode(line));
            return Some(CompressedLine { bytes });
        }
    }
    if 1 + f.min(b) >= RAW_SIZE {
        return None;
    }
    let mut bytes;
    if b <= f {
        let mode = bdi::best_mode(line).expect("b < 64 implies a mode fits");
        bytes = Vec::with_capacity(1 + b as usize);
        bytes.push(mode as u8 + 1);
        bytes.extend_from_slice(&bdi::encode(line, mode));
    } else {
        bytes = Vec::with_capacity(1 + f as usize);
        bytes.push(HDR_FPC);
        bytes.extend_from_slice(&fpc::encode(line));
    }
    Some(CompressedLine { bytes })
}

/// Decompress a hybrid stream produced by [`encode`].
pub fn decode(c: &CompressedLine) -> CacheLine {
    decode_prefix(&c.bytes).0
}

/// Decode one hybrid payload from the front of `bytes`, returning the line
/// and the number of bytes consumed (header + payload).  Payloads are
/// byte-aligned, so compressed lines can be packed back to back in a
/// physical line and decoded sequentially — this is the compressed-store
/// read path.
pub fn decode_prefix(bytes: &[u8]) -> (CacheLine, usize) {
    let hdr = bytes[0];
    let payload = &bytes[1..];
    if hdr == HDR_FPC {
        let (line, used) = fpc::decode_with_len(payload);
        (line, 1 + used)
    } else if hdr == HDR_CPACK {
        let (line, used) = cpack::decode_with_len(payload);
        (line, 1 + used)
    } else {
        let mode = bdi::BdiMode::from_u8(hdr - 1).expect("valid BDI mode in header");
        (
            bdi::decode(payload, mode),
            1 + mode.size_bytes() as usize,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::forall;

    /// Value regimes mirroring the python test generators.
    pub(crate) fn random_line(rng: &mut Rng) -> CacheLine {
        match rng.below(8) {
            0 => CacheLine::zero(),
            1 => CacheLine::from_words(core::array::from_fn(|_| rng.below(256) as u32)),
            2 => {
                let b = rng.next_u32() & 0xFF;
                CacheLine::from_words([b * 0x0101_0101; 16])
            }
            3 => {
                let base = rng.next_u64();
                CacheLine::from_qwords(core::array::from_fn(|_| {
                    base.wrapping_add(rng.below(200) as u64).wrapping_sub(100)
                }))
            }
            4 => CacheLine::from_words(core::array::from_fn(|_| rng.next_u32() & 0xFFFF_0000)),
            5 => {
                let base = rng.next_u32();
                CacheLine::from_words(core::array::from_fn(|_| {
                    base.wrapping_add(rng.below(100) as u32)
                }))
            }
            _ => CacheLine::from_words(core::array::from_fn(|_| rng.next_u32())),
        }
    }

    #[test]
    fn size_spec_pins() {
        // mirror python/tests/test_kernel.py hand pins
        assert_eq!(compressed_size(&CacheLine::zero()), 2);
        assert_eq!(compressed_size(&CacheLine::from_words([7; 16])), 9);
        assert_eq!(compressed_size(&CacheLine::from_words([0x4141_4141; 16])), 9);
        let base = 0x1234_5678_9ABC_DE00u64;
        let line = CacheLine::from_qwords(core::array::from_fn(|i| base + i as u64));
        assert_eq!(compressed_size(&line), 17);
    }

    #[test]
    fn encode_size_agrees_with_size_fn() {
        forall("hybrid size agreement", 1024, |rng| {
            let line = random_line(rng);
            let size = compressed_size(&line);
            match encode(&line) {
                Some(c) => assert_eq!(c.size(), size),
                None => assert_eq!(size, RAW_SIZE),
            }
        });
    }

    #[test]
    fn ordered_fast_path_is_exact() {
        // the hit-rate-ordered selector with floor-based skips must equal
        // the exhaustive min over every algorithm, on every line
        forall("hybrid skip exactness", 1024, |rng| {
            let line = random_line(rng);
            let f = fpc::size_bytes(&line);
            let b = bdi::size_bytes(&line);
            let c = cpack::size_bytes(&line);
            assert_eq!(compressed_size(&line), (1 + f.min(b)).min(RAW_SIZE));
            assert_eq!(
                compressed_size_with(&line, AlgoSet::FpcBdiCpack),
                (1 + f.min(b).min(c)).min(RAW_SIZE)
            );
        });
    }

    #[test]
    fn roundtrip() {
        forall("hybrid roundtrip", 1024, |rng| {
            let line = random_line(rng);
            if let Some(c) = encode(&line) {
                assert_eq!(decode(&c), line);
            }
        });
    }

    #[test]
    fn cpack_set_only_improves() {
        forall("cpack never hurts", 512, |rng| {
            let line = random_line(rng);
            let base = compressed_size(&line);
            let ext = compressed_size_with(&line, AlgoSet::FpcBdiCpack);
            assert!(ext <= base, "adding an algorithm can only shrink");
            if let Some(c) = encode_with(&line, AlgoSet::FpcBdiCpack) {
                assert_eq!(c.size(), ext);
                assert_eq!(decode(&c), line);
            } else {
                assert_eq!(ext, RAW_SIZE);
            }
        });
    }

    #[test]
    fn cpack_wins_on_dictionary_friendly_data() {
        // repeated irregular words: FPC can't, BDI can't (u64 pairs
        // unequal), C-Pack dictionary can
        let w: [u32; 16] = core::array::from_fn(|i| {
            [0xDEAD_BEEF, 0xCAFE_F00D, 0x8BAD_F00D][i % 3]
        });
        let line = CacheLine::from_words(w);
        let base = compressed_size(&line);
        let ext = compressed_size_with(&line, AlgoSet::FpcBdiCpack);
        assert!(ext < base, "cpack should win: {ext} vs {base}");
        let c = encode_with(&line, AlgoSet::FpcBdiCpack).unwrap();
        assert_eq!(c.bytes[0], 9, "C-Pack header");
        assert_eq!(decode(&c), line);
    }

    #[test]
    fn incompressible_returns_none() {
        let w: [u32; 16] =
            core::array::from_fn(|i| 0x9E37_79B9u32.wrapping_mul(i as u32 + 1) | 0x8000_0001);
        let line = CacheLine::from_words(w);
        assert!(encode(&line).is_none());
        assert_eq!(compressed_size(&line), RAW_SIZE);
    }
}
