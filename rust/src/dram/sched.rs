//! Per-channel FR-FCFS transaction scheduler.
//!
//! The memory controller's scheduling pipeline, sitting between the
//! request stream and the bank/bus timing of [`super::timing`].  Each
//! channel owns:
//!
//! * **a read path with row-hit-first, oldest-first (FR-FCFS) bus
//!   arbitration** — a request whose bank is still preparing (activate /
//!   precharge) leaves the data bus idle; that idle window is recorded as
//!   a *gap*, and a younger row-hit whose column access completes inside
//!   the gap claims it, finishing before the older row-miss.  Among
//!   row-hits, the older request reaches the bus first.  A packed CRAM
//!   co-fetch is a single transaction: it occupies one read slot and one
//!   burst no matter how many lines it decodes to.
//! * **a write queue with high/low-watermark drain hysteresis** — posted
//!   writes (data, metadata, stale-slot invalidates) queue per channel.
//!   They drain opportunistically in the bank-preparation shadow of reads
//!   (read-over-write priority: an opportunistic drain never delays the
//!   read that opened the window).  When the queue reaches
//!   [`SchedConfig::write_hi`] the channel enters forced-drain mode and
//!   the next read stalls while the queue drains down to
//!   [`SchedConfig::write_lo`] — the hysteresis that turns write bursts
//!   into read tail-latency spikes.  Queue order is FR-FCFS over the
//!   queued writes: row-hits (to the bank's open row or the last-written
//!   row) first, oldest first among equals.
//! * **CRAM-aware issue** — a stale-slot `Invalidate` is a 4-byte marker
//!   write: one bus beat on its own, and *free* when it folds into a
//!   queued write to the same bank+row (it rides the same activation).
//!   Invalidates therefore stop competing with demand reads entirely.
//! * **read-slot occupancy** — at most [`SchedConfig::read_slots`]
//!   transactions in flight per channel; an arrival past that waits for
//!   the oldest completion, which is where queueing delay shows up in the
//!   tail under load.
//!
//! The [`crate::tier`] far-memory expander instantiates the same engine
//! for its device DRAM (every `DramSim` embeds one scheduler per
//! channel), so expander-side queueing is modeled identically.

use crate::dram::timing::{DramConfig, DramStats, ReqKind};

/// Transaction-scheduler knobs (per channel).
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Read-transaction slots in flight (a packed co-fetch is one slot).
    pub read_slots: usize,
    /// Write-queue capacity; posting past it force-issues synchronously.
    pub write_slots: usize,
    /// Queue depth that arms a forced write drain (read-blocking).
    pub write_hi: usize,
    /// A forced drain stops once the queue falls to this depth.
    pub write_lo: usize,
    /// QoS: read slots reserved for priority traffic.  Non-priority
    /// reads are capped at `read_slots - reserved_slots` in-flight
    /// transactions (never below 1); reads issued while the owning
    /// [`DramSim`](crate::dram::DramSim) has priority set see the full
    /// pool.  0 (the default) disables the reservation entirely.
    pub reserved_slots: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            read_slots: 32,
            write_slots: 64,
            write_hi: 48,
            write_lo: 16,
            reserved_slots: 0,
        }
    }
}

impl SchedConfig {
    /// Clamp watermarks into a consistent ordering
    /// (`write_lo <= write_hi <= write_slots`, at least one read slot,
    /// reservation leaves at least one unreserved slot).
    pub fn validated(mut self) -> Self {
        self.read_slots = self.read_slots.max(1);
        self.write_slots = self.write_slots.max(1);
        self.write_hi = self.write_hi.clamp(1, self.write_slots);
        self.write_lo = self.write_lo.min(self.write_hi.saturating_sub(1));
        self.reserved_slots = self.reserved_slots.min(self.read_slots - 1);
        self
    }
}

/// Per-bank state: the open row plus write-batching locality.
#[derive(Clone, Copy, Debug, Default)]
pub struct Bank {
    /// Earliest cycle the bank can start a new column/row command.
    pub ready: u64,
    /// Cycle the current row was activated (for tRAS).
    pub activated: u64,
    /// Row left open by the last read (writes use auto-precharge and do
    /// not disturb it).
    pub open_row: Option<u64>,
    /// Row targeted by the last drained write (write-batch locality).
    pub write_row: Option<u64>,
}

/// One queued posted write (data, metadata, or invalidate).
#[derive(Clone, Copy, Debug)]
pub struct WriteTxn {
    pub bank: usize,
    pub row: u64,
    pub kind: ReqKind,
    /// Arrival cycle — the FCFS key.
    pub enq: u64,
}

/// FR-FCFS arbitration over the write queue: row-hit first (the bank's
/// open row or its last-written row), oldest first among equals.
pub fn frfcfs_pick(q: &[WriteTxn], banks: &[Bank]) -> Option<usize> {
    let hit = |w: &WriteTxn| {
        let b = &banks[w.bank];
        b.write_row == Some(w.row) || b.open_row == Some(w.row)
    };
    let mut best: Option<(bool, u64, usize)> = None;
    for (i, w) in q.iter().enumerate() {
        let h = hit(w);
        let better = match best {
            None => true,
            Some((bh, be, _)) => (h && !bh) || (h == bh && w.enq < be),
        };
        if better {
            best = Some((h, w.enq, i));
        }
    }
    best.map(|(_, _, i)| i)
}

/// One channel's scheduler: banks, bus schedule (tail + claimable gaps),
/// write queue with drain hysteresis, and read-slot occupancy.
#[derive(Clone, Debug)]
pub struct ChannelSched {
    pub banks: Vec<Bank>,
    /// Data-bus tail: occupied until this cycle.
    pub bus_free: u64,
    /// Idle bus intervals behind `bus_free` that row-hit reads may claim.
    gaps: Vec<(u64, u64)>,
    write_q: Vec<WriteTxn>,
    /// Forced-drain hysteresis state (armed at `write_hi`, cleared after
    /// draining to `write_lo`).
    draining: bool,
    /// Completion times of in-flight read transactions.
    inflight: Vec<u64>,
}

impl ChannelSched {
    pub fn new(nbanks: usize) -> Self {
        Self {
            banks: vec![Bank::default(); nbanks],
            bus_free: 0,
            gaps: Vec::new(),
            write_q: Vec::new(),
            draining: false,
            inflight: Vec::new(),
        }
    }

    pub fn write_queue_len(&self) -> usize {
        self.write_q.len()
    }

    /// Bus cost of issuing one queued write.  Full bursts pay a
    /// half-precharge turnaround when they open a new row; an invalidate
    /// is a 4-byte marker — a single beat.
    fn write_cost(&self, cfg: &DramConfig, w: &WriteTxn) -> u64 {
        if w.kind == ReqKind::Invalidate {
            return 1;
        }
        let b = &self.banks[w.bank];
        if b.write_row == Some(w.row) || b.open_row == Some(w.row) {
            cfg.t_burst
        } else {
            cfg.t_burst + cfg.t_rp / 2
        }
    }

    /// Drain queued writes in FR-FCFS order while the queue is longer
    /// than `target_len` and each issue finishes by `bound`.
    /// Opportunistic drains pass the read's CAS completion as `bound`
    /// (writes ride the bank-preparation shadow and never delay the
    /// read); forced drains pass `u64::MAX`.
    fn drain(
        &mut self,
        cfg: &DramConfig,
        stats: &mut DramStats,
        bound: u64,
        target_len: usize,
    ) {
        while self.write_q.len() > target_len {
            let Some(mut i) = frfcfs_pick(&self.write_q, &self.banks) else { break };
            let mut w = self.write_q[i];
            let mut start = self.bus_free.max(w.enq);
            let mut cost = self.write_cost(cfg, &w);
            if start + cost > bound {
                // The FR-FCFS pick overflows the drain window.  Don't
                // head-of-line block on it: a 1-beat invalidate may still
                // fit (invalidates never compete with reads — the module
                // contract).
                let inval = self
                    .write_q
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| v.kind == ReqKind::Invalidate)
                    .min_by_key(|(_, v)| v.enq)
                    .map(|(j, _)| j);
                let Some(j) = inval else { break };
                w = self.write_q[j];
                start = self.bus_free.max(w.enq);
                cost = self.write_cost(cfg, &w);
                if start + cost > bound {
                    break;
                }
                i = j;
            }
            self.write_q.swap_remove(i);
            if w.kind != ReqKind::Invalidate {
                // fold queued stale-slot invalidates into this write: the
                // marker rides the same bank+row activation for free
                let mut j = 0;
                while j < self.write_q.len() {
                    let v = self.write_q[j];
                    if v.kind == ReqKind::Invalidate && v.bank == w.bank && v.row == w.row {
                        self.write_q.swap_remove(j);
                        stats.folded_invalidates += 1;
                    } else {
                        j += 1;
                    }
                }
            }
            let b = &mut self.banks[w.bank];
            if b.write_row == Some(w.row) || b.open_row == Some(w.row) {
                stats.row_hits += 1;
            } else {
                stats.row_misses += 1;
            }
            if w.kind != ReqKind::Invalidate {
                b.write_row = Some(w.row);
            }
            self.bus_free = start + cost;
            stats.busy_cycles += cost;
            stats.drained_writes += 1;
        }
    }

    /// Post a write (data/metadata/invalidate).  Never blocks the caller;
    /// past the hard queue cap the excess force-issues onto the bus tail,
    /// which is where write bandwidth starts costing later reads.
    pub fn post_write(
        &mut self,
        cfg: &DramConfig,
        stats: &mut DramStats,
        bank: usize,
        row: u64,
        kind: ReqKind,
        now: u64,
    ) {
        let sched = cfg.sched.validated();
        self.write_q.push(WriteTxn { bank, row, kind, enq: now });
        if self.write_q.len() >= sched.write_hi {
            self.draining = true;
        }
        if self.write_q.len() > sched.write_slots {
            self.drain(cfg, stats, u64::MAX, sched.write_slots);
        }
    }

    /// Service one read transaction arriving at `now`; returns the cycle
    /// its data burst completes.  `hi_prio` reads see the full read-slot
    /// pool; others are capped below it by
    /// [`SchedConfig::reserved_slots`] (the per-tenant QoS knob).
    #[allow(clippy::too_many_arguments)]
    pub fn read(
        &mut self,
        cfg: &DramConfig,
        stats: &mut DramStats,
        bank_i: usize,
        row: u64,
        now: u64,
        same_row_hint: bool,
        hi_prio: bool,
    ) -> u64 {
        let sched = cfg.sched.validated();

        // Forced write drain (hysteresis): the read stalls behind it.
        if self.draining || self.write_q.len() >= sched.write_hi {
            self.draining = false;
            stats.forced_drains += 1;
            self.drain(cfg, stats, u64::MAX, sched.write_lo);
        }

        // Read-slot occupancy: wait for a transaction slot.  Priority
        // traffic uses the whole pool; everyone else stays below the
        // reservation (validated() keeps at least one slot open).
        let slot_cap = if hi_prio {
            sched.read_slots
        } else {
            sched.read_slots - sched.reserved_slots
        };
        let mut now = now;
        self.inflight.retain(|&d| d > now);
        while self.inflight.len() >= slot_cap {
            let min = *self.inflight.iter().min().expect("non-empty inflight");
            stats.read_slot_wait_cycles += min - now;
            now = min;
            self.inflight.retain(|&d| d > now);
        }
        self.gaps.retain(|g| g.1 >= now + cfg.t_burst);

        // Bank timing: row hit vs conflict, exactly the Table I path.
        let cas_done = {
            let bank = &mut self.banks[bank_i];
            let start = now.max(bank.ready);
            let row_hit = same_row_hint || bank.open_row == Some(row);
            if row_hit {
                stats.row_hits += 1;
                start + cfg.t_cas
            } else {
                stats.row_misses += 1;
                let pre_start = if bank.open_row.is_some() {
                    start.max(bank.activated + cfg.t_ras)
                } else {
                    start
                };
                let act = pre_start + if bank.open_row.is_some() { cfg.t_rp } else { 0 };
                bank.activated = act;
                bank.open_row = Some(row);
                act + cfg.t_rcd + cfg.t_cas
            }
        };

        // Opportunistic write drain into this read's bank-prep shadow —
        // the bus idles until `cas_done`, so queued writes issue without
        // delaying the read (read-over-write priority).
        self.drain(cfg, stats, cas_done, 0);

        // Data burst: earliest free bus slot at/after the column access —
        // a claimable gap (FR-FCFS row-hit bypass) or the bus tail.
        let data_start = self.claim_bus(cfg, stats, cas_done);
        let done = data_start + cfg.t_burst;
        self.banks[bank_i].ready = data_start;
        stats.busy_cycles += cfg.t_burst;
        self.inflight.push(done);
        done
    }

    /// Earliest `t_burst`-wide bus slot at or after `ready`: claim a
    /// recorded idle gap (a younger row-hit overtaking an older
    /// row-miss), else the tail of the bus schedule — recording the new
    /// idle window this request's own bank prep leaves behind.
    fn claim_bus(&mut self, cfg: &DramConfig, stats: &mut DramStats, ready: u64) -> u64 {
        for i in 0..self.gaps.len() {
            let (g0, g1) = self.gaps[i];
            let slot = g0.max(ready);
            if slot + cfg.t_burst <= g1 {
                self.gaps[i] = (g0, slot);
                if slot + cfg.t_burst < g1 {
                    self.gaps.push((slot + cfg.t_burst, g1));
                }
                stats.gap_fills += 1;
                self.prune_gaps(cfg);
                return slot;
            }
        }
        let slot = ready.max(self.bus_free);
        if slot > self.bus_free {
            self.gaps.push((self.bus_free, slot));
        }
        self.bus_free = slot + cfg.t_burst;
        self.prune_gaps(cfg);
        slot
    }

    fn prune_gaps(&mut self, cfg: &DramConfig) {
        self.gaps.retain(|g| g.1 >= g.0 + cfg.t_burst);
        if self.gaps.len() > 8 {
            // keep the latest few: older gaps expire first anyway
            self.gaps.sort_by_key(|g| g.0);
            let n = self.gaps.len();
            self.gaps.drain(0..n - 8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::timing::{DramConfig, DramSim};

    fn cfg1() -> DramConfig {
        DramConfig::default().with_channels(1)
    }

    fn wt(bank: usize, row: u64, enq: u64) -> WriteTxn {
        WriteTxn { bank, row, kind: ReqKind::Write, enq }
    }

    #[test]
    fn frfcfs_row_hit_beats_older_row_miss() {
        let mut banks = vec![Bank::default(); 4];
        banks[1].open_row = Some(7);
        // older miss (enq 0) vs younger hit (enq 5): the hit wins
        let q = vec![wt(0, 3, 0), wt(1, 7, 5)];
        assert_eq!(frfcfs_pick(&q, &banks), Some(1));
        // write-batch locality counts as a hit too
        banks[2].write_row = Some(9);
        let q = vec![wt(0, 3, 0), wt(2, 9, 8)];
        assert_eq!(frfcfs_pick(&q, &banks), Some(1));
    }

    #[test]
    fn frfcfs_oldest_wins_among_hits_and_among_misses() {
        let mut banks = vec![Bank::default(); 4];
        banks[0].open_row = Some(1);
        banks[1].open_row = Some(2);
        let hits = vec![wt(1, 2, 9), wt(0, 1, 4)];
        assert_eq!(frfcfs_pick(&hits, &banks), Some(1), "older hit first");
        let misses = vec![wt(2, 5, 9), wt(3, 6, 4)];
        assert_eq!(frfcfs_pick(&misses, &banks), Some(1), "older miss first");
        assert_eq!(frfcfs_pick(&[], &banks), None);
    }

    #[test]
    fn drain_issues_row_hit_before_older_miss() {
        let cfg = cfg1();
        let mut stats = DramStats::default();
        let mut ch = ChannelSched::new(4);
        ch.banks[1].open_row = Some(7);
        ch.write_q.push(wt(0, 3, 0)); // older, row miss
        ch.write_q.push(wt(1, 7, 2)); // younger, row hit
        ch.drain(&cfg, &mut stats, u64::MAX, 1);
        // one write issued: it must have been the row hit
        assert_eq!(ch.write_q.len(), 1);
        assert_eq!(ch.write_q[0].bank, 0, "the miss is still queued");
        assert_eq!(stats.row_hits, 1);
        assert_eq!(ch.bus_free, 2 + cfg.t_burst, "hit pays a bare burst");
    }

    #[test]
    fn read_row_hit_overtakes_older_miss_on_the_bus() {
        // derived sequence: a conflict read leaves the bus idle during its
        // precharge+activate; a younger row hit claims that gap and
        // finishes first (FR-FCFS on the read path).
        let mut d = DramSim::new(cfg1());
        let t0 = d.access(0, ReqKind::Read, 0, false); // open bank0 row0
        assert_eq!(t0, 22);
        let t1 = d.access(128, ReqKind::Read, t0, false); // open bank1 row0
        assert_eq!(t1, 44);
        // older request: bank0 row conflict, long bank prep
        let done_miss = d.access(4096, ReqKind::Read, t1, false);
        // younger request, 1 cycle later: bank1 row hit
        let done_hit = d.access(130, ReqKind::Read, t1 + 1, false);
        assert!(
            done_hit < done_miss,
            "row hit ({done_hit}) must overtake the older miss ({done_miss})"
        );
        assert!(d.stats.gap_fills >= 1);
        // oldest-first among hits: a second hit lands after the first
        let done_hit2 = d.access(132, ReqKind::Read, t1 + 2, false);
        assert!(done_hit2 > done_hit);
    }

    #[test]
    fn write_drain_hysteresis_starts_at_hi_stops_at_lo() {
        let cfg = cfg1();
        let sched = cfg.sched.validated();
        let mut d = DramSim::new(cfg);
        // saturate the bus so opportunistic drains cannot run
        for i in 0..64u64 {
            d.access(i * 128, ReqKind::Read, 0, false);
        }
        // one below the high watermark: no forced drain on the next read
        for i in 0..(sched.write_hi - 1) as u64 {
            d.access(i, ReqKind::Write, 0, false);
        }
        // probe with a row hit (bank 28, row 1 — opened by the read
        // sweep): its CAS completes before the bus tail, so not even an
        // opportunistic drain window opens
        d.access(7680, ReqKind::Read, 0, false);
        assert_eq!(d.stats.forced_drains, 0, "below hi: no forced drain");
        assert_eq!(d.write_queue_len(0), sched.write_hi - 1);
        // one more write arms the hysteresis; the next read drains to lo
        d.access(500, ReqKind::Write, 0, false);
        d.access(7808, ReqKind::Read, 0, false);
        assert_eq!(d.stats.forced_drains, 1);
        assert_eq!(d.write_queue_len(0), sched.write_lo, "drain stops at lo");
    }

    #[test]
    fn invalidates_fold_into_samerow_write_drains() {
        let mut d = DramSim::new(cfg1());
        // a dirty write and two stale-slot invalidates in the same row
        d.access(8, ReqKind::Write, 0, false);
        d.access(9, ReqKind::Invalidate, 0, false);
        d.access(10, ReqKind::Invalidate, 0, false);
        assert_eq!(d.write_queue_len(0), 3);
        // an idle-bus read opportunistically drains all three: the
        // invalidates ride the write's activation for free
        d.access(100_000, ReqKind::Read, 10_000, false);
        assert_eq!(d.write_queue_len(0), 0);
        assert_eq!(d.stats.folded_invalidates, 2);
        assert_eq!(d.stats.invalidates, 2, "kind counters still tally them");
    }

    #[test]
    fn narrow_drain_window_still_issues_invalidates() {
        let cfg = cfg1();
        let mut stats = DramStats::default();
        let mut ch = ChannelSched::new(4);
        ch.write_q.push(wt(0, 3, 0)); // row-miss data write: cost 8
        ch.write_q.push(WriteTxn { bank: 1, row: 9, kind: ReqKind::Invalidate, enq: 0 });
        // a 2-cycle window: the data write cannot fit, the marker can —
        // no head-of-line blocking on the expensive FR-FCFS pick
        ch.drain(&cfg, &mut stats, 2, 0);
        assert_eq!(ch.write_q.len(), 1);
        assert_eq!(ch.write_q[0].kind, ReqKind::Write, "data write still queued");
        assert_eq!(ch.bus_free, 1);
        assert_eq!(stats.drained_writes, 1);
    }

    #[test]
    fn lone_invalidate_costs_one_beat() {
        let cfg = cfg1();
        let mut stats = DramStats::default();
        let mut ch = ChannelSched::new(4);
        ch.write_q.push(WriteTxn { bank: 0, row: 0, kind: ReqKind::Invalidate, enq: 0 });
        ch.drain(&cfg, &mut stats, u64::MAX, 0);
        assert_eq!(ch.bus_free, 1, "marker write is a single bus beat");
        assert_eq!(stats.drained_writes, 1);
    }

    #[test]
    fn read_slots_cap_delays_excess_transactions() {
        let mut cfg = cfg1();
        cfg.sched.read_slots = 2;
        let mut d = DramSim::new(cfg);
        d.access(0, ReqKind::Read, 0, false);
        d.access(128, ReqKind::Read, 0, false);
        assert_eq!(d.stats.read_slot_wait_cycles, 0);
        // third concurrent read must wait for a slot
        d.access(256, ReqKind::Read, 0, false);
        assert!(d.stats.read_slot_wait_cycles > 0, "slot wait accounted");
    }

    #[test]
    fn sched_config_validation_orders_watermarks() {
        let s = SchedConfig {
            read_slots: 0,
            write_slots: 8,
            write_hi: 99,
            write_lo: 99,
            reserved_slots: 99,
        }
        .validated();
        assert_eq!(s.read_slots, 1);
        assert_eq!(s.write_hi, 8);
        assert!(s.write_lo < s.write_hi);
        assert_eq!(s.reserved_slots, 0, "reservation leaves >= 1 open slot");
        let s = SchedConfig { read_slots: 4, reserved_slots: 9, ..Default::default() }.validated();
        assert_eq!(s.reserved_slots, 3);
    }

    #[test]
    fn reserved_slots_cap_non_priority_reads_only() {
        let mut cfg = cfg1();
        cfg.sched.read_slots = 2;
        cfg.sched.reserved_slots = 1;
        // non-priority traffic: capped at a single in-flight read
        let mut d = DramSim::new(cfg);
        d.access(0, ReqKind::Read, 0, false);
        d.access(128, ReqKind::Read, 0, false);
        assert!(
            d.stats.read_slot_wait_cycles > 0,
            "second concurrent read must wait behind the reservation"
        );
        // priority traffic: the same pair fits the full 2-slot pool
        let mut d = DramSim::new(cfg);
        d.set_priority(true);
        d.access(0, ReqKind::Read, 0, false);
        d.access(128, ReqKind::Read, 0, false);
        assert_eq!(d.stats.read_slot_wait_cycles, 0, "hi-prio sees the full pool");
    }
}
