//! The bank/bus occupancy engine behind the per-channel FR-FCFS
//! transaction scheduler ([`super::sched`]).

use crate::dram::sched::{ChannelSched, SchedConfig};

/// Request type, for stats and scheduling priority.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    /// Demand read — requester stalls until done.
    Read,
    /// Posted write — queues in the channel's write queue.
    Write,
    /// Metadata read (explicit-metadata designs).
    MetaRead,
    /// Metadata write-back from the metadata cache.
    MetaWrite,
    /// Invalid-line-marker write (CRAM stale-slot invalidation).
    Invalidate,
}

/// DDR4 geometry + timing (Table I).  All times in DRAM bus cycles
/// (800 MHz ⇒ 1.25 ns per cycle).
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    pub channels: usize,
    pub ranks: usize,
    /// Banks per rank.
    pub banks: usize,
    /// Lines (64B) per row buffer (8KB rows ⇒ 128 lines).
    pub row_lines: u64,
    /// Column access latency (tCAS = 11 ns ⇒ 9 cycles).
    pub t_cas: u64,
    /// Activate latency (tRCD = 11 ns ⇒ 9 cycles).
    pub t_rcd: u64,
    /// Precharge latency (tRP = 11 ns ⇒ 9 cycles).
    pub t_rp: u64,
    /// Minimum row-open time (tRAS = 39 ns ⇒ 31 cycles).
    pub t_ras: u64,
    /// Data burst occupancy on the channel bus (64B over a 64-bit DDR bus
    /// = 8 beats = 4 bus cycles).
    pub t_burst: u64,
    /// Per-channel transaction-scheduler knobs (queues + watermarks).
    pub sched: SchedConfig,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            channels: 2,
            ranks: 2,
            banks: 16,
            row_lines: 128,
            t_cas: 9,
            t_rcd: 9,
            t_rp: 9,
            t_ras: 31,
            t_burst: 4,
            sched: SchedConfig::default(),
        }
    }
}

impl DramConfig {
    pub fn with_channels(mut self, ch: usize) -> Self {
        self.channels = ch;
        self
    }

    pub fn with_sched(mut self, sched: SchedConfig) -> Self {
        self.sched = sched;
        self
    }

    /// Peak bandwidth in bytes per cycle across all channels.
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.channels as f64 * 64.0 / self.t_burst as f64
    }
}

/// Per-kind access counters (the bandwidth breakdown of Figs. 8 & 15)
/// plus the scheduler's queue/drain diagnostics.
#[derive(Clone, Copy, Debug, Default)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub meta_reads: u64,
    pub meta_writes: u64,
    pub invalidates: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub busy_cycles: u64,
    /// Writes issued from the per-channel write queues.
    pub drained_writes: u64,
    /// Stale-slot invalidates folded into a same-row write drain (free).
    pub folded_invalidates: u64,
    /// Forced (read-blocking) write drains triggered by the high
    /// watermark.
    pub forced_drains: u64,
    /// Reads whose data burst claimed an idle bus gap ahead of an older
    /// request (FR-FCFS row-hit bypass).
    pub gap_fills: u64,
    /// Cycles reads waited for a free read-transaction slot.
    pub read_slot_wait_cycles: u64,
}

impl DramStats {
    pub fn total_accesses(&self) -> u64 {
        self.reads + self.writes + self.meta_reads + self.meta_writes + self.invalidates
    }

    pub fn row_hit_rate(&self) -> f64 {
        let t = self.row_hits + self.row_misses;
        if t == 0 {
            0.0
        } else {
            self.row_hits as f64 / t as f64
        }
    }
}

/// The memory system: per-channel FR-FCFS transaction schedulers over
/// shared bank state (see `sched.rs` and DESIGN.md §Scheduler).
pub struct DramSim {
    cfg: DramConfig,
    channels: Vec<ChannelSched>,
    /// Current requester priority: `true` while the access stream being
    /// issued belongs to the QoS-protected tenant (set per-request by
    /// the memory controller; see [`SchedConfig::reserved_slots`]).
    hi_prio: bool,
    pub stats: DramStats,
}

impl DramSim {
    pub fn new(cfg: DramConfig) -> Self {
        Self {
            channels: (0..cfg.channels)
                .map(|_| ChannelSched::new(cfg.ranks * cfg.banks))
                .collect(),
            cfg,
            hi_prio: false,
            stats: DramStats::default(),
        }
    }

    pub fn config(&self) -> &DramConfig {
        &self.cfg
    }

    /// Mark subsequent reads as priority (QoS) traffic — they see the
    /// full read-slot pool instead of the unreserved remainder.  The
    /// controller sets this per request from the issuing core's tenant;
    /// it stays `false` in single-tenant runs.
    pub fn set_priority(&mut self, hi_prio: bool) {
        self.hi_prio = hi_prio;
    }

    /// Pending writes queued on one channel (diagnostics / tests).
    pub fn write_queue_len(&self, ch: usize) -> usize {
        self.channels[ch].write_queue_len()
    }

    /// Address decomposition: line-interleaved channels, then banks, with
    /// `row_lines` consecutive lines per row.
    #[inline]
    fn map(&self, line_addr: u64) -> (usize, usize, u64) {
        let ch = (line_addr % self.cfg.channels as u64) as usize;
        let after_ch = line_addr / self.cfg.channels as u64;
        let nbanks = (self.cfg.ranks * self.cfg.banks) as u64;
        let bank = (after_ch / self.cfg.row_lines % nbanks) as usize;
        let row = after_ch / self.cfg.row_lines / nbanks;
        (ch, bank, row)
    }

    /// Service one 64-byte access arriving at `now`.  Returns the
    /// completion cycle (data fully transferred).  `same_row_hint` forces
    /// row-hit latency (the Fig. 20 row-co-located-metadata variant).
    ///
    /// Reads (and metadata reads) are latency-critical: they go through
    /// the read path of the channel scheduler (slot occupancy, forced
    /// write drains, bank timing, FR-FCFS bus arbitration).
    /// Writes/invalidates are *posted*: they join the channel's write
    /// queue and drain in the bank-prep shadow of later reads, stalling
    /// reads only through the high-watermark drain hysteresis.
    pub fn access(&mut self, line_addr: u64, kind: ReqKind, now: u64, same_row_hint: bool) -> u64 {
        let cfg = self.cfg;
        let (ch_i, bank_i, row) = self.map(line_addr);
        match kind {
            ReqKind::Write | ReqKind::MetaWrite | ReqKind::Invalidate => {
                match kind {
                    ReqKind::Write => self.stats.writes += 1,
                    ReqKind::MetaWrite => self.stats.meta_writes += 1,
                    _ => self.stats.invalidates += 1,
                }
                // busy_cycles is charged at *issue* time (in the drain),
                // with the actual bus cost — folded invalidates are free,
                // row-miss writes pay their turnaround
                self.channels[ch_i].post_write(&cfg, &mut self.stats, bank_i, row, kind, now);
                now // posted
            }
            ReqKind::Read | ReqKind::MetaRead => {
                match kind {
                    ReqKind::Read => self.stats.reads += 1,
                    _ => self.stats.meta_reads += 1,
                }
                self.channels[ch_i].read(
                    &cfg,
                    &mut self.stats,
                    bank_i,
                    row,
                    now,
                    same_row_hint,
                    self.hi_prio,
                )
            }
        }
    }

    /// Aggregate achieved bandwidth in bytes/cycle over `elapsed` cycles.
    pub fn achieved_bytes_per_cycle(&self, elapsed: u64) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        self.stats.total_accesses() as f64 * 64.0 / elapsed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_hit_faster_than_miss() {
        let mut d = DramSim::new(DramConfig::default());
        let t1 = d.access(0, ReqKind::Read, 0, false); // cold miss
        let t2_start = t1;
        let t2 = d.access(2, ReqKind::Read, t2_start, false); // same row (ch0: lines 0,2,4..)
        let hit_lat = t2 - t2_start;
        assert!(d.stats.row_hits >= 1);
        // a row hit costs tCAS + burst = 13
        assert_eq!(hit_lat, 9 + 4);
        // cold activate costs tRCD + tCAS + burst = 22
        assert_eq!(t1, 9 + 9 + 4);
    }

    #[test]
    fn row_conflict_costs_precharge() {
        let cfg = DramConfig::default();
        let mut d = DramSim::new(cfg);
        let rows_stride = cfg.channels as u64 * cfg.row_lines * (cfg.ranks * cfg.banks) as u64;
        let t1 = d.access(0, ReqKind::Read, 0, false);
        // same channel & bank, different row
        let t2 = d.access(rows_stride, ReqKind::Read, t1, false);
        // must include tRAS wait (activated at 9, +31), tRP, tRCD, tCAS
        assert!(t2 - t1 > 9 + 9 + 4, "conflict latency {}", t2 - t1);
        assert_eq!(d.stats.row_misses, 2);
    }

    #[test]
    fn channel_interleave() {
        let d = DramSim::new(DramConfig::default());
        assert_eq!(d.map(0).0, 0);
        assert_eq!(d.map(1).0, 1);
        assert_eq!(d.map(2).0, 0);
    }

    #[test]
    fn bus_serializes_same_channel() {
        let mut d = DramSim::new(DramConfig::default());
        // two requests to different banks, same channel, same instant:
        let bank_stride = DramConfig::default().channels as u64 * DramConfig::default().row_lines;
        let t1 = d.access(0, ReqKind::Read, 0, false);
        let t2 = d.access(bank_stride, ReqKind::Read, 0, false);
        // bank latencies overlap but bursts serialize: t2 >= t1 + burst
        assert!(t2 >= t1 + 4, "t1={t1} t2={t2}");
    }

    #[test]
    fn different_channels_fully_parallel() {
        let mut d = DramSim::new(DramConfig::default());
        let t1 = d.access(0, ReqKind::Read, 0, false);
        let t2 = d.access(1, ReqKind::Read, 0, false);
        assert_eq!(t1, t2, "distinct channels don't interfere");
    }

    #[test]
    fn same_row_hint_forces_hit() {
        let mut d = DramSim::new(DramConfig::default());
        let t = d.access(12345 * 2, ReqKind::MetaRead, 0, true);
        assert_eq!(t, 9 + 4);
        assert_eq!(d.stats.row_hits, 1);
        assert_eq!(d.stats.meta_reads, 1);
    }

    #[test]
    fn stats_accumulate_by_kind() {
        let mut d = DramSim::new(DramConfig::default());
        d.access(0, ReqKind::Read, 0, false);
        d.access(2, ReqKind::Write, 0, false);
        d.access(4, ReqKind::Invalidate, 0, false);
        d.access(6, ReqKind::MetaRead, 0, false);
        d.access(8, ReqKind::MetaWrite, 0, false);
        assert_eq!(d.stats.reads, 1);
        assert_eq!(d.stats.writes, 1);
        assert_eq!(d.stats.invalidates, 1);
        assert_eq!(d.stats.meta_reads, 1);
        assert_eq!(d.stats.meta_writes, 1);
        assert_eq!(d.stats.total_accesses(), 5);
    }

    #[test]
    fn posted_writes_do_not_block_reads_when_sparse() {
        let mut d = DramSim::new(DramConfig::default());
        // a handful of posted writes...
        for i in 0..8u64 {
            let t = d.access(i * 2, ReqKind::Write, 0, false);
            assert_eq!(t, 0, "writes are posted");
        }
        // ...must not delay an isolated read that arrives much later
        let t = d.access(100, ReqKind::Read, 1000, false);
        assert_eq!(t - 1000, 9 + 9 + 4, "read pays only its own latency");
        assert_eq!(d.write_queue_len(0), 0, "writes drained in the shadow");
    }

    #[test]
    fn saturated_write_queue_stalls_reads() {
        let mut d = DramSim::new(DramConfig::default().with_channels(1));
        // flood the write queue far past its capacity at t=0
        for i in 0..300u64 {
            d.access(i, ReqKind::Write, 0, false);
        }
        // a read at t=0 must absorb the forced drain of the excess
        let t = d.access(1000, ReqKind::Read, 0, false);
        assert!(
            t > 300 * 4 / 2,
            "forced write drain must delay the read: done at {t}"
        );
        assert!(d.stats.forced_drains >= 1);
    }

    #[test]
    fn write_bandwidth_costs_under_saturation() {
        // On a *bandwidth-bound* stream (open-loop arrivals) writes must
        // stretch completion; on a latency-bound dependent chain they
        // drain into idle gaps for free — both are the intended model.
        let run = |with_writes: bool| {
            let mut d = DramSim::new(DramConfig::default().with_channels(1));
            let mut done = 0u64;
            // stride across banks so the read stream is BUS-bound (banks
            // overlap their activates), arrivals outpace the burst rate
            for i in 0..2000u64 {
                let arrive = i;
                if with_writes {
                    d.access(i + 5_000_000, ReqKind::Write, arrive, false);
                }
                done = done.max(d.access(i * 256, ReqKind::Read, arrive, false));
            }
            done
        };
        let reads_only = run(false);
        let with_writes = run(true);
        assert!(
            with_writes as f64 > reads_only as f64 * 1.3,
            "writes must cost bandwidth when saturated: {reads_only} vs {with_writes}"
        );

        // latency-bound dependent chain: writes ride the idle gaps
        let chain = |with_writes: bool| {
            let mut d = DramSim::new(DramConfig::default().with_channels(1));
            let mut t = 0;
            for i in 0..500u64 {
                if with_writes {
                    d.access(i + 500_000, ReqKind::Write, t, false);
                }
                t = d.access(i * 2, ReqKind::Read, t, false);
            }
            t
        };
        let a = chain(false);
        let b = chain(true);
        assert!(
            (b as f64) < a as f64 * 1.1,
            "sparse writes hide in idle gaps: {a} vs {b}"
        );
    }

    #[test]
    fn more_channels_more_bandwidth() {
        // stream 1000 sequential lines through 1 vs 4 channels
        // open-loop: all requests arrive at cycle 0 and queue up
        let run = |nch: usize| {
            let mut d = DramSim::new(DramConfig::default().with_channels(nch));
            let mut done = 0;
            for i in 0..1000u64 {
                done = done.max(d.access(i, ReqKind::Read, 0, false));
            }
            done
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(
            (t1 as f64) > 3.0 * t4 as f64,
            "4-channel should be ~4x faster: {t1} vs {t4}"
        );
    }
}
