//! DDR4 main-memory timing model (the USIMM substitute).
//!
//! Bank-state + bus-occupancy model at DRAM-bus-cycle granularity
//! (800 MHz, 1.25 ns/cycle; Table I timings).  Captures the three effects
//! CRAM's evaluation hinges on:
//!
//! * **bandwidth contention** — every access (data, metadata, second
//!   access, compressed writeback, invalidate) occupies a channel's data
//!   bus for a burst; extra accesses queue behind demand traffic;
//! * **row-buffer locality** — row hits cost tCAS, row conflicts
//!   tRP+tRCD+tCAS (plus tRAS-limited re-activation);
//! * **bank-level parallelism** — requests to different banks overlap.
//!
//! Reads are serviced with the requester waiting; writes are posted (the
//! write queue drains opportunistically and charges bandwidth without
//! stalling the core — §VI "extra writebacks" are pure bandwidth cost).

pub mod timing;

pub use timing::{DramConfig, DramSim, ReqKind};
