//! DDR4 main-memory model (the USIMM substitute): per-channel FR-FCFS
//! transaction scheduling over bank-state + bus-occupancy timing.
//!
//! Modeled at DRAM-bus-cycle granularity (800 MHz, 1.25 ns/cycle;
//! Table I timings).  Captures the effects CRAM's evaluation hinges on:
//!
//! * **bandwidth contention** — every access (data, metadata, second
//!   access, compressed writeback, invalidate) occupies a channel's data
//!   bus for a burst; extra accesses queue behind demand traffic;
//! * **row-buffer locality** — row hits cost tCAS, row conflicts
//!   tRP+tRCD+tCAS (plus tRAS-limited re-activation);
//! * **bank-level parallelism** — requests to different banks overlap;
//! * **transaction scheduling** ([`sched`]) — per-channel read/write
//!   queues with FR-FCFS arbitration (row-hit-first, oldest-first),
//!   read-over-write priority with high/low-watermark write-drain
//!   hysteresis, read-slot occupancy, and CRAM-aware issue (stale-slot
//!   invalidates fold into write drains; a packed co-fetch is one
//!   transaction).  This is what makes *tail latency* — not just
//!   bandwidth — observable per design (Figure Q1).
//!
//! Reads are serviced with the requester waiting; writes are posted (the
//! write queue drains in the bank-prep shadow of reads and charges
//! bandwidth without stalling the core, until the drain hysteresis says
//! otherwise — §VI "extra writebacks" are bandwidth *and* tail cost).

pub mod sched;
pub mod timing;

pub use sched::SchedConfig;
pub use timing::{DramConfig, DramSim, ReqKind};
