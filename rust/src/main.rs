//! `repro` — the CRAM reproduction CLI (L3 leader binary).
//!
//! ```text
//! repro reproduce-all [--out DIR] [--insts N] [--threads N] [--seed S]
//! repro figure <3|4|7|8|12|14|15|16|18|19|20|t1|q1|c1|x1|l1|m1|r1|p1> [--insts N]
//! repro figure <q1|c1|l1|m1|r1|p1> --format table|csv|json
//! repro figure x1 --far-ratio R1,R2,... [--format table|csv|json]
//! repro table <2|3|4|5> [--insts N]
//! repro sim --workload W --design D [--insts N] [--channels C]
//!           [--far-ratio R] [--link-codec raw|compressed] [--trace FILE]
//!           [--llc-compressed] [--fault-ber B] [--fault-watchdog on|off]
//! repro sim --tenants W1[:CORES][:qos][:bias=N],W2,... [--design D] [--qos-slots N]
//! repro sweep [--far-ratio R1,R2,...] [--llc-compressed] [--extended]
//!             [--format table|csv|json] [--cache PATH] [--no-cache] [--refresh]
//! repro analyze [--artifact PATH] [--workload W] [--groups N]
//! repro list
//! ```
//!
//! `sweep` drives the whole design space — all 32 compositions x every
//! workload profile set — through the sharded experiment engine in one
//! command, with per-phase wall-time/jobs-per-second telemetry on
//! stderr.  `reproduce-all`, `figure`, `table` and `sweep` all reuse
//! completed runs from the persistent `CRAM_RESULTS.json` cache (keyed
//! by a build+plan fingerprint, so a stale cache self-invalidates);
//! `--no-cache` skips it, `--refresh` ignores what is on disk but
//! re-records, and `--cache PATH` relocates it.
//!
//! `figure t1` is the tiered-memory exhibit: uncompressed vs
//! CRAM-compressed CXL far tier over the far-memory-pressure workloads.
//! The `tiered-uncomp` / `tiered-cram` designs take `--far-ratio R`
//! (fraction of capacity behind the link, default 0.5).
//!
//! `figure q1` is the tail-latency exhibit: p50/p95/p99 demand-read
//! latency through the per-channel FR-FCFS transaction scheduler, for
//! the uncompressed baseline vs explicit-metadata CRAM vs Dynamic-CRAM,
//! over the 27-workload suite plus the latency-sensitive `lat_*` set.
//!
//! `figure c1` is the compressed-LLC exhibit: static/dynamic CRAM under
//! the plain vs Touché-style compressed LLC (`--llc-compressed` on
//! `repro sim` flips the same knob), over the 27 suite plus the
//! cache-pressure `llcfit_*` set.  `repro ablate llc` sweeps the
//! superblock-tag ratio and the per-set data budget.
//!
//! `figure x1` is the composed-design exhibit the layered controller
//! opened: {static, dynamic, explicit} × {flat, tiered} over the
//! far-pressure suite.  `--design` accepts any composition name
//! (`tiered-cram-dyn`, `tiered-explicit`, …) — `repro list` prints them
//! all; see `controller::policy`.  With `--far-ratio R1,R2,...` it
//! becomes the break-even sweep: each tiered composition re-run at every
//! split, with `--format csv|json` for machine-readable output.
//!
//! `figure l1` is the link-codec exhibit the third design axis opened:
//! each tiered composition with a raw vs compressed CXL link (`+lc`
//! designs run the size-only compressor pass on the TX side so
//! transfers emit fewer flits), reporting the speedup over the raw-link
//! twin and the wire-vs-storage byte breakdown per traffic class.
//! `--link-codec compressed` on `repro sim` flips the same axis on any
//! tiered design (flat placements have no serialized link, so it is a
//! structural no-op there).
//!
//! `figure m1` is the multi-tenant exhibit: canonical co-location mixes
//! under {uncompressed, cram-dynamic, tiered-cram-dyn}, reporting each
//! tenant's p99 read latency, slowdown vs running alone, compression-
//! interference beats and a Jain fairness index, plus a QoS contrast
//! with read slots reserved for the `:qos`-marked tenant.  `repro sim
//! --tenants` runs one such co-location directly; a `:bias=N` field
//! shifts that tenant's Dynamic-CRAM gate thresholds (positive =
//! compression-friendly, negative = latency-friendly).
//!
//! `figure r1` is the reliability exhibit: the CRAM far tier under a
//! uniform bit-error-rate sweep across every injection site (link
//! flits, far-media reads, marker tails), with the error-storm
//! watchdog disarmed and armed.  `repro sim --fault-ber B` injects the
//! same faults into any single run (`--fault-watchdog off` disarms the
//! degradation ladder); injection is off by default and the disabled
//! path is bit-identical to a fault-free build.
//!
//! `figure p1` is the layout-family exhibit the LayoutEngine seam
//! opened: the line-granular CRAM layouts next to the LCP
//! page-granular layout (`lcp` / `tiered-lcp` designs), flat and on
//! the far expander, reporting per-family speedup, metadata-traffic
//! share, and the effective-capacity ledger (expansion, exception
//! lines, recompactions) that only the page family can honestly fill.
//!
//! (clap is unavailable in this offline environment; argument parsing is
//! hand-rolled — see DESIGN.md §Substitutions.)

use std::collections::HashMap;

use cram::controller::{Design, LinkCodec};
use cram::coordinator::figures;
use cram::coordinator::runner::{ResultsDb, RunPlan};
use cram::sim::{simulate, SimConfig};
use cram::workloads::profiles::{all64, by_name, cache_pressure, far_pressure, latency_sensitive};

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn plan_from(flags: &HashMap<String, String>) -> RunPlan {
    let mut plan = RunPlan::default();
    if let Some(n) = flags.get("insts") {
        plan.insts_per_core = n.parse().expect("--insts must be an integer");
    }
    if let Some(n) = flags.get("threads") {
        plan.threads = n.parse().expect("--threads must be an integer");
    }
    if let Some(s) = flags.get("seed") {
        plan.seed = s.parse().expect("--seed must be an integer");
    }
    plan
}

fn parse_format(flags: &HashMap<String, String>) -> figures::OutputFormat {
    match flags.get("format").map(String::as_str) {
        None | Some("table") => figures::OutputFormat::Table,
        Some("csv") => figures::OutputFormat::Csv,
        Some("json") => figures::OutputFormat::Json,
        Some(f) => usage(&format!("unknown --format {f}")),
    }
}

/// Attach the persistent results cache unless `--no-cache`: load
/// fingerprint-compatible runs from `--cache PATH` (default
/// `CRAM_RESULTS.json`) and arm write-back so every executed batch
/// re-saves.  `--refresh` skips the load but still re-records.
fn attach_cache_flags(db: &mut ResultsDb, flags: &HashMap<String, String>) {
    if flags.contains_key("no-cache") {
        return;
    }
    let path = flags
        .get("cache")
        .cloned()
        .unwrap_or_else(|| "CRAM_RESULTS.json".into());
    let load = db.attach_cache(&path, flags.contains_key("refresh"));
    if let Some(note) = load.note {
        eprintln!("cache: {note}");
    } else if load.loaded > 0 {
        eprintln!("cache: loaded {} runs from {path}", load.loaded);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("help");

    match cmd {
        "reproduce-all" => {
            let out_dir = flags.get("out").cloned().unwrap_or_else(|| "results".into());
            let mut db = ResultsDb::new(plan_from(&flags));
            attach_cache_flags(&mut db, &flags);
            eprintln!(
                "running full matrix (insts/core={}, threads={}) ...",
                db.plan.insts_per_core, db.plan.threads
            );
            db.run_full_matrix(true);
            std::fs::create_dir_all(&out_dir).expect("create output dir");
            for r in figures::all_reports(&db) {
                let text = r.render();
                print!("{text}");
                std::fs::write(format!("{out_dir}/{}.txt", r.id), &text)
                    .expect("write report");
            }
            eprintln!("reports written to {out_dir}/");
        }
        "figure" | "table" => {
            let n = match pos.get(1) {
                Some(n) => n.clone(),
                None => usage("missing figure/table number"),
            };
            let id = if cmd == "figure" { format!("fig{n}") } else { format!("table{n}") };
            let mut db = ResultsDb::new(plan_from(&flags));
            attach_cache_flags(&mut db, &flags);
            let format = parse_format(&flags);
            // machine formats get the bare body (no banner) and silent
            // progress so stdout pipes clean
            let human = format == figures::OutputFormat::Table;
            // `figure x1 --far-ratio R1,R2,...`: the break-even sweep
            // instead of the fixed-split cross-product
            if id == "figx1" && flags.contains_key("far-ratio") {
                let ratios: Vec<f64> = flags["far-ratio"]
                    .split(',')
                    .map(|s| s.trim().parse().expect("--far-ratio takes a comma list"))
                    .collect();
                if ratios.is_empty() {
                    usage("--far-ratio needs at least one split");
                }
                db.run_x1_sweep(&ratios, human);
                let r = figures::figure_x1_sweep(&db, &ratios, format);
                if human {
                    print!("{}", r.render());
                } else {
                    print!("{}", r.body);
                }
                return;
            }
            // run only the designs the exhibit needs (batch telemetry
            // is sweep's business — figures discard it)
            let _ = match id.as_str() {
                "fig4" | "table3" | "figm1" | "figr1" => cram::coordinator::BatchStats::default(),
                "figt1" => db.run_tiered_t1(true),
                "figx1" => db.run_x1(true),
                "figq1" => db.run_q1(human),
                "figc1" => db.run_c1(human),
                "figl1" => db.run_l1(human),
                "figp1" => db.run_p1(human),
                "fig18" => db.run_designs(&[Design::Uncompressed, Design::Dynamic], true, true),
                "table4" => db.run_channel_sweep(true),
                "fig3" => db.run_designs(
                    &[Design::Uncompressed, Design::Ideal, Design::explicit(false)],
                    false,
                    true,
                ),
                "fig7" | "fig8" => db.run_designs(
                    &[Design::Uncompressed, Design::explicit(false)],
                    false,
                    true,
                ),
                "fig12" | "fig14" => db.run_designs(
                    &[
                        Design::Uncompressed,
                        Design::explicit(false),
                        Design::Implicit,
                    ],
                    false,
                    true,
                ),
                "fig15" => db.run_designs(&[Design::Uncompressed, Design::Implicit], false, true),
                "fig16" => db.run_designs(
                    &[Design::Uncompressed, Design::Implicit, Design::Dynamic, Design::Ideal],
                    false,
                    true,
                ),
                "fig19" => db.run_designs(&[Design::Uncompressed, Design::Dynamic], false, true),
                "fig20" => db.run_designs(
                    &[Design::Uncompressed, Design::explicit(true), Design::Dynamic],
                    false,
                    true,
                ),
                "table2" => db.run_designs(&[Design::Uncompressed], false, true),
                "table5" => db.run_designs(
                    &[Design::Uncompressed, Design::NextLinePrefetch, Design::Dynamic],
                    false,
                    true,
                ),
                _ => usage(&format!("unknown exhibit {id}")),
            };
            match figures::report_fmt(&db, &id, format) {
                Some(r) if human => print!("{}", r.render()),
                Some(r) => print!("{}", r.body),
                None => usage(&format!("unknown exhibit {id}")),
            }
        }
        "sim" => {
            if let Some(spec) = flags.get("tenants") {
                sim_tenants(spec, &flags);
                return;
            }
            let wl = match flags.get("workload") {
                Some(w) => w.clone(),
                None => usage("--workload required (or --tenants for co-location)"),
            };
            let d = match flags.get("design") {
                Some(d) => d.clone(),
                None => usage("--design required"),
            };
            let profile = match by_name(&wl) {
                Some(p) => p,
                None => usage(&format!("unknown workload {wl}")),
            };
            let design = match Design::parse(&d) {
                Some(d) => d,
                None => usage(&format!("unknown design {d}")),
            };
            let mut b = SimConfig::builder().design(design);
            if let Some(lc) = flags.get("link-codec") {
                b = b.link_codec(match lc.as_str() {
                    "raw" => LinkCodec::Raw,
                    "compressed" => LinkCodec::Compressed,
                    other => usage(&format!("unknown --link-codec {other}")),
                });
            }
            if let Some(n) = flags.get("insts") {
                b = b.insts(n.parse().expect("--insts"));
            }
            if let Some(c) = flags.get("channels") {
                b = b.channels(c.parse().expect("--channels"));
            }
            if let Some(r) = flags.get("far-ratio") {
                b = b.far_ratio(r.parse().expect("--far-ratio"));
            }
            if let Some(path) = flags.get("trace") {
                b = b.trace(
                    cram::workloads::TraceReplay::from_file(path).expect("load trace file"),
                );
            }
            if flags.contains_key("llc-compressed") {
                b = b.compressed_llc();
            }
            if let Some(ber) = flags.get("fault-ber") {
                b = b.fault_ber(ber.parse().expect("--fault-ber must be a number"));
            }
            if let Some(w) = flags.get("fault-watchdog") {
                b = b.fault_watchdog(match w.as_str() {
                    "on" | "true" => true,
                    "off" | "false" => false,
                    other => usage(&format!("unknown --fault-watchdog {other}")),
                });
            }
            let cfg = match b.try_build() {
                Ok(c) => c,
                Err(e) => usage(&format!("invalid config: {e}")),
            };
            let design = cfg.design;
            let d = design.name();
            let base_cfg = SimConfig { design: Design::Uncompressed, ..cfg.clone() };
            let r = simulate(&profile, &cfg);
            let base = simulate(&profile, &base_cfg);
            println!("workload {wl} design {d}");
            println!("  cycles             {}", r.cycles);
            println!("  aggregate IPC      {:.3}", r.total_ipc());
            println!("  measured MPKI      {:.2}", r.mpki());
            println!(
                "  weighted speedup   {}",
                cram::util::pct(r.weighted_speedup(&base))
            );
            println!(
                "  LLC hit rate       {:.1}%",
                100.0 * r.llc_hits as f64 / (r.llc_hits + r.llc_misses).max(1) as f64
            );
            match r.llp_accuracy {
                Some(a) => println!("  LLP accuracy       {:.1}%", 100.0 * a),
                None => println!("  LLP accuracy       n/a (LCT never consulted)"),
            }
            println!(
                "  read lat (ns)      mean {:.0} | p50 {:.0} | p95 {:.0} | p99 {:.0}",
                r.read_lat.mean() * cram::stats::NS_PER_BUS_CYCLE,
                r.read_lat.percentile(0.50) * cram::stats::NS_PER_BUS_CYCLE,
                r.read_lat.percentile(0.95) * cram::stats::NS_PER_BUS_CYCLE,
                r.read_lat.percentile(0.99) * cram::stats::NS_PER_BUS_CYCLE,
            );
            if let Some(mh) = r.meta_hit_rate {
                println!("  meta$ hit rate     {:.1}%", 100.0 * mh);
            }
            println!("  traffic (64B)      {:?}", r.bw);
            println!("  prefetch used/inst {} / {}", r.prefetch_used, r.prefetch_installed);
            println!("  groups compressed  {:.1}%", 100.0 * r.compression_enabled_frac);
            if let Some(c) = &r.capacity {
                println!(
                    "  page capacity      {:.2}x expansion ({} pages, {} exception \
                     lines, {} recompactions)",
                    c.expansion(),
                    c.pages,
                    c.exception_lines,
                    c.recompactions
                );
            }
            println!("  dyn cost/benefit   {} / {}", r.dyn_costs, r.dyn_benefits);
            if cfg.fault.enabled() {
                let rel = &r.rel;
                println!(
                    "  fault: link        {} flits retried, {} retry beats",
                    rel.flits_retried, rel.retry_beats
                );
                println!(
                    "  fault: media/marker {} media errs, {} marker errs \
                     ({} detected, {} silent), {} re-keys",
                    rel.media_errors,
                    rel.marker_errors,
                    rel.marker_detected,
                    rel.silent_misreads,
                    rel.rekeys
                );
                println!(
                    "  fault: watchdog    {} degrades, {} re-arms, {} degraded epochs",
                    rel.watchdog_degrades, rel.watchdog_rearms, rel.degraded_epochs
                );
            }
            if !r.dyn_counters.is_empty() {
                println!("  dyn counters(end)  {:?}", r.dyn_counters);
            }
            if let Some(st) = &r.llc_stats {
                println!(
                    "  LLC eff. capacity  {:.2}x ({:.0} lines avg vs {} uncompressed)",
                    st.effective_ratio(),
                    st.avg_lines(),
                    st.baseline_lines
                );
                println!(
                    "  LLC evictions      {} tag-forced / {} budget-forced",
                    st.tag_evictions, st.data_evictions
                );
            }
            if let Some(t) = &r.tier {
                println!("  tier near/far      {} / {} accesses", t.near.total(), t.far.total());
                println!("  far access share   {:.1}%", 100.0 * t.far_frac());
                println!(
                    "  migrations         {} promoted, {} demoted, {} lines",
                    t.promotions, t.demotions, t.migrated_lines
                );
                println!(
                    "  link flits tx/rx   {} / {}  (waits {} / {} cycles)",
                    t.link.tx_flits, t.link.rx_flits,
                    t.link.tx_wait_cycles, t.link.rx_wait_cycles
                );
                let lt = &t.link_traffic;
                println!(
                    "  link bytes         {} raw -> {} wire ({} flit-cycles saved)",
                    lt.raw_bytes(),
                    lt.wire_bytes(),
                    lt.flits_saved
                );
                println!(
                    "  wire/raw by class  demand {} meta {} wb {} pf {} migr {}",
                    ratio_str(lt.demand_wire_bytes, lt.demand_raw_bytes),
                    ratio_str(lt.meta_wire_bytes, lt.meta_raw_bytes),
                    ratio_str(lt.writeback_wire_bytes, lt.writeback_raw_bytes),
                    ratio_str(lt.prefetch_wire_bytes, lt.prefetch_raw_bytes),
                    ratio_str(lt.migration_wire_bytes, lt.migration_raw_bytes),
                );
                println!("  far prefetches     {}", t.far_prefetch_installs);
                assert_eq!(
                    t.total_accesses(),
                    r.bw.total(),
                    "per-tier counters must sum to total traffic"
                );
            }
        }
        "analyze" => {
            let artifact = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| cram::runtime::AnalysisEngine::DEFAULT_ARTIFACT.into());
            let wl = flags.get("workload").cloned().unwrap_or_else(|| "libq".into());
            let n_groups: usize = flags
                .get("groups")
                .map(|g| g.parse().expect("--groups"))
                .unwrap_or(2048);
            let profile = match by_name(&wl) {
                Some(p) => p,
                None => usage(&format!("unknown workload {wl}")),
            };
            let engine = cram::runtime::AnalysisEngine::load(&artifact)
                .expect("load analysis engine (a present artifact failed validation — rebuild with `python -m compile.aot`)");
            let model = profile.value_model(0xF16_4);
            let groups: Vec<[cram::mem::CacheLine; 4]> = (0..n_groups as u64)
                .map(|g| core::array::from_fn(|s| model.gen_line(g * 4 + s as u64, 0)))
                .collect();
            let analysis = engine.analyze(&groups).expect("analyze");
            let mut counts = [0u64; 5];
            for a in &analysis {
                counts[a.csi as usize] += 1;
            }
            let backend = match engine.backend() {
                cram::runtime::Backend::ArtifactValidated => {
                    format!("native engine, artifact validated ({artifact})")
                }
                cram::runtime::Backend::NativeOnly => "native engine, no artifact".into(),
            };
            println!("workload {wl}: {n_groups} groups via {backend}");
            for (i, label) in ["uncompressed", "pair-AB", "pair-CD", "pair-both", "quad"]
                .iter()
                .enumerate()
            {
                println!(
                    "  {label:<14} {:>6}  ({:.1}%)",
                    counts[i],
                    100.0 * counts[i] as f64 / n_groups as f64
                );
            }
        }
        "gen-trace" => {
            // export a synthetic stream in the trace-file format — both a
            // dogfood test of the loader and a way to hand workloads to
            // other simulators
            let wl = flags.get("workload").cloned().unwrap_or_else(|| "libq".into());
            let out = flags.get("out").cloned().unwrap_or_else(|| "/tmp/cram_trace.txt".into());
            let n: usize = flags.get("events").map(|v| v.parse().expect("--events")).unwrap_or(100_000);
            let profile = match by_name(&wl) {
                Some(p) => p,
                None => usage(&format!("unknown workload {wl}")),
            };
            let mut s = cram::workloads::AccessStream::new(&profile, 0xC0DE);
            let events: Vec<_> = (0..n).map(|_| s.next_event()).collect();
            let replay = cram::workloads::TraceReplay::from_events(events);
            std::fs::write(&out, replay.to_text()).expect("write trace");
            println!("wrote {n} events from {wl} to {out}");
        }
        "ablate" => {
            let what = pos.get(1).map(|s| s.as_str()).unwrap_or("all");
            let insts: u64 = flags
                .get("insts")
                .map(|n| n.parse().expect("--insts"))
                .unwrap_or(1_500_000);
            use cram::coordinator::ablation;
            let reports: Vec<cram::coordinator::Report> = match what {
                "llp" => vec![ablation::ablate_llp(insts)],
                "metacache" => vec![ablation::ablate_metacache(insts)],
                "compressor" => vec![ablation::ablate_compressor(insts)],
                "marker" => vec![ablation::ablate_marker_width()],
                "sched" => vec![ablation::ablate_sched(insts)],
                "llc" => vec![ablation::ablate_llc(insts)],
                "all" => vec![
                    ablation::ablate_marker_width(),
                    ablation::ablate_llp(insts),
                    ablation::ablate_metacache(insts),
                    ablation::ablate_compressor(insts),
                    ablation::ablate_sched(insts),
                    ablation::ablate_llc(insts),
                ],
                other => usage(&format!("unknown ablation {other}")),
            };
            for r in reports {
                print!("{}", r.render());
            }
        }
        "bench" => {
            // `repro bench` — the simulator throughput matrix + regression
            // gate, runnable locally and by the CI bench job:
            //   repro bench [--insts N] [--json OUT] [--save]
            //               [--check [BASELINE]] [--current FILE]
            //               [--tolerance PCT]
            // --check compares the run (or --current, a previously written
            // BENCH_*.json, skipping the re-run) against BASELINE (default
            // BENCH_sim.json) and exits 1 on a >PCT% median Melem/s drop.
            // --save (re)records the committed baseline: it writes the run
            // to BENCH_sim.json in the working directory — run it on the
            // machine class that executes the gate (see DESIGN.md
            // §Simulation performance on arming the CI gate), then
            // commit the file.
            let tolerance: f64 = flags
                .get("tolerance")
                .map(|v| v.parse().expect("--tolerance must be a number"))
                .unwrap_or(15.0);
            let melems: Vec<f64> = if let Some(cur) = flags.get("current") {
                let text = std::fs::read_to_string(cur)
                    .unwrap_or_else(|e| usage(&format!("cannot read --current {cur}: {e}")));
                cram::util::bench::read_json_melems(&text)
            } else {
                let insts: u64 = flags
                    .get("insts")
                    .map(|v| v.parse().expect("--insts must be an integer"))
                    .unwrap_or(150_000);
                let b = cram::util::bench::Bencher::quick();
                let results = cram::coordinator::bench::run_sim_matrix(insts, &b);
                // --json OUT writes wherever asked; --save always
                // (additionally) writes the gate's baseline path, since
                // that is the file --check and CI read
                let mut outputs: Vec<String> = Vec::new();
                if let Some(p) = flags.get("json") {
                    outputs.push(p.clone());
                }
                if flags.contains_key("save") && !outputs.iter().any(|p| p == "BENCH_sim.json")
                {
                    outputs.push("BENCH_sim.json".to_string());
                }
                for path in &outputs {
                    cram::util::bench::write_json(path, &results).expect("write bench json");
                    println!("wrote {} results to {path}", results.len());
                }
                if flags.contains_key("save") {
                    println!(
                        "baseline recorded; commit BENCH_sim.json to arm the \
                         regression gate on this machine class (DESIGN.md \
                         §Simulation performance)"
                    );
                }
                results.iter().filter_map(|r| r.elems_per_sec()).map(|t| t / 1e6).collect()
            };
            if let Some(check) = flags.get("check") {
                let baseline =
                    if check == "true" { "BENCH_sim.json" } else { check.as_str() };
                match cram::util::bench::check_regression(baseline, &melems, tolerance) {
                    Ok(msg) => println!("{msg}"),
                    Err(msg) => {
                        eprintln!("{msg}");
                        std::process::exit(1);
                    }
                }
            }
        }
        "sweep" => {
            // `repro sweep` — the full design-space campaign: every one
            // of the 32 compositions x every workload profile set, with
            // optional grid axes, through the sharded experiment engine:
            //   repro sweep [--insts N] [--threads N] [--seed S]
            //               [--far-ratio R1,R2,...] [--llc-compressed]
            //               [--extended] [--format table|csv|json]
            //               [--cache PATH] [--no-cache] [--refresh]
            let mut db = ResultsDb::new(plan_from(&flags));
            attach_cache_flags(&mut db, &flags);
            let format = parse_format(&flags);
            let far_ratios: Vec<f64> = flags
                .get("far-ratio")
                .map(|s| {
                    s.split(',')
                        .map(|x| x.trim().parse().expect("--far-ratio takes a comma list"))
                        .collect()
                })
                .unwrap_or_default();
            let cfg = cram::coordinator::SweepConfig {
                far_ratios,
                llc_grid: flags.contains_key("llc-compressed"),
                extended: flags.contains_key("extended"),
                format,
            };
            let human = format == figures::OutputFormat::Table;
            let out = cram::coordinator::run_sweep(&mut db, &cfg, human);
            if human {
                print!("{}", out.report.render());
            } else {
                print!("{}", out.report.body);
            }
            cram::coordinator::sweep::print_telemetry(&out);
        }
        "list" => {
            println!("designs (policy x placement x link-codec compositions):");
            for d in Design::all() {
                println!("  {}", d.name());
            }
            let far = far_pressure();
            let lat = latency_sensitive();
            let cache = cache_pressure();
            println!(
                "workloads ({} + {} far-pressure + {} latency-sensitive + {} cache-pressure):",
                all64().len(),
                far.len(),
                lat.len(),
                cache.len()
            );
            for w in all64().iter().chain(far.iter()).chain(lat.iter()).chain(cache.iter()) {
                println!("  {:<14} {}", w.name, w.suite);
            }
        }
        _ => {
            usage("");
        }
    }
}

/// `repro sim --tenants W1[:CORES][:qos][:bias=N],W2,...` — one
/// co-located run with per-tenant accounting (plus the per-tenant solo
/// reruns behind the slowdown column).
fn sim_tenants(spec: &str, flags: &HashMap<String, String>) {
    let d = flags.get("design").map(String::as_str).unwrap_or("cram-dynamic");
    let design = match Design::parse(d) {
        Some(d) => d,
        None => usage(&format!("unknown design {d}")),
    };
    let mut b = SimConfig::builder().design(design);
    if let Some(n) = flags.get("insts") {
        b = b.insts(n.parse().expect("--insts"));
    }
    if let Some(c) = flags.get("channels") {
        b = b.channels(c.parse().expect("--channels"));
    }
    if let Some(r) = flags.get("far-ratio") {
        b = b.far_ratio(r.parse().expect("--far-ratio"));
    }
    if flags.contains_key("llc-compressed") {
        b = b.compressed_llc();
    }
    if let Some(n) = flags.get("qos-slots") {
        b = b.sched(cram::dram::SchedConfig {
            reserved_slots: n.parse().expect("--qos-slots"),
            ..Default::default()
        });
    }
    let cfg = match b.try_build() {
        Ok(c) => c,
        Err(e) => usage(&format!("invalid config: {e}")),
    };
    let specs = match cram::workloads::parse_tenants(spec, cfg.cores) {
        Ok(s) => s,
        Err(e) => usage(&format!("bad --tenants spec: {e}")),
    };
    let r = cram::sim::simulate_tenants(&specs, &cfg);
    println!("tenants {spec} design {}", design.name());
    println!("  cycles             {}", r.cycles);
    println!("  aggregate IPC      {:.3}", r.total_ipc());
    println!(
        "{:<12} {:>5} {:>10} {:>9} {:>8} {:>8} {:>8} {:>9} {:>13}",
        "tenant", "cores", "traffic", "reads", "p50-ns", "p95-ns", "p99-ns",
        "slowdown", "interf-beats"
    );
    let ns = cram::stats::NS_PER_BUS_CYCLE;
    for t in &r.tenants {
        let slow = t
            .slowdown
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<12} {:>5} {:>10} {:>9} {:>8.0} {:>8.0} {:>8.0} {:>9} {:>13.0}{}",
            t.name,
            t.cores,
            t.bw.total(),
            t.bw.demand_reads,
            t.read_lat.percentile(0.50) * ns,
            t.read_lat.percentile(0.95) * ns,
            t.read_lat.percentile(0.99) * ns,
            slow,
            t.interference_beats,
            if t.protected { "  [qos]" } else { "" }
        );
    }
    let progress: Vec<f64> = r
        .tenants
        .iter()
        .filter_map(|t| t.slowdown)
        .map(|s| 1.0 / s.max(1e-9))
        .collect();
    println!(
        "  fairness (Jain over 1/slowdown): {:.3}",
        cram::stats::jain_index(&progress)
    );
    let sum: u64 = r.tenants.iter().map(|t| t.bw.total()).sum();
    assert_eq!(sum, r.bw.total(), "per-tenant traffic must sum to the total");
}

/// Per-class wire/raw byte ratio for `repro sim` output ("-" when the
/// class never moved a byte).
fn ratio_str(wire: u64, raw: u64) -> String {
    if raw == 0 {
        "-".into()
    } else {
        format!("{:.2}", wire as f64 / raw as f64)
    }
}

fn usage(msg: &str) -> ! {
    if !msg.is_empty() {
        eprintln!("error: {msg}\n");
    }
    eprintln!(
        "usage:\n  repro reproduce-all [--out DIR] [--insts N] [--threads N] [--seed S]\n  repro figure <3|4|7|8|12|14|15|16|18|19|20|t1|q1|c1|x1|l1|m1|r1|p1> [--insts N]\n  repro figure <q1|c1|l1|m1|r1|p1> --format table|csv|json\n  repro figure x1 --far-ratio R1,R2,... [--format table|csv|json]\n  repro table <2|3|4|5> [--insts N]\n  repro sim --workload W --design D [--insts N] [--channels C] [--far-ratio R] [--link-codec raw|compressed] [--trace FILE] [--llc-compressed] [--fault-ber B] [--fault-watchdog on|off]\n  repro sim --tenants W1[:CORES][:qos][:bias=N],W2,... [--design D] [--qos-slots N] [--insts N]\n  repro sweep [--insts N] [--threads N] [--seed S] [--far-ratio R1,R2,...] [--llc-compressed] [--extended] [--format table|csv|json] [--cache PATH] [--no-cache] [--refresh]\n  repro analyze [--artifact PATH] [--workload W] [--groups N]\n  repro ablate <llp|metacache|compressor|marker|sched|llc|all> [--insts N]\n  repro bench [--insts N] [--json OUT] [--save] [--check [BASELINE]] [--current FILE] [--tolerance PCT]\n  repro list\n\ndesigns are policy x placement x link-codec compositions (repro list\nprints all 32): tiered-uncomp/tiered-cram (figure t1), tiered-cram-dyn/\ntiered-explicit (figure x1), lcp/tiered-lcp (figure p1) — near DDR + far\nCXL expander; --far-ratio R puts fraction R of capacity behind the link;\na +lc suffix (or --link-codec compressed on repro sim) compresses flits\nover that link\nfigure q1: p50/p95/p99 read latency per design through the FR-FCFS scheduler\nfigure c1: static/dynamic CRAM under the plain vs compressed (Touché-style)\nLLC over the 27 suite + cache-pressure llcfit_* workloads; --llc-compressed\nflips the same knob on repro sim; ablate llc sweeps tag ratio / data budget\nfigure x1: {static, dynamic, explicit} x {flat, tiered} over the far-pressure\nsuite — the composed-design cross-product; with --far-ratio R1,R2,... it\nsweeps the capacity split to each tiered composition's break-even\nfigure l1: raw vs compressed link x {static, dynamic, explicit} tiered\ndesigns over the far-pressure suite — speedup vs the raw-link twin plus\nthe wire-vs-storage byte breakdown per traffic class\nfigure m1: multi-tenant co-location mixes x {uncompressed, cram-dynamic,\ntiered-cram-dyn} — per-tenant p99, slowdown-vs-alone, interference beats,\nJain fairness, and a QoS read-slot-reservation contrast\nfigure r1: reliability — tiered-cram under a uniform BER sweep (link CRC\nretries, far-media errors, marker corruption) with the error-storm\nwatchdog disarmed vs armed; --fault-ber B on repro sim injects the same\nfaults into any run (--fault-watchdog off disarms the degradation ladder;\ninjection defaults off and is then bit-identical to a fault-free build)\nfigure p1: layout families — line-granular CRAM vs page-granular LCP\n(lcp/tiered-lcp), flat and tiered, over the 27 suite + far-pressure set:\nspeedup, metadata-traffic share, and the LCP effective-capacity ledger\n--format csv|json on figures q1/c1/l1/m1/r1/p1 and the x1 sweep emits the\nbare machine-readable rows for plotting scripts\nsim --tenants: one co-location (workload[:cores][:qos][:bias=N], comma-\nseparated; :qos marks the protected tenant, --qos-slots N reserves N of 32\nread slots; :bias=N shifts that tenant's Dynamic-CRAM gate thresholds)\nsweep: the full campaign — all 32 compositions x every profile set (plus\n--far-ratio splits and --llc-compressed twins as grid axes; --extended adds\nthe low-MPKI set); per-phase wall time and jobs/s land on stderr\nreproduce-all/figure/table/sweep reuse completed runs from the persistent\nCRAM_RESULTS.json cache (fingerprint-keyed, self-invalidating); --no-cache\nskips it, --refresh re-records, --cache PATH relocates it\nbench: simulator throughput matrix; --check gates a >PCT% (default 15) median\nMelem/s regression vs the committed BENCH_sim.json baseline; --save records\nBENCH_sim.json locally (commit it to arm the gate)"
    );
    std::process::exit(2);
}
