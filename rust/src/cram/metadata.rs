//! Explicit-metadata baseline (paper §IV-B, Fig. 7/8; row-optimized
//! variant for Fig. 20).
//!
//! Conventional compressed-memory designs keep Compression Status
//! Information (CSI, 3 bits per 4-line group) in a dedicated metadata
//! region in memory and cache it on chip.  This module models that region
//! (address geometry) plus a 32KB set-associative metadata cache with
//! dirty write-back — the bandwidth cost CRAM's implicit metadata
//! eliminates.

use crate::cram::group::Csi;
use crate::mem::GROUP_LINES;

/// CSI entries per 64-byte metadata line: 512 bits / 3 ≈ 170 groups.
pub const GROUPS_PER_META_LINE: u64 = 170;

/// Where a metadata access was served from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetaAccess {
    /// Metadata-cache hit: no memory traffic.
    Hit,
    /// Miss: one memory read for the metadata line (plus possibly a dirty
    /// write-back recorded separately in [`MetadataStore::writebacks`]).
    Miss,
}

#[derive(Clone, Copy, Debug, Default)]
struct MetaCacheLine {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp.
    lru: u64,
}

/// The metadata region + on-chip metadata cache.
pub struct MetadataStore {
    /// Ground-truth CSI per group (the memory-resident region).
    csi: std::collections::HashMap<u64, Csi>,
    /// Set-associative cache over metadata lines.
    sets: Vec<Vec<MetaCacheLine>>,
    tick: u64,
    /// First physical line address of the metadata region (so DRAM traffic
    /// can be attributed to real addresses).
    pub region_base_line: u64,
    /// Fig. 20 variant: metadata co-located with the data row (accesses
    /// become row-buffer hits but still consume bus bandwidth).
    pub row_optimized: bool,
    // --- statistics ---
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
    pub updates: u64,
}

impl MetadataStore {
    /// `cache_bytes` on-chip metadata cache (paper: 32KB, 8-way).
    pub fn new(cache_bytes: usize, ways: usize, region_base_line: u64) -> Self {
        let lines = cache_bytes / 64;
        let n_sets = (lines / ways).max(1);
        assert!(n_sets.is_power_of_two(), "metadata cache sets must be 2^k");
        Self {
            csi: Default::default(),
            sets: vec![vec![MetaCacheLine::default(); ways]; n_sets],
            tick: 0,
            region_base_line,
            row_optimized: false,
            hits: 0,
            misses: 0,
            writebacks: 0,
            updates: 0,
        }
    }

    /// Paper configuration: 32KB, 8-way.
    pub fn paper_default(region_base_line: u64) -> Self {
        Self::new(32 * 1024, 8, region_base_line)
    }

    /// Metadata line index covering `group`.
    #[inline]
    pub fn meta_line_of_group(&self, group: u64) -> u64 {
        group / GROUPS_PER_META_LINE
    }

    /// Physical line address of the metadata line for `line_addr`'s group.
    #[inline]
    pub fn meta_addr_for(&self, line_addr: u64) -> u64 {
        self.region_base_line + self.meta_line_of_group(line_addr / GROUP_LINES)
    }

    /// Ground-truth CSI for the group of `line_addr`.
    pub fn csi_of_line(&self, line_addr: u64) -> Csi {
        *self
            .csi
            .get(&(line_addr / GROUP_LINES))
            .unwrap_or(&Csi::Uncompressed)
    }

    fn set_index(&self, meta_line: u64) -> usize {
        (meta_line as usize) & (self.sets.len() - 1)
    }

    /// Touch the metadata cache for `meta_line`; true hit / false miss.
    /// On miss the victim's dirtiness is recorded in `writebacks`.
    fn touch(&mut self, meta_line: u64, mark_dirty: bool) -> MetaAccess {
        self.tick += 1;
        let tick = self.tick;
        let si = self.set_index(meta_line);
        let set = &mut self.sets[si];
        if let Some(way) = set.iter_mut().find(|w| w.valid && w.tag == meta_line) {
            way.lru = tick;
            way.dirty |= mark_dirty;
            self.hits += 1;
            return MetaAccess::Hit;
        }
        self.misses += 1;
        // victim = invalid way or LRU
        let victim = set
            .iter_mut()
            .min_by_key(|w| if w.valid { w.lru } else { 0 })
            .expect("ways > 0");
        if victim.valid && victim.dirty {
            self.writebacks += 1;
        }
        *victim = MetaCacheLine {
            tag: meta_line,
            valid: true,
            dirty: mark_dirty,
            lru: tick,
        };
        MetaAccess::Miss
    }

    /// Pure-cache access for a caller that does its own metadata-line
    /// addressing — the LCP page-descriptor cache
    /// ([`crate::controller::lcp`]) reuses this store's set-assoc LRU +
    /// dirty-writeback machinery with `meta_line = page /`
    /// [`DESCS_PER_LINE`](crate::controller::lcp::DESCS_PER_LINE)
    /// instead of the CSI group geometry; the ground-truth CSI map is
    /// not consulted (descriptors live in [`LcpLayout`]).  Misses and
    /// dirty-victim `writebacks` count exactly as for [`lookup`] /
    /// [`update`].
    ///
    /// [`LcpLayout`]: crate::controller::lcp::LcpLayout
    /// [`lookup`]: MetadataStore::lookup
    /// [`update`]: MetadataStore::update
    pub fn access(&mut self, meta_line: u64, mark_dirty: bool) -> MetaAccess {
        if mark_dirty {
            self.updates += 1;
        }
        self.touch(meta_line, mark_dirty)
    }

    /// Read path: obtain the CSI for `line_addr`'s group.
    /// Returns (csi, how it was served).
    pub fn lookup(&mut self, line_addr: u64) -> (Csi, MetaAccess) {
        let group = line_addr / GROUP_LINES;
        let access = self.touch(self.meta_line_of_group(group), false);
        (self.csi_of_line(line_addr), access)
    }

    /// Write path: record a (possibly changed) CSI after a group write.
    /// Dirty-allocates in the metadata cache.
    pub fn update(&mut self, line_addr: u64, csi: Csi) -> MetaAccess {
        let group = line_addr / GROUP_LINES;
        self.updates += 1;
        self.csi.insert(group, csi);
        self.touch(self.meta_line_of_group(group), true)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let m = MetadataStore::paper_default(1 << 28);
        assert_eq!(m.meta_line_of_group(0), 0);
        assert_eq!(m.meta_line_of_group(169), 0);
        assert_eq!(m.meta_line_of_group(170), 1);
        // lines 0..679 share metadata line 0 (170 groups * 4 lines)
        assert_eq!(m.meta_addr_for(679), 1 << 28);
        assert_eq!(m.meta_addr_for(680), (1 << 28) + 1);
    }

    #[test]
    fn cache_hit_after_miss() {
        let mut m = MetadataStore::paper_default(1 << 28);
        let (csi, a1) = m.lookup(0);
        assert_eq!(csi, Csi::Uncompressed);
        assert_eq!(a1, MetaAccess::Miss);
        let (_, a2) = m.lookup(1); // same group -> same metadata line
        assert_eq!(a2, MetaAccess::Hit);
        let (_, a3) = m.lookup(679 * 1); // still metadata line 0
        assert_eq!(a3, MetaAccess::Hit);
    }

    #[test]
    fn update_round_trips_csi() {
        let mut m = MetadataStore::paper_default(1 << 28);
        m.update(4, Csi::Quad);
        assert_eq!(m.csi_of_line(4), Csi::Quad);
        assert_eq!(m.csi_of_line(7), Csi::Quad); // same group
        assert_eq!(m.csi_of_line(8), Csi::Uncompressed); // next group
    }

    #[test]
    fn spatial_locality_hits_poor_locality_misses() {
        let mut m = MetadataStore::paper_default(1 << 28);
        // sequential scan: high hit rate
        for line in 0..64_000u64 {
            m.lookup(line);
        }
        assert!(m.hit_rate() > 0.95, "sequential hit rate {}", m.hit_rate());

        // scattered scan over a large footprint: poor hit rate
        let mut m2 = MetadataStore::paper_default(1 << 28);
        let mut x = 1u64;
        for _ in 0..64_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            m2.lookup(x % (1 << 28));
        }
        assert!(m2.hit_rate() < 0.2, "random hit rate {}", m2.hit_rate());
    }

    #[test]
    fn pure_cache_access_behaves_like_lookup() {
        let mut m = MetadataStore::paper_default(0);
        assert_eq!(m.access(3, false), MetaAccess::Miss);
        assert_eq!(m.access(3, false), MetaAccess::Hit);
        assert_eq!(m.access(3, true), MetaAccess::Hit, "dirty-allocate on a hit");
        assert_eq!((m.hits, m.misses, m.updates), (2, 1, 1));
        // a dirty line evicted by caller-addressed traffic still counts
        let mut tiny = MetadataStore::new(64 * 2, 2, 0); // 1 set, 2 ways
        tiny.access(0, true);
        tiny.access(1, false);
        tiny.access(2, false); // evicts dirty line 0
        assert_eq!(tiny.writebacks, 1);
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        // tiny cache: 64 sets * 8 ways would be big; use 1-set config
        let mut m = MetadataStore::new(64 * 2, 2, 0); // 2 lines, 2-way, 1 set
        m.update(0, Csi::Quad); // meta line 0, dirty
        m.update(680 * 4 / 4 * 4, Csi::Quad); // meta line 1... compute: group 680 -> line 1
        m.lookup(680 * 2 * 4); // meta line 8? -> evicts one dirty victim
        assert!(m.writebacks >= 1);
    }
}
