//! Dynamic-CRAM (paper §VI): set-sampled cost/benefit compression gating.
//!
//! A small fraction of LLC sets (1%) *always* compress; only they update
//! the statistics.  A 12-bit saturating counter per core is decremented on
//! every bandwidth **cost** event (extra clean writeback, invalidate,
//! mispredicted second access) and incremented on every **benefit** event
//! (useful bandwidth-free prefetch).  The counter's MSB gates compression
//! for the other 99% of sets, per requesting core.

/// Counter width (paper: 12 bits, sized for 1B-instruction slices; the
/// simulator scales it down with the slice length — see
/// [`DynamicCram::with_bits`]).
pub const COUNTER_BITS: u32 = 12;

/// Fraction of LLC sets that are sampled (always-compress). 1% ≈ 1/128
/// was chosen as a power-of-two approximation of the paper's 1%.
pub const SAMPLE_MOD: u64 = 128;

/// Per-core Dynamic-CRAM policy state.
#[derive(Clone, Debug)]
pub struct DynamicCram {
    counters: Vec<i32>,
    bits: u32,
    /// Gate state per core (hysteresis: see [`DynamicCram::enabled`]).
    state: Vec<std::cell::Cell<bool>>,
    /// Cost/benefit event counts (diagnostics & Fig. 15/16 analysis).
    pub cost_events: Vec<u64>,
    pub benefit_events: Vec<u64>,
}

impl DynamicCram {
    /// Paper configuration: 12-bit counters.
    pub fn new(cores: usize) -> Self {
        Self::with_bits(cores, COUNTER_BITS)
    }

    /// Scaled counter width: the MSB threshold (2^(bits-1)) is the
    /// hysteresis depth, which must be proportional to the sampled-event
    /// rate of the simulated slice (the paper's 12 bits suit 1B-inst
    /// slices; short simulation slices use 8).
    pub fn with_bits(cores: usize, bits: u32) -> Self {
        Self {
            // start at the enable threshold: compression on until costs
            // demonstrably dominate
            counters: vec![1 << (bits - 1); cores],
            bits,
            state: (0..cores).map(|_| std::cell::Cell::new(true)).collect(),
            cost_events: vec![0; cores],
            benefit_events: vec![0; cores],
        }
    }

    #[inline]
    fn max(&self) -> i32 {
        (1 << self.bits) - 1
    }

    /// Is `set_index` one of the sampled (always-compress) LLC sets?
    #[inline]
    pub fn is_sampled_set(set_index: u64) -> bool {
        set_index % SAMPLE_MOD == 0
    }

    /// Group-granular sampling: a compression group's four lines span four
    /// consecutive LLC sets, so cost/benefit attribution must be decided
    /// per *group* (all four lines agree), not per line's set.
    #[inline]
    pub fn is_sampled_group(group: u64) -> bool {
        group % SAMPLE_MOD == 0
    }

    /// Bandwidth-cost event observed on a sampled set.
    #[inline]
    pub fn on_cost(&mut self, core: usize) {
        self.cost_events[core] += 1;
        let c = &mut self.counters[core];
        *c = (*c - 1).max(0);
    }

    /// Bandwidth-benefit event observed on a sampled set.
    #[inline]
    pub fn on_benefit(&mut self, core: usize) {
        self.benefit_events[core] += 1;
        let max = self.max();
        let c = &mut self.counters[core];
        *c = (*c + 1).min(max);
    }

    /// Should the non-sampled sets compress for this core?
    ///
    /// The paper gates on the counter MSB.  At simulation scale a single
    /// threshold makes borderline workloads oscillate every few sampled
    /// events, and each flip pays real unpack/repack traffic; we add a
    /// hysteresis band around the MSB (enable at 3/4, disable at 1/4 of
    /// the range) — the natural scaled-slice reading of the MSB rule,
    /// since the paper's 12-bit counter makes flips ~1000x rarer.
    #[inline]
    pub fn enabled(&self, core: usize) -> bool {
        let hi = 3 * (1 << (self.bits - 2));
        let lo = 1 << (self.bits - 2);
        let c = self.counters[core];
        if c >= hi {
            self.state[core].set(true);
        } else if c < lo {
            self.state[core].set(false);
        }
        self.state[core].get()
    }

    pub fn counter(&self, core: usize) -> i32 {
        self.counters[core]
    }

    /// Storage cost of the counters (paper Table III: 12 bytes — eight
    /// 12-bit counters).
    pub fn storage_bytes(&self) -> u32 {
        (self.counters.len() as u32 * self.bits).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_enabled() {
        let d = DynamicCram::new(8);
        for c in 0..8 {
            assert!(d.enabled(c));
        }
    }

    #[test]
    fn costs_disable_benefits_reenable() {
        let mut d = DynamicCram::new(1);
        d.on_cost(0);
        assert!(d.enabled(0), "hysteresis: one cost does not flip the gate");
        // long cost streak: disabled and saturates at 0
        for _ in 0..10_000 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0));
        assert_eq!(d.counter(0), 0);
        // needs a sustained benefit streak to flip back (3/4 of range)
        for _ in 0..3 * 1024 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0));
    }

    #[test]
    fn hysteresis_band_prevents_flapping() {
        let mut d = DynamicCram::with_bits(1, 6); // range 0..63, lo=16 hi=48
        // drive to the middle repeatedly: state must not change
        for _ in 0..40 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0)); // hit 0 -> disabled... counter back up:
        for _ in 0..40 {
            d.on_benefit(0);
        }
        // at 40 (between lo and hi): stays disabled
        assert!(!d.enabled(0), "mid-band keeps prior state");
        for _ in 0..10 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0), "crossing hi enables");
        for _ in 0..20 {
            d.on_cost(0);
        }
        // back to mid-band: stays enabled
        assert!(d.enabled(0), "mid-band keeps prior state (enabled)");
    }

    #[test]
    fn saturates_high() {
        let mut d = DynamicCram::new(1);
        for _ in 0..10_000 {
            d.on_benefit(0);
        }
        assert_eq!(d.counter(0), 4095);
    }

    #[test]
    fn per_core_isolation() {
        let mut d = DynamicCram::new(2);
        for _ in 0..4000 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0));
        assert!(d.enabled(1), "core 1 unaffected by core 0's costs");
    }

    #[test]
    fn sampled_sets_are_about_one_percent() {
        let sampled = (0..8192u64).filter(|&s| DynamicCram::is_sampled_set(s)).count();
        assert_eq!(sampled, 8192 / SAMPLE_MOD as usize);
    }

    #[test]
    fn storage_overhead_table3() {
        // 8 cores * 12 bits = 12 bytes
        assert_eq!(DynamicCram::new(8).storage_bytes(), 12);
    }

    #[test]
    fn scaled_counter_flips_faster() {
        let mut d = DynamicCram::with_bits(1, 8);
        for _ in 0..300 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0));
        for _ in 0..200 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0), "8-bit counter recovers in ~192 benefits");
    }
}
