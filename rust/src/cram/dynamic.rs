//! Dynamic-CRAM (paper §VI): sampled cost/benefit compression gating.
//!
//! A small fraction (~1%) of compression *groups* always compress; only
//! they update the statistics.  Sampling is **group-granular**: the four
//! lines of a CRAM group span four consecutive LLC sets, so a set-granular
//! sample (the paper's framing) can disagree between members of one group
//! — cost/benefit events would then be attributed for lines whose group
//! was never in the always-compress population.  Every caller (read path,
//! writeback path, prefetch-use accounting) therefore decides sampling via
//! [`DynamicCram::is_sampled_group`] on the group index, so one group gets
//! one consistent verdict.
//!
//! A 12-bit saturating counter per core is decremented on every bandwidth
//! **cost** event (extra clean writeback, invalidate, mispredicted second
//! access) and incremented on every **benefit** event (useful
//! bandwidth-free prefetch).  The counter's MSB gates compression for the
//! other 99% of groups, per requesting core.

/// Counter width (paper: 12 bits, sized for 1B-instruction slices; the
/// simulator scales it down with the slice length — see
/// [`DynamicCram::with_bits`]).
pub const COUNTER_BITS: u32 = 12;

/// Fraction of compression groups that are sampled (always-compress).
/// 1% ≈ 1/128 was chosen as a power-of-two approximation of the paper's
/// 1% of LLC sets.
pub const SAMPLE_MOD: u64 = 128;

/// Per-core Dynamic-CRAM policy state.
#[derive(Clone, Debug)]
pub struct DynamicCram {
    counters: Vec<i32>,
    bits: u32,
    /// Gate state per core (hysteresis: see [`DynamicCram::enabled`]).
    state: Vec<std::cell::Cell<bool>>,
    /// Per-core tenant bias ([`DynamicCram::set_bias`]): shifts the
    /// hysteresis band, leaving the counters untouched.
    bias: Vec<i32>,
    /// Cost/benefit event counts (diagnostics & Fig. 15/16 analysis).
    pub cost_events: Vec<u64>,
    pub benefit_events: Vec<u64>,
}

impl DynamicCram {
    /// Paper configuration: 12-bit counters.
    pub fn new(cores: usize) -> Self {
        Self::with_bits(cores, COUNTER_BITS)
    }

    /// Scaled counter width: the MSB threshold (2^(bits-1)) is the
    /// hysteresis depth, which must be proportional to the sampled-event
    /// rate of the simulated slice (the paper's 12 bits suit 1B-inst
    /// slices; short simulation slices use 8).
    ///
    /// `bits` must be at least 2: the hysteresis band is `1 << (bits - 2)`
    /// wide, so a 1-bit counter has no representable band (and would
    /// underflow the shift into a corrupt threshold).
    pub fn with_bits(cores: usize, bits: u32) -> Self {
        assert!(
            (2..=30).contains(&bits),
            "DynamicCram counter width must be 2..=30 bits (got {bits}): \
             the hysteresis thresholds are 1<<(bits-2) and 3<<(bits-2)"
        );
        Self {
            // start at the enable threshold: compression on until costs
            // demonstrably dominate
            counters: vec![1 << (bits - 1); cores],
            bits,
            state: (0..cores).map(|_| std::cell::Cell::new(true)).collect(),
            bias: vec![0; cores],
            cost_events: vec![0; cores],
            benefit_events: vec![0; cores],
        }
    }

    /// Tenant QoS bias for `core` (the `:bias=N` knob of a tenant
    /// spec): a positive bias lowers both hysteresis thresholds, so the
    /// core's gate tolerates `N` more net cost events before closing
    /// (compression-friendly); a negative bias raises them, closing the
    /// gate sooner (latency-friendly).  `0` (the default) is
    /// bit-identical to an unbiased gate.
    pub fn set_bias(&mut self, core: usize, bias: i32) {
        self.bias[core] = bias;
    }

    #[inline]
    fn max(&self) -> i32 {
        (1 << self.bits) - 1
    }

    /// Group-granular sampling: a compression group's four lines span four
    /// consecutive LLC sets, so cost/benefit attribution must be decided
    /// per *group* (all four lines agree), not per line's set.  This is
    /// the **only** sampling predicate — a former set-granular variant
    /// (`is_sampled_set`) could disagree with this one for the same line
    /// (set index = line mod sets, group index = line / 4), which let
    /// cost/benefit events be recorded for sets whose group was never in
    /// the sampled population.
    #[inline]
    pub fn is_sampled_group(group: u64) -> bool {
        group % SAMPLE_MOD == 0
    }

    /// Bandwidth-cost event observed on a sampled set.
    #[inline]
    pub fn on_cost(&mut self, core: usize) {
        self.cost_events[core] += 1;
        let c = &mut self.counters[core];
        *c = (*c - 1).max(0);
    }

    /// Bandwidth-benefit event observed on a sampled set.
    #[inline]
    pub fn on_benefit(&mut self, core: usize) {
        self.benefit_events[core] += 1;
        let max = self.max();
        let c = &mut self.counters[core];
        *c = (*c + 1).min(max);
    }

    /// Should the non-sampled sets compress for this core?
    ///
    /// The paper gates on the counter MSB.  At simulation scale a single
    /// threshold makes borderline workloads oscillate every few sampled
    /// events, and each flip pays real unpack/repack traffic; we add a
    /// hysteresis band around the MSB (enable at 3/4, disable at 1/4 of
    /// the range) — the natural scaled-slice reading of the MSB rule,
    /// since the paper's 12-bit counter makes flips ~1000x rarer.
    #[inline]
    pub fn enabled(&self, core: usize) -> bool {
        // the tenant bias slides the whole band (clamped inside the
        // counter range so both thresholds stay reachable); bias == 0
        // reproduces the unbiased thresholds exactly
        let b = self.bias[core];
        let hi = (3 * (1 << (self.bits - 2)) - b).clamp(1, self.max());
        let lo = ((1 << (self.bits - 2)) - b).clamp(0, self.max() - 1);
        let c = self.counters[core];
        if c >= hi {
            self.state[core].set(true);
        } else if c < lo {
            self.state[core].set(false);
        }
        self.state[core].get()
    }

    pub fn counter(&self, core: usize) -> i32 {
        self.counters[core]
    }

    /// Storage cost of the counters (paper Table III: 12 bytes — eight
    /// 12-bit counters).
    pub fn storage_bytes(&self) -> u32 {
        (self.counters.len() as u32 * self.bits).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_enabled() {
        let d = DynamicCram::new(8);
        for c in 0..8 {
            assert!(d.enabled(c));
        }
    }

    #[test]
    fn costs_disable_benefits_reenable() {
        let mut d = DynamicCram::new(1);
        d.on_cost(0);
        assert!(d.enabled(0), "hysteresis: one cost does not flip the gate");
        // long cost streak: disabled and saturates at 0
        for _ in 0..10_000 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0));
        assert_eq!(d.counter(0), 0);
        // needs a sustained benefit streak to flip back (3/4 of range)
        for _ in 0..3 * 1024 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0));
    }

    #[test]
    fn hysteresis_band_prevents_flapping() {
        let mut d = DynamicCram::with_bits(1, 6); // range 0..63, lo=16 hi=48
        // drive to the middle repeatedly: state must not change
        for _ in 0..40 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0)); // hit 0 -> disabled... counter back up:
        for _ in 0..40 {
            d.on_benefit(0);
        }
        // at 40 (between lo and hi): stays disabled
        assert!(!d.enabled(0), "mid-band keeps prior state");
        for _ in 0..10 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0), "crossing hi enables");
        for _ in 0..20 {
            d.on_cost(0);
        }
        // back to mid-band: stays enabled
        assert!(d.enabled(0), "mid-band keeps prior state (enabled)");
    }

    #[test]
    fn zero_bias_is_bit_identical() {
        // a set_bias(0) gate must reproduce the stock gate exactly
        // through an adversarial mid-band walk, not just statistically
        let mut plain = DynamicCram::with_bits(1, 6);
        let mut biased = DynamicCram::with_bits(1, 6);
        biased.set_bias(0, 0);
        for i in 0..500u64 {
            if i % 3 == 0 {
                plain.on_benefit(0);
                biased.on_benefit(0);
            } else {
                plain.on_cost(0);
                biased.on_cost(0);
            }
            assert_eq!(plain.enabled(0), biased.enabled(0), "step {i}");
            assert_eq!(plain.counter(0), biased.counter(0), "step {i}");
        }
    }

    #[test]
    fn bias_shifts_the_hysteresis_band() {
        // bits=6: range 0..63, start 32, stock band lo=16 / hi=48
        let mut stock = DynamicCram::with_bits(1, 6);
        let mut tolerant = DynamicCram::with_bits(1, 6);
        tolerant.set_bias(0, 8); // lo=8: compression-friendly tenant
        let mut strict = DynamicCram::with_bits(1, 6);
        strict.set_bias(0, -8); // lo=24: latency-friendly tenant
        for _ in 0..9 {
            stock.on_cost(0);
            tolerant.on_cost(0);
            strict.on_cost(0);
        }
        // counter 23: only the negative bias has closed its gate
        assert!(stock.enabled(0));
        assert!(tolerant.enabled(0));
        assert!(!strict.enabled(0), "negative bias closes sooner");
        for _ in 0..8 {
            stock.on_cost(0);
            tolerant.on_cost(0);
        }
        // counter 15: the stock gate closes, the positive bias holds
        assert!(!stock.enabled(0));
        assert!(tolerant.enabled(0), "positive bias absorbs more cost");
    }

    #[test]
    fn saturates_high() {
        let mut d = DynamicCram::new(1);
        for _ in 0..10_000 {
            d.on_benefit(0);
        }
        assert_eq!(d.counter(0), 4095);
    }

    #[test]
    fn per_core_isolation() {
        let mut d = DynamicCram::new(2);
        for _ in 0..4000 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0));
        assert!(d.enabled(1), "core 1 unaffected by core 0's costs");
    }

    #[test]
    fn sampled_groups_are_about_one_percent() {
        let sampled = (0..8192u64)
            .filter(|&g| DynamicCram::is_sampled_group(g))
            .count();
        assert_eq!(sampled, 8192 / SAMPLE_MOD as usize);
    }

    #[test]
    fn sampling_is_consistent_across_a_group() {
        // every line of a group must get the same sampling verdict: the
        // predicate is a function of the group index alone, so the four
        // members (which span four consecutive LLC sets) always agree
        use crate::mem::{group_base, group_of};
        for line in 0..4096u64 {
            let verdicts: Vec<bool> = (0..4u64)
                .map(|s| DynamicCram::is_sampled_group(group_of(group_base(line) + s)))
                .collect();
            assert!(
                verdicts.iter().all(|&v| v == verdicts[0]),
                "line {line}: group members disagree: {verdicts:?}"
            );
            assert_eq!(DynamicCram::is_sampled_group(group_of(line)), verdicts[0]);
        }
    }

    #[test]
    #[should_panic(expected = "counter width must be 2..=30")]
    fn one_bit_counter_fails_fast() {
        // 1 << (bits - 2) underflows for bits < 2; construction must
        // reject it instead of producing a corrupt hysteresis band
        let _ = DynamicCram::with_bits(1, 1);
    }

    #[test]
    #[should_panic(expected = "counter width must be 2..=30")]
    fn zero_bit_counter_fails_fast() {
        let _ = DynamicCram::with_bits(4, 0);
    }

    #[test]
    fn two_bit_counter_is_the_smallest_valid_width() {
        let mut d = DynamicCram::with_bits(1, 2); // range 0..3, lo=1 hi=3
        assert!(d.enabled(0), "starts at the enable threshold");
        d.on_cost(0);
        d.on_cost(0);
        assert!(!d.enabled(0), "counter 0 < lo disables");
        for _ in 0..3 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0), "counter 3 >= hi re-enables");
    }

    #[test]
    fn storage_overhead_table3() {
        // 8 cores * 12 bits = 12 bytes
        assert_eq!(DynamicCram::new(8).storage_bytes(), 12);
    }

    #[test]
    fn scaled_counter_flips_faster() {
        let mut d = DynamicCram::with_bits(1, 8);
        for _ in 0..300 {
            d.on_cost(0);
        }
        assert!(!d.enabled(0));
        for _ in 0..200 {
            d.on_benefit(0);
        }
        assert!(d.enabled(0), "8-bit counter recovers in ~192 benefits");
    }
}
