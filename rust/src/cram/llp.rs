//! Line Location Predictor (paper §V-B, Fig. 13).
//!
//! Lines within a page tend to have similar compressibility, so a tiny
//! *Last Compressibility Table* (LCT) indexed by a hash of the page address
//! predicts a line's CSI — and therefore its location — with ~98% accuracy.
//! 512 entries × 2 bits ≈ 128 bytes (Table III).
//!
//! The predictor is consulted only when a line actually has location
//! uncertainty (slot A never moves).  On a misprediction the controller
//! re-issues to the next possible location ([`group::possible_locations`]);
//! the implicit-metadata markers verify every guess, which is what makes a
//! *memory-side* location predictor sound (caches verify via tags; memory
//! has no tags — §VIII-E).

use crate::cram::group::Csi;
use crate::util::rng::splitmix64;

/// Prediction statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct LlpStats {
    pub predictions: u64,
    pub correct: u64,
    pub no_prediction_needed: u64,
}

impl LlpStats {
    pub fn accuracy(&self) -> f64 {
        if self.predictions == 0 {
            1.0
        } else {
            self.correct as f64 / self.predictions as f64
        }
    }
}

/// The Line Location Predictor.
#[derive(Clone, Debug)]
pub struct LineLocationPredictor {
    /// Last CSI seen per page-hash bucket.
    lct: Vec<Csi>,
    key: u64,
    pub stats: LlpStats,
}

impl Default for LineLocationPredictor {
    fn default() -> Self {
        Self::new(512, 0xD1CE)
    }
}

impl LineLocationPredictor {
    pub fn new(entries: usize, key: u64) -> Self {
        assert!(entries.is_power_of_two());
        Self {
            lct: vec![Csi::Uncompressed; entries],
            key,
            stats: LlpStats::default(),
        }
    }

    #[inline]
    fn index(&self, page: u64) -> usize {
        (splitmix64(self.key, page) as usize) & (self.lct.len() - 1)
    }

    /// Predict the group CSI for a line in `page`.
    #[inline]
    pub fn predict(&self, page: u64) -> Csi {
        self.lct[self.index(page)]
    }

    /// Predict the physical location for a line at `slot` of its group.
    /// Returns (predicted location, whether a real prediction was needed).
    pub fn predict_location(&mut self, page: u64, slot: u8) -> (u8, bool) {
        if slot == 0 {
            // A never moves: no uncertainty, LCT not consulted.
            self.stats.no_prediction_needed += 1;
            return (0, false);
        }
        self.stats.predictions += 1;
        (self.predict(page).location(slot), true)
    }

    /// Train with the actual CSI discovered by the read/write path.
    pub fn update(&mut self, page: u64, actual: Csi) {
        let idx = self.index(page);
        self.lct[idx] = actual;
    }

    /// Record whether a needed prediction turned out correct.
    pub fn record_outcome(&mut self, correct: bool) {
        if correct {
            self.stats.correct += 1;
        }
    }

    /// Storage cost (paper Table III: 128 bytes for 512 entries).
    pub fn storage_bytes(&self) -> u32 {
        // 2 bits per entry is enough for the location-relevant state; the
        // paper provisions 128B for 512 entries.
        (self.lct.len() as u32 * 2).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_a_needs_no_prediction() {
        let mut llp = LineLocationPredictor::default();
        let (loc, needed) = llp.predict_location(123, 0);
        assert_eq!(loc, 0);
        assert!(!needed);
        assert_eq!(llp.stats.predictions, 0);
        assert_eq!(llp.stats.no_prediction_needed, 1);
    }

    #[test]
    fn learns_page_compressibility() {
        let mut llp = LineLocationPredictor::default();
        llp.update(77, Csi::Quad);
        assert_eq!(llp.predict(77), Csi::Quad);
        // B predicted at location 0 under Quad
        let (loc, needed) = llp.predict_location(77, 1);
        assert_eq!(loc, 0);
        assert!(needed);
    }

    #[test]
    fn distinct_pages_mostly_distinct_buckets() {
        let llp = LineLocationPredictor::default();
        let mut collisions = 0;
        for p in 0..512u64 {
            if llp.index(p) == llp.index(p + 10_000) {
                collisions += 1;
            }
        }
        // hash collisions exist but must not be systematic
        assert!(collisions < 32, "collisions={collisions}");
    }

    #[test]
    fn accuracy_accounting() {
        let mut llp = LineLocationPredictor::default();
        llp.predict_location(1, 1);
        llp.record_outcome(true);
        llp.predict_location(1, 2);
        llp.record_outcome(false);
        assert_eq!(llp.stats.predictions, 2);
        assert!((llp.stats.accuracy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn storage_overhead_table3() {
        assert_eq!(LineLocationPredictor::default().storage_bytes(), 128);
    }
}
