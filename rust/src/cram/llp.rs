//! Line Location Predictor (paper §V-B, Fig. 13).
//!
//! Lines within a page tend to have similar compressibility, so a tiny
//! *Last Compressibility Table* (LCT) indexed by a hash of the page address
//! predicts a line's CSI — and therefore its location — with ~98% accuracy.
//!
//! **Storage accounting.**  Table III provisions 2 bits per entry (128
//! bytes for 512 entries), but the CSI has five states
//! (`Uncompressed..Quad`) and all five are location-relevant: collapsing
//! `Quad` into `PairBoth` mispredicts slots C and D, so a genuinely 2-bit
//! entry cannot round-trip the layouts the predictor must distinguish.
//! The LCT therefore stores the canonical 3-bit CSI encoding (shared with
//! the explicit-metadata region) and [`storage_bytes`] accounts 3 bits per
//! entry honestly: 512 entries ≈ 192 bytes.
//!
//! The predictor is consulted only when a line actually has location
//! uncertainty (slot A never moves).  On a misprediction the controller
//! re-issues to the next possible location ([`group::possible_locations`]);
//! the implicit-metadata markers verify every guess, which is what makes a
//! *memory-side* location predictor sound (caches verify via tags; memory
//! has no tags — §VIII-E).
//!
//! [`storage_bytes`]: LineLocationPredictor::storage_bytes

use crate::cram::group::Csi;
use crate::util::rng::splitmix64;

/// Bits per LCT entry: the canonical CSI encoding.  Five states need
/// three bits; two (the paper's Table III claim) cannot round-trip them.
pub const LCT_ENTRY_BITS: u32 = 3;

/// Prediction statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct LlpStats {
    pub predictions: u64,
    pub correct: u64,
    pub no_prediction_needed: u64,
}

impl LlpStats {
    /// Fraction of needed predictions that were correct, or `None` when
    /// the LCT was never consulted (a run with no location uncertainty
    /// has no accuracy to report — figures print it as "n/a", not 100%).
    pub fn accuracy(&self) -> Option<f64> {
        if self.predictions == 0 {
            None
        } else {
            Some(self.correct as f64 / self.predictions as f64)
        }
    }
}

/// The Line Location Predictor.
#[derive(Clone, Debug)]
pub struct LineLocationPredictor {
    /// Last CSI seen per page-hash bucket, stored through the 3-bit
    /// canonical encoding (every `Csi` round-trips — tested below).
    lct: Vec<u8>,
    key: u64,
    pub stats: LlpStats,
}

impl Default for LineLocationPredictor {
    fn default() -> Self {
        Self::new(512, 0xD1CE)
    }
}

impl LineLocationPredictor {
    pub fn new(entries: usize, key: u64) -> Self {
        assert!(entries.is_power_of_two());
        Self {
            lct: vec![Self::encode(Csi::Uncompressed); entries],
            key,
            stats: LlpStats::default(),
        }
    }

    /// The LCT entry encoding: the canonical 3-bit CSI discriminant.
    #[inline]
    fn encode(csi: Csi) -> u8 {
        csi as u8
    }

    #[inline]
    fn decode(v: u8) -> Csi {
        Csi::from_u8(v).expect("LCT holds canonical CSI encodings")
    }

    #[inline]
    fn index(&self, page: u64) -> usize {
        (splitmix64(self.key, page) as usize) & (self.lct.len() - 1)
    }

    /// Predict the group CSI for a line in `page`.
    #[inline]
    pub fn predict(&self, page: u64) -> Csi {
        Self::decode(self.lct[self.index(page)])
    }

    /// Predict the physical location for a line at `slot` of its group.
    /// Returns (predicted location, whether a real prediction was needed).
    pub fn predict_location(&mut self, page: u64, slot: u8) -> (u8, bool) {
        if slot == 0 {
            // A never moves: no uncertainty, LCT not consulted.
            self.stats.no_prediction_needed += 1;
            return (0, false);
        }
        self.stats.predictions += 1;
        (self.predict(page).location(slot), true)
    }

    /// Train with the actual CSI discovered by the read/write path.
    pub fn update(&mut self, page: u64, actual: Csi) {
        let idx = self.index(page);
        self.lct[idx] = Self::encode(actual);
    }

    /// Record whether a needed prediction turned out correct.  Must pair
    /// with a prior `predict_location` that consulted the LCT — `correct`
    /// can never exceed `predictions`.
    pub fn record_outcome(&mut self, correct: bool) {
        if correct {
            assert!(
                self.stats.correct < self.stats.predictions,
                "record_outcome on a no-prediction path: correct would exceed predictions"
            );
            self.stats.correct += 1;
        }
    }

    /// Storage cost: 3 bits per entry (512 entries ≈ 192 bytes; the
    /// paper's Table III claims 128B at 2 bits, which cannot encode the
    /// five CSI states — see the module doc).
    pub fn storage_bytes(&self) -> u32 {
        (self.lct.len() as u32 * LCT_ENTRY_BITS).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_a_needs_no_prediction() {
        let mut llp = LineLocationPredictor::default();
        let (loc, needed) = llp.predict_location(123, 0);
        assert_eq!(loc, 0);
        assert!(!needed);
        assert_eq!(llp.stats.predictions, 0);
        assert_eq!(llp.stats.no_prediction_needed, 1);
    }

    #[test]
    fn learns_page_compressibility() {
        let mut llp = LineLocationPredictor::default();
        llp.update(77, Csi::Quad);
        assert_eq!(llp.predict(77), Csi::Quad);
        // B predicted at location 0 under Quad
        let (loc, needed) = llp.predict_location(77, 1);
        assert_eq!(loc, 0);
        assert!(needed);
    }

    #[test]
    fn every_csi_round_trips_through_the_lct() {
        let mut llp = LineLocationPredictor::default();
        for csi in Csi::ALL {
            llp.update(42, csi);
            assert_eq!(llp.predict(42), csi, "{csi:?} must survive store/load");
            // and the stored encoding fits the 3-bit budget
            assert!(
                (csi as u8) < (1 << LCT_ENTRY_BITS),
                "{csi:?} exceeds {LCT_ENTRY_BITS} bits"
            );
        }
    }

    #[test]
    fn distinct_pages_mostly_distinct_buckets() {
        let llp = LineLocationPredictor::default();
        let mut collisions = 0;
        for p in 0..512u64 {
            if llp.index(p) == llp.index(p + 10_000) {
                collisions += 1;
            }
        }
        // hash collisions exist but must not be systematic
        assert!(collisions < 32, "collisions={collisions}");
    }

    #[test]
    fn accuracy_accounting() {
        let mut llp = LineLocationPredictor::default();
        llp.predict_location(1, 1);
        llp.record_outcome(true);
        llp.predict_location(1, 2);
        llp.record_outcome(false);
        assert_eq!(llp.stats.predictions, 2);
        assert!((llp.stats.accuracy().unwrap() - 0.5).abs() < 1e-12);
        assert!(llp.stats.correct <= llp.stats.predictions);
    }

    #[test]
    fn accuracy_is_none_when_lct_never_consulted() {
        let mut llp = LineLocationPredictor::default();
        assert_eq!(llp.stats.accuracy(), None, "no predictions => n/a, not 100%");
        // slot-A traffic alone never consults the LCT
        llp.predict_location(9, 0);
        assert_eq!(llp.stats.accuracy(), None);
    }

    #[test]
    #[should_panic(expected = "no-prediction path")]
    fn record_outcome_without_prediction_panics() {
        let mut llp = LineLocationPredictor::default();
        llp.predict_location(9, 0); // slot A: no prediction consumed
        llp.record_outcome(true); // nothing to credit: correct > predictions
    }

    #[test]
    fn correct_never_exceeds_predictions_under_mixed_traffic() {
        let mut llp = LineLocationPredictor::default();
        for i in 0..200u64 {
            let slot = (i % 4) as u8;
            let (_, needed) = llp.predict_location(i, slot);
            if needed {
                llp.record_outcome(i % 3 == 0);
            }
            assert!(llp.stats.correct <= llp.stats.predictions);
        }
    }

    #[test]
    fn storage_overhead_three_bits_per_entry() {
        // 512 entries * 3 bits = 192 bytes (Table III's 128B claim cannot
        // round-trip the five CSI states)
        assert_eq!(LineLocationPredictor::default().storage_bytes(), 192);
        assert_eq!(LineLocationPredictor::new(64, 1).storage_bytes(), 24);
    }
}
