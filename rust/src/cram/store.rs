//! Byte-accurate compressed physical memory.
//!
//! This substrate holds the *actual bytes* resident in DRAM under CRAM:
//! packed hybrid bitstreams with markers in their tails, invalid-line
//! markers in stale slots, and inverted collision victims.  The memory
//! controllers drive it; its invariants are the paper's correctness
//! argument:
//!
//! 1. every physical line whose tail matches a marker is either genuinely
//!    compressed or tracked by the LIT;
//! 2. a read of any logical line — through prediction, misprediction and
//!    re-issue — always returns the last value written;
//! 3. stale locations always hold Marker-IL (never interpretable as data).
//!
//! `rust/tests/` property-tests all three.
//!
//! **Hot-path layout** (DESIGN.md §Simulation performance): the physical
//! lines and per-group CSI live in [`PagedArena`]s — O(1) shifted-address
//! indexing, no hashing, and a 4-line group contiguous in one page — and
//! per-access results travel in fixed inline vectors, so neither reads
//! nor group writes allocate.  A per-line compressibility memo
//! (content fingerprint → hybrid size, refreshed whenever a write changes
//! the content) lets [`CompressedStore::write_group_auto`] skip
//! recompressing the unmodified lines of a group on every dirty eviction.

use crate::compress::{hybrid, PACK_BUDGET};
use crate::controller::lcp::{EXC_CAP, PAGE_LINES, TARGETS};
use crate::controller::{CramEngine, LayoutEngine, LcpLayout, LinkCodec, PageDesc};
use crate::cram::group::Csi;
use crate::cram::lit::{LineInversionTable, LitInsert};
use crate::cram::marker::{LineKind, MarkerEngine};
use crate::mem::{group_base, group_of, CacheLine, PagedArena, GROUP_LINES};
use crate::util::small::InlineVec;

/// Physical locations touched by a group write (≤ 4, inline).
pub type WrittenLocs = InlineVec<u64, 4>;

/// Logical lines recovered by one physical access (≤ 4, inline).
pub type RecoveredLines = InlineVec<(u64, CacheLine), 4>;

/// Result of interpreting a physical read.
#[derive(Clone, Copy, Debug)]
pub struct Interpreted {
    pub kind: LineKind,
    /// Logical (line_addr, data) pairs recovered from this access.
    pub lines: RecoveredLines,
    /// Whether the LIT had to be consulted (complement match).
    pub lit_checked: bool,
}

/// Byte-accurate physical memory with CRAM packing.
pub struct CompressedStore {
    /// Physical contents by line address (paged arena; unwritten = zeros).
    phys: PagedArena<CacheLine>,
    pub markers: MarkerEngine,
    pub lit: LineInversionTable,
    /// Ground-truth layout (what a perfect metadata store would hold) —
    /// the shared [`LayoutEngine`] is the store's layout authority, the
    /// same seam the host controller and the far-tier expander run;
    /// this store adds the byte-accurate substrate on top.  Group
    /// writes drive the CRAM family; [`Self::lcp_write_page`] drives
    /// the page family.
    layout: LayoutEngine,
    /// Compressibility memo: line address → (content fingerprint, hybrid
    /// size).  A hit whose fingerprint matches the incoming data skips the
    /// compressor stack entirely.
    memo: PagedArena<(u64, u8)>,
    /// Memo diagnostics (hits = compressor passes avoided).
    pub memo_hits: u64,
    pub memo_misses: u64,
    /// Detected marker-tail corruptions since the last re-key (the error
    /// signal feeding [`CompressedStore::note_marker_error`]'s cure).
    marker_errors_since_rekey: u32,
}

impl CompressedStore {
    pub fn new(seed: u64) -> Self {
        Self::with_link_codec(seed, LinkCodec::Raw)
    }

    /// Store whose layout engine carries the design's link codec — the
    /// same plumbing the host controller and far-tier expander use, so a
    /// byte-accurate run can answer wire-size questions consistently.
    pub fn with_link_codec(seed: u64, link_codec: LinkCodec) -> Self {
        Self::with_layout(seed, LayoutEngine::Cram(CramEngine::with_link_codec(link_codec)))
    }

    /// Store running the page family: group writes are replaced by
    /// [`Self::lcp_write_page`] / [`Self::lcp_read_line`], and reads
    /// never interpret markers (LCP's metadata is explicit).
    pub fn lcp(seed: u64, link_codec: LinkCodec) -> Self {
        Self::with_layout(seed, LayoutEngine::Lcp(LcpLayout::with_link_codec(link_codec)))
    }

    fn with_layout(seed: u64, layout: LayoutEngine) -> Self {
        Self {
            phys: PagedArena::new(CacheLine::zero()),
            markers: MarkerEngine::new(seed),
            lit: LineInversionTable::default(),
            layout,
            memo: PagedArena::new((0, 0)),
            memo_hits: 0,
            memo_misses: 0,
            marker_errors_since_rekey: 0,
        }
    }

    /// Detected marker corruptions that trigger the re-key cure.  Low
    /// enough that a persistently noisy medium rotates keys promptly,
    /// high enough that an isolated upset doesn't pay the re-encode sweep.
    pub const REKEY_ERROR_THRESHOLD: u32 = 16;

    /// Feed the marker-error signal: a corrupted marker tail was detected
    /// (classification disagreed with the layout authority).  Every
    /// [`Self::REKEY_ERROR_THRESHOLD`] detections the keys are
    /// regenerated and the memory re-encoded — the paper's Option-2 cure
    /// wired to an actual error signal instead of only LIT overflow.
    /// Returns whether this detection tripped a re-key.
    pub fn note_marker_error(&mut self) -> bool {
        self.marker_errors_since_rekey += 1;
        if self.marker_errors_since_rekey >= Self::REKEY_ERROR_THRESHOLD {
            self.marker_errors_since_rekey = 0;
            self.rekey_and_reencode();
            true
        } else {
            false
        }
    }

    /// Cross-check the marker classification of physical location `loc`
    /// against the ground-truth layout — the detection predicate of the
    /// reliability subsystem.  `false` means the stored tail no longer
    /// says what the layout authority knows is there: a detectable
    /// marker corruption.
    pub fn classification_matches_layout(&self, loc: u64) -> bool {
        let phys = self.read_phys(loc);
        let kind = self.markers.classify(loc, &phys);
        let base = group_base(loc);
        let slot = (loc - base) as u8;
        let csi = self.csi_of(base);
        match kind {
            LineKind::Compressed2 => csi.colocated(slot).len() == 2,
            LineKind::Compressed4 => csi.colocated(slot).len() == 4,
            LineKind::Invalid => csi.is_stale(slot),
            LineKind::NeedsLitCheck | LineKind::Uncompressed => csi.colocated(slot).len() == 1,
        }
    }

    /// Fault-injection hook for byte-accurate corruption tests: flip one
    /// bit of the stored tail word at `loc` (where the markers live).
    pub fn corrupt_tail_bit(&mut self, loc: u64, bit: u32) {
        let mut line = self.read_phys(loc);
        line.set_tail_u32(line.tail_u32() ^ (1 << (bit % 32)));
        self.phys.insert(loc, line);
    }

    /// Bytes a transfer of physical location `loc` puts on the link under
    /// the store's codec.  Byte-accurate where the timing model uses the
    /// size oracle: under [`LinkCodec::Compressed`] the payload is the
    /// line's actual hybrid-compressed size (full width when the content
    /// is incompressible or the location holds a packed bitstream, which
    /// already fills the line).
    pub fn wire_bytes_of(&mut self, loc: u64) -> u64 {
        if self.layout.link_codec() == LinkCodec::Raw {
            return 64;
        }
        let csi = self.csi_of(loc);
        let slot = (loc - group_base(loc)) as u8;
        if csi.colocated(slot).len() != 1 {
            return 64; // packed bitstream (already at the pack budget) or IL
        }
        let line = self.read_phys(loc);
        u64::from(self.memo_size(loc, &line)).min(64)
    }

    /// Ground-truth CSI of the group containing `line` (tests/baselines).
    pub fn csi_of(&self, line: u64) -> Csi {
        self.layout.csi_of_line(line)
    }

    /// Raw physical line at `loc` (what the DRAM bus would deliver).
    pub fn read_phys(&self, loc: u64) -> CacheLine {
        self.phys.copied_or_default(loc)
    }

    /// Hybrid size of `line` destined for `line_addr`, via the memo: the
    /// compressor stack only runs when the content actually changed since
    /// the last write to this address.
    fn memo_size(&mut self, line_addr: u64, line: &CacheLine) -> u32 {
        let fp = line.fingerprint();
        if let Some(&(f, s)) = self.memo.get(line_addr) {
            if f == fp {
                self.memo_hits += 1;
                return s as u32;
            }
        }
        self.memo_misses += 1;
        let s = hybrid::compressed_size(line);
        self.memo.insert(line_addr, (fp, s as u8));
        s
    }

    /// Write one *uncompressed* logical line to its own slot, handling
    /// marker collisions by inversion (paper Fig. 10).
    fn write_raw(&mut self, loc: u64, line: CacheLine) {
        if self.markers.collides(loc, &line) {
            let outcome = self.lit.insert(loc);
            if outcome == LitInsert::Overflow && !self.lit.contains(loc) {
                // Option-2 environment (no memory-mapped region): re-key and
                // rewrite everything that was inverted.  Extremely rare.
                self.rekey_and_reencode();
                // after re-keying, the line may no longer collide
                return self.write_raw(loc, line);
            }
            self.phys.insert(loc, line.inverted());
        } else {
            // If the line previously collided and no longer does, retire
            // the LIT entry (paper: "on a write ... remove from the LIT").
            if self.lit.contains(loc) {
                self.lit.remove(loc);
            }
            self.phys.insert(loc, line);
        }
    }

    /// Option-2 overflow cure: regenerate markers, re-encode affected
    /// lines.  In hardware this is a background sweep; functionally we
    /// only need to fix inverted lines (their stored form must keep
    /// matching a complement) — with fresh keys nothing collides any more
    /// with overwhelming probability, so we simply revert them.
    fn rekey_and_reencode(&mut self) {
        let inverted: Vec<u64> = self
            .phys
            .keys()
            .filter(|l| self.lit.contains(*l))
            .collect();
        for loc in &inverted {
            if let Some(line) = self.phys.get(*loc).copied() {
                self.phys.insert(*loc, line.inverted()); // revert to raw
            }
        }
        self.lit.clear();
        self.markers.rekey();
        // Re-encode the memory under the new keys (paper Option-2): stale
        // slots get the fresh Marker-IL, and packed blocks get their tails
        // re-stamped with the fresh 2:1 / 4:1 markers (payload unchanged).
        let groups: Vec<(u64, Csi)> = self.groups().collect();
        for (g, csi) in groups {
            for loc_slot in 0..GROUP_LINES as u8 {
                let loc = g + loc_slot as u64;
                if csi.is_stale(loc_slot) {
                    self.phys.insert(loc, self.markers.marker_il(loc));
                } else if csi.is_compressed_at(loc_slot) {
                    let mut phys = *self.phys.get(loc).expect("packed block exists");
                    let n = csi.colocated(loc_slot).len();
                    let marker = if n == 4 {
                        self.markers.marker4(loc)
                    } else {
                        self.markers.marker2(loc)
                    };
                    phys.set_tail_u32(marker);
                    self.phys.insert(loc, phys);
                }
            }
        }
    }

    /// Pack and write a whole group (ganged eviction delivers all four
    /// lines).  `lines[i]` is the data of logical slot i.  Returns the
    /// physical locations written (for bandwidth accounting): live slots +
    /// newly-stale slots that needed a Marker-IL write.
    pub fn write_group(
        &mut self,
        base_line: u64,
        lines: &[CacheLine; 4],
        csi: Csi,
    ) -> WrittenLocs {
        debug_assert_eq!(base_line % GROUP_LINES, 0);
        let prev_csi = self.csi_of(base_line);
        let mut written = WrittenLocs::new();

        for loc_slot in 0..GROUP_LINES as u8 {
            let loc = base_line + loc_slot as u64;
            let residents = csi.colocated(loc_slot);
            match residents.len() {
                0 => {
                    // Stale under the new layout: invalidate if it held
                    // live data before (avoid rewriting IL repeatedly).
                    if !prev_csi.is_stale(loc_slot) || !self.phys.contains(loc) {
                        self.phys.insert(loc, self.markers.marker_il(loc));
                        written.push(loc);
                    }
                }
                1 => {
                    self.write_raw(loc, lines[residents[0] as usize]);
                    written.push(loc);
                }
                n => {
                    // Packed slot: concatenate payloads, pad, stamp marker.
                    let mut bytes = Vec::with_capacity(64);
                    for &s in residents {
                        let c = hybrid::encode(&lines[s as usize])
                            .expect("CSI decision guarantees compressibility");
                        bytes.extend_from_slice(&c.bytes);
                    }
                    debug_assert!(bytes.len() as u32 <= PACK_BUDGET);
                    bytes.resize(60, 0);
                    let marker = if n == 4 {
                        self.markers.marker4(loc)
                    } else {
                        self.markers.marker2(loc)
                    };
                    bytes.extend_from_slice(&marker.to_le_bytes());
                    let mut arr = [0u8; 64];
                    arr.copy_from_slice(&bytes);
                    let phys_line = CacheLine::from_bytes(&arr);
                    // A packed line's tail IS the marker; no collision
                    // handling needed, but retire any stale LIT entry.
                    if self.lit.contains(loc) {
                        self.lit.remove(loc);
                    }
                    self.phys.insert(loc, phys_line);
                    written.push(loc);
                }
            }
        }
        self.layout.record(group_of(base_line), csi);
        written
    }

    /// Convenience: compress-and-write a group from its four lines using
    /// the canonical CSI decision.  Sizes come through the per-line memo,
    /// so re-evicting a group with (say) one dirtied line re-runs the
    /// compressor stack on that line only.
    pub fn write_group_auto(
        &mut self,
        base_line: u64,
        lines: &[CacheLine; 4],
    ) -> (Csi, WrittenLocs) {
        let sizes: [u32; 4] =
            core::array::from_fn(|i| self.memo_size(base_line + i as u64, &lines[i]));
        let csi = Csi::from_sizes(sizes);
        let written = self.write_group(base_line, lines, csi);
        (csi, written)
    }

    /// Read physical location `loc` and interpret it via markers (the CRAM
    /// read path, §V-A).  Returns every logical line recoverable from this
    /// single access.
    pub fn read_interpret(&mut self, loc: u64) -> Interpreted {
        let phys = self.read_phys(loc);
        let kind = self.markers.classify(loc, &phys);
        match kind {
            LineKind::Compressed2 | LineKind::Compressed4 => {
                let n = if kind == LineKind::Compressed4 { 4 } else { 2 };
                let bytes = phys.to_bytes();
                let base = group_base(loc);
                let loc_slot = (loc - base) as u8;
                // Which logical slots live here follows from the layout:
                // slot0 holds [A,B] (2:1) or [A,B,C,D] (4:1); slot2 holds
                // [C,D].
                let first_slot = if loc_slot == 0 { 0u8 } else { 2 };
                let mut lines = RecoveredLines::new();
                let mut off = 0usize;
                for k in 0..n {
                    let (line, used) = hybrid::decode_prefix(&bytes[off..]);
                    lines.push((base + (first_slot + k as u8) as u64, line));
                    off += used;
                }
                Interpreted { kind, lines, lit_checked: false }
            }
            LineKind::Invalid => Interpreted {
                kind,
                lines: RecoveredLines::new(),
                lit_checked: false,
            },
            LineKind::NeedsLitCheck => {
                let (inverted, _how) = self.lit.query(loc);
                let data = if inverted { phys.inverted() } else { phys };
                Interpreted {
                    kind,
                    lines: RecoveredLines::of(&[(loc, data)]),
                    lit_checked: true,
                }
            }
            LineKind::Uncompressed => Interpreted {
                kind,
                lines: RecoveredLines::of(&[(loc, phys)]),
                lit_checked: false,
            },
        }
    }

    /// Full logical read of `line_addr` the way the controller would do it
    /// given a location prediction: probe `predicted_loc` first, then the
    /// remaining possible locations.  Returns (data, accesses, all lines
    /// recovered on the successful access).
    pub fn read_line(
        &mut self,
        line_addr: u64,
        predicted_loc: u64,
    ) -> (CacheLine, u32, RecoveredLines) {
        let base = group_base(line_addr);
        let slot = (line_addr - base) as u8;
        // Probe the prediction first, then every remaining possible
        // location — the same walk the host controller issues, from the
        // shared engine.
        debug_assert!(predicted_loc >= base && predicted_loc < base + GROUP_LINES);
        let probes = CramEngine::probe_order(slot, (predicted_loc - base) as u8);
        let mut accesses = 0u32;
        for &p in probes.iter() {
            let probe = base + p as u64;
            accesses += 1;
            let interp = self.read_interpret(probe);
            if let Some((_, data)) = interp.lines.iter().find(|(a, _)| *a == line_addr) {
                return (*data, accesses, interp.lines);
            }
        }
        // Exhausted: line was never written — fresh memory reads zero.
        (CacheLine::zero(), accesses, RecoveredLines::new())
    }

    /// Byte-accurate LCP page write (the page family's analog of
    /// [`Self::write_group`]).  Targets are chosen from the *actual*
    /// hybrid compressed sizes (through the per-line memo): the
    /// smallest `T` whose overflow set fits the exception region, else
    /// raw.  Fitting slots are encoded into `T`-byte sub-slots at byte
    /// offset `(slot × T) mod 64` of physical line
    /// `page_base + (slot × T) / 64`; exceptions land raw after the
    /// data region in rank order.  The resulting descriptor is
    /// registered with the layout authority and returned.
    pub fn lcp_write_page(
        &mut self,
        page: u64,
        lines: &[CacheLine; PAGE_LINES as usize],
    ) -> PageDesc {
        let base = page * PAGE_LINES;
        let sizes: [u32; PAGE_LINES as usize] =
            core::array::from_fn(|s| self.memo_size(base + s as u64, &lines[s]));
        let mut desc = PageDesc { target: 64, exceptions: 0 };
        for &t in TARGETS.iter() {
            if u64::from(t) >= 64 {
                break; // raw: every line fits trivially
            }
            let mut exc = 0u64;
            for (s, &size) in sizes.iter().enumerate() {
                if size > u32::from(t) {
                    exc |= 1u64 << s;
                }
            }
            if exc.count_ones() <= EXC_CAP {
                desc = PageDesc { target: t, exceptions: exc };
                break;
            }
        }
        if u64::from(desc.target) >= 64 {
            for s in 0..PAGE_LINES as usize {
                self.phys.insert(base + s as u64, lines[s]);
            }
        } else {
            let t = desc.target as usize;
            let per_line = 64 / t;
            for i in 0..desc.data_lines() {
                let mut bytes = [0u8; 64];
                for k in 0..per_line {
                    let s = i as usize * per_line + k;
                    if desc.is_exception(s as u8) {
                        continue; // sub-slot stays zero; data lives in the region
                    }
                    let c = hybrid::encode(&lines[s])
                        .expect("fitting slot compresses within its target");
                    debug_assert!(c.bytes.len() <= t);
                    bytes[k * t..k * t + c.bytes.len()].copy_from_slice(&c.bytes);
                }
                self.phys.insert(base + i, CacheLine::from_bytes(&bytes));
            }
            for s in 0..PAGE_LINES as u8 {
                if desc.is_exception(s) {
                    self.phys.insert(desc.physical_line(base, s), lines[s as usize]);
                }
            }
        }
        self.layout
            .as_lcp_mut()
            .expect("lcp_write_page runs on a page-family store")
            .install_desc(page, desc);
        desc
    }

    /// Byte-accurate LCP read: one shift from the descriptor to the
    /// physical line, then either the raw exception line or a prefix
    /// decode at the slot's fixed sub-slot offset.  Never probes,
    /// never interprets markers — exactly the read path predictable
    /// offsets buy.
    pub fn lcp_read_line(&mut self, page: u64, slot: u8) -> CacheLine {
        let base = page * PAGE_LINES;
        let d = self
            .layout
            .as_lcp()
            .expect("lcp_read_line runs on a page-family store")
            .desc_of(page)
            .unwrap_or(PageDesc { target: 64, exceptions: 0 });
        let phys = self.read_phys(d.physical_line(base, slot));
        if d.is_exception(slot) || u64::from(d.target) >= 64 {
            return phys;
        }
        let t = d.target as usize;
        let off = (slot as usize * t) % 64;
        let (line, used) = hybrid::decode_prefix(&phys.to_bytes()[off..]);
        debug_assert!(used <= t, "sub-slot decode stays within its target");
        line
    }

    /// Iterate over the ground-truth group CSIs as (base line, csi).
    pub fn groups(&self) -> impl Iterator<Item = (u64, Csi)> + '_ {
        self.layout.groups().map(|(g, c)| (g * GROUP_LINES, c))
    }

    /// Number of physical lines materialized.
    pub fn phys_lines(&self) -> usize {
        self.phys.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::forall;

    fn compressible_line(tag: u32) -> CacheLine {
        CacheLine::from_words([tag & 0xFF; 16])
    }

    fn incompressible_line(rng: &mut Rng) -> CacheLine {
        CacheLine::from_words(core::array::from_fn(|_| rng.next_u32() | 0x0100_0001))
    }

    #[test]
    fn quad_pack_roundtrip() {
        let mut store = CompressedStore::new(42);
        let lines: [CacheLine; 4] = core::array::from_fn(|i| compressible_line(i as u32));
        let (csi, _) = store.write_group_auto(0, &lines);
        assert_eq!(csi, Csi::Quad);
        // one access to location 0 recovers all four lines
        let interp = store.read_interpret(0);
        assert_eq!(interp.kind, LineKind::Compressed4);
        assert_eq!(interp.lines.len(), 4);
        for (i, (addr, data)) in interp.lines.iter().enumerate() {
            assert_eq!(*addr, i as u64);
            assert_eq!(*data, lines[i]);
        }
        // stale slots read as invalid
        for loc in 1..4 {
            assert_eq!(store.read_interpret(loc).kind, LineKind::Invalid);
        }
    }

    #[test]
    fn pair_pack_roundtrip() {
        let mut store = CompressedStore::new(43);
        let mut rng = Rng::new(7);
        let lines = [
            compressible_line(1),
            compressible_line(2),
            incompressible_line(&mut rng),
            incompressible_line(&mut rng),
        ];
        let (csi, _) = store.write_group_auto(8, &lines);
        assert_eq!(csi, Csi::PairAb);
        let interp = store.read_interpret(8);
        assert_eq!(interp.kind, LineKind::Compressed2);
        assert_eq!(interp.lines.as_slice(), &[(8, lines[0]), (9, lines[1])]);
        assert_eq!(store.read_interpret(9).kind, LineKind::Invalid);
        // C and D raw in place
        assert_eq!(store.read_interpret(10).lines.as_slice(), &[(10, lines[2])]);
        assert_eq!(store.read_interpret(11).lines.as_slice(), &[(11, lines[3])]);
    }

    #[test]
    fn read_line_with_misprediction_walks_locations() {
        let mut store = CompressedStore::new(44);
        let lines: [CacheLine; 4] = core::array::from_fn(|i| compressible_line(i as u32));
        store.write_group_auto(0, &lines); // Quad: B lives at loc 0
        // predict B at its original location (wrong): 1 -> invalid -> 0
        let (data, accesses, _) = store.read_line(1, 1);
        assert_eq!(data, lines[1]);
        assert_eq!(accesses, 2);
        // correct prediction: single access
        let (data, accesses, _) = store.read_line(1, 0);
        assert_eq!(data, lines[1]);
        assert_eq!(accesses, 1);
    }

    #[test]
    fn layout_transition_invalidates_and_restores() {
        let mut store = CompressedStore::new(45);
        let mut rng = Rng::new(9);
        let compressible: [CacheLine; 4] = core::array::from_fn(|i| compressible_line(i as u32));
        store.write_group_auto(0, &compressible);
        // now the group becomes incompressible: all lines move home
        let raw: [CacheLine; 4] = core::array::from_fn(|_| incompressible_line(&mut rng));
        let (csi, _) = store.write_group_auto(0, &raw);
        assert_eq!(csi, Csi::Uncompressed);
        for i in 0..4u64 {
            let (data, acc, _) = store.read_line(i, i);
            assert_eq!(data, raw[i as usize]);
            assert_eq!(acc, 1);
        }
    }

    #[test]
    fn memo_skips_recompression_of_unmodified_lines() {
        let mut store = CompressedStore::new(50);
        let mut rng = Rng::new(3);
        let lines: [CacheLine; 4] = core::array::from_fn(|i| compressible_line(i as u32));
        store.write_group_auto(0, &lines);
        assert_eq!(store.memo_misses, 4, "cold memo: all four compressed");
        assert_eq!(store.memo_hits, 0);
        // re-evict with exactly one line dirtied: three memo hits, one miss
        let mut dirtied = lines;
        dirtied[2] = incompressible_line(&mut rng);
        store.write_group_auto(0, &dirtied);
        assert_eq!(store.memo_hits, 3, "unmodified lines skip the compressors");
        assert_eq!(store.memo_misses, 5, "the dirtied line recompresses");
        // the memoized decision must still be the ground truth
        let sizes: [u32; 4] =
            core::array::from_fn(|i| hybrid::compressed_size(&dirtied[i]));
        assert_eq!(store.csi_of(0), Csi::from_sizes(sizes));
        // clean re-eviction: all hits, layout unchanged
        let (csi, _) = store.write_group_auto(0, &dirtied);
        assert_eq!(store.memo_hits, 7);
        assert_eq!(csi, Csi::from_sizes(sizes));
    }

    #[test]
    fn wire_bytes_follow_the_stores_codec() {
        let mut raw = CompressedStore::new(60);
        let mut lc = CompressedStore::with_link_codec(60, LinkCodec::Compressed);
        let mut rng = Rng::new(11);
        let lines = [
            incompressible_line(&mut rng),
            incompressible_line(&mut rng),
            compressible_line(1),
            compressible_line(2),
        ];
        let (csi, _) = raw.write_group_auto(0, &lines);
        lc.write_group_auto(0, &lines);
        assert_eq!(csi, Csi::PairCd);
        // raw codec: every transfer is full width
        for loc in 0..4 {
            assert_eq!(raw.wire_bytes_of(loc), 64);
        }
        // compressed codec: raw-resident incompressible lines stay full
        // width, the packed bitstream fills its line, and nothing exceeds it
        assert_eq!(lc.wire_bytes_of(0), 64);
        assert_eq!(lc.wire_bytes_of(2), 64, "packed slot is a full bitstream");
        // a compressible raw-resident line shrinks: re-home C,D raw
        let raw_group = [
            incompressible_line(&mut rng),
            incompressible_line(&mut rng),
            compressible_line(3),
            compressible_line(4),
        ];
        let mut lc2 = CompressedStore::with_link_codec(61, LinkCodec::Compressed);
        lc2.write_group(8, &raw_group, Csi::Uncompressed);
        assert!(lc2.wire_bytes_of(10) < 64, "compressible line shrinks on the wire");
    }

    #[test]
    fn lcp_page_roundtrip_with_exceptions() {
        let mut store = CompressedStore::lcp(70, LinkCodec::Raw);
        let mut rng = Rng::new(13);
        // mostly compressible page with 3 incompressible exception lines
        let mut lines: [CacheLine; 64] = core::array::from_fn(|i| compressible_line(i as u32));
        for &s in &[5usize, 17, 40] {
            lines[s] = incompressible_line(&mut rng);
        }
        let d = store.lcp_write_page(0, &lines);
        assert!(u64::from(d.target) < 64, "page compresses");
        assert_eq!(d.exceptions.count_ones(), 3);
        assert!(d.physical_lines() < 64, "the capacity win is real");
        for s in 0..64u8 {
            assert_eq!(store.lcp_read_line(0, s), lines[s as usize], "slot {s}");
        }
        // offset predictability: a fitting slot's location is a pure shift
        let t = u64::from(d.target);
        for s in 0..64u8 {
            if !d.is_exception(s) {
                assert_eq!(d.physical_line(0, s), (u64::from(s) * t) >> 6);
            }
        }
        // dirty a fitting line incompressible and re-encode: one more
        // exception, everything still round-trips
        lines[9] = incompressible_line(&mut rng);
        let d2 = store.lcp_write_page(0, &lines);
        assert_eq!(d2.exceptions.count_ones(), 4);
        for s in 0..64u8 {
            assert_eq!(store.lcp_read_line(0, s), lines[s as usize]);
        }
        // an incompressible page stores raw with no exceptions
        let raw: [CacheLine; 64] = core::array::from_fn(|_| incompressible_line(&mut rng));
        let d3 = store.lcp_write_page(1, &raw);
        assert_eq!((d3.target, d3.exceptions), (64, 0));
        for s in 0..64u8 {
            assert_eq!(store.lcp_read_line(1, s), raw[s as usize]);
        }
    }

    #[test]
    fn marker_collision_roundtrips_via_inversion() {
        let mut store = CompressedStore::new(46);
        let mut rng = Rng::new(5);
        // craft an uncompressed line whose tail collides with marker2(loc)
        let loc = 100; // slot 0 of group 25
        let mut evil = incompressible_line(&mut rng);
        evil.set_tail_u32(store.markers.marker2(loc));
        let group: [CacheLine; 4] = [
            evil,
            incompressible_line(&mut rng),
            incompressible_line(&mut rng),
            incompressible_line(&mut rng),
        ];
        let (csi, _) = store.write_group_auto(100, &group);
        assert_eq!(csi, Csi::Uncompressed);
        assert!(store.lit.contains(loc));
        // read back: classified NeedsLitCheck, inverted back correctly
        let interp = store.read_interpret(loc);
        assert!(interp.lit_checked);
        assert_eq!(interp.lines.as_slice(), &[(loc, evil)]);
        // rewrite with a benign line: LIT entry retired
        let benign = incompressible_line(&mut rng);
        let group2 = [benign, group[1], group[2], group[3]];
        store.write_group_auto(100, &group2);
        assert!(!store.lit.contains(loc));
    }

    #[test]
    fn corrupted_marker_is_detected_and_rekey_cures_it() {
        let mut store = CompressedStore::new(47);
        let lines: [CacheLine; 4] = core::array::from_fn(|i| compressible_line(i as u32));
        store.write_group_auto(0, &lines);
        assert_eq!(store.csi_of(0), Csi::Quad);
        assert!(store.classification_matches_layout(0));

        // flip a bit in the stored 4:1 marker tail: the packed block no
        // longer classifies compressed, but the layout authority still
        // knows four lines live there — the mismatch is the detection
        store.corrupt_tail_bit(0, 13);
        assert_ne!(store.read_interpret(0).kind, LineKind::Compressed4);
        assert!(!store.classification_matches_layout(0));

        // feed the error signal to threshold: the re-key cure fires,
        // re-stamping every packed tail under fresh keys
        let mut rekeyed = false;
        for _ in 0..CompressedStore::REKEY_ERROR_THRESHOLD {
            rekeyed = store.note_marker_error();
        }
        assert!(rekeyed, "threshold-th detection trips the cure");
        assert_eq!(store.markers.rekey_count, 1);
        assert!(store.classification_matches_layout(0), "fresh tail restored");
        let interp = store.read_interpret(0);
        assert_eq!(interp.kind, LineKind::Compressed4);
        for (i, (addr, data)) in interp.lines.iter().enumerate() {
            assert_eq!(*addr, i as u64);
            assert_eq!(*data, lines[i], "payload survived corruption + cure");
        }
        // below threshold the counter just accumulates
        assert!(!store.note_marker_error());
        assert_eq!(store.markers.rekey_count, 1);
    }

    #[test]
    fn store_invariant_marker_implies_compressed_or_lit() {
        forall("marker invariant", 64, |rng| {
            let mut store = CompressedStore::new(rng.next_u64());
            // random groups of mixed compressibility
            for g in 0..8u64 {
                let lines: [CacheLine; 4] = core::array::from_fn(|_| {
                    if rng.chance(0.5) {
                        compressible_line(rng.next_u32())
                    } else {
                        incompressible_line(rng)
                    }
                });
                store.write_group_auto(g * 4, &lines);
            }
            // invariant: every physical line whose tail matches a marker is
            // compressed (per ground-truth CSI) or is in the LIT or is IL.
            let locs: Vec<u64> = store.phys.keys().collect();
            for loc in locs {
                let phys = store.read_phys(loc);
                let kind = store.markers.classify(loc, &phys);
                let base = group_base(loc);
                let csi = store.csi_of(base);
                let loc_slot = (loc - base) as u8;
                match kind {
                    LineKind::Compressed2 | LineKind::Compressed4 => {
                        assert!(csi.is_compressed_at(loc_slot), "false compressed at {loc}");
                    }
                    LineKind::Invalid => assert!(csi.is_stale(loc_slot)),
                    LineKind::NeedsLitCheck => { /* LIT resolves it */ }
                    LineKind::Uncompressed => {
                        assert_eq!(csi.colocated(loc_slot).len(), 1);
                    }
                }
            }
        });
    }

    #[test]
    fn latest_write_wins_across_transitions() {
        forall("latest write wins", 32, |rng| {
            let mut store = CompressedStore::new(rng.next_u64());
            let mut shadow: std::collections::HashMap<u64, CacheLine> = Default::default();
            for _ in 0..24 {
                let g = rng.below(4) * 4;
                let lines: [CacheLine; 4] = core::array::from_fn(|_| {
                    if rng.chance(0.5) {
                        compressible_line(rng.next_u32())
                    } else {
                        incompressible_line(rng)
                    }
                });
                store.write_group_auto(g, &lines);
                for i in 0..4 {
                    shadow.insert(g + i as u64, lines[i]);
                }
                // read a few random lines with a random (possibly wrong)
                // prediction; data must always match the shadow copy.
                for _ in 0..4 {
                    let la = rng.below(16);
                    if let Some(want) = shadow.get(&la) {
                        let base = group_base(la);
                        let slot = (la - base) as u8;
                        let order = crate::cram::group::possible_locations(slot);
                        let guess = base + order[rng.below(order.len() as u64) as usize] as u64;
                        let (got, _acc, _) = store.read_line(la, guess);
                        assert_eq!(got, *want, "line {la}");
                    }
                }
            }
        });
    }
}
