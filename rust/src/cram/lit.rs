//! Line Inversion Table (paper §V-A).
//!
//! Tracks the (extremely rare) lines stored in inverted form because their
//! raw data collided with a marker.  16 entries of {valid bit, 30-bit line
//! address} = 64 bytes of storage at the memory controller.
//!
//! Overflow handling implements both options from the paper:
//! * **Option-1** (memory-mapped): a 1-bit-per-line region in memory backs
//!   the table; collisions then cost one extra memory access each.  The
//!   simulator charges that bandwidth via [`LitAccess::MemoryMapped`].
//! * **Option-2** (re-key): regenerate the marker keys and re-encode; the
//!   caller drives [`MarkerEngine::rekey`] and [`LineInversionTable::clear`].

/// Outcome of an insert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LitInsert {
    /// Stored in an on-chip entry.
    Stored,
    /// Already present.
    AlreadyPresent,
    /// On-chip table full — overflow path required.
    Overflow,
}

/// How a LIT query was served (for bandwidth accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LitAccess {
    OnChip,
    /// Served from the memory-mapped overflow region: costs one extra
    /// DRAM access.
    MemoryMapped,
}

/// The Line Inversion Table.
#[derive(Clone, Debug)]
pub struct LineInversionTable {
    entries: Vec<u64>,
    capacity: usize,
    /// Option-1 overflow region active: addresses beyond capacity spill to
    /// a memory-mapped bitmap (modeled as a set here; the bandwidth cost is
    /// what matters to the simulator).
    memory_mapped: bool,
    overflow: std::collections::BTreeSet<u64>,
    /// Statistics.
    pub inserts: u64,
    pub overflows: u64,
    pub mm_accesses: u64,
}

impl Default for LineInversionTable {
    fn default() -> Self {
        Self::new(16, true)
    }
}

impl LineInversionTable {
    /// `capacity` on-chip entries (paper: 16 for 16GB).  `memory_mapped`
    /// enables the Option-1 overflow region.
    pub fn new(capacity: usize, memory_mapped: bool) -> Self {
        Self {
            entries: Vec::with_capacity(capacity),
            capacity,
            memory_mapped,
            overflow: Default::default(),
            inserts: 0,
            overflows: 0,
            mm_accesses: 0,
        }
    }

    /// Number of tracked inverted lines (on-chip + overflow region).
    pub fn len(&self) -> usize {
        self.entries.len() + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record that the line at physical `loc` is stored inverted.
    pub fn insert(&mut self, loc: u64) -> LitInsert {
        if self.entries.contains(&loc) || self.overflow.contains(&loc) {
            return LitInsert::AlreadyPresent;
        }
        self.inserts += 1;
        if self.entries.len() < self.capacity {
            self.entries.push(loc);
            LitInsert::Stored
        } else if self.memory_mapped {
            self.overflows += 1;
            self.mm_accesses += 1; // writing the bitmap costs an access
            self.overflow.insert(loc);
            LitInsert::Overflow
        } else {
            self.overflows += 1;
            LitInsert::Overflow
        }
    }

    /// Is `loc` stored inverted?  Also reports where the answer came from
    /// so callers can charge bandwidth for memory-mapped lookups.
    ///
    /// NOTE on fidelity: a real memory-mapped LIT must be consulted for any
    /// complement-match read.  On-chip lookups are free; only lookups that
    /// *fall through* to the overflow region cost a DRAM access, and only
    /// when the region is in use (non-empty) — before first overflow the
    /// controller knows the on-chip table is authoritative.
    pub fn query(&mut self, loc: u64) -> (bool, LitAccess) {
        if self.entries.contains(&loc) {
            return (true, LitAccess::OnChip);
        }
        if self.memory_mapped && !self.overflow.is_empty() {
            self.mm_accesses += 1;
            return (self.overflow.contains(&loc), LitAccess::MemoryMapped);
        }
        (false, LitAccess::OnChip)
    }

    /// Non-mutating containment check (tests / invariants).
    pub fn contains(&self, loc: u64) -> bool {
        self.entries.contains(&loc) || self.overflow.contains(&loc)
    }

    /// Remove `loc` (line rewritten in its natural form).
    pub fn remove(&mut self, loc: u64) {
        if let Some(i) = self.entries.iter().position(|&e| e == loc) {
            self.entries.swap_remove(i);
            // Promote an overflow entry into the freed on-chip slot.
            if let Some(&promoted) = self.overflow.iter().next() {
                self.overflow.remove(&promoted);
                self.entries.push(promoted);
                self.mm_accesses += 1;
            }
        } else if self.overflow.remove(&loc) {
            self.mm_accesses += 1;
        }
    }

    /// Drop everything (Option-2 re-key cure).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.overflow.clear();
    }

    /// Storage at the memory controller (paper Table III: 64 bytes for 16
    /// entries — 1 valid bit + 30-bit address each, rounded to 4B/entry).
    pub fn storage_bytes(&self) -> u32 {
        (self.capacity * 4) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_query_remove() {
        let mut lit = LineInversionTable::default();
        assert_eq!(lit.insert(10), LitInsert::Stored);
        assert_eq!(lit.insert(10), LitInsert::AlreadyPresent);
        assert_eq!(lit.query(10), (true, LitAccess::OnChip));
        assert_eq!(lit.query(11).0, false);
        lit.remove(10);
        assert!(!lit.contains(10));
        assert!(lit.is_empty());
    }

    #[test]
    fn overflow_spills_to_memory_mapped_region() {
        let mut lit = LineInversionTable::new(2, true);
        assert_eq!(lit.insert(1), LitInsert::Stored);
        assert_eq!(lit.insert(2), LitInsert::Stored);
        assert_eq!(lit.insert(3), LitInsert::Overflow);
        assert_eq!(lit.len(), 3);
        // overflow lookups cost a memory access
        let before = lit.mm_accesses;
        assert_eq!(lit.query(3), (true, LitAccess::MemoryMapped));
        assert!(lit.mm_accesses > before);
    }

    #[test]
    fn overflow_without_mm_region_reports() {
        let mut lit = LineInversionTable::new(1, false);
        assert_eq!(lit.insert(1), LitInsert::Stored);
        assert_eq!(lit.insert(2), LitInsert::Overflow);
        assert_eq!(lit.overflows, 1);
        // without the region the entry is NOT tracked — caller must re-key
        assert!(!lit.contains(2));
    }

    #[test]
    fn remove_promotes_overflow_entry() {
        let mut lit = LineInversionTable::new(1, true);
        lit.insert(1);
        lit.insert(2); // overflows
        lit.remove(1);
        // 2 must now be servable on-chip
        assert_eq!(lit.query(2), (true, LitAccess::OnChip));
    }

    #[test]
    fn clear_for_rekey() {
        let mut lit = LineInversionTable::default();
        for i in 0..20 {
            lit.insert(i);
        }
        lit.clear();
        assert!(lit.is_empty());
    }

    #[test]
    fn storage_overhead_table3() {
        assert_eq!(LineInversionTable::default().storage_bytes(), 64);
    }

    #[test]
    fn empty_overflow_region_is_free() {
        let mut lit = LineInversionTable::new(16, true);
        lit.insert(5);
        let before = lit.mm_accesses;
        lit.query(99);
        assert_eq!(lit.mm_accesses, before, "no MM access while region empty");
    }
}
