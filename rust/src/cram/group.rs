//! Restricted data mapping (paper §IV-A, Fig. 6).
//!
//! CRAM groups four consecutive lines `[A, B, C, D]` (line address ending
//! 00/01/10/11) and allows exactly five layouts.  Restricting placement
//! bounds the number of locations a line can occupy — A never moves, B has
//! two possible homes, C two, D three — which is what makes the LLP's job
//! tractable.
//!
//! The `Csi` discriminants are the canonical encoding shared with the L2
//! model (`python/compile/kernels/ref.py`) and the explicit-metadata
//! region (3 bits per group).

use crate::compress::PACK_BUDGET;

/// Compression Status Information for one 4-line group.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
#[repr(u8)]
pub enum Csi {
    /// All four lines uncompressed, in their original slots.
    #[default]
    Uncompressed = 0,
    /// A+B packed at slot 0; C, D uncompressed; slot 1 stale.
    PairAb = 1,
    /// C+D packed at slot 2; A, B uncompressed; slot 3 stale.
    PairCd = 2,
    /// A+B at slot 0 and C+D at slot 2; slots 1 and 3 stale.
    PairBoth = 3,
    /// All four packed at slot 0 (4:1); slots 1-3 stale.
    Quad = 4,
}

impl Csi {
    pub const ALL: [Csi; 5] = [
        Csi::Uncompressed,
        Csi::PairAb,
        Csi::PairCd,
        Csi::PairBoth,
        Csi::Quad,
    ];

    pub fn from_u8(v: u8) -> Option<Csi> {
        Csi::ALL.get(v as usize).copied()
    }

    /// Layout decision from the four hybrid sizes (bytes; 64 = raw).
    /// 4:1 if all four fit in the 60-byte budget, else each pair
    /// independently.  Must match `ref.csi_decision` on the python side.
    pub fn from_sizes(sizes: [u32; 4]) -> Csi {
        let total: u32 = sizes.iter().sum();
        if total <= PACK_BUDGET {
            return Csi::Quad;
        }
        let ab = sizes[0] + sizes[1] <= PACK_BUDGET;
        let cd = sizes[2] + sizes[3] <= PACK_BUDGET;
        match (ab, cd) {
            (true, true) => Csi::PairBoth,
            (true, false) => Csi::PairAb,
            (false, true) => Csi::PairCd,
            (false, false) => Csi::Uncompressed,
        }
    }

    /// Physical slot (0..4) where the line in logical `slot` lives.
    pub fn location(self, slot: u8) -> u8 {
        debug_assert!(slot < 4);
        match self {
            Csi::Uncompressed => slot,
            Csi::PairAb => match slot {
                0 | 1 => 0,
                s => s,
            },
            Csi::PairCd => match slot {
                2 | 3 => 2,
                s => s,
            },
            Csi::PairBoth => match slot {
                0 | 1 => 0,
                _ => 2,
            },
            Csi::Quad => 0,
        }
    }

    /// Logical slots co-resident at physical `loc` under this layout
    /// (empty ⇒ `loc` holds stale data / the invalid-line marker).
    pub fn colocated(self, loc: u8) -> &'static [u8] {
        debug_assert!(loc < 4);
        const NONE: &[u8] = &[];
        const SINGLES: [&[u8]; 4] = [&[0], &[1], &[2], &[3]];
        match (self, loc) {
            (Csi::Uncompressed, l) => SINGLES[l as usize],
            (Csi::PairAb, 0) => &[0, 1],
            (Csi::PairAb, 2) => &[2],
            (Csi::PairAb, 3) => &[3],
            (Csi::PairCd, 0) => &[0],
            (Csi::PairCd, 1) => &[1],
            (Csi::PairCd, 2) => &[2, 3],
            (Csi::PairBoth, 0) => &[0, 1],
            (Csi::PairBoth, 2) => &[2, 3],
            (Csi::Quad, 0) => &[0, 1, 2, 3],
            _ => NONE,
        }
    }

    /// Is physical slot `loc` a *stale* location under this layout (left
    /// behind by packing and overwritten with the invalid-line marker)?
    pub fn is_stale(self, loc: u8) -> bool {
        self.colocated(loc).is_empty()
    }

    /// Whether the data at physical `loc` is stored compressed.
    pub fn is_compressed_at(self, loc: u8) -> bool {
        self.colocated(loc).len() > 1
    }

    /// Compression level recorded in the LLC tag store (2 bits, §V-A
    /// "Handling Updates to Compressed Lines") for a line in `slot`.
    /// 0 = uncompressed, 1 = 2:1, 2 = 4:1.
    pub fn level_of(self, slot: u8) -> u8 {
        match self {
            Csi::Quad => 2,
            _ if self.location(slot) != slot || self.colocated(slot).len() > 1 => 1,
            _ => 0,
        }
    }

    /// Number of DRAM locations holding live data for the group.
    pub fn live_locations(self) -> u8 {
        match self {
            Csi::Uncompressed => 4,
            Csi::PairAb | Csi::PairCd => 3,
            Csi::PairBoth => 2,
            Csi::Quad => 1,
        }
    }
}

/// The locations a line in logical `slot` may occupy across all layouts,
/// most-common first.  This is the re-issue order after an LLP miss:
/// slot 0 never moves; B ∈ {1, 0}; C ∈ {2, 0}; D ∈ {3, 2, 0}.
pub fn possible_locations(slot: u8) -> &'static [u8] {
    match slot {
        0 => &[0],
        1 => &[1, 0],
        2 => &[2, 0],
        3 => &[3, 2, 0],
        _ => panic!("slot out of range"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sizes_decisions() {
        assert_eq!(Csi::from_sizes([2, 2, 2, 2]), Csi::Quad);
        assert_eq!(Csi::from_sizes([15, 15, 15, 15]), Csi::Quad); // sum 60 fits
        assert_eq!(Csi::from_sizes([15, 15, 15, 16]), Csi::PairBoth); // sum 61 doesn't
        assert_eq!(Csi::from_sizes([30, 30, 29, 31]), Csi::PairBoth);
        assert_eq!(Csi::from_sizes([30, 30, 64, 64]), Csi::PairAb);
        assert_eq!(Csi::from_sizes([64, 64, 30, 30]), Csi::PairCd);
        assert_eq!(Csi::from_sizes([64, 64, 64, 64]), Csi::Uncompressed);
        // boundary: exactly 60 fits
        assert_eq!(Csi::from_sizes([30, 30, 64, 64]), Csi::PairAb);
        assert_eq!(Csi::from_sizes([30, 31, 64, 64]), Csi::Uncompressed);
    }

    #[test]
    fn locations_consistent_with_colocation() {
        for csi in Csi::ALL {
            for slot in 0..4u8 {
                let loc = csi.location(slot);
                assert!(
                    csi.colocated(loc).contains(&slot),
                    "{csi:?} slot {slot} -> loc {loc}"
                );
                // and the location is among the globally possible ones
                assert!(possible_locations(slot).contains(&loc));
            }
        }
    }

    #[test]
    fn every_slot_lives_somewhere_exactly_once() {
        for csi in Csi::ALL {
            for slot in 0..4u8 {
                let homes: usize = (0..4u8)
                    .filter(|&loc| csi.colocated(loc).contains(&slot))
                    .count();
                assert_eq!(homes, 1, "{csi:?} slot {slot}");
            }
        }
    }

    #[test]
    fn stale_slots() {
        assert!(!Csi::Uncompressed.is_stale(0));
        assert!(Csi::PairAb.is_stale(1));
        assert!(Csi::PairCd.is_stale(3));
        assert!(Csi::PairBoth.is_stale(1));
        assert!(Csi::PairBoth.is_stale(3));
        assert!(Csi::Quad.is_stale(1));
        assert!(Csi::Quad.is_stale(2));
        assert!(Csi::Quad.is_stale(3));
    }

    #[test]
    fn a_never_moves() {
        for csi in Csi::ALL {
            assert_eq!(csi.location(0), 0);
        }
    }

    #[test]
    fn levels() {
        assert_eq!(Csi::Quad.level_of(0), 2);
        assert_eq!(Csi::PairAb.level_of(0), 1);
        assert_eq!(Csi::PairAb.level_of(1), 1);
        assert_eq!(Csi::PairAb.level_of(2), 0);
        assert_eq!(Csi::Uncompressed.level_of(3), 0);
    }

    #[test]
    fn live_location_counts() {
        assert_eq!(Csi::Uncompressed.live_locations(), 4);
        assert_eq!(Csi::PairAb.live_locations(), 3);
        assert_eq!(Csi::PairBoth.live_locations(), 2);
        assert_eq!(Csi::Quad.live_locations(), 1);
    }
}
