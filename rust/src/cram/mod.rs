//! The paper's contribution: CRAM's compressed-memory machinery.
//!
//! * [`group`] — restricted data mapping: the five layouts of a 4-line
//!   group (Fig. 6) and where each line may live.
//! * [`marker`] — implicit metadata: keyed per-line 2:1 / 4:1 markers, the
//!   64-byte invalid-line marker, and line inversion (§V-A).
//! * [`lit`] — the Line Inversion Table, including both overflow options.
//! * [`llp`] — the Line Location Predictor / Last Compressibility Table.
//! * [`store`] — byte-accurate compressed physical memory: packs real
//!   hybrid bitstreams + markers into 64-byte locations and interprets
//!   reads back (the substrate the controllers drive).
//! * [`metadata`] — the explicit-metadata baseline: an in-memory CSI region
//!   plus a 32KB on-chip metadata cache (and the row-buffer-optimized
//!   variant of Fig. 20).
//! * [`dynamic`] — Dynamic-CRAM: set-sampled cost/benefit counters that
//!   enable/disable compression at runtime (§VI).

pub mod dynamic;
pub mod group;
pub mod lit;
pub mod llp;
pub mod marker;
pub mod metadata;
pub mod store;

pub use group::Csi;
pub use lit::LineInversionTable;
pub use llp::LineLocationPredictor;
pub use marker::MarkerEngine;
