//! Parallel simulation runner + results cache.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

use crate::controller::{Design, LinkCodec, Placement, Policy};
use crate::dram::SchedConfig;
use crate::sim::{simulate, simulate_tenants, FaultConfig, SimConfig};
use crate::stats::SimResult;
use crate::workloads::profiles::{
    all27, all64, cache_pressure, far_pressure, latency_sensitive, WorkloadProfile,
};
use crate::workloads::tenant::m1_mixes;
use crate::workloads::parse_tenants;

/// Key identifying one simulation run.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RunKey {
    pub workload: String,
    pub design: &'static str,
    pub channels: usize,
    /// Far-tier capacity split in thousandths (0 for flat designs), so
    /// tiered runs at different ratios never collide in the cache.
    pub far_mill: u16,
    /// Compressed LLC (Figure C1 runs) — plain-LLC runs use `false`.
    pub llc_comp: bool,
}

/// Far ratio → cache-key thousandths.
fn far_mill_of(far_ratio: Option<f64>) -> u16 {
    far_ratio.map(|r| (r * 1000.0).round() as u16).unwrap_or(0)
}

/// What to simulate.
#[derive(Clone, Debug)]
pub struct RunPlan {
    pub insts_per_core: u64,
    pub seed: u64,
    pub threads: usize,
}

impl Default for RunPlan {
    fn default() -> Self {
        Self {
            insts_per_core: 2_000_000,
            seed: 0xC0DE,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        }
    }
}

/// One simulation job.
#[derive(Clone)]
struct Job {
    profile: WorkloadProfile,
    design: Design,
    channels: usize,
    /// Far-tier capacity fraction for tiered designs (None = flat).
    far_ratio: Option<f64>,
    /// Run with the compressed LLC (Figure C1).
    llc_comp: bool,
}

impl Job {
    /// Tiered designs always simulate (and cache) at the Figure T1 split,
    /// matching the `far_mill` that [`ResultsDb::get_ch`] looks up — so a
    /// tiered job enqueued through any matrix path stays reachable.
    fn new(profile: WorkloadProfile, design: Design, channels: usize) -> Self {
        let far_ratio = design.is_tiered().then_some(T1_FAR_RATIO);
        Self { profile, design, channels, far_ratio, llc_comp: false }
    }

    /// Same design, compressed LLC (Figure C1's second column family).
    fn new_comp(profile: WorkloadProfile, design: Design, channels: usize) -> Self {
        Self { llc_comp: true, ..Self::new(profile, design, channels) }
    }

    fn key(&self) -> RunKey {
        RunKey {
            workload: self.profile.name.to_string(),
            design: self.design.name(),
            channels: self.channels,
            far_mill: far_mill_of(self.far_ratio),
            llc_comp: self.llc_comp,
        }
    }
}

/// The designs every per-workload figure compares.
pub const CORE_DESIGNS: [Design; 7] = [
    Design::Uncompressed,
    Design::Ideal,
    Design::explicit(false),
    Design::explicit(true),
    Design::Implicit,
    Design::Dynamic,
    Design::NextLinePrefetch,
];

/// The tiered-memory designs (Figure T1).
pub const TIERED_DESIGNS: [Design; 2] = [
    Design::tiered(false),
    Design::tiered(true),
];

/// Far-tier capacity fraction used by the Figure T1 evaluation: three
/// quarters of capacity behind the link, i.e. a deployment that bought
/// expansion because it needed it.
pub const T1_FAR_RATIO: f64 = 0.75;

/// The designs the Figure Q1 tail-latency exhibit compares:
/// uncompressed baseline, explicit-metadata CRAM (serialized lookups in
/// the tail), and Dynamic-CRAM.
pub const Q1_DESIGNS: [Design; 3] = [
    Design::Uncompressed,
    Design::explicit(false),
    Design::Dynamic,
];

/// The memory-side designs the Figure C1 compressed-LLC exhibit crosses
/// with the LLC organization (cache compression × memory compression).
pub const C1_DESIGNS: [Design; 2] = [Design::Implicit, Design::Dynamic];

/// The Figure X1 cross-product: {static, dynamic, explicit} × {flat,
/// tiered} — the design space the composable controller opened.  Tiered
/// columns run at the T1 capacity split.
pub const X1_DESIGNS: [Design; 6] = [
    Design::Implicit,
    Design::Dynamic,
    Design::explicit(false),
    Design::tiered(true), // Implicit × Tiered
    Design::new(Policy::Dynamic, Placement::Tiered),
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
];

/// The Figure L1 matrix: {static, dynamic, explicit} tiered designs,
/// each with a raw link and with the compressed link (`+lc`) — the
/// third-axis exhibit.  Rows pair each design with its `+lc` twin so the
/// figure answers where link compression still pays once storage
/// compression has already shrunk the transfers.
pub const L1_DESIGNS: [Design; 6] = [
    Design::tiered(true), // Implicit × Tiered
    Design::new(Policy::Dynamic, Placement::Tiered),
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
    Design::tiered(true).with_link_codec(LinkCodec::Compressed),
    Design::new(Policy::Dynamic, Placement::Tiered).with_link_codec(LinkCodec::Compressed),
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered)
        .with_link_codec(LinkCodec::Compressed),
];

/// The Figure P1 layout-family matrix: the line-granular CRAM layouts
/// (implicit metadata, gated, explicit) next to the LCP page-granular
/// layout, each flat and on the far expander.  The uncompressed flat and
/// tiered baselines anchor the speedups; every other column answers the
/// same question from a different layout family: what does the layout
/// authority cost in metadata traffic, and what does it buy in effective
/// capacity?  Tiered columns run at the T1 capacity split.
pub const P1_DESIGNS: [Design; 9] = [
    Design::Uncompressed,
    Design::Implicit,
    Design::Dynamic,
    Design::explicit(false),
    Design::new(Policy::Lcp, Placement::Flat),
    Design::tiered(false),
    Design::tiered(true), // Implicit × Tiered
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
    Design::new(Policy::Lcp, Placement::Tiered),
];

/// The designs the Figure M1 multi-tenant exhibit compares: uncompressed
/// sharing, flat Dynamic-CRAM, and tiered Dynamic-CRAM at the T1 split.
pub const M1_DESIGNS: [Design; 3] = [
    Design::Uncompressed,
    Design::Dynamic,
    Design::new(Policy::Dynamic, Placement::Tiered),
];

/// Read slots the M1 QoS contrast run reserves for the protected tenant
/// (out of [`SchedConfig::default`]'s 32 per channel).  Deliberately
/// aggressive so the shift in the protected tenant's tail is visible
/// even at smoke-test instruction budgets.
pub const M1_QOS_RESERVED: usize = 24;

/// One shared-tenancy simulation from the Figure M1 matrix.
pub struct M1Run {
    pub mix: &'static str,
    pub design: Design,
    pub result: SimResult,
}

/// The Figure M1 QoS contrast: the `:qos`-marked mix re-run with
/// read-slot reservation enabled, next to its unreserved baseline.
pub struct M1Qos {
    pub mix: &'static str,
    pub design: Design,
    pub reserved: usize,
    pub read_slots: usize,
    pub base: SimResult,
    pub qos: SimResult,
}

/// Run the Figure M1 matrix: each canonical tenant mix under each M1
/// design (shared run + per-tenant solo reruns for the slowdown metric),
/// plus one QoS contrast run of the `:qos`-marked mix with read slots
/// reserved.  Tenant runs carry per-tenant state that the [`RunKey`]
/// cache does not key on, so this returns results directly instead of
/// populating a [`ResultsDb`].
pub fn run_m1(plan: &RunPlan, progress: bool) -> (Vec<M1Run>, Option<M1Qos>) {
    #[derive(Clone, Copy)]
    struct M1Job {
        mix: &'static str,
        spec: &'static str,
        design: Design,
        reserved: usize,
    }
    let mut jobs: Vec<M1Job> = Vec::new();
    for (mix, spec) in m1_mixes() {
        for d in M1_DESIGNS {
            jobs.push(M1Job { mix, spec, design: d, reserved: 0 });
        }
    }
    let qos_mix = m1_mixes().into_iter().find(|(_, s)| s.contains(":qos"));
    if let Some((mix, spec)) = qos_mix {
        jobs.push(M1Job { mix, spec, design: Design::Dynamic, reserved: M1_QOS_RESERVED });
    }

    let descs = jobs.clone();
    let total = jobs.len();
    let queue = Mutex::new(jobs.into_iter().enumerate().collect::<VecDeque<_>>());
    let out: Mutex<Vec<(usize, SimResult)>> = Mutex::new(Vec::with_capacity(total));
    let done = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..plan.threads.min(total) {
            scope.spawn(|| loop {
                let job = { queue.lock().unwrap().pop_front() };
                let Some((idx, job)) = job else { break };
                let mut b = SimConfig::builder()
                    .design(job.design)
                    .seed(plan.seed)
                    .insts(plan.insts_per_core)
                    .warmup(plan.insts_per_core * 2);
                if job.design.is_tiered() {
                    b = b.far_ratio(T1_FAR_RATIO);
                }
                if job.reserved > 0 {
                    b = b.sched(SchedConfig {
                        reserved_slots: job.reserved,
                        ..Default::default()
                    });
                }
                let cfg = b.build();
                let specs = parse_tenants(job.spec, cfg.cores).expect("m1 mixes parse");
                let r = simulate_tenants(&specs, &cfg);
                out.lock().unwrap().push((idx, r));
                let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                if progress {
                    eprintln!("  [{d}/{total}] tenant mixes done");
                }
            });
        }
    });

    let mut results = out.into_inner().unwrap();
    results.sort_by_key(|(idx, _)| *idx);
    let mut runs = Vec::new();
    let mut qos_run: Option<SimResult> = None;
    for (idx, r) in results {
        let j = descs[idx];
        if j.reserved > 0 {
            qos_run = Some(r);
        } else {
            runs.push(M1Run { mix: j.mix, design: j.design, result: r });
        }
    }
    let qos = qos_mix.and_then(|(mix, _)| {
        let q = qos_run.take()?;
        let base = runs
            .iter()
            .find(|r| r.mix == mix && r.design.name() == Design::Dynamic.name())?;
        Some(M1Qos {
            mix,
            design: Design::Dynamic,
            reserved: M1_QOS_RESERVED,
            read_slots: SchedConfig::default().read_slots,
            base: base.result.clone(),
            qos: q,
        })
    });
    (runs, qos)
}

/// The Figure R1 BER sweep points: clean baseline plus three decades of
/// uniform bit-error rate across every injection site.
pub const R1_BERS: [f64; 4] = [0.0, 1e-4, 1e-3, 1e-2];

/// The design the Figure R1 exhibit stresses: the CRAM-compressed far
/// tier, whose link flits, far-media reads and marker tails are all
/// exposed to injection at once.
pub const R1_DESIGN: Design = Design::tiered(true);

/// The far-pressure workload Figure R1 sweeps (the Figure T1 anchor).
pub const R1_WORKLOAD: &str = "cap_stream";

/// One point of the Figure R1 reliability sweep.
pub struct R1Run {
    pub ber: f64,
    pub watchdog: bool,
    pub result: SimResult,
}

/// Run the Figure R1 matrix: [`R1_WORKLOAD`] under [`R1_DESIGN`] at each
/// BER in [`R1_BERS`], with the error-storm watchdog disarmed and armed.
/// Fault runs carry injector state the [`RunKey`] cache does not key on,
/// so — like [`run_m1`] — this returns results directly instead of
/// populating a [`ResultsDb`].
pub fn run_r1(plan: &RunPlan, progress: bool) -> Vec<R1Run> {
    #[derive(Clone, Copy)]
    struct R1Job {
        ber: f64,
        watchdog: bool,
    }
    let mut jobs: Vec<R1Job> = Vec::new();
    for &ber in &R1_BERS {
        for watchdog in [false, true] {
            jobs.push(R1Job { ber, watchdog });
        }
    }
    let profile =
        crate::workloads::profiles::by_name(R1_WORKLOAD).expect("r1 workload exists");

    let descs = jobs.clone();
    let total = jobs.len();
    let queue = Mutex::new(jobs.into_iter().enumerate().collect::<VecDeque<_>>());
    let out: Mutex<Vec<(usize, SimResult)>> = Mutex::new(Vec::with_capacity(total));
    let done = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..plan.threads.min(total) {
            scope.spawn(|| loop {
                let job = { queue.lock().unwrap().pop_front() };
                let Some((idx, job)) = job else { break };
                let mut fault = FaultConfig::uniform(job.ber);
                fault.watchdog = job.watchdog;
                let cfg = SimConfig::builder()
                    .design(R1_DESIGN)
                    .far_ratio(T1_FAR_RATIO)
                    .seed(plan.seed)
                    .insts(plan.insts_per_core)
                    .warmup(plan.insts_per_core * 2)
                    .fault(fault)
                    .build();
                let r = simulate(&profile, &cfg);
                out.lock().unwrap().push((idx, r));
                let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                if progress {
                    eprintln!("  [{d}/{total}] BER points done");
                }
            });
        }
    });

    let mut results = out.into_inner().unwrap();
    results.sort_by_key(|(idx, _)| *idx);
    results
        .into_iter()
        .map(|(idx, r)| {
            let j = descs[idx];
            R1Run { ber: j.ber, watchdog: j.watchdog, result: r }
        })
        .collect()
}

/// Results cache for the full evaluation.
pub struct ResultsDb {
    pub plan: RunPlan,
    results: HashMap<RunKey, SimResult>,
}

impl ResultsDb {
    pub fn new(plan: RunPlan) -> Self {
        Self { plan, results: HashMap::new() }
    }

    /// Run the complete matrix needed by every figure and table:
    /// * all 27 memory-intensive workloads × 7 designs @ 2 channels,
    /// * the 37 extra low-MPKI workloads × {baseline, dynamic} (Fig. 18),
    /// * all 27 × {baseline, dynamic} @ 1 and 4 channels (Table IV).
    pub fn run_full_matrix(&mut self, progress: bool) {
        let mut jobs: Vec<Job> = Vec::new();
        for w in all27() {
            for d in CORE_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        let names27: std::collections::HashSet<_> =
            all27().iter().map(|w| w.name).collect();
        for w in all64() {
            if !names27.contains(w.name) {
                for d in [Design::Uncompressed, Design::Dynamic] {
                    jobs.push(Job::new(w.clone(), d, 2));
                }
            }
        }
        for w in all27() {
            for ch in [1usize, 4] {
                for d in [Design::Uncompressed, Design::Dynamic] {
                    jobs.push(Job::new(w.clone(), d, ch));
                }
            }
        }
        jobs.extend(Self::t1_jobs());
        jobs.extend(Self::q1_extra_jobs());
        jobs.extend(Self::c1_jobs());
        jobs.extend(Self::x1_jobs());
        jobs.extend(Self::l1_jobs());
        jobs.extend(Self::p1_jobs());
        self.run_jobs(jobs, progress);
    }

    /// The Figure P1 matrix: the 27-workload suite plus the far-pressure
    /// set, each under the [`P1_DESIGNS`] layout families (the flat and
    /// tiered uncompressed baselines ride inside the design list).
    fn p1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in all27().into_iter().chain(far_pressure()) {
            for d in P1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure P1 matrix only.
    pub fn run_p1(&mut self, progress: bool) {
        self.run_jobs(Self::p1_jobs(), progress);
    }

    /// The Figure L1 matrix: far-memory-pressure workloads × the
    /// raw/compressed-link pairs of [`L1_DESIGNS`], plus the flat
    /// uncompressed baseline for absolute speedups.
    fn l1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in L1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure L1 matrix only.
    pub fn run_l1(&mut self, progress: bool) {
        self.run_jobs(Self::l1_jobs(), progress);
    }

    /// The Figure C1 matrix: the 27 suite plus the cache-pressure set,
    /// each under {static, dynamic} CRAM × {plain, compressed} LLC, with
    /// a plain-LLC uncompressed baseline for the speedup denominator.
    fn c1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in all27().into_iter().chain(cache_pressure()) {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in C1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
                jobs.push(Job::new_comp(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure C1 matrix only.
    pub fn run_c1(&mut self, progress: bool) {
        self.run_jobs(Self::c1_jobs(), progress);
    }

    /// The Figure Q1 jobs not already covered by the core matrix: the
    /// latency-sensitive workloads under the Q1 design triple (the 27
    /// paper workloads run these designs via `CORE_DESIGNS`).
    fn q1_extra_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in latency_sensitive() {
            for d in Q1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure Q1 matrix: the 27-workload suite plus the
    /// latency-sensitive set, each under the Q1 design triple.
    pub fn run_q1(&mut self, progress: bool) {
        let mut jobs = Vec::new();
        for w in all27() {
            for d in Q1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs.extend(Self::q1_extra_jobs());
        self.run_jobs(jobs, progress);
    }

    /// The Figure T1 matrix: far-memory-pressure workloads × {flat DDR,
    /// uncompressed far tier, CRAM-compressed far tier} at the T1 split.
    fn t1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in TIERED_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure T1 matrix only.
    pub fn run_tiered_t1(&mut self, progress: bool) {
        self.run_jobs(Self::t1_jobs(), progress);
    }

    /// The Figure X1 matrix: far-memory-pressure workloads × the
    /// {static, dynamic, explicit} × {flat, tiered} cross-product, plus
    /// the flat uncompressed baseline for the speedup denominator.
    fn x1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in X1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure X1 matrix only.
    pub fn run_x1(&mut self, progress: bool) {
        self.run_jobs(Self::x1_jobs(), progress);
    }

    /// The Figure X1 far-ratio sweep: every tiered composition from the
    /// X1 cross-product re-run at each requested capacity split, plus
    /// the flat uncompressed baseline the speedups divide by (which does
    /// not depend on the split).  Results land in the cache keyed by
    /// `far_mill`, so sweep ratios never collide with the T1-split runs.
    pub fn run_x1_sweep(&mut self, ratios: &[f64], progress: bool) {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in X1_DESIGNS.into_iter().filter(Design::is_tiered) {
                for &r in ratios {
                    jobs.push(Job {
                        profile: w.clone(),
                        design: d,
                        channels: 2,
                        far_ratio: Some(r),
                        llc_comp: false,
                    });
                }
            }
        }
        self.run_jobs(jobs, progress);
    }

    /// Fetch a tiered run simulated at an explicit far-capacity split
    /// (2 channels, plain LLC) — the sweep counterpart of [`Self::get`].
    pub fn get_far(&self, workload: &str, design: Design, far_ratio: f64) -> Option<&SimResult> {
        self.results.get(&RunKey {
            workload: workload.to_string(),
            design: design.name(),
            channels: 2,
            far_mill: far_mill_of(design.is_tiered().then_some(far_ratio)),
            llc_comp: false,
        })
    }

    /// Speedup over the flat uncompressed baseline at an explicit split.
    pub fn speedup_far(&self, workload: &str, design: Design, far_ratio: f64) -> Option<f64> {
        let base = self.get(workload, Design::Uncompressed)?;
        let r = self.get_far(workload, design, far_ratio)?;
        Some(r.weighted_speedup(base))
    }

    /// Smaller matrix: the 27 workloads × the designs needed by a single
    /// figure (used by per-figure CLI invocations).
    pub fn run_designs(&mut self, designs: &[Design], extended: bool, progress: bool) {
        let set = if extended { all64() } else { all27() };
        let mut jobs = Vec::new();
        for w in set {
            for &d in designs {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        self.run_jobs(jobs, progress);
    }

    pub fn run_channel_sweep(&mut self, progress: bool) {
        let mut jobs = Vec::new();
        for w in all27() {
            for ch in [1usize, 2, 4] {
                for d in [Design::Uncompressed, Design::Dynamic] {
                    jobs.push(Job::new(w.clone(), d, ch));
                }
            }
        }
        self.run_jobs(jobs, progress);
    }

    fn run_jobs(&mut self, jobs: Vec<Job>, progress: bool) {
        // skip already-cached runs and in-batch duplicates (sub-matrices
        // like C1 overlap the core matrix on their plain-LLC runs)
        let mut seen = std::collections::HashSet::new();
        let jobs: Vec<Job> = jobs
            .into_iter()
            .filter(|j| {
                let key = j.key();
                !self.results.contains_key(&key) && seen.insert(key)
            })
            .collect();
        if jobs.is_empty() {
            return;
        }
        let total = jobs.len();
        let plan = self.plan.clone();
        // FIFO drain: workers take jobs in submission order, so figure
        // sub-matrices start producing their own results first and the
        // progress counter tracks the order jobs were enqueued in.
        let queue = Mutex::new(jobs.into_iter().collect::<VecDeque<_>>());
        let out: Mutex<Vec<(RunKey, SimResult)>> = Mutex::new(Vec::with_capacity(total));
        let done = std::sync::atomic::AtomicUsize::new(0);

        std::thread::scope(|scope| {
            for _ in 0..plan.threads.min(total) {
                scope.spawn(|| loop {
                    let job = { queue.lock().unwrap().pop_front() };
                    let Some(job) = job else { break };
                    // Equalize LLC-access counts across workloads: scale
                    // the instruction budget so every run issues a similar
                    // number of accesses (anchored at apki=30) — low-APKI
                    // workloads need proportionally more instructions to
                    // traverse their arrays the same number of times.
                    // Each workload's speedup compares runs of equal
                    // length, so this only equalizes simulation cost.
                    let apki = if job.profile.apki > 0.0 {
                        job.profile.apki
                    } else {
                        // MIX: scale by the mean APKI of the components
                        let comps: Vec<f64> = job
                            .profile
                            .mix_of
                            .iter()
                            .filter_map(|n| crate::workloads::profiles::by_name(n))
                            .map(|p| p.apki)
                            .collect();
                        comps.iter().sum::<f64>() / comps.len().max(1) as f64
                    };
                    let insts = ((plan.insts_per_core as f64 * 30.0 / apki) as u64)
                        .clamp(plan.insts_per_core / 4, plan.insts_per_core * 6);
                    // 2x warmup: the LLC, memory layout AND the Dynamic
                    // gate must all reach steady state before measurement
                    // (the paper's 1B-inst slices warm up for free).
                    let mut b = SimConfig::builder()
                        .design(job.design)
                        .seed(plan.seed)
                        .insts(insts)
                        .warmup(insts * 2)
                        .channels(job.channels);
                    if let Some(r) = job.far_ratio {
                        b = b.far_ratio(r);
                    }
                    if job.llc_comp {
                        b = b.compressed_llc();
                    }
                    let cfg = b.build();
                    let r = simulate(&job.profile, &cfg);
                    out.lock().unwrap().push((job.key(), r));
                    let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                    if progress && (d % 10 == 0 || d == total) {
                        eprintln!("  [{d}/{total}] simulations done");
                    }
                });
            }
        });
        for (k, v) in out.into_inner().unwrap() {
            self.results.insert(k, v);
        }
    }

    /// Fetch a cached result (2 channels unless stated).
    pub fn get(&self, workload: &str, design: Design) -> Option<&SimResult> {
        self.get_ch(workload, design, 2)
    }

    pub fn get_ch(&self, workload: &str, design: Design, channels: usize) -> Option<&SimResult> {
        // tiered runs are produced at the Figure T1 split; flat runs at 0
        let far_mill = far_mill_of(design.is_tiered().then_some(T1_FAR_RATIO));
        self.results.get(&RunKey {
            workload: workload.to_string(),
            design: design.name(),
            channels,
            far_mill,
            llc_comp: false,
        })
    }

    /// Fetch a cached result by LLC organization (2 channels; Figure C1).
    pub fn get_llc(&self, workload: &str, design: Design, llc_comp: bool) -> Option<&SimResult> {
        let far_mill = far_mill_of(design.is_tiered().then_some(T1_FAR_RATIO));
        self.results.get(&RunKey {
            workload: workload.to_string(),
            design: design.name(),
            channels: 2,
            far_mill,
            llc_comp,
        })
    }

    /// Speedup of `design` over the uncompressed baseline for a workload.
    pub fn speedup(&self, workload: &str, design: Design) -> Option<f64> {
        let base = self.get(workload, Design::Uncompressed)?;
        let r = self.get(workload, design)?;
        Some(r.weighted_speedup(base))
    }

    pub fn speedup_ch(&self, workload: &str, design: Design, ch: usize) -> Option<f64> {
        let base = self.get_ch(workload, Design::Uncompressed, ch)?;
        let r = self.get_ch(workload, design, ch)?;
        Some(r.weighted_speedup(base))
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_caches_and_parallelizes() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 40_000,
            seed: 1,
            threads: 4,
        });
        db.run_designs(&[Design::Uncompressed, Design::Implicit], false, false);
        assert_eq!(db.len(), 27 * 2);
        let s = db.speedup("libq", Design::Implicit).unwrap();
        assert!(s > 0.5 && s < 3.0, "sane speedup {s}");
        // re-run is a no-op (cache)
        let before = db.len();
        db.run_designs(&[Design::Uncompressed], false, false);
        assert_eq!(db.len(), before);
    }

    #[test]
    fn q1_matrix_covers_latency_set() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 4,
            threads: 4,
        });
        db.run_q1(false);
        assert_eq!(db.len(), (27 + latency_sensitive().len()) * Q1_DESIGNS.len());
        for w in latency_sensitive() {
            for d in Q1_DESIGNS {
                let r = db.get(w.name, d).expect("q1 result cached");
                assert_eq!(r.read_lat.count(), r.bw.demand_reads);
            }
        }
    }

    #[test]
    fn c1_matrix_covers_both_llc_organizations() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 7,
            threads: 4,
        });
        db.run_c1(false);
        let n_wl = 27 + cache_pressure().len();
        // per workload: 1 baseline + 2 designs x {plain, compressed}
        assert_eq!(db.len(), n_wl * (1 + 2 * C1_DESIGNS.len()));
        for w in cache_pressure() {
            assert!(db.get_llc(w.name, Design::Uncompressed, false).is_some());
            for d in C1_DESIGNS {
                let plain = db.get_llc(w.name, d, false).expect("plain run cached");
                let comp = db.get_llc(w.name, d, true).expect("compressed run cached");
                assert!(plain.llc_stats.is_none());
                assert!(comp.llc_stats.is_some(), "{} {}", w.name, d.name());
            }
        }
    }

    #[test]
    fn x1_matrix_covers_the_cross_product() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 9,
            threads: 4,
        });
        db.run_x1(false);
        assert_eq!(db.len(), far_pressure().len() * (1 + X1_DESIGNS.len()));
        for w in far_pressure() {
            for d in X1_DESIGNS {
                let r = db.get(w.name, d).expect("x1 result cached");
                assert_eq!(r.design, d.name());
                assert_eq!(
                    r.tier.is_some(),
                    d.is_tiered(),
                    "{} {}: tier stats iff tiered placement",
                    w.name,
                    d.name()
                );
                if let Some(t) = &r.tier {
                    assert_eq!(t.total_accesses(), r.bw.total(), "{} {}", w.name, d.name());
                }
            }
            assert!(db.speedup(w.name, X1_DESIGNS[4]).is_some(), "tiered-cram-dyn ran");
        }
    }

    #[test]
    fn x1_sweep_caches_each_ratio_independently() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 5,
            threads: 4,
        });
        let ratios = [0.25, 0.75];
        db.run_x1_sweep(&ratios, false);
        let tiered: Vec<Design> =
            X1_DESIGNS.into_iter().filter(Design::is_tiered).collect();
        assert_eq!(
            db.len(),
            far_pressure().len() * (1 + tiered.len() * ratios.len())
        );
        for w in far_pressure() {
            for &d in &tiered {
                for r in ratios {
                    let run = db.get_far(w.name, d, r).expect("sweep run cached");
                    assert!(run.tier.is_some(), "{} {} @{r}", w.name, d.name());
                    assert!(db.speedup_far(w.name, d, r).is_some());
                }
            }
        }
    }

    #[test]
    fn l1_matrix_pairs_each_design_with_its_lc_twin() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 6,
            threads: 4,
        });
        db.run_l1(false);
        assert_eq!(db.len(), far_pressure().len() * (1 + L1_DESIGNS.len()));
        for w in far_pressure() {
            for d in L1_DESIGNS {
                let r = db.get(w.name, d).expect("l1 result cached");
                assert_eq!(r.design, d.name());
                let t = r.tier.as_ref().expect("l1 designs are tiered");
                // conservation: wire bytes never exceed raw bytes, and a
                // raw link moves every byte at full width
                assert!(
                    t.link_traffic.wire_bytes() <= t.link_traffic.raw_bytes(),
                    "{} {}", w.name, d.name()
                );
                if !d.link_compressed() {
                    assert_eq!(
                        t.link_traffic.wire_bytes(),
                        t.link_traffic.raw_bytes(),
                        "{} {}", w.name, d.name()
                    );
                    assert_eq!(t.link_traffic.flits_saved, 0, "{} {}", w.name, d.name());
                }
            }
        }
        // across the matrix, link compression must actually save traffic
        // (per-run: wire ≤ raw is asserted above for every composition)
        let mut saved = 0u64;
        for w in far_pressure() {
            for i in 0..3 {
                let lc = db.get(w.name, L1_DESIGNS[i + 3]).unwrap();
                let tl = lc.tier.as_ref().unwrap();
                saved += tl.link_traffic.raw_bytes() - tl.link_traffic.wire_bytes();
                assert!(db.speedup(w.name, L1_DESIGNS[i + 3]).is_some());
            }
        }
        assert!(saved > 0, "link compression must save bytes somewhere in the matrix");
    }

    #[test]
    fn p1_matrix_covers_both_layout_families() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 8,
            threads: 4,
        });
        db.run_p1(false);
        assert_eq!(db.len(), (27 + far_pressure().len()) * P1_DESIGNS.len());
        let lcp_flat = Design::new(Policy::Lcp, Placement::Flat);
        let lcp_far = Design::new(Policy::Lcp, Placement::Tiered);
        for w in far_pressure() {
            for d in [lcp_flat, lcp_far] {
                let r = db.get(w.name, d).expect("p1 lcp run cached");
                assert_eq!(r.design, d.name());
                assert!(
                    r.capacity.is_some(),
                    "{} {}: the page family reports a capacity ledger",
                    w.name,
                    d.name()
                );
                assert!(
                    r.llp_accuracy.is_none(),
                    "{} {}: no line-location predictor to report",
                    w.name,
                    d.name()
                );
                assert!(db.speedup(w.name, d).is_some());
            }
            // the line family owns no page ledger — its capacity column
            // is honestly n/a, not zero
            assert!(db.get(w.name, Design::Implicit).unwrap().capacity.is_none());
        }
    }

    #[test]
    fn m1_matrix_reports_per_tenant_rows_and_qos_contrast() {
        let plan = RunPlan { insts_per_core: 8_000, seed: 3, threads: 4 };
        let (runs, qos) = run_m1(&plan, false);
        assert_eq!(runs.len(), m1_mixes().len() * M1_DESIGNS.len());
        for r in &runs {
            assert!(!r.result.tenants.is_empty(), "{} {}", r.mix, r.design.name());
            for t in &r.result.tenants {
                let s = t.slowdown.expect("slowdown-vs-alone populated");
                assert!(s.is_finite() && s > 0.0, "{} {}: {s}", r.mix, t.name);
            }
        }
        let q = qos.expect("one mix carries a :qos mark");
        assert_eq!(q.reserved, M1_QOS_RESERVED);
        assert!(q.base.tenants.iter().any(|t| t.protected));
        assert!(q.qos.tenants.iter().any(|t| t.protected));
    }

    #[test]
    fn r1_sweep_covers_every_ber_and_watchdog_point() {
        let plan = RunPlan { insts_per_core: 8_000, seed: 3, threads: 4 };
        let runs = run_r1(&plan, false);
        assert_eq!(runs.len(), R1_BERS.len() * 2);
        for r in &runs {
            assert!(r.result.cycles > 0, "ber {} dog {}", r.ber, r.watchdog);
            // detection is total at every point: nothing slips through
            assert_eq!(r.result.rel.silent_misreads, 0);
            assert_eq!(r.result.rel.marker_detected, r.result.rel.marker_errors);
            if r.ber == 0.0 {
                assert!(r.result.rel.is_zero(), "clean point: {:?}", r.result.rel);
            }
            if !r.watchdog {
                assert_eq!(r.result.rel.watchdog_degrades, 0);
                assert_eq!(r.result.rel.degraded_epochs, 0);
            }
        }
        // the clean points bracket the sweep: injection off is the same
        // run with and without the watchdog armed (bit-identity)
        let clean: Vec<_> = runs.iter().filter(|r| r.ber == 0.0).collect();
        assert_eq!(clean[0].result.cycles, clean[1].result.cycles);
        // somewhere in the swept decades the injectors must actually fire
        assert!(
            runs.iter().any(|r| r.result.rel.flits_retried > 0),
            "1e-2 over a far-pressure run must retry flits"
        );
    }

    #[test]
    fn t1_matrix_covers_far_pressure_set() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 30_000,
            seed: 2,
            threads: 4,
        });
        db.run_tiered_t1(false);
        assert_eq!(db.len(), far_pressure().len() * 3);
        for w in far_pressure() {
            for d in TIERED_DESIGNS {
                let r = db.get(w.name, d).expect("tiered result cached");
                assert!(r.tier.is_some(), "{} {} has tier stats", w.name, d.name());
            }
            assert!(db.get(w.name, Design::Uncompressed).is_some());
        }
    }
}
