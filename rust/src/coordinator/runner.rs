//! Parallel simulation runner + striped results cache.
//!
//! The experiment engine behind every exhibit: batches of seed-
//! deterministic simulation jobs drain through the shared work pool
//! ([`crate::coordinator::pool`]), land in a **lock-striped**
//! [`ResultsDb`] (results sharded by [`RunKey`] hash, merged
//! shard-parallel at batch end), and optionally persist to a versioned
//! on-disk cache ([`crate::coordinator::persist`]) so re-rendering a
//! figure or resuming an interrupted `repro sweep` reuses completed
//! runs across invocations.  DESIGN.md §Experiment engine documents the
//! contracts.

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use crate::controller::{Design, LinkCodec, Placement, Policy};
use crate::coordinator::persist;
use crate::coordinator::pool::{self, Progress};
use crate::dram::SchedConfig;
use crate::sim::{simulate, simulate_tenants, FaultConfig, SimConfig};
use crate::stats::SimResult;
use crate::workloads::profiles::{
    all27, all64, cache_pressure, far_pressure, latency_sensitive, WorkloadProfile,
};
use crate::workloads::tenant::m1_mixes;
use crate::workloads::parse_tenants;

/// Key identifying one simulation run.  The `Ord` derive gives the
/// persistent cache its canonical on-disk entry order (and the
/// determinism tests their canonical serialization).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RunKey {
    pub workload: String,
    pub design: &'static str,
    pub channels: usize,
    /// Far-tier capacity split in thousandths (0 for flat designs), so
    /// tiered runs at different ratios never collide in the cache.
    pub far_mill: u16,
    /// Compressed LLC (Figure C1 runs) — plain-LLC runs use `false`.
    pub llc_comp: bool,
}

/// Far ratio → cache-key thousandths.
fn far_mill_of(far_ratio: Option<f64>) -> u16 {
    far_ratio.map(|r| (r * 1000.0).round() as u16).unwrap_or(0)
}

/// What to simulate.
#[derive(Clone, Debug)]
pub struct RunPlan {
    pub insts_per_core: u64,
    pub seed: u64,
    pub threads: usize,
}

impl Default for RunPlan {
    fn default() -> Self {
        Self {
            insts_per_core: 2_000_000,
            seed: 0xC0DE,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        }
    }
}

/// One simulation job.
#[derive(Clone)]
struct Job {
    profile: WorkloadProfile,
    design: Design,
    channels: usize,
    /// Far-tier capacity fraction for tiered designs (None = flat).
    far_ratio: Option<f64>,
    /// Run with the compressed LLC (Figure C1).
    llc_comp: bool,
}

impl Job {
    /// Tiered designs always simulate (and cache) at the Figure T1 split,
    /// matching the `far_mill` that [`ResultsDb::get_ch`] looks up — so a
    /// tiered job enqueued through any matrix path stays reachable.
    fn new(profile: WorkloadProfile, design: Design, channels: usize) -> Self {
        let far_ratio = design.is_tiered().then_some(T1_FAR_RATIO);
        Self { profile, design, channels, far_ratio, llc_comp: false }
    }

    /// Same design, compressed LLC (Figure C1's second column family).
    fn new_comp(profile: WorkloadProfile, design: Design, channels: usize) -> Self {
        Self { llc_comp: true, ..Self::new(profile, design, channels) }
    }

    fn key(&self) -> RunKey {
        RunKey {
            workload: self.profile.name.to_string(),
            design: self.design.name(),
            channels: self.channels,
            far_mill: far_mill_of(self.far_ratio),
            llc_comp: self.llc_comp,
        }
    }

    /// Equalize LLC-access counts across workloads: scale the
    /// instruction budget so every run issues a similar number of
    /// accesses (anchored at apki=30) — low-APKI workloads need
    /// proportionally more instructions to traverse their arrays the
    /// same number of times.  Each workload's speedup compares runs of
    /// equal length, so this only equalizes simulation cost.
    fn scaled_insts(&self, plan: &RunPlan) -> u64 {
        let apki = if self.profile.apki > 0.0 {
            self.profile.apki
        } else {
            // MIX: scale by the mean APKI of the components
            let comps: Vec<f64> = self
                .profile
                .mix_of
                .iter()
                .filter_map(|n| crate::workloads::profiles::by_name(n))
                .map(|p| p.apki)
                .collect();
            comps.iter().sum::<f64>() / comps.len().max(1) as f64
        };
        ((plan.insts_per_core as f64 * 30.0 / apki) as u64)
            .clamp(plan.insts_per_core / 4, plan.insts_per_core * 6)
    }

    /// Relative duration estimate for the pool's longest-first
    /// scheduling: the scaled instruction budget, marked up for the
    /// paths that cost more per instruction (link serialization on the
    /// tiered executor, superblock bookkeeping in the compressed LLC).
    fn cost(&self, plan: &RunPlan) -> f64 {
        let mut c = self.scaled_insts(plan) as f64;
        if self.design.is_tiered() {
            c *= 1.5;
        }
        if self.llc_comp {
            c *= 1.2;
        }
        c
    }

    /// Execute the job.  2x warmup: the LLC, memory layout AND the
    /// Dynamic gate must all reach steady state before measurement (the
    /// paper's 1B-inst slices warm up for free).
    fn run(&self, plan: &RunPlan) -> SimResult {
        let insts = self.scaled_insts(plan);
        let mut b = SimConfig::builder()
            .design(self.design)
            .seed(plan.seed)
            .insts(insts)
            .warmup(insts * 2)
            .channels(self.channels);
        if let Some(r) = self.far_ratio {
            b = b.far_ratio(r);
        }
        if self.llc_comp {
            b = b.compressed_llc();
        }
        simulate(&self.profile, &b.build())
    }
}

/// The designs every per-workload figure compares.
pub const CORE_DESIGNS: [Design; 7] = [
    Design::Uncompressed,
    Design::Ideal,
    Design::explicit(false),
    Design::explicit(true),
    Design::Implicit,
    Design::Dynamic,
    Design::NextLinePrefetch,
];

/// The tiered-memory designs (Figure T1).
pub const TIERED_DESIGNS: [Design; 2] = [
    Design::tiered(false),
    Design::tiered(true),
];

/// Far-tier capacity fraction used by the Figure T1 evaluation: three
/// quarters of capacity behind the link, i.e. a deployment that bought
/// expansion because it needed it.
pub const T1_FAR_RATIO: f64 = 0.75;

/// The designs the Figure Q1 tail-latency exhibit compares:
/// uncompressed baseline, explicit-metadata CRAM (serialized lookups in
/// the tail), and Dynamic-CRAM.
pub const Q1_DESIGNS: [Design; 3] = [
    Design::Uncompressed,
    Design::explicit(false),
    Design::Dynamic,
];

/// The memory-side designs the Figure C1 compressed-LLC exhibit crosses
/// with the LLC organization (cache compression × memory compression).
pub const C1_DESIGNS: [Design; 2] = [Design::Implicit, Design::Dynamic];

/// The Figure X1 cross-product: {static, dynamic, explicit} × {flat,
/// tiered} — the design space the composable controller opened.  Tiered
/// columns run at the T1 capacity split.
pub const X1_DESIGNS: [Design; 6] = [
    Design::Implicit,
    Design::Dynamic,
    Design::explicit(false),
    Design::tiered(true), // Implicit × Tiered
    Design::new(Policy::Dynamic, Placement::Tiered),
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
];

/// The Figure L1 matrix: {static, dynamic, explicit} tiered designs,
/// each with a raw link and with the compressed link (`+lc`) — the
/// third-axis exhibit.  Rows pair each design with its `+lc` twin so the
/// figure answers where link compression still pays once storage
/// compression has already shrunk the transfers.
pub const L1_DESIGNS: [Design; 6] = [
    Design::tiered(true), // Implicit × Tiered
    Design::new(Policy::Dynamic, Placement::Tiered),
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
    Design::tiered(true).with_link_codec(LinkCodec::Compressed),
    Design::new(Policy::Dynamic, Placement::Tiered).with_link_codec(LinkCodec::Compressed),
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered)
        .with_link_codec(LinkCodec::Compressed),
];

/// The Figure P1 layout-family matrix: the line-granular CRAM layouts
/// (implicit metadata, gated, explicit) next to the LCP page-granular
/// layout, each flat and on the far expander.  The uncompressed flat and
/// tiered baselines anchor the speedups; every other column answers the
/// same question from a different layout family: what does the layout
/// authority cost in metadata traffic, and what does it buy in effective
/// capacity?  Tiered columns run at the T1 capacity split.
pub const P1_DESIGNS: [Design; 9] = [
    Design::Uncompressed,
    Design::Implicit,
    Design::Dynamic,
    Design::explicit(false),
    Design::new(Policy::Lcp, Placement::Flat),
    Design::tiered(false),
    Design::tiered(true), // Implicit × Tiered
    Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
    Design::new(Policy::Lcp, Placement::Tiered),
];

/// The designs the Figure M1 multi-tenant exhibit compares: uncompressed
/// sharing, flat Dynamic-CRAM, and tiered Dynamic-CRAM at the T1 split.
pub const M1_DESIGNS: [Design; 3] = [
    Design::Uncompressed,
    Design::Dynamic,
    Design::new(Policy::Dynamic, Placement::Tiered),
];

/// Read slots the M1 QoS contrast run reserves for the protected tenant
/// (out of [`SchedConfig::default`]'s 32 per channel).  Deliberately
/// aggressive so the shift in the protected tenant's tail is visible
/// even at smoke-test instruction budgets.
pub const M1_QOS_RESERVED: usize = 24;

/// One shared-tenancy simulation from the Figure M1 matrix.
pub struct M1Run {
    pub mix: &'static str,
    pub design: Design,
    pub result: SimResult,
}

/// The Figure M1 QoS contrast: the `:qos`-marked mix re-run with
/// read-slot reservation enabled, next to its unreserved baseline.
pub struct M1Qos {
    pub mix: &'static str,
    pub design: Design,
    pub reserved: usize,
    pub read_slots: usize,
    pub base: SimResult,
    pub qos: SimResult,
}

/// Run the Figure M1 matrix: each canonical tenant mix under each M1
/// design (shared run + per-tenant solo reruns for the slowdown metric),
/// plus one QoS contrast run of the `:qos`-marked mix with read slots
/// reserved.  Tenant runs carry per-tenant state that the [`RunKey`]
/// cache does not key on, so this returns results directly instead of
/// populating a [`ResultsDb`].
pub fn run_m1(plan: &RunPlan, progress: bool) -> (Vec<M1Run>, Option<M1Qos>) {
    #[derive(Clone, Copy)]
    struct M1Job {
        mix: &'static str,
        spec: &'static str,
        design: Design,
        reserved: usize,
    }
    let mut jobs: Vec<M1Job> = Vec::new();
    for (mix, spec) in m1_mixes() {
        for d in M1_DESIGNS {
            jobs.push(M1Job { mix, spec, design: d, reserved: 0 });
        }
    }
    let qos_mix = m1_mixes().into_iter().find(|(_, s)| s.contains(":qos"));
    if let Some((mix, spec)) = qos_mix {
        jobs.push(M1Job { mix, spec, design: Design::Dynamic, reserved: M1_QOS_RESERVED });
    }

    let descs = jobs.clone();
    let results = pool::drain_jobs(
        jobs,
        plan.threads,
        // shared run + one solo rerun per tenant → cost ∝ tenant count
        |j| 1.0 + j.spec.split(',').count() as f64,
        progress.then_some(Progress { label: "tenant mixes done", every: 1 }),
        |job| {
            let mut b = SimConfig::builder()
                .design(job.design)
                .seed(plan.seed)
                .insts(plan.insts_per_core)
                .warmup(plan.insts_per_core * 2);
            if job.design.is_tiered() {
                b = b.far_ratio(T1_FAR_RATIO);
            }
            if job.reserved > 0 {
                b = b.sched(SchedConfig {
                    reserved_slots: job.reserved,
                    ..Default::default()
                });
            }
            let cfg = b.build();
            let specs = parse_tenants(job.spec, cfg.cores).expect("m1 mixes parse");
            simulate_tenants(&specs, &cfg)
        },
    );

    let mut runs = Vec::new();
    let mut qos_run: Option<SimResult> = None;
    for (j, r) in descs.iter().zip(results) {
        if j.reserved > 0 {
            qos_run = Some(r);
        } else {
            runs.push(M1Run { mix: j.mix, design: j.design, result: r });
        }
    }
    let qos = qos_mix.and_then(|(mix, _)| {
        let q = qos_run.take()?;
        let base = runs
            .iter()
            .find(|r| r.mix == mix && r.design.name() == Design::Dynamic.name())?;
        Some(M1Qos {
            mix,
            design: Design::Dynamic,
            reserved: M1_QOS_RESERVED,
            read_slots: SchedConfig::default().read_slots,
            base: base.result.clone(),
            qos: q,
        })
    });
    (runs, qos)
}

/// The Figure R1 BER sweep points: clean baseline plus three decades of
/// uniform bit-error rate across every injection site.
pub const R1_BERS: [f64; 4] = [0.0, 1e-4, 1e-3, 1e-2];

/// The design the Figure R1 exhibit stresses: the CRAM-compressed far
/// tier, whose link flits, far-media reads and marker tails are all
/// exposed to injection at once.
pub const R1_DESIGN: Design = Design::tiered(true);

/// The far-pressure workload Figure R1 sweeps (the Figure T1 anchor).
pub const R1_WORKLOAD: &str = "cap_stream";

/// One point of the Figure R1 reliability sweep.
pub struct R1Run {
    pub ber: f64,
    pub watchdog: bool,
    pub result: SimResult,
}

/// Run the Figure R1 matrix: [`R1_WORKLOAD`] under [`R1_DESIGN`] at each
/// BER in [`R1_BERS`], with the error-storm watchdog disarmed and armed.
/// Fault runs carry injector state the [`RunKey`] cache does not key on,
/// so — like [`run_m1`] — this returns results directly instead of
/// populating a [`ResultsDb`].
pub fn run_r1(plan: &RunPlan, progress: bool) -> Vec<R1Run> {
    #[derive(Clone, Copy)]
    struct R1Job {
        ber: f64,
        watchdog: bool,
    }
    let mut jobs: Vec<R1Job> = Vec::new();
    for &ber in &R1_BERS {
        for watchdog in [false, true] {
            jobs.push(R1Job { ber, watchdog });
        }
    }
    let profile =
        crate::workloads::profiles::by_name(R1_WORKLOAD).expect("r1 workload exists");

    let descs = jobs.clone();
    let results = pool::drain_jobs(
        jobs,
        plan.threads,
        // every point runs the same workload/design/budget — uniform
        // cost keeps the drain order FIFO
        |_| 1.0,
        progress.then_some(Progress { label: "BER points done", every: 1 }),
        |job| {
            let mut fault = FaultConfig::uniform(job.ber);
            fault.watchdog = job.watchdog;
            let cfg = SimConfig::builder()
                .design(R1_DESIGN)
                .far_ratio(T1_FAR_RATIO)
                .seed(plan.seed)
                .insts(plan.insts_per_core)
                .warmup(plan.insts_per_core * 2)
                .fault(fault)
                .build();
            simulate(&profile, &cfg)
        },
    );

    descs
        .iter()
        .zip(results)
        .map(|(j, r)| R1Run { ber: j.ber, watchdog: j.watchdog, result: r })
        .collect()
}

/// Number of result stripes (power of two, see [`ResultsDb::stripe`]).
/// Sized for the thread counts the pool actually runs (≤ a few dozen):
/// with FNV-mixed keys, 16 stripes keep merge collisions rare without
/// fragmenting lookups.
const RESULT_SHARDS: usize = 16;

/// What one [`ResultsDb`] batch did — the figure callers can ignore it;
/// `repro sweep` aggregates these into its telemetry, the campaign
/// bench turns `executed / wall` into jobs/s, and the cache tests pin
/// `from_cache` / `duplicates` accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Jobs submitted to the batch before any filtering.
    pub requested: usize,
    /// In-batch duplicate keys dropped (overlapping sub-matrices).
    pub duplicates: usize,
    /// Jobs satisfied by an already-present result (in-memory or loaded
    /// from the persistent cache).
    pub from_cache: usize,
    /// Simulations actually executed.
    pub executed: usize,
    pub wall: Duration,
}

impl BatchStats {
    /// Executed-simulation throughput over the batch wall time.
    pub fn jobs_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.executed as f64 / secs
        } else {
            0.0
        }
    }

    /// Fraction of submitted jobs served without simulating.
    pub fn cached_frac(&self) -> f64 {
        self.from_cache as f64 / self.requested.max(1) as f64
    }

    /// Fold another batch into this aggregate.
    pub fn absorb(&mut self, o: &BatchStats) {
        self.requested += o.requested;
        self.duplicates += o.duplicates;
        self.from_cache += o.from_cache;
        self.executed += o.executed;
        self.wall += o.wall;
    }
}

/// What [`ResultsDb::attach_cache`] found on disk.
pub struct CacheLoad {
    /// Runs loaded into the stripes.
    pub loaded: usize,
    /// Why a present cache file was ignored (stale fingerprint, parse
    /// error) — `None` on a clean load or a cold start.
    pub note: Option<String>,
}

struct PersistTarget {
    path: std::path::PathBuf,
    fingerprint: String,
}

/// Results cache for the full evaluation, lock-striped by [`RunKey`]
/// hash.  Workers never touch the stripes: the pool hands each batch
/// back as per-thread buffers, and [`ResultsDb::merge`] distributes
/// them shard-parallel under `&mut self` — disjoint `&mut` per stripe,
/// no locks anywhere on the result path, and the borrow-returning
/// getters (`get*` → `Option<&SimResult>`) stay exactly as cheap as a
/// plain `HashMap`.
pub struct ResultsDb {
    pub plan: RunPlan,
    shards: Vec<HashMap<RunKey, SimResult>>,
    persist_to: Option<PersistTarget>,
}

impl ResultsDb {
    pub fn new(plan: RunPlan) -> Self {
        Self {
            plan,
            shards: (0..RESULT_SHARDS).map(|_| HashMap::new()).collect(),
            persist_to: None,
        }
    }

    /// Stripe index for a key — FNV-1a over the canonical key bytes, so
    /// the layout is deterministic across runs and Rust versions
    /// (`DefaultHasher` promises neither).
    fn stripe(key: &RunKey) -> usize {
        let mut bytes = Vec::with_capacity(key.workload.len() + key.design.len() + 13);
        bytes.extend_from_slice(key.workload.as_bytes());
        bytes.push(0); // field separator: names never contain NUL
        bytes.extend_from_slice(key.design.as_bytes());
        bytes.push(0);
        bytes.extend_from_slice(&(key.channels as u64).to_le_bytes());
        bytes.extend_from_slice(&key.far_mill.to_le_bytes());
        bytes.push(key.llc_comp as u8);
        (crate::util::fnv1a64(&bytes) as usize) & (RESULT_SHARDS - 1)
    }

    fn lookup(&self, key: &RunKey) -> Option<&SimResult> {
        self.shards[Self::stripe(key)].get(key)
    }

    fn insert(&mut self, key: RunKey, r: SimResult) {
        let s = Self::stripe(&key);
        self.shards[s].insert(key, r);
    }

    /// Merge a finished batch into the stripes.  Large batches
    /// partition by stripe and insert shard-parallel (disjoint `&mut`
    /// per stripe via scoped threads); small batches are not worth the
    /// thread spawns.
    fn merge(&mut self, pairs: Vec<(RunKey, SimResult)>) {
        const PARALLEL_MERGE_MIN: usize = 64;
        if pairs.len() < PARALLEL_MERGE_MIN {
            for (k, v) in pairs {
                self.insert(k, v);
            }
            return;
        }
        let mut striped: Vec<Vec<(RunKey, SimResult)>> =
            (0..RESULT_SHARDS).map(|_| Vec::new()).collect();
        for (k, v) in pairs {
            striped[Self::stripe(&k)].push((k, v));
        }
        std::thread::scope(|scope| {
            for (shard, batch) in self.shards.iter_mut().zip(striped) {
                if batch.is_empty() {
                    continue;
                }
                scope.spawn(move || {
                    for (k, v) in batch {
                        shard.insert(k, v);
                    }
                });
            }
        });
    }

    /// Every cached run, sorted by the canonical [`RunKey`] order.
    fn sorted_pairs(&self) -> Vec<(&RunKey, &SimResult)> {
        let mut pairs: Vec<(&RunKey, &SimResult)> =
            self.shards.iter().flat_map(|s| s.iter()).collect();
        pairs.sort_by_key(|(k, _)| *k);
        pairs
    }

    /// Canonical serialization of the whole db — the persistent-cache
    /// file format, and the byte string the sharding determinism tests
    /// compare (`threads=1` vs `threads=N` must be identical).
    pub fn serialize(&self) -> String {
        persist::encode(&persist::fingerprint(&self.plan), &self.plan, &self.sorted_pairs())
    }

    /// Attach a persistent cache file: load compatible results from
    /// `path` (unless `refresh`), then arm write-back so every executed
    /// batch re-saves the db.  A cache written under a different
    /// fingerprint — other schema, crate version, probe semantics, or
    /// plan — is ignored wholesale, never partially trusted.
    pub fn attach_cache(&mut self, path: &str, refresh: bool) -> CacheLoad {
        let fingerprint = persist::fingerprint(&self.plan);
        let mut load = CacheLoad { loaded: 0, note: None };
        if !refresh {
            // a missing file is the normal cold start, not an error
            if let Ok(text) = std::fs::read_to_string(path) {
                match persist::decode(&text, &fingerprint) {
                    Ok(pairs) => {
                        load.loaded = pairs.len();
                        for (k, v) in pairs {
                            self.insert(k, v);
                        }
                    }
                    Err(e) => load.note = Some(e),
                }
            }
        }
        self.persist_to = Some(PersistTarget { path: path.into(), fingerprint });
        load
    }

    /// Write every cached run to the attached cache file (no-op when
    /// none is attached).  Runs at the end of each executed batch, so
    /// an interrupted campaign resumes from its last completed batch;
    /// write-then-rename keeps a torn write from clobbering the
    /// previous cache.
    fn save_cache(&self) {
        let Some(p) = &self.persist_to else { return };
        let text = persist::encode(&p.fingerprint, &self.plan, &self.sorted_pairs());
        let tmp = p.path.with_extension("tmp");
        let wrote = std::fs::write(&tmp, text).and_then(|()| std::fs::rename(&tmp, &p.path));
        if let Err(e) = wrote {
            eprintln!("warning: could not persist results cache to {}: {e}", p.path.display());
        }
    }

    /// Run the complete matrix needed by every figure and table:
    /// * all 27 memory-intensive workloads × 7 designs @ 2 channels,
    /// * the 37 extra low-MPKI workloads × {baseline, dynamic} (Fig. 18),
    /// * all 27 × {baseline, dynamic} @ 1 and 4 channels (Table IV).
    pub fn run_full_matrix(&mut self, progress: bool) -> BatchStats {
        let mut jobs: Vec<Job> = Vec::new();
        for w in all27() {
            for d in CORE_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        let names27: std::collections::HashSet<_> =
            all27().iter().map(|w| w.name).collect();
        for w in all64() {
            if !names27.contains(w.name) {
                for d in [Design::Uncompressed, Design::Dynamic] {
                    jobs.push(Job::new(w.clone(), d, 2));
                }
            }
        }
        for w in all27() {
            for ch in [1usize, 4] {
                for d in [Design::Uncompressed, Design::Dynamic] {
                    jobs.push(Job::new(w.clone(), d, ch));
                }
            }
        }
        jobs.extend(Self::t1_jobs());
        jobs.extend(Self::q1_extra_jobs());
        jobs.extend(Self::c1_jobs());
        jobs.extend(Self::x1_jobs());
        jobs.extend(Self::l1_jobs());
        jobs.extend(Self::p1_jobs());
        self.run_jobs(jobs, progress)
    }

    /// The Figure P1 matrix: the 27-workload suite plus the far-pressure
    /// set, each under the [`P1_DESIGNS`] layout families (the flat and
    /// tiered uncompressed baselines ride inside the design list).
    fn p1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in all27().into_iter().chain(far_pressure()) {
            for d in P1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure P1 matrix only.
    pub fn run_p1(&mut self, progress: bool) -> BatchStats {
        self.run_jobs(Self::p1_jobs(), progress)
    }

    /// The Figure L1 matrix: far-memory-pressure workloads × the
    /// raw/compressed-link pairs of [`L1_DESIGNS`], plus the flat
    /// uncompressed baseline for absolute speedups.
    fn l1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in L1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure L1 matrix only.
    pub fn run_l1(&mut self, progress: bool) -> BatchStats {
        self.run_jobs(Self::l1_jobs(), progress)
    }

    /// The Figure C1 matrix: the 27 suite plus the cache-pressure set,
    /// each under {static, dynamic} CRAM × {plain, compressed} LLC, with
    /// a plain-LLC uncompressed baseline for the speedup denominator.
    fn c1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in all27().into_iter().chain(cache_pressure()) {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in C1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
                jobs.push(Job::new_comp(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure C1 matrix only.
    pub fn run_c1(&mut self, progress: bool) -> BatchStats {
        self.run_jobs(Self::c1_jobs(), progress)
    }

    /// The Figure Q1 jobs not already covered by the core matrix: the
    /// latency-sensitive workloads under the Q1 design triple (the 27
    /// paper workloads run these designs via `CORE_DESIGNS`).
    fn q1_extra_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in latency_sensitive() {
            for d in Q1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure Q1 matrix: the 27-workload suite plus the
    /// latency-sensitive set, each under the Q1 design triple.
    pub fn run_q1(&mut self, progress: bool) -> BatchStats {
        let mut jobs = Vec::new();
        for w in all27() {
            for d in Q1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs.extend(Self::q1_extra_jobs());
        self.run_jobs(jobs, progress)
    }

    /// The Figure T1 matrix: far-memory-pressure workloads × {flat DDR,
    /// uncompressed far tier, CRAM-compressed far tier} at the T1 split.
    fn t1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in TIERED_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure T1 matrix only.
    pub fn run_tiered_t1(&mut self, progress: bool) -> BatchStats {
        self.run_jobs(Self::t1_jobs(), progress)
    }

    /// The Figure X1 matrix: far-memory-pressure workloads × the
    /// {static, dynamic, explicit} × {flat, tiered} cross-product, plus
    /// the flat uncompressed baseline for the speedup denominator.
    fn x1_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in X1_DESIGNS {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        jobs
    }

    /// Run the Figure X1 matrix only.
    pub fn run_x1(&mut self, progress: bool) -> BatchStats {
        self.run_jobs(Self::x1_jobs(), progress)
    }

    /// The Figure X1 far-ratio sweep: every tiered composition from the
    /// X1 cross-product re-run at each requested capacity split, plus
    /// the flat uncompressed baseline the speedups divide by (which does
    /// not depend on the split).  Results land in the cache keyed by
    /// `far_mill`, so sweep ratios never collide with the T1-split runs.
    pub fn run_x1_sweep(&mut self, ratios: &[f64], progress: bool) -> BatchStats {
        let mut jobs = Vec::new();
        for w in far_pressure() {
            jobs.push(Job::new(w.clone(), Design::Uncompressed, 2));
            for d in X1_DESIGNS.into_iter().filter(Design::is_tiered) {
                for &r in ratios {
                    jobs.push(Job {
                        profile: w.clone(),
                        design: d,
                        channels: 2,
                        far_ratio: Some(r),
                        llc_comp: false,
                    });
                }
            }
        }
        self.run_jobs(jobs, progress)
    }

    /// Fetch a tiered run simulated at an explicit far-capacity split
    /// (2 channels, plain LLC) — the sweep counterpart of [`Self::get`].
    pub fn get_far(&self, workload: &str, design: Design, far_ratio: f64) -> Option<&SimResult> {
        self.lookup(&RunKey {
            workload: workload.to_string(),
            design: design.name(),
            channels: 2,
            far_mill: far_mill_of(design.is_tiered().then_some(far_ratio)),
            llc_comp: false,
        })
    }

    /// Speedup over the flat uncompressed baseline at an explicit split.
    pub fn speedup_far(&self, workload: &str, design: Design, far_ratio: f64) -> Option<f64> {
        let base = self.get(workload, Design::Uncompressed)?;
        let r = self.get_far(workload, design, far_ratio)?;
        Some(r.weighted_speedup(base))
    }

    /// Smaller matrix: the 27 workloads × the designs needed by a single
    /// figure (used by per-figure CLI invocations).
    pub fn run_designs(&mut self, designs: &[Design], extended: bool, progress: bool) -> BatchStats {
        let set = if extended { all64() } else { all27() };
        self.run_matrix(&set, designs, progress)
    }

    /// Arbitrary small matrix: `workloads` × `designs` at 2 channels
    /// (the campaign bench and the engine tests drive this directly).
    pub fn run_matrix(
        &mut self,
        workloads: &[WorkloadProfile],
        designs: &[Design],
        progress: bool,
    ) -> BatchStats {
        let mut jobs = Vec::new();
        for w in workloads {
            for &d in designs {
                jobs.push(Job::new(w.clone(), d, 2));
            }
        }
        self.run_jobs(jobs, progress)
    }

    /// One `repro sweep` phase: every one of the 32 design compositions
    /// over `profiles`, plus the optional grid axes — the compressed-LLC
    /// twin of every composition (`llc_grid`), and every tiered
    /// composition re-run at each extra far-capacity split in
    /// `far_ratios` (the T1 split always runs; a ratio equal to it
    /// dedups against the base job inside the batch).
    pub fn run_sweep_matrix(
        &mut self,
        profiles: &[WorkloadProfile],
        far_ratios: &[f64],
        llc_grid: bool,
        progress: bool,
    ) -> BatchStats {
        let mut jobs = Vec::new();
        for w in profiles {
            for d in Design::all() {
                jobs.push(Job::new(w.clone(), d, 2));
                if llc_grid {
                    jobs.push(Job::new_comp(w.clone(), d, 2));
                }
                if d.is_tiered() {
                    for &r in far_ratios {
                        jobs.push(Job {
                            profile: w.clone(),
                            design: d,
                            channels: 2,
                            far_ratio: Some(r),
                            llc_comp: false,
                        });
                    }
                }
            }
        }
        self.run_jobs(jobs, progress)
    }

    pub fn run_channel_sweep(&mut self, progress: bool) -> BatchStats {
        let mut jobs = Vec::new();
        for w in all27() {
            for ch in [1usize, 2, 4] {
                for d in [Design::Uncompressed, Design::Dynamic] {
                    jobs.push(Job::new(w.clone(), d, ch));
                }
            }
        }
        self.run_jobs(jobs, progress)
    }

    fn run_jobs(&mut self, jobs: Vec<Job>, progress: bool) -> BatchStats {
        let t0 = Instant::now();
        let requested = jobs.len();
        let mut duplicates = 0usize;
        let mut from_cache = 0usize;
        // skip already-cached runs and in-batch duplicates (sub-matrices
        // like C1 overlap the core matrix on their plain-LLC runs)
        let mut seen = HashSet::new();
        let jobs: Vec<Job> = jobs
            .into_iter()
            .filter(|j| {
                let key = j.key();
                if self.lookup(&key).is_some() {
                    from_cache += 1;
                    return false;
                }
                if !seen.insert(key) {
                    duplicates += 1;
                    return false;
                }
                true
            })
            .collect();
        let executed = jobs.len();
        if executed > 0 {
            let plan = self.plan.clone();
            let pairs = pool::drain_jobs(
                jobs,
                plan.threads,
                |j| j.cost(&plan),
                progress.then_some(Progress { label: "simulations done", every: 10 }),
                |j| (j.key(), j.run(&plan)),
            );
            self.merge(pairs);
            self.save_cache();
        }
        BatchStats { requested, duplicates, from_cache, executed, wall: t0.elapsed() }
    }

    /// Fetch a cached result (2 channels unless stated).
    pub fn get(&self, workload: &str, design: Design) -> Option<&SimResult> {
        self.get_ch(workload, design, 2)
    }

    pub fn get_ch(&self, workload: &str, design: Design, channels: usize) -> Option<&SimResult> {
        // tiered runs are produced at the Figure T1 split; flat runs at 0
        let far_mill = far_mill_of(design.is_tiered().then_some(T1_FAR_RATIO));
        self.lookup(&RunKey {
            workload: workload.to_string(),
            design: design.name(),
            channels,
            far_mill,
            llc_comp: false,
        })
    }

    /// Fetch a cached result by LLC organization (2 channels; Figure C1).
    pub fn get_llc(&self, workload: &str, design: Design, llc_comp: bool) -> Option<&SimResult> {
        let far_mill = far_mill_of(design.is_tiered().then_some(T1_FAR_RATIO));
        self.lookup(&RunKey {
            workload: workload.to_string(),
            design: design.name(),
            channels: 2,
            far_mill,
            llc_comp,
        })
    }

    /// Speedup of `design` over the uncompressed baseline for a workload.
    pub fn speedup(&self, workload: &str, design: Design) -> Option<f64> {
        let base = self.get(workload, Design::Uncompressed)?;
        let r = self.get(workload, design)?;
        Some(r.weighted_speedup(base))
    }

    pub fn speedup_ch(&self, workload: &str, design: Design, ch: usize) -> Option<f64> {
        let base = self.get_ch(workload, Design::Uncompressed, ch)?;
        let r = self.get_ch(workload, design, ch)?;
        Some(r.weighted_speedup(base))
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(HashMap::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(HashMap::is_empty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_caches_and_parallelizes() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 40_000,
            seed: 1,
            threads: 4,
        });
        db.run_designs(&[Design::Uncompressed, Design::Implicit], false, false);
        assert_eq!(db.len(), 27 * 2);
        let s = db.speedup("libq", Design::Implicit).unwrap();
        assert!(s > 0.5 && s < 3.0, "sane speedup {s}");
        // re-run is a no-op (cache)
        let before = db.len();
        db.run_designs(&[Design::Uncompressed], false, false);
        assert_eq!(db.len(), before);
    }

    #[test]
    fn overlapping_batches_dedup_and_count_cache_hits() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 11,
            threads: 4,
        });
        let libq = crate::workloads::profiles::by_name("libq").unwrap();
        // the same workload submitted twice in one batch: in-batch dedup
        let s1 = db.run_matrix(
            &[libq.clone(), libq.clone()],
            &[Design::Uncompressed, Design::Dynamic],
            false,
        );
        assert_eq!(s1.requested, 4);
        assert_eq!(s1.duplicates, 2);
        assert_eq!(s1.executed, 2);
        assert_eq!(s1.from_cache, 0);
        assert_eq!(db.len(), 2);
        // an overlapping re-submission is served entirely from the stripes
        let s2 = db.run_matrix(&[libq], &[Design::Uncompressed, Design::Dynamic], false);
        assert_eq!(s2.requested, 2);
        assert_eq!(s2.from_cache, 2);
        assert_eq!(s2.executed, 0);
        assert_eq!(s2.cached_frac(), 1.0);
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn sharded_db_matches_single_thread_bit_for_bit() {
        // 90 jobs → exercises the parallel stripe merge path (≥ 64) on
        // both sides; the canonical serialization compares every
        // counter, histogram bucket and float of every run
        let mk = |threads| {
            let mut db = ResultsDb::new(RunPlan {
                insts_per_core: 8_000,
                seed: 42,
                threads,
            });
            db.run_q1(false);
            db
        };
        assert_eq!(mk(1).serialize(), mk(8).serialize());
    }

    #[test]
    fn q1_matrix_covers_latency_set() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 4,
            threads: 4,
        });
        db.run_q1(false);
        assert_eq!(db.len(), (27 + latency_sensitive().len()) * Q1_DESIGNS.len());
        for w in latency_sensitive() {
            for d in Q1_DESIGNS {
                let r = db.get(w.name, d).expect("q1 result cached");
                assert_eq!(r.read_lat.count(), r.bw.demand_reads);
            }
        }
    }

    #[test]
    fn c1_matrix_covers_both_llc_organizations() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 7,
            threads: 4,
        });
        db.run_c1(false);
        let n_wl = 27 + cache_pressure().len();
        // per workload: 1 baseline + 2 designs x {plain, compressed}
        assert_eq!(db.len(), n_wl * (1 + 2 * C1_DESIGNS.len()));
        for w in cache_pressure() {
            assert!(db.get_llc(w.name, Design::Uncompressed, false).is_some());
            for d in C1_DESIGNS {
                let plain = db.get_llc(w.name, d, false).expect("plain run cached");
                let comp = db.get_llc(w.name, d, true).expect("compressed run cached");
                assert!(plain.llc_stats.is_none());
                assert!(comp.llc_stats.is_some(), "{} {}", w.name, d.name());
            }
        }
    }

    #[test]
    fn x1_matrix_covers_the_cross_product() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 9,
            threads: 4,
        });
        db.run_x1(false);
        assert_eq!(db.len(), far_pressure().len() * (1 + X1_DESIGNS.len()));
        for w in far_pressure() {
            for d in X1_DESIGNS {
                let r = db.get(w.name, d).expect("x1 result cached");
                assert_eq!(r.design, d.name());
                assert_eq!(
                    r.tier.is_some(),
                    d.is_tiered(),
                    "{} {}: tier stats iff tiered placement",
                    w.name,
                    d.name()
                );
                if let Some(t) = &r.tier {
                    assert_eq!(t.total_accesses(), r.bw.total(), "{} {}", w.name, d.name());
                }
            }
            assert!(db.speedup(w.name, X1_DESIGNS[4]).is_some(), "tiered-cram-dyn ran");
        }
    }

    #[test]
    fn x1_sweep_caches_each_ratio_independently() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 5,
            threads: 4,
        });
        let ratios = [0.25, 0.75];
        db.run_x1_sweep(&ratios, false);
        let tiered: Vec<Design> =
            X1_DESIGNS.into_iter().filter(Design::is_tiered).collect();
        assert_eq!(
            db.len(),
            far_pressure().len() * (1 + tiered.len() * ratios.len())
        );
        for w in far_pressure() {
            for &d in &tiered {
                for r in ratios {
                    let run = db.get_far(w.name, d, r).expect("sweep run cached");
                    assert!(run.tier.is_some(), "{} {} @{r}", w.name, d.name());
                    assert!(db.speedup_far(w.name, d, r).is_some());
                }
            }
        }
    }

    #[test]
    fn l1_matrix_pairs_each_design_with_its_lc_twin() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 6,
            threads: 4,
        });
        db.run_l1(false);
        assert_eq!(db.len(), far_pressure().len() * (1 + L1_DESIGNS.len()));
        for w in far_pressure() {
            for d in L1_DESIGNS {
                let r = db.get(w.name, d).expect("l1 result cached");
                assert_eq!(r.design, d.name());
                let t = r.tier.as_ref().expect("l1 designs are tiered");
                // conservation: wire bytes never exceed raw bytes, and a
                // raw link moves every byte at full width
                assert!(
                    t.link_traffic.wire_bytes() <= t.link_traffic.raw_bytes(),
                    "{} {}", w.name, d.name()
                );
                if !d.link_compressed() {
                    assert_eq!(
                        t.link_traffic.wire_bytes(),
                        t.link_traffic.raw_bytes(),
                        "{} {}", w.name, d.name()
                    );
                    assert_eq!(t.link_traffic.flits_saved, 0, "{} {}", w.name, d.name());
                }
            }
        }
        // across the matrix, link compression must actually save traffic
        // (per-run: wire ≤ raw is asserted above for every composition)
        let mut saved = 0u64;
        for w in far_pressure() {
            for i in 0..3 {
                let lc = db.get(w.name, L1_DESIGNS[i + 3]).unwrap();
                let tl = lc.tier.as_ref().unwrap();
                saved += tl.link_traffic.raw_bytes() - tl.link_traffic.wire_bytes();
                assert!(db.speedup(w.name, L1_DESIGNS[i + 3]).is_some());
            }
        }
        assert!(saved > 0, "link compression must save bytes somewhere in the matrix");
    }

    #[test]
    fn p1_matrix_covers_both_layout_families() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 8,
            threads: 4,
        });
        db.run_p1(false);
        assert_eq!(db.len(), (27 + far_pressure().len()) * P1_DESIGNS.len());
        let lcp_flat = Design::new(Policy::Lcp, Placement::Flat);
        let lcp_far = Design::new(Policy::Lcp, Placement::Tiered);
        for w in far_pressure() {
            for d in [lcp_flat, lcp_far] {
                let r = db.get(w.name, d).expect("p1 lcp run cached");
                assert_eq!(r.design, d.name());
                assert!(
                    r.capacity.is_some(),
                    "{} {}: the page family reports a capacity ledger",
                    w.name,
                    d.name()
                );
                assert!(
                    r.llp_accuracy.is_none(),
                    "{} {}: no line-location predictor to report",
                    w.name,
                    d.name()
                );
                assert!(db.speedup(w.name, d).is_some());
            }
            // the line family owns no page ledger — its capacity column
            // is honestly n/a, not zero
            assert!(db.get(w.name, Design::Implicit).unwrap().capacity.is_none());
        }
    }

    #[test]
    fn m1_matrix_reports_per_tenant_rows_and_qos_contrast() {
        let plan = RunPlan { insts_per_core: 8_000, seed: 3, threads: 4 };
        let (runs, qos) = run_m1(&plan, false);
        assert_eq!(runs.len(), m1_mixes().len() * M1_DESIGNS.len());
        for r in &runs {
            assert!(!r.result.tenants.is_empty(), "{} {}", r.mix, r.design.name());
            for t in &r.result.tenants {
                let s = t.slowdown.expect("slowdown-vs-alone populated");
                assert!(s.is_finite() && s > 0.0, "{} {}: {s}", r.mix, t.name);
            }
        }
        let q = qos.expect("one mix carries a :qos mark");
        assert_eq!(q.reserved, M1_QOS_RESERVED);
        assert!(q.base.tenants.iter().any(|t| t.protected));
        assert!(q.qos.tenants.iter().any(|t| t.protected));
    }

    #[test]
    fn r1_sweep_covers_every_ber_and_watchdog_point() {
        let plan = RunPlan { insts_per_core: 8_000, seed: 3, threads: 4 };
        let runs = run_r1(&plan, false);
        assert_eq!(runs.len(), R1_BERS.len() * 2);
        for r in &runs {
            assert!(r.result.cycles > 0, "ber {} dog {}", r.ber, r.watchdog);
            // detection is total at every point: nothing slips through
            assert_eq!(r.result.rel.silent_misreads, 0);
            assert_eq!(r.result.rel.marker_detected, r.result.rel.marker_errors);
            if r.ber == 0.0 {
                assert!(r.result.rel.is_zero(), "clean point: {:?}", r.result.rel);
            }
            if !r.watchdog {
                assert_eq!(r.result.rel.watchdog_degrades, 0);
                assert_eq!(r.result.rel.degraded_epochs, 0);
            }
        }
        // the clean points bracket the sweep: injection off is the same
        // run with and without the watchdog armed (bit-identity)
        let clean: Vec<_> = runs.iter().filter(|r| r.ber == 0.0).collect();
        assert_eq!(clean[0].result.cycles, clean[1].result.cycles);
        // somewhere in the swept decades the injectors must actually fire
        assert!(
            runs.iter().any(|r| r.result.rel.flits_retried > 0),
            "1e-2 over a far-pressure run must retry flits"
        );
    }

    #[test]
    fn t1_matrix_covers_far_pressure_set() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 30_000,
            seed: 2,
            threads: 4,
        });
        db.run_tiered_t1(false);
        assert_eq!(db.len(), far_pressure().len() * 3);
        for w in far_pressure() {
            for d in TIERED_DESIGNS {
                let r = db.get(w.name, d).expect("tiered result cached");
                assert!(r.tier.is_some(), "{} {} has tier stats", w.name, d.name());
            }
            assert!(db.get(w.name, Design::Uncompressed).is_some());
        }
    }
}
