//! Generic scoped-thread work pool for simulation batches.
//!
//! One drain loop replaces the three copies that used to live in
//! `runner.rs` (`run_jobs`, `run_m1`, `run_r1`): jobs go into a shared
//! FIFO, scoped worker threads pop until it runs dry, and every worker
//! accumulates its `(submission index, result)` pairs in a **private
//! buffer** that is handed over once at thread exit — so the hot loop
//! never contends on a shared output sink.  The caller gets results in
//! submission order regardless of scheduling.
//!
//! Scheduling is **cost-aware**: jobs are queued longest-estimated
//! first (stable on ties, so equal-cost jobs keep submission order).
//! With per-job costs spanning ~25× (the APKI-scaled instruction
//! budgets of the figure matrices), FIFO order can park the most
//! expensive job last and leave every other worker idle while one
//! straggler finishes; longest-first bounds that makespan tail.
//! Simulations are seed-deterministic and independent, so execution
//! order never changes any result — only the wall clock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Progress reporting for a drain: prints `  [done/total] {label}` to
/// stderr every `every` completions (and always at the last one).
pub struct Progress {
    pub label: &'static str,
    pub every: usize,
}

/// Drain `jobs` across `threads` scoped workers and return the results
/// in **submission order**.
///
/// * `cost` estimates relative job duration (any unit); jobs run
///   longest-estimated first.
/// * `run` executes one job.  It must be deterministic per job for the
///   output to be scheduling-independent — every caller in this crate
///   passes seed-deterministic simulations.
pub fn drain_jobs<J, R, C, F>(
    jobs: Vec<J>,
    threads: usize,
    cost: C,
    progress: Option<Progress>,
    run: F,
) -> Vec<R>
where
    J: Send,
    R: Send,
    C: Fn(&J) -> f64,
    F: Fn(J) -> R + Sync,
{
    let total = jobs.len();
    if total == 0 {
        return Vec::new();
    }
    let mut order: Vec<(f64, usize, J)> = jobs
        .into_iter()
        .enumerate()
        .map(|(idx, j)| (cost(&j), idx, j))
        .collect();
    // longest first; ties (incl. all-equal costs) stay in submission
    // order, so uniform-cost batches drain exactly like the old FIFO
    order.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    let queue: Mutex<VecDeque<(usize, J)>> =
        Mutex::new(order.into_iter().map(|(_, idx, j)| (idx, j)).collect());
    let done = AtomicUsize::new(0);
    // one entry per worker, pushed once at thread exit — not a per-job
    // contention point like the old `Mutex<Vec>` sink
    let collected: Mutex<Vec<Vec<(usize, R)>>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for _ in 0..threads.clamp(1, total) {
            scope.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let job = { queue.lock().unwrap().pop_front() };
                    let Some((idx, job)) = job else { break };
                    local.push((idx, run(job)));
                    let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                    if let Some(p) = &progress {
                        if d % p.every == 0 || d == total {
                            eprintln!("  [{d}/{total}] {}", p.label);
                        }
                    }
                }
                if !local.is_empty() {
                    collected.lock().unwrap().push(local);
                }
            });
        }
    });

    let mut out: Vec<(usize, R)> = collected
        .into_inner()
        .unwrap()
        .into_iter()
        .flatten()
        .collect();
    out.sort_by_key(|(idx, _)| *idx);
    debug_assert_eq!(out.len(), total);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_submission_order() {
        for threads in [1, 4, 16] {
            let jobs: Vec<usize> = (0..100).collect();
            // adversarial cost: later submissions run first
            let out = drain_jobs(jobs, threads, |&j| j as f64, None, |j| j * 10);
            assert_eq!(out, (0..100).map(|j| j * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn single_thread_executes_longest_first() {
        let exec: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let jobs: Vec<usize> = (0..10).collect();
        let out = drain_jobs(jobs, 1, |&j| j as f64, None, |j| {
            exec.lock().unwrap().push(j);
            j
        });
        // output is still submission order...
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        // ...but execution ran in descending cost order
        assert_eq!(exec.into_inner().unwrap(), (0..10).rev().collect::<Vec<_>>());
    }

    #[test]
    fn uniform_costs_preserve_fifo_execution() {
        let exec: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let jobs: Vec<usize> = (0..10).collect();
        drain_jobs(jobs, 1, |_| 1.0, None, |j| {
            exec.lock().unwrap().push(j);
        });
        assert_eq!(exec.into_inner().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batch_and_more_threads_than_jobs() {
        let none: Vec<u32> = Vec::new();
        assert!(drain_jobs(none, 8, |_| 0.0, None, |j| j).is_empty());
        let out = drain_jobs(vec![7u32, 8], 64, |_| 0.0, None, |j| j + 1);
        assert_eq!(out, vec![8, 9]);
    }
}
