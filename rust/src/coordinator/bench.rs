//! The simulator throughput matrix — one definition shared by the
//! `cargo bench --bench simulator` target and the `repro bench` CLI
//! subcommand, so the CI bench job and the local regression gate measure
//! exactly the same thing.
//!
//! Throughput is reported in Melem/s where an element is one simulated
//! instruction (warmup + measurement phases, all cores); `BENCH_sim.json`
//! records the trajectory and `repro bench --check` fails the run when
//! the median regresses beyond tolerance (DESIGN.md §Simulation
//! performance).

use crate::controller::Design;
use crate::coordinator::runner::{BatchStats, ResultsDb, RunPlan};
use crate::sim::{simulate, SimConfig};
use crate::util::bench::{black_box, BenchResult, Bencher};
use crate::workloads::profiles::by_name;

/// Workloads in the matrix: one streaming/compressible, one graph/
/// incompressible — the two ends of the simulator's behaviour space.
pub const BENCH_WORKLOADS: [&str; 2] = ["libq", "pr_twi"];

/// Every core design (the tiered designs run their own exhibit).
pub const BENCH_DESIGNS: [Design; 6] = [
    Design::Uncompressed,
    Design::Ideal,
    Design::explicit(false),
    Design::Implicit,
    Design::Dynamic,
    Design::NextLinePrefetch,
];

/// Instruction budget per core for the campaign-throughput row — small
/// enough that one 12-job batch fits a bench iteration, large enough to
/// exercise the warmup/measure phases of every job.
const CAMPAIGN_INSTS: u64 = 4_000;

/// Run the full (workload × design) simulator bench matrix at
/// `insts` instructions per core.
pub fn run_sim_matrix(insts: u64, b: &Bencher) -> Vec<BenchResult> {
    let mut results: Vec<BenchResult> = Vec::new();
    for wl in BENCH_WORKLOADS {
        println!("# simulator — {wl}, {insts} insts/core x8 cores (+= equal warmup)");
        let profile = by_name(wl).expect("bench workload exists");
        for design in BENCH_DESIGNS {
            let cfg = SimConfig::default().with_design(design).with_insts(insts);
            // throughput denominator: total instructions simulated
            let elems = insts * 8 * 2; // warmup + measure
            results.push(b.run(&format!("{wl}/{}", design.name()), Some(elems), || {
                black_box(simulate(&profile, &cfg));
            }));
        }
        println!();
    }
    println!("# campaign — 12-job batch through the experiment engine (pool + striped merge)");
    results.push(campaign_row(b));
    println!();
    results
}

/// Campaign throughput: the bench matrix's 12 (workload × design) jobs
/// driven through the full experiment engine — job dedup, cost-ordered
/// pool drain, striped merge — with a fresh [`ResultsDb`] per iteration
/// so every job simulates.  Catches engine-level regressions (queue
/// contention, merge cost) that the single-simulation rows can't see.
fn campaign_row(b: &Bencher) -> BenchResult {
    let plan = RunPlan { insts_per_core: CAMPAIGN_INSTS, seed: 0xBE7C, threads: 4 };
    let workloads: Vec<_> = BENCH_WORKLOADS
        .iter()
        .map(|w| by_name(w).expect("bench workload exists"))
        .collect();
    // nominal element count: the engine APKI-scales each job's budget,
    // but deterministically, so the row stays self-consistent across
    // runs — which is all the regression gate compares
    let elems = CAMPAIGN_INSTS * 3 * 8 * 12; // (warmup 2x + measure) x cores x jobs
    let mut last = BatchStats::default();
    let result = b.run("campaign/12-job batch", Some(elems), || {
        let mut db = ResultsDb::new(plan.clone());
        last = db.run_matrix(&workloads, &BENCH_DESIGNS, false);
        black_box(db.len());
    });
    println!(
        "# campaign batch: {} jobs executed/iter, {:.1} jobs/s",
        last.executed,
        last.jobs_per_sec()
    );
    result
}
