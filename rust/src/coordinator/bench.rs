//! The simulator throughput matrix — one definition shared by the
//! `cargo bench --bench simulator` target and the `repro bench` CLI
//! subcommand, so the CI bench job and the local regression gate measure
//! exactly the same thing.
//!
//! Throughput is reported in Melem/s where an element is one simulated
//! instruction (warmup + measurement phases, all cores); `BENCH_sim.json`
//! records the trajectory and `repro bench --check` fails the run when
//! the median regresses beyond tolerance (DESIGN.md §Simulation
//! performance).

use crate::controller::Design;
use crate::sim::{simulate, SimConfig};
use crate::util::bench::{black_box, BenchResult, Bencher};
use crate::workloads::profiles::by_name;

/// Workloads in the matrix: one streaming/compressible, one graph/
/// incompressible — the two ends of the simulator's behaviour space.
pub const BENCH_WORKLOADS: [&str; 2] = ["libq", "pr_twi"];

/// Every core design (the tiered designs run their own exhibit).
pub const BENCH_DESIGNS: [Design; 6] = [
    Design::Uncompressed,
    Design::Ideal,
    Design::explicit(false),
    Design::Implicit,
    Design::Dynamic,
    Design::NextLinePrefetch,
];

/// Run the full (workload × design) simulator bench matrix at
/// `insts` instructions per core.
pub fn run_sim_matrix(insts: u64, b: &Bencher) -> Vec<BenchResult> {
    let mut results: Vec<BenchResult> = Vec::new();
    for wl in BENCH_WORKLOADS {
        println!("# simulator — {wl}, {insts} insts/core x8 cores (+= equal warmup)");
        let profile = by_name(wl).expect("bench workload exists");
        for design in BENCH_DESIGNS {
            let cfg = SimConfig::default().with_design(design).with_insts(insts);
            // throughput denominator: total instructions simulated
            let elems = insts * 8 * 2; // warmup + measure
            results.push(b.run(&format!("{wl}/{}", design.name()), Some(elems), || {
                black_box(simulate(&profile, &cfg));
            }));
        }
        println!();
    }
    results
}
