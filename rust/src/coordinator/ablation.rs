//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! * **LLP size** — the paper picks 512 entries (192 B at the honest
//!   3-bit encoding); how much accuracy do smaller/larger LCTs buy?
//! * **Metadata-cache size** — would a bigger cache rescue the explicit
//!   design (paper argues no for low-locality workloads)?
//! * **Compression algorithm set** — paper §VIII-A: CRAM is orthogonal to
//!   the compressor; FPC+BDI vs FPC+BDI+C-Pack packing rates.
//! * **Marker width** — Fig. 4's argument: how much pair-compressibility
//!   is lost as the reserved marker grows?
//! * **Scheduler geometry** — read-queue depth and write-drain
//!   watermarks vs tail latency (the Figure Q1 knobs).
//! * **Compressed-LLC geometry** — superblock-tag ratio and per-set data
//!   budget vs effective capacity and speedup (the Figure C1 knobs).

use crate::cache::CompressedLlcConfig;
use crate::compress::hybrid::{self, AlgoSet};
use crate::controller::Design;
use crate::coordinator::figures::Report;
use crate::dram::SchedConfig;
use crate::sim::{simulate, SimConfig};
use crate::stats::NS_PER_BUS_CYCLE;
use crate::util::pct;
use crate::workloads::profiles::by_name;
use crate::workloads::SizeOracle;

/// Representative workloads: a streaming winner, a scattered loser, and a
/// pointer-chaser.
const WORKLOADS: [&str; 3] = ["libq", "xz", "mcf17"];

fn run_with(wl: &str, design: Design, insts: u64, f: impl Fn(&mut SimConfig)) -> f64 {
    let p = by_name(wl).unwrap();
    let mut cfg = SimConfig::default().with_design(design).with_insts(insts);
    f(&mut cfg);
    let mut base = cfg.clone();
    base.design = Design::Uncompressed;
    let r = simulate(&p, &cfg);
    let b = simulate(&p, &base);
    r.weighted_speedup(&b)
}

/// LLP size sweep: accuracy and speedup vs LCT entries.
pub fn ablate_llp(insts: u64) -> Report {
    let mut body = format!("{:<10}", "entries");
    for wl in WORKLOADS {
        body.push_str(&format!(" {wl:>16}"));
    }
    body.push('\n');
    for entries in [64usize, 128, 512, 2048] {
        body.push_str(&format!("{entries:<10}"));
        for wl in WORKLOADS {
            let p = by_name(wl).unwrap();
            let mut cfg = SimConfig::default().with_design(Design::Implicit).with_insts(insts);
            cfg.llp_entries = entries;
            let r = simulate(&p, &cfg);
            let cell = match r.llp_accuracy {
                Some(a) => format!("{:.1}% acc", 100.0 * a),
                None => "n/a".into(),
            };
            body.push_str(&format!(" {cell:>13}   "));
        }
        body.push('\n');
    }
    body.push_str(
        "(paper picks 512 entries — 192 bytes at 3b/entry; accuracy saturates quickly)\n",
    );
    Report {
        id: "ablate-llp".into(),
        title: "LLP size ablation (LCT entries vs prediction accuracy)".into(),
        body,
    }
}

/// Metadata-cache size sweep for the explicit design.
pub fn ablate_metacache(insts: u64) -> Report {
    let mut body = format!("{:<10}", "meta$");
    for wl in WORKLOADS {
        body.push_str(&format!(" {wl:>12}"));
    }
    body.push('\n');
    for kb in [8usize, 32, 128, 512] {
        body.push_str(&format!("{:<10}", format!("{kb}KB")));
        for wl in WORKLOADS {
            let s = run_with(wl, Design::explicit(false), insts, |c| {
                c.meta_cache_bytes = kb * 1024;
            });
            body.push_str(&format!(" {:>12}", pct(s)));
        }
        body.push('\n');
    }
    body.push_str(
        "(even large metadata caches do not rescue low-locality workloads —\n the paper's argument for eliminating the lookup entirely)\n",
    );
    Report {
        id: "ablate-metacache".into(),
        title: "Explicit-metadata cache size ablation".into(),
        body,
    }
}

/// Compressor-set ablation: FPC+BDI vs +C-Pack (packing probability and
/// end-to-end speedup).
pub fn ablate_compressor(insts: u64) -> Report {
    let mut body = format!(
        "{:<10} {:>14} {:>14} {:>12} {:>12}\n",
        "workload", "pair60 fpcbdi", "pair60 +cpack", "dyn fpcbdi", "dyn +cpack"
    );
    for wl in ["libq", "soplex", "omnet17", "xz"] {
        let p = by_name(wl).unwrap();
        let pair60 = |algo: AlgoSet| {
            let model = p.value_model(0xF16_4);
            let mut fit = 0u64;
            let n = 2048u64;
            for g in 0..n {
                let a = hybrid::compressed_size_with(&model.gen_line(g * 4, 0), algo);
                let b = hybrid::compressed_size_with(&model.gen_line(g * 4 + 1, 0), algo);
                if a + b <= 60 {
                    fit += 1;
                }
            }
            fit as f64 / n as f64
        };
        let s_base = run_with(wl, Design::Dynamic, insts, |_| {});
        let s_cpack = run_with(wl, Design::Dynamic, insts, |c| {
            c.algo = AlgoSet::FpcBdiCpack;
        });
        body.push_str(&format!(
            "{:<10} {:>13.1}% {:>13.1}% {:>12} {:>12}\n",
            wl,
            100.0 * pair60(AlgoSet::FpcBdi),
            100.0 * pair60(AlgoSet::FpcBdiCpack),
            pct(s_base),
            pct(s_cpack)
        ));
    }
    body.push_str("(paper §VIII-A: CRAM is orthogonal to the compression algorithm)\n");
    Report {
        id: "ablate-compressor".into(),
        title: "Compressor-set ablation: FPC+BDI vs FPC+BDI+C-Pack".into(),
        body,
    }
}

/// Scheduler-geometry ablation: read-queue depth and write-drain
/// watermarks vs p99 read latency and aggregate IPC, under Dynamic-CRAM.
/// Shallow read queues serialize misses; lazy (high/wide) watermarks
/// batch writes into longer read-blocking drains; tight watermarks drain
/// eagerly and steal bus slots more often but in smaller bites.
pub fn ablate_sched(insts: u64) -> Report {
    const WORKLOADS: [&str; 3] = ["lat_wrburst", "lat_chase", "libq"];
    let configs: [(&str, SchedConfig); 4] = [
        ("shallow-8", SchedConfig { read_slots: 8, ..Default::default() }),
        ("default-32", SchedConfig::default()),
        (
            "lazy-drain",
            SchedConfig { write_hi: 60, write_lo: 8, ..Default::default() },
        ),
        (
            "tight-drain",
            SchedConfig { write_hi: 12, write_lo: 4, ..Default::default() },
        ),
    ];
    let mut body = format!("{:<12}", "sched");
    for wl in WORKLOADS {
        body.push_str(&format!(" {:>22}", format!("{wl} p99 | ipc")));
    }
    body.push('\n');
    for (label, sc) in configs {
        body.push_str(&format!("{label:<12}"));
        for wl in WORKLOADS {
            let p = by_name(wl).unwrap();
            let cfg = SimConfig::default()
                .with_design(Design::Dynamic)
                .with_insts(insts)
                .with_sched(sc);
            let r = simulate(&p, &cfg);
            body.push_str(&format!(
                " {:>22}",
                format!(
                    "{:.0} ns | {:.2}",
                    r.read_lat.percentile(0.99) * NS_PER_BUS_CYCLE,
                    r.total_ipc()
                )
            ));
        }
        body.push('\n');
    }
    body.push_str(
        "(p99 CPU-visible read latency; watermarks are per-channel write-queue\n \
         depths: drain arms at hi, read-blocking until lo)\n",
    );
    Report {
        id: "ablate-sched".into(),
        title: "Transaction-scheduler geometry (queue depth, drain watermarks)".into(),
        body,
    }
}

/// Compressed-LLC geometry ablation: superblock-tag ratio and per-set
/// data budget vs effective capacity and end-to-end speedup, under
/// Dynamic-CRAM.  Tag ratio 1 caps residency at the plain cache's line
/// count (compression buys nothing but slack); ratios above 2 chase the
/// tail of tiny lines with real tag silicon — the sweep shows where the
/// knee sits per workload.  The budget rows shrink the data array at a
/// fixed 2x tag ratio: a compressed LLC holding the plain cache's hit
/// rate at half the data is the capacity-equivalence reading.
pub fn ablate_llc(insts: u64) -> Report {
    const WORKLOADS: [&str; 3] = ["llcfit_stream", "llcfit_rand", "libq"];
    // "tags-2x" doubles as the full-budget anchor: at the paper LLC's 16
    // ways, data_lines 0 (= ways) is a 16-line budget, so a "budget-16"
    // row would duplicate it simulation-for-simulation.
    let configs: [(&str, CompressedLlcConfig); 5] = [
        ("tags-1x", CompressedLlcConfig { tag_ratio: 1, data_lines: 0 }),
        ("tags-2x", CompressedLlcConfig::default()),
        ("tags-4x", CompressedLlcConfig { tag_ratio: 4, data_lines: 0 }),
        ("budget-8", CompressedLlcConfig { tag_ratio: 2, data_lines: 8 }),
        ("budget-12", CompressedLlcConfig { tag_ratio: 2, data_lines: 12 }),
    ];
    let mut body = format!("{:<12}", "llc");
    for wl in WORKLOADS {
        body.push_str(&format!(" {:>22}", format!("{wl} spd | eff")));
    }
    body.push('\n');
    // plain-LLC Dynamic runs: the denominator for every row
    let bases: Vec<_> = WORKLOADS
        .iter()
        .map(|&wl| {
            let p = by_name(wl).unwrap();
            let cfg = SimConfig::default().with_design(Design::Dynamic).with_insts(insts);
            simulate(&p, &cfg)
        })
        .collect();
    for (label, knobs) in configs {
        body.push_str(&format!("{label:<12}"));
        for (&wl, base) in WORKLOADS.iter().zip(&bases) {
            let p = by_name(wl).unwrap();
            let cfg = SimConfig::default()
                .with_design(Design::Dynamic)
                .with_insts(insts)
                .with_llc_knobs(knobs);
            let r = simulate(&p, &cfg);
            let eff = r.llc_stats.expect("compressed run has stats").effective_ratio();
            body.push_str(&format!(
                " {:>22}",
                format!("{} | {:.2}x", pct(r.weighted_speedup(base)), eff)
            ));
        }
        body.push('\n');
    }
    body.push_str(
        "(speedup vs Dynamic-CRAM on the plain LLC; eff = avg resident lines /\n \
         uncompressed-equivalent capacity at the row's data budget; budget-N\n \
         rows hold N lines' worth of data per set at 2x tags — tags-2x is\n \
         the full 16-line budget)\n",
    );
    Report {
        id: "ablate-llc".into(),
        title: "Compressed-LLC geometry (superblock-tag ratio, data budget)".into(),
        body,
    }
}

/// Marker-width ablation: pair compressibility under different reserves
/// (the Fig. 4 trade-off, generalized).
pub fn ablate_marker_width() -> Report {
    let mut body = format!(
        "{:<10} {:>9} {:>9} {:>9} {:>9}\n",
        "workload", "0B", "2B", "4B", "8B"
    );
    for wl in ["libq", "soplex", "milc", "xz"] {
        let p = by_name(wl).unwrap();
        let mut oracle = SizeOracle::new(p.value_model(0xF16_4));
        body.push_str(&format!("{wl:<10}"));
        for reserve in [0u32, 2, 4, 8] {
            let budget = 64 - reserve;
            let mut fit = 0u64;
            let n = 2048u64;
            for g in 0..n {
                let s = oracle.group_sizes(g * 4);
                if s[0] + s[1] <= budget {
                    fit += 1;
                }
            }
            body.push_str(&format!(" {:>8.1}%", 100.0 * fit as f64 / n as f64));
        }
        body.push('\n');
    }
    body.push_str("(the paper's 4-byte marker costs ~0-2pp of pair compressibility — Fig. 4)\n");
    Report {
        id: "ablate-marker".into(),
        title: "Marker reserve width vs pair compressibility".into(),
        body,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marker_width_monotone() {
        let r = ablate_marker_width();
        assert!(r.body.contains("libq"));
        // sanity: report renders with all four columns
        assert!(r.body.contains("8B"));
    }

    #[test]
    fn compressor_pairing_never_worse_with_cpack() {
        for wl in ["libq", "xz"] {
            let p = by_name(wl).unwrap();
            let model = p.value_model(7);
            for g in 0..256u64 {
                let line = model.gen_line(g, 0);
                assert!(
                    hybrid::compressed_size_with(&line, AlgoSet::FpcBdiCpack)
                        <= hybrid::compressed_size_with(&line, AlgoSet::FpcBdi)
                );
            }
        }
    }
}
