//! Figure/table harnesses: format each paper exhibit from cached results.

use crate::controller::{Design, MemoryController};
use crate::coordinator::runner::{
    run_m1, run_r1, ResultsDb, C1_DESIGNS, L1_DESIGNS, P1_DESIGNS, Q1_DESIGNS, R1_DESIGN,
    R1_WORKLOAD, T1_FAR_RATIO, X1_DESIGNS,
};
use crate::cram::dynamic::DynamicCram;
use crate::cram::lit::LineInversionTable;
use crate::cram::llp::LineLocationPredictor;
use crate::cram::marker::MarkerEngine;
use crate::energy::{energy_of, EnergyConfig};
use crate::stats::{geomean_speedup, jain_index, SimResult, NS_PER_BUS_CYCLE};
use crate::util::pct;
use crate::workloads::profiles::{
    all27, all64, cache_pressure, far_pressure, latency_sensitive, Suite,
};
use crate::workloads::tenant::m1_mixes;
use crate::workloads::SizeOracle;

/// A formatted report for one figure or table.
pub struct Report {
    pub id: String,
    pub title: String,
    pub body: String,
}

impl Report {
    pub fn render(&self) -> String {
        format!("=== {} — {} ===\n{}\n", self.id, self.title, self.body)
    }
}

fn speedup_table(db: &ResultsDb, designs: &[(Design, &str)]) -> String {
    let mut s = String::new();
    s.push_str(&format!("{:<10}", "workload"));
    for (_, label) in designs {
        s.push_str(&format!(" {label:>16}"));
    }
    s.push('\n');
    let mut per_design: Vec<Vec<f64>> = vec![Vec::new(); designs.len()];
    for w in all27() {
        s.push_str(&format!("{:<10}", w.name));
        for (i, (d, _)) in designs.iter().enumerate() {
            match db.speedup(w.name, *d) {
                Some(sp) => {
                    per_design[i].push(sp);
                    s.push_str(&format!(" {:>16}", pct(sp)));
                }
                None => s.push_str(&format!(" {:>16}", "-")),
            }
        }
        s.push('\n');
    }
    s.push_str(&format!("{:<10}", "GEOMEAN"));
    for col in &per_design {
        s.push_str(&format!(" {:>16}", pct(geomean_speedup(col))));
    }
    s.push('\n');
    s
}

fn bandwidth_table(db: &ResultsDb, design: Design) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<10} {:>8} {:>8} {:>9} {:>8} {:>8} {:>8} {:>8}\n",
        "workload", "data", "writes", "clean-wb", "invals", "2nd-acc", "meta", "total"
    ));
    for w in all27() {
        let (Some(base), Some(r)) = (db.get(w.name, Design::Uncompressed), db.get(w.name, design))
        else {
            continue;
        };
        let bt = base.bw.total().max(1) as f64;
        let b = &r.bw;
        s.push_str(&format!(
            "{:<10} {:>8.3} {:>8.3} {:>9.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}\n",
            w.name,
            b.demand_reads as f64 / bt,
            b.demand_writes as f64 / bt,
            b.clean_writes as f64 / bt,
            b.invalidates as f64 / bt,
            b.second_reads as f64 / bt,
            (b.meta_reads + b.meta_writes) as f64 / bt,
            b.total() as f64 / bt,
        ));
    }
    s.push_str("(all columns normalized to the uncompressed design's total traffic)\n");
    s
}

/// Fig. 3: ideal vs practical (explicit-metadata) compression speedup.
pub fn figure3(db: &ResultsDb) -> Report {
    Report {
        id: "fig3".into(),
        title: "Speedup: ideal compression vs practical (32KB metadata cache)".into(),
        body: speedup_table(
            db,
            &[(Design::Ideal, "ideal"), (Design::explicit(false), "practical")],
        ),
    }
}

/// Fig. 4: probability a pair of adjacent lines compresses to ≤64B / ≤60B.
pub fn figure4() -> Report {
    let mut body = format!(
        "{:<10} {:>12} {:>12} {:>12}\n",
        "workload", "pair<=64B", "pair<=60B", "quad<=60B"
    );
    let (mut s64, mut s60) = (Vec::new(), Vec::new());
    for w in all27() {
        if !w.mix_of.is_empty() {
            continue;
        }
        let mut oracle = SizeOracle::new(w.value_model(0xF16_4));
        let (p64, p60, q60) = MemoryController::pair_quad_compressibility(&mut oracle, 4096);
        s64.push(p64);
        s60.push(p60);
        body.push_str(&format!(
            "{:<10} {:>11.1}% {:>11.1}% {:>11.1}%\n",
            w.name,
            p64 * 100.0,
            p60 * 100.0,
            q60 * 100.0
        ));
    }
    body.push_str(&format!(
        "{:<10} {:>11.1}% {:>11.1}%   (paper: 38% / 36%)\n",
        "AVG",
        crate::util::mean(&s64) * 100.0,
        crate::util::mean(&s60) * 100.0,
    ));
    Report {
        id: "fig4".into(),
        title: "P(adjacent pair compresses) with and without marker reserve".into(),
        body,
    }
}

/// Fig. 7: CRAM with explicit metadata vs uncompressed.
pub fn figure7(db: &ResultsDb) -> Report {
    Report {
        id: "fig7".into(),
        title: "CRAM + explicit metadata (paper: avg ~-10%)".into(),
        body: speedup_table(db, &[(Design::explicit(false), "explicit")]),
    }
}

/// Fig. 8: bandwidth breakdown of explicit-metadata CRAM.
pub fn figure8(db: &ResultsDb) -> Report {
    Report {
        id: "fig8".into(),
        title: "Bandwidth breakdown, CRAM w/ explicit metadata (normalized)".into(),
        body: bandwidth_table(db, Design::explicit(false)),
    }
}

/// Fig. 12: explicit vs implicit metadata.
pub fn figure12(db: &ResultsDb) -> Report {
    Report {
        id: "fig12".into(),
        title: "CRAM: explicit vs implicit metadata (+LLP)".into(),
        body: speedup_table(
            db,
            &[
                (Design::explicit(false), "explicit"),
                (Design::Implicit, "implicit"),
            ],
        ),
    }
}

/// Fig. 14: metadata-cache hit rate vs LLP accuracy.
pub fn figure14(db: &ResultsDb) -> Report {
    let mut body = format!(
        "{:<10} {:>16} {:>16}\n",
        "workload", "meta$ hit (32KB)", "LLP acc (192B)"
    );
    let (mut mh, mut la) = (Vec::new(), Vec::new());
    for w in all27() {
        let (Some(e), Some(i)) = (
            db.get(w.name, Design::explicit(false)),
            db.get(w.name, Design::Implicit),
        ) else {
            continue;
        };
        let m = e.meta_hit_rate.unwrap_or(1.0);
        mh.push(m);
        // a run that never consulted the LCT has no accuracy — report
        // "n/a" and keep it out of the average instead of printing 100%
        let acc = match i.llp_accuracy {
            Some(a) => {
                la.push(a);
                format!("{:.1}%", a * 100.0)
            }
            None => "n/a".into(),
        };
        body.push_str(&format!(
            "{:<10} {:>15.1}% {:>16}\n",
            w.name,
            m * 100.0,
            acc
        ));
    }
    body.push_str(&format!(
        "{:<10} {:>15.1}% {:>15.1}%   (paper: LLP ~98%)\n",
        "AVG",
        crate::util::mean(&mh) * 100.0,
        crate::util::mean(&la) * 100.0
    ));
    Report {
        id: "fig14".into(),
        title: "Probability of finding the line in one access".into(),
        body,
    }
}

/// Fig. 15: bandwidth breakdown of optimized (implicit) CRAM.
pub fn figure15(db: &ResultsDb) -> Report {
    Report {
        id: "fig15".into(),
        title: "Bandwidth breakdown, optimized CRAM (normalized)".into(),
        body: bandwidth_table(db, Design::Implicit),
    }
}

/// Fig. 16: Static-CRAM vs Dynamic-CRAM vs Ideal.
pub fn figure16(db: &ResultsDb) -> Report {
    Report {
        id: "fig16".into(),
        title: "Static-CRAM vs Dynamic-CRAM vs Ideal (paper: dyn avg +6%, no slowdowns)".into(),
        body: speedup_table(
            db,
            &[
                (Design::Implicit, "static"),
                (Design::Dynamic, "dynamic"),
                (Design::Ideal, "ideal"),
            ],
        ),
    }
}

/// Fig. 18: S-curve of Dynamic-CRAM speedup across 64 workloads.
pub fn figure18(db: &ResultsDb) -> Report {
    let mut rows: Vec<(String, f64)> = Vec::new();
    for w in all64() {
        if let Some(s) = db.speedup(w.name, Design::Dynamic) {
            rows.push((w.name.to_string(), s));
        }
    }
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut body = format!("{:<6} {:<14} {:>9}\n", "rank", "workload", "speedup");
    for (i, (name, s)) in rows.iter().enumerate() {
        body.push_str(&format!("{:<6} {:<14} {:>9}\n", i + 1, name, pct(*s)));
    }
    let worst = rows.first().map(|r| r.1).unwrap_or(1.0);
    let best = rows.last().map(|r| r.1).unwrap_or(1.0);
    let speedups: Vec<f64> = rows.iter().map(|r| r.1).collect();
    body.push_str(&format!(
        "min {} | geomean {} | max {}   (paper: no slowdown, up to +73%)\n",
        pct(worst),
        pct(geomean_speedup(&speedups)),
        pct(best)
    ));
    Report {
        id: "fig18".into(),
        title: "S-curve: Dynamic-CRAM speedup over 64 workloads".into(),
        body,
    }
}

/// Fig. 19: normalized power / energy / EDP of Dynamic-CRAM.
pub fn figure19(db: &ResultsDb) -> Report {
    let mut body = format!(
        "{:<10} {:>9} {:>9} {:>9}\n",
        "workload", "power", "energy", "EDP"
    );
    let (mut ps, mut es, mut ds) = (Vec::new(), Vec::new(), Vec::new());
    for w in all27() {
        let (Some(base), Some(dynr)) =
            (db.get(w.name, Design::Uncompressed), db.get(w.name, Design::Dynamic))
        else {
            continue;
        };
        // re-derive energy from recorded traffic (row stats scale with
        // accesses; approximate hit/miss split by recorded row hit rate)
        let derive = |r: &crate::stats::SimResult| {
            let total = r.bw.total();
            let hits = (total as f64 * r.row_hit_rate) as u64;
            let stats = crate::dram::timing::DramStats {
                row_hits: hits,
                row_misses: total - hits,
                ..Default::default()
            };
            energy_of(&EnergyConfig::default(), &stats, r.cycles)
        };
        let eb = derive(base);
        let ed = derive(dynr);
        let p = ed.avg_power_mw() / eb.avg_power_mw();
        let e = ed.total_uj() / eb.total_uj();
        let d = ed.edp() / eb.edp();
        ps.push(p);
        es.push(e);
        ds.push(d);
        body.push_str(&format!(
            "{:<10} {:>9.3} {:>9.3} {:>9.3}\n",
            w.name, p, e, d
        ));
    }
    body.push_str(&format!(
        "{:<10} {:>9.3} {:>9.3} {:>9.3}   (paper: energy 0.95, EDP 0.90)\n",
        "MEAN",
        crate::util::mean(&ps),
        crate::util::mean(&es),
        crate::util::mean(&ds)
    ));
    Report {
        id: "fig19".into(),
        title: "Dynamic-CRAM impact on power / energy / EDP (normalized)".into(),
        body,
    }
}

/// Fig. 20: row-optimized explicit metadata (MemZip/LCP-like) vs Dynamic.
pub fn figure20(db: &ResultsDb) -> Report {
    Report {
        id: "fig20".into(),
        title: "Row-buffer-optimized explicit metadata vs Dynamic-CRAM".into(),
        body: speedup_table(
            db,
            &[
                (Design::explicit(true), "rowopt-meta"),
                (Design::Dynamic, "dynamic"),
            ],
        ),
    }
}

/// Figure T1: the tiered-memory evaluation — uncompressed vs
/// CRAM-compressed far tier on the far-memory-pressure workloads.
///
/// Columns: each tiered design's weighted speedup vs the flat-DDR
/// baseline (context: what capacity expansion costs), the speedup of the
/// CRAM far tier over the uncompressed far tier (the headline), the
/// fraction of traffic served far, and the link data flits per far
/// access (compression pushes this below 1 by co-fetching packed lines).
pub fn figure_t1(db: &ResultsDb) -> Report {
    let raw = Design::tiered(false);
    let cram = Design::tiered(true);
    let mut body = format!(
        "{:<12} {:>12} {:>12} {:>14} {:>9} {:>11}\n",
        "workload", "far-raw", "far-cram", "cram-vs-raw", "far-frac", "flits/far"
    );
    let mut gains = Vec::new();
    for w in far_pressure() {
        let (Some(base), Some(r_raw), Some(r_cram)) = (
            db.get(w.name, Design::Uncompressed),
            db.get(w.name, raw),
            db.get(w.name, cram),
        ) else {
            continue;
        };
        let s_raw = r_raw.weighted_speedup(base);
        let s_cram = r_cram.weighted_speedup(base);
        let gain = r_cram.weighted_speedup(r_raw);
        gains.push(gain);
        let t = r_cram.tier.as_ref().expect("tiered run records tier stats");
        debug_assert_eq!(t.total_accesses(), r_cram.bw.total());
        let far_frac = t.far_frac();
        // demand rx flits per far line delivered: each far demand read is
        // exactly one completion flit, so packing (extra lines per flit)
        // pushes this below 1.  Migration flits are deliberately excluded.
        let delivered = t.far.demand_reads + t.far_prefetch_installs;
        let flits_per_far = if delivered == 0 {
            0.0
        } else {
            t.far.demand_reads as f64 / delivered as f64
        };
        body.push_str(&format!(
            "{:<12} {:>12} {:>12} {:>14} {:>8.1}% {:>11.2}\n",
            w.name,
            pct(s_raw),
            pct(s_cram),
            pct(gain),
            100.0 * far_frac,
            flits_per_far,
        ));
    }
    body.push_str(&format!(
        "{:<12} {:>12} {:>12} {:>14}\n",
        "GEOMEAN", "", "", pct(geomean_speedup(&gains))
    ));
    body.push_str(&format!(
        "(far-raw / far-cram: speedup vs flat DDR; cram-vs-raw: CRAM far tier \
         vs uncompressed far tier; {:.0}% of capacity behind the link)\n",
        T1_FAR_RATIO * 100.0
    ));
    Report {
        id: "figt1".into(),
        title: "Tiered memory: CRAM-compressed vs uncompressed CXL far tier".into(),
        body,
    }
}

/// Figure X1: the composed-design exhibit — the {static, dynamic,
/// explicit} × {flat, tiered} cross-product the layered controller
/// opened, over the far-memory-pressure workloads.
///
/// Flat columns answer "what does each policy cost on plain DDR"; the
/// tiered columns put the same policy on the CXL expander at the T1
/// capacity split, where the narrow link amplifies both the co-fetch
/// benefit and every metadata/second-access overhead.  All speedups are
/// vs the flat uncompressed baseline, so a tiered column below 100%
/// reads as "what capacity expansion costs under this policy".
pub fn figure_x1(db: &ResultsDb) -> Report {
    let labels = ["static", "dynamic", "explicit", "t-cram", "t-cram-dyn", "t-explicit"];
    let mut body = format!("{:<12}", "workload");
    for l in labels {
        body.push_str(&format!(" {l:>11}"));
    }
    body.push('\n');
    let mut per_col: Vec<Vec<f64>> = vec![Vec::new(); X1_DESIGNS.len()];
    for w in far_pressure() {
        let results: Vec<_> = X1_DESIGNS.iter().map(|d| db.speedup(w.name, *d)).collect();
        if results.iter().any(|r| r.is_none()) {
            continue;
        }
        body.push_str(&format!("{:<12}", w.name));
        for (i, s) in results.iter().enumerate() {
            let s = s.expect("checked above");
            per_col[i].push(s);
            body.push_str(&format!(" {:>11}", pct(s)));
        }
        body.push('\n');
    }
    body.push_str(&format!("{:<12}", "GEOMEAN"));
    for col in &per_col {
        body.push_str(&format!(" {:>11}", pct(geomean_speedup(col))));
    }
    body.push('\n');
    body.push_str(&format!(
        "(weighted speedup vs flat uncompressed DDR; t-* columns run the same \
         policy on the CXL expander at the Figure T1 split, {:.0}% of capacity \
         behind the link; t-explicit pays the link twice on metadata misses)\n",
        T1_FAR_RATIO * 100.0
    ));
    Report {
        id: "figx1".into(),
        title: "Composed designs: {static, dynamic, explicit} x {flat, tiered}".into(),
        body,
    }
}

/// Figure C1: the compressed-LLC evaluation — cache compression ×
/// memory compression over the 27 suite plus the cache-pressure set.
///
/// Columns: weighted speedup vs the uncompressed baseline (plain LLC)
/// for static and dynamic CRAM under each LLC organization, then the
/// compressed LLC's effective capacity (time-averaged resident lines
/// over the uncompressed-equivalent capacity) and the share of its
/// evictions forced by tag exhaustion rather than the data budget (tag
/// pressure — Touché's 2× provisioning question), both from the
/// dynamic-CRAM compressed-LLC run.
pub fn figure_c1(db: &ResultsDb, format: OutputFormat) -> Report {
    if format != OutputFormat::Table {
        let mut sink = Sink::new(&["workload", "design", "compressed_llc", "speedup"]);
        for w in all27().into_iter().chain(cache_pressure()) {
            let Some(base) = db.get_llc(w.name, Design::Uncompressed, false) else {
                continue;
            };
            for d in C1_DESIGNS {
                for comp in [false, true] {
                    let Some(r) = db.get_llc(w.name, d, comp) else { continue };
                    sink.push(vec![
                        Cell::s(w.name),
                        Cell::s(d.name()),
                        Cell::n(comp),
                        Cell::n(format!("{:.4}", r.weighted_speedup(base))),
                    ]);
                }
            }
        }
        return c1_report(sink.render(format));
    }
    let mut body = format!(
        "{:<14} {:>9} {:>11} {:>9} {:>11} {:>8} {:>8}\n",
        "workload", "static", "static+cL", "dynamic", "dynamic+cL", "eff-cap", "tag-ev%"
    );
    // columns: (design, compressed-LLC?) in print order
    let cols: [(Design, bool); 4] = [
        (C1_DESIGNS[0], false),
        (C1_DESIGNS[0], true),
        (C1_DESIGNS[1], false),
        (C1_DESIGNS[1], true),
    ];
    let mut per_col: Vec<Vec<f64>> = vec![Vec::new(); cols.len()];
    for w in all27().into_iter().chain(cache_pressure()) {
        let Some(base) = db.get_llc(w.name, Design::Uncompressed, false) else {
            continue;
        };
        let results: Vec<_> = cols
            .iter()
            .map(|&(d, comp)| db.get_llc(w.name, d, comp))
            .collect();
        if results.iter().any(|r| r.is_none()) {
            continue;
        }
        body.push_str(&format!("{:<14}", w.name));
        for (i, r) in results.iter().enumerate() {
            let s = r.expect("checked above").weighted_speedup(base);
            per_col[i].push(s);
            body.push_str(&format!(
                " {:>width$}",
                pct(s),
                width = if i % 2 == 0 { 9 } else { 11 }
            ));
        }
        let st = results[3]
            .expect("checked above")
            .llc_stats
            .expect("compressed-LLC run records cache stats");
        let ev = st.tag_evictions + st.data_evictions;
        let tag_pct = if ev == 0 {
            0.0
        } else {
            100.0 * st.tag_evictions as f64 / ev as f64
        };
        body.push_str(&format!(
            " {:>7.2}x {:>7.1}%\n",
            st.effective_ratio(),
            tag_pct
        ));
    }
    body.push_str(&format!("{:<14}", "GEOMEAN"));
    for (i, col) in per_col.iter().enumerate() {
        body.push_str(&format!(
            " {:>width$}",
            pct(geomean_speedup(col)),
            width = if i % 2 == 0 { 9 } else { 11 }
        ));
    }
    body.push('\n');
    body.push_str(
        "(speedups vs the uncompressed design on the plain LLC; +cL = Touché-\n \
         style compressed LLC, 2x superblock tags over the same data budget;\n \
         eff-cap and tag-ev% from the dynamic+cL run; llcfit_* are the\n \
         cache-pressure profiles whose hot set straddles the 8MB LLC)\n",
    );
    c1_report(body)
}

fn c1_report(body: String) -> Report {
    Report {
        id: "figc1".into(),
        title: "Compressed LLC x CRAM memory compression (speedup, effective capacity)".into(),
        body,
    }
}

/// Figure Q1: demand-read tail latency per design — the transaction
/// scheduler's exhibit.  For every workload in the 27-suite plus the
/// latency-sensitive set, prints p50/p95/p99 (and mean) CPU-visible
/// read latency in nanoseconds under the uncompressed baseline,
/// explicit-metadata CRAM, and Dynamic-CRAM.
///
/// The story the columns tell: explicit metadata serializes a lookup in
/// front of cache-miss reads, which barely moves p50 but stretches the
/// tail; Dynamic-CRAM keeps the tail near the baseline while its
/// co-fetches cut queue pressure on compressible workloads.
pub fn figure_q1(db: &ResultsDb, format: OutputFormat) -> Report {
    if format != OutputFormat::Table {
        let mut sink =
            Sink::new(&["workload", "design", "p50_ns", "p95_ns", "p99_ns", "mean_ns"]);
        for w in all27().into_iter().chain(latency_sensitive()) {
            for d in Q1_DESIGNS {
                let Some(r) = db.get(w.name, d) else { continue };
                let ns = |p: f64| r.read_lat.percentile(p) * NS_PER_BUS_CYCLE;
                sink.push(vec![
                    Cell::s(w.name),
                    Cell::s(d.name()),
                    Cell::n(format!("{:.1}", ns(0.50))),
                    Cell::n(format!("{:.1}", ns(0.95))),
                    Cell::n(format!("{:.1}", ns(0.99))),
                    Cell::n(format!("{:.1}", r.read_lat.mean() * NS_PER_BUS_CYCLE)),
                ]);
            }
        }
        return q1_report(sink.render(format));
    }
    let mut body = format!("{:<12}", "workload");
    for d in Q1_DESIGNS {
        body.push_str(&format!(" {:>26}", format!("{} p50/p95/p99", d.name())));
    }
    body.push('\n');
    let mut p99s: Vec<Vec<f64>> = vec![Vec::new(); Q1_DESIGNS.len()];
    let mut means: Vec<Vec<f64>> = vec![Vec::new(); Q1_DESIGNS.len()];
    for w in all27().into_iter().chain(latency_sensitive()) {
        let results: Vec<_> = Q1_DESIGNS.iter().map(|d| db.get(w.name, *d)).collect();
        if results.iter().any(|r| r.is_none()) {
            continue;
        }
        body.push_str(&format!("{:<12}", w.name));
        for (i, r) in results.iter().enumerate() {
            let h = &r.expect("checked above").read_lat;
            let ns = |p: f64| h.percentile(p) * NS_PER_BUS_CYCLE;
            p99s[i].push(ns(0.99));
            means[i].push(h.mean() * NS_PER_BUS_CYCLE);
            body.push_str(&format!(
                " {:>26}",
                format!("{:.0}/{:.0}/{:.0} ns", ns(0.50), ns(0.95), ns(0.99))
            ));
        }
        body.push('\n');
    }
    body.push_str(&format!("{:<12}", "MEAN p99"));
    for col in &p99s {
        body.push_str(&format!(" {:>23.0} ns", crate::util::mean(col)));
    }
    body.push('\n');
    body.push_str(&format!("{:<12}", "MEAN lat"));
    for col in &means {
        body.push_str(&format!(" {:>23.0} ns", crate::util::mean(col)));
    }
    body.push('\n');
    body.push_str(
        "(CPU-visible demand-read latency through the FR-FCFS scheduler; \
         lat_* rows are the latency-sensitive profiles where scheduling \
         dominates)\n",
    );
    q1_report(body)
}

fn q1_report(body: String) -> Report {
    Report {
        id: "figq1".into(),
        title: "Read-latency tail: uncompressed vs explicit metadata vs CRAM".into(),
        body,
    }
}

/// Table II: measured workload characteristics vs calibration targets.
pub fn table2(db: &ResultsDb) -> Report {
    let mut body = format!(
        "{:<10} {:>6} {:>12} {:>12} {:>12}\n",
        "workload", "suite", "paper MPKI", "sim MPKI", "footprint"
    );
    for w in all27() {
        if !w.mix_of.is_empty() {
            continue;
        }
        let mpki = db
            .get(w.name, Design::Uncompressed)
            .map(|r| format!("{:.1}", r.mpki()))
            .unwrap_or_else(|| "-".into());
        body.push_str(&format!(
            "{:<10} {:>6} {:>12.1} {:>12} {:>9} MB\n",
            w.name,
            w.suite.to_string(),
            w.table_mpki,
            mpki,
            w.footprint_mb
        ));
    }
    body.push_str("(footprint is per-core, Table II / 8 cores, capped at 256MB)\n");
    Report {
        id: "table2".into(),
        title: "Workload characteristics (calibration check)".into(),
        body,
    }
}

/// Table III: storage overhead of the CRAM structures.
///
/// The LLP row deviates from the paper on purpose: Table III provisions
/// 2 bits per LCT entry (128 B), but the five CSI states need 3 bits to
/// round-trip, so the honest figure is 192 B and the total 340 B — see
/// `cram::llp`.
pub fn table3() -> Report {
    let markers = MarkerEngine::new(0).storage_bytes();
    let lit = LineInversionTable::default().storage_bytes();
    let llp = LineLocationPredictor::default().storage_bytes();
    let dyn_ctr = DynamicCram::new(8).storage_bytes();
    let total = markers + lit + llp + dyn_ctr;
    let body = format!(
        "Marker for 2-to-1            {:>4} Bytes\n\
         Marker for 4-to-1            {:>4} Bytes\n\
         Marker for Invalid Line      {:>4} Bytes\n\
         Line Inversion Table (LIT)   {:>4} Bytes\n\
         Line Location Predictor      {:>4} Bytes   (paper claims 128 at 2b/entry;\n\
         {:>34}5 CSI states need 3b)\n\
         Dynamic-CRAM counters        {:>4} Bytes\n\
         TOTAL                        {:>4} Bytes   (paper: 276 bytes)\n",
        4, 4, 64, lit, llp, "", dyn_ctr, total
    );
    Report {
        id: "table3".into(),
        title: "Storage overhead of CRAM structures at the memory controller".into(),
        body,
    }
}

/// Table IV: sensitivity to the number of memory channels.
pub fn table4(db: &ResultsDb) -> Report {
    let mut body = format!("{:<10} {:>22}\n", "channels", "avg speedup (dynamic)");
    for ch in [1usize, 2, 4] {
        let sp: Vec<f64> = all27()
            .iter()
            .filter_map(|w| db.speedup_ch(w.name, Design::Dynamic, ch))
            .collect();
        if sp.is_empty() {
            continue;
        }
        body.push_str(&format!("{:<10} {:>22}\n", ch, pct(geomean_speedup(&sp))));
    }
    body.push_str("(paper: 4.8% / 5.5% / 4.6%)\n");
    Report {
        id: "table4".into(),
        title: "CRAM sensitivity to number of memory channels".into(),
        body,
    }
}

/// Table V: next-line prefetch vs Dynamic-CRAM, per suite.
pub fn table5(db: &ResultsDb) -> Report {
    let mut body = format!(
        "{:<8} {:>20} {:>16}\n",
        "suite", "next-line prefetch", "Dynamic-CRAM"
    );
    let suites = [
        (Some(Suite::Spec06), "SPEC"),
        (Some(Suite::Gap), "GAP"),
        (Some(Suite::Mix), "MIX"),
        (None, "ALL27"),
    ];
    for (suite, label) in suites {
        let mut pf = Vec::new();
        let mut dy = Vec::new();
        for w in all27() {
            let in_suite = match suite {
                // "SPEC" aggregates both generations
                Some(Suite::Spec06) => matches!(w.suite, Suite::Spec06 | Suite::Spec17),
                Some(s) => w.suite == s,
                None => true,
            };
            if !in_suite {
                continue;
            }
            if let Some(s) = db.speedup(w.name, Design::NextLinePrefetch) {
                pf.push(s);
            }
            if let Some(s) = db.speedup(w.name, Design::Dynamic) {
                dy.push(s);
            }
        }
        if pf.is_empty() {
            continue;
        }
        body.push_str(&format!(
            "{:<8} {:>20} {:>16}\n",
            label,
            pct(geomean_speedup(&pf)),
            pct(geomean_speedup(&dy))
        ));
    }
    body.push_str("(paper ALL27: prefetch -9.7%, Dynamic-CRAM +5.5%)\n");
    Report {
        id: "table5".into(),
        title: "Comparison of CRAM to next-line prefetch".into(),
        body,
    }
}

/// Figure M1: the multi-tenant exhibit — canonical co-location mixes ×
/// {uncompressed, flat Dynamic-CRAM, tiered Dynamic-CRAM}, with
/// per-tenant tail latency, slowdown-vs-alone, compression-interference
/// beats, a Jain fairness index per run, and a QoS contrast re-running
/// the `:qos`-marked mix with read slots reserved for its protected
/// tenant.
///
/// Unlike the cached exhibits this one simulates on demand (per-tenant
/// accounting is not part of the [`ResultsDb`] key space), sized by the
/// db's [`crate::coordinator::runner::RunPlan`] like every other figure.
pub fn figure_m1(db: &ResultsDb, format: OutputFormat) -> Report {
    let (runs, qos) = run_m1(&db.plan, false);
    if format != OutputFormat::Table {
        // machine formats emit the per-tenant records of the main runs;
        // the QoS contrast stays a table-only annotation
        let mut sink = Sink::new(&[
            "mix",
            "design",
            "tenant",
            "cores",
            "p99_ns",
            "slowdown",
            "interference_beats",
            "protected",
        ]);
        for r in &runs {
            for t in &r.result.tenants {
                let slow = t
                    .slowdown
                    .map(|s| format!("{s:.3}"))
                    .unwrap_or_else(|| "null".into());
                sink.push(vec![
                    Cell::s(r.mix),
                    Cell::s(r.design.name()),
                    Cell::s(t.name.clone()),
                    Cell::n(t.cores),
                    Cell::n(format!("{:.1}", t.read_lat.percentile(0.99) * NS_PER_BUS_CYCLE)),
                    Cell::n(slow),
                    Cell::n(format!("{:.0}", t.interference_beats)),
                    Cell::n(t.protected),
                ]);
            }
        }
        return m1_report(sink.render(format));
    }
    let mut body = String::new();
    let mut cur_mix = "";
    for r in &runs {
        if r.mix != cur_mix {
            cur_mix = r.mix;
            let spec = m1_mixes()
                .into_iter()
                .find(|(m, _)| *m == cur_mix)
                .map(|(_, s)| s)
                .unwrap_or("");
            body.push_str(&format!("-- mix {cur_mix} ({spec}) --\n"));
            body.push_str(&format!(
                "{:<16} {:<12} {:>5} {:>9} {:>9} {:>13}\n",
                "design", "tenant", "cores", "p99-ns", "slowdown", "interf-beats"
            ));
        }
        for t in &r.result.tenants {
            let p99 = t.read_lat.percentile(0.99) * NS_PER_BUS_CYCLE;
            let slow = t
                .slowdown
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into());
            let marker = if t.protected { " [qos]" } else { "" };
            body.push_str(&format!(
                "{:<16} {:<12} {:>5} {:>9.0} {:>9} {:>13.0}{}\n",
                r.design.name(),
                t.name,
                t.cores,
                p99,
                slow,
                t.interference_beats,
                marker
            ));
        }
        let progress: Vec<f64> = r
            .result
            .tenants
            .iter()
            .filter_map(|t| t.slowdown)
            .map(|s| 1.0 / s.max(1e-9))
            .collect();
        body.push_str(&format!(
            "{:<16} fairness (Jain over 1/slowdown): {:.3}\n",
            r.design.name(),
            jain_index(&progress)
        ));
    }
    if let Some(q) = &qos {
        body.push_str(&format!(
            "-- QoS contrast: mix {} under {}, {}/{} read slots reserved --\n",
            q.mix,
            q.design.name(),
            q.reserved,
            q.read_slots
        ));
        for (bt, qt) in q.base.tenants.iter().zip(&q.qos.tenants) {
            let b99 = bt.read_lat.percentile(0.99) * NS_PER_BUS_CYCLE;
            let q99 = qt.read_lat.percentile(0.99) * NS_PER_BUS_CYCLE;
            let marker = if qt.protected { " [qos]" } else { "" };
            body.push_str(&format!(
                "{:<12} p99 {:>7.0} -> {:>7.0} ns{}\n",
                bt.name, b99, q99, marker
            ));
        }
    }
    body.push_str(
        "(slowdown = tenant alone on its cores / shared, equal instruction \
         budget; interf-beats = bus beats of other tenants' compression \
         overhead traffic attributed to this tenant by demand share; [qos] \
         marks the tenant the reservation protects)\n",
    );
    m1_report(body)
}

fn m1_report(body: String) -> Report {
    Report {
        id: "figm1".into(),
        title: "Multi-tenant co-location: per-tenant tail, slowdown, interference, QoS".into(),
        body,
    }
}

/// Figure R1: the reliability exhibit — the CRAM far tier under a
/// uniform bit-error-rate sweep across every injection site (link
/// flits, far-media reads, marker tails), with the error-storm watchdog
/// disarmed and armed.  Each point reports the weighted speedup vs the
/// clean (BER 0) run, the fault/cure telemetry, detection coverage
/// (always total: the marker no-alias property makes silent misreads
/// structurally impossible), and the watchdog's degradation history.
///
/// Like Figure M1 this simulates on demand (injector state is not part
/// of the [`ResultsDb`] key space), sized by the db's
/// [`crate::coordinator::runner::RunPlan`].
pub fn figure_r1(db: &ResultsDb, format: OutputFormat) -> Report {
    let runs = run_r1(&db.plan, false);
    let clean = |dog: bool| runs.iter().find(|r| r.ber == 0.0 && r.watchdog == dog);
    if format != OutputFormat::Table {
        let mut sink = Sink::new(&[
            "ber",
            "watchdog",
            "vs_clean",
            "flits_retried",
            "retry_beats",
            "media_errors",
            "marker_errors",
            "marker_detected",
            "silent_misreads",
            "rekeys",
            "degrades",
            "rearms",
            "degraded_epochs",
        ]);
        for r in &runs {
            let vs = clean(r.watchdog)
                .map(|c| format!("{:.3}", r.result.weighted_speedup(&c.result)))
                .unwrap_or_else(|| "null".into());
            let rel = &r.result.rel;
            sink.push(vec![
                Cell::n(r.ber),
                Cell::n(r.watchdog),
                Cell::n(vs),
                Cell::n(rel.flits_retried),
                Cell::n(rel.retry_beats),
                Cell::n(rel.media_errors),
                Cell::n(rel.marker_errors),
                Cell::n(rel.marker_detected),
                Cell::n(rel.silent_misreads),
                Cell::n(rel.rekeys),
                Cell::n(rel.watchdog_degrades),
                Cell::n(rel.watchdog_rearms),
                Cell::n(rel.degraded_epochs),
            ]);
        }
        return r1_report(sink.render(format));
    }
    let mut body = String::new();
    for dog in [false, true] {
        body.push_str(&format!(
            "-- watchdog {} --\n",
            if dog { "armed" } else { "disarmed" }
        ));
        body.push_str(&format!(
            "{:<8} {:>9} {:>10} {:>10} {:>10} {:>10} {:>7} {:>16}\n",
            "ber", "vs-clean", "flit-retry", "media-err", "marker-err", "detected",
            "rekeys", "degr/rearm/epochs"
        ));
        for r in runs.iter().filter(|r| r.watchdog == dog) {
            let vs = clean(dog)
                .map(|c| pct(r.result.weighted_speedup(&c.result)))
                .unwrap_or_else(|| "-".into());
            let rel = &r.result.rel;
            body.push_str(&format!(
                "{:<8} {:>9} {:>10} {:>10} {:>10} {:>10} {:>7} {:>16}\n",
                r.ber,
                vs,
                rel.flits_retried,
                rel.media_errors,
                rel.marker_errors,
                rel.marker_detected,
                rel.rekeys,
                format!(
                    "{}/{}/{}",
                    rel.watchdog_degrades, rel.watchdog_rearms, rel.degraded_epochs
                ),
            ));
        }
    }
    body.push_str(&format!(
        "({} under {} at the T1 split; vs-clean = weighted speedup over the \
         BER-0 run, negative under faults; detection is total at every \
         point — zero silent misreads by the marker no-alias property)\n",
        R1_WORKLOAD,
        R1_DESIGN.name()
    ));
    r1_report(body)
}

fn r1_report(body: String) -> Report {
    Report {
        id: "figr1".into(),
        title: "Reliability: BER sweep, detection coverage, watchdog degradation".into(),
        body,
    }
}

/// Figure L1: the link-codec exhibit — each tiered composition from
/// [`L1_DESIGNS`] with and without flit compression over the CXL link,
/// on the far-memory-pressure workloads at the T1 capacity split.
///
/// For every `+lc` design the table reports its weighted speedup over
/// the raw-link twin (the headline), the storage bytes its far
/// transfers moved vs the bytes that actually crossed the wire, the
/// link flit-cycles the payload-aware serializer avoided, and the
/// wire/raw ratio split by traffic class — demand fills, metadata,
/// writebacks, prefetch and migration.  Command headers and metadata
/// lines compress at fixed ratios (address/opcode packing, dense CSI
/// fields); data payloads track the size oracle, so a ratio of 1.00 on
/// incompressible data traffic is correct, not a bug.
pub fn figure_l1(db: &ResultsDb, format: OutputFormat) -> Report {
    let pairs: Vec<(Design, Design)> =
        (0..3).map(|i| (L1_DESIGNS[i], L1_DESIGNS[i + 3])).collect();
    if format != OutputFormat::Table {
        let mut sink = Sink::new(&[
            "workload",
            "design",
            "vs_raw_twin",
            "flits_saved",
            "demand_raw",
            "demand_wire",
            "meta_raw",
            "meta_wire",
            "writeback_raw",
            "writeback_wire",
            "prefetch_raw",
            "prefetch_wire",
            "migration_raw",
            "migration_wire",
        ]);
        for w in far_pressure() {
            for (raw, lc) in &pairs {
                let (Some(r_raw), Some(r_lc)) =
                    (db.get(w.name, *raw), db.get(w.name, *lc))
                else {
                    continue;
                };
                let t = r_lc.tier.as_ref().expect("tiered run records tier stats");
                let l = &t.link_traffic;
                sink.push(vec![
                    Cell::s(w.name),
                    Cell::s(lc.name()),
                    Cell::n(format!("{:.4}", r_lc.weighted_speedup(r_raw))),
                    Cell::n(l.flits_saved),
                    Cell::n(l.demand_raw_bytes),
                    Cell::n(l.demand_wire_bytes),
                    Cell::n(l.meta_raw_bytes),
                    Cell::n(l.meta_wire_bytes),
                    Cell::n(l.writeback_raw_bytes),
                    Cell::n(l.writeback_wire_bytes),
                    Cell::n(l.prefetch_raw_bytes),
                    Cell::n(l.prefetch_wire_bytes),
                    Cell::n(l.migration_raw_bytes),
                    Cell::n(l.migration_wire_bytes),
                ]);
            }
        }
        return l1_report(sink.render(format));
    }
    // per-class wire/raw ratio, "-" when the class never moved a byte
    let ratio = |wire: u64, raw: u64| {
        if raw == 0 {
            format!("{:>7}", "-")
        } else {
            format!("{:>7.2}", wire as f64 / raw as f64)
        }
    };
    let mut body = String::new();
    let mut gains: Vec<Vec<f64>> = vec![Vec::new(); pairs.len()];
    for w in far_pressure() {
        let mut rows = String::new();
        for (i, (raw, lc)) in pairs.iter().enumerate() {
            let (Some(r_raw), Some(r_lc)) = (db.get(w.name, *raw), db.get(w.name, *lc))
            else {
                continue;
            };
            let gain = r_lc.weighted_speedup(r_raw);
            gains[i].push(gain);
            let t = r_lc.tier.as_ref().expect("tiered run records tier stats");
            let l = &t.link_traffic;
            rows.push_str(&format!(
                "{:<20} {:>8} {:>8} {:>8} {:>9}{}{}{}{}{}\n",
                lc.name(),
                pct(gain),
                l.raw_bytes() / 1024,
                l.wire_bytes() / 1024,
                l.flits_saved,
                ratio(l.demand_wire_bytes, l.demand_raw_bytes),
                ratio(l.meta_wire_bytes, l.meta_raw_bytes),
                ratio(l.writeback_wire_bytes, l.writeback_raw_bytes),
                ratio(l.prefetch_wire_bytes, l.prefetch_raw_bytes),
                ratio(l.migration_wire_bytes, l.migration_raw_bytes),
            ));
        }
        if rows.is_empty() {
            continue;
        }
        body.push_str(&format!("-- {} --\n", w.name));
        body.push_str(&format!(
            "{:<20} {:>8} {:>8} {:>8} {:>9}{:>7}{:>7}{:>7}{:>7}{:>7}\n",
            "design", "vs-raw", "raw-KB", "wire-KB", "flits-svd", "dem", "meta", "wb", "pf", "migr"
        ));
        body.push_str(&rows);
    }
    body.push_str("GEOMEAN vs-raw:");
    for (i, (_, lc)) in pairs.iter().enumerate() {
        body.push_str(&format!(" {} {} |", lc.name(), pct(geomean_speedup(&gains[i]))));
    }
    body.pop();
    body.push('\n');
    body.push_str(
        "(vs-raw: weighted speedup of each +lc design over its raw-link twin at \
         the same capacity split; raw-KB/wire-KB: storage bytes the far transfers \
         moved vs bytes that crossed the CXL wire; per-class columns are wire/raw \
         byte ratios; flits-svd: link flit-cycles avoided by payload-aware \
         serialization)\n",
    );
    l1_report(body)
}

fn l1_report(body: String) -> Report {
    Report {
        id: "figl1".into(),
        title: "Link codec: flit compression over the CXL link (wire vs storage bytes)".into(),
        body,
    }
}

/// Figure P1: the layout-family exhibit — the line-granular CRAM
/// layouts (implicit, gated, explicit metadata) next to the LCP
/// page-granular layout, flat and on the far expander, over the
/// 27-workload suite plus the far-pressure set ([`P1_DESIGNS`]).
///
/// Every column answers the same three questions from a different
/// layout family: what the layout buys in weighted speedup over flat
/// uncompressed DDR, what its metadata authority costs as a fraction
/// of total traffic, and what it returns in effective capacity.
/// CRAM's capacity column is honestly `-`, not 1.00: a packed group
/// still owns its four physical slots (CRAM trades capacity for
/// bandwidth), while LCP's fixed-offset pages are the first layout in
/// the repo where main memory actually grows.
pub fn figure_p1(db: &ResultsDb, format: OutputFormat) -> Report {
    let designs: Vec<(Design, &str)> = P1_DESIGNS
        .into_iter()
        .filter(|d| *d != Design::Uncompressed)
        .map(|d| {
            let label = match d.name() {
                "cram-static" => "cram",
                "cram-dynamic" => "cram-dyn",
                "cram-explicit" => "explicit",
                "lcp" => "lcp",
                "tiered-uncomp" => "t-uncomp",
                "tiered-cram" => "t-cram",
                "tiered-explicit" => "t-expl",
                _ => "t-lcp",
            };
            (d, label)
        })
        .collect();
    let workloads: Vec<_> = all27().into_iter().chain(far_pressure()).collect();
    let meta_frac = |r: &SimResult| {
        (r.bw.meta_reads + r.bw.meta_writes) as f64 / r.bw.total().max(1) as f64
    };
    if format != OutputFormat::Table {
        let mut sink = Sink::new(&[
            "workload",
            "design",
            "speedup",
            "meta_frac",
            "eff_capacity",
            "exception_lines",
            "recompactions",
        ]);
        for w in &workloads {
            for (d, _) in &designs {
                let (Some(base), Some(r)) =
                    (db.get(w.name, Design::Uncompressed), db.get(w.name, *d))
                else {
                    continue;
                };
                let (cap, exc, rec) = match r.capacity {
                    Some(c) => (
                        Cell::n(format!("{:.4}", c.expansion())),
                        Cell::n(c.exception_lines),
                        Cell::n(c.recompactions),
                    ),
                    None => (Cell::s("n/a"), Cell::s("n/a"), Cell::s("n/a")),
                };
                sink.push(vec![
                    Cell::s(w.name),
                    Cell::s(d.name()),
                    Cell::n(format!("{:.4}", r.weighted_speedup(base))),
                    Cell::n(format!("{:.4}", meta_frac(r))),
                    cap,
                    exc,
                    rec,
                ]);
            }
        }
        return p1_report(sink.render(format));
    }
    // section 1: per-workload speedups, one column per layout family
    let mut body = format!("{:<12}", "workload");
    for (_, l) in &designs {
        body.push_str(&format!(" {l:>9}"));
    }
    body.push('\n');
    let n = designs.len();
    let mut speedups: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut metas: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut caps: Vec<Vec<f64>> = vec![Vec::new(); n];
    let (mut excs, mut recs) = (vec![0u64; n], vec![0u64; n]);
    for w in &workloads {
        body.push_str(&format!("{:<12}", w.name));
        for (i, (d, _)) in designs.iter().enumerate() {
            let (Some(base), Some(r)) =
                (db.get(w.name, Design::Uncompressed), db.get(w.name, *d))
            else {
                body.push_str(&format!(" {:>9}", "-"));
                continue;
            };
            let s = r.weighted_speedup(base);
            speedups[i].push(s);
            metas[i].push(meta_frac(r));
            if let Some(c) = r.capacity {
                caps[i].push(c.expansion());
                excs[i] += c.exception_lines;
                recs[i] += c.recompactions;
            }
            body.push_str(&format!(" {:>9}", pct(s)));
        }
        body.push('\n');
    }
    body.push_str(&format!("{:<12}", "GEOMEAN"));
    for col in &speedups {
        body.push_str(&format!(" {:>9}", pct(geomean_speedup(col))));
    }
    body.push('\n');
    // section 2: what each layout authority costs and returns
    body.push_str(&format!(
        "\n{:<10} {:>9} {:>10} {:>8} {:>10} {:>11}\n",
        "design", "geomean", "meta-frac", "eff-cap", "exc-lines", "recompacts"
    ));
    for (i, (_, l)) in designs.iter().enumerate() {
        let cap = if caps[i].is_empty() {
            (format!("{:>8}", "-"), format!("{:>10}", "-"), format!("{:>11}", "-"))
        } else {
            (
                format!("{:>8.3}", geomean_speedup(&caps[i])),
                format!("{:>10}", excs[i]),
                format!("{:>11}", recs[i]),
            )
        };
        body.push_str(&format!(
            "{:<10} {:>9} {:>9.1}% {} {} {}\n",
            l,
            pct(geomean_speedup(&speedups[i])),
            crate::util::mean(&metas[i]) * 100.0,
            cap.0,
            cap.1,
            cap.2,
        ));
    }
    body.push_str(
        "(speedups: weighted vs flat uncompressed DDR, tiered columns at the T1 \
         capacity split; meta-frac: metadata reads+writes as a share of total \
         accesses; eff-cap: geomean capacity expansion of the page ledger — `-` \
         for line-granular families, whose packed groups still own their slots; \
         exc-lines/recompacts: LCP exception-region footprint and page \
         re-encodes after exception overflow)\n",
    );
    p1_report(body)
}

fn p1_report(body: String) -> Report {
    Report {
        id: "figp1".into(),
        title: "Layout families: CRAM line-granular vs LCP page-granular".into(),
        body,
    }
}

/// Output format for the machine-readable figures — the table is for
/// humans, CSV and JSON feed plotting scripts (`--format csv|json`).
/// Figures q1, c1, m1, l1 and the x1 sweep all render through the same
/// row sink; table bodies stay bespoke per figure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputFormat {
    Table,
    Csv,
    Json,
}

/// One cell of a machine-readable record.  Strings are quoted in JSON;
/// numbers (pre-formatted by the figure, so CSV and JSON agree to the
/// digit) pass through verbatim.
pub(crate) enum Cell {
    Str(String),
    Num(String),
}

impl Cell {
    pub(crate) fn s(v: impl Into<String>) -> Cell {
        Cell::Str(v.into())
    }
    pub(crate) fn n(v: impl std::fmt::Display) -> Cell {
        Cell::Num(v.to_string())
    }
}

/// The shared sink behind every `--format`-aware figure (and the sweep
/// campaign report): named columns plus rows of cells, rendered as a
/// CSV header + lines or a JSON array of flat objects.
pub(crate) struct Sink {
    columns: &'static [&'static str],
    rows: Vec<Vec<Cell>>,
}

impl Sink {
    pub(crate) fn new(columns: &'static [&'static str]) -> Self {
        Sink { columns, rows: Vec::new() }
    }

    pub(crate) fn push(&mut self, row: Vec<Cell>) {
        debug_assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
    }

    pub(crate) fn render(&self, format: OutputFormat) -> String {
        match format {
            OutputFormat::Csv => {
                let mut s = self.columns.join(",");
                s.push('\n');
                for row in &self.rows {
                    let cells: Vec<&str> = row
                        .iter()
                        .map(|c| match c {
                            Cell::Str(v) | Cell::Num(v) => v.as_str(),
                        })
                        .collect();
                    s.push_str(&cells.join(","));
                    s.push('\n');
                }
                s
            }
            OutputFormat::Json => {
                let objs: Vec<String> = self
                    .rows
                    .iter()
                    .map(|row| {
                        let fields: Vec<String> = self
                            .columns
                            .iter()
                            .zip(row)
                            .map(|(k, c)| match c {
                                Cell::Str(v) => format!("{k:?}:{v:?}"),
                                Cell::Num(v) => format!("{k:?}:{v}"),
                            })
                            .collect();
                        format!("{{{}}}", fields.join(","))
                    })
                    .collect();
                format!("[\n  {}\n]\n", objs.join(",\n  "))
            }
            OutputFormat::Table => unreachable!("table bodies are bespoke per figure"),
        }
    }
}

/// The Figure X1 far-ratio sweep: each tiered composition's weighted
/// speedup vs flat uncompressed DDR at every swept capacity split, with
/// a break-even line per composition (the largest swept ratio where the
/// geomean still clears 100%).  Requires the sweep runs to be cached —
/// see [`ResultsDb::run_x1_sweep`].
pub fn figure_x1_sweep(db: &ResultsDb, ratios: &[f64], format: OutputFormat) -> Report {
    let tiered: Vec<(Design, &str)> = X1_DESIGNS
        .into_iter()
        .filter(Design::is_tiered)
        .map(|d| {
            let label = match d.name() {
                "tiered-cram" => "t-cram",
                "tiered-cram-dyn" => "t-cram-dyn",
                _ => "t-explicit",
            };
            (d, label)
        })
        .collect();
    // geomean per (design, ratio), in tiered x ratios order
    let mut geo: Vec<Vec<f64>> = Vec::new();
    for (d, _) in &tiered {
        let mut per_ratio = Vec::new();
        for &r in ratios {
            let sp: Vec<f64> = far_pressure()
                .iter()
                .filter_map(|w| db.speedup_far(w.name, *d, r))
                .collect();
            per_ratio.push(geomean_speedup(&sp));
        }
        geo.push(per_ratio);
    }
    let mut body = String::new();
    match format {
        OutputFormat::Csv | OutputFormat::Json => {
            let mut sink = Sink::new(&["far_ratio", "workload", "design", "speedup"]);
            for (ri, &r) in ratios.iter().enumerate() {
                for w in far_pressure() {
                    for (d, _) in &tiered {
                        if let Some(s) = db.speedup_far(w.name, *d, r) {
                            sink.push(vec![
                                Cell::n(r),
                                Cell::s(w.name),
                                Cell::s(d.name()),
                                Cell::n(format!("{s:.4}")),
                            ]);
                        }
                    }
                }
                for (di, (d, _)) in tiered.iter().enumerate() {
                    sink.push(vec![
                        Cell::n(r),
                        Cell::s("GEOMEAN"),
                        Cell::s(d.name()),
                        Cell::n(format!("{:.4}", geo[di][ri])),
                    ]);
                }
            }
            body = sink.render(format);
        }
        OutputFormat::Table => {
            for (ri, &r) in ratios.iter().enumerate() {
                body.push_str(&format!("-- far-ratio {r} --\n"));
                body.push_str(&format!("{:<12}", "workload"));
                for (_, l) in &tiered {
                    body.push_str(&format!(" {l:>11}"));
                }
                body.push('\n');
                for w in far_pressure() {
                    body.push_str(&format!("{:<12}", w.name));
                    for (d, _) in &tiered {
                        match db.speedup_far(w.name, *d, r) {
                            Some(s) => body.push_str(&format!(" {:>11}", pct(s))),
                            None => body.push_str(&format!(" {:>11}", "-")),
                        }
                    }
                    body.push('\n');
                }
                body.push_str(&format!("{:<12}", "GEOMEAN"));
                for (di, _) in tiered.iter().enumerate() {
                    body.push_str(&format!(" {:>11}", pct(geo[di][ri])));
                }
                body.push('\n');
            }
            body.push_str("break-even (largest swept ratio with geomean >= 100%):");
            for (di, (_, l)) in tiered.iter().enumerate() {
                let be = ratios
                    .iter()
                    .enumerate()
                    .filter(|&(ri, _)| geo[di][ri] >= 1.0)
                    .map(|(_, &r)| r)
                    .fold(f64::NAN, f64::max);
                if be.is_nan() {
                    body.push_str(&format!(" {l}: none"));
                } else {
                    body.push_str(&format!(" {l}: {be}"));
                }
            }
            body.push('\n');
            body.push_str(
                "(weighted speedup vs flat uncompressed DDR; far-ratio = fraction \
                 of capacity behind the CXL link)\n",
            );
        }
    }
    Report {
        id: "figx1-sweep".into(),
        title: "Tiered compositions vs far-capacity split (break-even sweep)".into(),
        body,
    }
}

/// All figure/table ids, in paper order (figt1, figq1, figc1, figx1,
/// figl1, figm1, figr1 and figp1 are this repo's tiered-memory,
/// tail-latency, compressed-LLC, composed-design, link-codec,
/// multi-tenant, reliability and layout-family extensions, not paper
/// exhibits).
pub const ALL_IDS: [&str; 22] = [
    "fig3", "fig4", "fig7", "fig8", "fig12", "fig14", "fig15", "fig16", "fig18",
    "fig19", "fig20", "figt1", "figq1", "figc1", "figx1", "figl1", "figm1",
    "figr1", "figp1", "table2", "table3", "table4",
];

/// Produce one report by id (None for an unknown id).
pub fn report(db: &ResultsDb, id: &str) -> Option<Report> {
    report_fmt(db, id, OutputFormat::Table)
}

/// Produce one report by id in the requested [`OutputFormat`].  Figures
/// without a machine-readable form render their table regardless of the
/// format ([`figure_x1_sweep`] has its own entry point because of the
/// ratio argument).
pub fn report_fmt(db: &ResultsDb, id: &str, format: OutputFormat) -> Option<Report> {
    Some(match id {
        "fig3" => figure3(db),
        "figt1" => figure_t1(db),
        "figq1" => figure_q1(db, format),
        "figc1" => figure_c1(db, format),
        "figx1" => figure_x1(db),
        "figl1" => figure_l1(db, format),
        "figm1" => figure_m1(db, format),
        "figr1" => figure_r1(db, format),
        "figp1" => figure_p1(db, format),
        "fig4" => figure4(),
        "fig7" => figure7(db),
        "fig8" => figure8(db),
        "fig12" => figure12(db),
        "fig14" => figure14(db),
        "fig15" => figure15(db),
        "fig16" => figure16(db),
        "fig18" => figure18(db),
        "fig19" => figure19(db),
        "fig20" => figure20(db),
        "table2" => table2(db),
        "table3" => table3(),
        "table4" => table4(db),
        "table5" => table5(db),
        _ => return None,
    })
}

/// Every report, in paper order (plus Table V).
pub fn all_reports(db: &ResultsDb) -> Vec<Report> {
    let mut v: Vec<Report> = ALL_IDS.iter().filter_map(|id| report(db, id)).collect();
    v.push(table5(db));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::RunPlan;

    #[test]
    fn figure4_reports_compressibility() {
        let r = figure4();
        assert!(r.body.contains("libq"));
        assert!(r.body.contains("AVG"));
    }

    #[test]
    fn table3_storage_accounts_three_bit_lct() {
        let r = table3();
        assert!(r.body.contains("TOTAL"), "{}", r.body);
        // 72 marker + 64 LIT + 192 LLP (3b/entry, honest) + 12 counters
        assert!(r.body.contains("340 Bytes"), "total must be 340: {}", r.body);
        assert!(r.body.contains("192 Bytes"), "LLP must be 192: {}", r.body);
        // the paper's figure stays visible as the reference point
        assert!(r.body.contains("paper: 276"), "{}", r.body);
    }

    #[test]
    fn figure_t1_reports_tier_breakdown() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 30_000,
            seed: 5,
            threads: 4,
        });
        db.run_tiered_t1(false);
        let r = figure_t1(&db);
        assert!(r.body.contains("cap_stream"), "{}", r.body);
        assert!(r.body.contains("GEOMEAN"));
        assert!(report(&db, "figt1").is_some());
    }

    #[test]
    fn figure_q1_reports_tail_latency_per_design() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 6,
            threads: 4,
        });
        db.run_q1(false);
        let r = figure_q1(&db, OutputFormat::Table);
        assert!(r.body.contains("lat_chase"), "{}", r.body);
        assert!(r.body.contains("p50/p95/p99"));
        assert!(r.body.contains("MEAN p99"));
        assert!(report(&db, "figq1").is_some());
        let c = figure_q1(&db, OutputFormat::Csv);
        assert!(
            c.body.starts_with("workload,design,p50_ns,p95_ns,p99_ns,mean_ns\n"),
            "{}",
            c.body
        );
        assert!(c.body.contains("lat_chase,"), "{}", c.body);
        let j = report_fmt(&db, "figq1", OutputFormat::Json).unwrap();
        assert!(j.body.trim_start().starts_with('['), "{}", j.body);
        assert!(j.body.contains("\"p99_ns\":"), "{}", j.body);
    }

    #[test]
    fn figure_c1_reports_both_llc_organizations() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 8,
            threads: 4,
        });
        db.run_c1(false);
        let r = figure_c1(&db, OutputFormat::Table);
        assert!(r.body.contains("llcfit_stream"), "{}", r.body);
        assert!(r.body.contains("eff-cap"));
        assert!(r.body.contains("GEOMEAN"));
        assert!(report(&db, "figc1").is_some());
        let c = figure_c1(&db, OutputFormat::Csv);
        assert!(
            c.body.starts_with("workload,design,compressed_llc,speedup\n"),
            "{}",
            c.body
        );
        assert!(c.body.contains(",true,"), "{}", c.body);
        assert!(c.body.contains(",false,"), "{}", c.body);
    }

    #[test]
    fn figure_x1_reports_the_cross_product() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 11,
            threads: 4,
        });
        db.run_x1(false);
        let r = figure_x1(&db);
        assert!(r.body.contains("cap_stream"), "{}", r.body);
        assert!(r.body.contains("t-cram-dyn"));
        assert!(r.body.contains("t-explicit"));
        assert!(r.body.contains("GEOMEAN"));
        assert!(report(&db, "figx1").is_some());
    }

    #[test]
    fn figure_m1_reports_per_tenant_rows_and_qos_contrast() {
        let db = ResultsDb::new(RunPlan {
            insts_per_core: 6_000,
            seed: 13,
            threads: 4,
        });
        let r = report(&db, "figm1").expect("figm1 is a known id");
        for (mix, _) in m1_mixes() {
            assert!(r.body.contains(&format!("-- mix {mix} ")), "{}", r.body);
        }
        assert!(r.body.contains("tiered-cram-dyn"), "{}", r.body);
        assert!(r.body.contains("fairness (Jain over 1/slowdown)"));
        assert!(r.body.contains("[qos]"), "{}", r.body);
        assert!(r.body.contains("QoS contrast"), "{}", r.body);
    }

    #[test]
    fn figure_r1_reports_both_watchdog_arms_across_the_sweep() {
        let db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 19,
            threads: 4,
        });
        let r = report(&db, "figr1").expect("figr1 is a known id");
        assert!(r.body.contains("-- watchdog disarmed --"), "{}", r.body);
        assert!(r.body.contains("-- watchdog armed --"), "{}", r.body);
        assert!(r.body.contains("0.01"), "{}", r.body);
        assert!(r.body.contains("zero silent misreads"), "{}", r.body);
        let c = report_fmt(&db, "figr1", OutputFormat::Csv).unwrap();
        assert!(
            c.body.starts_with("ber,watchdog,vs_clean,flits_retried,"),
            "{}",
            c.body
        );
        let j = report_fmt(&db, "figr1", OutputFormat::Json).unwrap();
        assert!(j.body.trim_start().starts_with('['), "{}", j.body);
        assert!(j.body.contains("\"silent_misreads\":"), "{}", j.body);
        assert!(j.body.trim_end().ends_with(']'), "{}", j.body);
    }

    #[test]
    fn figure_x1_sweep_formats_table_csv_json() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 17,
            threads: 4,
        });
        let ratios = [0.25, 0.75];
        db.run_x1_sweep(&ratios, false);
        let t = figure_x1_sweep(&db, &ratios, OutputFormat::Table);
        assert!(t.body.contains("-- far-ratio 0.25 --"), "{}", t.body);
        assert!(t.body.contains("break-even"), "{}", t.body);
        let c = figure_x1_sweep(&db, &ratios, OutputFormat::Csv);
        assert!(c.body.starts_with("far_ratio,workload,design,speedup\n"));
        assert!(c.body.contains("0.25,cap_stream,tiered-cram,"), "{}", c.body);
        assert!(c.body.contains(",GEOMEAN,tiered-cram-dyn,"), "{}", c.body);
        let j = figure_x1_sweep(&db, &ratios, OutputFormat::Json);
        assert!(j.body.trim_start().starts_with('['), "{}", j.body);
        assert!(j.body.contains("\"far_ratio\":0.75"), "{}", j.body);
        assert!(j.body.trim_end().ends_with(']'), "{}", j.body);
    }

    #[test]
    fn figure_l1_reports_link_vs_storage_per_class() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 21,
            threads: 4,
        });
        db.run_l1(false);
        let r = figure_l1(&db, OutputFormat::Table);
        assert!(r.body.contains("-- cap_stream --"), "{}", r.body);
        assert!(r.body.contains("tiered-cram+lc"), "{}", r.body);
        assert!(r.body.contains("tiered-explicit+lc"), "{}", r.body);
        assert!(r.body.contains("flits-svd"), "{}", r.body);
        assert!(r.body.contains("GEOMEAN vs-raw:"), "{}", r.body);
        assert!(report(&db, "figl1").is_some());
        let c = figure_l1(&db, OutputFormat::Csv);
        assert!(
            c.body
                .starts_with("workload,design,vs_raw_twin,flits_saved,demand_raw,demand_wire,"),
            "{}",
            c.body
        );
        assert!(c.body.contains("cap_stream,tiered-cram+lc,"), "{}", c.body);
        let j = report_fmt(&db, "figl1", OutputFormat::Json).unwrap();
        assert!(j.body.trim_start().starts_with('['), "{}", j.body);
        assert!(j.body.contains("\"demand_wire\":"), "{}", j.body);
        assert!(j.body.trim_end().ends_with(']'), "{}", j.body);
    }

    #[test]
    fn figure_p1_reports_both_layout_families() {
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 8_000,
            seed: 23,
            threads: 4,
        });
        db.run_p1(false);
        let r = figure_p1(&db, OutputFormat::Table);
        assert!(r.body.contains("cap_stream"), "{}", r.body);
        for label in ["cram-dyn", "lcp", "t-lcp", "t-expl"] {
            assert!(r.body.contains(label), "{label} missing: {}", r.body);
        }
        assert!(r.body.contains("GEOMEAN"), "{}", r.body);
        assert!(r.body.contains("eff-cap"), "{}", r.body);
        assert!(r.body.contains("recompacts"), "{}", r.body);
        assert!(report(&db, "figp1").is_some());
        let c = figure_p1(&db, OutputFormat::Csv);
        assert!(
            c.body.starts_with(
                "workload,design,speedup,meta_frac,eff_capacity,exception_lines,recompactions\n"
            ),
            "{}",
            c.body
        );
        assert!(c.body.contains("cap_stream,lcp,"), "{}", c.body);
        assert!(c.body.contains(",tiered-lcp,"), "{}", c.body);
        // the line family's capacity cells are n/a, never fabricated
        assert!(c.body.contains(",cram-static,"), "{}", c.body);
        assert!(c.body.contains("n/a"), "{}", c.body);
        let j = report_fmt(&db, "figp1", OutputFormat::Json).unwrap();
        assert!(j.body.trim_start().starts_with('['), "{}", j.body);
        assert!(j.body.contains("\"eff_capacity\":"), "{}", j.body);
        assert!(j.body.trim_end().ends_with(']'), "{}", j.body);
    }

    #[test]
    fn speedup_tables_format() {
        // tiny matrix so the test stays fast
        let mut db = ResultsDb::new(RunPlan {
            insts_per_core: 20_000,
            seed: 3,
            threads: 4,
        });
        db.run_designs(&[Design::Uncompressed, Design::Implicit], false, false);
        let r = figure15(&db);
        assert!(r.body.contains("libq"));
        let r = report(&db, "table2").unwrap();
        assert!(r.body.contains("sim MPKI"));
    }
}
