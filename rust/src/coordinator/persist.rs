//! Persistent on-disk results cache (`CRAM_RESULTS.json`).
//!
//! Serializes `(RunKey, SimResult)` pairs through the zero-dependency
//! [`crate::util::json`] codec so figure re-renders and `repro sweep`
//! re-runs reuse completed simulations across invocations (and across
//! interrupts — the runner re-saves after every executed batch).
//!
//! **Self-invalidation.**  A cache file is trusted only when its
//! fingerprint matches the current build *exactly*; otherwise it is
//! ignored wholesale and overwritten on the next save.  The fingerprint
//! concatenates
//! * the cache [`SCHEMA`] version (bumped on any codec/layout change,
//!   including the latency-histogram bucket layout),
//! * the crate version,
//! * a **probe hash**: one tiny fixed-seed simulation run at load time,
//!   serialized through this codec and FNV-hashed — any change to
//!   simulator semantics, stats layout, or the codec itself changes
//!   these bytes, so stale caches self-invalidate without anyone
//!   remembering to bump a version, and
//! * the plan's `insts_per_core` and `seed` (different budgets are
//!   different experiments).
//!
//! `threads` is deliberately **excluded**: results are scheduling-
//! independent (pinned by the sharded-vs-serial determinism tests), so
//! a cache written at `--threads 1` serves a 32-thread run bit-for-bit.
//!
//! Numbers round-trip exactly: u64 counters print in full decimal and
//! re-parse without an f64 intermediate ([`crate::util::json`] keeps
//! raw number tokens), and floats use Rust's shortest round-trip
//! `Display` form — so a figure rendered from a reloaded cache is
//! byte-identical to one rendered from fresh runs.

use std::sync::OnceLock;

use crate::cache::CacheStats;
use crate::controller::Design;
use crate::coordinator::runner::{RunKey, RunPlan};
use crate::sim::{simulate, SimConfig};
use crate::stats::{
    Bandwidth, CapacityStats, LatencyHist, LinkTraffic, ReliabilityStats, SimResult, TenantStats,
    TierStats, TierTraffic,
};
use crate::tier::link::LinkStats;
use crate::util::json::{escape, Json};
use crate::util::fnv1a64;
use crate::workloads::profiles::by_name;

/// Cache schema version.  Bump on any change to the entry layout or to
/// a serialized struct that the probe hash cannot see (there are none
/// today — the probe serializes a full `SimResult` — but the explicit
/// version documents intent and guards refactors of the probe itself).
pub const SCHEMA: u32 = 1;

/// The build+plan fingerprint a cache file must match to be loaded.
pub fn fingerprint(plan: &RunPlan) -> String {
    format!(
        "v{SCHEMA}:{}:{:016x}:i{}:s{}",
        env!("CARGO_PKG_VERSION"),
        probe_hash(),
        plan.insts_per_core,
        plan.seed
    )
}

/// Hash of one tiny canonical probe simulation serialized through this
/// codec (see the module docs).  Computed once per process — the probe
/// costs a few milliseconds.
fn probe_hash() -> u64 {
    static PROBE: OnceLock<u64> = OnceLock::new();
    *PROBE.get_or_init(|| {
        let profile = by_name("libq").expect("probe workload exists");
        let cfg = SimConfig::builder()
            .design(Design::Dynamic)
            .seed(0xF17E)
            .insts(2_000)
            .warmup(4_000)
            .build();
        let r = simulate(&profile, &cfg);
        let key = RunKey {
            workload: "__probe".to_string(),
            design: Design::Dynamic.name(),
            channels: 2,
            far_mill: 0,
            llc_comp: false,
        };
        fnv1a64(enc_entry(&key, &r).as_bytes())
    })
}

/// Serialize a whole cache file.  Entries must already be in canonical
/// [`RunKey`] order (the runner sorts) so the file bytes — and the
/// determinism tests that compare them — never depend on hash-map
/// iteration order.
pub fn encode(fingerprint: &str, plan: &RunPlan, pairs: &[(&RunKey, &SimResult)]) -> String {
    let mut s = String::with_capacity(256 + pairs.len() * 2048);
    s.push_str("{\n");
    s.push_str(&format!("\"schema\":{SCHEMA},\n"));
    s.push_str(&format!("\"fingerprint\":\"{}\",\n", escape(fingerprint)));
    s.push_str(&format!(
        "\"plan\":{{\"insts_per_core\":{},\"seed\":{}}},\n",
        plan.insts_per_core, plan.seed
    ));
    s.push_str("\"results\":[\n");
    for (i, (k, r)) in pairs.iter().enumerate() {
        if i > 0 {
            s.push_str(",\n");
        }
        s.push_str(&enc_entry(k, r));
    }
    s.push_str("\n]}\n");
    s
}

/// Parse a cache file, validating schema and fingerprint.  Any mismatch
/// or malformed entry rejects the whole file — a cache is either fully
/// trusted or not at all.
pub fn decode(text: &str, expected_fingerprint: &str) -> Result<Vec<(RunKey, SimResult)>, String> {
    let root = Json::parse(text).map_err(|e| format!("cache parse error: {e}"))?;
    let schema = root
        .get("schema")
        .and_then(Json::as_u64)
        .ok_or("cache missing schema")?;
    if schema != u64::from(SCHEMA) {
        return Err(format!("stale cache: schema {schema} != {SCHEMA}"));
    }
    let fp = root
        .get("fingerprint")
        .and_then(Json::as_str)
        .ok_or("cache missing fingerprint")?;
    if fp != expected_fingerprint {
        return Err(format!(
            "stale cache: fingerprint {fp:?} != current {expected_fingerprint:?}"
        ));
    }
    let results = root
        .get("results")
        .and_then(Json::as_arr)
        .ok_or("cache missing results")?;
    results.iter().map(dec_entry).collect()
}

// ---------------------------------------------------------------------
// encoding

fn num(v: f64) -> String {
    // shortest round-trip Display; a non-finite value (none occur in
    // practice) prints as NaN/inf, which the parser rejects — the cache
    // is then regenerated rather than silently mangled
    format!("{v}")
}

fn f64s(xs: &[f64]) -> String {
    let inner: Vec<String> = xs.iter().map(|v| num(*v)).collect();
    format!("[{}]", inner.join(","))
}

fn u64s(xs: &[u64]) -> String {
    let inner: Vec<String> = xs.iter().map(u64::to_string).collect();
    format!("[{}]", inner.join(","))
}

fn opt_f64(v: Option<f64>) -> String {
    v.map_or_else(|| "null".to_string(), num)
}

pub(crate) fn enc_entry(key: &RunKey, r: &SimResult) -> String {
    format!("{{\"key\":{},\"result\":{}}}", enc_key(key), enc_result(r))
}

fn enc_key(k: &RunKey) -> String {
    format!(
        "{{\"workload\":\"{}\",\"design\":\"{}\",\"channels\":{},\"far_mill\":{},\"llc_comp\":{}}}",
        escape(&k.workload),
        escape(k.design),
        k.channels,
        k.far_mill,
        k.llc_comp
    )
}

fn enc_result(r: &SimResult) -> String {
    let mut s = String::with_capacity(2048);
    s.push('{');
    s.push_str(&format!("\"workload\":\"{}\",", escape(&r.workload)));
    s.push_str(&format!("\"design\":\"{}\",", escape(&r.design)));
    s.push_str(&format!("\"cycles\":{},", r.cycles));
    s.push_str(&format!("\"insts_per_core\":{},", r.insts_per_core));
    s.push_str(&format!("\"cores\":{},", r.cores));
    s.push_str(&format!("\"ipc\":{},", f64s(&r.ipc)));
    s.push_str(&format!("\"llc_hits\":{},", r.llc_hits));
    s.push_str(&format!("\"llc_misses\":{},", r.llc_misses));
    s.push_str(&format!("\"bw\":{},", enc_bw(&r.bw)));
    s.push_str(&format!(
        "\"llc_stats\":{},",
        r.llc_stats.as_ref().map_or_else(|| "null".to_string(), enc_cache)
    ));
    s.push_str(&format!("\"llp_accuracy\":{},", opt_f64(r.llp_accuracy)));
    s.push_str(&format!("\"meta_hit_rate\":{},", opt_f64(r.meta_hit_rate)));
    s.push_str(&format!("\"prefetch_installed\":{},", r.prefetch_installed));
    s.push_str(&format!("\"prefetch_used\":{},", r.prefetch_used));
    s.push_str(&format!("\"row_hit_rate\":{},", num(r.row_hit_rate)));
    s.push_str(&format!("\"read_lat\":{},", enc_hist(&r.read_lat)));
    s.push_str(&format!(
        "\"compression_enabled_frac\":{},",
        num(r.compression_enabled_frac)
    ));
    s.push_str(&format!("\"dyn_costs\":{},", r.dyn_costs));
    s.push_str(&format!("\"dyn_benefits\":{},", r.dyn_benefits));
    let counters: Vec<String> = r.dyn_counters.iter().map(i32::to_string).collect();
    s.push_str(&format!("\"dyn_counters\":[{}],", counters.join(",")));
    s.push_str(&format!(
        "\"tier\":{},",
        r.tier.as_ref().map_or_else(|| "null".to_string(), enc_tier)
    ));
    let tenants: Vec<String> = r.tenants.iter().map(enc_tenant).collect();
    s.push_str(&format!("\"tenants\":[{}],", tenants.join(",")));
    s.push_str(&format!("\"rel\":{},", enc_rel(&r.rel)));
    s.push_str(&format!(
        "\"capacity\":{}",
        r.capacity.as_ref().map_or_else(|| "null".to_string(), enc_cap)
    ));
    s.push('}');
    s
}

fn enc_bw(b: &Bandwidth) -> String {
    format!(
        "{{\"demand_reads\":{},\"demand_writes\":{},\"clean_writes\":{},\"invalidates\":{},\
         \"second_reads\":{},\"meta_reads\":{},\"meta_writes\":{},\"prefetch_reads\":{},\
         \"migration\":{}}}",
        b.demand_reads,
        b.demand_writes,
        b.clean_writes,
        b.invalidates,
        b.second_reads,
        b.meta_reads,
        b.meta_writes,
        b.prefetch_reads,
        b.migration
    )
}

fn enc_hist(h: &LatencyHist) -> String {
    format!(
        "{{\"buckets\":{},\"count\":{},\"sum\":{}}}",
        u64s(h.bucket_counts()),
        h.count(),
        h.sum()
    )
}

fn enc_cache(c: &CacheStats) -> String {
    format!(
        "{{\"samples\":{},\"lines_sum\":{},\"bytes_sum\":{},\"tag_evictions\":{},\
         \"data_evictions\":{},\"baseline_lines\":{},\"tag_capacity\":{}}}",
        c.samples,
        c.lines_sum,
        c.bytes_sum,
        c.tag_evictions,
        c.data_evictions,
        c.baseline_lines,
        c.tag_capacity
    )
}

fn enc_tt(t: &TierTraffic) -> String {
    format!(
        "{{\"demand_reads\":{},\"demand_writes\":{},\"clean_writes\":{},\"invalidates\":{},\
         \"meta_accesses\":{},\"prefetch_reads\":{},\"migr_accesses\":{},\"second_reads\":{}}}",
        t.demand_reads,
        t.demand_writes,
        t.clean_writes,
        t.invalidates,
        t.meta_accesses,
        t.prefetch_reads,
        t.migr_accesses,
        t.second_reads
    )
}

fn enc_link(l: &LinkStats) -> String {
    format!(
        "{{\"tx_flits\":{},\"rx_flits\":{},\"tx_busy_cycles\":{},\"rx_busy_cycles\":{},\
         \"tx_wait_cycles\":{},\"rx_wait_cycles\":{}}}",
        l.tx_flits, l.rx_flits, l.tx_busy_cycles, l.rx_busy_cycles, l.tx_wait_cycles,
        l.rx_wait_cycles
    )
}

fn enc_lt(l: &LinkTraffic) -> String {
    format!(
        "{{\"demand_raw_bytes\":{},\"demand_wire_bytes\":{},\"meta_raw_bytes\":{},\
         \"meta_wire_bytes\":{},\"writeback_raw_bytes\":{},\"writeback_wire_bytes\":{},\
         \"prefetch_raw_bytes\":{},\"prefetch_wire_bytes\":{},\"migration_raw_bytes\":{},\
         \"migration_wire_bytes\":{},\"flits_saved\":{},\"retried_flits\":{},\"retry_beats\":{}}}",
        l.demand_raw_bytes,
        l.demand_wire_bytes,
        l.meta_raw_bytes,
        l.meta_wire_bytes,
        l.writeback_raw_bytes,
        l.writeback_wire_bytes,
        l.prefetch_raw_bytes,
        l.prefetch_wire_bytes,
        l.migration_raw_bytes,
        l.migration_wire_bytes,
        l.flits_saved,
        l.retried_flits,
        l.retry_beats
    )
}

fn enc_tier(t: &TierStats) -> String {
    format!(
        "{{\"near\":{},\"far\":{},\"promotions\":{},\"demotions\":{},\"migrated_lines\":{},\
         \"link\":{},\"link_traffic\":{},\"far_prefetch_installs\":{},\"far_groups_written\":{},\
         \"far_groups_packed\":{}}}",
        enc_tt(&t.near),
        enc_tt(&t.far),
        t.promotions,
        t.demotions,
        t.migrated_lines,
        enc_link(&t.link),
        enc_lt(&t.link_traffic),
        t.far_prefetch_installs,
        t.far_groups_written,
        t.far_groups_packed
    )
}

fn enc_rel(r: &ReliabilityStats) -> String {
    format!(
        "{{\"flits_retried\":{},\"retry_beats\":{},\"media_errors\":{},\"marker_errors\":{},\
         \"marker_detected\":{},\"silent_misreads\":{},\"rekeys\":{},\"watchdog_degrades\":{},\
         \"watchdog_rearms\":{},\"degraded_epochs\":{}}}",
        r.flits_retried,
        r.retry_beats,
        r.media_errors,
        r.marker_errors,
        r.marker_detected,
        r.silent_misreads,
        r.rekeys,
        r.watchdog_degrades,
        r.watchdog_rearms,
        r.degraded_epochs
    )
}

fn enc_cap(c: &CapacityStats) -> String {
    format!(
        "{{\"pages\":{},\"logical_lines\":{},\"physical_lines\":{},\"exception_lines\":{},\
         \"recompactions\":{}}}",
        c.pages, c.logical_lines, c.physical_lines, c.exception_lines, c.recompactions
    )
}

fn enc_tenant(t: &TenantStats) -> String {
    format!(
        "{{\"name\":\"{}\",\"first_core\":{},\"cores\":{},\"ipc\":{},\"bw\":{},\"read_lat\":{},\
         \"slowdown\":{},\"interference_beats\":{},\"protected\":{}}}",
        escape(&t.name),
        t.first_core,
        t.cores,
        f64s(&t.ipc),
        enc_bw(&t.bw),
        enc_hist(&t.read_lat),
        opt_f64(t.slowdown),
        num(t.interference_beats),
        t.protected
    )
}

// ---------------------------------------------------------------------
// decoding

fn field<'a>(o: &'a Json, k: &str) -> Result<&'a Json, String> {
    o.get(k).ok_or_else(|| format!("cache entry missing field {k:?}"))
}

fn f_u64(o: &Json, k: &str) -> Result<u64, String> {
    field(o, k)?
        .as_u64()
        .ok_or_else(|| format!("bad u64 field {k:?}"))
}

fn f_usize(o: &Json, k: &str) -> Result<usize, String> {
    Ok(f_u64(o, k)? as usize)
}

fn f_f64(o: &Json, k: &str) -> Result<f64, String> {
    field(o, k)?
        .as_f64()
        .ok_or_else(|| format!("bad f64 field {k:?}"))
}

fn f_bool(o: &Json, k: &str) -> Result<bool, String> {
    field(o, k)?
        .as_bool()
        .ok_or_else(|| format!("bad bool field {k:?}"))
}

fn f_str(o: &Json, k: &str) -> Result<String, String> {
    Ok(field(o, k)?
        .as_str()
        .ok_or_else(|| format!("bad string field {k:?}"))?
        .to_string())
}

fn f_opt_f64(o: &Json, k: &str) -> Result<Option<f64>, String> {
    let v = field(o, k)?;
    if v.is_null() {
        return Ok(None);
    }
    v.as_f64().map(Some).ok_or_else(|| format!("bad f64 field {k:?}"))
}

fn f_f64_arr(o: &Json, k: &str) -> Result<Vec<f64>, String> {
    field(o, k)?
        .as_arr()
        .ok_or_else(|| format!("bad array field {k:?}"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| format!("bad f64 in {k:?}")))
        .collect()
}

fn f_u64_arr(o: &Json, k: &str) -> Result<Vec<u64>, String> {
    field(o, k)?
        .as_arr()
        .ok_or_else(|| format!("bad array field {k:?}"))?
        .iter()
        .map(|v| v.as_u64().ok_or_else(|| format!("bad u64 in {k:?}")))
        .collect()
}

fn dec_entry(e: &Json) -> Result<(RunKey, SimResult), String> {
    let key = dec_key(field(e, "key")?)?;
    let result = dec_result(field(e, "result")?)?;
    Ok((key, result))
}

fn dec_key(o: &Json) -> Result<RunKey, String> {
    let name = f_str(o, "design")?;
    // map back onto the interned &'static name — a design the current
    // build no longer knows invalidates the entry (and thus the cache)
    let design = Design::parse(&name)
        .ok_or_else(|| format!("cache names unknown design {name:?}"))?
        .name();
    Ok(RunKey {
        workload: f_str(o, "workload")?,
        design,
        channels: f_usize(o, "channels")?,
        far_mill: f_u64(o, "far_mill")? as u16,
        llc_comp: f_bool(o, "llc_comp")?,
    })
}

fn dec_result(o: &Json) -> Result<SimResult, String> {
    let llc_stats = match field(o, "llc_stats")? {
        Json::Null => None,
        v => Some(dec_cache(v)?),
    };
    let tier = match field(o, "tier")? {
        Json::Null => None,
        v => Some(dec_tier(v)?),
    };
    let capacity = match field(o, "capacity")? {
        Json::Null => None,
        v => Some(dec_cap(v)?),
    };
    let tenants = field(o, "tenants")?
        .as_arr()
        .ok_or("bad tenants array")?
        .iter()
        .map(dec_tenant)
        .collect::<Result<Vec<_>, _>>()?;
    let dyn_counters = field(o, "dyn_counters")?
        .as_arr()
        .ok_or("bad dyn_counters array")?
        .iter()
        .map(|v| {
            v.as_i64()
                .and_then(|x| i32::try_from(x).ok())
                .ok_or_else(|| "bad i32 in dyn_counters".to_string())
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(SimResult {
        workload: f_str(o, "workload")?,
        design: f_str(o, "design")?,
        cycles: f_u64(o, "cycles")?,
        insts_per_core: f_u64(o, "insts_per_core")?,
        cores: f_usize(o, "cores")?,
        ipc: f_f64_arr(o, "ipc")?,
        llc_hits: f_u64(o, "llc_hits")?,
        llc_misses: f_u64(o, "llc_misses")?,
        bw: dec_bw(field(o, "bw")?)?,
        llc_stats,
        llp_accuracy: f_opt_f64(o, "llp_accuracy")?,
        meta_hit_rate: f_opt_f64(o, "meta_hit_rate")?,
        prefetch_installed: f_u64(o, "prefetch_installed")?,
        prefetch_used: f_u64(o, "prefetch_used")?,
        row_hit_rate: f_f64(o, "row_hit_rate")?,
        read_lat: dec_hist(field(o, "read_lat")?)?,
        compression_enabled_frac: f_f64(o, "compression_enabled_frac")?,
        dyn_costs: f_u64(o, "dyn_costs")?,
        dyn_benefits: f_u64(o, "dyn_benefits")?,
        dyn_counters,
        tier,
        tenants,
        rel: dec_rel(field(o, "rel")?)?,
        capacity,
    })
}

fn dec_bw(o: &Json) -> Result<Bandwidth, String> {
    Ok(Bandwidth {
        demand_reads: f_u64(o, "demand_reads")?,
        demand_writes: f_u64(o, "demand_writes")?,
        clean_writes: f_u64(o, "clean_writes")?,
        invalidates: f_u64(o, "invalidates")?,
        second_reads: f_u64(o, "second_reads")?,
        meta_reads: f_u64(o, "meta_reads")?,
        meta_writes: f_u64(o, "meta_writes")?,
        prefetch_reads: f_u64(o, "prefetch_reads")?,
        migration: f_u64(o, "migration")?,
    })
}

fn dec_hist(o: &Json) -> Result<LatencyHist, String> {
    let buckets = f_u64_arr(o, "buckets")?;
    LatencyHist::from_parts(&buckets, f_u64(o, "count")?, f_u64(o, "sum")?)
        .ok_or_else(|| "histogram bucket layout mismatch".to_string())
}

fn dec_cache(o: &Json) -> Result<CacheStats, String> {
    Ok(CacheStats {
        samples: f_u64(o, "samples")?,
        lines_sum: f_u64(o, "lines_sum")?,
        bytes_sum: f_u64(o, "bytes_sum")?,
        tag_evictions: f_u64(o, "tag_evictions")?,
        data_evictions: f_u64(o, "data_evictions")?,
        baseline_lines: f_u64(o, "baseline_lines")?,
        tag_capacity: f_u64(o, "tag_capacity")?,
    })
}

fn dec_tt(o: &Json) -> Result<TierTraffic, String> {
    Ok(TierTraffic {
        demand_reads: f_u64(o, "demand_reads")?,
        demand_writes: f_u64(o, "demand_writes")?,
        clean_writes: f_u64(o, "clean_writes")?,
        invalidates: f_u64(o, "invalidates")?,
        meta_accesses: f_u64(o, "meta_accesses")?,
        prefetch_reads: f_u64(o, "prefetch_reads")?,
        migr_accesses: f_u64(o, "migr_accesses")?,
        second_reads: f_u64(o, "second_reads")?,
    })
}

fn dec_link(o: &Json) -> Result<LinkStats, String> {
    Ok(LinkStats {
        tx_flits: f_u64(o, "tx_flits")?,
        rx_flits: f_u64(o, "rx_flits")?,
        tx_busy_cycles: f_u64(o, "tx_busy_cycles")?,
        rx_busy_cycles: f_u64(o, "rx_busy_cycles")?,
        tx_wait_cycles: f_u64(o, "tx_wait_cycles")?,
        rx_wait_cycles: f_u64(o, "rx_wait_cycles")?,
    })
}

fn dec_lt(o: &Json) -> Result<LinkTraffic, String> {
    Ok(LinkTraffic {
        demand_raw_bytes: f_u64(o, "demand_raw_bytes")?,
        demand_wire_bytes: f_u64(o, "demand_wire_bytes")?,
        meta_raw_bytes: f_u64(o, "meta_raw_bytes")?,
        meta_wire_bytes: f_u64(o, "meta_wire_bytes")?,
        writeback_raw_bytes: f_u64(o, "writeback_raw_bytes")?,
        writeback_wire_bytes: f_u64(o, "writeback_wire_bytes")?,
        prefetch_raw_bytes: f_u64(o, "prefetch_raw_bytes")?,
        prefetch_wire_bytes: f_u64(o, "prefetch_wire_bytes")?,
        migration_raw_bytes: f_u64(o, "migration_raw_bytes")?,
        migration_wire_bytes: f_u64(o, "migration_wire_bytes")?,
        flits_saved: f_u64(o, "flits_saved")?,
        retried_flits: f_u64(o, "retried_flits")?,
        retry_beats: f_u64(o, "retry_beats")?,
    })
}

fn dec_tier(o: &Json) -> Result<TierStats, String> {
    Ok(TierStats {
        near: dec_tt(field(o, "near")?)?,
        far: dec_tt(field(o, "far")?)?,
        promotions: f_u64(o, "promotions")?,
        demotions: f_u64(o, "demotions")?,
        migrated_lines: f_u64(o, "migrated_lines")?,
        link: dec_link(field(o, "link")?)?,
        link_traffic: dec_lt(field(o, "link_traffic")?)?,
        far_prefetch_installs: f_u64(o, "far_prefetch_installs")?,
        far_groups_written: f_u64(o, "far_groups_written")?,
        far_groups_packed: f_u64(o, "far_groups_packed")?,
    })
}

fn dec_rel(o: &Json) -> Result<ReliabilityStats, String> {
    Ok(ReliabilityStats {
        flits_retried: f_u64(o, "flits_retried")?,
        retry_beats: f_u64(o, "retry_beats")?,
        media_errors: f_u64(o, "media_errors")?,
        marker_errors: f_u64(o, "marker_errors")?,
        marker_detected: f_u64(o, "marker_detected")?,
        silent_misreads: f_u64(o, "silent_misreads")?,
        rekeys: f_u64(o, "rekeys")?,
        watchdog_degrades: f_u64(o, "watchdog_degrades")?,
        watchdog_rearms: f_u64(o, "watchdog_rearms")?,
        degraded_epochs: f_u64(o, "degraded_epochs")?,
    })
}

fn dec_cap(o: &Json) -> Result<CapacityStats, String> {
    Ok(CapacityStats {
        pages: f_u64(o, "pages")?,
        logical_lines: f_u64(o, "logical_lines")?,
        physical_lines: f_u64(o, "physical_lines")?,
        exception_lines: f_u64(o, "exception_lines")?,
        recompactions: f_u64(o, "recompactions")?,
    })
}

fn dec_tenant(o: &Json) -> Result<TenantStats, String> {
    Ok(TenantStats {
        name: f_str(o, "name")?,
        first_core: f_usize(o, "first_core")?,
        cores: f_usize(o, "cores")?,
        ipc: f_f64_arr(o, "ipc")?,
        bw: dec_bw(field(o, "bw")?)?,
        read_lat: dec_hist(field(o, "read_lat")?)?,
        slowdown: f_opt_f64(o, "slowdown")?,
        interference_beats: f_f64(o, "interference_beats")?,
        protected: f_bool(o, "protected")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{Placement, Policy};
    use crate::sim::simulate_tenants;
    use crate::workloads::parse_tenants;

    fn probe_key(design: Design, llc: bool) -> RunKey {
        RunKey {
            workload: "t".to_string(),
            design: design.name(),
            channels: 2,
            far_mill: 0,
            llc_comp: llc,
        }
    }

    /// Encode → decode → re-encode is a fixpoint: the second encoding
    /// must be byte-identical, which proves every field round-trips
    /// exactly (counters, histogram buckets, floats, options).
    fn assert_fixpoint(key: &RunKey, r: &SimResult) {
        let one = enc_entry(key, r);
        let doc = format!(
            "{{\"schema\":{SCHEMA},\"fingerprint\":\"f\",\"plan\":{{\"insts_per_core\":1,\
             \"seed\":1}},\"results\":[{one}]}}"
        );
        let pairs = decode(&doc, "f").expect("decodes");
        assert_eq!(pairs.len(), 1);
        let (k2, r2) = &pairs[0];
        assert_eq!(enc_entry(k2, r2), one, "codec fixpoint for {}", key.design);
    }

    #[test]
    fn flat_tiered_llc_and_lcp_results_round_trip() {
        let profile = by_name("cap_stream").unwrap();
        for (design, llc) in [
            (Design::Dynamic, false),
            (Design::Dynamic, true),
            (Design::tiered(true), false),
            (Design::new(Policy::Lcp, Placement::Flat), false),
        ] {
            let mut b = SimConfig::builder()
                .design(design)
                .seed(9)
                .insts(3_000)
                .warmup(6_000);
            if design.is_tiered() {
                b = b.far_ratio(0.75);
            }
            if llc {
                b = b.compressed_llc();
            }
            let r = simulate(&profile, &b.build());
            // cover the Option branches we expect per design
            assert_eq!(r.tier.is_some(), design.is_tiered());
            assert_eq!(r.llc_stats.is_some(), llc);
            assert_fixpoint(&probe_key(design, llc), &r);
        }
    }

    #[test]
    fn tenant_results_round_trip() {
        let cfg = SimConfig::builder()
            .design(Design::Dynamic)
            .seed(5)
            .insts(3_000)
            .warmup(6_000)
            .build();
        let specs = parse_tenants("cap_stream:4,cap_ptr:4", cfg.cores).unwrap();
        let r = simulate_tenants(&specs, &cfg);
        assert!(!r.tenants.is_empty());
        assert_fixpoint(&probe_key(Design::Dynamic, false), &r);
    }

    #[test]
    fn fingerprint_is_stable_and_plan_sensitive() {
        let plan = RunPlan { insts_per_core: 10_000, seed: 7, threads: 4 };
        let a = fingerprint(&plan);
        assert_eq!(a, fingerprint(&plan), "deterministic within a build");
        let other = RunPlan { seed: 8, ..plan.clone() };
        assert_ne!(a, fingerprint(&other), "seed is part of the experiment");
        // threads are excluded: a cache from a serial run serves any
        // thread count (results are scheduling-independent)
        let threads = RunPlan { threads: 1, ..plan };
        assert_eq!(a, fingerprint(&threads));
    }

    #[test]
    fn decode_rejects_mismatches_wholesale() {
        let plan = RunPlan { insts_per_core: 1, seed: 1, threads: 1 };
        let doc = encode("right", &plan, &[]);
        assert!(decode(&doc, "right").unwrap().is_empty());
        assert!(decode(&doc, "wrong").unwrap_err().contains("fingerprint"));
        assert!(decode("not json", "right").is_err());
        let stale = doc.replace(&format!("\"schema\":{SCHEMA}"), "\"schema\":99999");
        assert!(decode(&stale, "right").unwrap_err().contains("schema"));
    }
}
