//! `repro sweep` — the full design-space campaign in one command.
//!
//! Drives every one of the 32 design compositions ([`Design::all`])
//! across every workload profile set (the core 27-workload suite plus
//! the far-pressure, latency-sensitive and cache-pressure sets — 38
//! profiles, 1216 runs), with optional grid axes: extra far-capacity
//! splits for the tiered compositions (`--far-ratio`) and the
//! compressed-LLC twin of every composition (`--llc-compressed`).
//!
//! The campaign leans on the whole experiment engine: batches drain
//! through the cost-aware pool, land in the striped [`ResultsDb`], and
//! — when a cache is attached — persist so an interrupted or repeated
//! sweep only simulates what is missing.  Per-phase wall time and
//! throughput land on stderr via [`print_telemetry`]; the CI smoke run
//! greps the `cache-hit-rate` line to pin cache reuse ≥ 90%.

use crate::controller::Design;
use crate::coordinator::figures::{Cell, Report, Sink};
use crate::coordinator::runner::{BatchStats, ResultsDb};
use crate::coordinator::OutputFormat;
use crate::util::geomean;
use crate::workloads::profiles::{
    all27, cache_pressure, far_pressure, latency_sensitive, low_mpki, WorkloadProfile,
};

/// What to sweep beyond the core 38-profile × 32-composition matrix.
pub struct SweepConfig {
    /// Extra far-capacity splits for every tiered composition (the
    /// Figure T1 split always runs).
    pub far_ratios: Vec<f64>,
    /// Also run the compressed-LLC twin of every composition.
    pub llc_grid: bool,
    /// Add the low-MPKI extension set (the Fig. 18 long tail).
    pub extended: bool,
    pub format: OutputFormat,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            far_ratios: Vec::new(),
            llc_grid: false,
            extended: false,
            format: OutputFormat::Table,
        }
    }
}

/// One profile set's worth of campaign work.
pub struct SweepPhase {
    pub name: &'static str,
    pub workloads: usize,
    pub stats: BatchStats,
}

/// What [`run_sweep`] produced: the formatted report plus per-phase and
/// aggregate batch telemetry.
pub struct SweepOutcome {
    pub report: Report,
    pub phases: Vec<SweepPhase>,
    pub total: BatchStats,
}

/// The campaign's profile sets, in paper order.  Each becomes one
/// telemetry phase so a long sweep shows forward progress and the
/// per-set cost is visible.
fn phase_sets(extended: bool) -> Vec<(&'static str, Vec<WorkloadProfile>)> {
    let mut sets = vec![
        ("suite27", all27()),
        ("far-pressure", far_pressure()),
        ("latency", latency_sensitive()),
        ("cache-pressure", cache_pressure()),
    ];
    if extended {
        sets.push(("low-mpki", low_mpki()));
    }
    sets
}

/// Run the full campaign against `db` and format the report.
pub fn run_sweep(db: &mut ResultsDb, cfg: &SweepConfig, progress: bool) -> SweepOutcome {
    let sets = phase_sets(cfg.extended);
    let compositions = Design::all().len();
    let mut phases = Vec::new();
    let mut total = BatchStats::default();
    for (name, profiles) in &sets {
        if progress {
            eprintln!("phase {name}: {} workloads x {compositions} compositions", profiles.len());
        }
        let stats = db.run_sweep_matrix(profiles, &cfg.far_ratios, cfg.llc_grid, progress);
        total.absorb(&stats);
        phases.push(SweepPhase { name, workloads: profiles.len(), stats });
    }
    let report = build_report(db, cfg, &sets);
    SweepOutcome { report, phases, total }
}

/// Per-phase and aggregate telemetry on stderr.  The final line's
/// `cache-hit-rate` token is a stable interface: CI's second sweep
/// invocation greps it to assert ≥ 90% reuse from the persistent cache.
pub fn print_telemetry(o: &SweepOutcome) {
    for p in &o.phases {
        eprintln!(
            "  phase {:<14} {:>3} workloads: {:>5} jobs ({} run, {} cached, {} dup) in {:.1}s ({:.1} jobs/s)",
            p.name,
            p.workloads,
            p.stats.requested,
            p.stats.executed,
            p.stats.from_cache,
            p.stats.duplicates,
            p.stats.wall.as_secs_f64(),
            p.stats.jobs_per_sec(),
        );
    }
    let t = &o.total;
    eprintln!(
        "sweep total: {} jobs, {} executed, cache-hit-rate {:.1}%, {:.1}s wall, {:.1} jobs/s",
        t.requested,
        t.executed,
        t.cached_frac() * 100.0,
        t.wall.as_secs_f64(),
        t.jobs_per_sec(),
    );
}

const SWEEP_COLUMNS: &[&str] = &["phase", "workload", "design", "axis", "speedup", "cycles"];

fn build_report(
    db: &ResultsDb,
    cfg: &SweepConfig,
    sets: &[(&'static str, Vec<WorkloadProfile>)],
) -> Report {
    let designs = Design::all();
    let workloads: usize = sets.iter().map(|(_, p)| p.len()).sum();
    let title = format!(
        "design-space sweep — {} compositions x {} workloads",
        designs.len(),
        workloads
    );

    let body = match cfg.format {
        OutputFormat::Table => {
            // summary view: per-composition geomean of weighted speedup
            // over every swept workload (full per-run rows live in the
            // csv/json renderings)
            let mut s = format!("{:<26} {:>10} {:>4}\n", "design", "geomean", "n");
            for d in designs {
                let speedups: Vec<f64> = sets
                    .iter()
                    .flat_map(|(_, profiles)| profiles.iter())
                    .filter_map(|w| db.speedup(w.name, d))
                    .collect();
                s.push_str(&format!(
                    "{:<26} {:>9.1}% {:>4}\n",
                    d.name(),
                    geomean(&speedups) * 100.0,
                    speedups.len()
                ));
            }
            s
        }
        format => {
            let mut sink = Sink::new(SWEEP_COLUMNS);
            for (phase, profiles) in sets {
                for w in profiles {
                    for d in designs {
                        push_rows(&mut sink, db, cfg, phase, w.name, d);
                    }
                }
                // one aggregate row per composition closes each phase
                for d in designs {
                    let speedups: Vec<f64> = profiles
                        .iter()
                        .filter_map(|w| db.speedup(w.name, d))
                        .collect();
                    sink.push(vec![
                        Cell::s(*phase),
                        Cell::s("GEOMEAN"),
                        Cell::s(d.name()),
                        Cell::s("base"),
                        Cell::n(format!("{:.4}", geomean(&speedups))),
                        Cell::n(0),
                    ]);
                }
            }
            sink.render(format)
        }
    };
    Report { id: "SWEEP".to_string(), title, body }
}

/// All rows one (workload, composition) cell contributes: the base run,
/// plus the grid-axis runs the config requested.
fn push_rows(
    sink: &mut Sink,
    db: &ResultsDb,
    cfg: &SweepConfig,
    phase: &str,
    workload: &str,
    d: Design,
) {
    let base = db.get(workload, Design::Uncompressed);
    if let (Some(r), Some(sp)) = (db.get(workload, d), db.speedup(workload, d)) {
        sink.push(vec![
            Cell::s(phase),
            Cell::s(workload),
            Cell::s(d.name()),
            Cell::s("base"),
            Cell::n(format!("{sp:.4}")),
            Cell::n(r.cycles),
        ]);
    }
    if cfg.llc_grid {
        if let (Some(b), Some(r)) = (base, db.get_llc(workload, d, true)) {
            sink.push(vec![
                Cell::s(phase),
                Cell::s(workload),
                Cell::s(d.name()),
                Cell::s("llc"),
                Cell::n(format!("{:.4}", r.weighted_speedup(b))),
                Cell::n(r.cycles),
            ]);
        }
    }
    if d.is_tiered() {
        for &ratio in &cfg.far_ratios {
            if let (Some(r), Some(sp)) =
                (db.get_far(workload, d, ratio), db.speedup_far(workload, d, ratio))
            {
                sink.push(vec![
                    Cell::s(phase),
                    Cell::s(workload),
                    Cell::s(d.name()),
                    Cell::s(format!("far={ratio}")),
                    Cell::n(format!("{sp:.4}")),
                    Cell::n(r.cycles),
                ]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::RunPlan;
    use crate::workloads::profiles::by_name;

    fn tiny_plan() -> RunPlan {
        RunPlan { insts_per_core: 500, seed: 3, threads: 8 }
    }

    #[test]
    fn sweep_covers_every_composition_across_all_profile_sets() {
        let mut db = ResultsDb::new(tiny_plan());
        let cfg = SweepConfig { format: OutputFormat::Json, ..SweepConfig::default() };
        let out = run_sweep(&mut db, &cfg, false);

        // 4 phases, 38 profiles x 32 compositions
        assert_eq!(out.phases.len(), 4);
        assert_eq!(out.total.requested, 38 * 32);
        assert_eq!(
            out.total.executed + out.total.from_cache + out.total.duplicates,
            out.total.requested
        );
        assert_eq!(db.len(), out.total.executed);
        // every composition landed for a representative profile of each set
        for w in ["libq", "cap_stream", "lat_chase", "llcfit_stream"] {
            for d in Design::all() {
                assert!(db.get(w, d).is_some(), "{w}/{}", d.name());
            }
        }
        // machine-readable report carries per-run and aggregate rows
        assert!(out.report.body.contains("\"phase\""));
        assert!(out.report.body.contains("GEOMEAN"));

        // a second sweep against the same db is served entirely from memory
        let again = run_sweep(&mut db, &cfg, false);
        assert_eq!(again.total.executed, 0);
        assert_eq!(again.total.from_cache, again.total.requested);
        assert!(again.total.cached_frac() > 0.99);
    }

    #[test]
    fn grid_axes_add_llc_and_far_runs() {
        let mut db = ResultsDb::new(tiny_plan());
        let profile = by_name("libq").unwrap();
        let stats = db.run_sweep_matrix(&[profile], &[0.25], true, false);
        // 32 base + 32 llc twins + 16 tiered compositions at far=0.25
        assert_eq!(stats.requested, 80);
        assert_eq!(stats.executed, 80);
        let tiered = Design::tiered(true);
        assert!(db.get_llc("libq", tiered, true).is_some());
        assert!(db.get_far("libq", tiered, 0.25).is_some());
        assert!(db.speedup_far("libq", tiered, 0.25).is_some());
    }
}
