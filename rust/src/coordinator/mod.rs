//! Experiment orchestration: the figure/table harnesses.
//!
//! [`ResultsDb`] runs the (workload × design × channels) simulation matrix
//! once — in parallel over std threads — and every figure/table harness
//! formats its paper counterpart from the cached results.  `repro
//! reproduce-all` regenerates the complete evaluation section.

pub mod ablation;
pub mod bench;
pub mod figures;
pub mod runner;

pub use figures::{all_reports, report, report_fmt, OutputFormat, Report};
pub use runner::{ResultsDb, RunPlan};
