//! Experiment orchestration: the figure/table harnesses.
//!
//! [`ResultsDb`] runs the (workload × design × channels) simulation matrix
//! once — drained through the shared work [`pool`] and striped across
//! shards — and every figure/table harness formats its paper counterpart
//! from the cached results.  Completed runs can [`persist`] to a
//! versioned on-disk cache that later invocations reload, and [`sweep`]
//! drives the full design-space campaign in one command.  `repro
//! reproduce-all` regenerates the complete evaluation section.

pub mod ablation;
pub mod bench;
pub mod figures;
pub mod persist;
pub mod pool;
pub mod runner;
pub mod sweep;

pub use figures::{all_reports, report, report_fmt, OutputFormat, Report};
pub use runner::{BatchStats, CacheLoad, ResultsDb, RunPlan};
pub use sweep::{run_sweep, SweepConfig, SweepOutcome};
