//! Synthetic workload models (the PinPoints/SPEC/GAP substitute).
//!
//! We cannot run SPEC/GAP binaries in this environment, so every workload
//! is modeled by a *generator* calibrated to the paper's Table II and the
//! behaviours its evaluation depends on:
//!
//! * **memory intensity** — LLC accesses per kilo-instruction (so misses
//!   per kilo-instruction emerge from the modeled LLC at roughly the
//!   Table II MPKI);
//! * **footprint** — the physical region the stream touches;
//! * **spatial locality** — sequential-run behaviour (drives both the
//!   usefulness of CRAM's free adjacent-line prefetch and the metadata
//!   cache hit rate of the explicit baseline);
//! * **temporal reuse** — hot-set fraction (drives LLC hit rate and how
//!   well the cost of compressed writebacks is amortized);
//! * **data values** — a per-page value-class model (drives FPC+BDI
//!   compressibility; Fig. 4);
//! * **memory-level parallelism** — how many misses a core overlaps.
//!
//! [`profiles::all27`] is the paper's memory-intensive evaluation set;
//! [`profiles::all64`] the extended Fig. 18 set.

pub mod generator;
pub mod profiles;
pub mod tenant;
pub mod trace;
pub mod values;

pub use generator::{AccessStream, TraceEvent};
pub use tenant::{parse_tenants, TenantSpec};
pub use trace::TraceReplay;
pub use profiles::{Suite, WorkloadProfile};
pub use values::{SizeOracle, ValueClass, ValueModel};
