//! Trace-file input: replay externally captured LLC-access traces.
//!
//! The synthetic generators cover the paper's evaluation, but a real
//! deployment wants to feed measured traces (USIMM-style).  Format: one
//! access per line, whitespace separated:
//!
//! ```text
//! <gap> <R|W> <hex-line-address> [D]
//! ```
//!
//! * `gap`  — instructions since the previous LLC access,
//! * `R|W`  — read or write,
//! * address in hex (line granularity, i.e. byte address >> 6),
//! * optional `D` marks a dependent load (the core blocks on it).
//!
//! Comment lines start with `#`.  The replay loops when the trace is
//! exhausted, so any instruction budget can be simulated.

use crate::workloads::generator::TraceEvent;

/// A parsed trace, replayed cyclically.
#[derive(Clone, Debug)]
pub struct TraceReplay {
    events: Vec<TraceEvent>,
    pos: usize,
    /// How many times the trace wrapped (diagnostics).
    pub wraps: u64,
}

/// Parse errors carry the line number.
#[derive(Debug, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub reason: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "trace parse error at line {}: {}", self.line, self.reason)
    }
}

impl std::error::Error for ParseError {}

impl TraceReplay {
    /// Parse from text (see module docs for the format).
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut events = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let err = |reason: &str| ParseError { line: i + 1, reason: reason.into() };
            let gap: u64 = parts
                .next()
                .ok_or_else(|| err("missing gap"))?
                .parse()
                .map_err(|_| err("gap must be an integer"))?;
            let rw = parts.next().ok_or_else(|| err("missing R|W"))?;
            let write = match rw {
                "R" | "r" => false,
                "W" | "w" => true,
                _ => return Err(err("second field must be R or W")),
            };
            let addr = parts.next().ok_or_else(|| err("missing address"))?;
            let addr = addr.strip_prefix("0x").unwrap_or(addr);
            let vline =
                u64::from_str_radix(addr, 16).map_err(|_| err("address must be hex"))?;
            let dependent = matches!(parts.next(), Some("D") | Some("d"));
            events.push(TraceEvent { vline, write, gap: gap.max(1), dependent });
        }
        if events.is_empty() {
            return Err(ParseError { line: 0, reason: "empty trace".into() });
        }
        Ok(Self { events, pos: 0, wraps: 0 })
    }

    /// Build from in-memory events (the `repro gen-trace` exporter).
    pub fn from_events(events: Vec<TraceEvent>) -> Self {
        assert!(!events.is_empty());
        Self { events, pos: 0, wraps: 0 }
    }

    /// Load from a file path (the CLI's `--trace FILE` entry point).
    /// I/O and parse failures both surface with the path for context.
    pub fn from_file(path: &str) -> Result<Self, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read trace {path}: {e}"))?;
        Self::parse(&text).map_err(|e| format!("{path}: {e}").into())
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Next event (cyclic).
    pub fn next_event(&mut self) -> TraceEvent {
        let e = self.events[self.pos];
        self.pos += 1;
        if self.pos == self.events.len() {
            self.pos = 0;
            self.wraps += 1;
        }
        e
    }

    /// Largest line address in the trace (for footprint sizing).
    pub fn max_line(&self) -> u64 {
        self.events.iter().map(|e| e.vline).max().unwrap_or(0)
    }

    /// Serialize back to the text format (round-trip/testing, and for the
    /// `repro gen-trace` exporter).
    pub fn to_text(&self) -> String {
        let mut s = String::with_capacity(self.events.len() * 16);
        s.push_str("# gap R|W hex-line-addr [D]\n");
        for e in &self.events {
            s.push_str(&format!(
                "{} {} {:x}{}\n",
                e.gap,
                if e.write { 'W' } else { 'R' },
                e.vline,
                if e.dependent { " D" } else { "" }
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# a comment
10 R 1a2b
5 W 0x1a2c D

3 r ff
";

    #[test]
    fn parses_sample() {
        let t = TraceReplay::parse(SAMPLE).unwrap();
        assert_eq!(t.len(), 3);
        let mut t = t;
        let e1 = t.next_event();
        assert_eq!((e1.gap, e1.write, e1.vline, e1.dependent), (10, false, 0x1a2b, false));
        let e2 = t.next_event();
        assert_eq!((e2.gap, e2.write, e2.vline, e2.dependent), (5, true, 0x1a2c, true));
        let e3 = t.next_event();
        assert_eq!((e3.gap, e3.write, e3.vline), (3, false, 0xff));
    }

    #[test]
    fn wraps_cyclically() {
        let mut t = TraceReplay::parse("1 R 0\n1 R 1\n").unwrap();
        for _ in 0..5 {
            t.next_event();
        }
        assert_eq!(t.wraps, 2);
        // 5 events consumed: 0,1,0,1,0 — next up is event 1
        assert_eq!(t.next_event().vline, 1);
    }

    #[test]
    fn error_reporting() {
        let e = TraceReplay::parse("1 X 0\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(e.reason.contains("R or W"));
        let e = TraceReplay::parse("nope R 0\n").unwrap_err();
        assert!(e.reason.contains("integer"));
        assert!(TraceReplay::parse("# only comments\n").is_err());
    }

    #[test]
    fn bad_gap_rejected_with_line_number() {
        // line 1 is a comment, line 2 blank — the bad gap is on line 3
        let e = TraceReplay::parse("# hdr\n\n-5 R 10\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.reason.contains("integer"), "{}", e.reason);
        let e = TraceReplay::parse("1.5 R 10\n").unwrap_err();
        assert!(e.reason.contains("integer"));
    }

    #[test]
    fn bad_rw_flag_rejected() {
        for bad in ["RW", "read", "0", "-"] {
            let e = TraceReplay::parse(&format!("1 {bad} 10\n")).unwrap_err();
            assert!(e.reason.contains("R or W"), "{bad}: {}", e.reason);
        }
    }

    #[test]
    fn bad_hex_address_rejected() {
        for bad in ["zz", "0xGG", "0x"] {
            let e = TraceReplay::parse(&format!("1 R {bad}\n")).unwrap_err();
            assert!(e.reason.contains("hex"), "{bad}: {}", e.reason);
        }
        // 0x prefix and bare hex both accepted
        assert!(TraceReplay::parse("1 R 0xff\n").is_ok());
        assert!(TraceReplay::parse("1 R ff\n").is_ok());
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(TraceReplay::parse("1\n").unwrap_err().reason.contains("missing R|W"));
        assert!(TraceReplay::parse("1 R\n").unwrap_err().reason.contains("missing address"));
    }

    #[test]
    fn empty_trace_rejected() {
        let e = TraceReplay::parse("").unwrap_err();
        assert_eq!(e.line, 0);
        assert!(e.reason.contains("empty"));
        assert!(TraceReplay::parse("   \n\n# nothing\n").is_err());
    }

    #[test]
    fn wraps_accounting_counts_full_cycles_only() {
        let mut t = TraceReplay::parse("1 R 0\n1 R 1\n1 R 2\n").unwrap();
        assert_eq!(t.wraps, 0);
        for _ in 0..3 {
            t.next_event();
        }
        assert_eq!(t.wraps, 1, "exactly one wrap after consuming the trace once");
        for _ in 0..2 {
            t.next_event();
        }
        assert_eq!(t.wraps, 1, "mid-cycle: no extra wrap");
        t.next_event();
        assert_eq!(t.wraps, 2);
    }

    #[test]
    fn from_file_round_trip_and_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join("cram_trace_test.txt");
        std::fs::write(&path, SAMPLE).unwrap();
        let t = TraceReplay::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(t.len(), 3);
        let _ = std::fs::remove_file(&path);

        // missing file: error mentions the path
        let missing = dir.join("cram_no_such_trace.txt");
        let e = TraceReplay::from_file(missing.to_str().unwrap()).unwrap_err();
        assert!(e.to_string().contains("cram_no_such_trace"));

        // parse error surfaces through from_file with the path
        let bad = dir.join("cram_bad_trace.txt");
        std::fs::write(&bad, "1 Q 0\n").unwrap();
        let e = TraceReplay::from_file(bad.to_str().unwrap()).unwrap_err();
        assert!(e.to_string().contains("R or W"));
        let _ = std::fs::remove_file(&bad);
    }

    #[test]
    fn round_trips_through_text() {
        let t = TraceReplay::parse(SAMPLE).unwrap();
        let t2 = TraceReplay::parse(&t.to_text()).unwrap();
        assert_eq!(t.events, t2.events);
    }

    #[test]
    fn max_line() {
        let t = TraceReplay::parse("1 R ff\n1 W 1000\n").unwrap();
        assert_eq!(t.max_line(), 0x1000);
    }
}
