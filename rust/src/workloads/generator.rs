//! Per-core LLC-access trace generation.
//!
//! Produces the stream of (line address, read/write, instruction gap)
//! events a core presents to the shared LLC.  The address process is a
//! three-state mixture driven by the profile:
//!
//! * with `p_seq`, continue the current sequential run (next line);
//! * otherwise jump — with `p_hot` into the hot set (temporal reuse),
//!   else uniformly into the full footprint (cold).
//!
//! Addresses are *virtual* lines; the VM layer ([`crate::sim::vm`]) maps
//! them per-core so cores never share physical pages (paper §III-A).

use crate::util::rng::Rng;
use crate::workloads::profiles::WorkloadProfile;

/// One LLC access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual line address.
    pub vline: u64,
    pub write: bool,
    /// Instructions executed since the previous LLC access.
    pub gap: u64,
    /// Core must wait for this access's data before making progress.
    pub dependent: bool,
}

/// The cyclic "streaming arrays" region: real streaming workloads (lbm,
/// libquantum, milc…) re-traverse their main arrays every outer iteration.
/// Sequential traffic walks this region cyclically so memory-level reuse
/// exists within a simulated slice; 2 MB per core ≫ the per-core share of
/// the shared 8MB LLC, so the traversal still misses (cyclic-LRU
/// thrashing), exactly like the full-size arrays would.
pub const SWEEP_LINES: u64 = 2 * 1024 * 1024 / 64;

/// Deterministic, infinite access stream for one core.
pub struct AccessStream {
    rng: Rng,
    footprint_lines: u64,
    sweep_lines: u64,
    hot_lines: u64,
    p_seq: f64,
    p_hot: f64,
    write_frac: f64,
    p_dep: f64,
    mean_gap: f64,
    /// Streaming cursor (cycles through the sweep region).
    cursor: u64,
}

impl AccessStream {
    pub fn new(profile: &WorkloadProfile, seed: u64) -> Self {
        assert!(
            profile.mix_of.is_empty(),
            "mixes are expanded per-core by the experiment runner"
        );
        let footprint_lines = profile.footprint_lines().max(1024);
        Self {
            rng: Rng::new(seed),
            footprint_lines,
            sweep_lines: footprint_lines.min(SWEEP_LINES),
            hot_lines: ((footprint_lines as f64 * profile.hot_frac) as u64).max(64),
            p_seq: profile.p_seq,
            p_hot: profile.p_hot,
            write_frac: profile.write_frac,
            p_dep: profile.p_dep,
            mean_gap: 1000.0 / profile.apki,
            cursor: 0,
        }
    }

    /// Next LLC access.  Sequential runs emerge as geometric streaks of
    /// `p_seq` successes (mean run length 1/(1-p_seq)), so `p_seq` IS the
    /// long-run sequential fraction of the stream.  Non-sequential
    /// accesses are one-off excursions (hot set or anywhere in the
    /// footprint) that do not derail the streaming cursor.
    pub fn next_event(&mut self) -> TraceEvent {
        let vline = if self.rng.chance(self.p_seq) {
            self.cursor = (self.cursor + 1) % self.sweep_lines;
            self.cursor
        } else if self.rng.chance(self.p_hot) {
            self.rng.below(self.hot_lines)
        } else {
            self.rng.below(self.footprint_lines)
        };
        TraceEvent {
            vline,
            write: self.rng.chance(self.write_frac),
            gap: self.rng.geometric(self.mean_gap),
            dependent: self.rng.chance(self.p_dep),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::profiles::by_name;

    fn stream(name: &str, seed: u64) -> AccessStream {
        AccessStream::new(&by_name(name).unwrap(), seed)
    }

    #[test]
    fn deterministic() {
        let mut a = stream("libq", 1);
        let mut b = stream("libq", 1);
        for _ in 0..1000 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }

    #[test]
    fn stays_in_footprint() {
        let p = by_name("sphinx").unwrap();
        let fp = p.footprint_lines();
        let mut s = AccessStream::new(&p, 3);
        for _ in 0..10_000 {
            assert!(s.next_event().vline < fp);
        }
    }

    #[test]
    fn spatial_locality_reflects_p_seq() {
        let seq_frac = |name: &str| {
            let mut s = stream(name, 7);
            let mut prev = s.next_event().vline;
            let mut seq = 0;
            let n = 20_000;
            for _ in 0..n {
                let e = s.next_event();
                if e.vline == prev + 1 {
                    seq += 1;
                }
                prev = e.vline;
            }
            seq as f64 / n as f64
        };
        let libq = seq_frac("libq"); // p_seq 0.95
        let cc = seq_frac("cc_twi"); // p_seq 0.06
        assert!(libq > 0.85, "libq sequential fraction {libq}");
        assert!(cc < 0.30, "cc_twi sequential fraction {cc}");
    }

    #[test]
    fn gap_matches_apki() {
        let p = by_name("libq").unwrap(); // apki 30 => mean gap ~33 insts
        let mut s = AccessStream::new(&p, 11);
        let n = 50_000;
        let total: u64 = (0..n).map(|_| s.next_event().gap).sum();
        let apki = 1000.0 * n as f64 / total as f64;
        assert!(
            (apki - p.apki).abs() / p.apki < 0.1,
            "measured apki {apki} vs {}",
            p.apki
        );
    }

    #[test]
    fn write_fraction_respected() {
        let p = by_name("lbm17").unwrap(); // write_frac 0.40
        let mut s = AccessStream::new(&p, 13);
        let n = 50_000;
        let writes = (0..n).filter(|_| s.next_event().write).count();
        let frac = writes as f64 / n as f64;
        assert!((frac - 0.40).abs() < 0.03, "write frac {frac}");
    }
}
