//! Multi-tenant workload mixes.
//!
//! A *tenant* is a named group of cores running one base workload
//! profile with its own RNG salt, co-located with other tenants on the
//! shared memory system ([`crate::sim::tenant`]).  This module owns the
//! CLI grammar for `--tenants` and the canonical mixes the Figure M1
//! exhibit runs.
//!
//! Grammar (comma-separated tenants):
//!
//! ```text
//! WORKLOAD[:CORES][:qos][:bias=N][,WORKLOAD[:CORES][:qos][:bias=N],...]
//! ```
//!
//! * `WORKLOAD` — any base profile name known to
//!   [`profiles::by_name`](crate::workloads::profiles::by_name).  MIX
//!   pseudo-profiles are rejected: a tenant is one coherent stream, not
//!   a bag of streams.
//! * `CORES` — how many of the machine's cores the tenant owns.
//!   Tenants that omit it split the leftover cores evenly.
//! * `qos` — marks the tenant whose reads get the scheduler's reserved
//!   slots ([`crate::dram::SchedConfig::reserved_slots`]).  At most one
//!   tenant may be marked.
//! * `bias=N` — per-tenant Dynamic-CRAM gate bias
//!   ([`DynamicCram::set_bias`](crate::cram::dynamic::DynamicCram::set_bias)),
//!   applied to each of the tenant's cores under the `dynamic` /
//!   `tiered-dynamic` policies (and ignored by the others).  Positive
//!   `N` keeps the tenant's gate open through `N` more net cost events
//!   (compression-friendly); negative `N` closes it sooner
//!   (latency-friendly).

use crate::workloads::profiles::{by_name, WorkloadProfile};

/// One tenant of the co-located machine: a workload, a core allocation,
/// and a seed salt that keeps its streams distinct from every other
/// tenant's (including same-profile neighbours).
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    pub profile: WorkloadProfile,
    pub cores: usize,
    /// Folded into each of the tenant's per-core stream/oracle seeds.
    pub seed_salt: u64,
    /// Reads from this tenant's cores see the full read-slot pool even
    /// when `reserved_slots` caps everyone else.
    pub protected: bool,
    /// Dynamic-gate bias for the tenant's cores (`:bias=N`; 0 = stock
    /// thresholds, bit-identical to an unbiased gate).
    pub bias: i32,
}

/// Parse a `--tenants` spec against a machine of `total_cores` cores.
///
/// Returns the tenants in declaration order with all core counts
/// resolved (they sum to `total_cores`), or a human-readable error.
pub fn parse_tenants(spec: &str, total_cores: usize) -> Result<Vec<TenantSpec>, String> {
    let items: Vec<&str> = spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if items.is_empty() {
        return Err("--tenants: empty tenant list".into());
    }
    if items.len() > total_cores {
        return Err(format!(
            "--tenants: {} tenants need at least {} cores (machine has {total_cores})",
            items.len(),
            items.len()
        ));
    }
    let mut specs = Vec::with_capacity(items.len());
    for (idx, item) in items.iter().enumerate() {
        let mut fields = item.split(':').map(str::trim);
        let name = fields.next().unwrap_or("");
        let mut cores = 0usize; // 0 = split the leftover evenly
        let mut protected = false;
        let mut bias = 0i32;
        for f in fields {
            if f.eq_ignore_ascii_case("qos") {
                protected = true;
            } else if let Some(b) = f.strip_prefix("bias=") {
                bias = b.parse().map_err(|_| {
                    format!("tenant {name:?}: bias {b:?} is not a (signed) integer")
                })?;
            } else {
                cores = f.parse().map_err(|_| {
                    format!(
                        "tenant {name:?}: field {f:?} is neither a core count, `qos`, \
                         nor `bias=N`"
                    )
                })?;
                if cores == 0 {
                    return Err(format!("tenant {name:?}: core count must be > 0"));
                }
            }
        }
        let profile =
            by_name(name).ok_or_else(|| format!("tenant {name:?}: unknown workload"))?;
        if !profile.mix_of.is_empty() {
            return Err(format!(
                "tenant {name:?}: MIX profiles cannot be tenants; list base profiles instead"
            ));
        }
        specs.push(TenantSpec {
            name: name.to_string(),
            profile,
            cores,
            seed_salt: idx as u64 + 1,
            protected,
            bias,
        });
    }
    if specs.iter().filter(|t| t.protected).count() > 1 {
        return Err("--tenants: at most one tenant may be marked `qos`".into());
    }

    let fixed: usize = specs.iter().map(|t| t.cores).sum();
    let auto = specs.iter().filter(|t| t.cores == 0).count();
    if fixed > total_cores {
        return Err(format!(
            "--tenants: core counts sum to {fixed} > machine's {total_cores}"
        ));
    }
    let leftover = total_cores - fixed;
    if auto == 0 {
        if leftover != 0 {
            return Err(format!(
                "--tenants: core counts sum to {fixed}, machine has {total_cores}"
            ));
        }
    } else {
        if leftover == 0 || leftover % auto != 0 {
            return Err(format!(
                "--tenants: {leftover} leftover cores do not split evenly over \
                 {auto} tenants without explicit counts"
            ));
        }
        let each = leftover / auto;
        for t in specs.iter_mut().filter(|t| t.cores == 0) {
            t.cores = each;
        }
    }
    Ok(specs)
}

/// The Figure M1 tenant mixes: `(label, --tenants spec)`.
///
/// * `stream+ptr` — two bandwidth-bound tenants with opposite
///   compressibility (symmetric contention);
/// * `lat+stream` — a latency-critical pointer chaser (marked `qos`)
///   beside a streaming bandwidth hog (the QoS-contrast mix);
/// * `quad` — four smaller tenants, the many-tenant fairness case.
pub fn m1_mixes() -> [(&'static str, &'static str); 3] {
    [
        ("stream+ptr", "cap_stream:4,cap_ptr:4"),
        ("lat+stream", "lat_chase:4:qos,cap_stream:4"),
        ("quad", "cap_stream:2,cap_ptr:2,cap_gap:2,lat_zipf:2"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_counts_and_qos() {
        let t = parse_tenants("lat_chase:4:qos,cap_stream:4", 8).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!((t[0].name.as_str(), t[0].cores, t[0].protected), ("lat_chase", 4, true));
        assert_eq!((t[1].name.as_str(), t[1].cores, t[1].protected), ("cap_stream", 4, false));
        assert_ne!(t[0].seed_salt, t[1].seed_salt);
        assert_eq!((t[0].bias, t[1].bias), (0, 0), "bias defaults to the stock gate");
    }

    #[test]
    fn bias_field_parses_in_any_position() {
        let t = parse_tenants("lat_chase:4:qos:bias=-16,cap_stream:bias=32:4", 8).unwrap();
        assert_eq!((t[0].cores, t[0].protected, t[0].bias), (4, true, -16));
        assert_eq!((t[1].cores, t[1].protected, t[1].bias), (4, false, 32));
    }

    #[test]
    fn leftover_cores_split_evenly() {
        let t = parse_tenants("libq,mcf17", 8).unwrap();
        assert_eq!(t[0].cores, 4);
        assert_eq!(t[1].cores, 4);
        let t = parse_tenants("libq:2,mcf17,milc", 8).unwrap();
        assert_eq!([t[0].cores, t[1].cores, t[2].cores], [2, 3, 3]);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(parse_tenants("", 8).is_err());
        assert!(parse_tenants("nosuch:4,libq:4", 8).is_err());
        assert!(parse_tenants("libq:4,mcf17:8", 8).is_err(), "over-committed cores");
        assert!(parse_tenants("libq:2,mcf17:2", 8).is_err(), "under-committed, no auto tenants");
        assert!(parse_tenants("libq:3,mcf17,milc", 8).is_err(), "5 leftover over 2 tenants");
        assert!(parse_tenants("libq:4:qos,mcf17:4:qos", 8).is_err(), "two qos marks");
        assert!(parse_tenants("libq:bogus", 8).is_err());
        assert!(parse_tenants("mix1:8", 8).is_err(), "MIX profiles rejected");
        // malformed input must come back as Err, never a panic
        assert!(parse_tenants(",,,", 8).is_err(), "comma soup is an empty list");
        assert!(parse_tenants(":4", 8).is_err(), "empty workload name");
        assert!(parse_tenants("libq:0", 8).is_err(), "zero-core tenant");
        assert!(parse_tenants("libq:-2", 8).is_err(), "negative core count");
        assert!(parse_tenants("libq:4:bias=,mcf17:4", 8).is_err(), "empty bias");
        assert!(parse_tenants("libq:4:bias=big,mcf17:4", 8).is_err(), "non-numeric bias");
        assert!(
            parse_tenants("libq:99999999999999999999", 8).is_err(),
            "overflowing core count"
        );
        assert!(
            parse_tenants("libq,mcf17,milc,xz,bwaves,lbm,gcc,omnetpp,roms", 8).is_err(),
            "more tenants than cores"
        );
    }

    #[test]
    fn m1_mixes_parse_on_eight_cores() {
        for (label, spec) in m1_mixes() {
            let t = parse_tenants(spec, 8).unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(t.iter().map(|s| s.cores).sum::<usize>(), 8, "{label}");
        }
        // exactly one mix carries the QoS mark (the contrast exhibit keys on it)
        let marked = m1_mixes()
            .iter()
            .filter(|(_, s)| s.contains(":qos"))
            .count();
        assert_eq!(marked, 1);
    }
}
