//! Data-value models: what the bytes in memory look like, per workload.
//!
//! Each 4KB page is assigned a [`ValueClass`] on first touch (hash of the
//! page address under the workload's class weights), and every line's
//! content is generated deterministically from its address and class.
//! Compressed sizes then follow from the real FPC+BDI compressors, so the
//! whole pipeline (markers, packing, budget checks) runs on genuine
//! bitstreams — not on synthetic size labels.
//!
//! Class → typical hybrid size → packing behaviour:
//!
//! | class    | content                     | size    | packs as |
//! |----------|-----------------------------|---------|----------|
//! | Zero     | zero lines                  | 2 B     | 4:1      |
//! | SmallInt | small signed words          | ~9-15 B | 4:1      |
//! | Pointer  | u64 base + small deltas     | ~17-25 B| 2:1      |
//! | Float    | high-entropy mantissas      | ~41-64 B| rarely   |
//! | Random   | uniform random words        | 64 B    | never    |

use crate::compress::hybrid::{self, AlgoSet};
use crate::mem::{CacheLine, PAGE_BYTES};
use crate::util::rng::splitmix64;
use std::collections::HashMap;

/// Per-page data-value class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueClass {
    Zero,
    SmallInt,
    Pointer,
    Float,
    Random,
}

/// Workload-level mixture of page classes (weights, not normalized).
#[derive(Clone, Copy, Debug)]
pub struct ValueModel {
    /// Weights for [Zero, SmallInt, Pointer, Float, Random].
    pub weights: [f64; 5],
    /// Per-model seed so different workloads see different page layouts.
    pub seed: u64,
}

impl ValueModel {
    pub const CLASSES: [ValueClass; 5] = [
        ValueClass::Zero,
        ValueClass::SmallInt,
        ValueClass::Pointer,
        ValueClass::Float,
        ValueClass::Random,
    ];

    pub fn new(weights: [f64; 5], seed: u64) -> Self {
        Self { weights, seed }
    }

    /// Class of the page containing `line_addr` (deterministic).
    pub fn class_of_line(&self, line_addr: u64) -> ValueClass {
        let page = line_addr * 64 / PAGE_BYTES;
        let h = splitmix64(self.seed ^ 0x7061_6765, page); // "page"
        let total: f64 = self.weights.iter().sum();
        let mut x = (h >> 11) as f64 / (1u64 << 53) as f64 * total;
        for (i, w) in self.weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return Self::CLASSES[i];
            }
        }
        ValueClass::Random
    }

    /// Deterministic content of the line at `line_addr`.
    /// `version` models in-place updates: bumping it changes the values
    /// (but not the class), like a store to the line would.
    pub fn gen_line(&self, line_addr: u64, version: u32) -> CacheLine {
        let class = self.class_of_line(line_addr);
        let key = self.seed ^ ((version as u64) << 48);
        let mut words = [0u32; 16];
        match class {
            ValueClass::Zero => {
                // mostly-zero page: occasional small counter word
                if splitmix64(key, line_addr) % 8 == 0 {
                    words[0] = (splitmix64(key, line_addr) % 16) as u32;
                }
            }
            ValueClass::SmallInt => {
                // sparse small counters: half zero, half 4-bit — FPC-friendly
                // (≤14B), so groups of four reliably reach 4:1.
                for (i, w) in words.iter_mut().enumerate() {
                    let h = splitmix64(key, line_addr * 16 + i as u64);
                    *w = if h & 1 == 0 { (h >> 1) as u32 % 8 } else { 0 };
                }
            }
            ValueClass::Pointer => {
                // qword array of nearby pointers: base8-delta1/2 territory
                let base = 0x5500_0000_0000u64 | (splitmix64(key, line_addr / 64) & 0xFFFF_FFFF_F000);
                let mut q = [0u64; 8];
                for (i, v) in q.iter_mut().enumerate() {
                    let h = splitmix64(key, line_addr * 8 + i as u64);
                    *v = base.wrapping_add((h % 4096) as u64);
                }
                return CacheLine::from_qwords(q);
            }
            ValueClass::Float => {
                // double-precision-like values sharing exponents: high
                // mantissa entropy, compresses poorly but not never
                let exp = 0x3FF0u64 | (splitmix64(key, line_addr / 16) & 0x7);
                let mut q = [0u64; 8];
                for (i, v) in q.iter_mut().enumerate() {
                    let h = splitmix64(key, line_addr * 8 + i as u64);
                    // ~30 bits of mantissa entropy: B8D4 applies (41B) —
                    // individually compressible but too big to pack pairs.
                    *v = (exp << 48) | (h & 0x3FFF_FFFF);
                }
                return CacheLine::from_qwords(q);
            }
            ValueClass::Random => {
                for (i, w) in words.iter_mut().enumerate() {
                    *w = splitmix64(key, line_addr * 16 + i as u64) as u32;
                }
            }
        }
        CacheLine::from_words(words)
    }
}

/// Memoizing per-line hybrid-size oracle — the timing simulator's view of
/// compressibility.  Sizes come from the real compressors over generated
/// contents; `dirty_update` re-rolls a line's version, modeling stores
/// that change (and occasionally break) compressibility.
pub struct SizeOracle {
    model: ValueModel,
    /// Which algorithms the hybrid compressor may pick (ablation knob).
    pub algo: AlgoSet,
    /// Flat cache for the contiguous physical region this oracle serves
    /// (0 = not yet computed; real sizes are >= 2).
    region_base: u64,
    region: Vec<u8>,
    /// Spill cache for addresses outside the region (tests, ad-hoc use).
    cache: HashMap<u64, u8>,
    versions: HashMap<u64, u32>,
    pub lookups: u64,
    pub computes: u64,
}

impl SizeOracle {
    pub fn new(model: ValueModel) -> Self {
        Self {
            model,
            algo: AlgoSet::FpcBdi,
            region_base: 0,
            region: Vec::new(),
            cache: HashMap::new(),
            versions: HashMap::new(),
            lookups: 0,
            computes: 0,
        }
    }

    /// Oracle with a flat (Vec-backed) size cache over `[base, base+len)`
    /// physical lines — the simulator's per-core region.  O(1) lookups
    /// with no hashing on the hot path.
    pub fn with_region(model: ValueModel, base: u64, len: u64) -> Self {
        Self {
            model,
            algo: AlgoSet::FpcBdi,
            region_base: base,
            region: vec![0u8; len as usize],
            cache: HashMap::new(),
            versions: HashMap::new(),
            lookups: 0,
            computes: 0,
        }
    }

    pub fn model(&self) -> &ValueModel {
        &self.model
    }

    /// Hybrid compressed size of the line (64 ⇒ raw).
    pub fn size(&mut self, line_addr: u64) -> u32 {
        self.lookups += 1;
        let idx = line_addr.wrapping_sub(self.region_base);
        if (idx as usize) < self.region.len() {
            let s = self.region[idx as usize];
            if s != 0 {
                return s as u32;
            }
            let s = self.compute(line_addr);
            self.region[idx as usize] = s as u8;
            return s;
        }
        if let Some(&s) = self.cache.get(&line_addr) {
            return s as u32;
        }
        let s = self.compute(line_addr);
        self.cache.insert(line_addr, s as u8);
        s
    }

    fn compute(&mut self, line_addr: u64) -> u32 {
        self.computes += 1;
        let v = self.versions.get(&line_addr).copied().unwrap_or(0);
        let line = self.model.gen_line(line_addr, v);
        hybrid::compressed_size_with(&line, self.algo)
    }

    /// Sizes of all four lines in `line_addr`'s group.
    pub fn group_sizes(&mut self, line_addr: u64) -> [u32; 4] {
        let base = crate::mem::group_base(line_addr);
        core::array::from_fn(|i| self.size(base + i as u64))
    }

    /// A store dirtied the line: bump its version (values change, class
    /// stays — compressibility usually survives but can shift).
    pub fn dirty_update(&mut self, line_addr: u64) {
        let v = self.versions.entry(line_addr).or_insert(0);
        *v += 1;
        let idx = line_addr.wrapping_sub(self.region_base);
        if (idx as usize) < self.region.len() {
            self.region[idx as usize] = 0;
        } else {
            self.cache.remove(&line_addr);
        }
    }

    /// The actual line content (byte-accurate paths).
    pub fn content(&self, line_addr: u64) -> CacheLine {
        let v = self.versions.get(&line_addr).copied().unwrap_or(0);
        self.model.gen_line(line_addr, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::hybrid;

    fn model(weights: [f64; 5]) -> ValueModel {
        ValueModel::new(weights, 0xABCD)
    }

    #[test]
    fn classes_deterministic_and_page_granular() {
        let m = model([1.0, 1.0, 1.0, 1.0, 1.0]);
        for page in 0..50u64 {
            let first = m.class_of_line(page * 64);
            for l in 0..64 {
                assert_eq!(m.class_of_line(page * 64 + l), first);
            }
        }
    }

    #[test]
    fn class_sizes_land_in_expected_bands() {
        let zero = model([1.0, 0.0, 0.0, 0.0, 0.0]);
        let small = model([0.0, 1.0, 0.0, 0.0, 0.0]);
        let ptr = model([0.0, 0.0, 1.0, 0.0, 0.0]);
        let rnd = model([0.0, 0.0, 0.0, 0.0, 1.0]);
        for la in 0..256u64 {
            let sz = hybrid::compressed_size(&zero.gen_line(la, 0));
            assert!(sz <= 15, "zero-class line {la} size {sz}");
            let ss = hybrid::compressed_size(&small.gen_line(la, 0));
            assert!(ss <= 15, "small-int line {la} size {ss}");
            let sp = hybrid::compressed_size(&ptr.gen_line(la, 0));
            assert!((16..=30).contains(&sp), "pointer line {la} size {sp}");
            let sr = hybrid::compressed_size(&rnd.gen_line(la, 0));
            assert_eq!(sr, 64, "random line {la}");
        }
    }

    #[test]
    fn float_class_mostly_unpackable() {
        let f = model([0.0, 0.0, 0.0, 1.0, 0.0]);
        let mut pair_fits = 0;
        for g in 0..200u64 {
            let a = hybrid::compressed_size(&f.gen_line(g * 4, 0));
            let b = hybrid::compressed_size(&f.gen_line(g * 4 + 1, 0));
            if a + b <= 60 {
                pair_fits += 1;
            }
        }
        assert!(pair_fits < 20, "float pages should rarely pair: {pair_fits}");
    }

    #[test]
    fn oracle_caches_and_invalidates() {
        let mut o = SizeOracle::new(model([0.0, 1.0, 0.0, 0.0, 0.0]));
        let s1 = o.size(100);
        let s2 = o.size(100);
        assert_eq!(s1, s2);
        assert_eq!(o.computes, 1);
        o.dirty_update(100);
        let _s3 = o.size(100);
        assert_eq!(o.computes, 2);
    }

    #[test]
    fn oracle_matches_content_compression() {
        let mut o = SizeOracle::new(model([1.0, 1.0, 1.0, 1.0, 1.0]));
        for la in 0..200u64 {
            let want = hybrid::compressed_size(&o.content(la));
            assert_eq!(o.size(la), want);
        }
    }

    #[test]
    fn version_changes_values_not_class() {
        let m = model([1.0, 1.0, 1.0, 1.0, 1.0]);
        let mut changed = 0;
        for la in 0..64u64 {
            if m.gen_line(la, 0) != m.gen_line(la, 1) {
                changed += 1;
            }
            assert_eq!(m.class_of_line(la), m.class_of_line(la));
        }
        assert!(changed > 32, "most lines should change under a new version");
    }
}
