//! Workload profiles: the 64-benchmark evaluation set.
//!
//! Memory-intensive profiles are calibrated to the paper's Table II
//! (L3 MPKI, footprint); behavioural knobs (spatial locality, reuse,
//! value mix, MLP) are set per suite/benchmark from the workloads'
//! well-known characteristics so the evaluation *shape* reproduces:
//! streaming FP codes gain from CRAM's free adjacent-line prefetch,
//! graph codes have poor locality/reuse (compression costs dominate),
//! `xz`/`cactu` thrash the explicit-metadata cache, etc.
//!
//! Footprints are the per-core share of Table II's rate-mode footprint,
//! capped at 256 MB to bound simulator memory (documented in DESIGN.md
//! §Substitutions; the cap preserves footprint ≫ LLC, which is what the
//! behaviour depends on).

use super::values::ValueModel;

/// Benchmark suite, for per-suite averages (Table V).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Suite {
    Spec06,
    Spec17,
    Gap,
    Mix,
    /// Far-memory-pressure set for the tiered-memory evaluation (Fig. T1).
    Far,
    /// Latency-sensitive set for the scheduler evaluation (Figure Q1).
    Lat,
    /// Cache-pressure set for the compressed-LLC evaluation (Figure C1).
    Cache,
}

impl std::fmt::Display for Suite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Suite::Spec06 => write!(f, "SPEC06"),
            Suite::Spec17 => write!(f, "SPEC17"),
            Suite::Gap => write!(f, "GAP"),
            Suite::Mix => write!(f, "MIX"),
            Suite::Far => write!(f, "FAR"),
            Suite::Lat => write!(f, "LAT"),
            Suite::Cache => write!(f, "CACHE"),
        }
    }
}

/// A single-program workload model (run in rate mode on 8 cores, or mixed).
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    pub name: &'static str,
    pub suite: Suite,
    /// Paper Table II L3 MPKI (calibration target, for reporting).
    pub table_mpki: f64,
    /// Per-core footprint in MB (Table II / 8 cores, capped at 256).
    pub footprint_mb: u64,
    /// LLC accesses per kilo-instruction.
    pub apki: f64,
    /// Probability the next access continues a sequential run.
    pub p_seq: f64,
    /// Hot-set fraction of the footprint.
    pub hot_frac: f64,
    /// Probability a non-sequential access targets the hot set.
    pub p_hot: f64,
    /// Fraction of accesses that are writes.
    pub write_frac: f64,
    /// Outstanding-miss window (memory-level parallelism).
    pub mlp: usize,
    /// Probability an access is a dependent load (core blocks on it).
    pub p_dep: f64,
    /// Page value-class weights [Zero, SmallInt, Pointer, Float, Random].
    pub values: [f64; 5],
    /// If non-empty this is a MIX: per-core component workload names.
    pub mix_of: &'static [&'static str],
}

impl WorkloadProfile {
    pub fn value_model(&self, seed: u64) -> ValueModel {
        ValueModel::new(self.values, seed)
    }

    pub fn footprint_lines(&self) -> u64 {
        self.footprint_mb * 1024 * 1024 / 64
    }
}

macro_rules! wl {
    ($name:expr, $suite:expr, $mpki:expr, $fp:expr, $apki:expr, $seq:expr,
     $hotf:expr, $phot:expr, $wr:expr, $mlp:expr, $dep:expr, $vals:expr) => {
        WorkloadProfile {
            name: $name,
            suite: $suite,
            table_mpki: $mpki,
            footprint_mb: $fp,
            apki: $apki,
            p_seq: $seq,
            hot_frac: $hotf,
            p_hot: $phot,
            write_frac: $wr,
            mlp: $mlp,
            p_dep: $dep,
            values: $vals,
            mix_of: &[],
        }
    };
}

/// The 21 memory-intensive single-program workloads of Table II.
pub fn table2() -> Vec<WorkloadProfile> {
    use Suite::*;
    vec![
        // --- SPEC (Table II order) ---
        // streaming FP solver; mixed float/small data
        wl!("fotonik", Spec17, 26.2, 256, 34.0, 0.82, 0.05, 0.30, 0.30, 8, 0.25,
            [0.10, 0.25, 0.10, 0.45, 0.10]),
        // lattice-boltzmann: big streaming arrays, moderate compressibility
        wl!("lbm17", Spec17, 25.5, 256, 33.0, 0.85, 0.05, 0.25, 0.40, 8, 0.20,
            [0.08, 0.22, 0.10, 0.50, 0.10]),
        // LP solver: sparse matrices, pointer+small mix
        wl!("soplex", Spec06, 23.3, 256, 31.0, 0.55, 0.10, 0.45, 0.25, 6, 0.45,
            [0.15, 0.25, 0.30, 0.15, 0.15]),
        // libquantum: highly regular stream of small states — the big CRAM
        // winner (up to ~73%)
        wl!("libq", Spec06, 23.1, 52, 30.0, 0.95, 0.08, 0.50, 0.25, 8, 0.15,
            [0.45, 0.40, 0.05, 0.05, 0.05]),
        // mcf: pointer chasing, low MLP, moderately compressible graph data
        wl!("mcf17", Spec17, 22.8, 256, 32.0, 0.30, 0.12, 0.50, 0.20, 3, 0.70,
            [0.10, 0.25, 0.35, 0.05, 0.25]),
        // milc: QCD lattice, streaming doubles
        wl!("milc", Spec06, 21.9, 256, 29.0, 0.80, 0.06, 0.30, 0.35, 8, 0.25,
            [0.08, 0.17, 0.10, 0.50, 0.15]),
        // GemsFDTD: streaming stencil
        wl!("Gems", Spec06, 17.2, 256, 24.0, 0.80, 0.06, 0.30, 0.35, 8, 0.25,
            [0.10, 0.25, 0.10, 0.45, 0.10]),
        // parest: FE solver, small footprint, decent reuse
        wl!("parest", Spec17, 16.4, 58, 23.0, 0.65, 0.15, 0.55, 0.30, 6, 0.35,
            [0.12, 0.28, 0.20, 0.30, 0.10]),
        // sphinx: speech model, small footprint, compressible acoustics
        wl!("sphinx", Spec06, 11.9, 28, 17.0, 0.60, 0.20, 0.60, 0.15, 6, 0.40,
            [0.15, 0.30, 0.20, 0.25, 0.10]),
        // leslie3d: streaming CFD
        wl!("leslie", Spec06, 11.9, 108, 17.0, 0.82, 0.08, 0.35, 0.35, 8, 0.25,
            [0.08, 0.22, 0.10, 0.50, 0.10]),
        // cactuBSSN: stencil with LOW spatial locality at LLC level —
        // metadata-cache unfriendly (paper: 50-80% metadata overhead)
        wl!("cactu17", Spec17, 10.6, 256, 16.0, 0.22, 0.08, 0.35, 0.30, 5, 0.40,
            [0.10, 0.25, 0.20, 0.30, 0.15]),
        // omnetpp: discrete-event sim, pointer-heavy, poor locality
        wl!("omnet17", Spec17, 8.6, 238, 13.0, 0.25, 0.15, 0.55, 0.25, 4, 0.60,
            [0.10, 0.20, 0.40, 0.05, 0.25]),
        // gcc: small footprint, good reuse, compressible structs
        wl!("gcc06", Spec06, 5.8, 26, 9.5, 0.45, 0.25, 0.70, 0.25, 5, 0.45,
            [0.15, 0.30, 0.30, 0.05, 0.20]),
        // xz: dictionary compression — scattered accesses, LOW spatial
        // locality, big footprint: the explicit-metadata worst case
        wl!("xz", Spec17, 5.7, 118, 9.0, 0.12, 0.10, 0.40, 0.35, 4, 0.50,
            [0.08, 0.17, 0.25, 0.05, 0.45]),
        // wrf: weather model, streaming FP
        wl!("wrf17", Spec17, 5.2, 100, 8.5, 0.75, 0.10, 0.40, 0.30, 7, 0.30,
            [0.10, 0.25, 0.10, 0.45, 0.10]),
        // --- GAP (real-graph analytics: poor locality, poor reuse) ---
        wl!("bc_twi", Gap, 66.6, 256, 78.0, 0.08, 0.06, 0.30, 0.15, 5, 0.55,
            [0.06, 0.14, 0.30, 0.00, 0.50]),
        wl!("bc_web", Gap, 7.4, 256, 12.0, 0.30, 0.10, 0.45, 0.15, 5, 0.50,
            [0.08, 0.17, 0.30, 0.00, 0.45]),
        wl!("cc_twi", Gap, 101.8, 256, 115.0, 0.06, 0.06, 0.25, 0.20, 6, 0.50,
            [0.06, 0.14, 0.30, 0.00, 0.50]),
        wl!("cc_web", Gap, 8.1, 256, 13.0, 0.32, 0.10, 0.45, 0.20, 5, 0.50,
            [0.08, 0.17, 0.30, 0.00, 0.45]),
        wl!("pr_twi", Gap, 144.8, 256, 160.0, 0.10, 0.05, 0.20, 0.25, 8, 0.40,
            [0.06, 0.14, 0.30, 0.00, 0.50]),
        wl!("pr_web", Gap, 13.1, 256, 19.0, 0.35, 0.08, 0.40, 0.25, 6, 0.40,
            [0.08, 0.17, 0.30, 0.00, 0.45]),
    ]
}

/// Additional non-memory-intensive SPEC workloads for the Fig. 18 extended
/// set (MPKI < 5: little is at stake either way — the S-curve's flat
/// middle).
pub fn low_mpki() -> Vec<WorkloadProfile> {
    use Suite::*;
    let t = |name, suite, mpki, fp, seq: f64, vals| {
        wl!(
            name, suite, mpki, fp,
            mpki * 2.0 + 1.0, seq, 0.25, 0.75, 0.25, 4, 0.45, vals
        )
    };
    // value mixes: int codes lean small/pointer, fp codes lean float
    let int_mix = [0.12, 0.28, 0.30, 0.05, 0.25];
    let fp_mix = [0.10, 0.22, 0.10, 0.43, 0.15];
    let v = vec![
        // SPEC2006 remainder (29 total - 7 in table2 = 22)
        t("perlbench06", Spec06, 0.8, 24, 0.4, int_mix),
        t("bzip206", Spec06, 3.1, 52, 0.3, int_mix),
        t("bwaves06", Spec06, 4.8, 112, 0.8, fp_mix),
        t("gamess06", Spec06, 0.2, 12, 0.4, fp_mix),
        t("mcf06", Spec06, 4.9, 108, 0.3, int_mix),
        t("zeusmp06", Spec06, 4.2, 64, 0.75, fp_mix),
        t("gromacs06", Spec06, 0.7, 16, 0.6, fp_mix),
        t("cactusADM06", Spec06, 4.6, 86, 0.25, fp_mix),
        t("namd06", Spec06, 0.3, 14, 0.6, fp_mix),
        t("gobmk06", Spec06, 0.6, 16, 0.35, int_mix),
        t("dealII06", Spec06, 2.1, 40, 0.5, fp_mix),
        t("povray06", Spec06, 0.1, 8, 0.4, fp_mix),
        t("calculix06", Spec06, 1.3, 30, 0.6, fp_mix),
        t("hmmer06", Spec06, 0.9, 18, 0.5, int_mix),
        t("sjeng06", Spec06, 0.4, 22, 0.3, int_mix),
        t("h264ref06", Spec06, 0.5, 20, 0.5, int_mix),
        t("tonto06", Spec06, 0.6, 16, 0.5, fp_mix),
        t("omnetpp06", Spec06, 2.8, 42, 0.25, int_mix),
        t("astar06", Spec06, 3.2, 48, 0.3, int_mix),
        t("wrf06", Spec06, 2.9, 74, 0.7, fp_mix),
        t("xalancbmk06", Spec06, 2.4, 46, 0.3, int_mix),
        t("specrand06", Spec06, 0.1, 4, 0.2, int_mix),
        // SPEC2017 remainder (23 total - 8 in table2 = 15)
        t("perlbench17", Spec17, 0.9, 26, 0.4, int_mix),
        t("gcc17", Spec17, 3.4, 64, 0.4, int_mix),
        t("bwaves17", Spec17, 4.9, 128, 0.8, fp_mix),
        t("namd17", Spec17, 0.4, 18, 0.6, fp_mix),
        t("povray17", Spec17, 0.1, 8, 0.4, fp_mix),
        t("xalancbmk17", Spec17, 4.9, 108, 0.3, int_mix),
        t("x26417", Spec17, 0.6, 24, 0.5, int_mix),
        t("blender17", Spec17, 1.8, 56, 0.45, fp_mix),
        t("cam417", Spec17, 3.1, 96, 0.7, fp_mix),
        t("deepsjeng17", Spec17, 0.7, 44, 0.3, int_mix),
        t("imagick17", Spec17, 1.1, 38, 0.6, fp_mix),
        t("leela17", Spec17, 0.5, 16, 0.35, int_mix),
        t("nab17", Spec17, 1.4, 30, 0.55, fp_mix),
        t("exchange217", Spec17, 0.1, 6, 0.4, int_mix),
        t("roms17", Spec17, 4.4, 112, 0.75, fp_mix),
    ];
    v
}

/// The 6 MIX workloads: random SPEC pairings, 8 cores alternating.
pub fn mixes() -> Vec<WorkloadProfile> {
    let mk = |name: &'static str, comp: &'static [&'static str]| WorkloadProfile {
        name,
        suite: Suite::Mix,
        table_mpki: 0.0,
        footprint_mb: 0,
        apki: 0.0,
        p_seq: 0.0,
        hot_frac: 0.0,
        p_hot: 0.0,
        write_frac: 0.0,
        mlp: 0,
        p_dep: 0.0,
        values: [0.0; 5],
        mix_of: comp,
    };
    vec![
        mk("mix1", &["libq", "mcf17", "fotonik", "xz", "libq", "mcf17", "fotonik", "xz"]),
        mk("mix2", &["soplex", "milc", "omnet17", "gcc06", "soplex", "milc", "omnet17", "gcc06"]),
        mk("mix3", &["lbm17", "sphinx", "cactu17", "parest", "lbm17", "sphinx", "cactu17", "parest"]),
        mk("mix4", &["Gems", "libq", "wrf17", "mcf17", "Gems", "libq", "wrf17", "mcf17"]),
        mk("mix5", &["leslie", "xz", "soplex", "fotonik", "leslie", "xz", "soplex", "fotonik"]),
        mk("mix6", &["milc", "omnet17", "libq", "cactu17", "milc", "omnet17", "libq", "cactu17"]),
    ]
}

/// Far-memory-pressure workloads for the tiered-memory evaluation
/// (Figure T1).  Each models a capacity-bound deployment: the footprint
/// maxes out the per-core cap so a large slice of it lives on the far
/// tier, and the hot set is big enough that migration cannot simply pull
/// the working set near — the far tier stays on the demand path, which is
/// exactly where a compressed expander earns (or fails to earn) its keep.
///
/// * `cap_stream` — capacity-bound streaming analytics over small-value
///   arrays (libq-like); quad-packable → the best case for a CRAM far
///   tier (4 lines per link flit).
/// * `cap_ptr` — in-memory index sweep: pointer-dense nodes, moderate
///   sequentiality; mostly 2:1-packable.
/// * `cap_gap` — capacity-bound graph analytics (pr_twi-like): scattered
///   demand over a huge footprint, pointer/small mix.
/// * `cap_float` — an HPC checkpoint-like FP footprint: high mantissa
///   entropy, rarely packs — the honesty case (a compressed far tier
///   must not *lose* here).
/// * `cap_mix` — rate-mode mix of the above on 8 cores.
pub fn far_pressure() -> Vec<WorkloadProfile> {
    use Suite::*;
    let mut v = vec![
        wl!("cap_stream", Far, 30.0, 256, 40.0, 0.80, 0.30, 0.55, 0.30, 8, 0.25,
            [0.35, 0.40, 0.10, 0.10, 0.05]),
        wl!("cap_ptr", Far, 25.0, 256, 34.0, 0.55, 0.30, 0.60, 0.25, 6, 0.40,
            [0.10, 0.20, 0.50, 0.05, 0.15]),
        wl!("cap_gap", Far, 60.0, 256, 70.0, 0.12, 0.25, 0.50, 0.20, 5, 0.50,
            [0.08, 0.20, 0.35, 0.00, 0.37]),
        wl!("cap_float", Far, 20.0, 256, 28.0, 0.75, 0.25, 0.50, 0.30, 7, 0.30,
            [0.05, 0.10, 0.10, 0.45, 0.30]),
    ];
    v.push(WorkloadProfile {
        name: "cap_mix",
        suite: Suite::Far,
        table_mpki: 0.0,
        footprint_mb: 0,
        apki: 0.0,
        p_seq: 0.0,
        hot_frac: 0.0,
        p_hot: 0.0,
        write_frac: 0.0,
        mlp: 0,
        p_dep: 0.0,
        values: [0.0; 5],
        mix_of: &[
            "cap_stream", "cap_ptr", "cap_gap", "cap_float",
            "cap_stream", "cap_ptr", "cap_gap", "cap_float",
        ],
    });
    v
}

/// Latency-sensitive workloads for the transaction-scheduler evaluation
/// (Figure Q1).  Low memory-level parallelism and high load dependence
/// make these *tail-latency-bound*: IPC barely moves with raw bandwidth,
/// but p95/p99 read latency moves with scheduling policy (queue depth,
/// write-drain watermarks, row-hit bypass) — which is exactly what the
/// Q1 exhibit isolates.
///
/// * `lat_chase` — a single-chain pointer walk over a large working set:
///   MLP 1, almost every access a dependent load.  Every miss exposes
///   queueing, row conflicts, and any write drain in its way.
/// * `lat_zipf` — zipf-skewed key-value lookups: a tiny hot set keeps a
///   few rows warm while the cold tail rides behind conflicts — the
///   p50/p99 split is the signature.
/// * `lat_wrburst` — read-mostly scans with bursty logging writes:
///   dependent reads race the write-drain hysteresis, the case the
///   high/low watermarks exist for.
pub fn latency_sensitive() -> Vec<WorkloadProfile> {
    use Suite::*;
    vec![
        wl!("lat_chase", Lat, 18.0, 192, 24.0, 0.05, 0.10, 0.35, 0.05, 1, 0.95,
            [0.08, 0.20, 0.45, 0.02, 0.25]),
        wl!("lat_zipf", Lat, 12.0, 224, 18.0, 0.10, 0.02, 0.90, 0.10, 2, 0.85,
            [0.10, 0.30, 0.30, 0.05, 0.25]),
        wl!("lat_wrburst", Lat, 16.0, 208, 22.0, 0.45, 0.10, 0.40, 0.50, 3, 0.70,
            [0.12, 0.30, 0.20, 0.08, 0.30]),
    ]
}

/// Cache-pressure workloads for the compressed-LLC evaluation (Figure
/// C1).  Each keeps a hot working set *slightly larger* than the 8MB
/// shared LLC — the regime where storing lines compressed turns capacity
/// misses into hits (Touché's motivating case).  Footprints are small
/// enough that raw memory bandwidth is not the bottleneck; residency is.
///
/// * `llcfit_stream` — a hot ~10MB (8 cores × 1.25MB) array of
///   small-value records re-touched continuously; quad-packable lines,
///   so a 2×-tag compressed LLC holds the whole hot set.
/// * `llcfit_ptr` — an index/pointer structure with a hot ~12MB core;
///   pointer-dense lines pack ~2:1 — the partial-win case.
/// * `llcfit_rand` — the honesty control: the same pressure but high-
///   entropy values; the data budget stays the limit, so the compressed
///   LLC must behave like the plain one (no slowdown, ratio ≈ 1).
pub fn cache_pressure() -> Vec<WorkloadProfile> {
    use Suite::*;
    vec![
        wl!("llcfit_stream", Cache, 8.0, 10, 50.0, 0.10, 0.125, 0.92, 0.20, 8, 0.30,
            [0.30, 0.55, 0.05, 0.00, 0.10]),
        wl!("llcfit_ptr", Cache, 9.0, 12, 40.0, 0.05, 0.125, 0.90, 0.25, 4, 0.60,
            [0.10, 0.30, 0.45, 0.05, 0.10]),
        wl!("llcfit_rand", Cache, 9.0, 12, 40.0, 0.10, 0.104, 0.90, 0.20, 6, 0.40,
            [0.02, 0.08, 0.05, 0.05, 0.80]),
    ]
}

/// The paper's 27-workload memory-intensive evaluation set
/// (15 SPEC + 6 GAP + 6 MIX).
pub fn all27() -> Vec<WorkloadProfile> {
    let mut v = table2();
    v.extend(mixes());
    v
}

/// The extended 64-workload set of Fig. 18
/// (29 SPEC2006 + 23 SPEC2017 + 6 GAP + 6 MIX).
pub fn all64() -> Vec<WorkloadProfile> {
    let mut v = table2();
    v.extend(low_mpki());
    v.extend(mixes());
    v
}

/// Look up a profile by name across the full set (including the
/// far-memory-pressure, latency-sensitive and cache-pressure sets).
pub fn by_name(name: &str) -> Option<WorkloadProfile> {
    all64()
        .into_iter()
        .chain(far_pressure())
        .chain(latency_sensitive())
        .chain(cache_pressure())
        .find(|w| w.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_sizes_match_paper() {
        assert_eq!(table2().len(), 21); // Table II rows
        assert_eq!(all27().len(), 27); // 21 + 6 MIX
        assert_eq!(all64().len(), 64); // 29+23+6+6
        let a64 = all64();
        let count = |s: Suite| a64.iter().filter(|w| w.suite == s).count();
        assert_eq!(count(Suite::Spec06), 29);
        assert_eq!(count(Suite::Spec17), 23);
        assert_eq!(count(Suite::Gap), 6);
        assert_eq!(count(Suite::Mix), 6);
    }

    #[test]
    fn names_unique() {
        let all = all64();
        let mut names: Vec<_> = all.iter().map(|w| w.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn mix_components_resolve() {
        for m in mixes() {
            assert_eq!(m.mix_of.len(), 8);
            for c in m.mix_of {
                let p = by_name(c).expect("mix component exists");
                assert!(p.mix_of.is_empty(), "mixes must not nest");
            }
        }
    }

    #[test]
    fn weights_sane() {
        for w in all64() {
            if w.mix_of.is_empty() {
                assert!(w.apki > 0.0, "{}", w.name);
                assert!(w.footprint_mb > 0, "{}", w.name);
                assert!((0.0..=1.0).contains(&w.p_seq));
                assert!((0.0..=1.0).contains(&w.write_frac));
                assert!(w.mlp >= 1);
                assert!(w.values.iter().sum::<f64>() > 0.0);
            }
        }
    }

    #[test]
    fn by_name_finds_table2_entries() {
        assert!(by_name("libq").is_some());
        assert!(by_name("pr_twi").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn far_pressure_set_well_formed() {
        let far = far_pressure();
        assert!(far.len() >= 4, "at least 4 far-memory-pressure profiles");
        for w in &far {
            assert_eq!(w.suite, Suite::Far);
            assert!(by_name(w.name).is_some(), "{} resolvable", w.name);
            if w.mix_of.is_empty() {
                assert_eq!(w.footprint_mb, 256, "{}: capacity-bound", w.name);
                assert!(w.apki > 0.0);
            } else {
                assert_eq!(w.mix_of.len(), 8);
                for c in w.mix_of {
                    assert!(by_name(c).unwrap().mix_of.is_empty());
                }
            }
        }
        // the far set must not leak into the paper's evaluation sets
        for w in all64() {
            assert_ne!(w.suite, Suite::Far);
        }
    }

    #[test]
    fn latency_set_well_formed() {
        let lat = latency_sensitive();
        assert!(lat.len() >= 3, "at least 3 latency-sensitive profiles");
        for w in &lat {
            assert_eq!(w.suite, Suite::Lat);
            assert!(by_name(w.name).is_some(), "{} resolvable", w.name);
            assert!(w.mlp <= 3, "{}: scheduling-dominated means low MLP", w.name);
            assert!(w.p_dep >= 0.7, "{}: dependent-load heavy", w.name);
            assert!(w.footprint_mb * 1024 * 1024 / 64 > 128 * 1024, "{}: footprint >> LLC", w.name);
        }
        // the latency set must not leak into the paper's evaluation sets
        for w in all64() {
            assert_ne!(w.suite, Suite::Lat);
        }
    }

    #[test]
    fn cache_pressure_set_well_formed() {
        let set = cache_pressure();
        assert!(set.len() >= 2, "at least 2 cache-pressure profiles");
        for w in &set {
            assert_eq!(w.suite, Suite::Cache);
            assert!(by_name(w.name).is_some(), "{} resolvable", w.name);
            assert!(w.mix_of.is_empty());
            // the defining property: hot set slightly larger than the 8MB
            // LLC (shared by 8 cores), but not so large that residency
            // stops mattering
            let hot_bytes =
                (w.footprint_mb as f64 * 1024.0 * 1024.0 * w.hot_frac * 8.0) as u64;
            let llc = 8 * 1024 * 1024u64;
            assert!(
                hot_bytes > llc && hot_bytes < 3 * llc,
                "{}: hot set {}MB must straddle the LLC",
                w.name,
                hot_bytes / (1024 * 1024)
            );
            assert!(w.p_hot >= 0.85, "{}: reuse-dominated", w.name);
        }
        // at least one compressible winner and one incompressible control
        assert!(set.iter().any(|w| w.values[4] <= 0.15));
        assert!(set.iter().any(|w| w.values[4] >= 0.6));
        // the cache set must not leak into the paper's evaluation sets
        for w in all64() {
            assert_ne!(w.suite, Suite::Cache);
        }
    }
}
