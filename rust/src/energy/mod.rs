//! DRAM energy / power / EDP model (Fig. 19).
//!
//! Standard DDR4 energy accounting at the abstraction level of the
//! bandwidth model: per-access energy split by row hit/miss (activation is
//! the expensive part), plus background power integrated over the run.
//! Constants are representative DDR4-2400 x8 numbers (Micron power calc
//! methodology); the figure reports *normalized* energy, so only ratios
//! matter.

use crate::dram::timing::DramStats;

/// Energy constants (nanojoules / milliwatts).
#[derive(Clone, Copy, Debug)]
pub struct EnergyConfig {
    /// Row-buffer-hit access: read/write burst energy.
    pub nj_per_hit: f64,
    /// Row miss adds activate+precharge energy.
    pub nj_per_miss: f64,
    /// Background power per channel (mW).
    pub mw_background_per_channel: f64,
    pub channels: usize,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        Self {
            nj_per_hit: 10.0,
            nj_per_miss: 25.0,
            mw_background_per_channel: 450.0,
            channels: 2,
        }
    }
}

/// Energy accounting for one simulation run.
#[derive(Clone, Copy, Debug)]
pub struct EnergyResult {
    /// Dynamic (access) energy in µJ.
    pub dynamic_uj: f64,
    /// Background energy in µJ.
    pub background_uj: f64,
    /// Run time in seconds.
    pub seconds: f64,
}

impl EnergyResult {
    pub fn total_uj(&self) -> f64 {
        self.dynamic_uj + self.background_uj
    }

    /// Average power in mW.
    pub fn avg_power_mw(&self) -> f64 {
        self.total_uj() / self.seconds / 1000.0
    }

    /// Energy-delay product (µJ·s).
    pub fn edp(&self) -> f64 {
        self.total_uj() * self.seconds
    }
}

/// Compute energy from DRAM stats and the run length in CPU cycles
/// (3.2 GHz).
pub fn energy_of(cfg: &EnergyConfig, dram: &DramStats, cpu_cycles: u64) -> EnergyResult {
    let seconds = cpu_cycles as f64 / 3.2e9;
    let dynamic_nj =
        dram.row_hits as f64 * cfg.nj_per_hit + dram.row_misses as f64 * cfg.nj_per_miss;
    let background_mw = cfg.mw_background_per_channel * cfg.channels as f64;
    EnergyResult {
        dynamic_uj: dynamic_nj / 1000.0,
        background_uj: background_mw * seconds * 1000.0,
        seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(hits: u64, misses: u64) -> DramStats {
        DramStats {
            row_hits: hits,
            row_misses: misses,
            ..Default::default()
        }
    }

    #[test]
    fn fewer_accesses_less_dynamic_energy() {
        let cfg = EnergyConfig::default();
        let a = energy_of(&cfg, &stats(1000, 1000), 3_200_000);
        let b = energy_of(&cfg, &stats(500, 500), 3_200_000);
        assert!(b.dynamic_uj < a.dynamic_uj);
        assert_eq!(a.background_uj, b.background_uj);
    }

    #[test]
    fn shorter_run_less_background_and_better_edp() {
        let cfg = EnergyConfig::default();
        let slow = energy_of(&cfg, &stats(1000, 1000), 6_400_000);
        let fast = energy_of(&cfg, &stats(1000, 1000), 3_200_000);
        assert!(fast.background_uj < slow.background_uj);
        assert!(fast.edp() < slow.edp());
    }

    #[test]
    fn row_misses_cost_more() {
        let cfg = EnergyConfig::default();
        let hits = energy_of(&cfg, &stats(1000, 0), 3_200_000);
        let misses = energy_of(&cfg, &stats(0, 1000), 3_200_000);
        assert!(misses.dynamic_uj > 2.0 * hits.dynamic_uj);
    }

    #[test]
    fn power_is_energy_over_time() {
        let cfg = EnergyConfig::default();
        let e = energy_of(&cfg, &stats(0, 0), 3_200_000_000);
        // background only: 900 mW over 1 s
        assert!((e.avg_power_mw() - 900.0).abs() < 1.0);
    }
}
