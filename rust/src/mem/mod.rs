//! Memory data model: the 64-byte cacheline, physical-address helpers,
//! and the paged-arena map backing the hot-path physical stores.

pub mod arena;
pub mod line;

pub use arena::PagedArena;
pub use line::{CacheLine, LINE_BYTES, LINE_WORDS};

/// Bytes per cacheline everywhere in the system (paper Table I).
pub const LINE_SHIFT: u64 = 6;

/// Lines per compression group (paper §IV-A: up to 4-to-1).
pub const GROUP_LINES: u64 = 4;

/// Bytes per OS page (used by the LLP page-hash and the VM model).
pub const PAGE_BYTES: u64 = 4096;

/// Line address (= byte address >> 6).
#[inline]
pub fn line_addr(byte_addr: u64) -> u64 {
    byte_addr >> LINE_SHIFT
}

/// The group a line belongs to (4 consecutive lines).
#[inline]
pub fn group_of(line: u64) -> u64 {
    line / GROUP_LINES
}

/// Slot of the line within its group: 0 = "A" (address ends 00) … 3 = "D".
#[inline]
pub fn slot_of(line: u64) -> u8 {
    (line % GROUP_LINES) as u8
}

/// First line ("A") of the group containing `line`.
#[inline]
pub fn group_base(line: u64) -> u64 {
    line & !(GROUP_LINES - 1)
}

/// Page number of a line address.
#[inline]
pub fn page_of_line(line: u64) -> u64 {
    (line << LINE_SHIFT) / PAGE_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_helpers() {
        assert_eq!(line_addr(0), 0);
        assert_eq!(line_addr(64), 1);
        assert_eq!(line_addr(127), 1);
        assert_eq!(group_of(7), 1);
        assert_eq!(slot_of(5), 1);
        assert_eq!(group_base(7), 4);
        assert_eq!(page_of_line(63), 0);
        assert_eq!(page_of_line(64), 1); // line 64 = byte 4096
    }
}
