//! The 64-byte cacheline: the unit of transfer on the memory bus.
//!
//! Stored as sixteen little-endian u32 words — the same layout the L1
//! Pallas kernel and the pure-jnp oracle use, so sizes computed here and
//! there are directly comparable.

pub const LINE_BYTES: usize = 64;
pub const LINE_WORDS: usize = 16;

/// A 64-byte line of data.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheLine {
    words: [u32; LINE_WORDS],
}

impl Default for CacheLine {
    fn default() -> Self {
        Self::zero()
    }
}

impl std::fmt::Debug for CacheLine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CacheLine[{:08x} {:08x} … {:08x}]", self.words[0], self.words[1], self.words[15])
    }
}

impl CacheLine {
    /// All-zero line.
    pub const fn zero() -> Self {
        Self { words: [0; LINE_WORDS] }
    }

    pub const fn from_words(words: [u32; LINE_WORDS]) -> Self {
        Self { words }
    }

    pub fn from_bytes(bytes: &[u8; LINE_BYTES]) -> Self {
        let mut words = [0u32; LINE_WORDS];
        for (i, w) in words.iter_mut().enumerate() {
            *w = u32::from_le_bytes([bytes[4 * i], bytes[4 * i + 1], bytes[4 * i + 2], bytes[4 * i + 3]]);
        }
        Self { words }
    }

    pub fn to_bytes(&self) -> [u8; LINE_BYTES] {
        let mut out = [0u8; LINE_BYTES];
        for (i, w) in self.words.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    #[inline]
    pub fn words(&self) -> &[u32; LINE_WORDS] {
        &self.words
    }

    #[inline]
    pub fn words_mut(&mut self) -> &mut [u32; LINE_WORDS] {
        &mut self.words
    }

    /// The line as eight little-endian u64 qwords.
    pub fn qwords(&self) -> [u64; 8] {
        let mut q = [0u64; 8];
        for (i, v) in q.iter_mut().enumerate() {
            *v = self.words[2 * i] as u64 | ((self.words[2 * i + 1] as u64) << 32);
        }
        q
    }

    pub fn from_qwords(q: [u64; 8]) -> Self {
        let mut words = [0u32; LINE_WORDS];
        for (i, v) in q.iter().enumerate() {
            words[2 * i] = *v as u32;
            words[2 * i + 1] = (*v >> 32) as u32;
        }
        Self { words }
    }

    /// The line as thirty-two u16 halfwords (little-endian order).
    pub fn halfwords(&self) -> [u16; 32] {
        let mut h = [0u16; 32];
        for (i, w) in self.words.iter().enumerate() {
            h[2 * i] = *w as u16;
            h[2 * i + 1] = (*w >> 16) as u16;
        }
        h
    }

    /// Last four bytes of the line as a u32 (the marker position).
    #[inline]
    pub fn tail_u32(&self) -> u32 {
        self.words[LINE_WORDS - 1]
    }

    /// Overwrite the marker position.
    #[inline]
    pub fn set_tail_u32(&mut self, v: u32) {
        self.words[LINE_WORDS - 1] = v;
    }

    /// Bitwise inversion — CRAM's marker-collision escape hatch (§V-A).
    pub fn inverted(&self) -> Self {
        let mut words = self.words;
        for w in &mut words {
            *w = !*w;
        }
        Self { words }
    }

    /// True if every byte is zero.
    pub fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// 64-bit content fingerprint (FNV-1a over the words, with a final
    /// avalanche).  Keys the compressibility memo: two lines with equal
    /// fingerprints are treated as having equal compressed size — the
    /// standard memoization tradeoff at ~2^-64 collision probability.
    #[inline]
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        for &w in &self.words {
            h = (h ^ w as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
        h ^= h >> 33;
        h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        h ^ (h >> 33)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        let mut bytes = [0u8; LINE_BYTES];
        for (i, b) in bytes.iter_mut().enumerate() {
            *b = i as u8;
        }
        let line = CacheLine::from_bytes(&bytes);
        assert_eq!(line.to_bytes(), bytes);
        // little-endian check
        assert_eq!(line.words()[0], u32::from_le_bytes([0, 1, 2, 3]));
    }

    #[test]
    fn qwords_roundtrip() {
        let q: [u64; 8] = core::array::from_fn(|i| 0x0123_4567_89AB_CDEF ^ (i as u64) << 56);
        let line = CacheLine::from_qwords(q);
        assert_eq!(line.qwords(), q);
    }

    #[test]
    fn halfwords_layout() {
        let line = CacheLine::from_words(core::array::from_fn(|i| (i as u32) << 16 | 0xBEEF));
        let h = line.halfwords();
        assert_eq!(h[0], 0xBEEF);
        assert_eq!(h[1], 0);
        assert_eq!(h[3], 1);
    }

    #[test]
    fn inversion_is_involution() {
        let line = CacheLine::from_words(core::array::from_fn(|i| 0xDEAD_0000 + i as u32));
        assert_eq!(line.inverted().inverted(), line);
        assert_ne!(line.inverted(), line);
    }

    #[test]
    fn tail_is_last_word() {
        let mut line = CacheLine::zero();
        line.set_tail_u32(0x2222_2222);
        assert_eq!(line.tail_u32(), 0x2222_2222);
        assert_eq!(line.to_bytes()[60..64], [0x22, 0x22, 0x22, 0x22]);
    }

    #[test]
    fn fingerprint_tracks_content() {
        let a = CacheLine::from_words(core::array::from_fn(|i| i as u32));
        let b = CacheLine::from_words(core::array::from_fn(|i| i as u32));
        assert_eq!(a.fingerprint(), b.fingerprint(), "deterministic");
        let mut c = a;
        c.words_mut()[3] ^= 1;
        assert_ne!(a.fingerprint(), c.fingerprint(), "one-bit sensitivity");
        assert_ne!(CacheLine::zero().fingerprint(), a.fingerprint());
    }

    #[test]
    fn zero_detection() {
        assert!(CacheLine::zero().is_zero());
        let mut l = CacheLine::zero();
        l.words_mut()[7] = 1;
        assert!(!l.is_zero());
    }
}
