//! Paged arena — the dense replacement for the simulator's hot-path
//! `HashMap`s (physical line store, per-group CSI maps).
//!
//! Physical line and group addresses are drawn from a bounded, mostly
//! contiguous space (per-core regions of a 16GB machine), so a sparse
//! hash map pays SipHash plus probe chains on every access for no
//! benefit.  The arena instead splits a key into (page, slot):
//! fixed-size pages of `1 << page_shift` slots, allocated lazily on first
//! touch, indexed by plain shifts — O(1) with no hashing, and the four
//! lines of a CRAM group land in adjacent slots of one page, so a group
//! read touches one cache line of metadata instead of four hash probes.
//!
//! A per-page occupancy bitmap preserves exact `HashMap` semantics
//! (`contains`/`remove`/`len` distinguish "never inserted" from "inserted
//! with the default value"); the randomized shadow-model test below pins
//! the equivalence.

/// Default page size: 4096 slots (one shift, one mask per lookup).
pub const ARENA_PAGE_SHIFT: u32 = 12;

struct Page<T> {
    slots: Box<[T]>,
    /// One bit per slot: has this slot been inserted (and not removed)?
    occupied: Box<[u64]>,
}

impl<T: Copy> Page<T> {
    fn new(slots_per_page: usize, default: T) -> Self {
        Self {
            slots: vec![default; slots_per_page].into_boxed_slice(),
            occupied: vec![0u64; slots_per_page.div_ceil(64)].into_boxed_slice(),
        }
    }

    #[inline]
    fn is_occupied(&self, slot: usize) -> bool {
        (self.occupied[slot >> 6] >> (slot & 63)) & 1 == 1
    }

    #[inline]
    fn set_occupied(&mut self, slot: usize) {
        self.occupied[slot >> 6] |= 1u64 << (slot & 63);
    }

    #[inline]
    fn clear_occupied(&mut self, slot: usize) {
        self.occupied[slot >> 6] &= !(1u64 << (slot & 63));
    }
}

/// Lazily-paged flat map from `u64` keys to `T`, with `HashMap`-equivalent
/// insert/get/remove/contains/len semantics.
pub struct PagedArena<T: Copy> {
    page_shift: u32,
    slots_per_page: usize,
    pages: Vec<Option<Page<T>>>,
    default: T,
    len: usize,
}

impl<T: Copy> PagedArena<T> {
    /// Arena with the default page geometry.  `default` is the value
    /// reported by [`PagedArena::copied_or_default`] for absent keys (and
    /// the fill value of fresh pages).
    pub fn new(default: T) -> Self {
        Self::with_page_shift(default, ARENA_PAGE_SHIFT)
    }

    pub fn with_page_shift(default: T, page_shift: u32) -> Self {
        assert!((4..=20).contains(&page_shift), "unreasonable page shift");
        Self {
            page_shift,
            slots_per_page: 1usize << page_shift,
            pages: Vec::new(),
            default,
            len: 0,
        }
    }

    #[inline]
    fn split(&self, key: u64) -> (usize, usize) {
        (
            (key >> self.page_shift) as usize,
            (key & ((1u64 << self.page_shift) - 1)) as usize,
        )
    }

    /// Reference to the value at `key`, if one was inserted.
    #[inline]
    pub fn get(&self, key: u64) -> Option<&T> {
        let (p, s) = self.split(key);
        match self.pages.get(p) {
            Some(Some(page)) if page.is_occupied(s) => Some(&page.slots[s]),
            _ => None,
        }
    }

    /// The value at `key`, or the arena default for absent keys — the
    /// hot-path read (one shift, one mask, no hashing, no branching on
    /// `Option` at the caller).
    #[inline]
    pub fn copied_or_default(&self, key: u64) -> T {
        let (p, s) = self.split(key);
        match self.pages.get(p) {
            Some(Some(page)) if page.is_occupied(s) => page.slots[s],
            _ => self.default,
        }
    }

    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    /// Insert, returning the previous value if the key was occupied.
    pub fn insert(&mut self, key: u64, value: T) -> Option<T> {
        let (p, s) = self.split(key);
        if p >= self.pages.len() {
            self.pages.resize_with(p + 1, || None);
        }
        let slots_per_page = self.slots_per_page;
        let default = self.default;
        let page = self.pages[p].get_or_insert_with(|| Page::new(slots_per_page, default));
        let old = if page.is_occupied(s) {
            Some(page.slots[s])
        } else {
            page.set_occupied(s);
            self.len += 1;
            None
        };
        page.slots[s] = value;
        old
    }

    /// Remove, returning the value if the key was occupied.
    pub fn remove(&mut self, key: u64) -> Option<T> {
        let (p, s) = self.split(key);
        let default = self.default;
        match self.pages.get_mut(p) {
            Some(Some(page)) if page.is_occupied(s) => {
                let old = page.slots[s];
                page.slots[s] = default;
                page.clear_occupied(s);
                self.len -= 1;
                Some(old)
            }
            _ => None,
        }
    }

    /// Number of occupied keys.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of materialized pages (diagnostics).
    pub fn pages_allocated(&self) -> usize {
        self.pages.iter().filter(|p| p.is_some()).count()
    }

    /// Iterate occupied `(key, value)` pairs in key order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, T)> + '_ {
        let shift = self.page_shift;
        self.pages.iter().enumerate().flat_map(move |(pi, page)| {
            page.as_ref().into_iter().flat_map(move |pg| {
                pg.slots.iter().enumerate().filter_map(move |(si, v)| {
                    if pg.is_occupied(si) {
                        Some((((pi as u64) << shift) | si as u64, *v))
                    } else {
                        None
                    }
                })
            })
        })
    }

    /// Iterate occupied keys in key order.
    pub fn keys(&self) -> impl Iterator<Item = u64> + '_ {
        self.iter().map(|(k, _)| k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a: PagedArena<u32> = PagedArena::new(0);
        assert!(a.is_empty());
        assert_eq!(a.get(5), None);
        assert_eq!(a.copied_or_default(5), 0);
        assert_eq!(a.insert(5, 7), None);
        assert_eq!(a.insert(5, 9), Some(7));
        assert_eq!(a.get(5), Some(&9));
        assert_eq!(a.copied_or_default(5), 9);
        assert_eq!(a.len(), 1);
        assert_eq!(a.remove(5), Some(9));
        assert_eq!(a.remove(5), None);
        assert!(a.is_empty());
    }

    #[test]
    fn default_valued_inserts_are_still_occupied() {
        // inserting the default value must be observable (HashMap parity)
        let mut a: PagedArena<u8> = PagedArena::new(0);
        a.insert(100, 0);
        assert!(a.contains(100));
        assert_eq!(a.len(), 1);
        assert!(!a.contains(101));
    }

    #[test]
    fn pages_materialize_lazily_and_group_locality_holds() {
        let mut a: PagedArena<u8> = PagedArena::with_page_shift(0, 6); // 64 slots/page
        a.insert(0, 1);
        a.insert(3, 1); // same page as key 0 (a 4-line group shares a page)
        assert_eq!(a.pages_allocated(), 1);
        a.insert(1 << 20, 2); // far key: exactly one more page
        assert_eq!(a.pages_allocated(), 2);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn iteration_yields_sorted_occupied_keys() {
        let mut a: PagedArena<u64> = PagedArena::with_page_shift(0, 6);
        for k in [300u64, 2, 65, 64] {
            a.insert(k, k * 10);
        }
        a.remove(65);
        let pairs: Vec<(u64, u64)> = a.iter().collect();
        assert_eq!(pairs, vec![(2, 20), (64, 640), (300, 3000)]);
        let keys: Vec<u64> = a.keys().collect();
        assert_eq!(keys, vec![2, 64, 300]);
    }

    /// Shadow-model test: the arena must behave exactly like a `HashMap`
    /// under randomized insert/remove/get sequences, including group-pack
    /// style bursts over four consecutive keys.
    #[test]
    fn shadow_model_matches_hashmap() {
        forall("arena vs hashmap", 64, |rng| {
            let mut arena: PagedArena<u32> = PagedArena::with_page_shift(0, 6);
            let mut shadow: HashMap<u64, u32> = HashMap::new();
            for _ in 0..400 {
                // keys span several pages; occasional far outliers
                let key = if rng.chance(0.05) {
                    rng.below(1 << 16)
                } else {
                    rng.below(512)
                };
                match rng.below(4) {
                    0 => {
                        let v = rng.next_u32();
                        assert_eq!(arena.insert(key, v), shadow.insert(key, v));
                    }
                    1 => {
                        assert_eq!(arena.remove(key), shadow.remove(&key));
                    }
                    2 => {
                        // group-pack burst: write all four lines of a group
                        let base = key & !3;
                        for i in 0..4 {
                            let v = rng.next_u32();
                            assert_eq!(
                                arena.insert(base + i, v),
                                shadow.insert(base + i, v)
                            );
                        }
                    }
                    _ => {
                        assert_eq!(arena.get(key), shadow.get(&key));
                        assert_eq!(arena.contains(key), shadow.contains_key(&key));
                        assert_eq!(
                            arena.copied_or_default(key),
                            shadow.get(&key).copied().unwrap_or(0)
                        );
                    }
                }
                assert_eq!(arena.len(), shadow.len());
            }
            // full-content equivalence at the end
            let mut from_shadow: Vec<(u64, u32)> =
                shadow.iter().map(|(k, v)| (*k, *v)).collect();
            from_shadow.sort();
            let from_arena: Vec<(u64, u32)> = arena.iter().collect();
            assert_eq!(from_arena, from_shadow);
        });
    }
}
