//! Runtime for the AOT-compiled compression-analysis model.
//!
//! This is the L3↔L2 bridge: python lowers `analyze_groups` once at build
//! time (`python -m compile.aot`) to HLO text; this module evaluates that
//! model from rust over batches of raw lines.
//!
//! **Offline substitution (DESIGN.md §Substitutions).**  The PJRT CPU
//! client (`xla` crate) is not available in this environment and the
//! crate carries zero external dependencies, so the engine executes the
//! model with the *native bit-exact port* of the L1 kernel
//! ([`crate::compress`]) — the same math the HLO text encodes, as proven
//! by the cross-language parity suite (`rust/tests/parity_hlo.rs` here,
//! `python/tests/test_kernel.py` on the python side).  When the HLO
//! artifact exists on disk it is loaded and sanity-checked (module name,
//! batch geometry) so a drifted artifact still fails loudly; when it does
//! not, the engine runs native-only and says so.

use crate::compress::hybrid;
use crate::cram::group::Csi;
use crate::mem::CacheLine;

/// Batch geometry baked into the artifact (must match
/// `python/compile/model.py::GROUPS`).
pub const GROUPS: usize = 1024;

/// Errors loading or validating the analysis artifact.
#[derive(Debug)]
pub struct RuntimeError {
    pub reason: String,
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "analysis runtime error: {}", self.reason)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err(reason: impl Into<String>) -> RuntimeError {
    RuntimeError { reason: reason.into() }
}

/// Per-group analysis result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupAnalysis {
    pub csi: Csi,
    /// Hybrid compressed size per line (64 = raw).
    pub sizes: [u32; 4],
}

/// Which backend the engine is executing on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// HLO artifact present + validated; evaluated by the native port
    /// (the PJRT client is unavailable offline).
    ArtifactValidated,
    /// No artifact on disk; native port only.
    NativeOnly,
}

/// The compression-analysis engine.
pub struct AnalysisEngine {
    backend: Backend,
}

impl AnalysisEngine {
    /// Default artifact path relative to the repo root.
    pub const DEFAULT_ARTIFACT: &'static str = "artifacts/compress_analysis.hlo.txt";

    /// Load the engine.  If the HLO artifact exists it is parsed for its
    /// module header and checked against the expected batch geometry; a
    /// present-but-wrong artifact is an error (silent drift is worse than
    /// a missing file).  A missing artifact degrades to native-only.
    pub fn load(path: &str) -> Result<Self> {
        if !std::path::Path::new(path).exists() {
            return Ok(Self { backend: Backend::NativeOnly });
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("read {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(err(format!("{path} is not HLO text (no HloModule header)")));
        }
        // the lowered input is u32[GROUPS,4,16]; its shape string must
        // appear in the entry computation
        let shape = format!("u32[{GROUPS},4,16]");
        if !text.contains(&shape) {
            return Err(err(format!(
                "{path} batch geometry mismatch: expected {shape} \
                 (rebuild with `python -m compile.aot`)"
            )));
        }
        Ok(Self { backend: Backend::ArtifactValidated })
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Analyze groups of four lines.  `groups.len()` may be anything (the
    /// artifact's [`GROUPS`] batch geometry constrains only the lowered
    /// model, not this evaluator).
    pub fn analyze(&self, groups: &[[CacheLine; 4]]) -> Result<Vec<GroupAnalysis>> {
        Ok(groups
            .iter()
            .map(|group| {
                let sizes: [u32; 4] =
                    core::array::from_fn(|i| hybrid::compressed_size(&group[i]));
                GroupAnalysis { csi: Csi::from_sizes(sizes), sizes }
            })
            .collect())
    }
}

// NOTE: integration tests live in rust/tests/parity_hlo.rs — they assert
// engine-vs-native parity and pin the same spec vectors as the python
// kernel tests.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_degrades_to_native() {
        let e = AnalysisEngine::load("/nonexistent/path.hlo.txt").unwrap();
        assert_eq!(e.backend(), Backend::NativeOnly);
    }

    #[test]
    fn bogus_artifact_rejected() {
        let p = std::env::temp_dir().join("cram_bogus_artifact.txt");
        std::fs::write(&p, "not an hlo module").unwrap();
        let r = AnalysisEngine::load(p.to_str().unwrap());
        assert!(r.is_err(), "non-HLO file must be rejected");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn analysis_matches_native_compressors() {
        let e = AnalysisEngine::load("/nonexistent.hlo").unwrap();
        let zero = CacheLine::zero();
        let sevens = CacheLine::from_words([7; 16]);
        let rep = CacheLine::from_words([0x4141_4141; 16]);
        let base = 0x1234_5678_9ABC_DE00u64;
        let b8d1 = CacheLine::from_qwords(core::array::from_fn(|i| base + i as u64));
        let a = e.analyze(&[[zero, sevens, rep, b8d1]]).unwrap();
        // the same spec pins as python/tests/test_kernel.py
        assert_eq!(a[0].sizes, [2, 9, 9, 17]);
        assert_eq!(a[0].csi, Csi::Quad);
    }
}
