//! PJRT runtime: load and execute the AOT-compiled compression-analysis
//! HLO (`artifacts/compress_analysis.hlo.txt`) from rust.
//!
//! This is the L3↔L2 bridge: python lowers `analyze_groups` once at build
//! time (`make artifacts`); this module compiles the HLO text on the PJRT
//! CPU client and executes it with batches of raw lines.  Python is never
//! on the request path.
//!
//! The artifact has a fixed batch geometry of [`GROUPS`] groups (4096
//! lines); [`AnalysisEngine::analyze`] pads/splits arbitrary batches.

use anyhow::{Context, Result};

use crate::cram::group::Csi;
use crate::mem::CacheLine;

/// Batch geometry baked into the artifact (must match
/// `python/compile/model.py::GROUPS`).
pub const GROUPS: usize = 1024;

/// Per-group analysis result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupAnalysis {
    pub csi: Csi,
    /// Hybrid compressed size per line (64 = raw).
    pub sizes: [u32; 4],
}

/// A compiled PJRT executable for the compression-analysis model.
pub struct AnalysisEngine {
    exe: xla::PjRtLoadedExecutable,
}

impl AnalysisEngine {
    /// Default artifact path relative to the repo root.
    pub const DEFAULT_ARTIFACT: &'static str = "artifacts/compress_analysis.hlo.txt";

    /// Load + compile the HLO text artifact on the PJRT CPU client.
    pub fn load(path: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text at {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile HLO")?;
        Ok(Self { exe })
    }

    /// Analyze groups of four lines.  `groups.len()` may be anything; the
    /// engine pads to the artifact's batch size internally.
    pub fn analyze(&self, groups: &[[CacheLine; 4]]) -> Result<Vec<GroupAnalysis>> {
        let mut out = Vec::with_capacity(groups.len());
        for chunk in groups.chunks(GROUPS) {
            out.extend(self.analyze_batch(chunk)?);
        }
        Ok(out)
    }

    fn analyze_batch(&self, groups: &[[CacheLine; 4]]) -> Result<Vec<GroupAnalysis>> {
        assert!(groups.len() <= GROUPS);
        // Build the padded u32[GROUPS, 4, 16] input.
        let mut flat = vec![0u32; GROUPS * 4 * 16];
        for (g, group) in groups.iter().enumerate() {
            for (s, line) in group.iter().enumerate() {
                let base = (g * 4 + s) * 16;
                flat[base..base + 16].copy_from_slice(line.words());
            }
        }
        let input = xla::Literal::vec1(&flat)
            .reshape(&[GROUPS as i64, 4, 16])
            .context("reshape input literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[input])
            .context("execute analysis")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True: (csi s32[G], sizes s32[G,4])
        let (csi_lit, sizes_lit) = result.to_tuple2().context("unpack 2-tuple")?;
        let csi: Vec<i32> = csi_lit.to_vec().context("csi to_vec")?;
        let sizes: Vec<i32> = sizes_lit.to_vec().context("sizes to_vec")?;
        Ok((0..groups.len())
            .map(|g| GroupAnalysis {
                csi: Csi::from_u8(csi[g] as u8).expect("csi in 0..=4"),
                sizes: core::array::from_fn(|i| sizes[g * 4 + i] as u32),
            })
            .collect())
    }
}

// NOTE: integration tests live in rust/tests/parity_hlo.rs — they need the
// artifact built (`make artifacts`) and assert native-vs-HLO parity.
