//! Simulation statistics: the bandwidth breakdown of Figs. 8/15 and the
//! weighted-speedup metric of §III-B.

use crate::util::geomean;

/// Memory-traffic breakdown by cause, in 64-byte accesses.
/// `demand_*` exists in an uncompressed baseline too; everything else is
/// compression overhead (or metadata overhead for explicit designs).
#[derive(Clone, Copy, Debug, Default)]
pub struct Bandwidth {
    /// Demand line reads (first access per LLC read miss).
    pub demand_reads: u64,
    /// Dirty-data writes (packed or raw — would exist in the baseline).
    pub demand_writes: u64,
    /// Writes of purely-clean packed data (compression overhead).
    pub clean_writes: u64,
    /// Invalid-line-marker writes (compression overhead).
    pub invalidates: u64,
    /// Re-issued reads after LLP mispredictions (compression overhead).
    pub second_reads: u64,
    /// Metadata-region reads (explicit-metadata overhead).
    pub meta_reads: u64,
    /// Metadata-region write-backs (explicit-metadata overhead).
    pub meta_writes: u64,
    /// Extra prefetch reads (next-line-prefetch baseline only).
    pub prefetch_reads: u64,
}

impl Bandwidth {
    pub fn total(&self) -> u64 {
        self.demand_reads
            + self.demand_writes
            + self.clean_writes
            + self.invalidates
            + self.second_reads
            + self.meta_reads
            + self.meta_writes
            + self.prefetch_reads
    }

    /// Overhead accesses (everything a plain uncompressed memory would not
    /// have issued).
    pub fn overhead(&self) -> u64 {
        self.clean_writes
            + self.invalidates
            + self.second_reads
            + self.meta_reads
            + self.meta_writes
            + self.prefetch_reads
    }
}

/// Result of simulating one workload under one memory-system design.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub workload: String,
    pub design: String,
    /// Wall time in CPU cycles (3.2 GHz).
    pub cycles: u64,
    pub insts_per_core: u64,
    pub cores: usize,
    /// Per-core IPC.
    pub ipc: Vec<f64>,
    pub llc_hits: u64,
    pub llc_misses: u64,
    pub bw: Bandwidth,
    /// LLP accuracy (1.0 when the design has no predictor).
    pub llp_accuracy: f64,
    /// Metadata-cache hit rate (None for implicit designs).
    pub meta_hit_rate: Option<f64>,
    /// Lines installed for free by compression, and how many were used.
    pub prefetch_installed: u64,
    pub prefetch_used: u64,
    /// DRAM row-buffer hit rate.
    pub row_hit_rate: f64,
    /// Fraction of groups written compressed (Dynamic-CRAM diagnostics).
    pub compression_enabled_frac: f64,
    /// Dynamic-CRAM sampled-set cost / benefit event totals.
    pub dyn_costs: u64,
    pub dyn_benefits: u64,
    /// Final per-core Dynamic-CRAM counter values (empty for non-dynamic).
    pub dyn_counters: Vec<i32>,
}

impl SimResult {
    /// Measured L3 misses per kilo-instruction (aggregate).
    pub fn mpki(&self) -> f64 {
        let insts = self.insts_per_core as f64 * self.cores as f64;
        self.llc_misses as f64 / (insts / 1000.0)
    }

    /// Aggregate IPC (sum over cores).
    pub fn total_ipc(&self) -> f64 {
        self.ipc.iter().sum()
    }

    /// Weighted speedup vs a baseline run of the same workload
    /// (rate-mode: per-core IPC ratios, averaged).
    pub fn weighted_speedup(&self, base: &SimResult) -> f64 {
        assert_eq!(self.cores, base.cores);
        let ws: f64 = self
            .ipc
            .iter()
            .zip(&base.ipc)
            .map(|(a, b)| a / b)
            .sum();
        ws / self.cores as f64
    }
}

/// Geometric-mean speedup across workloads.
pub fn geomean_speedup(speedups: &[f64]) -> f64 {
    geomean(speedups)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(ipc: Vec<f64>) -> SimResult {
        SimResult {
            workload: "w".into(),
            design: "d".into(),
            cycles: 1000,
            insts_per_core: 1000,
            cores: ipc.len(),
            ipc,
            llc_hits: 0,
            llc_misses: 500,
            bw: Bandwidth::default(),
            llp_accuracy: 1.0,
            meta_hit_rate: None,
            prefetch_installed: 0,
            prefetch_used: 0,
            row_hit_rate: 0.0,
            compression_enabled_frac: 1.0,
            dyn_costs: 0,
            dyn_benefits: 0,
            dyn_counters: vec![],
        }
    }

    #[test]
    fn weighted_speedup_identity() {
        let a = result(vec![1.0, 2.0]);
        assert!((a.weighted_speedup(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_speedup_mixed() {
        let base = result(vec![1.0, 1.0]);
        let fast = result(vec![2.0, 1.0]);
        assert!((fast.weighted_speedup(&base) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn mpki_math() {
        let r = result(vec![1.0; 8]); // 8 cores * 1000 insts, 500 misses
        assert!((r.mpki() - 62.5).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_totals() {
        let bw = Bandwidth {
            demand_reads: 10,
            demand_writes: 5,
            clean_writes: 2,
            invalidates: 1,
            second_reads: 1,
            meta_reads: 3,
            meta_writes: 1,
            prefetch_reads: 0,
        };
        assert_eq!(bw.total(), 23);
        assert_eq!(bw.overhead(), 8);
    }
}
