//! Simulation statistics: the bandwidth breakdown of Figs. 8/15, the
//! weighted-speedup metric of §III-B, the per-tier traffic breakdown of
//! the tiered-memory subsystem (Figure T1), and the read-latency
//! histogram behind the tail-latency exhibit (Figure Q1).

use crate::tier::link::LinkStats;
use crate::util::geomean;

/// DRAM bus cycle length in nanoseconds (800 MHz bus).
pub const NS_PER_BUS_CYCLE: f64 = 1.25;

/// Histogram buckets: values 0..7 exact, then four sub-buckets per
/// power of two up to the overflow bucket (~2^16 bus cycles).
const LAT_BUCKETS: usize = 64;

/// Fixed-size log-scaled latency histogram (bus cycles).  Records every
/// demand read's CPU-visible memory latency — queueing, drains, bank
/// conflicts, metadata serialization, second probes, link crossings —
/// and reports mean/p50/p95/p99.  `Copy`, so warmup snapshots subtract
/// the same way the scalar counters do.
#[derive(Clone, Copy, Debug)]
pub struct LatencyHist {
    buckets: [u64; LAT_BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self { buckets: [0; LAT_BUCKETS], count: 0, sum: 0 }
    }
}

impl LatencyHist {
    fn bucket_of(v: u64) -> usize {
        if v < 8 {
            return v as usize;
        }
        let l = 63 - u64::from(v.leading_zeros()); // floor(log2 v) >= 3
        let sub = (v >> (l - 2)) & 3;
        ((8 + (l - 3) * 4 + sub) as usize).min(LAT_BUCKETS - 1)
    }

    /// Representative latency (bucket midpoint) for percentile queries.
    fn bucket_mid(i: usize) -> f64 {
        if i < 8 {
            return i as f64;
        }
        let l = 3 + (i - 8) / 4;
        let sub = ((i - 8) % 4) as u64;
        let quarter = 1u64 << (l - 2);
        ((1u64 << l) + sub * quarter) as f64 + quarter as f64 / 2.0
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Samples recorded.  For any simulated design this equals the
    /// demand reads issued — the Figure Q1 accounting invariant.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Raw bucket counts, exposed for the persistent results codec
    /// (`coordinator::persist`).  The log-bucket layout is part of the
    /// cache schema: a layout change must bump the cache schema version.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Total of all recorded values — the codec counterpart of
    /// [`LatencyHist::mean`].
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Rebuild a histogram from its serialized parts.  `None` when the
    /// bucket count does not match this build's layout (a stale cache
    /// written by a different schema).
    pub fn from_parts(buckets: &[u64], count: u64, sum: u64) -> Option<Self> {
        if buckets.len() != LAT_BUCKETS {
            return None;
        }
        let mut h = Self::default();
        h.buckets.copy_from_slice(buckets);
        h.count = count;
        h.sum = sum;
        Some(h)
    }

    /// Exact arithmetic mean (the sum is tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Latency below which fraction `p` of reads completed, at bucket
    /// resolution (`p` in [0, 1]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(LAT_BUCKETS - 1)
    }

    /// Per-bucket difference vs a warmup snapshot.
    pub fn since(&self, warm: &LatencyHist) -> LatencyHist {
        let mut out = *self;
        for (o, w) in out.buckets.iter_mut().zip(warm.buckets.iter()) {
            *o -= *w;
        }
        out.count -= warm.count;
        out.sum -= warm.sum;
        out
    }
}

/// Memory-traffic breakdown by cause, in 64-byte accesses.
/// `demand_*` exists in an uncompressed baseline too; everything else is
/// compression overhead (or metadata overhead for explicit designs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Bandwidth {
    /// Demand line reads (first access per LLC read miss).
    pub demand_reads: u64,
    /// Dirty-data writes (packed or raw — would exist in the baseline).
    pub demand_writes: u64,
    /// Writes of purely-clean packed data (compression overhead).
    pub clean_writes: u64,
    /// Invalid-line-marker writes (compression overhead).
    pub invalidates: u64,
    /// Re-issued reads after LLP mispredictions (compression overhead).
    pub second_reads: u64,
    /// Metadata-region reads (explicit-metadata overhead).
    pub meta_reads: u64,
    /// Metadata-region write-backs (explicit-metadata overhead).
    pub meta_writes: u64,
    /// Extra prefetch reads (next-line-prefetch baseline only).
    pub prefetch_reads: u64,
    /// Accesses issued by tiered-memory page migration (promotion reads +
    /// fills, demotion reads + writes — tiered designs only).
    pub migration: u64,
}

impl Bandwidth {
    pub fn total(&self) -> u64 {
        self.demand_reads
            + self.demand_writes
            + self.clean_writes
            + self.invalidates
            + self.second_reads
            + self.meta_reads
            + self.meta_writes
            + self.prefetch_reads
            + self.migration
    }

    /// Overhead accesses (everything a plain uncompressed memory would not
    /// have issued).
    pub fn overhead(&self) -> u64 {
        self.clean_writes
            + self.invalidates
            + self.second_reads
            + self.meta_reads
            + self.meta_writes
            + self.prefetch_reads
            + self.migration
    }

    /// Field-wise difference vs an earlier snapshot (warmup subtraction
    /// and the per-call deltas the tenant tracker charges).
    pub fn since(&self, warm: &Bandwidth) -> Bandwidth {
        Bandwidth {
            demand_reads: self.demand_reads - warm.demand_reads,
            demand_writes: self.demand_writes - warm.demand_writes,
            clean_writes: self.clean_writes - warm.clean_writes,
            invalidates: self.invalidates - warm.invalidates,
            second_reads: self.second_reads - warm.second_reads,
            meta_reads: self.meta_reads - warm.meta_reads,
            meta_writes: self.meta_writes - warm.meta_writes,
            prefetch_reads: self.prefetch_reads - warm.prefetch_reads,
            migration: self.migration - warm.migration,
        }
    }

    /// Field-wise accumulation of a delta produced by [`Bandwidth::since`].
    pub fn accumulate(&mut self, d: &Bandwidth) {
        self.demand_reads += d.demand_reads;
        self.demand_writes += d.demand_writes;
        self.clean_writes += d.clean_writes;
        self.invalidates += d.invalidates;
        self.second_reads += d.second_reads;
        self.meta_reads += d.meta_reads;
        self.meta_writes += d.meta_writes;
        self.prefetch_reads += d.prefetch_reads;
        self.migration += d.migration;
    }
}

/// Bus beats of *overhead* traffic a traffic source injects: every
/// data-sized overhead access costs a full `t_burst`-beat transfer,
/// while invalidates are the 1-beat folded markers of the CRAM paper.
pub fn overhead_beats(bw: &Bandwidth, t_burst: u64) -> u64 {
    let data_sized = bw.clean_writes
        + bw.second_reads
        + bw.meta_reads
        + bw.meta_writes
        + bw.prefetch_reads
        + bw.migration;
    data_sized * t_burst + bw.invalidates
}

/// Compression-interference attribution: how many bus beats of *other
/// tenants'* compression/metadata overhead each tenant absorbs.
///
/// Every tenant A injects [`overhead_beats`] of non-demand traffic
/// (packed clean writes, ganged-eviction invalidates, second reads,
/// metadata, migration).  Those beats occupy shared channel time, and
/// the delay lands on whoever else is queueing — so A's beats are
/// distributed over the *other* tenants proportionally to their share
/// of demand beats (a tenant issuing twice the demand traffic collides
/// with twice as much of A's overhead).  The per-tenant charges sum to
/// the total overhead beats injected (nothing is dropped), and a tenant
/// never absorbs its own overhead.
pub fn interference_beats(per_tenant: &[Bandwidth], t_burst: u64) -> Vec<f64> {
    let n = per_tenant.len();
    let demand: Vec<f64> = per_tenant
        .iter()
        .map(|b| ((b.demand_reads + b.demand_writes) * t_burst) as f64)
        .collect();
    let mut absorbed = vec![0.0; n];
    for a in 0..n {
        let injected = overhead_beats(&per_tenant[a], t_burst) as f64;
        let others: f64 = (0..n).filter(|&c| c != a).map(|c| demand[c]).sum();
        if others <= 0.0 {
            continue;
        }
        for (b, acc) in absorbed.iter_mut().enumerate() {
            if b != a {
                *acc += injected * demand[b] / others;
            }
        }
    }
    absorbed
}

/// Jain's fairness index over per-tenant progress values:
/// `(Σx)² / (n·Σx²)` — 1.0 when all tenants progress equally, → 1/n
/// when one tenant starves the rest.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Per-tenant slice of a multi-tenant run.  The `bw`/`read_lat` fields
/// partition the run's totals exactly: summed over tenants they
/// reproduce [`SimResult::bw`] field-for-field and
/// [`SimResult::read_lat`]`.count()` — the conservation invariant the
/// tenant tests pin.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: String,
    /// First core index owned by this tenant (cores are contiguous).
    pub first_core: usize,
    pub cores: usize,
    /// Per-core IPC for this tenant's cores.
    pub ipc: Vec<f64>,
    pub bw: Bandwidth,
    pub read_lat: LatencyHist,
    /// Mean over the tenant's cores of `IPC_alone / IPC_shared` — ≥ 1
    /// under contention.  `None` when the solo reference run was skipped.
    pub slowdown: Option<f64>,
    /// Bus beats of other tenants' compression overhead this tenant
    /// absorbed ([`interference_beats`]).
    pub interference_beats: f64,
    /// This tenant holds the QoS read-slot reservation.
    pub protected: bool,
}

impl TenantStats {
    /// Aggregate IPC over the tenant's cores.
    pub fn total_ipc(&self) -> f64 {
        self.ipc.iter().sum()
    }
}

/// Traffic reaching one tier of a tiered memory, in 64-byte accesses.
/// The categories mirror [`Bandwidth`]; for any tiered run,
/// `near.total() + far.total() == bw.total()` — every access the
/// controller charges is attributed to exactly one tier.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierTraffic {
    pub demand_reads: u64,
    pub demand_writes: u64,
    /// Clean packed writes on the compressed far tier.
    pub clean_writes: u64,
    /// Stale-slot invalidates on the compressed far tier.
    pub invalidates: u64,
    /// Metadata-region accesses on the far device (the `tiered-explicit`
    /// composition: meta reads + meta write-backs).
    pub meta_accesses: u64,
    /// Extra next-line prefetch reads (the tiered prefetch baseline).
    pub prefetch_reads: u64,
    /// Accesses caused by page migration (both directions count the
    /// accesses they issue on *this* tier).
    pub migr_accesses: u64,
    /// Verify re-reads cured by the reliability machinery (detected
    /// media / marker errors under fault injection; zero otherwise).
    pub second_reads: u64,
}

impl TierTraffic {
    pub fn total(&self) -> u64 {
        self.demand_reads
            + self.demand_writes
            + self.clean_writes
            + self.invalidates
            + self.meta_accesses
            + self.prefetch_reads
            + self.migr_accesses
            + self.second_reads
    }

    fn since(&self, warm: &TierTraffic) -> TierTraffic {
        TierTraffic {
            demand_reads: self.demand_reads - warm.demand_reads,
            demand_writes: self.demand_writes - warm.demand_writes,
            clean_writes: self.clean_writes - warm.clean_writes,
            invalidates: self.invalidates - warm.invalidates,
            meta_accesses: self.meta_accesses - warm.meta_accesses,
            prefetch_reads: self.prefetch_reads - warm.prefetch_reads,
            migr_accesses: self.migr_accesses - warm.migr_accesses,
            second_reads: self.second_reads - warm.second_reads,
        }
    }
}

/// Link-bytes-vs-storage-bytes breakdown, split by traffic class —
/// the [`crate::controller::LinkCodec`] exhibit (Figure L1).
///
/// For every payload crossing the link, `raw` counts the bytes the
/// transfer represents at storage granularity (what [`LinkCodec::Raw`]
/// serializes) and `wire` the bytes actually serialized after the
/// TX-side size-only pass.  Under `LinkCodec::Raw` the two are equal in
/// every class; under `Compressed`, `wire ≤ raw` class by class and
/// `flits_saved` accumulates the flit cycles the codec removed.
/// The five classes partition the totals exactly:
/// `demand + meta + writeback + prefetch + migration == raw/wire bytes`.
///
/// [`LinkCodec::Raw`]: crate::controller::LinkCodec::Raw
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkTraffic {
    /// Demand far reads: the command flit + the returned line/block.
    pub demand_raw_bytes: u64,
    pub demand_wire_bytes: u64,
    /// Explicit-metadata crossings (the `tiered-explicit` compositions).
    pub meta_raw_bytes: u64,
    pub meta_wire_bytes: u64,
    /// Writeback bursts host→device (dirty data, packed writes,
    /// invalidate markers, victim writebacks).
    pub writeback_raw_bytes: u64,
    pub writeback_wire_bytes: u64,
    /// Next-line prefetch reads on the far tier.
    pub prefetch_raw_bytes: u64,
    pub prefetch_wire_bytes: u64,
    /// Page-migration transfers (promotion and demotion line moves).
    pub migration_raw_bytes: u64,
    pub migration_wire_bytes: u64,
    /// Flit cycles the codec removed vs serializing every payload raw.
    pub flits_saved: u64,
    /// Transfers that failed per-flit CRC at least once and were replayed
    /// (fault injection only; always ≤ total flits sent).
    pub retried_flits: u64,
    /// Extra serialization + backoff cycles the replays cost.
    pub retry_beats: u64,
}

impl LinkTraffic {
    /// Total storage-sized bytes offered to the link (sum of the class
    /// splits — the conservation invariant the link tests pin).
    pub fn raw_bytes(&self) -> u64 {
        self.demand_raw_bytes
            + self.meta_raw_bytes
            + self.writeback_raw_bytes
            + self.prefetch_raw_bytes
            + self.migration_raw_bytes
    }

    /// Total bytes actually serialized over the wire.
    pub fn wire_bytes(&self) -> u64 {
        self.demand_wire_bytes
            + self.meta_wire_bytes
            + self.writeback_wire_bytes
            + self.prefetch_wire_bytes
            + self.migration_wire_bytes
    }

    /// Field-wise difference (measurement-phase accounting).
    pub fn since(&self, warm: &LinkTraffic) -> LinkTraffic {
        LinkTraffic {
            demand_raw_bytes: self.demand_raw_bytes - warm.demand_raw_bytes,
            demand_wire_bytes: self.demand_wire_bytes - warm.demand_wire_bytes,
            meta_raw_bytes: self.meta_raw_bytes - warm.meta_raw_bytes,
            meta_wire_bytes: self.meta_wire_bytes - warm.meta_wire_bytes,
            writeback_raw_bytes: self.writeback_raw_bytes - warm.writeback_raw_bytes,
            writeback_wire_bytes: self.writeback_wire_bytes - warm.writeback_wire_bytes,
            prefetch_raw_bytes: self.prefetch_raw_bytes - warm.prefetch_raw_bytes,
            prefetch_wire_bytes: self.prefetch_wire_bytes - warm.prefetch_wire_bytes,
            migration_raw_bytes: self.migration_raw_bytes - warm.migration_raw_bytes,
            migration_wire_bytes: self.migration_wire_bytes - warm.migration_wire_bytes,
            flits_saved: self.flits_saved - warm.flits_saved,
            retried_flits: self.retried_flits - warm.retried_flits,
            retry_beats: self.retry_beats - warm.retry_beats,
        }
    }
}

/// Reliability telemetry for a run: what the fault injectors did, what
/// the detection machinery caught, and how the error-storm watchdog
/// reacted.  All-zero (the `Default`) whenever injection is off — the
/// bit-identity acceptance test pins exactly that.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReliabilityStats {
    /// Link transfers CRC-rejected at least once and replayed.
    pub flits_retried: u64,
    /// Extra link cycles (re-serialization + bounded backoff) the
    /// replays cost.
    pub retry_beats: u64,
    /// Far-media reads that needed a media-level retry.
    pub media_errors: u64,
    /// Marker-tail interpretations struck by injected corruption.
    pub marker_errors: u64,
    /// Corrupted markers *detected* (cross-checked against the layout
    /// authority and cured with a verified re-read).
    pub marker_detected: u64,
    /// Corrupted markers that would have been consumed as wrong data
    /// without being flagged.  The no-silent-corruption acceptance
    /// criterion asserts this stays zero.
    pub silent_misreads: u64,
    /// Key regenerations triggered by the marker-error signal (the
    /// paper's re-key cure, wired to detected corruption instead of
    /// only LIT overflow).
    pub rekeys: u64,
    /// Watchdog degradation steps taken (compressed→raw link codec,
    /// then compression off).
    pub watchdog_degrades: u64,
    /// Watchdog re-arms after sustained quiet epochs.
    pub watchdog_rearms: u64,
    /// Epochs spent at a degraded level (> 0).
    pub degraded_epochs: u64,
}

impl ReliabilityStats {
    /// Field-wise difference vs a warmup snapshot.
    pub fn since(&self, warm: &ReliabilityStats) -> ReliabilityStats {
        ReliabilityStats {
            flits_retried: self.flits_retried - warm.flits_retried,
            retry_beats: self.retry_beats - warm.retry_beats,
            media_errors: self.media_errors - warm.media_errors,
            marker_errors: self.marker_errors - warm.marker_errors,
            marker_detected: self.marker_detected - warm.marker_detected,
            silent_misreads: self.silent_misreads - warm.silent_misreads,
            rekeys: self.rekeys - warm.rekeys,
            watchdog_degrades: self.watchdog_degrades - warm.watchdog_degrades,
            watchdog_rearms: self.watchdog_rearms - warm.watchdog_rearms,
            degraded_epochs: self.degraded_epochs - warm.degraded_epochs,
        }
    }

    /// Field-wise accumulation (folding executor-local counters into the
    /// run total).
    pub fn accumulate(&mut self, d: &ReliabilityStats) {
        self.flits_retried += d.flits_retried;
        self.retry_beats += d.retry_beats;
        self.media_errors += d.media_errors;
        self.marker_errors += d.marker_errors;
        self.marker_detected += d.marker_detected;
        self.silent_misreads += d.silent_misreads;
        self.rekeys += d.rekeys;
        self.watchdog_degrades += d.watchdog_degrades;
        self.watchdog_rearms += d.watchdog_rearms;
        self.degraded_epochs += d.degraded_epochs;
    }

    /// No reliability event of any kind — the disabled-injection state.
    pub fn is_zero(&self) -> bool {
        *self == ReliabilityStats::default()
    }

    /// Fraction of injected marker errors that were detected (None when
    /// no marker error ever struck).
    pub fn detection_coverage(&self) -> Option<f64> {
        if self.marker_errors == 0 {
            None
        } else {
            Some(self.marker_detected as f64 / self.marker_errors as f64)
        }
    }
}

/// Full tiered-memory breakdown: per-tier traffic, migration policy
/// activity, link utilization, and far-tier compression diagnostics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    pub near: TierTraffic,
    pub far: TierTraffic,
    /// Hot pages promoted far→near / cold pages demoted near→far.
    pub promotions: u64,
    pub demotions: u64,
    /// Lines moved by migrations (both directions).
    pub migrated_lines: u64,
    pub link: LinkStats,
    /// Link-bytes-vs-storage-bytes breakdown per traffic class (the
    /// [`crate::controller::LinkCodec`] exhibit).
    pub link_traffic: LinkTraffic,
    /// Lines installed for free from packed far blocks.
    pub far_prefetch_installs: u64,
    /// Far groups written / written packed (compressed far only).
    pub far_groups_written: u64,
    pub far_groups_packed: u64,
}

impl TierStats {
    /// Accesses across both tiers; equals [`Bandwidth::total`] for the
    /// same run (the acceptance invariant of the tier subsystem).
    pub fn total_accesses(&self) -> u64 {
        self.near.total() + self.far.total()
    }

    /// Fraction of all accesses served by the far tier.
    pub fn far_frac(&self) -> f64 {
        let t = self.total_accesses();
        if t == 0 {
            0.0
        } else {
            self.far.total() as f64 / t as f64
        }
    }

    /// Field-wise difference vs a warmup snapshot.
    pub fn since(&self, warm: &TierStats) -> TierStats {
        TierStats {
            near: self.near.since(&warm.near),
            far: self.far.since(&warm.far),
            promotions: self.promotions - warm.promotions,
            demotions: self.demotions - warm.demotions,
            migrated_lines: self.migrated_lines - warm.migrated_lines,
            link: self.link.since(&warm.link),
            link_traffic: self.link_traffic.since(&warm.link_traffic),
            far_prefetch_installs: self.far_prefetch_installs
                - warm.far_prefetch_installs,
            far_groups_written: self.far_groups_written - warm.far_groups_written,
            far_groups_packed: self.far_groups_packed - warm.far_groups_packed,
        }
    }
}

/// Effective-capacity ledger of a page-granular (LCP) layout: how many
/// physical lines the touched pages actually occupy vs their logical
/// footprint.  CRAM-family designs trade capacity for bandwidth (a
/// packed group still owns its four physical slots), so only LCP runs
/// carry this — the first design in the repo where main memory *grows*.
///
/// The line counts are an end-of-run state snapshot (capacity is a
/// state, not a flow — nothing to warmup-subtract); `recompactions` is
/// a run-total event counter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CapacityStats {
    /// Pages with a materialized descriptor.
    pub pages: u64,
    /// Logical lines those pages present to the system (pages × 64).
    pub logical_lines: u64,
    /// Physical lines they occupy (data regions + exception regions).
    pub physical_lines: u64,
    /// Lines living in exception regions (stored raw, rank-indexed).
    pub exception_lines: u64,
    /// Pages re-encoded at a larger target after exception overflow.
    pub recompactions: u64,
}

impl CapacityStats {
    /// Capacity expansion factor: logical / physical (1.0 = no gain,
    /// also reported for an empty ledger).
    pub fn expansion(&self) -> f64 {
        if self.physical_lines == 0 {
            1.0
        } else {
            self.logical_lines as f64 / self.physical_lines as f64
        }
    }
}

/// Result of simulating one workload under one memory-system design.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub workload: String,
    pub design: String,
    /// Wall time in CPU cycles (3.2 GHz).
    pub cycles: u64,
    pub insts_per_core: u64,
    pub cores: usize,
    /// Per-core IPC.
    pub ipc: Vec<f64>,
    pub llc_hits: u64,
    pub llc_misses: u64,
    pub bw: Bandwidth,
    /// Compressed-LLC occupancy / pressure counters, warmup-subtracted
    /// (None when the run used the plain uncompressed LLC).
    pub llc_stats: Option<crate::cache::CacheStats>,
    /// LLP accuracy (None when the design never consulted the LCT — a
    /// run with zero needed predictions has no accuracy, not 100%).
    pub llp_accuracy: Option<f64>,
    /// Metadata-cache hit rate (None for implicit designs).
    pub meta_hit_rate: Option<f64>,
    /// Lines installed for free by compression, and how many were used.
    pub prefetch_installed: u64,
    pub prefetch_used: u64,
    /// DRAM row-buffer hit rate.
    pub row_hit_rate: f64,
    /// CPU-visible demand-read latency histogram (bus cycles): one
    /// sample per LLC read miss, including queueing, forced write
    /// drains, metadata serialization, second probes, and link
    /// crossings.  `count()` equals `bw.demand_reads`.
    pub read_lat: LatencyHist,
    /// Fraction of groups written compressed (Dynamic-CRAM diagnostics).
    pub compression_enabled_frac: f64,
    /// Dynamic-CRAM sampled-set cost / benefit event totals.
    pub dyn_costs: u64,
    pub dyn_benefits: u64,
    /// Final per-core Dynamic-CRAM counter values (empty for non-dynamic).
    pub dyn_counters: Vec<i32>,
    /// Tiered-memory breakdown (None for flat designs).
    pub tier: Option<TierStats>,
    /// Per-tenant breakdown (empty for single-tenant runs).  Tenant
    /// `bw` sums and `read_lat` counts partition the totals above.
    pub tenants: Vec<TenantStats>,
    /// Reliability telemetry; all-zero whenever fault injection is off.
    pub rel: ReliabilityStats,
    /// Effective-capacity ledger (None for every non-LCP design — the
    /// group family never grows capacity, and absent ≠ 1.0×).
    pub capacity: Option<CapacityStats>,
}

impl SimResult {
    /// Measured L3 misses per kilo-instruction (aggregate).
    pub fn mpki(&self) -> f64 {
        let insts = self.insts_per_core as f64 * self.cores as f64;
        self.llc_misses as f64 / (insts / 1000.0)
    }

    /// Aggregate IPC (sum over cores).
    pub fn total_ipc(&self) -> f64 {
        self.ipc.iter().sum()
    }

    /// Weighted speedup vs a baseline run of the same workload
    /// (rate-mode: per-core IPC ratios, averaged).
    pub fn weighted_speedup(&self, base: &SimResult) -> f64 {
        assert_eq!(self.cores, base.cores);
        let ws: f64 = self
            .ipc
            .iter()
            .zip(&base.ipc)
            .map(|(a, b)| a / b)
            .sum();
        ws / self.cores as f64
    }
}

/// Geometric-mean speedup across workloads.
pub fn geomean_speedup(speedups: &[f64]) -> f64 {
    geomean(speedups)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(ipc: Vec<f64>) -> SimResult {
        SimResult {
            workload: "w".into(),
            design: "d".into(),
            cycles: 1000,
            insts_per_core: 1000,
            cores: ipc.len(),
            ipc,
            llc_hits: 0,
            llc_misses: 500,
            bw: Bandwidth::default(),
            llc_stats: None,
            llp_accuracy: None,
            meta_hit_rate: None,
            prefetch_installed: 0,
            prefetch_used: 0,
            row_hit_rate: 0.0,
            read_lat: LatencyHist::default(),
            compression_enabled_frac: 1.0,
            dyn_costs: 0,
            dyn_benefits: 0,
            dyn_counters: vec![],
            tier: None,
            tenants: vec![],
            rel: ReliabilityStats::default(),
            capacity: None,
        }
    }

    #[test]
    fn capacity_expansion_factor() {
        let c = CapacityStats {
            pages: 2,
            logical_lines: 128,
            physical_lines: 64,
            exception_lines: 3,
            recompactions: 1,
        };
        assert!((c.expansion() - 2.0).abs() < 1e-12);
        assert_eq!(CapacityStats::default().expansion(), 1.0, "empty ledger = no gain");
    }

    #[test]
    fn weighted_speedup_identity() {
        let a = result(vec![1.0, 2.0]);
        assert!((a.weighted_speedup(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_speedup_mixed() {
        let base = result(vec![1.0, 1.0]);
        let fast = result(vec![2.0, 1.0]);
        assert!((fast.weighted_speedup(&base) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn mpki_math() {
        let r = result(vec![1.0; 8]); // 8 cores * 1000 insts, 500 misses
        assert!((r.mpki() - 62.5).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_totals() {
        let bw = Bandwidth {
            demand_reads: 10,
            demand_writes: 5,
            clean_writes: 2,
            invalidates: 1,
            second_reads: 1,
            meta_reads: 3,
            meta_writes: 1,
            prefetch_reads: 0,
            migration: 0,
        };
        assert_eq!(bw.total(), 23);
        assert_eq!(bw.overhead(), 8);
    }

    #[test]
    fn latency_hist_mean_and_count_exact() {
        let mut h = LatencyHist::default();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn latency_hist_percentiles_ordered_and_bracketed() {
        let mut h = LatencyHist::default();
        // 95 fast reads at 13 cycles, 5 slow tail reads at 1000
        for _ in 0..95 {
            h.record(13);
        }
        for _ in 0..5 {
            h.record(1000);
        }
        let (p50, p95, p99) = (h.percentile(0.5), h.percentile(0.95), h.percentile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "p50 {p50} p95 {p95} p99 {p99}");
        // bucket resolution: within a factor of ~1.25 of the true value
        assert!(p50 >= 10.0 && p50 <= 18.0, "p50 {p50}");
        assert!(p99 >= 750.0 && p99 <= 1300.0, "p99 {p99}");
    }

    #[test]
    fn latency_hist_since_subtracts_per_bucket() {
        let mut warm = LatencyHist::default();
        warm.record(5);
        warm.record(100);
        let mut full = warm;
        full.record(100);
        full.record(7);
        let d = full.since(&warm);
        assert_eq!(d.count(), 2);
        assert!((d.mean() - 53.5).abs() < 1e-12);
        assert!(d.percentile(1.0) > 64.0, "the 100-cycle sample survived");
    }

    #[test]
    fn latency_hist_bucket_roundtrip_monotone() {
        // bucket index must be monotone in the value, and the midpoint
        // must land inside [value/1.3, value*1.3] for in-range values
        let mut prev = 0usize;
        for v in 1..5000u64 {
            let b = LatencyHist::bucket_of(v);
            assert!(b >= prev, "bucket order at {v}");
            prev = b;
            let mid = LatencyHist::bucket_mid(b);
            assert!(
                mid >= v as f64 / 1.3 && mid <= v as f64 * 1.3,
                "v {v} bucket {b} mid {mid}"
            );
        }
    }

    #[test]
    fn bandwidth_since_and_accumulate_roundtrip() {
        let warm = Bandwidth { demand_reads: 3, clean_writes: 1, ..Default::default() };
        let full = Bandwidth {
            demand_reads: 10,
            demand_writes: 4,
            clean_writes: 2,
            invalidates: 5,
            ..Default::default()
        };
        let d = full.since(&warm);
        assert_eq!(d.demand_reads, 7);
        assert_eq!(d.clean_writes, 1);
        assert_eq!(d.invalidates, 5);
        let mut acc = warm;
        acc.accumulate(&d);
        assert_eq!(acc.total(), full.total());
        assert_eq!(acc.demand_writes, full.demand_writes);
    }

    #[test]
    fn jain_index_bounds() {
        assert!((jain_index(&[]) - 1.0).abs() < 1e-12);
        assert!((jain_index(&[0.7]) - 1.0).abs() < 1e-12);
        assert!((jain_index(&[1.0, 1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        // one tenant starving three others → approaches 1/4
        let skew = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12, "skewed index {skew}");
        let mid = jain_index(&[1.0, 0.5]);
        assert!(mid > 0.25 && mid < 1.0, "partial skew {mid}");
    }

    #[test]
    fn interference_conserves_injected_beats() {
        let t_burst = 4;
        let a = Bandwidth {
            demand_reads: 100,
            demand_writes: 20,
            clean_writes: 30,
            invalidates: 8,
            ..Default::default()
        };
        let b = Bandwidth { demand_reads: 60, demand_writes: 20, ..Default::default() };
        let c = Bandwidth { demand_reads: 20, demand_writes: 20, ..Default::default() };
        let per = [a, b, c];
        let absorbed = interference_beats(&per, t_burst);
        // only A injects overhead: 30 data-sized accesses + 8 one-beat markers
        let injected = (30 * t_burst + 8) as f64;
        assert!((absorbed.iter().sum::<f64>() - injected).abs() < 1e-9);
        assert_eq!(absorbed[0], 0.0, "a tenant never absorbs its own overhead");
        // B has twice C's demand beats, so it absorbs twice the share
        assert!((absorbed[1] / absorbed[2] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn overhead_beats_counts_invalidates_as_one_beat() {
        let bw = Bandwidth { clean_writes: 3, invalidates: 5, demand_reads: 99, ..Default::default() };
        assert_eq!(overhead_beats(&bw, 4), 3 * 4 + 5);
    }

    #[test]
    fn tier_traffic_sums_per_tier() {
        let near = TierTraffic { demand_reads: 7, demand_writes: 3, ..Default::default() };
        let far = TierTraffic {
            demand_reads: 4,
            demand_writes: 1,
            clean_writes: 2,
            invalidates: 1,
            migr_accesses: 6,
            ..Default::default()
        };
        let t = TierStats { near, far, ..Default::default() };
        assert_eq!(near.total(), 10);
        assert_eq!(far.total(), 14);
        assert_eq!(t.total_accesses(), 24);
        assert!((t.far_frac() - 14.0 / 24.0).abs() < 1e-12);
        // since() against itself zeroes every counter
        assert_eq!(t.since(&t), TierStats::default());
    }

    #[test]
    fn link_traffic_splits_sum_to_totals() {
        let lt = LinkTraffic {
            demand_raw_bytes: 640,
            demand_wire_bytes: 320,
            meta_raw_bytes: 128,
            meta_wire_bytes: 32,
            writeback_raw_bytes: 256,
            writeback_wire_bytes: 200,
            prefetch_raw_bytes: 64,
            prefetch_wire_bytes: 64,
            migration_raw_bytes: 512,
            migration_wire_bytes: 300,
            flits_saved: 17,
            retried_flits: 2,
            retry_beats: 40,
        };
        assert_eq!(lt.raw_bytes(), 640 + 128 + 256 + 64 + 512);
        assert_eq!(lt.wire_bytes(), 320 + 32 + 200 + 64 + 300);
        assert!(lt.wire_bytes() <= lt.raw_bytes());
        assert_eq!(lt.since(&lt), LinkTraffic::default());
        let half = lt.since(&LinkTraffic {
            demand_raw_bytes: 320,
            demand_wire_bytes: 160,
            retried_flits: 1,
            ..Default::default()
        });
        assert_eq!(half.demand_raw_bytes, 320);
        assert_eq!(half.demand_wire_bytes, 160);
        assert_eq!(half.flits_saved, 17);
        assert_eq!(half.retried_flits, 1);
        assert_eq!(half.retry_beats, 40);
    }

    #[test]
    fn reliability_stats_since_accumulate_and_coverage() {
        let zero = ReliabilityStats::default();
        assert!(zero.is_zero());
        assert_eq!(zero.detection_coverage(), None);
        let full = ReliabilityStats {
            flits_retried: 9,
            retry_beats: 120,
            media_errors: 4,
            marker_errors: 10,
            marker_detected: 10,
            silent_misreads: 0,
            rekeys: 1,
            watchdog_degrades: 2,
            watchdog_rearms: 1,
            degraded_epochs: 6,
        };
        assert!(!full.is_zero());
        assert!((full.detection_coverage().unwrap() - 1.0).abs() < 1e-12);
        // since() against itself zeroes; warm-subtraction keeps the tail
        assert!(full.since(&full).is_zero());
        let warm = ReliabilityStats { flits_retried: 4, marker_errors: 3, ..Default::default() };
        let d = full.since(&warm);
        assert_eq!(d.flits_retried, 5);
        assert_eq!(d.marker_errors, 7);
        // accumulate() inverts since()
        let mut acc = warm;
        acc.accumulate(&d);
        assert_eq!(acc, full);
    }
}
