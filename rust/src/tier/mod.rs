//! Tiered memory: a CRAM-compressed CXL far-memory expander behind the
//! controller.
//!
//! The paper evaluates CRAM on a flat DDR4 system; the industry pull
//! (IBEX, hyperscale CXL adoption) is toward *memory expanders* — extra
//! capacity behind a narrow serialized link, where bandwidth is scarcest
//! and compression pays off most.  This subsystem models that system:
//!
//! * [`link::CxlLink`] — the narrow full-duplex link: 64B flits
//!   serialized over configurable lanes, per-direction queuing, port
//!   latency;
//! * [`memory::TieredMemory`] — near-DDR + far-expander routing by a
//!   configurable capacity split, hot-page promotion / cold-page
//!   demotion, and the expander-side executor of the design's
//!   compression [`Policy`](crate::controller::Policy) — every layout
//!   decision comes from the shared
//!   [`CramEngine`](crate::controller::CramEngine), so this module owns
//!   no packing logic of its own;
//! * [`crate::controller::Placement::Tiered`] — composes the tier with
//!   the rest of the system; `repro figure t1` compares an uncompressed
//!   far tier against a CRAM-compressed one on far-memory-pressure
//!   workloads ([`crate::workloads::profiles::far_pressure`]), and
//!   `repro figure x1` opens the full policy × placement cross-product
//!   (`tiered-cram-dyn`, `tiered-explicit`, …).
//!
//! Per-tier traffic lands in [`crate::stats::TierStats`], whose
//! `total_accesses()` equals the run's `Bandwidth::total()` — the
//! accounting invariant tying the tier breakdown to the paper's
//! bandwidth methodology.  See DESIGN.md §Tiered memory.

pub mod link;
pub mod memory;

pub use link::{CxlLink, CxlLinkConfig, LinkClass, LinkStats};
pub use memory::{TierConfig, TieredMemory};
