//! CXL link model: a narrow, full-duplex serialized channel.
//!
//! The far tier sits behind a CXL.mem-style point-to-point link.  Unlike a
//! DDR channel (parallel bus, 16B per bus cycle at our 800 MHz time base),
//! the link serializes traffic into 64-byte data flits over a configurable
//! number of lanes, with each direction (TX = host→device commands and
//! write data, RX = device→host read completions) occupied independently.
//!
//! The model captures the three effects the tiered evaluation hinges on:
//!
//! * **narrowness** — a lane moves one byte per DRAM bus cycle
//!   (~0.8 GB/s effective), so the default x8 link is half a DDR4 channel;
//!   a 64B flit occupies the direction for `64 / lanes` cycles;
//! * **queuing delay** — each direction is a single serialized resource;
//!   bursts (writebacks, page migrations) queue demand reads behind them,
//!   and the wait is tracked per direction;
//! * **port latency** — a fixed one-way controller + propagation delay on
//!   top of serialization (retimers, CXL stack).
//!
//! Every transfer is tagged with a [`LinkClass`] and carries both its
//! *raw* (storage-sized) and *wire* (post-[`LinkCodec`]) byte counts:
//! serialization and busy cycles charge the wire bytes, while the
//! [`LinkTraffic`] breakdown records raw vs wire per class — the data
//! behind the link-bytes-vs-storage-bytes exhibit.  A payload that
//! crossed compressed (`wire < raw`) pays [`CxlLinkConfig::decomp_latency`]
//! at the receiving port on top of serialization; raw transfers are
//! cycle-identical to the pre-codec model.
//!
//! All times are DRAM bus cycles (800 MHz, 1.25 ns) to match
//! [`crate::dram::DramSim`].
//!
//! [`LinkCodec`]: crate::controller::LinkCodec

use crate::sim::fault::FaultInjector;
use crate::stats::LinkTraffic;

/// Link geometry and latency.
#[derive(Clone, Copy, Debug)]
pub struct CxlLinkConfig {
    /// Lane count; one lane carries 1 byte per bus cycle (~0.8 GB/s).
    pub lanes: u64,
    /// One-way port/controller latency in bus cycles (~30 ns default).
    pub port_latency: u64,
    /// Extra cycles the receiving port spends decompressing a payload
    /// that crossed with `wire < raw` bytes (~5 ns default — a ZeroPoint
    /// -class inline codec).  Raw transfers never pay it.
    pub decomp_latency: u64,
}

impl Default for CxlLinkConfig {
    fn default() -> Self {
        Self { lanes: 8, port_latency: 24, decomp_latency: 4 }
    }
}

impl CxlLinkConfig {
    pub fn with_lanes(mut self, lanes: u64) -> Self {
        self.lanes = lanes;
        self
    }

    /// Cycles a transfer of `bytes` occupies one direction.
    #[inline]
    pub fn flit_cycles(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.lanes).max(1)
    }

    /// Peak per-direction bandwidth in bytes per bus cycle.
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.lanes as f64
    }
}

/// What a link transfer is for — the split axis of the [`LinkTraffic`]
/// breakdown.  Command flits take the class of the transfer they
/// initiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// Demand far reads (command + returned line/block).
    Demand,
    /// Explicit-metadata region crossings.
    Metadata,
    /// Dirty/packed writebacks and invalidate markers host→device.
    Writeback,
    /// Next-line prefetch reads on the far tier.
    Prefetch,
    /// Page-migration line moves (both directions).
    Migration,
}

/// Per-direction traffic counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Flits sent host→device (commands, write data, demoted pages).
    pub tx_flits: u64,
    /// Flits sent device→host (read completions, promoted pages).
    pub rx_flits: u64,
    pub tx_busy_cycles: u64,
    pub rx_busy_cycles: u64,
    /// Cycles transfers spent queued behind earlier traffic, per direction.
    pub tx_wait_cycles: u64,
    pub rx_wait_cycles: u64,
}

impl LinkStats {
    /// Field-wise difference (measurement-phase accounting).
    pub fn since(&self, warm: &LinkStats) -> LinkStats {
        LinkStats {
            tx_flits: self.tx_flits - warm.tx_flits,
            rx_flits: self.rx_flits - warm.rx_flits,
            tx_busy_cycles: self.tx_busy_cycles - warm.tx_busy_cycles,
            rx_busy_cycles: self.rx_busy_cycles - warm.rx_busy_cycles,
            tx_wait_cycles: self.tx_wait_cycles - warm.tx_wait_cycles,
            rx_wait_cycles: self.rx_wait_cycles - warm.rx_wait_cycles,
        }
    }
}

/// The link: two independent serialized directions plus port latency.
pub struct CxlLink {
    cfg: CxlLinkConfig,
    /// TX direction occupied until this cycle.
    tx_free: u64,
    /// RX direction occupied until this cycle.
    rx_free: u64,
    pub stats: LinkStats,
    /// Raw-vs-wire byte accounting per [`LinkClass`].
    pub traffic: LinkTraffic,
    /// Per-flit CRC error source (None = fault injection off; the
    /// transfer paths are then cycle- and state-identical to the
    /// pre-reliability model).
    fault: Option<FaultInjector>,
}

/// A CRC-rejected transfer is replayed at most this many times before
/// the link gives up and delivers (the containing protocol would reset;
/// the bound keeps worst-case timing finite under `--fault-ber 1`).
const MAX_REPLAYS: u32 = 8;
/// First-replay backoff in bus cycles; doubles per attempt up to
/// [`BACKOFF_CAP`].
const BACKOFF_BASE: u64 = 2;
const BACKOFF_CAP: u64 = 64;

/// A read command / header flit on the wire (address + opcode).
pub const CMD_BYTES: u64 = 8;
/// A full data flit (one 64B line or packed block).
pub const DATA_BYTES: u64 = 64;

impl CxlLink {
    pub fn new(cfg: CxlLinkConfig) -> Self {
        Self {
            cfg,
            tx_free: 0,
            rx_free: 0,
            stats: LinkStats::default(),
            traffic: LinkTraffic::default(),
            fault: None,
        }
    }

    pub fn config(&self) -> &CxlLinkConfig {
        &self.cfg
    }

    /// Arm (or disarm, with `ber <= 0`) the per-flit CRC error source.
    /// Seeded: the same `(ber, seed)` replays the same error sequence.
    pub fn set_fault(&mut self, ber: f64, seed: u64) {
        self.fault = if ber > 0.0 { Some(FaultInjector::link(ber, seed)) } else { None };
    }

    /// Replay a CRC-rejected transfer: each rejected attempt re-occupies
    /// the direction for the transfer's serialization plus a bounded
    /// exponential backoff (doubling from [`BACKOFF_BASE`], capped at
    /// [`BACKOFF_CAP`], at most [`MAX_REPLAYS`] attempts).  Returns the
    /// extra cycles added to the arrival; counts one retried flit per
    /// affected transfer plus every replay beat into [`LinkTraffic`].
    fn replay(
        fault: &mut Option<FaultInjector>,
        free: &mut u64,
        busy: &mut u64,
        traffic: &mut LinkTraffic,
        cycles: u64,
    ) -> u64 {
        let Some(inj) = fault.as_mut() else { return 0 };
        let mut extra = 0u64;
        let mut attempt = 0u32;
        while attempt < MAX_REPLAYS && inj.fires() {
            let backoff = (BACKOFF_BASE << attempt).min(BACKOFF_CAP);
            let beats = backoff + cycles;
            *free += beats;
            *busy += cycles;
            extra += beats;
            if attempt == 0 {
                traffic.retried_flits += 1;
            }
            traffic.retry_beats += beats;
            attempt += 1;
        }
        extra
    }

    /// Occupy one direction for `bytes` starting no earlier than `now`.
    /// Returns (arrival cycle after port latency, queuing wait, cycles).
    fn occupy(cfg: &CxlLinkConfig, free: &mut u64, now: u64, bytes: u64) -> (u64, u64, u64) {
        let cycles = cfg.flit_cycles(bytes);
        let start = now.max(*free);
        let wait = start - now;
        *free = start + cycles;
        (*free + cfg.port_latency, wait, cycles)
    }

    /// Charge the raw-vs-wire breakdown for one transfer.
    fn charge(traffic: &mut LinkTraffic, cfg: &CxlLinkConfig, class: LinkClass, raw: u64, wire: u64) {
        let (raw_acc, wire_acc) = match class {
            LinkClass::Demand => (&mut traffic.demand_raw_bytes, &mut traffic.demand_wire_bytes),
            LinkClass::Metadata => (&mut traffic.meta_raw_bytes, &mut traffic.meta_wire_bytes),
            LinkClass::Writeback => {
                (&mut traffic.writeback_raw_bytes, &mut traffic.writeback_wire_bytes)
            }
            LinkClass::Prefetch => {
                (&mut traffic.prefetch_raw_bytes, &mut traffic.prefetch_wire_bytes)
            }
            LinkClass::Migration => {
                (&mut traffic.migration_raw_bytes, &mut traffic.migration_wire_bytes)
            }
        };
        *raw_acc += raw;
        *wire_acc += wire;
        traffic.flits_saved += cfg.flit_cycles(raw) - cfg.flit_cycles(wire);
    }

    /// Transfer `bytes` host→device starting no earlier than `now`.
    /// Returns the cycle the payload is available at the device (after
    /// serialization + port latency).  Occupies TX for the serialization.
    pub fn send(&mut self, now: u64, bytes: u64, class: LinkClass) -> u64 {
        self.send_payload(now, bytes, bytes, class)
    }

    /// Transfer a payload of `raw` storage bytes host→device, serialized
    /// as `wire ≤ raw` bytes after the TX-side size-only pass.  A
    /// compressed payload (`wire < raw`) pays the device port's
    /// decompression latency on top of serialization + port latency.
    pub fn send_payload(&mut self, now: u64, raw: u64, wire: u64, class: LinkClass) -> u64 {
        debug_assert!(wire <= raw, "link codec never expands a payload");
        let (mut arrival, wait, cycles) = Self::occupy(&self.cfg, &mut self.tx_free, now, wire);
        self.stats.tx_flits += 1;
        self.stats.tx_busy_cycles += cycles;
        self.stats.tx_wait_cycles += wait;
        arrival += Self::replay(
            &mut self.fault,
            &mut self.tx_free,
            &mut self.stats.tx_busy_cycles,
            &mut self.traffic,
            cycles,
        );
        Self::charge(&mut self.traffic, &self.cfg, class, raw, wire);
        if wire < raw {
            arrival + self.cfg.decomp_latency
        } else {
            arrival
        }
    }

    /// Transfer a command/header flit of `raw` bytes host→device,
    /// serialized as `wire ≤ raw` bytes (header compression: address
    /// deltas + opcode packing).  Unlike [`send_payload`], a shrunken
    /// header pays **no** decompression latency: header decode is
    /// pipelined in the port, so the saving is pure wire bytes (and, on
    /// narrow links, serialization cycles).  Occupancy, CRC replay, and
    /// per-class raw/wire accounting are otherwise identical.
    ///
    /// [`send_payload`]: CxlLink::send_payload
    pub fn send_cmd(&mut self, now: u64, raw: u64, wire: u64, class: LinkClass) -> u64 {
        debug_assert!(wire <= raw, "link codec never expands a header");
        let (mut arrival, wait, cycles) = Self::occupy(&self.cfg, &mut self.tx_free, now, wire);
        self.stats.tx_flits += 1;
        self.stats.tx_busy_cycles += cycles;
        self.stats.tx_wait_cycles += wait;
        arrival += Self::replay(
            &mut self.fault,
            &mut self.tx_free,
            &mut self.stats.tx_busy_cycles,
            &mut self.traffic,
            cycles,
        );
        Self::charge(&mut self.traffic, &self.cfg, class, raw, wire);
        arrival
    }

    /// Transfer `bytes` device→host starting no earlier than `now`.
    /// Returns the cycle the payload arrives at the host.
    pub fn recv(&mut self, now: u64, bytes: u64, class: LinkClass) -> u64 {
        self.recv_payload(now, bytes, bytes, class)
    }

    /// Transfer a payload of `raw` storage bytes device→host, serialized
    /// as `wire ≤ raw` bytes; the host port pays the decompression
    /// latency when the payload crossed compressed.
    pub fn recv_payload(&mut self, now: u64, raw: u64, wire: u64, class: LinkClass) -> u64 {
        debug_assert!(wire <= raw, "link codec never expands a payload");
        let (mut arrival, wait, cycles) = Self::occupy(&self.cfg, &mut self.rx_free, now, wire);
        self.stats.rx_flits += 1;
        self.stats.rx_busy_cycles += cycles;
        self.stats.rx_wait_cycles += wait;
        arrival += Self::replay(
            &mut self.fault,
            &mut self.rx_free,
            &mut self.stats.rx_busy_cycles,
            &mut self.traffic,
            cycles,
        );
        Self::charge(&mut self.traffic, &self.cfg, class, raw, wire);
        if wire < raw {
            arrival + self.cfg.decomp_latency
        } else {
            arrival
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flit_serialization_scales_with_lanes() {
        let x8 = CxlLinkConfig::default();
        assert_eq!(x8.flit_cycles(DATA_BYTES), 8);
        assert_eq!(x8.flit_cycles(CMD_BYTES), 1);
        let x16 = CxlLinkConfig::default().with_lanes(16);
        assert_eq!(x16.flit_cycles(DATA_BYTES), 4);
        let x4 = CxlLinkConfig::default().with_lanes(4);
        assert_eq!(x4.flit_cycles(DATA_BYTES), 16);
    }

    #[test]
    fn directions_are_independent() {
        let mut l = CxlLink::new(CxlLinkConfig::default());
        let a = l.send(0, DATA_BYTES, LinkClass::Writeback);
        let b = l.recv(0, DATA_BYTES, LinkClass::Demand);
        // both transfer concurrently: same completion, no cross-queuing
        assert_eq!(a, b);
        assert_eq!(l.stats.tx_wait_cycles + l.stats.rx_wait_cycles, 0);
    }

    #[test]
    fn same_direction_queues() {
        let mut l = CxlLink::new(CxlLinkConfig::default());
        let a = l.recv(0, DATA_BYTES, LinkClass::Demand); // 8 serialize + 24 port = 32
        let b = l.recv(0, DATA_BYTES, LinkClass::Demand); // queued 8 cycles behind
        assert_eq!(a, 8 + 24);
        assert_eq!(b, 16 + 24);
        assert_eq!(l.stats.rx_wait_cycles, 8);
        assert_eq!(l.stats.rx_flits, 2);
    }

    #[test]
    fn idle_link_pays_only_latency_and_serialization() {
        let mut l = CxlLink::new(CxlLinkConfig::default());
        let done = l.send(1000, CMD_BYTES, LinkClass::Demand);
        assert_eq!(done, 1000 + 1 + 24);
    }

    #[test]
    fn stats_since_subtracts() {
        let mut l = CxlLink::new(CxlLinkConfig::default());
        l.send(0, DATA_BYTES, LinkClass::Writeback);
        let warm = l.stats;
        l.send(0, DATA_BYTES, LinkClass::Writeback);
        let d = l.stats.since(&warm);
        assert_eq!(d.tx_flits, 1);
        assert_eq!(d.tx_busy_cycles, 8);
    }

    #[test]
    fn raw_payload_is_cycle_identical_to_untyped_transfer() {
        // send(bytes) == send_payload(raw == wire): no decompression
        // penalty, same serialization — the LinkCodec::Raw guarantee
        let mut a = CxlLink::new(CxlLinkConfig::default());
        let mut b = CxlLink::new(CxlLinkConfig::default());
        let ta = a.recv(0, DATA_BYTES, LinkClass::Demand);
        let tb = b.recv_payload(0, DATA_BYTES, DATA_BYTES, LinkClass::Demand);
        assert_eq!(ta, tb);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.traffic.flits_saved, 0);
        assert_eq!(a.traffic.raw_bytes(), a.traffic.wire_bytes());
    }

    #[test]
    fn compressed_payload_saves_serialization_but_pays_decomp() {
        let cfg = CxlLinkConfig::default();
        let mut l = CxlLink::new(cfg);
        // a 64B line compressed to 16B: 2 serialize cycles instead of 8,
        // plus the decompression latency at the port
        let t = l.recv_payload(0, DATA_BYTES, 16, LinkClass::Demand);
        assert_eq!(t, 2 + cfg.port_latency + cfg.decomp_latency);
        assert_eq!(l.stats.rx_busy_cycles, 2);
        assert_eq!(l.traffic.demand_raw_bytes, 64);
        assert_eq!(l.traffic.demand_wire_bytes, 16);
        assert_eq!(l.traffic.flits_saved, 8 - 2);
    }

    #[test]
    fn compressed_cmd_flit_skips_decomp_latency() {
        let cfg = CxlLinkConfig::default();
        // raw 8B header vs a 4B compressed header: at x8 lanes both
        // serialize in one cycle (flit_cycles floors at 1), and the
        // compressed header must NOT pay the decompression latency —
        // otherwise header compression would be a pure timing regression
        let mut raw = CxlLink::new(cfg);
        let mut lc = CxlLink::new(cfg);
        let tr = raw.send_cmd(0, CMD_BYTES, CMD_BYTES, LinkClass::Demand);
        let tc = lc.send_cmd(0, CMD_BYTES, CMD_BYTES / 2, LinkClass::Demand);
        assert_eq!(tr, 1 + cfg.port_latency);
        assert_eq!(tc, tr, "same cycles at x8 — no decomp addendum");
        // ...but the wire-byte ledger records the shrink
        assert_eq!(lc.traffic.demand_raw_bytes, CMD_BYTES);
        assert_eq!(lc.traffic.demand_wire_bytes, CMD_BYTES / 2);
        assert_eq!(lc.traffic.flits_saved, 0, "both headers fit one flit cycle");
        // on a narrower link the shrink also saves serialization cycles
        let mut x2 = CxlLink::new(CxlLinkConfig::default().with_lanes(2));
        let t2 = x2.send_cmd(0, CMD_BYTES, CMD_BYTES / 2, LinkClass::Demand);
        assert_eq!(t2, 2 + cfg.port_latency, "4B over x2 = 2 cycles, not 4");
        assert_eq!(x2.traffic.flits_saved, 2);
    }

    #[test]
    fn raw_cmd_is_cycle_identical_to_untyped_send() {
        let mut a = CxlLink::new(CxlLinkConfig::default());
        let mut b = CxlLink::new(CxlLinkConfig::default());
        let ta = a.send(7, CMD_BYTES, LinkClass::Metadata);
        let tb = b.send_cmd(7, CMD_BYTES, CMD_BYTES, LinkClass::Metadata);
        assert_eq!(ta, tb);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.traffic, b.traffic);
    }

    #[test]
    fn traffic_classes_split_the_totals() {
        let mut l = CxlLink::new(CxlLinkConfig::default());
        l.recv_payload(0, DATA_BYTES, 32, LinkClass::Demand);
        l.recv_payload(0, DATA_BYTES, 16, LinkClass::Metadata);
        l.send_payload(0, DATA_BYTES, 48, LinkClass::Writeback);
        l.recv_payload(0, DATA_BYTES, DATA_BYTES, LinkClass::Prefetch);
        l.send_payload(0, DATA_BYTES, 24, LinkClass::Migration);
        let t = &l.traffic;
        assert_eq!(t.raw_bytes(), 5 * DATA_BYTES);
        assert_eq!(t.wire_bytes(), 32 + 16 + 48 + 64 + 24);
        assert!(t.wire_bytes() <= t.raw_bytes());
        assert_eq!(t.demand_wire_bytes, 32);
        assert_eq!(t.meta_wire_bytes, 16);
        assert_eq!(t.writeback_wire_bytes, 48);
        assert_eq!(t.prefetch_wire_bytes, 64);
        assert_eq!(t.migration_wire_bytes, 24);
    }

    #[test]
    fn disarmed_fault_is_bit_identical() {
        let mut plain = CxlLink::new(CxlLinkConfig::default());
        let mut armed_off = CxlLink::new(CxlLinkConfig::default());
        armed_off.set_fault(0.0, 42); // ber 0 ⇒ stays None
        for i in 0..50 {
            let a = plain.recv_payload(i * 3, DATA_BYTES, 32, LinkClass::Demand);
            let b = armed_off.recv_payload(i * 3, DATA_BYTES, 32, LinkClass::Demand);
            assert_eq!(a, b);
        }
        assert_eq!(plain.stats, armed_off.stats);
        assert_eq!(plain.traffic, armed_off.traffic);
        assert_eq!(plain.traffic.retried_flits, 0);
        assert_eq!(plain.traffic.retry_beats, 0);
    }

    #[test]
    fn certain_error_replays_bounded_with_backoff() {
        // ber = 1.0 rejects every attempt: exactly MAX_REPLAYS replays,
        // each costing the 8-cycle re-serialization plus the doubling,
        // capped backoff 2,4,8,16,32,64,64,64.
        let mut l = CxlLink::new(CxlLinkConfig::default());
        l.set_fault(1.0, 7);
        let t = l.recv(0, DATA_BYTES, LinkClass::Demand);
        let backoff: u64 = 2 + 4 + 8 + 16 + 32 + 64 + 64 + 64;
        let beats = backoff + 8 * 8;
        assert_eq!(l.traffic.retried_flits, 1);
        assert_eq!(l.traffic.retry_beats, beats);
        assert_eq!(t, 8 + beats + 24);
        assert_eq!(l.stats.rx_busy_cycles, 8 + 8 * 8);
        // the direction stays serialized: a second transfer queues behind
        // the replays
        let t2 = l.recv(0, DATA_BYTES, LinkClass::Demand);
        assert!(t2 > t);
    }

    #[test]
    fn retry_telemetry_conserves() {
        let mut l = CxlLink::new(CxlLinkConfig::default());
        l.set_fault(0.3, 11);
        let mut last_wire = 0;
        for i in 0..200 {
            l.send_payload(i, DATA_BYTES, 48, LinkClass::Writeback);
            l.recv_payload(i, DATA_BYTES, 32, LinkClass::Demand);
            // wire bytes are monotone and unaffected by replays
            let w = l.traffic.wire_bytes();
            assert!(w >= last_wire);
            last_wire = w;
        }
        let sent = l.stats.tx_flits + l.stats.rx_flits;
        assert!(l.traffic.retried_flits <= sent, "retried ≤ sent");
        assert!(l.traffic.retried_flits > 0, "30% BER over 400 transfers");
        assert!(l.traffic.retry_beats >= l.traffic.retried_flits);
        // raw/wire accounting is untouched by the replays
        assert_eq!(l.traffic.raw_bytes(), 400 * DATA_BYTES);
        assert_eq!(l.traffic.wire_bytes(), 200 * 48 + 200 * 32);
    }

    #[test]
    fn fault_sequence_is_seed_replayable() {
        let run = |seed: u64| {
            let mut l = CxlLink::new(CxlLinkConfig::default());
            l.set_fault(0.1, seed);
            for i in 0..500 {
                l.recv(i, DATA_BYTES, LinkClass::Demand);
            }
            (l.stats, l.traffic)
        };
        // same seed ⇒ identical timing and telemetry, field for field
        assert_eq!(run(3), run(3));
    }
}
