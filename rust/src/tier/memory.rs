//! The tiered-memory front-end: near DDR + far CXL expander.
//!
//! Routes line addresses to the near tier (the host's DDR4 channels,
//! always uncompressed) or the far tier (expander-internal DRAM behind a
//! [`CxlLink`]), runs a hot-page promotion / cold-page demotion policy,
//! and executes the design's compression [`Policy`] **on the expander**:
//! the tier is the [`Placement::Tiered`](crate::controller::Placement)
//! executor of the composable design space (see
//! [`crate::controller::policy`]).
//!
//! **Placement.**  Pages default to near/far by a deterministic hash
//! against `far_ratio` (the capacity split: `far_ratio` = fraction of
//! capacity on the expander), first-touch-style.  The migration policy
//! overrides the default per page: a far page whose access counter
//! crosses `promote_threshold` is promoted, and a cold near page is
//! demoted in exchange to preserve the split.  Counters decay by halving
//! every `epoch_accesses` accesses.
//!
//! **Far-tier policies.**  All layout decisions come from the shared
//! [`LayoutEngine`] — the same authority the flat host controller uses
//! (the [`CramEngine`] group family or the [`LcpLayout`] page family);
//! this module owns only the expander-side issue path (link flits +
//! device DRAM accesses + per-tier accounting):
//!
//! * `Implicit` (`tiered-cram`) — device-held metadata (IBEX-style):
//!   layouts live next to the data, so there is no host-side predictor
//!   and no second-probe traffic; one flit returns every co-located
//!   line of a packed block.
//! * `Dynamic` (`tiered-cram-dyn`) — the same engine gated by the
//!   per-core Dynamic-CRAM cost/benefit counters: far invalidates and
//!   clean packed writes charge costs, useful far co-fetches pay
//!   benefits, and a closed gate stops *creating* packed far data while
//!   leaving existing packed groups to decay lazily.
//! * `Explicit` (`tiered-explicit`) — a Pekhimenko-style explicit
//!   metadata region in device memory with a host-side metadata cache:
//!   a meta-cache miss crosses the link **twice** (metadata fetch, then
//!   the data access) before the demand data moves, which is the cost
//!   story this composition exists to expose.
//! * `Lcp` (`tiered-lcp`) — the page layout family on the expander:
//!   page-table-resident descriptors cached host-side (a miss crosses
//!   the link like `tiered-explicit` metadata), demand data read at the
//!   descriptor's *fixed* offset — no predictor, no probes — and
//!   exception-overflow recompaction executed device-internally (far
//!   DRAM traffic, **no** link flits: the expander re-encodes its own
//!   page).
//! * `Ideal` — far co-fetch benefits with no write-side overheads.
//! * `Uncompressed` / `NextLinePrefetch` — raw far lines (the prefetch
//!   baseline issues its extra next-line access through the same
//!   near/far routing).
//!
//! **Scheduling.**  The expander's device DRAM is a [`DramSim`] like the
//! host's, so it runs the same per-channel FR-FCFS transaction scheduler
//! ([`crate::dram::sched`]).  [`TierConfig::far_dram`]`.sched` carries
//! the expander's knobs; `SimConfig::with_sched` sets host and device
//! alike.
//!
//! Every access is charged to exactly one tier, so
//! `TierStats::total_accesses() == Bandwidth::total()` for a tiered run —
//! the subsystem's accounting invariant (checked in tests).  This module
//! deliberately owns **no packing logic**: `decide_packed_layout`, slot
//! plans, install recovery and gang masks are [`CramEngine`] calls, and
//! descriptor choice / exception ranks / recompaction are [`LcpLayout`]
//! calls — the tier-owns-no-packing invariant holds for both families.

use std::collections::{HashMap, HashSet};

use crate::controller::{
    CramEngine, Install, Installs, LayoutEngine, LcpLayout, LcpWriteOutcome, LinkCodec, Policy,
    ReadOutcome, SlotOp,
};
use crate::cram::dynamic::DynamicCram;
use crate::cram::group::Csi;
use crate::cram::metadata::{MetaAccess, MetadataStore};
use crate::dram::{DramConfig, DramSim, ReqKind};
use crate::cram::store::CompressedStore;
use crate::mem::{group_base, group_of, page_of_line};
use crate::sim::fault::{FaultConfig, FaultInjector};
use crate::stats::{Bandwidth, CapacityStats, ReliabilityStats, TierStats};
use crate::tier::link::{CxlLink, CxlLinkConfig, LinkClass, CMD_BYTES, DATA_BYTES};
use crate::util::rng::splitmix64;
use crate::workloads::SizeOracle;

/// Lines per 4KB page.
const PAGE_LINES: u64 = 64;
/// Groups per page.
const PAGE_GROUPS: u64 = PAGE_LINES / 4;
/// First line of the expander's metadata region (device address space,
/// past the 16GB data window — `tiered-explicit` only).
const FAR_META_BASE: u64 = 16 * 1024 * 1024 * 1024 / 64;

/// Tiered-memory configuration.
#[derive(Clone, Copy, Debug)]
pub struct TierConfig {
    /// Fraction of capacity (pages) placed on the far tier by default.
    pub far_ratio: f64,
    pub link: CxlLinkConfig,
    /// Expander-internal DRAM (default: a single channel).
    pub far_dram: DramConfig,
    /// Accesses to a far page before it is promoted near.
    pub promote_threshold: u32,
    /// Heat counters halve every this many accesses.
    pub epoch_accesses: u64,
    /// Near pages sampled when picking a demotion victim.
    pub victim_samples: usize,
    /// Placement-hash seed.
    pub seed: u64,
}

impl Default for TierConfig {
    fn default() -> Self {
        Self {
            far_ratio: 0.5,
            link: CxlLinkConfig::default(),
            far_dram: DramConfig::default().with_channels(1),
            // Promotion is reserved for *sustained* heat: the threshold
            // sits above the ~64 touches a streaming pass leaves on a
            // page, and the decay epoch is short enough that heat from a
            // single pass evaporates before a second pass tops it up.
            // Pages a stream merely traverses stay far (a one-time
            // migration storm would just move the stream off the link it
            // is supposed to stress); pages re-touched heavily between
            // decays promote.
            promote_threshold: 96,
            epoch_accesses: 100_000,
            victim_samples: 8,
            seed: 0x7153,
        }
    }
}

impl TierConfig {
    pub fn with_far_ratio(mut self, r: f64) -> Self {
        self.far_ratio = r.clamp(0.0, 1.0);
        self
    }
}

/// The two-tier memory behind the controller.
pub struct TieredMemory {
    cfg: TierConfig,
    /// The compression policy running on the expander.
    policy: Policy,
    /// Placement-hash cutoff: page is far iff hash % 4096 < far_cut.
    far_cut: u64,
    pub link: CxlLink,
    pub far_dram: DramSim,
    /// The expander's layout authority: far-tier group layouts
    /// (device-held metadata) plus the shared packing machinery, or the
    /// page family's descriptor ledger under the `Lcp` policy.
    engine: LayoutEngine,
    /// Host-side metadata cache over the device metadata region
    /// (`Explicit` far policy) or the device descriptor region (`Lcp`).
    pub meta: Option<MetadataStore>,
    /// Per-page placement overrides from migration (true = far).
    placement: HashMap<u64, bool>,
    /// Per-page access heat with the epoch it was last updated.  Decay is
    /// lazy — applied when an entry is next touched or read — so no
    /// stop-the-world sweep ever runs on the demand path.
    heat: HashMap<u64, (u32, u32)>,
    /// Near pages eligible as demotion victims (dedup + ring order).
    listed: HashSet<u64>,
    near_pages: Vec<u64>,
    victim_cursor: usize,
    accesses: u64,
    stats: TierStats,
    /// Far-media read fault site (None = injection off).
    media_fault: Option<FaultInjector>,
    /// Marker-tail fault site on packed far reads (None = injection off).
    marker_fault: Option<FaultInjector>,
    /// Expander-side reliability counters (media/marker sites; the link
    /// site's retry telemetry rides in [`CxlLink::traffic`]).
    rel: ReliabilityStats,
    /// Detections since the device last re-keyed its markers.
    marker_errors_since_rekey: u32,
    /// Watchdog level 2: stop creating packed far data (existing packed
    /// groups decay lazily, exactly like a closed Dynamic gate).
    compress_off: bool,
}

impl TieredMemory {
    /// Expander with the paper-default 32KB metadata cache (when the
    /// policy needs one) and a raw link.
    pub fn new(cfg: TierConfig, policy: Policy) -> Self {
        Self::with_meta_cache(cfg, policy, 32 * 1024)
    }

    /// Raw-link constructor with the metadata-cache size knob (the
    /// `Explicit` far policy; `SimConfig::meta_cache_bytes`).
    pub fn with_meta_cache(cfg: TierConfig, policy: Policy, meta_cache_bytes: usize) -> Self {
        Self::with_codec(cfg, policy, meta_cache_bytes, LinkCodec::Raw)
    }

    /// Full constructor: the design's link codec rides in the expander's
    /// [`CramEngine`], so every wire-size question on this tier's link
    /// goes through the same plumbing the other executors use.
    pub fn with_codec(
        cfg: TierConfig,
        policy: Policy,
        meta_cache_bytes: usize,
        link_codec: LinkCodec,
    ) -> Self {
        let meta = match policy {
            Policy::Explicit { row_opt } => {
                let mut m = MetadataStore::new(meta_cache_bytes, 8, FAR_META_BASE);
                m.row_optimized = row_opt;
                Some(m)
            }
            // Lcp caches page descriptors host-side over the device
            // descriptor region (pure-cache mode: no CSI geometry)
            Policy::Lcp => Some(MetadataStore::new(meta_cache_bytes, 8, FAR_META_BASE)),
            _ => None,
        };
        Self {
            far_cut: (cfg.far_ratio.clamp(0.0, 1.0) * 4096.0) as u64,
            link: CxlLink::new(cfg.link),
            far_dram: DramSim::new(cfg.far_dram),
            engine: LayoutEngine::for_policy(policy, link_codec),
            meta,
            placement: HashMap::new(),
            heat: HashMap::new(),
            listed: HashSet::new(),
            near_pages: Vec::new(),
            victim_cursor: 0,
            accesses: 0,
            stats: TierStats::default(),
            media_fault: None,
            marker_fault: None,
            rel: ReliabilityStats::default(),
            marker_errors_since_rekey: 0,
            compress_off: false,
            cfg,
            policy,
        }
    }

    /// Arm the expander's fault-injection sites (link flits, far-media
    /// reads, marker tails).  Sites with a zero rate stay uninstalled, so
    /// the default [`FaultConfig`] leaves the tier bit-identical to an
    /// un-faulted run.
    pub fn set_fault(&mut self, cfg: &FaultConfig, seed: u64) {
        self.link.set_fault(cfg.link_ber, seed);
        if cfg.media_ber > 0.0 {
            self.media_fault = Some(FaultInjector::media(cfg.media_ber, seed));
        }
        if cfg.marker_ber > 0.0 {
            self.marker_fault = Some(FaultInjector::marker(cfg.marker_ber, seed));
        }
    }

    /// Watchdog degradation ladder: `raw` forces raw flits on this tier's
    /// link (via the shared engine's wire-size override), `compress_off`
    /// stops creating packed far data.
    pub fn set_degraded(&mut self, raw: bool, compress_off: bool) {
        self.engine.set_degraded_raw(raw);
        self.compress_off = compress_off;
    }

    /// Expander-side reliability counters.  Link retry telemetry is in
    /// `snapshot().link_traffic`; the controller folds both together.
    pub fn rel(&self) -> ReliabilityStats {
        self.rel
    }

    /// Far-media fault site: the device's internal ECC flags a corrupted
    /// read, cured by one serialized verify re-read before the completion
    /// flit leaves the expander.  No-op unless injection is armed.
    fn media_site(&mut self, addr: u64, done: u64, bw: &mut Bandwidth) -> u64 {
        let Some(inj) = self.media_fault.as_mut() else { return done };
        if !inj.fires() {
            return done;
        }
        self.rel.media_errors += 1;
        bw.second_reads += 1;
        self.stats.far.second_reads += 1;
        self.far_dram.access(addr, ReqKind::Read, done, false)
    }

    /// Marker fault site on a packed far read: a corrupted tail is always
    /// a detectable downward miscue (`cram::marker` pins the no-alias
    /// property), so the expander cross-checks the tail it read against
    /// its device-held layout, detects the mismatch, and cures it with a
    /// serialized verify re-read.  Every
    /// [`CompressedStore::REKEY_ERROR_THRESHOLD`] detections the device
    /// re-keys its markers (the sweep runs off the demand path; counted).
    fn marker_site(&mut self, addr: u64, done: u64, bw: &mut Bandwidth) -> u64 {
        let Some(inj) = self.marker_fault.as_mut() else { return done };
        if !inj.fires() {
            return done;
        }
        self.rel.marker_errors += 1;
        self.rel.marker_detected += 1;
        self.marker_errors_since_rekey += 1;
        if self.marker_errors_since_rekey >= CompressedStore::REKEY_ERROR_THRESHOLD {
            self.marker_errors_since_rekey = 0;
            self.rel.rekeys += 1;
        }
        bw.second_reads += 1;
        self.stats.far.second_reads += 1;
        self.far_dram.access(addr, ReqKind::Read, done, false)
    }

    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    /// The compression policy running on the expander.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Does the far tier pack groups at all under this policy?
    fn far_packs(&self) -> bool {
        matches!(
            self.policy,
            Policy::Implicit | Policy::Dynamic | Policy::Explicit { .. }
        )
    }

    /// Current expander-held layout of `line`'s group (diagnostics).
    pub fn far_csi_of(&self, line: u64) -> Csi {
        self.engine.csi_of_line(line)
    }

    /// Current placement of a page (override, else the capacity-split hash).
    pub fn is_far_page(&self, page: u64) -> bool {
        match self.placement.get(&page) {
            Some(&far) => far,
            None => splitmix64(self.cfg.seed ^ 0x7165_72, page) % 4096 < self.far_cut,
        }
    }

    /// Current placement of a line.
    pub fn is_far_line(&self, line: u64) -> bool {
        self.is_far_page(page_of_line(line))
    }

    /// Stats snapshot with the link counters folded in.
    pub fn snapshot(&self) -> TierStats {
        let mut s = self.stats;
        s.link = self.link.stats;
        s.link_traffic = self.link.traffic;
        s.far_groups_written = self.engine.groups_written();
        s.far_groups_packed = self.engine.groups_compressed();
        s
    }

    /// The expander's effective-capacity ledger (`Lcp` far policy only;
    /// the group family trades capacity for bandwidth and reports none).
    pub fn capacity_snapshot(&self) -> Option<CapacityStats> {
        self.engine.capacity_snapshot()
    }

    /// Demand read of `line` at bus-cycle `now`.  `near` is the host DDR.
    pub fn read(
        &mut self,
        line: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) -> ReadOutcome {
        let page = page_of_line(line);
        self.touch(page, now, near, bw, oracle);
        let out = if !self.is_far_page(page) {
            bw.demand_reads += 1;
            self.stats.near.demand_reads += 1;
            let done = near.access(line, ReqKind::Read, now, false);
            ReadOutcome {
                done,
                installs: Installs::of(&[Install {
                    line_addr: line,
                    level: 0,
                    prefetch: false,
                    size: 0,
                }]),
            }
        } else {
            bw.demand_reads += 1;
            self.stats.far.demand_reads += 1;
            self.read_far(line, now, bw, oracle)
        };
        if self.policy == Policy::NextLinePrefetch {
            // next-line prefetch baseline: a full extra access, routed by
            // the prefetched line's own placement (heat untouched — the
            // migration policy is driven by demand accesses only)
            return self.prefetch_next(line, now, near, bw, oracle, out);
        }
        out
    }

    /// Far-tier demand read under the expander's policy.
    fn read_far(
        &mut self,
        line: u64,
        now: u64,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) -> ReadOutcome {
        let base = group_base(line);
        let slot = (line - base) as u8;
        match self.policy {
            Policy::Uncompressed | Policy::NextLinePrefetch => {
                // request flit out, device access, completion flit back —
                // the uncompressed-far line is exactly where in-flight
                // compression still pays once storage compression cannot
                let wire = self.engine.line_wire_bytes(oracle, line);
                let cw = self.engine.cmd_wire_bytes();
                let at_device = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Demand);
                let far_done = self.far_dram.access(line, ReqKind::Read, at_device, false);
                let far_done = self.media_site(line, far_done, bw);
                let done = self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Demand);
                ReadOutcome {
                    done,
                    installs: Installs::of(&[Install {
                        line_addr: line,
                        level: 0,
                        prefetch: false,
                        size: 0,
                    }]),
                }
            }
            Policy::Ideal => {
                // far co-fetch benefits with none of the overheads: the
                // layout is recomputed from the oracle, never written
                let csi = Csi::from_sizes(oracle.group_sizes(line));
                let loc = csi.location(slot);
                let wire = self.engine.block_wire_bytes(oracle, base, csi, loc);
                let cw = self.engine.cmd_wire_bytes();
                let at_device = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Demand);
                let far_done = self.far_dram.access(line, ReqKind::Read, at_device, false);
                let far_done = self.media_site(line, far_done, bw);
                let done = self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Demand);
                self.far_installs(base, csi, loc, line, done)
            }
            Policy::Implicit | Policy::Dynamic => {
                // device-held metadata: the expander reads the correct
                // (possibly packed) location directly; one flit returns
                // every co-located line
                let csi = self.engine.csi_of_group(group_of(base));
                let loc = csi.location(slot);
                let wire = self.engine.block_wire_bytes(oracle, base, csi, loc);
                let cw = self.engine.cmd_wire_bytes();
                let at_device = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Demand);
                let far_done =
                    self.far_dram.access(base + loc as u64, ReqKind::Read, at_device, false);
                let far_done = self.media_site(base + loc as u64, far_done, bw);
                // only marker-bearing lines interpret a tail on this read
                let far_done = if csi != Csi::Uncompressed {
                    self.marker_site(base + loc as u64, far_done, bw)
                } else {
                    far_done
                };
                let done = self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Demand);
                self.far_installs(base, csi, loc, line, done)
            }
            Policy::Explicit { row_opt } => {
                // host-side metadata cache over the device region: a miss
                // crosses the link twice before the demand data moves
                let (meta_addr, how) = {
                    let meta = self.meta.as_mut().expect("explicit far tier has metadata");
                    (meta.meta_addr_for(line), meta.lookup(line).1)
                };
                let actual = self.engine.csi_of_line(line);
                let mut t = now;
                if how == MetaAccess::Miss {
                    bw.meta_reads += 1;
                    self.stats.far.meta_accesses += 1;
                    let meta_wire = self.engine.meta_wire_bytes();
                    let cw = self.engine.cmd_wire_bytes();
                    let at = self.link.send_cmd(t, CMD_BYTES, cw, LinkClass::Metadata);
                    let meta_done =
                        self.far_dram.access(meta_addr, ReqKind::MetaRead, at, row_opt);
                    t = self
                        .link
                        .recv_payload(meta_done, DATA_BYTES, meta_wire, LinkClass::Metadata);
                }
                let loc = actual.location(slot);
                let wire = self.engine.block_wire_bytes(oracle, base, actual, loc);
                let cw = self.engine.cmd_wire_bytes();
                let at = self.link.send_cmd(t, CMD_BYTES, cw, LinkClass::Demand);
                let far_done =
                    self.far_dram.access(base + loc as u64, ReqKind::Read, at, false);
                // explicit metadata carries no markers: media site only
                let far_done = self.media_site(base + loc as u64, far_done, bw);
                let done = self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Demand);
                self.far_installs(base, actual, loc, line, done)
            }
            Policy::Lcp => {
                // page-table descriptor through the host-side cache: a
                // miss crosses the link for the device-resident copy
                // before the demand data moves (the tiered-explicit cost
                // story, at 8 descriptors per metadata line)
                let page = page_of_line(line);
                let pslot = (line % PAGE_LINES) as u8;
                let d = self
                    .engine
                    .as_lcp_mut()
                    .expect("lcp far tier runs the page family")
                    .ensure_desc(page, oracle);
                let desc_line = LcpLayout::desc_line_of_page(page);
                let how = self
                    .meta
                    .as_mut()
                    .expect("lcp far tier has a descriptor cache")
                    .access(desc_line, false);
                let mut t = now;
                if how == MetaAccess::Miss {
                    bw.meta_reads += 1;
                    self.stats.far.meta_accesses += 1;
                    let meta_wire = self.engine.meta_wire_bytes();
                    let cw = self.engine.cmd_wire_bytes();
                    let at = self.link.send_cmd(t, CMD_BYTES, cw, LinkClass::Metadata);
                    let meta_done = self
                        .far_dram
                        .access(FAR_META_BASE + desc_line, ReqKind::MetaRead, at, false);
                    t = self
                        .link
                        .recv_payload(meta_done, DATA_BYTES, meta_wire, LinkClass::Metadata);
                }
                // the data access at the descriptor's fixed offset: one
                // shift, never a probe; the flit carries every logical
                // co-resident of the physical line
                let page_base = page * PAGE_LINES;
                let phys = d.physical_line(page_base, pslot);
                let wire =
                    self.engine.as_lcp().unwrap().block_wire_bytes(oracle, page, pslot);
                let cw = self.engine.cmd_wire_bytes();
                let at = self.link.send_cmd(t, CMD_BYTES, cw, LinkClass::Demand);
                let far_done = self.far_dram.access(phys, ReqKind::Read, at, false);
                // fixed offsets interpret no markers: media site only
                let far_done = self.media_site(phys, far_done, bw);
                let done = self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Demand);
                let mut installs = Installs::new();
                for &s in d.coresidents(pslot).iter() {
                    installs.push(Install {
                        line_addr: page_base + s as u64,
                        level: 0,
                        prefetch: s != pslot,
                        size: 0,
                    });
                }
                self.stats.far_prefetch_installs +=
                    installs.iter().filter(|i| i.prefetch).count() as u64;
                ReadOutcome { done, installs }
            }
        }
    }

    /// Build the install list of a far packed read and count co-fetches.
    fn far_installs(&mut self, base: u64, csi: Csi, loc: u8, line: u64, done: u64) -> ReadOutcome {
        let installs = CramEngine::installs_for(base, csi, loc, line);
        self.stats.far_prefetch_installs +=
            installs.iter().filter(|i| i.prefetch).count() as u64;
        ReadOutcome { done, installs }
    }

    /// Issue the next-line prefetch access for the `NextLinePrefetch`
    /// far policy and append its install.
    fn prefetch_next(
        &mut self,
        line: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
        mut out: ReadOutcome,
    ) -> ReadOutcome {
        let pf = line + 1;
        bw.prefetch_reads += 1;
        if self.is_far_line(pf) {
            self.stats.far.prefetch_reads += 1;
            let wire = self.engine.line_wire_bytes(oracle, pf);
            let cw = self.engine.cmd_wire_bytes();
            let at = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Prefetch);
            let far_done = self.far_dram.access(pf, ReqKind::Read, at, false);
            self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Prefetch);
        } else {
            self.stats.near.prefetch_reads += 1;
            near.access(pf, ReqKind::Read, now, false);
        }
        out.installs.push(Install { line_addr: pf, level: 0, prefetch: true, size: 0 });
        out
    }

    /// Ganged writeback of one group (mirrors the controller contract).
    /// `sampled` / `gate` carry the Dynamic-CRAM sampling verdict and
    /// per-core counters for the `Dynamic` far policy (`gate` is `None`
    /// for every other composition).
    #[allow(clippy::too_many_arguments)]
    pub fn writeback(
        &mut self,
        gang: &[crate::cache::Evicted],
        now: u64,
        near: &mut DramSim,
        oracle: &mut SizeOracle,
        bw: &mut Bandwidth,
        sampled: bool,
        gate: &mut Option<DynamicCram>,
    ) {
        if gang.is_empty() {
            return;
        }
        let (base, present, dirty) = CramEngine::gang_masks(gang);
        for s in 0..4 {
            if present[s] && dirty[s] {
                oracle.dirty_update(base + s as u64);
            }
        }

        if !self.is_far_page(page_of_line(base)) {
            // near tier: plain DDR, dirty lines write back raw
            for s in 0..4 {
                if present[s] && dirty[s] {
                    bw.demand_writes += 1;
                    self.stats.near.demand_writes += 1;
                    near.access(base + s as u64, ReqKind::Write, now, false);
                }
            }
            return;
        }

        if self.policy == Policy::Lcp {
            // the page family has its own write discipline: fixed
            // offsets, exception region, device-internal recompaction
            self.writeback_far_lcp(base, present, dirty, now, bw, oracle);
            return;
        }

        if !self.far_packs() {
            // raw far tier (Uncompressed / NextLinePrefetch baselines and
            // Ideal's overhead-free writes): dirty lines cross the link raw
            self.raw_far_dirty_writes(base, present, dirty, now, bw, oracle);
            return;
        }

        // CRAM on the expander: the same residency-constrained packing
        // decision as the host-side controller (shared engine), then the
        // planned device writes / invalidates — each one a flit on the
        // link.  The Dynamic far policy gates packing exactly like the
        // flat controller: sampled groups always compress and train the
        // counters; the rest follow the owner core's gate.
        let owner_core = gang[0].core as usize;
        let compress = !self.compress_off
            && match (self.policy, gate.as_ref()) {
                (Policy::Dynamic, Some(d)) => sampled || d.enabled(owner_core),
                _ => true,
            };
        let old = self.engine.csi_of_line(base);
        if !compress && old == Csi::Uncompressed {
            // gate closed, group never packed: plain dirty far writes
            self.raw_far_dirty_writes(base, present, dirty, now, bw, oracle);
            return;
        }
        let sizes = oracle.group_sizes(base);
        let new = if compress {
            CramEngine::decide_packed_layout(old, present, sizes)
        } else {
            CramEngine::decayed_layout(old, present, dirty)
        };
        let plan = CramEngine::plan_group_write(old, new, present, dirty);
        if plan.is_empty() {
            return; // clean re-eviction of an unchanged layout: free drop
        }
        self.engine.note_group_write(new);
        for &(loc, op) in plan.iter() {
            let addr = base + loc as u64;
            match op {
                SlotOp::Invalidate => {
                    // stale under the new layout: device writes the
                    // invalid-line marker (command flit on the link)
                    bw.invalidates += 1;
                    self.stats.far.invalidates += 1;
                    if sampled {
                        if let Some(d) = gate.as_mut() {
                            d.on_cost(CramEngine::charged_core(gang, base, loc, owner_core));
                        }
                    }
                    let cw = self.engine.cmd_wire_bytes();
                    let at = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Writeback);
                    self.far_dram.access(addr, ReqKind::Invalidate, at, false);
                }
                SlotOp::WritePacked { dirty } | SlotOp::WriteSingle { dirty } => {
                    if dirty {
                        bw.demand_writes += 1;
                        self.stats.far.demand_writes += 1;
                    } else {
                        bw.clean_writes += 1;
                        self.stats.far.clean_writes += 1;
                        if sampled {
                            if let Some(d) = gate.as_mut() {
                                d.on_cost(owner_core);
                            }
                        }
                    }
                    let wire = self.engine.block_wire_bytes(oracle, base, new, loc);
                    let at = self.link.send_payload(now, DATA_BYTES, wire, LinkClass::Writeback);
                    self.far_dram.access(addr, ReqKind::Write, at, false);
                }
            }
        }
        self.engine.commit(group_of(base), new);

        // Explicit far policy: persist the CSI change to the device
        // metadata region through the host-side metadata cache.
        if new != old {
            if let Some(meta) = self.meta.as_mut() {
                let row_opt = meta.row_optimized;
                let meta_addr = meta.meta_addr_for(base);
                let before_wb = meta.writebacks;
                let how = meta.update(base, new);
                let victim_wb = meta.writebacks > before_wb;
                if how == MetaAccess::Miss {
                    // the metadata line fills the host-side cache before
                    // being updated: command flit out, device read, data
                    // flit back (same crossing the read path pays)
                    bw.meta_reads += 1;
                    self.stats.far.meta_accesses += 1;
                    let meta_wire = self.engine.meta_wire_bytes();
                    let cw = self.engine.cmd_wire_bytes();
                    let at = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Metadata);
                    let meta_done =
                        self.far_dram.access(meta_addr, ReqKind::MetaRead, at, row_opt);
                    self.link
                        .recv_payload(meta_done, DATA_BYTES, meta_wire, LinkClass::Metadata);
                }
                if victim_wb {
                    bw.meta_writes += 1;
                    self.stats.far.meta_accesses += 1;
                    let meta_wire = self.engine.meta_wire_bytes();
                    let at =
                        self.link.send_payload(now, DATA_BYTES, meta_wire, LinkClass::Metadata);
                    self.far_dram.access(meta_addr, ReqKind::MetaWrite, at, row_opt);
                }
            }
        }
    }

    /// Far writeback under the `Lcp` policy.  Every dirty line crosses
    /// the link once and lands at its page's fixed (or exception-region)
    /// offset.  Exception overflow recompacts the page *inside the
    /// expander* — far-DRAM migration-class traffic, no link flits,
    /// which is exactly the asymmetry against flat LCP (where the host
    /// performs the same move over its own channels) the tiered exhibit
    /// exists to show.  Descriptor changes persist to the device
    /// descriptor region through the host-side cache, like `Explicit`
    /// metadata.
    fn writeback_far_lcp(
        &mut self,
        base: u64,
        present: [bool; 4],
        dirty: [bool; 4],
        now: u64,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) {
        let page = page_of_line(base);
        let page_base = page * PAGE_LINES;
        for s in 0..4 {
            if !(present[s] && dirty[s]) {
                continue;
            }
            let line = base + s as u64;
            let pslot = (line % PAGE_LINES) as u8;
            let lcp = self.engine.as_lcp_mut().expect("lcp far tier runs the page family");
            let before = lcp.desc_of(page);
            let outcome = lcp.note_dirty_write(page, pslot, oracle);
            let d = lcp.desc_of(page).expect("descriptor materialized by the write");
            // the dirty data itself: one flit, one device write at the
            // post-layout offset
            bw.demand_writes += 1;
            self.stats.far.demand_writes += 1;
            let wire = self.engine.line_wire_bytes(oracle, line);
            let at = self.link.send_payload(now, DATA_BYTES, wire, LinkClass::Writeback);
            self.far_dram.access(d.physical_line(page_base, pslot), ReqKind::Write, at, false);
            if let LcpWriteOutcome::Recompacted { old_lines, new_lines } = outcome {
                // device-internal re-encode: read the old footprint,
                // write the new one, all on far DRAM — no link traffic
                for i in 0..old_lines {
                    bw.migration += 1;
                    self.stats.far.migr_accesses += 1;
                    self.far_dram.access(page_base + i, ReqKind::Read, now, false);
                }
                for i in 0..new_lines {
                    bw.migration += 1;
                    self.stats.far.migr_accesses += 1;
                    self.far_dram.access(page_base + i, ReqKind::Write, now, false);
                }
            }
            if before != Some(d) {
                // persist the changed descriptor through the host-side
                // cache: a miss fills from the device region first, a
                // dirty victim writes back — each a Metadata-class
                // link crossing
                let desc_line = LcpLayout::desc_line_of_page(page);
                let meta_addr = FAR_META_BASE + desc_line;
                let meta = self.meta.as_mut().expect("lcp far tier has a descriptor cache");
                let before_wb = meta.writebacks;
                let how = meta.access(desc_line, true);
                let victim_wb = meta.writebacks > before_wb;
                if how == MetaAccess::Miss {
                    bw.meta_reads += 1;
                    self.stats.far.meta_accesses += 1;
                    let meta_wire = self.engine.meta_wire_bytes();
                    let cw = self.engine.cmd_wire_bytes();
                    let at = self.link.send_cmd(now, CMD_BYTES, cw, LinkClass::Metadata);
                    let meta_done =
                        self.far_dram.access(meta_addr, ReqKind::MetaRead, at, false);
                    self.link
                        .recv_payload(meta_done, DATA_BYTES, meta_wire, LinkClass::Metadata);
                }
                if victim_wb {
                    bw.meta_writes += 1;
                    self.stats.far.meta_accesses += 1;
                    let meta_wire = self.engine.meta_wire_bytes();
                    let at =
                        self.link.send_payload(now, DATA_BYTES, meta_wire, LinkClass::Metadata);
                    self.far_dram.access(meta_addr, ReqKind::MetaWrite, at, false);
                }
            }
        }
    }

    /// Dirty lines of a far group written raw across the link (the
    /// uncompressed-far arms and the Dynamic closed-gate fast path share
    /// this so their accounting can never diverge).
    fn raw_far_dirty_writes(
        &mut self,
        base: u64,
        present: [bool; 4],
        dirty: [bool; 4],
        now: u64,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) {
        for s in 0..4 {
            if present[s] && dirty[s] {
                bw.demand_writes += 1;
                self.stats.far.demand_writes += 1;
                let wire = self.engine.line_wire_bytes(oracle, base + s as u64);
                let at = self.link.send_payload(now, DATA_BYTES, wire, LinkClass::Writeback);
                self.far_dram.access(base + s as u64, ReqKind::Write, at, false);
            }
        }
    }

    /// Heat-decay epoch counter (heat halves once per elapsed epoch).
    #[inline]
    fn epoch(&self) -> u32 {
        (self.accesses / self.cfg.epoch_accesses) as u32
    }

    /// Current (decayed) heat of a page.
    fn heat_of(&self, page: u64) -> u32 {
        let cur = self.epoch();
        self.heat
            .get(&page)
            .map(|&(h, ep)| h >> cur.saturating_sub(ep).min(31))
            .unwrap_or(0)
    }

    /// Record a page access: heat bookkeeping, lazy decay, promotion.
    fn touch(
        &mut self,
        page: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) {
        self.accesses += 1;
        let cur = self.epoch();
        let h = {
            let e = self.heat.entry(page).or_insert((0, cur));
            let lag = cur.saturating_sub(e.1).min(31);
            e.0 >>= lag;
            e.1 = cur;
            e.0 = e.0.saturating_add(1);
            e.0
        };
        if self.is_far_page(page) {
            if h >= self.cfg.promote_threshold {
                self.promote(page, now, near, bw, oracle);
            }
        } else if self.listed.insert(page) {
            self.near_pages.push(page);
        }
    }

    /// Move a hot far page near; demote a cold near page in exchange.
    fn promote(
        &mut self,
        page: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) {
        self.stats.promotions += 1;
        let first = page * PAGE_LINES;
        if self.engine.as_lcp().is_some() {
            self.promote_lcp_page(page, now, near, bw, oracle);
        } else {
            for g in 0..PAGE_GROUPS {
                let gbase = first + g * 4;
                // a packed group travels in fewer device reads + link flits;
                // live data sits at the non-stale physical slots (e.g. PairAb
                // lives at locs {0, 2, 3}, not 0..3).  Each block crosses the
                // link only after its device read completes, same sequencing
                // as the demand path.
                let csi = self.engine.remove(group_of(gbase)).unwrap_or_default();
                let mut arrived = now;
                for loc in 0..4u8 {
                    if csi.is_stale(loc) {
                        continue;
                    }
                    bw.migration += 1;
                    self.stats.far.migr_accesses += 1;
                    let wire = self.engine.block_wire_bytes(oracle, gbase, csi, loc);
                    let far_done =
                        self.far_dram.access(gbase + loc as u64, ReqKind::Read, now, false);
                    arrived = arrived.max(
                        self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Migration),
                    );
                }
                // lands near unpacked: four raw line fills once the data is here
                for s in 0..4 {
                    bw.migration += 1;
                    self.stats.near.migr_accesses += 1;
                    near.access(gbase + s, ReqKind::Write, arrived, false);
                }
            }
        }
        self.stats.migrated_lines += PAGE_LINES;
        self.placement.insert(page, false);
        if self.listed.insert(page) {
            self.near_pages.push(page);
        }
        if let Some(victim) = self.pick_victim(page) {
            self.demote(victim, now, near, bw, oracle);
        }
    }

    /// LCP promotion: the expander ships the page's *physical* footprint
    /// — the packed data region plus any exception lines — so a well
    /// compressed page crosses the link in far fewer device reads and
    /// flits than 64 raw lines.  The page lands near unpacked (near
    /// pages carry no layout state) and its descriptor is dropped; if
    /// the page is later demoted it re-materializes on the next far
    /// touch, same free-first-touch model as CRAM groups landing raw.
    fn promote_lcp_page(
        &mut self,
        page: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) {
        let first = page * PAGE_LINES;
        let d = self.engine.as_lcp_mut().expect("lcp promote").ensure_desc(page, oracle);
        let per_line = (DATA_BYTES / u64::from(d.target)).max(1);
        let mut arrived = now;
        // data region: one device read + one flit per physical line,
        // carrying all of that line's co-resident slots
        for i in 0..d.data_lines() {
            bw.migration += 1;
            self.stats.far.migr_accesses += 1;
            let lead = (i * per_line).min(PAGE_LINES - 1) as u8;
            let wire = self.engine.as_lcp().unwrap().block_wire_bytes(oracle, page, lead);
            let far_done = self.far_dram.access(first + i, ReqKind::Read, now, false);
            arrived = arrived
                .max(self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Migration));
        }
        // exception region: raw single-line crossings
        for s in 0..PAGE_LINES as u8 {
            if !d.is_exception(s) {
                continue;
            }
            bw.migration += 1;
            self.stats.far.migr_accesses += 1;
            let phys = d.physical_line(first, s);
            let wire = self.engine.line_wire_bytes(oracle, first + u64::from(s));
            let far_done = self.far_dram.access(phys, ReqKind::Read, now, false);
            arrived = arrived
                .max(self.link.recv_payload(far_done, DATA_BYTES, wire, LinkClass::Migration));
        }
        // lands near unpacked: 64 raw line fills once the data is here
        for l in 0..PAGE_LINES {
            bw.migration += 1;
            self.stats.near.migr_accesses += 1;
            near.access(first + l, ReqKind::Write, arrived, false);
        }
        self.engine.as_lcp_mut().unwrap().remove_page(page);
    }

    /// Coldest of a small sample of near pages (deterministic ring scan).
    /// Entries for pages demoted since they were listed are dropped as
    /// they are encountered, so the ring cannot silt up with stale pages
    /// and stop yielding victims.
    fn pick_victim(&mut self, exclude: u64) -> Option<u64> {
        let mut best: Option<(u32, u64)> = None;
        let mut scanned = 0;
        while scanned < self.cfg.victim_samples && !self.near_pages.is_empty() {
            let i = self.victim_cursor % self.near_pages.len();
            let p = self.near_pages[i];
            scanned += 1;
            if self.is_far_page(p) {
                // demoted since listing: drop (swap_remove keeps the slot
                // occupied by a fresh entry, so do not advance the cursor)
                self.near_pages.swap_remove(i);
                self.listed.remove(&p);
                continue;
            }
            self.victim_cursor = i + 1;
            if p == exclude {
                continue;
            }
            let h = self.heat_of(p);
            if best.map(|(bh, _)| h < bh).unwrap_or(true) {
                best = Some((h, p));
            }
        }
        best.map(|(_, p)| p)
    }

    /// Move a cold near page to the expander (stored raw; the far tier
    /// re-packs lazily on later writebacks).
    fn demote(
        &mut self,
        page: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
        oracle: &mut SizeOracle,
    ) {
        self.stats.demotions += 1;
        let first = page * PAGE_LINES;
        for l in 0..PAGE_LINES {
            // near read, then the line crosses the link, then the device
            // write lands — each stage waits for the one before it
            bw.migration += 1;
            self.stats.near.migr_accesses += 1;
            let read_done = near.access(first + l, ReqKind::Read, now, false);
            let wire = self.engine.line_wire_bytes(oracle, first + l);
            let at_device =
                self.link.send_payload(read_done, DATA_BYTES, wire, LinkClass::Migration);
            bw.migration += 1;
            self.stats.far.migr_accesses += 1;
            self.far_dram.access(first + l, ReqKind::Write, at_device, false);
        }
        for g in 0..PAGE_GROUPS {
            self.engine.remove(group_of(first + g * 4));
        }
        if let Some(l) = self.engine.as_lcp_mut() {
            // demoted pages land raw on the expander; the descriptor
            // re-materializes free on the next far touch
            l.remove_page(page);
        }
        self.stats.migrated_lines += PAGE_LINES;
        self.placement.insert(page, true);
        self.heat.insert(page, (0, self.epoch())); // must re-earn promotion
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Evicted;
    use crate::workloads::ValueModel;

    fn packable_oracle() -> SizeOracle {
        // all-SmallInt pages: every group packs 4:1
        SizeOracle::new(ValueModel::new([0.0, 1.0, 0.0, 0.0, 0.0], 7))
    }

    fn setup(policy: Policy) -> (TieredMemory, DramSim, SizeOracle, Bandwidth) {
        let t = TieredMemory::new(TierConfig::default(), policy);
        (t, DramSim::new(DramConfig::default()), packable_oracle(), Bandwidth::default())
    }

    fn gang(base: u64, dirty_mask: [bool; 4]) -> Vec<Evicted> {
        (0..4)
            .map(|i| Evicted {
                line_addr: base + i as u64,
                dirty: dirty_mask[i],
                level: 0,
                core: 0,
                referenced: true,
                was_prefetch: false,
            })
            .collect()
    }

    /// First line of a page currently placed in the requested tier.
    fn page_in(t: &TieredMemory, far: bool) -> u64 {
        (0..10_000u64)
            .find(|&p| t.is_far_page(p) == far)
            .expect("both tiers populated at default ratio")
            * PAGE_LINES
    }

    #[test]
    fn split_ratio_roughly_respected() {
        let t = TieredMemory::new(
            TierConfig::default().with_far_ratio(0.75),
            Policy::Uncompressed,
        );
        let far = (0..4000u64).filter(|&p| t.is_far_page(p)).count();
        let frac = far as f64 / 4000.0;
        assert!((frac - 0.75).abs() < 0.05, "far fraction {frac}");
        let none = TieredMemory::new(
            TierConfig::default().with_far_ratio(0.0),
            Policy::Uncompressed,
        );
        assert_eq!((0..1000u64).filter(|&p| none.is_far_page(p)).count(), 0);
    }

    #[test]
    fn far_read_slower_than_near_read() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Uncompressed);
        let nl = page_in(&t, false);
        let fl = page_in(&t, true);
        let rn = t.read(nl, 0, &mut near, &mut bw, &mut o);
        let rf = t.read(fl, 0, &mut near, &mut bw, &mut o);
        assert!(
            rf.done > rn.done + 2 * t.link.config().port_latency,
            "far {} vs near {}",
            rf.done,
            rn.done
        );
        assert_eq!(t.snapshot().near.demand_reads, 1);
        assert_eq!(t.snapshot().far.demand_reads, 1);
        assert_eq!(bw.demand_reads, 2);
    }

    #[test]
    fn compressed_far_read_prefetches_group() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Implicit);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        let s = t.snapshot();
        assert_eq!(s.far_groups_written, 1);
        assert_eq!(s.far_groups_packed, 1);
        let r = t.read(fl + 2, 1000, &mut near, &mut bw, &mut o);
        assert_eq!(r.installs.len(), 4, "quad block: whole group per flit");
        assert_eq!(r.installs.iter().filter(|i| i.prefetch).count(), 3);
        assert_eq!(t.snapshot().far_prefetch_installs, 3);
        // exactly one data flit came back over the link for 4 lines
        assert_eq!(t.snapshot().link.rx_flits, 1);
    }

    #[test]
    fn uncompressed_far_read_returns_single_line() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Uncompressed);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        let r = t.read(fl + 2, 1000, &mut near, &mut bw, &mut o);
        assert_eq!(r.installs.len(), 1);
    }

    #[test]
    fn tier_counters_sum_to_bandwidth_total() {
        // every policy the cross-product can place on the expander must
        // keep the accounting invariant through reads and writebacks
        for policy in [
            Policy::Uncompressed,
            Policy::Ideal,
            Policy::Implicit,
            Policy::Dynamic,
            Policy::Explicit { row_opt: false },
            Policy::NextLinePrefetch,
            Policy::Lcp,
        ] {
            let (mut t, mut near, mut o, mut bw) = setup(policy);
            let mut gate = matches!(policy, Policy::Dynamic)
                .then(|| DynamicCram::with_bits(1, 6));
            for i in 0..200u64 {
                let line = i * 37 % 4096;
                t.read(line, i * 10, &mut near, &mut bw, &mut o);
                if i % 3 == 0 {
                    t.writeback(
                        &gang(group_base(line), [true, false, i % 2 == 0, false]),
                        i * 10,
                        &mut near,
                        &mut o,
                        &mut bw,
                        i % 5 == 0,
                        &mut gate,
                    );
                }
            }
            assert_eq!(
                t.snapshot().total_accesses(),
                bw.total(),
                "{policy:?}: per-tier counters must sum to the bandwidth total"
            );
        }
    }

    #[test]
    fn hot_far_page_promotes_and_demotes_a_victim() {
        let mut cfg = TierConfig::default();
        cfg.promote_threshold = 8;
        let mut t = TieredMemory::new(cfg, Policy::Implicit);
        let mut near = DramSim::new(DramConfig::default());
        let mut o = packable_oracle();
        let mut bw = Bandwidth::default();
        let near_page = page_in(&t, false) / PAGE_LINES;
        let far_line = page_in(&t, true);
        // make a near page known (victim candidate)
        t.read(near_page * PAGE_LINES, 0, &mut near, &mut bw, &mut o);
        assert!(t.is_far_line(far_line));
        for i in 0..8u64 {
            t.read(far_line + i, i * 100, &mut near, &mut bw, &mut o);
        }
        let s = t.snapshot();
        assert_eq!(s.promotions, 1);
        assert_eq!(s.demotions, 1);
        assert_eq!(s.migrated_lines, 2 * PAGE_LINES);
        assert!(!t.is_far_line(far_line), "hot page now near");
        assert!(t.is_far_page(near_page), "cold victim now far");
        // accounting invariant holds through migrations
        assert_eq!(s.total_accesses(), bw.total());
        // further reads hit the near tier
        let before = t.snapshot().near.demand_reads;
        t.read(far_line, 10_000, &mut near, &mut bw, &mut o);
        assert_eq!(t.snapshot().near.demand_reads, before + 1);
    }

    #[test]
    fn clean_reeviction_of_packed_far_group_is_free() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Implicit);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        let total_before = bw.total();
        t.writeback(&gang(fl, [false; 4]), 100, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(bw.total(), total_before, "clean unchanged layout: no traffic");
    }

    #[test]
    fn far_expander_scheduler_folds_invalidates() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Implicit);
        let fl = page_in(&t, true);
        // packing a quad issues one block write + three stale-slot
        // invalidates on the device; they queue in the expander's
        // write queue, not on the demand path
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(t.far_dram.stats.invalidates, 3);
        assert_eq!(t.far_dram.write_queue_len(0), 4, "device writes queue");
        // a later far read drains the device queue in its bank-prep
        // shadow, folding the markers into the packed-block write
        t.read(fl, 100_000, &mut near, &mut bw, &mut o);
        assert_eq!(t.far_dram.write_queue_len(0), 0);
        assert_eq!(t.far_dram.stats.folded_invalidates, 3);
    }

    #[test]
    fn dynamic_far_policy_respects_the_gate() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Dynamic);
        let fl = page_in(&t, true);
        // open gate: packs like tiered-cram
        let mut gate = Some(DynamicCram::with_bits(1, 6));
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut gate);
        assert_eq!(t.far_csi_of(fl), Csi::Quad);
        // closed gate: a different group stays raw, dirty lines cross raw
        for _ in 0..200 {
            gate.as_mut().unwrap().on_cost(0);
        }
        let fl2 = (fl + PAGE_LINES..fl + 100 * PAGE_LINES)
            .step_by(PAGE_LINES as usize)
            .find(|&l| t.is_far_line(l))
            .unwrap();
        let writes_before = bw.demand_writes;
        t.writeback(&gang(fl2, [true; 4]), 100, &mut near, &mut o, &mut bw, false, &mut gate);
        assert_eq!(t.far_csi_of(fl2), Csi::Uncompressed, "closed gate: no new packing");
        assert_eq!(bw.demand_writes, writes_before + 4, "four raw dirty writes");
        assert_eq!(bw.clean_writes, 0);
        // clean re-eviction of the already-packed group stays free
        let total_before = bw.total();
        t.writeback(&gang(fl, [false; 4]), 200, &mut near, &mut o, &mut bw, false, &mut gate);
        assert_eq!(t.far_csi_of(fl), Csi::Quad, "packed data decays lazily");
        assert_eq!(bw.total(), total_before);
    }

    #[test]
    fn explicit_far_policy_serializes_metadata_over_the_link() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Explicit { row_opt: false });
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(t.far_csi_of(fl), Csi::Quad, "explicit far CRAM packs");
        assert_eq!(bw.meta_reads, 1, "cold metadata cache missed on the update");
        // cold-start a second tier to isolate the read path: the first
        // read misses metadata, so the demand data pays two round trips
        let (mut t2, mut near2, mut o2, mut bw2) = setup(Policy::Explicit { row_opt: false });
        let (mut t3, mut near3, mut o3, mut bw3) = setup(Policy::Implicit);
        let r_expl = t2.read(fl, 0, &mut near2, &mut bw2, &mut o2);
        let r_impl = t3.read(fl, 0, &mut near3, &mut bw3, &mut o3);
        assert_eq!(bw2.meta_reads, 1);
        assert!(
            r_expl.done > r_impl.done,
            "meta miss must serialize ahead of the far data read: {} vs {}",
            r_expl.done,
            r_impl.done
        );
        // metadata traffic lands on the far tier: invariant intact
        assert_eq!(t2.snapshot().total_accesses(), bw2.total());
        assert!(t2.snapshot().far.meta_accesses >= 1);
    }

    #[test]
    fn nextline_far_policy_pays_prefetch_flits() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::NextLinePrefetch);
        let fl = page_in(&t, true);
        let r = t.read(fl, 0, &mut near, &mut bw, &mut o);
        assert_eq!(r.installs.len(), 2);
        assert!(r.installs[1].prefetch);
        assert_eq!(bw.prefetch_reads, 1);
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn ideal_far_policy_cofetches_without_write_overheads() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Ideal);
        let fl = page_in(&t, true);
        // writes: dirty lines only, no invalidates/clean writes
        t.writeback(&gang(fl, [true, false, false, false]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(bw.demand_writes, 1);
        assert_eq!(bw.clean_writes + bw.invalidates, 0);
        // reads co-fetch the whole (compressible) group for free
        let r = t.read(fl + 1, 1000, &mut near, &mut bw, &mut o);
        assert_eq!(r.installs.len(), 4);
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    /// Drive a mixed read/writeback sequence and return (tier, bw).
    fn drive(mut t: TieredMemory) -> (TieredMemory, Bandwidth) {
        let mut near = DramSim::new(DramConfig::default());
        let mut o = packable_oracle();
        let mut bw = Bandwidth::default();
        for i in 0..300u64 {
            let line = i * 37 % 4096;
            t.read(line, i * 10, &mut near, &mut bw, &mut o);
            if i % 3 == 0 {
                t.writeback(
                    &gang(group_base(line), [true, false, i % 2 == 0, false]),
                    i * 10,
                    &mut near,
                    &mut o,
                    &mut bw,
                    false,
                    &mut None,
                );
            }
        }
        (t, bw)
    }

    #[test]
    fn raw_codec_moves_every_byte_at_full_width() {
        // LinkCodec::Raw (the default): wire == raw for every class, no
        // flits saved, no decompression stalls — the pre-codec link.
        let (t, bw) = drive(TieredMemory::new(TierConfig::default(), Policy::Implicit));
        let tr = t.snapshot().link_traffic;
        assert!(tr.raw_bytes() > 0, "the drive sequence must cross the link");
        assert_eq!(tr.raw_bytes(), tr.wire_bytes(), "raw codec never shrinks a payload");
        assert_eq!(tr.flits_saved, 0);
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn compressed_codec_shrinks_wire_bytes_on_every_class() {
        // all-SmallInt oracle: every demand / writeback / prefetch payload
        // compresses, so the wire total drops strictly below the raw total
        // while the storage-side accounting is untouched.
        for policy in [
            Policy::Implicit,
            Policy::Uncompressed,
            Policy::Explicit { row_opt: false },
            Policy::Lcp,
        ] {
            let raw = drive(TieredMemory::new(TierConfig::default(), policy));
            let lc = drive(TieredMemory::with_codec(
                TierConfig::default(),
                policy,
                32 * 1024,
                LinkCodec::Compressed,
            ));
            let (tr_raw, tr_lc) = (raw.0.snapshot().link_traffic, lc.0.snapshot().link_traffic);
            assert_eq!(
                tr_raw.raw_bytes(),
                tr_lc.raw_bytes(),
                "{policy:?}: the codec changes wire bytes, never demand"
            );
            assert!(
                tr_lc.wire_bytes() < tr_lc.raw_bytes(),
                "{policy:?}: compressible payloads must shrink on the wire"
            );
            assert!(tr_lc.flits_saved > 0, "{policy:?}");
            assert!(tr_lc.wire_bytes() <= tr_raw.wire_bytes(), "{policy:?}");
            // identical demand stream either side: storage accounting equal
            assert_eq!(raw.1.total(), lc.1.total(), "{policy:?}");
            assert_eq!(lc.0.snapshot().total_accesses(), lc.1.total(), "{policy:?}");
        }
    }

    #[test]
    fn compressed_codec_wins_latency_on_a_packed_far_read() {
        // a quad block (4×16B on the wire) serializes in 1 flit cycle
        // instead of 8; the 4-cycle decompression stop does not eat the
        // win, so the demand read completes strictly earlier
        let (mut t_raw, mut near, mut o, mut bw) = setup(Policy::Implicit);
        let mut t_lc = TieredMemory::with_codec(
            TierConfig::default(),
            Policy::Implicit,
            32 * 1024,
            LinkCodec::Compressed,
        );
        let fl = page_in(&t_raw, true);
        t_raw.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        t_lc.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        let r_raw = t_raw.read(fl, 100_000, &mut near, &mut bw, &mut o);
        let r_lc = t_lc.read(fl, 100_000, &mut near, &mut bw, &mut o);
        assert!(
            r_lc.done < r_raw.done,
            "compressed flit must land earlier: {} vs {}",
            r_lc.done,
            r_raw.done
        );
        assert_eq!(r_lc.installs.len(), 4, "codec never changes what a flit carries");
    }

    #[test]
    fn lcp_far_reads_use_fixed_offsets_and_descriptor_cache() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Lcp);
        let fl = page_in(&t, true);
        // all-SmallInt page -> T=16: the physical line carries 4
        // co-resident logical slots, all installed off one flit
        let r = t.read(fl + 2, 0, &mut near, &mut bw, &mut o);
        assert_eq!(r.installs.len(), 4);
        assert_eq!(r.installs.iter().filter(|i| i.prefetch).count(), 3);
        assert_eq!(t.snapshot().far_prefetch_installs, 3);
        // cold descriptor: one Metadata crossing, then the data flit
        assert_eq!(bw.meta_reads, 1);
        assert_eq!(t.snapshot().link.rx_flits, 2);
        // same page, different physical line: the host-side descriptor
        // cache absorbs the lookup — only the data flit returns
        let r2 = t.read(fl + 5, 1_000, &mut near, &mut bw, &mut o);
        assert_eq!(r2.installs.len(), 4);
        assert_eq!(bw.meta_reads, 1, "descriptor cached host-side");
        assert_eq!(t.snapshot().link.rx_flits, 3);
        // fixed offsets: no probes, no marker mispredicts, ever
        assert_eq!(bw.second_reads, 0);
        assert!(t.capacity_snapshot().is_some(), "the page family reports capacity");
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn lcp_far_writeback_persists_the_descriptor() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Lcp);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true, false, false, false]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        // one dirty line: one Writeback flit + device write at the fixed
        // offset; the freshly materialized descriptor fills the host
        // cache from the device region (Metadata crossing) and dirties it
        assert_eq!(bw.demand_writes, 1);
        assert_eq!(t.snapshot().far.demand_writes, 1);
        assert_eq!(bw.meta_reads, 1);
        assert_eq!(t.snapshot().far.meta_accesses, 1);
        assert_eq!(t.snapshot().link.tx_flits, 2, "data flit + descriptor-fill cmd");
        assert_eq!(t.snapshot().link.rx_flits, 1, "descriptor line comes back once");
        // same line again: layout unchanged, so no new descriptor traffic
        t.writeback(&gang(fl, [true, false, false, false]), 100, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(bw.demand_writes, 2);
        assert_eq!(bw.meta_reads, 1, "unchanged descriptor persists nothing");
        // clean re-eviction is free, exactly like the group family
        let total = bw.total();
        t.writeback(&gang(fl, [false; 4]), 200, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(bw.total(), total);
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn lcp_far_exception_overflow_recompacts_inside_the_expander() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Lcp);
        let fl = page_in(&t, true);
        // materialize the page at T=16 from the all-SmallInt oracle
        t.read(fl, 0, &mut near, &mut bw, &mut o);
        assert_eq!(t.capacity_snapshot().unwrap().recompactions, 0);
        let (tx0, rx0) = (t.snapshot().link.tx_flits, t.snapshot().link.rx_flits);
        // the page turns incompressible one dirty line at a time: the
        // first 8 land in the exception region, the 9th overflows it
        let mut inc = SizeOracle::new(ValueModel::new([0.0, 0.0, 0.0, 0.0, 1.0], 11));
        for k in 0..9u64 {
            t.writeback(
                &gang(fl + 4 * k, [true, false, false, false]),
                1_000 + k * 100,
                &mut near,
                &mut inc,
                &mut bw,
                false,
                &mut None,
            );
        }
        let cap = t.capacity_snapshot().unwrap();
        assert_eq!(cap.recompactions, 1);
        assert_eq!(cap.exception_lines, 0, "recompacted page is raw: no exceptions");
        // the re-encode read the old footprint (16 data + 8 exception
        // lines) and wrote 64 raw lines, all inside the expander
        assert_eq!(bw.migration, 24 + 64);
        assert_eq!(t.snapshot().far.migr_accesses, 24 + 64);
        // ...and crossed the link zero times: the TX side carried exactly
        // the 9 dirty-data flits, the RX side nothing new (the descriptor
        // stayed hot in the host cache from the read)
        assert_eq!(t.snapshot().link.tx_flits, tx0 + 9);
        assert_eq!(t.snapshot().link.rx_flits, rx0);
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn disarmed_fault_leaves_tier_bit_identical() {
        // the default FaultConfig has every rate at zero: set_fault must
        // install nothing and the run must be bit-identical, not merely
        // statistically equivalent
        let (plain, bw_plain) = drive(TieredMemory::new(TierConfig::default(), Policy::Implicit));
        let mut armed = TieredMemory::new(TierConfig::default(), Policy::Implicit);
        armed.set_fault(&FaultConfig::default(), 42);
        let (armed, bw_armed) = drive(armed);
        assert_eq!(plain.snapshot(), armed.snapshot());
        assert_eq!(bw_plain, bw_armed);
        assert!(armed.rel().is_zero());
    }

    #[test]
    fn packed_far_read_marker_errors_detected_and_cured() {
        let mut t = TieredMemory::new(TierConfig::default(), Policy::Implicit);
        t.set_fault(&FaultConfig { marker_ber: 1.0, ..FaultConfig::default() }, 9);
        let mut near = DramSim::new(DramConfig::default());
        let mut o = packable_oracle();
        let mut bw = Bandwidth::default();
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        // certain corruption: the packed read detects the bad tail against
        // the device-held layout and cures it with one serialized re-read
        let clean_done = {
            let mut c = TieredMemory::new(TierConfig::default(), Policy::Implicit);
            let mut cb = Bandwidth::default();
            c.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut cb, false, &mut None);
            c.read(fl, 100_000, &mut near, &mut cb, &mut o).done
        };
        let r = t.read(fl, 100_000, &mut near, &mut bw, &mut o);
        assert!(r.done > clean_done, "the cure re-read must cost time");
        assert_eq!(r.installs.len(), 4, "the cured read still returns the block");
        let rel = t.rel();
        assert_eq!(rel.marker_errors, 1);
        assert_eq!(rel.marker_detected, 1, "no corruption goes unflagged");
        assert_eq!(rel.silent_misreads, 0);
        assert_eq!(bw.second_reads, 1);
        assert_eq!(t.snapshot().far.second_reads, 1);
        // threshold detections re-key the device markers
        for i in 0..15u64 {
            t.read(fl, 200_000 + i * 1_000, &mut near, &mut bw, &mut o);
        }
        assert_eq!(t.rel().marker_errors, 16);
        assert_eq!(t.rel().rekeys, 1);
        assert_eq!(t.rel().detection_coverage(), Some(1.0));
        assert_eq!(t.snapshot().total_accesses(), bw.total(), "invariant under injection");
    }

    #[test]
    fn far_media_errors_cost_one_verify_reread() {
        let mut t = TieredMemory::new(TierConfig::default(), Policy::Uncompressed);
        t.set_fault(&FaultConfig { media_ber: 1.0, ..FaultConfig::default() }, 11);
        let mut near = DramSim::new(DramConfig::default());
        let mut o = packable_oracle();
        let mut bw = Bandwidth::default();
        let fl = page_in(&t, true);
        let r = t.read(fl, 0, &mut near, &mut bw, &mut o);
        let mut clean = TieredMemory::new(TierConfig::default(), Policy::Uncompressed);
        let mut cb = Bandwidth::default();
        let rc = clean.read(fl, 0, &mut near, &mut cb, &mut o);
        assert!(r.done > rc.done, "media retry serializes: {} vs {}", r.done, rc.done);
        assert_eq!(t.rel().media_errors, 1);
        assert_eq!(bw.second_reads, 1);
        assert_eq!(t.snapshot().far.second_reads, 1);
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn compress_off_degradation_stops_new_packing() {
        let (mut t, mut near, mut o, mut bw) = setup(Policy::Implicit);
        t.set_degraded(true, true);
        let fl = page_in(&t, true);
        let writes_before = bw.demand_writes;
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(t.far_csi_of(fl), Csi::Uncompressed, "degraded tier must not pack");
        assert_eq!(bw.demand_writes, writes_before + 4, "four raw dirty writes");
        assert_eq!(bw.invalidates + bw.clean_writes, 0);
        // re-arming restores packing for later writebacks
        t.set_degraded(false, false);
        t.writeback(&gang(fl, [true; 4]), 1_000, &mut near, &mut o, &mut bw, false, &mut None);
        assert_eq!(t.far_csi_of(fl), Csi::Quad);
    }

    #[test]
    fn far_layout_decision_comes_from_the_shared_engine() {
        // the tier consumes CramEngine::decide_packed_layout — same
        // semantics as the host controller, one implementation
        assert_eq!(
            CramEngine::decide_packed_layout(Csi::Uncompressed, [true; 4], [9, 9, 9, 9]),
            Csi::Quad
        );
        assert_eq!(
            CramEngine::decide_packed_layout(Csi::PairCd, [true, true, false, false], [9, 9, 64, 64]),
            Csi::PairBoth
        );
        assert_eq!(
            CramEngine::decide_packed_layout(Csi::Quad, [true; 4], [64, 64, 64, 64]),
            Csi::Uncompressed
        );
    }
}
