//! The tiered-memory front-end: near DDR + far CXL expander.
//!
//! Routes line addresses to the near tier (the host's DDR4 channels,
//! always uncompressed) or the far tier (expander-internal DRAM behind a
//! [`CxlLink`]), runs a hot-page promotion / cold-page demotion policy,
//! and — when the far tier is CRAM-compressed — keeps the expander's
//! group layouts so packed far reads deliver co-located lines in a single
//! link flit.
//!
//! **Placement.**  Pages default to near/far by a deterministic hash
//! against `far_ratio` (the capacity split: `far_ratio` = fraction of
//! capacity on the expander), first-touch-style.  The migration policy
//! overrides the default per page: a far page whose access counter
//! crosses `promote_threshold` is promoted, and a cold near page is
//! demoted in exchange to preserve the split.  Counters decay by halving
//! every `epoch_accesses` accesses.
//!
//! **Far-tier CRAM.**  The expander runs its own CRAM engine with
//! device-held metadata (IBEX-style): layouts live next to the data, so
//! there is no host-side predictor and no second-probe traffic — the
//! device always reads the right location.  What the host *does* pay is
//! the link: one 64-byte flit per far access.  Compression earns its keep
//! there — a packed block moves up to four lines per flit, cutting
//! demand flits on the narrow link, and packed pages migrate in fewer
//! flits too.  Demoted pages land raw and are re-packed lazily by later
//! writebacks (the migration engine moves data, not compressibility
//! analysis).
//!
//! **Scheduling.**  The expander's device DRAM is a [`DramSim`] like the
//! host's, so it runs the same per-channel FR-FCFS transaction scheduler
//! ([`crate::dram::sched`]): device-side write drains (including packed
//! writebacks and stale-slot invalidates, which fold into drains) queue
//! behind the same watermark hysteresis, and device queueing shows up in
//! the far-read tail.  [`TierConfig::far_dram`]`.sched` carries the
//! expander's knobs; `SimConfig::with_sched` sets host and device alike.
//!
//! Every access is charged to exactly one tier, so
//! `TierStats::total_accesses() == Bandwidth::total()` for a tiered run —
//! the subsystem's accounting invariant (checked in tests).

use std::collections::{HashMap, HashSet};

use crate::controller::{Install, Installs, ReadOutcome};
use crate::cram::group::Csi;
use crate::dram::{DramConfig, DramSim, ReqKind};
use crate::mem::{group_base, group_of, page_of_line, PagedArena};
use crate::stats::{Bandwidth, TierStats};
use crate::tier::link::{CxlLink, CxlLinkConfig, CMD_BYTES, DATA_BYTES};
use crate::util::rng::splitmix64;
use crate::workloads::SizeOracle;

/// Lines per 4KB page.
const PAGE_LINES: u64 = 64;
/// Groups per page.
const PAGE_GROUPS: u64 = PAGE_LINES / 4;

/// Tiered-memory configuration.
#[derive(Clone, Copy, Debug)]
pub struct TierConfig {
    /// Fraction of capacity (pages) placed on the far tier by default.
    pub far_ratio: f64,
    pub link: CxlLinkConfig,
    /// Expander-internal DRAM (default: a single channel).
    pub far_dram: DramConfig,
    /// Accesses to a far page before it is promoted near.
    pub promote_threshold: u32,
    /// Heat counters halve every this many accesses.
    pub epoch_accesses: u64,
    /// Near pages sampled when picking a demotion victim.
    pub victim_samples: usize,
    /// Placement-hash seed.
    pub seed: u64,
}

impl Default for TierConfig {
    fn default() -> Self {
        Self {
            far_ratio: 0.5,
            link: CxlLinkConfig::default(),
            far_dram: DramConfig::default().with_channels(1),
            // Promotion is reserved for *sustained* heat: the threshold
            // sits above the ~64 touches a streaming pass leaves on a
            // page, and the decay epoch is short enough that heat from a
            // single pass evaporates before a second pass tops it up.
            // Pages a stream merely traverses stay far (a one-time
            // migration storm would just move the stream off the link it
            // is supposed to stress); pages re-touched heavily between
            // decays promote.
            promote_threshold: 96,
            epoch_accesses: 100_000,
            victim_samples: 8,
            seed: 0x7153,
        }
    }
}

impl TierConfig {
    pub fn with_far_ratio(mut self, r: f64) -> Self {
        self.far_ratio = r.clamp(0.0, 1.0);
        self
    }
}

/// The two-tier memory behind the controller.
pub struct TieredMemory {
    cfg: TierConfig,
    far_compressed: bool,
    /// Placement-hash cutoff: page is far iff hash % 4096 < far_cut.
    far_cut: u64,
    pub link: CxlLink,
    pub far_dram: DramSim,
    /// Far-tier group layouts by group index (expander-held metadata) —
    /// paged arena, no hashing on the demand path.
    far_csi: PagedArena<Csi>,
    /// Per-page placement overrides from migration (true = far).
    placement: HashMap<u64, bool>,
    /// Per-page access heat with the epoch it was last updated.  Decay is
    /// lazy — applied when an entry is next touched or read — so no
    /// stop-the-world sweep ever runs on the demand path.
    heat: HashMap<u64, (u32, u32)>,
    /// Near pages eligible as demotion victims (dedup + ring order).
    listed: HashSet<u64>,
    near_pages: Vec<u64>,
    victim_cursor: usize,
    accesses: u64,
    stats: TierStats,
}

impl TieredMemory {
    pub fn new(cfg: TierConfig, far_compressed: bool) -> Self {
        Self {
            far_cut: (cfg.far_ratio.clamp(0.0, 1.0) * 4096.0) as u64,
            link: CxlLink::new(cfg.link),
            far_dram: DramSim::new(cfg.far_dram),
            far_csi: PagedArena::new(Csi::Uncompressed),
            placement: HashMap::new(),
            heat: HashMap::new(),
            listed: HashSet::new(),
            near_pages: Vec::new(),
            victim_cursor: 0,
            accesses: 0,
            stats: TierStats::default(),
            cfg,
            far_compressed,
        }
    }

    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    pub fn far_compressed(&self) -> bool {
        self.far_compressed
    }

    /// Current placement of a page (override, else the capacity-split hash).
    pub fn is_far_page(&self, page: u64) -> bool {
        match self.placement.get(&page) {
            Some(&far) => far,
            None => splitmix64(self.cfg.seed ^ 0x7165_72, page) % 4096 < self.far_cut,
        }
    }

    /// Current placement of a line.
    pub fn is_far_line(&self, line: u64) -> bool {
        self.is_far_page(page_of_line(line))
    }

    /// Stats snapshot with the link counters folded in.
    pub fn snapshot(&self) -> TierStats {
        let mut s = self.stats;
        s.link = self.link.stats;
        s
    }

    /// Demand read of `line` at bus-cycle `now`.  `near` is the host DDR.
    pub fn read(
        &mut self,
        line: u64,
        now: u64,
        near: &mut DramSim,
        bw: &mut Bandwidth,
    ) -> ReadOutcome {
        let page = page_of_line(line);
        self.touch(page, now, near, bw);
        if !self.is_far_page(page) {
            bw.demand_reads += 1;
            self.stats.near.demand_reads += 1;
            let done = near.access(line, ReqKind::Read, now, false);
            return ReadOutcome {
                done,
                installs: Installs::of(&[Install {
                    line_addr: line,
                    level: 0,
                    prefetch: false,
                    size: 0,
                }]),
            };
        }
        bw.demand_reads += 1;
        self.stats.far.demand_reads += 1;
        // request flit out, device access, completion flit back
        let at_device = self.link.send(now, CMD_BYTES);
        if !self.far_compressed {
            let far_done = self.far_dram.access(line, ReqKind::Read, at_device, false);
            let done = self.link.recv(far_done, DATA_BYTES);
            return ReadOutcome {
                done,
                installs: Installs::of(&[Install {
                    line_addr: line,
                    level: 0,
                    prefetch: false,
                    size: 0,
                }]),
            };
        }
        // device-held metadata: the expander reads the correct (possibly
        // packed) location directly; one flit returns every co-located line
        let base = group_base(line);
        let slot = (line - base) as u8;
        let csi = self.far_csi.copied_or_default(group_of(base));
        let loc = csi.location(slot);
        let far_done = self.far_dram.access(base + loc as u64, ReqKind::Read, at_device, false);
        let done = self.link.recv(far_done, DATA_BYTES);
        let mut installs = Installs::new();
        for &s in csi.colocated(loc) {
            let la = base + s as u64;
            let prefetch = la != line;
            if prefetch {
                self.stats.far_prefetch_installs += 1;
            }
            // size stays 0 here: when the LLC is compressed the
            // controller's read wrapper stamps hybrid sizes on every
            // install, including these far co-fetches
            installs.push(Install { line_addr: la, level: csi.level_of(s), prefetch, size: 0 });
        }
        debug_assert!(installs.iter().any(|i| i.line_addr == line));
        ReadOutcome { done, installs }
    }

    /// Ganged writeback of one group (mirrors the controller contract).
    pub fn writeback(
        &mut self,
        gang: &[crate::cache::Evicted],
        now: u64,
        near: &mut DramSim,
        oracle: &mut SizeOracle,
        bw: &mut Bandwidth,
    ) {
        if gang.is_empty() {
            return;
        }
        let (base, present, dirty) = crate::controller::gang_masks(gang);
        for s in 0..4 {
            if present[s] && dirty[s] {
                oracle.dirty_update(base + s as u64);
            }
        }

        if !self.is_far_page(page_of_line(base)) {
            // near tier: plain DDR, dirty lines write back raw
            for s in 0..4 {
                if present[s] && dirty[s] {
                    bw.demand_writes += 1;
                    self.stats.near.demand_writes += 1;
                    near.access(base + s as u64, ReqKind::Write, now, false);
                }
            }
            return;
        }

        if !self.far_compressed {
            for s in 0..4 {
                if present[s] && dirty[s] {
                    bw.demand_writes += 1;
                    self.stats.far.demand_writes += 1;
                    let at = self.link.send(now, DATA_BYTES);
                    self.far_dram.access(base + s as u64, ReqKind::Write, at, false);
                }
            }
            return;
        }

        // CRAM on the expander: the same residency-constrained packing
        // decision as the host-side controller (shared helper; the far
        // engine always compresses — no Dynamic gating, the link is
        // always the bottleneck it is sized against), then issue device
        // writes / invalidates — each one a flit on the link.
        let old = self.far_csi.copied_or_default(group_of(base));
        let sizes = oracle.group_sizes(base);
        let new = crate::controller::decide_packed_layout(old, present, sizes);

        if new == old && !dirty.iter().any(|&d| d) {
            return; // clean re-eviction of an unchanged layout: free drop
        }
        self.stats.far_groups_written += 1;
        if new != Csi::Uncompressed {
            self.stats.far_groups_packed += 1;
        }
        for loc in 0..4u8 {
            let addr = base + loc as u64;
            let old_res = old.colocated(loc);
            let new_res = new.colocated(loc);
            if new_res.is_empty() {
                if !old_res.is_empty() {
                    // stale under the new layout: device writes the
                    // invalid-line marker (command flit on the link)
                    bw.invalidates += 1;
                    self.stats.far.invalidates += 1;
                    let at = self.link.send(now, CMD_BYTES);
                    self.far_dram.access(addr, ReqKind::Invalidate, at, false);
                }
                continue;
            }
            if new_res.len() > 1 {
                let any_dirty = new_res.iter().any(|&s| dirty[s as usize]);
                if !any_dirty && crate::controller::layout_half_same(old, new, loc) {
                    continue; // packed block already in device memory
                }
                if any_dirty {
                    bw.demand_writes += 1;
                    self.stats.far.demand_writes += 1;
                } else {
                    bw.clean_writes += 1;
                    self.stats.far.clean_writes += 1;
                }
                let at = self.link.send(now, DATA_BYTES);
                self.far_dram.access(addr, ReqKind::Write, at, false);
            } else {
                let s = new_res[0] as usize;
                let relocated = old.location(s as u8) != loc || old.colocated(loc).len() > 1;
                if dirty[s] {
                    bw.demand_writes += 1;
                    self.stats.far.demand_writes += 1;
                    let at = self.link.send(now, DATA_BYTES);
                    self.far_dram.access(addr, ReqKind::Write, at, false);
                } else if relocated && present[s] {
                    bw.clean_writes += 1;
                    self.stats.far.clean_writes += 1;
                    let at = self.link.send(now, DATA_BYTES);
                    self.far_dram.access(addr, ReqKind::Write, at, false);
                }
            }
        }
        if new == Csi::Uncompressed {
            self.far_csi.remove(group_of(base));
        } else {
            self.far_csi.insert(group_of(base), new);
        }
    }

    /// Heat-decay epoch counter (heat halves once per elapsed epoch).
    #[inline]
    fn epoch(&self) -> u32 {
        (self.accesses / self.cfg.epoch_accesses) as u32
    }

    /// Current (decayed) heat of a page.
    fn heat_of(&self, page: u64) -> u32 {
        let cur = self.epoch();
        self.heat
            .get(&page)
            .map(|&(h, ep)| h >> cur.saturating_sub(ep).min(31))
            .unwrap_or(0)
    }

    /// Record a page access: heat bookkeeping, lazy decay, promotion.
    fn touch(&mut self, page: u64, now: u64, near: &mut DramSim, bw: &mut Bandwidth) {
        self.accesses += 1;
        let cur = self.epoch();
        let h = {
            let e = self.heat.entry(page).or_insert((0, cur));
            let lag = cur.saturating_sub(e.1).min(31);
            e.0 >>= lag;
            e.1 = cur;
            e.0 = e.0.saturating_add(1);
            e.0
        };
        if self.is_far_page(page) {
            if h >= self.cfg.promote_threshold {
                self.promote(page, now, near, bw);
            }
        } else if self.listed.insert(page) {
            self.near_pages.push(page);
        }
    }

    /// Move a hot far page near; demote a cold near page in exchange.
    fn promote(&mut self, page: u64, now: u64, near: &mut DramSim, bw: &mut Bandwidth) {
        self.stats.promotions += 1;
        let first = page * PAGE_LINES;
        for g in 0..PAGE_GROUPS {
            let gbase = first + g * 4;
            // a packed group travels in fewer device reads + link flits;
            // live data sits at the non-stale physical slots (e.g. PairAb
            // lives at locs {0, 2, 3}, not 0..3).  Each block crosses the
            // link only after its device read completes, same sequencing
            // as the demand path.
            let csi = self.far_csi.remove(group_of(gbase)).unwrap_or_default();
            let mut arrived = now;
            for loc in 0..4u8 {
                if csi.is_stale(loc) {
                    continue;
                }
                bw.migration += 1;
                self.stats.far.migr_accesses += 1;
                let far_done =
                    self.far_dram.access(gbase + loc as u64, ReqKind::Read, now, false);
                arrived = arrived.max(self.link.recv(far_done, DATA_BYTES));
            }
            // lands near unpacked: four raw line fills once the data is here
            for s in 0..4 {
                bw.migration += 1;
                self.stats.near.migr_accesses += 1;
                near.access(gbase + s, ReqKind::Write, arrived, false);
            }
        }
        self.stats.migrated_lines += PAGE_LINES;
        self.placement.insert(page, false);
        if self.listed.insert(page) {
            self.near_pages.push(page);
        }
        if let Some(victim) = self.pick_victim(page) {
            self.demote(victim, now, near, bw);
        }
    }

    /// Coldest of a small sample of near pages (deterministic ring scan).
    /// Entries for pages demoted since they were listed are dropped as
    /// they are encountered, so the ring cannot silt up with stale pages
    /// and stop yielding victims.
    fn pick_victim(&mut self, exclude: u64) -> Option<u64> {
        let mut best: Option<(u32, u64)> = None;
        let mut scanned = 0;
        while scanned < self.cfg.victim_samples && !self.near_pages.is_empty() {
            let i = self.victim_cursor % self.near_pages.len();
            let p = self.near_pages[i];
            scanned += 1;
            if self.is_far_page(p) {
                // demoted since listing: drop (swap_remove keeps the slot
                // occupied by a fresh entry, so do not advance the cursor)
                self.near_pages.swap_remove(i);
                self.listed.remove(&p);
                continue;
            }
            self.victim_cursor = i + 1;
            if p == exclude {
                continue;
            }
            let h = self.heat_of(p);
            if best.map(|(bh, _)| h < bh).unwrap_or(true) {
                best = Some((h, p));
            }
        }
        best.map(|(_, p)| p)
    }

    /// Move a cold near page to the expander (stored raw; the far tier
    /// re-packs lazily on later writebacks).
    fn demote(&mut self, page: u64, now: u64, near: &mut DramSim, bw: &mut Bandwidth) {
        self.stats.demotions += 1;
        let first = page * PAGE_LINES;
        for l in 0..PAGE_LINES {
            // near read, then the line crosses the link, then the device
            // write lands — each stage waits for the one before it
            bw.migration += 1;
            self.stats.near.migr_accesses += 1;
            let read_done = near.access(first + l, ReqKind::Read, now, false);
            let at_device = self.link.send(read_done, DATA_BYTES);
            bw.migration += 1;
            self.stats.far.migr_accesses += 1;
            self.far_dram.access(first + l, ReqKind::Write, at_device, false);
        }
        for g in 0..PAGE_GROUPS {
            self.far_csi.remove(group_of(first + g * 4));
        }
        self.stats.migrated_lines += PAGE_LINES;
        self.placement.insert(page, true);
        self.heat.insert(page, (0, self.epoch())); // must re-earn promotion
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Evicted;
    use crate::workloads::ValueModel;

    fn packable_oracle() -> SizeOracle {
        // all-SmallInt pages: every group packs 4:1
        SizeOracle::new(ValueModel::new([0.0, 1.0, 0.0, 0.0, 0.0], 7))
    }

    fn setup(far_compressed: bool) -> (TieredMemory, DramSim, SizeOracle, Bandwidth) {
        let t = TieredMemory::new(TierConfig::default(), far_compressed);
        (t, DramSim::new(DramConfig::default()), packable_oracle(), Bandwidth::default())
    }

    fn gang(base: u64, dirty_mask: [bool; 4]) -> Vec<Evicted> {
        (0..4)
            .map(|i| Evicted {
                line_addr: base + i as u64,
                dirty: dirty_mask[i],
                level: 0,
                core: 0,
                referenced: true,
                was_prefetch: false,
            })
            .collect()
    }

    /// First line of a page currently placed in the requested tier.
    fn page_in(t: &TieredMemory, far: bool) -> u64 {
        (0..10_000u64)
            .find(|&p| t.is_far_page(p) == far)
            .expect("both tiers populated at default ratio")
            * PAGE_LINES
    }

    #[test]
    fn split_ratio_roughly_respected() {
        let t = TieredMemory::new(TierConfig::default().with_far_ratio(0.75), false);
        let far = (0..4000u64).filter(|&p| t.is_far_page(p)).count();
        let frac = far as f64 / 4000.0;
        assert!((frac - 0.75).abs() < 0.05, "far fraction {frac}");
        let none = TieredMemory::new(TierConfig::default().with_far_ratio(0.0), false);
        assert_eq!((0..1000u64).filter(|&p| none.is_far_page(p)).count(), 0);
    }

    #[test]
    fn far_read_slower_than_near_read() {
        let (mut t, mut near, _o, mut bw) = setup(false);
        let nl = page_in(&t, false);
        let fl = page_in(&t, true);
        let rn = t.read(nl, 0, &mut near, &mut bw);
        let rf = t.read(fl, 0, &mut near, &mut bw);
        assert!(
            rf.done > rn.done + 2 * t.link.config().port_latency,
            "far {} vs near {}",
            rf.done,
            rn.done
        );
        assert_eq!(t.snapshot().near.demand_reads, 1);
        assert_eq!(t.snapshot().far.demand_reads, 1);
        assert_eq!(bw.demand_reads, 2);
    }

    #[test]
    fn compressed_far_read_prefetches_group() {
        let (mut t, mut near, mut o, mut bw) = setup(true);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw);
        let s = t.snapshot();
        assert_eq!(s.far_groups_written, 1);
        assert_eq!(s.far_groups_packed, 1);
        let r = t.read(fl + 2, 1000, &mut near, &mut bw);
        assert_eq!(r.installs.len(), 4, "quad block: whole group per flit");
        assert_eq!(r.installs.iter().filter(|i| i.prefetch).count(), 3);
        assert_eq!(t.snapshot().far_prefetch_installs, 3);
        // exactly one data flit came back over the link for 4 lines
        assert_eq!(t.snapshot().link.rx_flits, 1);
    }

    #[test]
    fn uncompressed_far_read_returns_single_line() {
        let (mut t, mut near, mut o, mut bw) = setup(false);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw);
        let r = t.read(fl + 2, 1000, &mut near, &mut bw);
        assert_eq!(r.installs.len(), 1);
    }

    #[test]
    fn tier_counters_sum_to_bandwidth_total() {
        let (mut t, mut near, mut o, mut bw) = setup(true);
        for i in 0..200u64 {
            let line = i * 37 % 4096;
            t.read(line, i * 10, &mut near, &mut bw);
            if i % 3 == 0 {
                t.writeback(
                    &gang(group_base(line), [true, false, i % 2 == 0, false]),
                    i * 10,
                    &mut near,
                    &mut o,
                    &mut bw,
                );
            }
        }
        assert_eq!(t.snapshot().total_accesses(), bw.total());
    }

    #[test]
    fn hot_far_page_promotes_and_demotes_a_victim() {
        let mut cfg = TierConfig::default();
        cfg.promote_threshold = 8;
        let mut t = TieredMemory::new(cfg, true);
        let mut near = DramSim::new(DramConfig::default());
        let mut bw = Bandwidth::default();
        let near_page = page_in(&t, false) / PAGE_LINES;
        let far_line = page_in(&t, true);
        // make a near page known (victim candidate)
        t.read(near_page * PAGE_LINES, 0, &mut near, &mut bw);
        assert!(t.is_far_line(far_line));
        for i in 0..8u64 {
            t.read(far_line + i, i * 100, &mut near, &mut bw);
        }
        let s = t.snapshot();
        assert_eq!(s.promotions, 1);
        assert_eq!(s.demotions, 1);
        assert_eq!(s.migrated_lines, 2 * PAGE_LINES);
        assert!(!t.is_far_line(far_line), "hot page now near");
        assert!(t.is_far_page(near_page), "cold victim now far");
        // accounting invariant holds through migrations
        assert_eq!(s.total_accesses(), bw.total());
        // further reads hit the near tier
        let before = t.snapshot().near.demand_reads;
        t.read(far_line, 10_000, &mut near, &mut bw);
        assert_eq!(t.snapshot().near.demand_reads, before + 1);
    }

    #[test]
    fn clean_reeviction_of_packed_far_group_is_free() {
        let (mut t, mut near, mut o, mut bw) = setup(true);
        let fl = page_in(&t, true);
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw);
        let total_before = bw.total();
        t.writeback(&gang(fl, [false; 4]), 100, &mut near, &mut o, &mut bw);
        assert_eq!(bw.total(), total_before, "clean unchanged layout: no traffic");
    }

    #[test]
    fn far_expander_scheduler_folds_invalidates() {
        let (mut t, mut near, mut o, mut bw) = setup(true);
        let fl = page_in(&t, true);
        // packing a quad issues one block write + three stale-slot
        // invalidates on the device; they queue in the expander's
        // write queue, not on the demand path
        t.writeback(&gang(fl, [true; 4]), 0, &mut near, &mut o, &mut bw);
        assert_eq!(t.far_dram.stats.invalidates, 3);
        assert_eq!(t.far_dram.write_queue_len(0), 4, "device writes queue");
        // a later far read drains the device queue in its bank-prep
        // shadow, folding the markers into the packed-block write
        t.read(fl, 100_000, &mut near, &mut bw);
        assert_eq!(t.far_dram.write_queue_len(0), 0);
        assert_eq!(t.far_dram.stats.folded_invalidates, 3);
    }

    #[test]
    fn far_layout_decision_matches_controller_semantics() {
        use crate::controller::decide_packed_layout;
        // quad packs when everything fits
        assert_eq!(
            decide_packed_layout(Csi::Uncompressed, [true; 4], [9, 9, 9, 9]),
            Csi::Quad
        );
        // absent half keeps its old packed arrangement
        assert_eq!(
            decide_packed_layout(Csi::PairCd, [true, true, false, false], [9, 9, 64, 64]),
            Csi::PairBoth
        );
        // nothing fits: unpack
        assert_eq!(
            decide_packed_layout(Csi::Quad, [true; 4], [64, 64, 64, 64]),
            Csi::Uncompressed
        );
    }
}
