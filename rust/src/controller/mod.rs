//! Memory-controller designs: CRAM and every baseline the paper
//! evaluates, as **compositions** of a compression [`Policy`] and a
//! [`Placement`] (see [`policy`]).
//!
//! The module is layered:
//!
//! * [`policy`] — the design space: `Policy` × `Placement`, the
//!   [`Design`] compatibility facade, name round-trips;
//! * [`layout`] — the [`LayoutEngine`] seam: enum dispatch over the two
//!   layout families so every executor talks to one layout authority;
//! * [`engine`] — the group family, the shared [`CramEngine`]:
//!   group-layout state, packing/unpacking decisions, slot-level write
//!   plans, install recovery and probe order — one implementation
//!   consumed by the flat host path, the far-tier expander, and the
//!   byte-accurate store;
//! * [`lcp`] — the page family, [`LcpLayout`]: per-page compression
//!   targets, predictable slot offsets, exception regions and
//!   page-overflow recompaction, with page-table-resident descriptors;
//! * [`host`] — the flat host path: per-policy read/writeback issue and
//!   accounting over the host DDR channels;
//! * [`crate::tier::memory`] — the tiered executor: the same engine
//!   instantiated on the far expander, behind the CXL link.
//!
//! One [`MemoryController`] front-ends all designs, so the read/
//! writeback contract — group-layout transitions, marker-implied
//! verification, LLP prediction walks, metadata traffic, Dynamic-CRAM
//! gating — shares one audited implementation per layer.
//!
//! | design name | composition | paper reference |
//! |---|---|---|
//! | `uncompressed` | `None × Flat` | baseline of every figure |
//! | `ideal` | `Ideal × Flat` | Fig. 3/16 (benefits, no overheads) |
//! | `cram-explicit[-rowopt]` | `Explicit × Flat` | Fig. 7/8/12/20 |
//! | `cram-static` | `Implicit × Flat` | Fig. 12/15/16 |
//! | `cram-dynamic` | `Dynamic × Flat` | Fig. 16/18/19 |
//! | `nextline-prefetch` | `NextLinePrefetch × Flat` | Table V |
//! | `tiered-uncomp` / `tiered-cram` | `None`/`Implicit` `× Tiered` | Figure T1 |
//! | `tiered-cram-dyn` | `Dynamic × Tiered` | Figure X1 (IBEX-style gated expander) |
//! | `tiered-explicit` | `Explicit × Tiered` | Figure X1 (explicit metadata on far memory) |
//! | `lcp` / `tiered-lcp` | `Lcp × Flat`/`Tiered` | Figure P1 (page-granular LCP layout family) |
//! | `<any>+lc` | `… × … × LinkCodec::Compressed` | Figure L1 (flit compression on the CXL link) |
//!
//! The third axis, [`LinkCodec`], rides in the design and reaches the
//! executors through the shared [`LayoutEngine`] — the controller
//! threads it into both the host-side engine and the tier's expander
//! engine at construction, so no executor special-cases the link codec.

pub mod engine;
pub mod host;
pub mod layout;
pub mod lcp;
pub mod policy;

pub use engine::{CramEngine, SlotOp, WritePlan};
pub use layout::LayoutEngine;
pub use lcp::{LcpLayout, LcpWriteOutcome, PageDesc};
pub use policy::{Design, LinkCodec, Placement, Policy};

use crate::cram::dynamic::DynamicCram;
use crate::cram::llp::LineLocationPredictor;
use crate::cram::metadata::MetadataStore;
use crate::cram::store::CompressedStore;
use crate::dram::DramSim;
use crate::sim::fault::{FaultConfig, FaultInjector};
use crate::stats::{Bandwidth, CapacityStats, LatencyHist, ReliabilityStats};
use crate::tier::{TierConfig, TieredMemory};
use crate::util::small::InlineVec;
use crate::workloads::SizeOracle;

/// A line the LLC should install after a read.
#[derive(Clone, Copy, Debug, Default)]
pub struct Install {
    pub line_addr: u64,
    /// Prior-compressibility tag bits (0/1/2).
    pub level: u8,
    /// Installed for free by compression (not the demanded line).
    pub prefetch: bool,
    /// Hybrid-compressed size in bytes, filled only when the LLC stores
    /// lines compressed ([`MemoryController::llc_compressed`]); 0 when
    /// the LLC is uncompressed and never looks at it.
    pub size: u8,
}

/// Install list of one read: at most the four lines of a group, inline
/// (no heap allocation per LLC miss).
pub type Installs = InlineVec<Install, 4>;

/// Outcome of a demand read.
#[derive(Clone, Copy, Debug)]
pub struct ReadOutcome {
    /// CPU-visible completion time (bus cycles) of the demanded data.
    pub done: u64,
    pub installs: Installs,
}

/// Per-tenant traffic accounting for multi-tenant runs.
///
/// Maps each core to its tenant and charges every bandwidth/latency
/// event the controller records to exactly one tenant, by snapshotting
/// [`MemoryController::bw`] around each read/writeback.  Because *all*
/// traffic mutations flow through those two entry points, the per-tenant
/// sums reproduce the controller totals field-for-field by construction
/// — the conservation invariant the tenant tests pin.
#[derive(Clone, Debug)]
pub struct TenantTracker {
    /// `core → tenant index` (tenants own contiguous core ranges).
    core_tenant: Vec<usize>,
    /// Tenant whose reads carry scheduler priority
    /// ([`crate::dram::SchedConfig::reserved_slots`]), if any.
    protected: Option<usize>,
    /// Per-tenant traffic, indexed by tenant.
    pub bw: Vec<Bandwidth>,
    /// Per-tenant demand-read latency, indexed by tenant.
    pub read_lat: Vec<LatencyHist>,
}

impl TenantTracker {
    /// `core_counts[t]` cores belong to tenant `t`, in core order.
    pub fn new(core_counts: &[usize], protected: Option<usize>) -> Self {
        let mut core_tenant = Vec::with_capacity(core_counts.iter().sum());
        for (t, &n) in core_counts.iter().enumerate() {
            for _ in 0..n {
                core_tenant.push(t);
            }
        }
        Self {
            core_tenant,
            protected,
            bw: vec![Bandwidth::default(); core_counts.len()],
            read_lat: vec![LatencyHist::default(); core_counts.len()],
        }
    }

    pub fn tenant_of(&self, core: usize) -> usize {
        self.core_tenant[core]
    }

    /// Does `core` belong to the QoS-protected tenant?
    pub fn is_protected(&self, core: usize) -> bool {
        self.protected == Some(self.core_tenant[core])
    }

    fn charge_read(&mut self, core: usize, delta: &Bandwidth, lat: u64) {
        let t = self.core_tenant[core];
        self.bw[t].accumulate(delta);
        self.read_lat[t].record(lat);
    }

    fn charge_write(&mut self, core: usize, delta: &Bandwidth) {
        self.bw[self.core_tenant[core]].accumulate(delta);
    }
}

/// Error-storm degradation watchdog: the reliability analogue of the
/// paper's Dynamic gate, keyed on the *measured* error/retry rate
/// instead of cost/benefit counters.
///
/// The controller ticks it once per demand read with the run's
/// cumulative error-event count (detected marker/media errors plus
/// CRC-retried link flits).  Every [`Self::EPOCH_ACCESSES`] ticks it
/// closes an epoch; an epoch with at least [`Self::HOT_ERRORS`] new
/// events is *hot*.  [`Self::TRIP_EPOCHS`] consecutive hot epochs walk
/// the degradation ladder down one level, [`Self::REARM_EPOCHS`]
/// consecutive quiet epochs walk it back up one level — the asymmetric
/// hysteresis keeps a marginal link from flapping.
///
/// Ladder: level 0 = full compression; level 1 = raw link flits (the
/// engine's degraded-raw override: compressed flits re-expand so a CRC
/// retry replays a predictable payload); level 2 = compression off (no
/// new packed data anywhere; existing packed groups decay lazily, like
/// a closed Dynamic gate).
#[derive(Clone, Debug, Default)]
pub struct ErrorWatchdog {
    /// Accesses into the current epoch.
    acc: u64,
    /// Cumulative error events at the last epoch close.
    last_errors: u64,
    /// Current ladder position (0 = full compression).
    level: u8,
    hot_epochs: u32,
    quiet_epochs: u32,
    /// Level-increase events (telemetry).
    pub degrades: u64,
    /// Level-decrease events after quiet hysteresis (telemetry).
    pub rearms: u64,
    /// Epochs that closed at a degraded level (telemetry).
    pub degraded_epochs: u64,
}

impl ErrorWatchdog {
    /// Accesses per evaluation epoch.
    pub const EPOCH_ACCESSES: u64 = 1024;
    /// New error events per epoch that make it hot (~1.6% of accesses).
    pub const HOT_ERRORS: u64 = 16;
    /// Consecutive hot epochs before degrading one level.
    pub const TRIP_EPOCHS: u32 = 2;
    /// Consecutive quiet epochs before re-arming one level.
    pub const REARM_EPOCHS: u32 = 4;
    /// Ladder bottom: compression fully off.
    pub const MAX_LEVEL: u8 = 2;

    pub fn new() -> Self {
        Self::default()
    }

    /// Current ladder position (0 = full compression).
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Tick one access with the run's cumulative error-event count.
    /// Returns the new level when this tick closes an epoch that moves
    /// the ladder.
    pub fn tick(&mut self, errors: u64) -> Option<u8> {
        self.acc += 1;
        if self.acc < Self::EPOCH_ACCESSES {
            return None;
        }
        self.acc = 0;
        let delta = errors.saturating_sub(self.last_errors);
        self.last_errors = errors;
        if self.level > 0 {
            self.degraded_epochs += 1;
        }
        if delta >= Self::HOT_ERRORS {
            self.quiet_epochs = 0;
            self.hot_epochs += 1;
            if self.hot_epochs >= Self::TRIP_EPOCHS && self.level < Self::MAX_LEVEL {
                self.hot_epochs = 0;
                self.level += 1;
                self.degrades += 1;
                return Some(self.level);
            }
        } else {
            self.hot_epochs = 0;
            self.quiet_epochs += 1;
            if self.quiet_epochs >= Self::REARM_EPOCHS && self.level > 0 {
                self.quiet_epochs = 0;
                self.level -= 1;
                self.rearms += 1;
                return Some(self.level);
            }
        }
        None
    }
}

/// The memory controller: composes the host-path policy with the
/// placement and front-ends every design behind one read/writeback
/// contract.
pub struct MemoryController {
    pub design: Design,
    /// The host-side layout authority (flat placements): the group
    /// family's layouts-in-DRAM plus packing machinery, or the page
    /// family's descriptor ledger — one seam shared with the far tier.
    pub engine: LayoutEngine,
    pub llp: LineLocationPredictor,
    pub meta: Option<MetadataStore>,
    pub dynamic: Option<DynamicCram>,
    /// The two-tier memory front-end (tiered placements only).
    pub tier: Option<TieredMemory>,
    /// The LLC stores lines compressed (`SimConfig::llc_compressed`):
    /// every [`Install`] this controller returns carries the line's
    /// hybrid-compressed size so the cache can charge its data budget.
    pub llc_compressed: bool,
    pub bw: Bandwidth,
    /// CPU-visible latency of every demand read this controller served
    /// (one sample per [`MemoryController::read`] call — the Figure Q1
    /// tail-latency exhibit; `read_lat.count() == bw.demand_reads`).
    pub read_lat: LatencyHist,
    /// Multi-tenant accounting + QoS priority routing (None for
    /// single-tenant runs — the default; zero cost on that path).
    pub tenants: Option<TenantTracker>,
    pub prefetch_installed: u64,
    pub prefetch_used: u64,
    /// Marker fault site on the flat Implicit/Dynamic probe path
    /// (None = injection off; tiered sites live inside the tier).
    marker_fault: Option<FaultInjector>,
    /// Host-side reliability counters (flat marker site; the tier's
    /// counters are folded in by [`Self::rel_snapshot`]).
    rel: ReliabilityStats,
    /// Detections since the host last re-keyed its markers.
    marker_errors_since_rekey: u32,
    /// Error-storm watchdog (Some only once armed by [`Self::set_fault`]).
    watchdog: Option<ErrorWatchdog>,
    /// Watchdog level 2: stop creating packed data on the flat path.
    compress_off: bool,
}

impl MemoryController {
    pub fn new(design: Design, cores: usize, meta_region_base: u64) -> Self {
        Self::with_knobs(design, cores, meta_region_base, 512, 32 * 1024)
    }

    /// Construct with ablation knobs: LLP entries and metadata-cache size.
    pub fn with_knobs(
        design: Design,
        cores: usize,
        meta_region_base: u64,
        llp_entries: usize,
        meta_cache_bytes: usize,
    ) -> Self {
        Self::with_tier_config(
            design,
            cores,
            meta_region_base,
            llp_entries,
            meta_cache_bytes,
            TierConfig::default(),
        )
    }

    /// Full constructor: ablation knobs plus the tiered-memory
    /// configuration (used when the placement is [`Placement::Tiered`]).
    pub fn with_tier_config(
        design: Design,
        cores: usize,
        meta_region_base: u64,
        llp_entries: usize,
        meta_cache_bytes: usize,
        tier_cfg: TierConfig,
    ) -> Self {
        // Flat explicit designs hold the metadata store at the host
        // controller; tiered explicit designs hold it inside the tier
        // (the expander's metadata region lives in device memory).
        // Flat LCP reuses the same store as its page-descriptor cache
        // (page-table-resident descriptors, explicitly cached on chip).
        let meta = match (design.placement, design.policy) {
            (Placement::Flat, Policy::Explicit { row_opt }) => {
                let mut m = MetadataStore::new(meta_cache_bytes, 8, meta_region_base);
                m.row_optimized = row_opt;
                Some(m)
            }
            (Placement::Flat, Policy::Lcp) => {
                Some(MetadataStore::new(meta_cache_bytes, 8, meta_region_base))
            }
            _ => None,
        };
        // 6-bit counters: hysteresis depth scaled to the shortened
        // simulation slices (the paper sizes 12 bits for 1B-instruction
        // slices; threshold must be crossable within a few array sweeps).
        let dynamic =
            matches!(design.policy, Policy::Dynamic).then(|| DynamicCram::with_bits(cores, 6));
        let tier = match design.placement {
            Placement::Tiered => Some(TieredMemory::with_codec(
                tier_cfg,
                design.policy,
                meta_cache_bytes,
                design.link_codec,
            )),
            Placement::Flat => None,
        };
        Self {
            design,
            tier,
            llc_compressed: false,
            engine: LayoutEngine::for_policy(design.policy, design.link_codec),
            llp: LineLocationPredictor::new(llp_entries, 0xD1CE),
            meta,
            dynamic,
            bw: Bandwidth::default(),
            read_lat: LatencyHist::default(),
            tenants: None,
            prefetch_installed: 0,
            prefetch_used: 0,
            marker_fault: None,
            rel: ReliabilityStats::default(),
            marker_errors_since_rekey: 0,
            watchdog: None,
            compress_off: false,
        }
    }

    /// Arm fault injection (and the error-storm watchdog) for this run.
    /// With every rate at zero nothing is installed and the controller
    /// stays bit-identical to an un-faulted run; the watchdog only arms
    /// alongside an enabled fault config.
    pub fn set_fault(&mut self, cfg: &FaultConfig, seed: u64) {
        if !cfg.enabled() {
            return;
        }
        match self.design.placement {
            Placement::Tiered => {
                self.tier
                    .as_mut()
                    .expect("tiered design has a tier")
                    .set_fault(cfg, seed);
            }
            Placement::Flat => {
                // only marker-interpreting flat designs have a fault
                // site: flat placements cross no link and model no far
                // media, and explicit metadata carries no markers
                if cfg.marker_ber > 0.0
                    && matches!(self.design.policy, Policy::Implicit | Policy::Dynamic)
                {
                    self.marker_fault = Some(FaultInjector::marker(cfg.marker_ber, seed));
                }
            }
        }
        if cfg.watchdog {
            self.watchdog = Some(ErrorWatchdog::new());
        }
    }

    /// Current watchdog ladder level (0 when the watchdog is unarmed).
    pub fn watchdog_level(&self) -> u8 {
        self.watchdog.as_ref().map_or(0, |w| w.level())
    }

    /// Assemble the run's [`ReliabilityStats`]: host-side counters plus
    /// the tier's media/marker counters, the link's retry telemetry and
    /// the watchdog's ladder activity.
    pub fn rel_snapshot(&self) -> ReliabilityStats {
        let mut r = self.rel;
        if let Some(t) = self.tier.as_ref() {
            r.accumulate(&t.rel());
            r.flits_retried = t.link.traffic.retried_flits;
            r.retry_beats = t.link.traffic.retry_beats;
        }
        if let Some(w) = self.watchdog.as_ref() {
            r.watchdog_degrades = w.degrades;
            r.watchdog_rearms = w.rearms;
            r.degraded_epochs = w.degraded_epochs;
        }
        r
    }

    /// Cumulative error events feeding the watchdog: detected marker /
    /// media errors plus CRC-retried link flits.
    fn error_events(&self) -> u64 {
        let mut e = self.rel.marker_errors + self.rel.media_errors;
        if let Some(t) = self.tier.as_ref() {
            let tr = t.rel();
            e += tr.marker_errors + tr.media_errors + t.link.traffic.retried_flits;
        }
        e
    }

    /// Close this access out for the watchdog and apply ladder moves to
    /// every executor (host engine, flat write path, tier).
    fn tick_watchdog(&mut self) {
        let errors = self.error_events();
        let Some(w) = self.watchdog.as_mut() else { return };
        if let Some(level) = w.tick(errors) {
            let raw = level >= 1;
            let off = level >= ErrorWatchdog::MAX_LEVEL;
            self.engine.set_degraded_raw(raw);
            self.compress_off = off;
            if let Some(t) = self.tier.as_mut() {
                t.set_degraded(raw, off);
            }
        }
    }

    /// Count a detected flat-path marker corruption; threshold
    /// detections re-key the host markers (the sweep runs off the
    /// demand path; counted only).
    fn note_flat_marker_error(&mut self) {
        self.rel.marker_errors += 1;
        self.rel.marker_detected += 1;
        self.marker_errors_since_rekey += 1;
        if self.marker_errors_since_rekey >= CompressedStore::REKEY_ERROR_THRESHOLD {
            self.marker_errors_since_rekey = 0;
            self.rel.rekeys += 1;
        }
    }

    /// Current host-side layout of `line`'s group (tests/diagnostics).
    #[inline]
    pub fn csi_of(&self, line: u64) -> crate::cram::group::Csi {
        self.engine.csi_of_line(line)
    }

    /// Demand read of `line` for `core` at bus-cycle `now`.
    /// `sampled` = the line maps to a Dynamic-CRAM sampled LLC set.
    ///
    /// Every call records exactly one sample in [`Self::read_lat`]: the
    /// CPU-visible completion latency of the demanded data, whatever the
    /// design serialized in front of it (metadata lookups, mispredicted
    /// probes, link crossings, scheduler queueing).
    pub fn read(
        &mut self,
        line: u64,
        core: usize,
        now: u64,
        dram: &mut DramSim,
        oracle: &mut SizeOracle,
        sampled: bool,
    ) -> ReadOutcome {
        let bw_before = self.bw;
        if let Some(tt) = self.tenants.as_ref() {
            // QoS: the protected tenant's reads see the full read-slot
            // pool, on the host channels and (tiered) the expander DRAM
            let prio = tt.is_protected(core);
            dram.set_priority(prio);
            if let Some(t) = self.tier.as_mut() {
                t.far_dram.set_priority(prio);
            }
        }
        let mut out = self.read_inner(line, core, now, dram, oracle, sampled);
        if self.llc_compressed {
            // a compressed LLC charges its data budget per line: stamp
            // every install with the hybrid size (memoized in the oracle,
            // so this is an O(1) lookup on the steady-state path)
            for ins in out.installs.as_mut_slice() {
                ins.size = oracle.size(ins.line_addr) as u8;
            }
        }
        let lat = out.done.saturating_sub(now);
        self.read_lat.record(lat);
        let delta = self.bw.since(&bw_before);
        if let Some(tt) = self.tenants.as_mut() {
            tt.charge_read(core, &delta, lat);
        }
        if self.watchdog.is_some() {
            self.tick_watchdog();
        }
        out
    }

    fn read_inner(
        &mut self,
        line: u64,
        core: usize,
        now: u64,
        dram: &mut DramSim,
        oracle: &mut SizeOracle,
        sampled: bool,
    ) -> ReadOutcome {
        if self.design.placement == Placement::Tiered {
            // the tier front-end routes near/far, runs the migration
            // policy, and executes the far policy on the expander
            let tier = self.tier.as_mut().expect("tiered design has a tier");
            let out = tier.read(line, now, dram, &mut self.bw, oracle);
            self.prefetch_installed +=
                out.installs.iter().filter(|i| i.prefetch).count() as u64;
            return out;
        }
        self.read_flat(line, core, now, dram, oracle, sampled)
    }

    /// A previously-prefetched line was demanded for the first time —
    /// Dynamic-CRAM's bandwidth-benefit event (§VI-A).  Placement-
    /// agnostic: a useful co-fetch from a packed far block trains the
    /// gate the same way a flat one does.
    pub fn on_prefetch_used(&mut self, core: usize, sampled: bool) {
        self.prefetch_used += 1;
        if sampled {
            if let Some(d) = self.dynamic.as_mut() {
                d.on_benefit(core);
            }
        }
    }

    /// Handle a ganged eviction: `gang` holds every group member that was
    /// resident (all forced out together).  Decides the new layout, issues
    /// the writes/invalidates, and updates metadata/LLP state.
    ///
    /// `sampled` = the group maps to sampled LLC sets (always compress,
    /// train counters); non-sampled sets follow the per-core counter.
    pub fn writeback(
        &mut self,
        gang: &[crate::cache::Evicted],
        now: u64,
        dram: &mut DramSim,
        oracle: &mut SizeOracle,
        sampled: bool,
    ) {
        if gang.is_empty() {
            return;
        }
        let bw_before = self.bw;
        if self.design.placement == Placement::Tiered {
            let tier = self.tier.as_mut().expect("tiered design has a tier");
            tier.writeback(gang, now, dram, oracle, &mut self.bw, sampled, &mut self.dynamic);
        } else {
            self.writeback_flat(gang, now, dram, oracle, sampled);
        }
        // a gang is one group, owned by one core's address space — charge
        // the whole eviction (data, invalidates, metadata) to its tenant
        let delta = self.bw.since(&bw_before);
        if let Some(tt) = self.tenants.as_mut() {
            tt.charge_write(gang[0].core as usize, &delta);
        }
    }

    /// Fraction of written groups that ended up compressed (host engine).
    pub fn compression_frac(&self) -> f64 {
        self.engine.compression_frac()
    }

    /// The effective-capacity ledger, wherever the page family runs
    /// (host engine for flat LCP, the far expander for tiered LCP).
    /// `None` for every group-family design: CRAM trades capacity for
    /// bandwidth by construction, and reporting 1.0× as if measured
    /// would be dishonest telemetry.
    pub fn capacity_snapshot(&self) -> Option<CapacityStats> {
        match self.design.placement {
            Placement::Flat => self.engine.capacity_snapshot(),
            Placement::Tiered => self.tier.as_ref().and_then(|t| t.capacity_snapshot()),
        }
    }

    /// Probability that a pair / quad of adjacent lines fits the packing
    /// budget under this oracle (Fig. 4 harness).
    pub fn pair_quad_compressibility(
        oracle: &mut SizeOracle,
        n_groups: u64,
    ) -> (f64, f64, f64) {
        let mut pair60 = 0u64;
        let mut pair64 = 0u64;
        let mut quad60 = 0u64;
        for g in 0..n_groups {
            let sizes = oracle.group_sizes(g * 4);
            if sizes[0] + sizes[1] <= 60 {
                pair60 += 1;
            }
            if sizes[0] + sizes[1] <= 64 {
                pair64 += 1;
            }
            if sizes.iter().sum::<u32>() <= 60 {
                quad60 += 1;
            }
        }
        (
            pair64 as f64 / n_groups as f64,
            pair60 as f64 / n_groups as f64,
            quad60 as f64 / n_groups as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Evicted;
    use crate::cram::group::Csi;
    use crate::dram::DramConfig;
    use crate::mem::group_base;
    use crate::workloads::{SizeOracle, ValueModel};

    fn setup(design: Design) -> (MemoryController, DramSim, SizeOracle) {
        let mc = MemoryController::new(design, 8, 1 << 28);
        let dram = DramSim::new(DramConfig::default());
        // all-SmallInt pages: every group packs 4:1
        let oracle = SizeOracle::new(ValueModel::new([0.0, 1.0, 0.0, 0.0, 0.0], 7));
        (mc, dram, oracle)
    }

    fn incompressible_oracle() -> SizeOracle {
        SizeOracle::new(ValueModel::new([0.0, 0.0, 0.0, 0.0, 1.0], 9))
    }

    fn gang(base: u64, dirty_mask: [bool; 4]) -> Vec<Evicted> {
        (0..4)
            .map(|i| Evicted {
                line_addr: base + i as u64,
                dirty: dirty_mask[i],
                level: 0,
                core: 0,
                referenced: true,
                was_prefetch: false,
            })
            .collect()
    }

    #[test]
    fn uncompressed_read_installs_one_line() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Uncompressed);
        let r = mc.read(5, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 1);
        assert_eq!(mc.bw.demand_reads, 1);
        assert_eq!(dram.stats.reads, 1);
    }

    #[test]
    fn quad_writeback_one_write_three_invalidates() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true, false, false, false]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Quad);
        assert_eq!(mc.bw.demand_writes, 1); // one packed block (dirty member)
        assert_eq!(mc.bw.invalidates, 3); // slots 1-3 were live before
        assert_eq!(dram.stats.writes, 1);
        assert_eq!(dram.stats.invalidates, 3);
    }

    #[test]
    fn compressed_read_prefetches_group() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        // LLP trained by the writeback: predicts Quad, so reading line 2
        // goes straight to slot 0 and returns all four lines.
        let r = mc.read(2, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4);
        assert_eq!(mc.bw.second_reads, 0, "trained LLP: no second access");
        assert_eq!(r.installs.iter().filter(|i| i.prefetch).count(), 3);
        assert!(r.installs.iter().all(|i| i.level == 2));
    }

    #[test]
    fn untrained_llp_pays_second_access() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        // poison the LCT: pretend this page was last seen uncompressed
        mc.llp.update(0, Csi::Uncompressed);
        let r = mc.read(1, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.second_reads, 1, "mispredicted: slot1 then slot0");
        assert_eq!(r.installs.len(), 4);
        assert_eq!(mc.llp.stats.accuracy(), Some(0.0));
    }

    #[test]
    fn clean_eviction_of_compressible_group_costs_clean_write() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        // packing clean lines: overhead the baseline wouldn't pay
        assert_eq!(mc.bw.clean_writes, 1);
        assert_eq!(mc.bw.demand_writes, 0);
        assert_eq!(mc.bw.invalidates, 3);
    }

    #[test]
    fn uncompressed_baseline_drops_clean_lines() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Uncompressed);
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.demand_writes + mc.bw.clean_writes, 0);
        assert_eq!(dram.stats.total_accesses(), 0);
    }

    #[test]
    fn incompressible_group_stays_uncompressed() {
        let (mut mc, mut dram, mut oracle_) = setup(Design::Implicit);
        let mut oracle = incompressible_oracle();
        let _ = &mut oracle_;
        mc.writeback(&gang(0, [true, true, false, false]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
        assert_eq!(mc.bw.demand_writes, 2); // two dirty raw lines
        assert_eq!(mc.bw.invalidates, 0);
        assert_eq!(mc.bw.clean_writes, 0);
    }

    #[test]
    fn layout_transition_packs_then_unpacks() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Quad);
        // dirty rewrites change values; with an incompressible oracle the
        // group must unpack: all four written raw, stale slots restored
        let mut bad = incompressible_oracle();
        mc.writeback(&gang(0, [true; 4]), 1000, &mut dram, &mut bad, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
    }

    #[test]
    fn dynamic_gates_compression_by_counter() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Dynamic);
        // hammer costs on core 0 via sampled activity
        for _ in 0..3000 {
            mc.dynamic.as_mut().unwrap().on_cost(0);
        }
        assert!(!mc.dynamic.as_ref().unwrap().enabled(0));
        // non-sampled set: compression disabled -> clean gang drops
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
        assert_eq!(mc.bw.clean_writes, 0);
        // sampled set: always compresses
        mc.writeback(&gang(8, [false; 4]), 0, &mut dram, &mut oracle, true);
        assert_eq!(mc.csi_of(8), Csi::Quad);
    }

    #[test]
    fn explicit_charges_metadata_traffic() {
        let (mut mc, mut dram, mut oracle) = setup(Design::explicit(false));
        // first read: metadata cache cold -> metadata read + data read
        let r = mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1);
        assert_eq!(mc.bw.demand_reads, 1);
        assert!(r.done > 0);
        // second read of a neighbor: metadata cached
        mc.read(4, 0, r.done, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1);
        assert_eq!(mc.meta.as_ref().unwrap().hits, 1);
    }

    #[test]
    fn prefetch_baseline_costs_extra_reads() {
        let (mut mc, mut dram, mut oracle) = setup(Design::NextLinePrefetch);
        let r = mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 2);
        assert_eq!(mc.bw.prefetch_reads, 1);
        assert_eq!(dram.stats.reads, 2);
    }

    #[test]
    fn ideal_no_write_overheads() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Ideal);
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(dram.stats.total_accesses(), 0);
        let r = mc.read(1, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4, "free co-fetch");
        assert_eq!(mc.bw.second_reads, 0);
    }

    #[test]
    fn row_opt_metadata_reads_are_row_hits() {
        let (mut mc, mut dram, mut oracle) = setup(Design::explicit(true));
        mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        // the metadata access must have been a forced row hit
        assert!(dram.stats.row_hits >= 1);
        assert_eq!(mc.bw.meta_reads, 1);
    }

    #[test]
    fn prefetch_benefit_feeds_dynamic_counter() {
        let (mut mc, _dram, _oracle) = setup(Design::Dynamic);
        let before = mc.dynamic.as_ref().unwrap().counter(2);
        mc.on_prefetch_used(2, true);
        assert_eq!(mc.dynamic.as_ref().unwrap().counter(2), before + 1);
        // non-sampled: counted as used, not as counter training
        mc.on_prefetch_used(2, false);
        assert_eq!(mc.dynamic.as_ref().unwrap().counter(2), before + 1);
        assert_eq!(mc.prefetch_used, 2);
    }

    #[test]
    fn dynamic_disabled_keeps_packed_data_on_clean_evict() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Dynamic);
        // pack while enabled (sampled path)
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, true);
        assert_eq!(mc.csi_of(0), Csi::Quad);
        // disable, then clean-evict the group: data must STAY packed and
        // cost nothing
        for _ in 0..200 {
            mc.dynamic.as_mut().unwrap().on_cost(0);
        }
        let writes_before = dram.stats.total_accesses();
        mc.writeback(&gang(0, [false; 4]), 100, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Quad, "clean drop keeps packed layout");
        assert_eq!(dram.stats.total_accesses(), writes_before, "no traffic");
        // a dirty evict while disabled unpacks
        mc.writeback(&gang(0, [true, false, false, false]), 200, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
    }

    #[test]
    fn second_access_serializes_latency() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.llp.update(0, Csi::Uncompressed); // poison -> mispredict
        let t0 = 1000;
        let r = mc.read(1, 0, t0, &mut dram, &mut oracle, false);
        // two serialized reads: strictly more than one access latency
        assert!(r.done > t0 + 22, "done {} vs issue {t0}", r.done);
    }

    #[test]
    fn read_latency_recorded_once_per_demand_read() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.llp.update(0, Csi::Uncompressed); // poison -> second probe
        mc.read(1, 0, 1000, &mut dram, &mut oracle, false);
        mc.read(2, 0, 2000, &mut dram, &mut oracle, false);
        assert_eq!(mc.read_lat.count(), mc.bw.demand_reads, "one sample per read");
        // the mispredicted read's serialized probes land in the tail
        assert!(mc.read_lat.percentile(1.0) > 22.0);
    }

    #[test]
    fn tenant_tracker_partitions_controller_totals() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.tenants = Some(TenantTracker::new(&[4, 4], Some(0)));
        // tenant 0 (core 1): a packed writeback + a read of the group
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.read(1, 1, 100, &mut dram, &mut oracle, false);
        // tenant 1 (cores 5/6): its own gang + two reads
        let mut g = gang(64, [true, false, false, false]);
        for e in &mut g {
            e.core = 5;
        }
        mc.writeback(&g, 200, &mut dram, &mut oracle, false);
        mc.read(64, 6, 300, &mut dram, &mut oracle, false);
        mc.read(65, 5, 400, &mut dram, &mut oracle, false);

        let tt = mc.tenants.as_ref().unwrap();
        assert!(tt.is_protected(0) && tt.is_protected(3));
        assert!(!tt.is_protected(4));
        assert_eq!(tt.tenant_of(5), 1);
        // every field of the totals is partitioned across tenants
        assert_eq!(tt.bw[0].total() + tt.bw[1].total(), mc.bw.total());
        assert_eq!(
            tt.bw[0].demand_reads + tt.bw[1].demand_reads,
            mc.bw.demand_reads
        );
        assert_eq!(
            tt.bw[0].invalidates + tt.bw[1].invalidates,
            mc.bw.invalidates
        );
        assert_eq!(
            tt.read_lat[0].count() + tt.read_lat[1].count(),
            mc.read_lat.count()
        );
        assert_eq!(tt.read_lat[0].count(), 1);
        assert_eq!(tt.read_lat[1].count(), 2);
        assert!(tt.bw[0].total() > 0 && tt.bw[1].total() > 0);
    }

    #[test]
    fn compressibility_probe_reports_sane_fractions() {
        let mut zero_oracle =
            SizeOracle::new(ValueModel::new([1.0, 0.0, 0.0, 0.0, 0.0], 3));
        let (p64, p60, q60) =
            MemoryController::pair_quad_compressibility(&mut zero_oracle, 512);
        assert!(p64 >= p60, "60B budget can't beat 64B");
        assert!(p60 > 0.95 && q60 > 0.95, "zero pages always pack");
        let mut rnd = incompressible_oracle();
        let (p64, p60, q60) = MemoryController::pair_quad_compressibility(&mut rnd, 512);
        assert_eq!((p64, p60, q60), (0.0, 0.0, 0.0));
    }

    #[test]
    fn tiered_controller_routes_and_accounts_per_tier() {
        let (mut mc, mut dram, mut oracle) = setup(Design::tiered(true));
        // find one near and one far group under the default 50/50 split
        let tier = mc.tier.as_ref().unwrap();
        let near_line = (0..100_000u64).find(|&l| !tier.is_far_line(l)).unwrap();
        let far_line = (0..100_000u64).find(|&l| tier.is_far_line(l)).unwrap();
        let rn = mc.read(near_line, 0, 0, &mut dram, &mut oracle, false);
        let rf = mc.read(far_line, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(rn.installs.len(), 1, "near tier is uncompressed");
        assert!(rf.done > rn.done, "far read pays the link");
        // pack a far group, then a read co-fetches it
        mc.writeback(
            &gang(group_base(far_line), [true; 4]),
            100,
            &mut dram,
            &mut oracle,
            false,
        );
        let r = mc.read(group_base(far_line) + 1, 0, 1000, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4, "packed far block co-fetches the group");
        assert!(mc.prefetch_installed >= 3);
        // per-tier counters account for every access the controller charged
        let stats = mc.tier.as_ref().unwrap().snapshot();
        assert_eq!(stats.total_accesses(), mc.bw.total());
    }

    #[test]
    fn tiered_dynamic_gates_far_packing() {
        let (mut mc, mut dram, mut oracle) =
            setup(Design::new(Policy::Dynamic, Placement::Tiered));
        assert!(mc.dynamic.is_some(), "tiered-cram-dyn has the gate");
        let tier = mc.tier.as_ref().unwrap();
        let far_line = (0..100_000u64).find(|&l| tier.is_far_line(l)).unwrap();
        let base = group_base(far_line);
        // enabled gate: a far gang packs like tiered-cram
        mc.writeback(&gang(base, [true; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(
            mc.tier.as_ref().unwrap().far_csi_of(base),
            Csi::Quad,
            "enabled gate packs the far group"
        );
        // hammer costs until the gate closes, then a dirty re-evict of a
        // *different* far group must stay raw on the expander
        for _ in 0..3000 {
            mc.dynamic.as_mut().unwrap().on_cost(0);
        }
        assert!(!mc.dynamic.as_ref().unwrap().enabled(0));
        let far2 = (base + 4..200_000u64)
            .step_by(4)
            .find(|&l| mc.tier.as_ref().unwrap().is_far_line(l) && l != base)
            .unwrap();
        mc.writeback(&gang(far2, [true; 4]), 100, &mut dram, &mut oracle, false);
        assert_eq!(
            mc.tier.as_ref().unwrap().far_csi_of(far2),
            Csi::Uncompressed,
            "closed gate stops creating packed far data"
        );
        // sampled groups always compress (they train the counters)
        let far3 = (far2 + 4..300_000u64)
            .step_by(4)
            .find(|&l| mc.tier.as_ref().unwrap().is_far_line(l))
            .unwrap();
        mc.writeback(&gang(far3, [true; 4]), 200, &mut dram, &mut oracle, true);
        assert_eq!(mc.tier.as_ref().unwrap().far_csi_of(far3), Csi::Quad);
    }

    #[test]
    fn tiered_explicit_charges_far_metadata_traffic() {
        let (mut mc, mut dram, mut oracle) =
            setup(Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered));
        let tier = mc.tier.as_ref().unwrap();
        let far_line = (0..100_000u64).find(|&l| tier.is_far_line(l)).unwrap();
        let base = group_base(far_line);
        // pack a far group: the layout change dirty-allocates in the
        // metadata cache (cold -> miss -> device metadata read)
        mc.writeback(&gang(base, [true; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1, "cold metadata cache misses on update");
        // a read of the same group hits the (host-side) metadata cache
        let r = mc.read(base + 1, 0, 1000, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1, "metadata cached after the update");
        assert_eq!(r.installs.len(), 4, "explicit far CRAM still co-fetches");
        // accounting invariant: every metadata access lands on a tier
        let stats = mc.tier.as_ref().unwrap().snapshot();
        assert_eq!(stats.total_accesses(), mc.bw.total());
        assert!(stats.far.meta_accesses >= 1);
    }

    #[test]
    fn compressed_llc_mode_stamps_install_sizes() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.llc_compressed = true;
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        let r = mc.read(2, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4);
        for i in r.installs.iter() {
            assert!(
                (2..=64).contains(&i.size),
                "compressed-LLC install must carry a real size, got {}",
                i.size
            );
            assert_eq!(i.size as u32, oracle.size(i.line_addr));
        }
        // with the knob off, sizes stay 0 (the plain LLC never reads them)
        let (mut mc2, mut dram2, mut oracle2) = setup(Design::Implicit);
        let r2 = mc2.read(2, 0, 0, &mut dram2, &mut oracle2, false);
        assert!(r2.installs.iter().all(|i| i.size == 0));
    }

    #[test]
    fn lcp_reads_need_no_probe_and_cofetch_coresidents() {
        let (mut mc, mut dram, mut oracle) = setup(Design::flat(Policy::Lcp));
        // cold descriptor cache: one metadata read serialized in front of
        // the data access; the data access itself never probes
        let r = mc.read(5, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1, "descriptor miss pays a metadata read");
        assert_eq!(mc.bw.demand_reads, 1);
        assert_eq!(mc.bw.second_reads, 0, "fixed offsets: no probe, ever");
        // all-SmallInt page -> T=16 -> slot 5 shares its physical line
        // with slots 4..8 (the free co-fetch)
        assert_eq!(r.installs.len(), 4);
        assert_eq!(r.installs.iter().filter(|i| i.prefetch).count(), 3);
        // a neighboring slot hits the cached descriptor
        mc.read(6, 0, r.done, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1, "descriptor cached after first touch");
        assert!(mc.llp.stats.accuracy().is_none(), "LLP telemetry honestly n/a");
        assert!(mc.capacity_snapshot().is_some(), "the page family reports capacity");
    }

    #[test]
    fn lcp_dirty_write_routes_through_the_exception_region() {
        let (mut mc, mut dram, mut oracle) = setup(Design::flat(Policy::Lcp));
        // materialize the page's descriptor at T=16 via a read
        mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        let writes_before = mc.bw.demand_writes;
        // a dirty store re-rolled against an incompressible model bloats
        // slot 0 past the target: it moves to the exception region
        let mut big = incompressible_oracle();
        mc.writeback(&gang(0, [true, false, false, false]), 100, &mut dram, &mut big, false);
        assert_eq!(mc.bw.demand_writes, writes_before + 1, "one data write");
        let d = mc.engine.as_lcp().unwrap().desc_of(0).unwrap();
        assert_eq!(d.target, 16, "target unchanged below the overflow cap");
        assert!(d.is_exception(0));
        assert_eq!(mc.meta.as_ref().unwrap().updates, 1, "descriptor persisted");
        let cap = mc.capacity_snapshot().unwrap();
        assert_eq!(cap.exception_lines, 1);
        assert!(cap.expansion() > 1.0, "a T=16 page grows effective capacity");
        // clean evictions drop free: no CSI state to repack
        let t = dram.stats.total_accesses();
        mc.writeback(&gang(4, [false; 4]), 200, &mut dram, &mut oracle, false);
        assert_eq!(dram.stats.total_accesses(), t, "clean LCP gang costs nothing");
    }

    #[test]
    fn tiered_names_resolve_both_ways() {
        assert_eq!(Design::tiered(false).name(), "tiered-uncomp");
        assert_eq!(Design::tiered(true).name(), "tiered-cram");
        assert!(!Design::tiered(true).compresses());
    }

    #[test]
    fn link_codec_threads_through_the_shared_engines() {
        // the design's third axis reaches both engines at construction —
        // no per-executor special case
        let lc = Design::tiered(true).with_link_codec(LinkCodec::Compressed);
        let mc = MemoryController::new(lc, 8, 1 << 28);
        assert_eq!(mc.engine.link_codec(), LinkCodec::Compressed);
        let raw = MemoryController::new(Design::tiered(true), 8, 1 << 28);
        assert_eq!(raw.engine.link_codec(), LinkCodec::Raw);
    }

    #[test]
    fn compressed_link_design_saves_wire_bytes_raw_twin_does_not() {
        let drive = |design: Design| {
            let (mut mc, mut dram, mut oracle) = setup(design);
            let far = (0..100_000u64)
                .find(|&l| mc.tier.as_ref().unwrap().is_far_line(l))
                .unwrap();
            let base = group_base(far);
            mc.writeback(&gang(base, [true; 4]), 0, &mut dram, &mut oracle, false);
            mc.read(base + 1, 0, 1000, &mut dram, &mut oracle, false);
            mc.tier.as_ref().unwrap().snapshot()
        };
        let raw = drive(Design::tiered(true));
        let lc = drive(Design::tiered(true).with_link_codec(LinkCodec::Compressed));
        assert_eq!(raw.link_traffic.raw_bytes(), raw.link_traffic.wire_bytes());
        assert_eq!(raw.link_traffic.flits_saved, 0);
        assert_eq!(lc.link_traffic.raw_bytes(), raw.link_traffic.raw_bytes());
        assert!(lc.link_traffic.wire_bytes() < lc.link_traffic.raw_bytes());
        assert!(lc.link_traffic.flits_saved > 0);
    }

    #[test]
    fn partial_gang_preserves_other_half() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        // pack CD only: evict gang of just C,D (A,B never resident)
        let cd: Vec<Evicted> = (2..4)
            .map(|i| Evicted {
                line_addr: i,
                dirty: true,
                level: 0,
                core: 0,
                referenced: true,
                was_prefetch: false,
            })
            .collect();
        mc.writeback(&cd, 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::PairCd);
        // now evict A alone (clean, incompressible pairing impossible
        // since B absent): CD half must stay packed
        let a = vec![Evicted {
            line_addr: 0,
            dirty: true,
            level: 0,
            core: 0,
            referenced: true,
            was_prefetch: false,
        }];
        mc.writeback(&a, 10, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::PairCd);
    }

    #[test]
    fn watchdog_ladder_trips_and_rearms_with_hysteresis() {
        let mut w = ErrorWatchdog::new();
        let mut errors = 0u64;
        // run one full epoch, optionally injecting a hot error burst
        let mut epoch = |w: &mut ErrorWatchdog, errors: &mut u64, hot: bool| {
            if hot {
                *errors += ErrorWatchdog::HOT_ERRORS;
            }
            let mut moved = None;
            for _ in 0..ErrorWatchdog::EPOCH_ACCESSES {
                if let Some(l) = w.tick(*errors) {
                    moved = Some(l);
                }
            }
            moved
        };
        // one hot epoch is not enough (hysteresis)
        assert_eq!(epoch(&mut w, &mut errors, true), None);
        assert_eq!(w.level(), 0);
        // the second consecutive hot epoch degrades to raw-link
        assert_eq!(epoch(&mut w, &mut errors, true), Some(1));
        // two more reach the ladder bottom: compression off
        epoch(&mut w, &mut errors, true);
        assert_eq!(epoch(&mut w, &mut errors, true), Some(2));
        assert_eq!(w.level(), ErrorWatchdog::MAX_LEVEL);
        // further storms cannot go past the bottom
        epoch(&mut w, &mut errors, true);
        assert_eq!(w.level(), ErrorWatchdog::MAX_LEVEL);
        assert_eq!(w.degrades, 2);
        // quiet epochs re-arm one level per hysteresis window
        for _ in 0..ErrorWatchdog::REARM_EPOCHS - 1 {
            assert_eq!(epoch(&mut w, &mut errors, false), None);
        }
        assert_eq!(epoch(&mut w, &mut errors, false), Some(1));
        for _ in 0..ErrorWatchdog::REARM_EPOCHS {
            epoch(&mut w, &mut errors, false);
        }
        assert_eq!(w.level(), 0);
        assert_eq!(w.rearms, 2);
        assert!(w.degraded_epochs > 0);
    }

    #[test]
    fn disarmed_fault_leaves_controller_untouched() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.set_fault(&FaultConfig::default(), 5);
        assert!(mc.watchdog.is_none(), "watchdog arms only with injection");
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.read(2, 0, 100, &mut dram, &mut oracle, false);
        assert!(mc.rel_snapshot().is_zero());
        assert_eq!(mc.bw.second_reads, 0);
        assert_eq!(mc.watchdog_level(), 0);
    }

    #[test]
    fn flat_marker_errors_detected_and_cured() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.set_fault(&FaultConfig { marker_ber: 1.0, ..FaultConfig::default() }, 5);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        // trained LLP, certain corruption: the single probe detects the
        // bad tail against the engine's layout and pays one verify re-read
        let r = mc.read(2, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4, "the cured read still returns the group");
        let rel = mc.rel_snapshot();
        assert_eq!(rel.marker_errors, 1);
        assert_eq!(rel.marker_detected, 1, "nothing silently misread");
        assert_eq!(rel.silent_misreads, 0);
        assert_eq!(mc.bw.second_reads, 1, "cure charged as a verify re-read");
        // threshold detections re-key
        for i in 0..15u64 {
            mc.read(2, 0, 1_000 + i * 100, &mut dram, &mut oracle, false);
        }
        assert_eq!(mc.rel_snapshot().marker_errors, 16);
        assert_eq!(mc.rel_snapshot().rekeys, 1);
    }

    #[test]
    fn error_storm_degrades_flat_compression_then_rearms() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.set_fault(&FaultConfig { marker_ber: 1.0, ..FaultConfig::default() }, 13);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        // storm: every packed read is a detected marker error, so epochs
        // run hot and the ladder walks down to compression-off
        let mut now = 100u64;
        while mc.rel_snapshot().watchdog_degrades < 2 && now < 1_000_000_000 {
            mc.read(2, 0, now, &mut dram, &mut oracle, false);
            now += 100;
        }
        assert_eq!(mc.rel_snapshot().watchdog_degrades, 2);
        assert_eq!(mc.watchdog_level(), ErrorWatchdog::MAX_LEVEL);
        // degraded: a new gang must stop packing
        mc.writeback(&gang(64, [true; 4]), now, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(64), Csi::Uncompressed, "compression forced off");
        // quiet traffic (uncompressed lines interpret no markers): the
        // ladder re-arms and packing resumes
        let mut q = 0u64;
        while mc.rel_snapshot().watchdog_rearms < 2 && q < 20_000 {
            mc.read(1_000_000 + q, 0, now + q * 100, &mut dram, &mut oracle, false);
            q += 1;
        }
        assert_eq!(mc.rel_snapshot().watchdog_rearms, 2, "quiet epochs re-arm");
        assert_eq!(mc.watchdog_level(), 0);
        assert!(mc.rel_snapshot().degraded_epochs > 0);
        mc.writeback(&gang(128, [true; 4]), now + q * 100, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(128), Csi::Quad, "re-armed controller packs again");
    }

    #[test]
    fn tiered_fault_counters_fold_into_the_controller_snapshot() {
        let (mut mc, mut dram, mut oracle) = setup(Design::tiered(true));
        mc.set_fault(&FaultConfig::uniform(1.0), 21);
        let far_line = {
            let tier = mc.tier.as_ref().unwrap();
            (0..100_000u64).find(|&l| tier.is_far_line(l)).unwrap()
        };
        let base = group_base(far_line);
        mc.writeback(&gang(base, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.read(base + 1, 0, 100_000, &mut dram, &mut oracle, false);
        let rel = mc.rel_snapshot();
        assert!(rel.flits_retried > 0, "link site fired");
        assert!(rel.retry_beats > 0);
        assert!(rel.media_errors >= 1, "media site fired on the far read");
        assert_eq!(rel.marker_errors, 1, "packed far read hit the marker site");
        assert_eq!(rel.marker_detected, rel.marker_errors);
        assert_eq!(rel.silent_misreads, 0);
        // the accounting invariant survives injection
        let stats = mc.tier.as_ref().unwrap().snapshot();
        assert_eq!(stats.total_accesses(), mc.bw.total());
    }
}
