//! Memory-controller designs: CRAM and every baseline the paper evaluates.
//!
//! One [`MemoryController`] drives all designs (selected by [`Design`]) so
//! the read/writeback machinery — group layout transitions, marker-implied
//! verification, LLP prediction walks, metadata traffic, Dynamic-CRAM
//! gating — shares one audited implementation.
//!
//! | [`Design`] | paper reference |
//! |---|---|
//! | `Uncompressed` | baseline of every figure |
//! | `Ideal` | Fig. 3/16 "ideal compression" (benefits, no overheads) |
//! | `Explicit` | Fig. 7/8/12 CRAM + metadata region + 32KB metadata cache |
//! | `Explicit { row_opt }` | Fig. 20 MemZip/LCP-style row-co-located metadata |
//! | `Implicit` | Fig. 12/15/16 "Static-CRAM": implicit metadata + LLP |
//! | `Dynamic` | Fig. 16/18/19: Static-CRAM + set-sampled cost/benefit gating |
//! | `NextLinePrefetch` | Table V baseline |
//! | `Tiered` | Figure T1: near DDR + far CXL expander (`tier` module) |

use crate::cram::dynamic::DynamicCram;
use crate::cram::group::{possible_locations, Csi};
use crate::cram::llp::LineLocationPredictor;
use crate::cram::metadata::{MetaAccess, MetadataStore};
use crate::dram::{DramSim, ReqKind};
use crate::mem::{group_base, group_of, page_of_line, PagedArena};
use crate::stats::{Bandwidth, LatencyHist};
use crate::tier::{TierConfig, TieredMemory};
use crate::util::small::InlineVec;
use crate::workloads::SizeOracle;

/// Which memory-system design the controller implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Design {
    Uncompressed,
    Ideal,
    Explicit { row_opt: bool },
    Implicit,
    Dynamic,
    NextLinePrefetch,
    /// Two-tier memory: near DDR (uncompressed) + far CXL expander,
    /// optionally CRAM-compressed on the device (see [`crate::tier`]).
    Tiered { far_compressed: bool },
}

impl Design {
    pub fn name(&self) -> &'static str {
        match self {
            Design::Uncompressed => "uncompressed",
            Design::Ideal => "ideal",
            Design::Explicit { row_opt: false } => "cram-explicit",
            Design::Explicit { row_opt: true } => "cram-explicit-rowopt",
            Design::Implicit => "cram-static",
            Design::Dynamic => "cram-dynamic",
            Design::NextLinePrefetch => "nextline-prefetch",
            Design::Tiered { far_compressed: false } => "tiered-uncomp",
            Design::Tiered { far_compressed: true } => "tiered-cram",
        }
    }

    pub fn compresses(&self) -> bool {
        // Tiered designs never pack on the host side; the far expander
        // runs its own engine (see `tier::TieredMemory`).
        !matches!(
            self,
            Design::Uncompressed | Design::NextLinePrefetch | Design::Tiered { .. }
        )
    }
}

/// A line the LLC should install after a read.
#[derive(Clone, Copy, Debug, Default)]
pub struct Install {
    pub line_addr: u64,
    /// Prior-compressibility tag bits (0/1/2).
    pub level: u8,
    /// Installed for free by compression (not the demanded line).
    pub prefetch: bool,
    /// Hybrid-compressed size in bytes, filled only when the LLC stores
    /// lines compressed ([`MemoryController::llc_compressed`]); 0 when
    /// the LLC is uncompressed and never looks at it.
    pub size: u8,
}

/// Install list of one read: at most the four lines of a group, inline
/// (no heap allocation per LLC miss).
pub type Installs = InlineVec<Install, 4>;

/// Outcome of a demand read.
#[derive(Clone, Copy, Debug)]
pub struct ReadOutcome {
    /// CPU-visible completion time (bus cycles) of the demanded data.
    pub done: u64,
    pub installs: Installs,
}

/// The memory controller.
pub struct MemoryController {
    pub design: Design,
    /// Current physical layout per group index (what is actually in DRAM)
    /// — a paged arena: O(1) shifted-address indexing, no hashing on the
    /// per-access path.
    mem_csi: PagedArena<Csi>,
    pub llp: LineLocationPredictor,
    pub meta: Option<MetadataStore>,
    pub dynamic: Option<DynamicCram>,
    /// The two-tier memory front-end (tiered designs only).
    pub tier: Option<TieredMemory>,
    /// The LLC stores lines compressed (`SimConfig::llc_compressed`):
    /// every [`Install`] this controller returns carries the line's
    /// hybrid-compressed size so the cache can charge its data budget.
    pub llc_compressed: bool,
    pub bw: Bandwidth,
    /// CPU-visible latency of every demand read this controller served
    /// (one sample per [`MemoryController::read`] call — the Figure Q1
    /// tail-latency exhibit; `read_lat.count() == bw.demand_reads`).
    pub read_lat: LatencyHist,
    pub prefetch_installed: u64,
    pub prefetch_used: u64,
    /// Groups written compressed vs total group writebacks (diagnostics).
    pub groups_written: u64,
    pub groups_compressed: u64,
}

impl MemoryController {
    pub fn new(design: Design, cores: usize, meta_region_base: u64) -> Self {
        Self::with_knobs(design, cores, meta_region_base, 512, 32 * 1024)
    }

    /// Construct with ablation knobs: LLP entries and metadata-cache size.
    pub fn with_knobs(
        design: Design,
        cores: usize,
        meta_region_base: u64,
        llp_entries: usize,
        meta_cache_bytes: usize,
    ) -> Self {
        Self::with_tier_config(
            design,
            cores,
            meta_region_base,
            llp_entries,
            meta_cache_bytes,
            TierConfig::default(),
        )
    }

    /// Full constructor: ablation knobs plus the tiered-memory
    /// configuration (used when `design` is [`Design::Tiered`]).
    pub fn with_tier_config(
        design: Design,
        cores: usize,
        meta_region_base: u64,
        llp_entries: usize,
        meta_cache_bytes: usize,
        tier_cfg: TierConfig,
    ) -> Self {
        let meta = match design {
            Design::Explicit { row_opt } => {
                let mut m = MetadataStore::new(meta_cache_bytes, 8, meta_region_base);
                m.row_optimized = row_opt;
                Some(m)
            }
            _ => None,
        };
        // 6-bit counters: hysteresis depth scaled to the shortened
        // simulation slices (the paper sizes 12 bits for 1B-instruction
        // slices; threshold must be crossable within a few array sweeps).
        let dynamic = matches!(design, Design::Dynamic).then(|| DynamicCram::with_bits(cores, 6));
        let tier = match design {
            Design::Tiered { far_compressed } => {
                Some(TieredMemory::new(tier_cfg, far_compressed))
            }
            _ => None,
        };
        Self {
            design,
            tier,
            llc_compressed: false,
            mem_csi: PagedArena::new(Csi::Uncompressed),
            llp: LineLocationPredictor::new(llp_entries, 0xD1CE),
            meta,
            dynamic,
            bw: Bandwidth::default(),
            read_lat: LatencyHist::default(),
            prefetch_installed: 0,
            prefetch_used: 0,
            groups_written: 0,
            groups_compressed: 0,
        }
    }

    #[inline]
    fn csi_of(&self, line: u64) -> Csi {
        self.mem_csi.copied_or_default(group_of(line))
    }

    /// Demand read of `line` for `core` at bus-cycle `now`.
    /// `sampled` = the line maps to a Dynamic-CRAM sampled LLC set.
    ///
    /// Every call records exactly one sample in [`Self::read_lat`]: the
    /// CPU-visible completion latency of the demanded data, whatever the
    /// design serialized in front of it (metadata lookups, mispredicted
    /// probes, link crossings, scheduler queueing).
    pub fn read(
        &mut self,
        line: u64,
        core: usize,
        now: u64,
        dram: &mut DramSim,
        oracle: &mut SizeOracle,
        sampled: bool,
    ) -> ReadOutcome {
        let mut out = self.read_inner(line, core, now, dram, oracle, sampled);
        if self.llc_compressed {
            // a compressed LLC charges its data budget per line: stamp
            // every install with the hybrid size (memoized in the oracle,
            // so this is an O(1) lookup on the steady-state path)
            for ins in out.installs.as_mut_slice() {
                ins.size = oracle.size(ins.line_addr) as u8;
            }
        }
        self.read_lat.record(out.done.saturating_sub(now));
        out
    }

    fn read_inner(
        &mut self,
        line: u64,
        core: usize,
        now: u64,
        dram: &mut DramSim,
        oracle: &mut SizeOracle,
        sampled: bool,
    ) -> ReadOutcome {
        match self.design {
            Design::Uncompressed => {
                self.bw.demand_reads += 1;
                let done = dram.access(line, ReqKind::Read, now, false);
                ReadOutcome {
                    done,
                    installs: Installs::of(&[Install {
                        line_addr: line,
                        level: 0,
                        prefetch: false,
                        size: 0,
                    }]),
                }
            }
            Design::Tiered { .. } => {
                // the tier front-end routes near/far, runs the migration
                // policy, and (compressed far) co-fetches packed lines
                let tier = self.tier.as_mut().expect("tiered design has a tier");
                let out = tier.read(line, now, dram, &mut self.bw);
                self.prefetch_installed +=
                    out.installs.iter().filter(|i| i.prefetch).count() as u64;
                out
            }
            Design::NextLinePrefetch => {
                self.bw.demand_reads += 1;
                let done = dram.access(line, ReqKind::Read, now, false);
                // next-line prefetch: a full extra access (the bandwidth
                // cost CRAM avoids — Table V)
                self.bw.prefetch_reads += 1;
                dram.access(line + 1, ReqKind::Read, now, false);
                self.prefetch_installed += 1;
                ReadOutcome {
                    done,
                    installs: Installs::of(&[
                        Install { line_addr: line, level: 0, prefetch: false, size: 0 },
                        Install { line_addr: line + 1, level: 0, prefetch: true, size: 0 },
                    ]),
                }
            }
            Design::Ideal => {
                // Fig. 3: all the benefits (co-fetched neighbors arrive
                // free), none of the overheads (no metadata, no markers, no
                // extra writebacks — layout magically always optimal).
                self.bw.demand_reads += 1;
                let done = dram.access(line, ReqKind::Read, now, false);
                let sizes = oracle.group_sizes(line);
                let csi = Csi::from_sizes(sizes);
                let base = group_base(line);
                let slot = (line - base) as u8;
                let loc = csi.location(slot);
                let installs = self.installs_for(base, csi, loc, line);
                ReadOutcome { done, installs }
            }
            Design::Explicit { row_opt } => {
                // 1) metadata lookup (cache hit: free; miss: a DRAM access
                //    that the data access serializes behind)
                let meta = self.meta.as_mut().expect("explicit has metadata");
                let meta_addr = meta.meta_addr_for(line);
                let (_, how) = meta.lookup(line);
                let actual = self.csi_of(line);
                let mut t = now;
                if how == MetaAccess::Miss {
                    self.bw.meta_reads += 1;
                    t = dram.access(meta_addr, ReqKind::MetaRead, t, row_opt);
                }
                // 2) data access at the (now known) correct location
                let base = group_base(line);
                let slot = (line - base) as u8;
                let loc = base + actual.location(slot) as u64;
                self.bw.demand_reads += 1;
                let done = dram.access(loc, ReqKind::Read, t, false);
                let installs = self.installs_for(base, actual, actual.location(slot), line);
                ReadOutcome { done, installs }
            }
            Design::Implicit | Design::Dynamic => {
                let base = group_base(line);
                let slot = (line - base) as u8;
                let page = page_of_line(line);
                let actual = self.csi_of(line);
                let actual_loc = actual.location(slot);
                let (pred_loc, needed) = self.llp.predict_location(page, slot);
                if needed {
                    self.llp.record_outcome(pred_loc == actual_loc);
                }
                // Probe predicted first, then remaining possible locations;
                // the markers in each fetched line verify the guess.
                let mut probes: InlineVec<u8, 4> = InlineVec::new();
                probes.push(pred_loc);
                for &s in possible_locations(slot) {
                    if s != pred_loc {
                        probes.push(s);
                    }
                }
                let mut t = now;
                let mut first = true;
                let mut done = 0;
                for &p in probes.iter() {
                    if first {
                        self.bw.demand_reads += 1;
                    } else {
                        self.bw.second_reads += 1;
                        if sampled {
                            if let Some(d) = self.dynamic.as_mut() {
                                d.on_cost(core);
                            }
                        }
                    }
                    t = dram.access(base + p as u64, ReqKind::Read, t, false);
                    done = t;
                    first = false;
                    if p == actual_loc {
                        break;
                    }
                }
                // train the LCT with the layout the markers revealed
                self.llp.update(page, actual);
                let installs = self.installs_for(base, actual, actual_loc, line);
                ReadOutcome { done, installs }
            }
        }
    }

    /// Lines recovered by reading physical slot `loc` of the group — the
    /// demanded line plus bandwidth-free prefetches.
    fn installs_for(&mut self, base: u64, csi: Csi, loc: u8, demanded: u64) -> Installs {
        let mut v = Installs::new();
        for &s in csi.colocated(loc) {
            let la = base + s as u64;
            let prefetch = la != demanded;
            if prefetch {
                self.prefetch_installed += 1;
            }
            v.push(Install { line_addr: la, level: csi.level_of(s), prefetch, size: 0 });
        }
        // The demanded line is always recoverable at `loc` by construction.
        debug_assert!(v.iter().any(|i| i.line_addr == demanded));
        v
    }

    /// A previously-prefetched line was demanded for the first time —
    /// Dynamic-CRAM's bandwidth-benefit event (§VI-A).
    pub fn on_prefetch_used(&mut self, core: usize, sampled: bool) {
        self.prefetch_used += 1;
        if sampled {
            if let Some(d) = self.dynamic.as_mut() {
                d.on_benefit(core);
            }
        }
    }

    /// Handle a ganged eviction: `gang` holds every group member that was
    /// resident (all forced out together).  Decides the new layout, issues
    /// the writes/invalidates, and updates metadata/LLP state.
    ///
    /// `sampled` = the group maps to sampled LLC sets (always compress,
    /// train counters); non-sampled sets follow the per-core counter.
    pub fn writeback(
        &mut self,
        gang: &[crate::cache::Evicted],
        now: u64,
        dram: &mut DramSim,
        oracle: &mut SizeOracle,
        sampled: bool,
    ) {
        if gang.is_empty() {
            return;
        }
        if matches!(self.design, Design::Tiered { .. }) {
            let tier = self.tier.as_mut().expect("tiered design has a tier");
            tier.writeback(gang, now, dram, oracle, &mut self.bw);
            return;
        }
        let (base, present, dirty) = gang_masks(gang);
        let old = self.csi_of(base);

        if !self.design.compresses() {
            // Baselines: dirty lines write back raw; clean lines drop.
            for s in 0..4 {
                if present[s] && dirty[s] {
                    self.bw.demand_writes += 1;
                    dram.access(base + s as u64, ReqKind::Write, now, false);
                }
            }
            return;
        }

        if self.design == Design::Ideal {
            // No write-side overheads: baseline write behaviour, layout
            // tracked implicitly via the oracle (reads recompute it).
            for s in 0..4 {
                if present[s] && dirty[s] {
                    self.bw.demand_writes += 1;
                    dram.access(base + s as u64, ReqKind::Write, now, false);
                }
            }
            return;
        }

        // Anything dirty? If the whole gang is clean and the layout is not
        // changing, nothing needs to touch memory (it's all clean drops) —
        // unless compression wants to newly pack clean lines.
        let owner_core = gang[0].core as usize;
        let compress = match (&self.design, &self.dynamic) {
            (Design::Dynamic, Some(d)) => sampled || d.enabled(owner_core),
            _ => true,
        };

        // Fast path: compression disabled and the group was never packed —
        // plain dirty writebacks, no compressibility analysis needed.
        if !compress && old == Csi::Uncompressed {
            for s in 0..4 {
                if present[s] && dirty[s] {
                    oracle.dirty_update(base + s as u64);
                    self.bw.demand_writes += 1;
                    dram.access(base + s as u64, ReqKind::Write, now, false);
                }
            }
            return;
        }

        // Dirty stores changed data: re-roll compressibility of dirty lines.
        for s in 0..4 {
            if present[s] && dirty[s] {
                oracle.dirty_update(base + s as u64);
            }
        }
        let sizes = oracle.group_sizes(base);

        // Decide the new layout under residency constraints (can only pack
        // lines we actually hold — ganged eviction guarantees packed peers
        // travel together, so halves are never split).
        let ab_touched = present[0] || present[1];
        let cd_touched = present[2] || present[3];
        let dirty_ab = dirty[0] || dirty[1];
        let dirty_cd = dirty[2] || dirty[3];

        let new = if compress {
            decide_packed_layout(old, present, sizes)
        } else {
            // Compression disabled (Dynamic-CRAM): stop *creating* packed
            // data but leave existing packed data alone — clean evictions
            // of packed groups drop for free; only dirty data forces the
            // affected half (or the whole quad) to unpack.
            match old {
                Csi::Quad => {
                    if dirty_ab || dirty_cd {
                        Csi::Uncompressed
                    } else {
                        Csi::Quad
                    }
                }
                _ => {
                    let ab_packed_old = matches!(old, Csi::PairAb | Csi::PairBoth);
                    let cd_packed_old = matches!(old, Csi::PairCd | Csi::PairBoth);
                    let new_ab = ab_packed_old && !(ab_touched && dirty_ab);
                    let new_cd = cd_packed_old && !(cd_touched && dirty_cd);
                    match (new_ab, new_cd) {
                        (true, true) => Csi::PairBoth,
                        (true, false) => Csi::PairAb,
                        (false, true) => Csi::PairCd,
                        (false, false) => Csi::Uncompressed,
                    }
                }
            }
        };

        // Issue writes per physical slot.
        self.groups_written += 1;
        if new != Csi::Uncompressed {
            self.groups_compressed += 1;
        }
        for loc in 0..4u8 {
            let addr = base + loc as u64;
            let old_res = old.colocated(loc);
            let new_res = new.colocated(loc);
            if new_res.is_empty() {
                // stale under the new layout: invalidate if it was live
                if !old_res.is_empty() {
                    self.bw.invalidates += 1;
                    if sampled {
                        if let Some(d) = self.dynamic.as_mut() {
                            d.on_cost(core_of(gang, base, loc, owner_core));
                        }
                    }
                    dram.access(addr, ReqKind::Invalidate, now, false);
                }
                continue;
            }
            if new_res.len() > 1 {
                // packed block: one write; if every member is clean this is
                // pure compression overhead (the baseline wrote nothing)
                let any_dirty = new_res.iter().any(|&s| dirty[s as usize]);
                // If the half keeps its old packed layout and nothing in it
                // was dirtied, the block already sits in memory byte-for-
                // byte: no write needed (clean re-eviction of packed data).
                if !any_dirty && layout_half_same(old, new, loc) {
                    continue;
                }
                if any_dirty {
                    self.bw.demand_writes += 1;
                } else {
                    self.bw.clean_writes += 1;
                    if sampled {
                        if let Some(d) = self.dynamic.as_mut() {
                            d.on_cost(owner_core);
                        }
                    }
                }
                dram.access(addr, ReqKind::Write, now, false);
            } else {
                let s = new_res[0] as usize;
                // single line at its home: write if dirty, or if the line
                // is being relocated back (its old location differs), or if
                // this slot previously held a packed block that must be
                // overwritten so its marker stops matching
                let relocated =
                    old.location(s as u8) != loc || old.colocated(loc).len() > 1;
                if dirty[s] {
                    self.bw.demand_writes += 1;
                    dram.access(addr, ReqKind::Write, now, false);
                } else if relocated && present[s] {
                    // clean line restored to its home during an unpack:
                    // overhead write
                    self.bw.clean_writes += 1;
                    if sampled {
                        if let Some(d) = self.dynamic.as_mut() {
                            d.on_cost(owner_core);
                        }
                    }
                    dram.access(addr, ReqKind::Write, now, false);
                }
            }
        }

        if new == old && !self.mem_csi.contains(group_of(base)) && new == Csi::Uncompressed {
            // nothing to record
        } else {
            self.mem_csi.insert(group_of(base), new);
        }

        // Explicit designs must persist the CSI change to the metadata
        // region (dirty-allocate in the metadata cache; misses and dirty
        // victims cost DRAM accesses).  An unchanged CSI needs no update
        // (the controller knows the prior level from the LLC tag bits).
        if new != old {
            if let Some(meta) = self.meta.as_mut() {
                let row_opt = meta.row_optimized;
                let meta_addr = meta.meta_addr_for(base);
                let before_wb = meta.writebacks;
                let how = meta.update(base, new);
                if how == MetaAccess::Miss {
                    self.bw.meta_reads += 1;
                    dram.access(meta_addr, ReqKind::MetaRead, now, row_opt);
                }
                if meta.writebacks > before_wb {
                    self.bw.meta_writes += 1;
                    dram.access(meta_addr, ReqKind::MetaWrite, now, row_opt);
                }
            }
        }

        // Keep the LLP trained on write-side layout changes too.
        if matches!(self.design, Design::Implicit | Design::Dynamic) {
            self.llp.update(page_of_line(base), new);
        }
    }

    /// Fraction of written groups that ended up compressed.
    pub fn compression_frac(&self) -> f64 {
        if self.groups_written == 0 {
            0.0
        } else {
            self.groups_compressed as f64 / self.groups_written as f64
        }
    }

    /// Probability that a pair / quad of adjacent lines fits the packing
    /// budget under this oracle (Fig. 4 harness).
    pub fn pair_quad_compressibility(
        oracle: &mut SizeOracle,
        n_groups: u64,
    ) -> (f64, f64, f64) {
        let mut pair60 = 0u64;
        let mut pair64 = 0u64;
        let mut quad60 = 0u64;
        for g in 0..n_groups {
            let sizes = oracle.group_sizes(g * 4);
            if sizes[0] + sizes[1] <= 60 {
                pair60 += 1;
            }
            if sizes[0] + sizes[1] <= 64 {
                pair64 += 1;
            }
            if sizes.iter().sum::<u32>() <= 60 {
                quad60 += 1;
            }
        }
        (
            pair64 as f64 / n_groups as f64,
            pair60 as f64 / n_groups as f64,
            quad60 as f64 / n_groups as f64,
        )
    }
}

/// Which core to charge for an invalidate: the evictee that owned the
/// stale slot if identifiable, else the gang owner.
fn core_of(gang: &[crate::cache::Evicted], base: u64, loc: u8, fallback: usize) -> usize {
    gang.iter()
        .find(|e| e.line_addr == base + loc as u64)
        .map(|e| e.core as usize)
        .unwrap_or(fallback)
}

/// Gang preamble shared by the host controller and the far-tier engine:
/// the group base plus per-slot present/dirty masks.  Panics on an empty
/// gang (both callers check first).
pub(crate) fn gang_masks(gang: &[crate::cache::Evicted]) -> (u64, [bool; 4], [bool; 4]) {
    let base = group_base(gang[0].line_addr);
    debug_assert!(gang.iter().all(|e| group_base(e.line_addr) == base));
    let mut present = [false; 4];
    let mut dirty = [false; 4];
    for e in gang {
        let s = (e.line_addr - base) as usize;
        present[s] = true;
        dirty[s] |= e.dirty;
    }
    (base, present, dirty)
}

/// The packing decision under residency constraints: pack whatever fits
/// among resident lines; halves with no resident members keep their old
/// arrangement (ganged eviction guarantees packed peers travel together,
/// so halves are never split).  Shared by the host-side controller and
/// the far-tier CRAM engine ([`crate::tier::memory`]).
pub(crate) fn decide_packed_layout(old: Csi, present: [bool; 4], sizes: [u32; 4]) -> Csi {
    let budget = crate::compress::PACK_BUDGET;
    let all4 = present.iter().all(|&p| p);
    let quad_ok = all4 && sizes.iter().sum::<u32>() <= budget;
    let pair_ab_ok = present[0] && present[1] && sizes[0] + sizes[1] <= budget;
    let pair_cd_ok = present[2] && present[3] && sizes[2] + sizes[3] <= budget;
    let old_ab_packed = matches!(old, Csi::PairAb | Csi::PairBoth | Csi::Quad);
    let old_cd_packed = matches!(old, Csi::PairCd | Csi::PairBoth | Csi::Quad);
    let new_ab = if present[0] || present[1] {
        pair_ab_ok
    } else {
        old_ab_packed
    };
    let new_cd = if present[2] || present[3] {
        pair_cd_ok
    } else {
        old_cd_packed
    };
    if quad_ok {
        Csi::Quad
    } else {
        match (new_ab, new_cd) {
            (true, true) => Csi::PairBoth,
            (true, false) => Csi::PairAb,
            (false, true) => Csi::PairCd,
            (false, false) => Csi::Uncompressed,
        }
    }
}

/// Is the half containing physical slot `loc` laid out identically in
/// `old` and `new`?  (Shared with the far-tier CRAM engine.)
pub(crate) fn layout_half_same(old: Csi, new: Csi, loc: u8) -> bool {
    let half = loc / 2;
    let packed = |c: Csi| match (c, half) {
        (Csi::Quad, _) => 2u8,
        (Csi::PairAb, 0) | (Csi::PairBoth, 0) => 1,
        (Csi::PairCd, 1) | (Csi::PairBoth, 1) => 1,
        _ => 0,
    };
    packed(old) == packed(new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Evicted;
    use crate::dram::DramConfig;
    use crate::workloads::{SizeOracle, ValueModel};

    fn setup(design: Design) -> (MemoryController, DramSim, SizeOracle) {
        let mc = MemoryController::new(design, 8, 1 << 28);
        let dram = DramSim::new(DramConfig::default());
        // all-SmallInt pages: every group packs 4:1
        let oracle = SizeOracle::new(ValueModel::new([0.0, 1.0, 0.0, 0.0, 0.0], 7));
        (mc, dram, oracle)
    }

    fn incompressible_oracle() -> SizeOracle {
        SizeOracle::new(ValueModel::new([0.0, 0.0, 0.0, 0.0, 1.0], 9))
    }

    fn gang(base: u64, dirty_mask: [bool; 4]) -> Vec<Evicted> {
        (0..4)
            .map(|i| Evicted {
                line_addr: base + i as u64,
                dirty: dirty_mask[i],
                level: 0,
                core: 0,
                referenced: true,
                was_prefetch: false,
            })
            .collect()
    }

    #[test]
    fn uncompressed_read_installs_one_line() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Uncompressed);
        let r = mc.read(5, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 1);
        assert_eq!(mc.bw.demand_reads, 1);
        assert_eq!(dram.stats.reads, 1);
    }

    #[test]
    fn quad_writeback_one_write_three_invalidates() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true, false, false, false]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Quad);
        assert_eq!(mc.bw.demand_writes, 1); // one packed block (dirty member)
        assert_eq!(mc.bw.invalidates, 3); // slots 1-3 were live before
        assert_eq!(dram.stats.writes, 1);
        assert_eq!(dram.stats.invalidates, 3);
    }

    #[test]
    fn compressed_read_prefetches_group() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        // LLP trained by the writeback: predicts Quad, so reading line 2
        // goes straight to slot 0 and returns all four lines.
        let r = mc.read(2, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4);
        assert_eq!(mc.bw.second_reads, 0, "trained LLP: no second access");
        assert_eq!(r.installs.iter().filter(|i| i.prefetch).count(), 3);
        assert!(r.installs.iter().all(|i| i.level == 2));
    }

    #[test]
    fn untrained_llp_pays_second_access() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        // poison the LCT: pretend this page was last seen uncompressed
        mc.llp.update(0, Csi::Uncompressed);
        let r = mc.read(1, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.second_reads, 1, "mispredicted: slot1 then slot0");
        assert_eq!(r.installs.len(), 4);
        assert_eq!(mc.llp.stats.accuracy(), Some(0.0));
    }

    #[test]
    fn clean_eviction_of_compressible_group_costs_clean_write() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        // packing clean lines: overhead the baseline wouldn't pay
        assert_eq!(mc.bw.clean_writes, 1);
        assert_eq!(mc.bw.demand_writes, 0);
        assert_eq!(mc.bw.invalidates, 3);
    }

    #[test]
    fn uncompressed_baseline_drops_clean_lines() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Uncompressed);
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.demand_writes + mc.bw.clean_writes, 0);
        assert_eq!(dram.stats.total_accesses(), 0);
    }

    #[test]
    fn incompressible_group_stays_uncompressed() {
        let (mut mc, mut dram, mut oracle_) = setup(Design::Implicit);
        let mut oracle = incompressible_oracle();
        let _ = &mut oracle_;
        mc.writeback(&gang(0, [true, true, false, false]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
        assert_eq!(mc.bw.demand_writes, 2); // two dirty raw lines
        assert_eq!(mc.bw.invalidates, 0);
        assert_eq!(mc.bw.clean_writes, 0);
    }

    #[test]
    fn layout_transition_packs_then_unpacks() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Quad);
        // dirty rewrites change values; with an incompressible oracle the
        // group must unpack: all four written raw, stale slots restored
        let mut bad = incompressible_oracle();
        mc.writeback(&gang(0, [true; 4]), 1000, &mut dram, &mut bad, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
    }

    #[test]
    fn dynamic_gates_compression_by_counter() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Dynamic);
        // hammer costs on core 0 via sampled activity
        for _ in 0..3000 {
            mc.dynamic.as_mut().unwrap().on_cost(0);
        }
        assert!(!mc.dynamic.as_ref().unwrap().enabled(0));
        // non-sampled set: compression disabled -> clean gang drops
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
        assert_eq!(mc.bw.clean_writes, 0);
        // sampled set: always compresses
        mc.writeback(&gang(8, [false; 4]), 0, &mut dram, &mut oracle, true);
        assert_eq!(mc.csi_of(8), Csi::Quad);
    }

    #[test]
    fn explicit_charges_metadata_traffic() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Explicit { row_opt: false });
        // first read: metadata cache cold -> metadata read + data read
        let r = mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1);
        assert_eq!(mc.bw.demand_reads, 1);
        assert!(r.done > 0);
        // second read of a neighbor: metadata cached
        mc.read(4, 0, r.done, &mut dram, &mut oracle, false);
        assert_eq!(mc.bw.meta_reads, 1);
        assert_eq!(mc.meta.as_ref().unwrap().hits, 1);
    }

    #[test]
    fn prefetch_baseline_costs_extra_reads() {
        let (mut mc, mut dram, mut oracle) = setup(Design::NextLinePrefetch);
        let r = mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 2);
        assert_eq!(mc.bw.prefetch_reads, 1);
        assert_eq!(dram.stats.reads, 2);
    }

    #[test]
    fn ideal_no_write_overheads() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Ideal);
        mc.writeback(&gang(0, [false; 4]), 0, &mut dram, &mut oracle, false);
        assert_eq!(dram.stats.total_accesses(), 0);
        let r = mc.read(1, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4, "free co-fetch");
        assert_eq!(mc.bw.second_reads, 0);
    }

    #[test]
    fn row_opt_metadata_reads_are_row_hits() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Explicit { row_opt: true });
        mc.read(0, 0, 0, &mut dram, &mut oracle, false);
        // the metadata access must have been a forced row hit
        assert!(dram.stats.row_hits >= 1);
        assert_eq!(mc.bw.meta_reads, 1);
    }

    #[test]
    fn prefetch_benefit_feeds_dynamic_counter() {
        let (mut mc, _dram, _oracle) = setup(Design::Dynamic);
        let before = mc.dynamic.as_ref().unwrap().counter(2);
        mc.on_prefetch_used(2, true);
        assert_eq!(mc.dynamic.as_ref().unwrap().counter(2), before + 1);
        // non-sampled: counted as used, not as counter training
        mc.on_prefetch_used(2, false);
        assert_eq!(mc.dynamic.as_ref().unwrap().counter(2), before + 1);
        assert_eq!(mc.prefetch_used, 2);
    }

    #[test]
    fn dynamic_disabled_keeps_packed_data_on_clean_evict() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Dynamic);
        // pack while enabled (sampled path)
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, true);
        assert_eq!(mc.csi_of(0), Csi::Quad);
        // disable, then clean-evict the group: data must STAY packed and
        // cost nothing
        for _ in 0..200 {
            mc.dynamic.as_mut().unwrap().on_cost(0);
        }
        let writes_before = dram.stats.total_accesses();
        mc.writeback(&gang(0, [false; 4]), 100, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Quad, "clean drop keeps packed layout");
        assert_eq!(dram.stats.total_accesses(), writes_before, "no traffic");
        // a dirty evict while disabled unpacks
        mc.writeback(&gang(0, [true, false, false, false]), 200, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::Uncompressed);
    }

    #[test]
    fn second_access_serializes_latency() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.llp.update(0, Csi::Uncompressed); // poison -> mispredict
        let t0 = 1000;
        let r = mc.read(1, 0, t0, &mut dram, &mut oracle, false);
        // two serialized reads: strictly more than one access latency
        assert!(r.done > t0 + 22, "done {} vs issue {t0}", r.done);
    }

    #[test]
    fn read_latency_recorded_once_per_demand_read() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        mc.llp.update(0, Csi::Uncompressed); // poison -> second probe
        mc.read(1, 0, 1000, &mut dram, &mut oracle, false);
        mc.read(2, 0, 2000, &mut dram, &mut oracle, false);
        assert_eq!(mc.read_lat.count(), mc.bw.demand_reads, "one sample per read");
        // the mispredicted read's serialized probes land in the tail
        assert!(mc.read_lat.percentile(1.0) > 22.0);
    }

    #[test]
    fn compressibility_probe_reports_sane_fractions() {
        let mut zero_oracle =
            SizeOracle::new(ValueModel::new([1.0, 0.0, 0.0, 0.0, 0.0], 3));
        let (p64, p60, q60) =
            MemoryController::pair_quad_compressibility(&mut zero_oracle, 512);
        assert!(p64 >= p60, "60B budget can't beat 64B");
        assert!(p60 > 0.95 && q60 > 0.95, "zero pages always pack");
        let mut rnd = incompressible_oracle();
        let (p64, p60, q60) = MemoryController::pair_quad_compressibility(&mut rnd, 512);
        assert_eq!((p64, p60, q60), (0.0, 0.0, 0.0));
    }

    #[test]
    fn tiered_controller_routes_and_accounts_per_tier() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Tiered { far_compressed: true });
        // find one near and one far group under the default 50/50 split
        let tier = mc.tier.as_ref().unwrap();
        let near_line = (0..100_000u64).find(|&l| !tier.is_far_line(l)).unwrap();
        let far_line = (0..100_000u64).find(|&l| tier.is_far_line(l)).unwrap();
        let rn = mc.read(near_line, 0, 0, &mut dram, &mut oracle, false);
        let rf = mc.read(far_line, 0, 0, &mut dram, &mut oracle, false);
        assert_eq!(rn.installs.len(), 1, "near tier is uncompressed");
        assert!(rf.done > rn.done, "far read pays the link");
        // pack a far group, then a read co-fetches it
        mc.writeback(
            &gang(group_base(far_line), [true; 4]),
            100,
            &mut dram,
            &mut oracle,
            false,
        );
        let r = mc.read(group_base(far_line) + 1, 0, 1000, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4, "packed far block co-fetches the group");
        assert!(mc.prefetch_installed >= 3);
        // per-tier counters account for every access the controller charged
        let stats = mc.tier.as_ref().unwrap().snapshot();
        assert_eq!(stats.total_accesses(), mc.bw.total());
    }

    #[test]
    fn compressed_llc_mode_stamps_install_sizes() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        mc.llc_compressed = true;
        mc.writeback(&gang(0, [true; 4]), 0, &mut dram, &mut oracle, false);
        let r = mc.read(2, 0, 100, &mut dram, &mut oracle, false);
        assert_eq!(r.installs.len(), 4);
        for i in r.installs.iter() {
            assert!(
                (2..=64).contains(&i.size),
                "compressed-LLC install must carry a real size, got {}",
                i.size
            );
            assert_eq!(i.size as u32, oracle.size(i.line_addr));
        }
        // with the knob off, sizes stay 0 (the plain LLC never reads them)
        let (mut mc2, mut dram2, mut oracle2) = setup(Design::Implicit);
        let r2 = mc2.read(2, 0, 0, &mut dram2, &mut oracle2, false);
        assert!(r2.installs.iter().all(|i| i.size == 0));
    }

    #[test]
    fn tiered_names_resolve_both_ways() {
        assert_eq!(Design::Tiered { far_compressed: false }.name(), "tiered-uncomp");
        assert_eq!(Design::Tiered { far_compressed: true }.name(), "tiered-cram");
        assert!(!Design::Tiered { far_compressed: true }.compresses());
    }

    #[test]
    fn partial_gang_preserves_other_half() {
        let (mut mc, mut dram, mut oracle) = setup(Design::Implicit);
        // pack CD only: evict gang of just C,D (A,B never resident)
        let cd: Vec<Evicted> = (2..4)
            .map(|i| Evicted {
                line_addr: i,
                dirty: true,
                level: 0,
                core: 0,
                referenced: true,
                was_prefetch: false,
            })
            .collect();
        mc.writeback(&cd, 0, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::PairCd);
        // now evict A alone (clean, incompressible pairing impossible
        // since B absent): CD half must stay packed
        let a = vec![Evicted {
            line_addr: 0,
            dirty: true,
            level: 0,
            core: 0,
            referenced: true,
            was_prefetch: false,
        }];
        mc.writeback(&a, 10, &mut dram, &mut oracle, false);
        assert_eq!(mc.csi_of(0), Csi::PairCd);
    }
}
