//! The composable design space: **compression policy** × **placement**
//! × **link codec**.
//!
//! The paper's designs (explicit metadata, implicit-marker CRAM, dynamic
//! cost/benefit gating) are orthogonal to *where* the compressed memory
//! lives — and both are orthogonal to whether traffic is compressed *in
//! flight* over the expander link.  This module makes that orthogonality
//! a type: a [`Design`] is a [`Policy`] (what compression machinery
//! runs) composed with a [`Placement`] (flat DDR vs a tiered CXL
//! expander) and a [`LinkCodec`] (raw vs compressed flits on the wire),
//! and every scenario the related work studies — IBEX-style dynamic
//! gating on an expander, Pekhimenko-style explicit metadata on far
//! memory, ZeroPoint-style in-flight CXL compression — is a one-line
//! composition instead of a new enum arm.
//!
//! With [`Placement::Flat`] the policy runs at the host memory
//! controller over all of DRAM.  With [`Placement::Tiered`] the near
//! tier is always plain DDR and the policy runs on the far expander
//! (where the narrow link makes compression pay) — see
//! [`crate::tier::memory`].  [`LinkCodec::Compressed`] additionally runs
//! the TX-side size-only compressor pass on every link payload, so
//! transfers occupy fewer flit cycles at the cost of a decompression
//! latency at the receiving port; on flat placements there is no link
//! and the codec is a no-op.
//!
//! **Compatibility facade.**  `Design` keeps associated constants named
//! after the pre-refactor enum variants (`Design::Uncompressed`,
//! `Design::Implicit`, …) and constructor shorthands
//! ([`Design::explicit`], [`Design::tiered`]), so call sites, CLI
//! strings, `ResultsDb` keys and figure outputs are unchanged: every
//! pre-existing [`Design::name`] maps to the same composition the old
//! enum arm implemented, with [`LinkCodec::Raw`] as the default third
//! field.  Names follow a `policy-placement[+lc]` grammar — the `+lc`
//! suffix selects the compressed link codec — and [`Design::parse`]
//! round-trips every composition (pinned by the
//! `design_names_round_trip` test).

/// The compression policy: which machinery runs at the controller that
/// owns the (flat or far) compressed memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// No compression — the baseline of every figure.
    Uncompressed,
    /// Fig. 3 "ideal": all co-fetch benefits, no overheads.
    Ideal,
    /// CRAM + explicit metadata region + metadata cache (Fig. 7/8/12);
    /// `row_opt` co-locates metadata with the data row (Fig. 20).
    Explicit { row_opt: bool },
    /// Static-CRAM: implicit marker metadata (+ LLP on the flat host,
    /// device-held layouts on an expander).
    Implicit,
    /// Static-CRAM + set-sampled cost/benefit gating (§VI).
    Dynamic,
    /// Next-line prefetch baseline (Table V): the bandwidth cost CRAM's
    /// free co-fetches avoid.
    NextLinePrefetch,
    /// LCP-style page-granular compression (Pekhimenko, MICRO'13): one
    /// *target* compressed size per page, fixed line offset = slot ×
    /// target, an exception region for incompressible lines, and a
    /// page-table-resident descriptor (modeled as an explicit host-side
    /// metadata cache).  The predictable offset needs no line-location
    /// predictor, and LCP is the first policy where *effective capacity*
    /// grows, not just bandwidth — see
    /// [`crate::stats::CapacityStats`].
    Lcp,
}

/// Where the (potentially compressed) memory lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// One flat DDR memory behind the host controller.
    Flat,
    /// Near DDR + far CXL expander ([`crate::tier`]); the policy runs on
    /// the expander, the near tier stays uncompressed.
    Tiered,
}

/// Whether payloads are compressed *in flight* over the expander link,
/// independent of how lines are stored (IBEX / ZeroPoint CXL style).
///
/// `Compressed` runs the TX-side size-only compressor pass
/// ([`crate::workloads::SizeOracle::size`] — the PR 3 fast path, so the
/// pass is nearly free) on every data payload crossing
/// [`crate::tier::CxlLink`], serializing only the compressed bytes and
/// paying a fixed decompression latency at the receiving port.  Command
/// and metadata flits shrink too (header compression — address deltas
/// and opcode packing halve the 8B command flit), but header decode is
/// pipelined in the port, so only *data* payloads pay the decompression
/// latency.  On [`Placement::Flat`] designs there is no link, so the
/// codec composes validly but changes nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkCodec {
    /// Every payload crosses the link at its storage size (default).
    Raw,
    /// TX compresses payloads; RX pays a decompression latency.
    Compressed,
}

/// A memory-system design: one policy at one placement over one link
/// codec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Design {
    pub policy: Policy,
    pub placement: Placement,
    pub link_codec: LinkCodec,
}

/// Pre-refactor spellings (`Design::Uncompressed`, `Design::Dynamic`, …)
/// stay valid: the enum variants became associated constants over the
/// composition.
#[allow(non_upper_case_globals)]
impl Design {
    pub const Uncompressed: Design = Design::flat(Policy::Uncompressed);
    pub const Ideal: Design = Design::flat(Policy::Ideal);
    pub const Implicit: Design = Design::flat(Policy::Implicit);
    pub const Dynamic: Design = Design::flat(Policy::Dynamic);
    pub const NextLinePrefetch: Design = Design::flat(Policy::NextLinePrefetch);
}

impl Design {
    pub const fn new(policy: Policy, placement: Placement) -> Design {
        Design { policy, placement, link_codec: LinkCodec::Raw }
    }

    pub const fn flat(policy: Policy) -> Design {
        Design::new(policy, Placement::Flat)
    }

    /// Flat CRAM with an explicit metadata region (`Design::Explicit` of
    /// the pre-refactor enum).
    pub const fn explicit(row_opt: bool) -> Design {
        Design::flat(Policy::Explicit { row_opt })
    }

    /// The pre-refactor `Design::Tiered { far_compressed }`: an
    /// uncompressed far tier, or the IBEX-style always-on far CRAM
    /// (device-held metadata = the `Implicit` policy on the expander).
    pub const fn tiered(far_compressed: bool) -> Design {
        Design::new(
            if far_compressed { Policy::Implicit } else { Policy::Uncompressed },
            Placement::Tiered,
        )
    }

    /// The same policy × placement under a different link codec — the
    /// third-axis constructor: `Design::tiered(true).with_link_codec(
    /// LinkCodec::Compressed)` is tiered CRAM over a compressed link.
    pub const fn with_link_codec(mut self, link_codec: LinkCodec) -> Design {
        self.link_codec = link_codec;
        self
    }

    #[inline]
    pub fn is_tiered(&self) -> bool {
        self.placement == Placement::Tiered
    }

    /// Does this design compress payloads on the wire?
    #[inline]
    pub fn link_compressed(&self) -> bool {
        self.link_codec == LinkCodec::Compressed
    }

    /// Every policy × placement pair, flat designs first (paper order),
    /// then the tiered cross-product — all under [`LinkCodec::Raw`].
    const BASE: [Design; 16] = [
        Design::Uncompressed,
        Design::Ideal,
        Design::explicit(false),
        Design::explicit(true),
        Design::Implicit,
        Design::Dynamic,
        Design::NextLinePrefetch,
        Design::tiered(false),
        Design::tiered(true),
        Design::new(Policy::Dynamic, Placement::Tiered),
        Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
        Design::new(Policy::Explicit { row_opt: true }, Placement::Tiered),
        Design::new(Policy::Ideal, Placement::Tiered),
        Design::new(Policy::NextLinePrefetch, Placement::Tiered),
        Design::flat(Policy::Lcp),
        Design::new(Policy::Lcp, Placement::Tiered),
    ];

    /// Every valid composition: the 16 raw-link pairs in their
    /// historical order (LCP appended after the original 14), then the
    /// same 16 over the compressed link.
    pub fn all() -> [Design; 32] {
        let mut out = [Design::Uncompressed; 32];
        let mut i = 0;
        while i < 16 {
            out[i] = Self::BASE[i];
            out[i + 16] = Self::BASE[i].with_link_codec(LinkCodec::Compressed);
            i += 1;
        }
        out
    }

    /// The policy × placement part of the name — the historical spelling.
    const fn base_name(&self) -> &'static str {
        match (self.placement, self.policy) {
            (Placement::Flat, Policy::Uncompressed) => "uncompressed",
            (Placement::Flat, Policy::Ideal) => "ideal",
            (Placement::Flat, Policy::Explicit { row_opt: false }) => "cram-explicit",
            (Placement::Flat, Policy::Explicit { row_opt: true }) => "cram-explicit-rowopt",
            (Placement::Flat, Policy::Implicit) => "cram-static",
            (Placement::Flat, Policy::Dynamic) => "cram-dynamic",
            (Placement::Flat, Policy::NextLinePrefetch) => "nextline-prefetch",
            (Placement::Flat, Policy::Lcp) => "lcp",
            (Placement::Tiered, Policy::Uncompressed) => "tiered-uncomp",
            (Placement::Tiered, Policy::Implicit) => "tiered-cram",
            (Placement::Tiered, Policy::Dynamic) => "tiered-cram-dyn",
            (Placement::Tiered, Policy::Explicit { row_opt: false }) => "tiered-explicit",
            (Placement::Tiered, Policy::Explicit { row_opt: true }) => {
                "tiered-explicit-rowopt"
            }
            (Placement::Tiered, Policy::Ideal) => "tiered-ideal",
            (Placement::Tiered, Policy::NextLinePrefetch) => "tiered-nextline",
            (Placement::Tiered, Policy::Lcp) => "tiered-lcp",
        }
    }

    /// Canonical CLI / `ResultsDb` name, following the
    /// `policy-placement[+lc]` grammar.  Total over the cross-product;
    /// every pre-existing (raw-link) name is byte-identical to the enum
    /// era, and the `+lc` suffix selects [`LinkCodec::Compressed`].
    /// Stays `&'static str` so [`crate::coordinator::runner::RunKey`]
    /// keys never allocate.
    pub fn name(&self) -> &'static str {
        match self.link_codec {
            LinkCodec::Raw => self.base_name(),
            LinkCodec::Compressed => match self.base_name() {
                "uncompressed" => "uncompressed+lc",
                "ideal" => "ideal+lc",
                "cram-explicit" => "cram-explicit+lc",
                "cram-explicit-rowopt" => "cram-explicit-rowopt+lc",
                "cram-static" => "cram-static+lc",
                "cram-dynamic" => "cram-dynamic+lc",
                "nextline-prefetch" => "nextline-prefetch+lc",
                "tiered-uncomp" => "tiered-uncomp+lc",
                "tiered-cram" => "tiered-cram+lc",
                "tiered-cram-dyn" => "tiered-cram-dyn+lc",
                "tiered-explicit" => "tiered-explicit+lc",
                "tiered-explicit-rowopt" => "tiered-explicit-rowopt+lc",
                "tiered-ideal" => "tiered-ideal+lc",
                "lcp" => "lcp+lc",
                "tiered-lcp" => "tiered-lcp+lc",
                _ => "tiered-nextline+lc",
            },
        }
    }

    /// Inverse of [`Design::name`] — the single parser behind `--design`
    /// (None for an unknown name).  Accepts the `policy-placement[+lc]`
    /// grammar: a `+lc` suffix selects the compressed link codec over
    /// any base composition.
    pub fn parse(name: &str) -> Option<Design> {
        let (base, codec) = match name.strip_suffix("+lc") {
            Some(base) => (base, LinkCodec::Compressed),
            None => (name, LinkCodec::Raw),
        };
        Self::BASE
            .into_iter()
            .find(|d| d.base_name() == base)
            .map(|d| d.with_link_codec(codec))
    }

    /// Does the *host-side* controller pack groups in DRAM?  Tiered
    /// designs never pack on the host side — the far expander runs its
    /// own engine (see [`crate::tier::TieredMemory`]).  The link codec
    /// is irrelevant here: it compresses transfers, never storage.
    pub fn compresses(&self) -> bool {
        self.placement == Placement::Flat
            && !matches!(self.policy, Policy::Uncompressed | Policy::NextLinePrefetch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_names_round_trip() {
        // every composed design parses back from the exact string name()
        // emits — figures, ResultsDb keys, and --design can never drift
        for d in Design::all() {
            assert_eq!(Design::parse(d.name()), Some(d), "{}", d.name());
        }
        assert_eq!(Design::parse("no-such-design"), None);
        assert_eq!(Design::parse("no-such-design+lc"), None);
        assert_eq!(Design::parse("+lc"), None);
        assert_eq!(Design::parse(""), None);
        assert_eq!(Design::parse("cram-static+lc+lc"), None);
        assert_eq!(Design::parse("CRAM-STATIC"), None, "names are case-sensitive");
    }

    #[test]
    fn design_names_are_unique() {
        let names: Vec<&str> = Design::all().iter().map(|d| d.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate design name: {names:?}");
    }

    #[test]
    fn facade_matches_pre_refactor_names() {
        // the compatibility constants map to the exact historical strings
        assert_eq!(Design::Uncompressed.name(), "uncompressed");
        assert_eq!(Design::Ideal.name(), "ideal");
        assert_eq!(Design::explicit(false).name(), "cram-explicit");
        assert_eq!(Design::explicit(true).name(), "cram-explicit-rowopt");
        assert_eq!(Design::Implicit.name(), "cram-static");
        assert_eq!(Design::Dynamic.name(), "cram-dynamic");
        assert_eq!(Design::NextLinePrefetch.name(), "nextline-prefetch");
        assert_eq!(Design::tiered(false).name(), "tiered-uncomp");
        assert_eq!(Design::tiered(true).name(), "tiered-cram");
    }

    #[test]
    fn raw_link_codec_is_the_default_everywhere() {
        // the third axis defaults off: every pre-existing constructor and
        // constant stays the same composition (and so the same RunKey)
        assert_eq!(Design::Uncompressed.link_codec, LinkCodec::Raw);
        assert_eq!(Design::explicit(true).link_codec, LinkCodec::Raw);
        assert_eq!(Design::tiered(true).link_codec, LinkCodec::Raw);
        assert_eq!(
            Design::new(Policy::Dynamic, Placement::Tiered).link_codec,
            LinkCodec::Raw
        );
        for d in Design::all().into_iter().take(16) {
            assert!(!d.link_compressed(), "{}", d.name());
            assert!(!d.name().ends_with("+lc"));
        }
    }

    #[test]
    fn lc_suffix_grammar_parses_and_prints() {
        let d = Design::parse("tiered-cram+lc").unwrap();
        assert_eq!(d.policy, Policy::Implicit);
        assert_eq!(d.placement, Placement::Tiered);
        assert_eq!(d.link_codec, LinkCodec::Compressed);
        assert_eq!(d.name(), "tiered-cram+lc");
        assert_eq!(
            d.with_link_codec(LinkCodec::Raw),
            Design::tiered(true),
            "stripping the codec recovers the base composition"
        );
        // all 32 compositions exist and split 16/16 by codec
        let all = Design::all();
        assert_eq!(all.len(), 32);
        assert_eq!(all.iter().filter(|d| d.link_compressed()).count(), 16);
    }

    #[test]
    fn new_compositions_exist() {
        let dyn_far = Design::parse("tiered-cram-dyn").unwrap();
        assert_eq!(dyn_far.policy, Policy::Dynamic);
        assert_eq!(dyn_far.placement, Placement::Tiered);
        let expl_far = Design::parse("tiered-explicit").unwrap();
        assert_eq!(expl_far.policy, Policy::Explicit { row_opt: false });
        assert!(expl_far.is_tiered());
        let expl_lc = Design::parse("tiered-explicit+lc").unwrap();
        assert_eq!(expl_lc.policy, Policy::Explicit { row_opt: false });
        assert!(expl_lc.link_compressed());
        // the LCP family round-trips through the same grammar
        let lcp = Design::parse("lcp").unwrap();
        assert_eq!((lcp.policy, lcp.placement), (Policy::Lcp, Placement::Flat));
        assert_eq!(lcp.name(), "lcp");
        let far_lcp = Design::parse("tiered-lcp").unwrap();
        assert_eq!(far_lcp.policy, Policy::Lcp);
        assert!(far_lcp.is_tiered());
        assert_eq!(far_lcp.name(), "tiered-lcp");
        let lcp_lc = Design::parse("tiered-lcp+lc").unwrap();
        assert_eq!(lcp_lc.policy, Policy::Lcp);
        assert!(lcp_lc.link_compressed());
        assert_eq!(lcp_lc.name(), "tiered-lcp+lc");
        assert_eq!(Design::parse("lcp+lc").unwrap().name(), "lcp+lc");
    }

    #[test]
    fn compresses_is_host_side_only() {
        assert!(!Design::Uncompressed.compresses());
        assert!(!Design::NextLinePrefetch.compresses());
        assert!(Design::Implicit.compresses());
        assert!(Design::Dynamic.compresses());
        assert!(Design::explicit(false).compresses());
        assert!(Design::Ideal.compresses());
        assert!(Design::flat(Policy::Lcp).compresses());
        // tiered: the expander packs, the host does not
        for d in Design::all().into_iter().filter(Design::is_tiered) {
            assert!(!d.compresses(), "{}", d.name());
        }
        // the link codec never makes a design "compress" storage
        assert!(!Design::Uncompressed
            .with_link_codec(LinkCodec::Compressed)
            .compresses());
    }
}
