//! The composable design space: **compression policy** × **placement**.
//!
//! The paper's designs (explicit metadata, implicit-marker CRAM, dynamic
//! cost/benefit gating) are orthogonal to *where* the compressed memory
//! lives.  This module makes that orthogonality a type: a [`Design`] is a
//! [`Policy`] (what compression machinery runs) composed with a
//! [`Placement`] (flat DDR vs a tiered CXL expander), and every scenario
//! the related work studies — IBEX-style dynamic gating on an expander,
//! Pekhimenko-style explicit metadata on far memory — is a one-line
//! composition instead of a new enum arm.
//!
//! With [`Placement::Flat`] the policy runs at the host memory
//! controller over all of DRAM.  With [`Placement::Tiered`] the near
//! tier is always plain DDR and the policy runs on the far expander
//! (where the narrow link makes compression pay) — see
//! [`crate::tier::memory`].
//!
//! **Compatibility facade.**  `Design` keeps associated constants named
//! after the pre-refactor enum variants (`Design::Uncompressed`,
//! `Design::Implicit`, …) and constructor shorthands
//! ([`Design::explicit`], [`Design::tiered`]), so call sites, CLI
//! strings, `ResultsDb` keys and figure outputs are unchanged: every
//! pre-existing [`Design::name`] maps to the same composition the old
//! enum arm implemented.  [`Design::parse`] round-trips every name
//! (pinned by the `design_names_round_trip` test).

/// The compression policy: which machinery runs at the controller that
/// owns the (flat or far) compressed memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// No compression — the baseline of every figure.
    Uncompressed,
    /// Fig. 3 "ideal": all co-fetch benefits, no overheads.
    Ideal,
    /// CRAM + explicit metadata region + metadata cache (Fig. 7/8/12);
    /// `row_opt` co-locates metadata with the data row (Fig. 20).
    Explicit { row_opt: bool },
    /// Static-CRAM: implicit marker metadata (+ LLP on the flat host,
    /// device-held layouts on an expander).
    Implicit,
    /// Static-CRAM + set-sampled cost/benefit gating (§VI).
    Dynamic,
    /// Next-line prefetch baseline (Table V): the bandwidth cost CRAM's
    /// free co-fetches avoid.
    NextLinePrefetch,
}

/// Where the (potentially compressed) memory lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// One flat DDR memory behind the host controller.
    Flat,
    /// Near DDR + far CXL expander ([`crate::tier`]); the policy runs on
    /// the expander, the near tier stays uncompressed.
    Tiered,
}

/// A memory-system design: one policy at one placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Design {
    pub policy: Policy,
    pub placement: Placement,
}

/// Pre-refactor spellings (`Design::Uncompressed`, `Design::Dynamic`, …)
/// stay valid: the enum variants became associated constants over the
/// composition.
#[allow(non_upper_case_globals)]
impl Design {
    pub const Uncompressed: Design = Design::flat(Policy::Uncompressed);
    pub const Ideal: Design = Design::flat(Policy::Ideal);
    pub const Implicit: Design = Design::flat(Policy::Implicit);
    pub const Dynamic: Design = Design::flat(Policy::Dynamic);
    pub const NextLinePrefetch: Design = Design::flat(Policy::NextLinePrefetch);
}

impl Design {
    pub const fn new(policy: Policy, placement: Placement) -> Design {
        Design { policy, placement }
    }

    pub const fn flat(policy: Policy) -> Design {
        Design::new(policy, Placement::Flat)
    }

    /// Flat CRAM with an explicit metadata region (`Design::Explicit` of
    /// the pre-refactor enum).
    pub const fn explicit(row_opt: bool) -> Design {
        Design::flat(Policy::Explicit { row_opt })
    }

    /// The pre-refactor `Design::Tiered { far_compressed }`: an
    /// uncompressed far tier, or the IBEX-style always-on far CRAM
    /// (device-held metadata = the `Implicit` policy on the expander).
    pub const fn tiered(far_compressed: bool) -> Design {
        Design::new(
            if far_compressed { Policy::Implicit } else { Policy::Uncompressed },
            Placement::Tiered,
        )
    }

    #[inline]
    pub fn is_tiered(&self) -> bool {
        self.placement == Placement::Tiered
    }

    /// Every valid composition, flat designs first (paper order), then
    /// the tiered cross-product.
    pub fn all() -> [Design; 14] {
        [
            Design::Uncompressed,
            Design::Ideal,
            Design::explicit(false),
            Design::explicit(true),
            Design::Implicit,
            Design::Dynamic,
            Design::NextLinePrefetch,
            Design::tiered(false),
            Design::tiered(true),
            Design::new(Policy::Dynamic, Placement::Tiered),
            Design::new(Policy::Explicit { row_opt: false }, Placement::Tiered),
            Design::new(Policy::Explicit { row_opt: true }, Placement::Tiered),
            Design::new(Policy::Ideal, Placement::Tiered),
            Design::new(Policy::NextLinePrefetch, Placement::Tiered),
        ]
    }

    /// Canonical CLI / `ResultsDb` name.  Total over the cross-product;
    /// every pre-existing name is byte-identical to the enum era.
    pub fn name(&self) -> &'static str {
        match (self.placement, self.policy) {
            (Placement::Flat, Policy::Uncompressed) => "uncompressed",
            (Placement::Flat, Policy::Ideal) => "ideal",
            (Placement::Flat, Policy::Explicit { row_opt: false }) => "cram-explicit",
            (Placement::Flat, Policy::Explicit { row_opt: true }) => "cram-explicit-rowopt",
            (Placement::Flat, Policy::Implicit) => "cram-static",
            (Placement::Flat, Policy::Dynamic) => "cram-dynamic",
            (Placement::Flat, Policy::NextLinePrefetch) => "nextline-prefetch",
            (Placement::Tiered, Policy::Uncompressed) => "tiered-uncomp",
            (Placement::Tiered, Policy::Implicit) => "tiered-cram",
            (Placement::Tiered, Policy::Dynamic) => "tiered-cram-dyn",
            (Placement::Tiered, Policy::Explicit { row_opt: false }) => "tiered-explicit",
            (Placement::Tiered, Policy::Explicit { row_opt: true }) => {
                "tiered-explicit-rowopt"
            }
            (Placement::Tiered, Policy::Ideal) => "tiered-ideal",
            (Placement::Tiered, Policy::NextLinePrefetch) => "tiered-nextline",
        }
    }

    /// Inverse of [`Design::name`] — the single parser behind `--design`
    /// (None for an unknown name).
    pub fn parse(name: &str) -> Option<Design> {
        Design::all().into_iter().find(|d| d.name() == name)
    }

    /// Does the *host-side* controller pack groups in DRAM?  Tiered
    /// designs never pack on the host side — the far expander runs its
    /// own engine (see [`crate::tier::TieredMemory`]).
    pub fn compresses(&self) -> bool {
        self.placement == Placement::Flat
            && !matches!(self.policy, Policy::Uncompressed | Policy::NextLinePrefetch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_names_round_trip() {
        // every composed design parses back from the exact string name()
        // emits — figures, ResultsDb keys, and --design can never drift
        for d in Design::all() {
            assert_eq!(Design::parse(d.name()), Some(d), "{}", d.name());
        }
        assert_eq!(Design::parse("no-such-design"), None);
    }

    #[test]
    fn design_names_are_unique() {
        let names: Vec<&str> = Design::all().iter().map(|d| d.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate design name: {names:?}");
    }

    #[test]
    fn facade_matches_pre_refactor_names() {
        // the compatibility constants map to the exact historical strings
        assert_eq!(Design::Uncompressed.name(), "uncompressed");
        assert_eq!(Design::Ideal.name(), "ideal");
        assert_eq!(Design::explicit(false).name(), "cram-explicit");
        assert_eq!(Design::explicit(true).name(), "cram-explicit-rowopt");
        assert_eq!(Design::Implicit.name(), "cram-static");
        assert_eq!(Design::Dynamic.name(), "cram-dynamic");
        assert_eq!(Design::NextLinePrefetch.name(), "nextline-prefetch");
        assert_eq!(Design::tiered(false).name(), "tiered-uncomp");
        assert_eq!(Design::tiered(true).name(), "tiered-cram");
    }

    #[test]
    fn new_compositions_exist() {
        let dyn_far = Design::parse("tiered-cram-dyn").unwrap();
        assert_eq!(dyn_far.policy, Policy::Dynamic);
        assert_eq!(dyn_far.placement, Placement::Tiered);
        let expl_far = Design::parse("tiered-explicit").unwrap();
        assert_eq!(expl_far.policy, Policy::Explicit { row_opt: false });
        assert!(expl_far.is_tiered());
    }

    #[test]
    fn compresses_is_host_side_only() {
        assert!(!Design::Uncompressed.compresses());
        assert!(!Design::NextLinePrefetch.compresses());
        assert!(Design::Implicit.compresses());
        assert!(Design::Dynamic.compresses());
        assert!(Design::explicit(false).compresses());
        assert!(Design::Ideal.compresses());
        // tiered: the expander packs, the host does not
        for d in Design::all().into_iter().filter(Design::is_tiered) {
            assert!(!d.compresses(), "{}", d.name());
        }
    }
}
