//! The layout authority behind every executor: one enum over the two
//! layout families.
//!
//! [`CramEngine`] (4-line groups, marker metadata, CSI placements) and
//! [`LcpLayout`] (page-granular targets, exception regions, page-table
//! descriptors) answer the same questions — *where does a line live*,
//! *what does a writeback touch*, *what does a transfer weigh on the
//! wire* — with opposite metadata designs.  `LayoutEngine` is the seam:
//! enum dispatch (not a trait object) so every call monomorphizes to a
//! two-arm match the optimizer folds — the PR 3 hot-path throughput gate
//! holds, and `LayoutEngine::Cram` is *the existing engine moved behind
//! the interface line-for-line*: all pre-existing compositions stay
//! bit-identical (pinned by `cram_behind_the_seam_is_bit_identical`
//! below and the golden figure parity test).
//!
//! Family-specific machinery stays on the concrete types — CRAM's
//! static planners (`decide_packed_layout`, `plan_group_write`,
//! `probe_order`, …) and LCP's descriptor calls — reached through
//! [`LayoutEngine::as_cram`]/[`LayoutEngine::as_lcp`] in the policy
//! arms that know which family they run.  Only the shared surface
//! (codec state, wire sizes, layout queries, write bookkeeping)
//! dispatches here.

use crate::cram::group::Csi;
use crate::stats::CapacityStats;
use crate::workloads::SizeOracle;

use super::engine::CramEngine;
use super::lcp::LcpLayout;
use super::policy::{LinkCodec, Policy};

/// The two layout families (see module docs).
pub enum LayoutEngine {
    /// Group-granular CRAM: the pre-refactor engine, unchanged.
    Cram(CramEngine),
    /// Page-granular LCP: predictable offsets + exception region.
    Lcp(LcpLayout),
}

impl LayoutEngine {
    /// The family a policy runs on: [`Policy::Lcp`] gets the page
    /// layout; every other policy keeps the group engine (including
    /// non-compressing baselines, which simply never consult it).
    pub fn for_policy(policy: Policy, link_codec: LinkCodec) -> Self {
        match policy {
            Policy::Lcp => LayoutEngine::Lcp(LcpLayout::with_link_codec(link_codec)),
            _ => LayoutEngine::Cram(CramEngine::with_link_codec(link_codec)),
        }
    }

    /// The CRAM engine, if this is the group family.
    #[inline]
    pub fn as_cram(&self) -> Option<&CramEngine> {
        match self {
            LayoutEngine::Cram(e) => Some(e),
            LayoutEngine::Lcp(_) => None,
        }
    }

    #[inline]
    pub fn as_cram_mut(&mut self) -> Option<&mut CramEngine> {
        match self {
            LayoutEngine::Cram(e) => Some(e),
            LayoutEngine::Lcp(_) => None,
        }
    }

    /// The LCP layout, if this is the page family.
    #[inline]
    pub fn as_lcp(&self) -> Option<&LcpLayout> {
        match self {
            LayoutEngine::Lcp(l) => Some(l),
            LayoutEngine::Cram(_) => None,
        }
    }

    #[inline]
    pub fn as_lcp_mut(&mut self) -> Option<&mut LcpLayout> {
        match self {
            LayoutEngine::Lcp(l) => Some(l),
            LayoutEngine::Cram(_) => None,
        }
    }

    /// The link codec this layout serves wire sizes for.
    #[inline]
    pub fn link_codec(&self) -> LinkCodec {
        match self {
            LayoutEngine::Cram(e) => e.link_codec(),
            LayoutEngine::Lcp(l) => l.link_codec(),
        }
    }

    /// Engage or release the watchdog's raw-wire override (both
    /// families honor it identically).
    #[inline]
    pub fn set_degraded_raw(&mut self, on: bool) {
        match self {
            LayoutEngine::Cram(e) => e.set_degraded_raw(on),
            LayoutEngine::Lcp(l) => l.set_degraded_raw(on),
        }
    }

    /// Wire bytes of one line shipped alone.
    #[inline]
    pub fn line_wire_bytes(&self, oracle: &mut SizeOracle, line: u64) -> u64 {
        match self {
            LayoutEngine::Cram(e) => e.line_wire_bytes(oracle, line),
            LayoutEngine::Lcp(l) => l.line_wire_bytes(oracle, line),
        }
    }

    /// Wire bytes of the packed block at CSI slot `loc` — a CRAM-shaped
    /// query; the page family (whose blocks are addressed by page/slot,
    /// see [`LcpLayout::block_wire_bytes`]) serves the single line.
    #[inline]
    pub fn block_wire_bytes(&self, oracle: &mut SizeOracle, base: u64, csi: Csi, loc: u8) -> u64 {
        match self {
            LayoutEngine::Cram(e) => e.block_wire_bytes(oracle, base, csi, loc),
            LayoutEngine::Lcp(l) => l.line_wire_bytes(oracle, base + loc as u64),
        }
    }

    /// Wire bytes of one metadata-region crossing (CSI lines and LCP
    /// descriptors are both dense small-field data: 4:1).
    #[inline]
    pub fn meta_wire_bytes(&self) -> u64 {
        match self {
            LayoutEngine::Cram(e) => e.meta_wire_bytes(),
            LayoutEngine::Lcp(l) => l.meta_wire_bytes(),
        }
    }

    /// Wire bytes of one command/header flit.
    #[inline]
    pub fn cmd_wire_bytes(&self) -> u64 {
        match self {
            LayoutEngine::Cram(e) => e.cmd_wire_bytes(),
            LayoutEngine::Lcp(l) => l.cmd_wire_bytes(),
        }
    }

    /// Current CSI of `line`'s group.  The page family has no CSI: its
    /// lines always read as uncompressed to group-shaped callers
    /// (promotion, audits), which matches how LCP data is addressed —
    /// per line, never per CRAM block.
    #[inline]
    pub fn csi_of_line(&self, line: u64) -> Csi {
        match self {
            LayoutEngine::Cram(e) => e.csi_of_line(line),
            LayoutEngine::Lcp(_) => Csi::Uncompressed,
        }
    }

    #[inline]
    pub fn csi_of_group(&self, group: u64) -> Csi {
        match self {
            LayoutEngine::Cram(e) => e.csi_of_group(group),
            LayoutEngine::Lcp(_) => Csi::Uncompressed,
        }
    }

    /// Record a group layout (CRAM family; a no-op for pages, which
    /// track descriptors through [`LcpLayout::note_dirty_write`]).
    #[inline]
    pub fn commit(&mut self, group: u64, csi: Csi) {
        if let LayoutEngine::Cram(e) = self {
            e.commit(group, csi);
        }
    }

    /// Forget a group's layout, returning it (CRAM family).
    #[inline]
    pub fn remove(&mut self, group: u64) -> Option<Csi> {
        match self {
            LayoutEngine::Cram(e) => e.remove(group),
            LayoutEngine::Lcp(_) => None,
        }
    }

    /// Count one group write toward the compression fraction.
    #[inline]
    pub fn note_group_write(&mut self, csi: Csi) {
        if let LayoutEngine::Cram(e) = self {
            e.note_group_write(csi);
        }
    }

    /// Record a group layout without the write bookkeeping (the
    /// byte-accurate store's commit; CRAM family — pages track
    /// descriptors through [`LcpLayout::note_dirty_write`]).
    #[inline]
    pub fn record(&mut self, group: u64, csi: Csi) {
        if let LayoutEngine::Cram(e) = self {
            e.record(group, csi);
        }
    }

    /// Every recorded group as `(group index, csi)` — the re-encode
    /// sweep's walk (cold path: boxed dispatch is fine here).  The page
    /// family holds no groups.
    pub fn groups(&self) -> Box<dyn Iterator<Item = (u64, Csi)> + '_> {
        match self {
            LayoutEngine::Cram(e) => Box::new(e.groups()),
            LayoutEngine::Lcp(_) => Box::new(std::iter::empty()),
        }
    }

    /// Groups written / packed (the tier's far-side telemetry; the page
    /// family reports dirty line writes and compressed-page counts).
    #[inline]
    pub fn groups_written(&self) -> u64 {
        match self {
            LayoutEngine::Cram(e) => e.groups_written,
            LayoutEngine::Lcp(l) => l.lines_written,
        }
    }

    #[inline]
    pub fn groups_compressed(&self) -> u64 {
        match self {
            LayoutEngine::Cram(e) => e.groups_compressed,
            LayoutEngine::Lcp(l) => l.recompactions,
        }
    }

    /// Fraction of write-side units that produced a compressed layout
    /// (groups for CRAM, pages for LCP).
    pub fn compression_frac(&self) -> f64 {
        match self {
            LayoutEngine::Cram(e) => e.compression_frac(),
            LayoutEngine::Lcp(l) => l.compression_frac(),
        }
    }

    /// The effective-capacity ledger — only the page family grows
    /// capacity, so the group family reports `None` (honest telemetry:
    /// CRAM trades capacity for bandwidth by design).
    pub fn capacity_snapshot(&self) -> Option<CapacityStats> {
        match self {
            LayoutEngine::Cram(_) => None,
            LayoutEngine::Lcp(l) => Some(l.capacity_snapshot()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::splitmix64;

    #[test]
    fn family_selection_follows_policy() {
        for p in [
            Policy::Uncompressed,
            Policy::Ideal,
            Policy::Explicit { row_opt: false },
            Policy::Implicit,
            Policy::Dynamic,
            Policy::NextLinePrefetch,
        ] {
            assert!(LayoutEngine::for_policy(p, LinkCodec::Raw).as_cram().is_some());
        }
        let l = LayoutEngine::for_policy(Policy::Lcp, LinkCodec::Compressed);
        assert!(l.as_lcp().is_some());
        assert!(l.as_cram().is_none());
        assert_eq!(l.link_codec(), LinkCodec::Compressed);
    }

    /// The refactor-seam cross-check the issue asks for: a randomized
    /// layout-decision sequence driven through `LayoutEngine::Cram`
    /// must be byte-identical to the same sequence on a bare
    /// (pre-refactor) `CramEngine` — the seam adds dispatch, never
    /// behavior.
    #[test]
    fn cram_behind_the_seam_is_bit_identical() {
        let mut bare = CramEngine::new();
        let mut seam = LayoutEngine::for_policy(Policy::Implicit, LinkCodec::Raw);
        for i in 0..5_000u64 {
            let r = splitmix64(0xC4A9, i);
            let group = r % 256;
            let present = [r & 1 != 0, r & 2 != 0, r & 4 != 0, r & 8 != 0];
            let sizes = core::array::from_fn(|k| 2 + (splitmix64(r, k as u64) % 63) as u32);
            // the decision statics are shared by construction; drive the
            // stateful surface (commit / csi_of / remove) through both
            let old_bare = bare.csi_of_group(group);
            let old_seam = seam.csi_of_group(group);
            assert_eq!(old_bare, old_seam);
            let new = CramEngine::decide_packed_layout(old_bare, present, sizes);
            bare.commit(group, new);
            seam.commit(group, new);
            bare.note_group_write(new);
            seam.note_group_write(new);
            assert_eq!(bare.csi_of_group(group), seam.csi_of_group(group), "iter {i}");
            if r % 17 == 0 {
                assert_eq!(bare.remove(group), seam.remove(group));
            }
        }
        assert_eq!(bare.groups_written, seam.groups_written());
        assert_eq!(bare.groups_compressed, seam.groups_compressed());
        assert!((bare.compression_frac() - seam.compression_frac()).abs() < 1e-15);
    }

    #[test]
    fn lcp_answers_the_shared_surface() {
        let mut l = LayoutEngine::for_policy(Policy::Lcp, LinkCodec::Raw);
        // CSI-shaped queries degrade to uncompressed, never panic
        assert_eq!(l.csi_of_line(123), Csi::Uncompressed);
        assert_eq!(l.csi_of_group(3), Csi::Uncompressed);
        assert_eq!(l.remove(3), None);
        l.commit(3, Csi::Quad); // no-op
        assert_eq!(l.csi_of_group(3), Csi::Uncompressed);
        l.note_group_write(Csi::Quad); // no-op
        assert_eq!(l.groups_written(), 0);
        assert!(l.capacity_snapshot().is_some(), "the page family reports capacity");
        assert!(
            LayoutEngine::for_policy(Policy::Implicit, LinkCodec::Raw)
                .capacity_snapshot()
                .is_none(),
            "the group family does not"
        );
    }
}
